"""Layer-2 correctness: model shapes, loss sanity, train-step descent, and
the AOT HLO-text contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import Config, forward, init_params, loss_fn, param_shapes, train_step


@pytest.fixture(scope="module")
def small_cfg():
    return Config(vocab=32, d_model=16, n_heads=2, n_layers=1, d_ff=32, seq=8, batch=2, lr=0.2)


@pytest.fixture(scope="module")
def small_setup(small_cfg):
    params = init_params(small_cfg, seed=0)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, small_cfg.vocab, size=(small_cfg.batch, small_cfg.seq)), jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    return params, tokens, targets


def test_param_shapes_match_init(small_cfg):
    params = init_params(small_cfg)
    shapes = param_shapes(small_cfg)
    assert len(params) == len(shapes)
    for p, (name, s) in zip(params, shapes):
        assert p.shape == s, name


def test_forward_shape_and_finite(small_cfg, small_setup):
    params, tokens, _ = small_setup
    logits = forward(small_cfg, params, tokens)
    assert logits.shape == (small_cfg.batch, small_cfg.seq, small_cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform(small_cfg, small_setup):
    params, tokens, targets = small_setup
    loss = loss_fn(small_cfg, params, tokens, targets)
    # Near-uniform logits at init -> loss ≈ ln(vocab).
    assert abs(float(loss) - np.log(small_cfg.vocab)) < 0.5


def test_train_step_decreases_loss(small_cfg, small_setup):
    params, tokens, targets = small_setup
    out = train_step(small_cfg, params, tokens, targets)
    loss0, params = out[0], out[1:]
    for _ in range(10):
        out = train_step(small_cfg, params, tokens, targets)
        params = out[1:]
    loss_n = loss_fn(small_cfg, params, tokens, targets)
    assert float(loss_n) < float(loss0), (float(loss0), float(loss_n))


def test_causality_of_forward(small_cfg, small_setup):
    params, tokens, _ = small_setup
    logits1 = forward(small_cfg, params, tokens)
    # Perturb the last token: logits for earlier positions must not change.
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % small_cfg.vocab)
    logits2 = forward(small_cfg, params, tokens2)
    np.testing.assert_allclose(logits1[:, :-1, :], logits2[:, :-1, :], rtol=1e-5, atol=1e-5)


def test_hlo_text_lowering_contract(small_cfg, small_setup):
    """The aot.py path: HLO text, 1-tuple outputs, parseable header."""
    from compile.aot import to_hlo_text

    params, tokens, targets = small_setup

    def loss_flat(tok, tgt, *ps):
        return (loss_fn(small_cfg, tuple(ps), tok, tgt),)

    text = to_hlo_text(loss_flat, tokens, targets, *params)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True -> root is a tuple
    assert "tuple(" in text
