"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles, with
hypothesis sweeps over shapes (the CORE kernel correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention, vmem_footprint_bytes
from compile.kernels.layernorm import layernorm
from compile.kernels.ref import attention_ref, layernorm_ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestAttention:
    def test_matches_ref_basic(self):
        q, k, v = (rand(i, (2, 4, 16, 8)) for i in range(3))
        got = attention(q, k, v)
        want = attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        q, k, v = (rand(i + 10, (1, 2, 8, 4)) for i in range(3))
        got = attention(q, k, v, causal=False)
        want = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_causal_mask_blocks_future(self):
        # Output at position 0 must not depend on later keys/values.
        q, k, v = (rand(i + 20, (1, 1, 8, 4)) for i in range(3))
        out1 = attention(q, k, v)
        k2 = k.at[:, :, 4:, :].set(999.0)
        v2 = v.at[:, :, 4:, :].set(-999.0)
        out2 = attention(q, k2, v2)
        np.testing.assert_allclose(out1[:, :, :4, :], out2[:, :, :4, :], rtol=1e-5, atol=1e-5)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        t=st.sampled_from([4, 8, 16, 32]),
        d=st.sampled_from([4, 8, 16]),
        causal=st.booleans(),
    )
    def test_shape_sweep(self, b, h, t, d, causal):
        q, k, v = (rand(i + b + h + t + d, (b, h, t, d)) for i in range(3))
        got = attention(q, k, v, causal=causal)
        want = attention_ref(q, k, v, causal=causal)
        assert got.shape == (b, h, t, d)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

    def test_rows_sum_to_one_via_uniform_v(self):
        # With v = ones, attention output must be exactly ones (probs sum 1).
        q, k = (rand(i + 30, (1, 2, 8, 4)) for i in range(2))
        v = jnp.ones((1, 2, 8, 4), jnp.float32)
        out = attention(q, k, v)
        np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5, atol=1e-5)

    def test_vmem_footprint_estimate(self):
        # The DESIGN.md §Perf numbers: [T=32, D=16] block must fit well
        # within a 16 MiB VMEM budget.
        assert vmem_footprint_bytes(32, 16) < 16 * 1024 * 1024
        assert vmem_footprint_bytes(32, 16) == 4 * (4 * 32 * 16 + 2 * 32 * 32)


class TestLayerNorm:
    def test_matches_ref(self):
        x = rand(1, (16, 32))
        g = rand(2, (32,)) * 0.1 + 1.0
        b = rand(3, (32,)) * 0.1
        np.testing.assert_allclose(layernorm(x, g, b), layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5)

    @settings(max_examples=12, deadline=None)
    @given(n=st.sampled_from([1, 2, 8, 24, 64]), d=st.sampled_from([8, 16, 64]))
    def test_shape_sweep(self, n, d):
        x = rand(n + d, (n, d))
        g = jnp.ones((d,), jnp.float32)
        b = jnp.zeros((d,), jnp.float32)
        got = layernorm(x, g, b)
        want = layernorm_ref(x, g, b)
        assert got.shape == (n, d)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)

    def test_normalization_invariants(self):
        x = rand(7, (8, 64)) * 10 + 5
        out = layernorm(x, jnp.ones((64,)), jnp.zeros((64,)))
        np.testing.assert_allclose(jnp.mean(out, axis=-1), jnp.zeros(8), atol=1e-4)
        np.testing.assert_allclose(jnp.std(out, axis=-1), jnp.ones(8), atol=1e-2)

    def test_odd_row_counts_fall_back_to_smaller_blocks(self):
        x = rand(9, (7, 16))
        got = layernorm(x, jnp.ones((16,)), jnp.zeros((16,)))
        want = layernorm_ref(x, jnp.ones((16,)), jnp.zeros((16,)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
