"""Layer 2: a small causal transformer LM in JAX, calling the Layer-1
Pallas kernels. Build-time only — `aot.py` lowers these functions to HLO
text; the Rust runtime executes them. Python never runs on the request
path.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.layernorm import layernorm


class Config(NamedTuple):
    vocab: int = 128
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 128
    seq: int = 32
    batch: int = 8
    lr: float = 0.1


def param_shapes(cfg: Config):
    """Ordered (name, shape) list — the flat param convention shared with
    the Rust driver."""
    shapes = [("tok_emb", (cfg.vocab, cfg.d_model)), ("pos_emb", (cfg.seq, cfg.d_model))]
    for i in range(cfg.n_layers):
        shapes += [
            (f"l{i}.wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{i}.wo", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln1_g", (cfg.d_model,)),
            (f"l{i}.ln1_b", (cfg.d_model,)),
            (f"l{i}.w1", (cfg.d_model, cfg.d_ff)),
            (f"l{i}.w2", (cfg.d_ff, cfg.d_model)),
            (f"l{i}.ln2_g", (cfg.d_model,)),
            (f"l{i}.ln2_b", (cfg.d_model,)),
        ]
    shapes += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return shapes


def init_params(cfg: Config, seed: int = 0):
    """Deterministic init, returned as a flat tuple in param_shapes order."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b",)):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            scale = 0.08
            out.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return tuple(out)


def _ln2d(x, g, b):
    """LayerNorm via the Pallas kernel, reshaping to rows."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    return layernorm(flat, g, b).reshape(shape)


def forward(cfg: Config, params, tokens):
    """Logits for token ids [B, T] -> [B, T, vocab]."""
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    b, t = tokens.shape
    x = tok_emb[tokens] + pos_emb[None, :t, :]
    for _ in range(cfg.n_layers):
        wqkv, wo, ln1_g, ln1_b, w1, w2, ln2_g, ln2_b = (next(it) for _ in range(8))
        h = _ln2d(x, ln1_g, ln1_b)
        qkv = h @ wqkv  # [B, T, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        dh = cfg.d_model // cfg.n_heads
        def heads(z):
            return z.reshape(b, t, cfg.n_heads, dh).transpose(0, 2, 1, 3)
        att = attention(heads(q), heads(k), heads(v), causal=True)  # [B,H,T,dh]
        att = att.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        x = x + att @ wo
        h2 = _ln2d(x, ln2_g, ln2_b)
        x = x + jax.nn.gelu(h2 @ w1) @ w2
    lnf_g = next(it)
    lnf_b = next(it)
    x = _ln2d(x, lnf_g, lnf_b)
    return x @ tok_emb.T  # weight tying


def loss_fn(cfg: Config, params, tokens, targets):
    logits = forward(cfg, params, tokens)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - picked)


def train_step(cfg: Config, params, tokens, targets):
    """(loss, new_params...) with inline SGD — the whole step is one HLO."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)
    new_params = tuple(p - cfg.lr * g for p, g in zip(params, grads))
    return (loss,) + new_params


@functools.lru_cache(maxsize=None)
def default_config():
    return Config()
