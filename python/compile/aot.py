"""AOT lowering: JAX functions -> HLO **text** artifacts for the Rust
runtime (`rust/src/runtime`). Runs once at build time (`make artifacts`).

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids. See /opt/xla-example/README.md.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.attention import attention
from .kernels.layernorm import layernorm
from .model import Config, init_params, loss_fn, param_shapes, train_step


def to_hlo_text(fn, *example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_str(shape) -> str:
    return ",".join(str(d) for d in shape) if shape else "scalar"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "goldens"), exist_ok=True)

    cfg = Config()
    manifest = []

    def emit(name, fn, example_args, n_outputs, out_shapes):
        text = to_hlo_text(fn, *example_args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
        ins = ";".join(shape_str(a.shape) for a in example_args)
        outs = ";".join(shape_str(s) for s in out_shapes)
        manifest.append(f"{name} {fname} {n_outputs} in={ins} out={outs}")
        print(f"wrote {fname} ({len(text)} chars)")

    # ---- Layer-1 kernels as standalone artifacts (fused-op registry) ----
    bhtd = (2, cfg.n_heads, cfg.seq, cfg.d_model // cfg.n_heads)
    q = jnp.zeros(bhtd, jnp.float32)
    emit("attention", lambda a, b, c: (attention(a, b, c),), (q, q, q), 1, [bhtd])

    nd = (cfg.batch * cfg.seq, cfg.d_model)
    x = jnp.zeros(nd, jnp.float32)
    g = jnp.ones((cfg.d_model,), jnp.float32)
    emit("layernorm", lambda a, b, c: (layernorm(a, b, c),), (x, g, g), 1, [nd])

    # ---- Layer-2 model ----
    params = init_params(cfg, seed=0)
    tokens = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    targets = jnp.zeros((cfg.batch, cfg.seq), jnp.int32)
    pshapes = [s for _, s in param_shapes(cfg)]

    # init(): no inputs -> params tuple (constants baked into HLO).
    emit("init_params", lambda: init_params(cfg, seed=0), (), len(pshapes), pshapes)

    # loss(tokens, targets, *params) -> (loss,)
    def loss_flat(tok, tgt, *ps):
        return (loss_fn(cfg, tuple(ps), tok, tgt),)

    emit("loss", loss_flat, (tokens, targets) + params, 1, [()])

    # train_step(tokens, targets, *params) -> (loss, *new_params)
    def step_flat(tok, tgt, *ps):
        return train_step(cfg, tuple(ps), tok, tgt)

    emit("train_step", step_flat, (tokens, targets) + params, 1 + len(pshapes), [()] + pshapes)

    # ---- goldens: deterministic first-step loss for the Rust driver ----
    rng = np.random.RandomState(1234)
    tok_np = rng.randint(0, cfg.vocab, size=(cfg.batch, cfg.seq)).astype(np.int32)
    tgt_np = np.roll(tok_np, -1, axis=1).astype(np.int32)
    step0 = step_flat(jnp.asarray(tok_np), jnp.asarray(tgt_np), *params)
    loss0 = float(step0[0])
    with open(os.path.join(out, "goldens", "first_step_loss.txt"), "w") as f:
        f.write(f"{loss0}\n")
    with open(os.path.join(out, "goldens", "first_batch_tokens.txt"), "w") as f:
        f.write(" ".join(str(int(v)) for v in tok_np.reshape(-1)) + "\n")
    print(f"golden first-step loss: {loss0:.6f} (ln(vocab)={np.log(cfg.vocab):.4f})")

    # config line for the Rust driver
    manifest.append(
        f"# config vocab={cfg.vocab} d_model={cfg.d_model} n_heads={cfg.n_heads} "
        f"n_layers={cfg.n_layers} seq={cfg.seq} batch={cfg.batch} lr={cfg.lr}"
    )
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("# name file n_outputs in=<shapes> out=<shapes>\n")
        f.write("\n".join(manifest) + "\n")
    print(f"manifest with {len(manifest)} entries")


if __name__ == "__main__":
    main()
