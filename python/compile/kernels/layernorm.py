"""Layer 1: fused LayerNorm as a Pallas kernel.

Grid over row-blocks: each program instance normalizes a [ROWS_PER_BLOCK, D]
tile in VMEM (mean/variance/scale in one pass over the tile — the classic
fusion that avoids materializing mean/var in HBM). `interpret=True` for the
CPU testbed (see attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]  # [ROWS, D]
    g = g_ref[...]  # [D]
    b = b_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mean) / jnp.sqrt(var + eps) * g + b


def _layernorm_impl(x, gamma, beta, eps=1e-5, block_rows=8):
    n, d = x.shape
    while n % block_rows != 0:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    grid = (n // block_rows,)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)


@jax.custom_vjp
def layernorm(x, gamma, beta):
    """Fused layernorm. x: [N, D] f32; gamma/beta: [D].

    Pallas forward; analytic reference VJP backward (interpret-mode
    pallas_call has no reverse-mode rule).
    """
    return _layernorm_impl(x, gamma, beta)


def _ln_fwd(x, gamma, beta):
    return _layernorm_impl(x, gamma, beta), (x, gamma, beta)


def _ln_bwd(res, g):
    from .ref import layernorm_ref

    x, gamma, beta = res
    _, vjp = jax.vjp(layernorm_ref, x, gamma, beta)
    return vjp(g)


layernorm.defvjp(_ln_fwd, _ln_bwd)
