"""Layer 1: fused causal attention as a Pallas kernel.

TPU-style adaptation of the FlashAttention insight the paper cites as its
motivating example (DESIGN.md §Hardware-Adaptation): instead of
warps/shared-memory tiling, the grid maps one (batch, head) pair per
program instance, the Q/K/V head-slices are staged into VMEM via
`BlockSpec`, QKᵀ hits the MXU, and the softmax is computed with the
numerically-stable row-max rewrite before the PV matmul — one fused kernel,
no [T, T] intermediate ever leaving VMEM.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for this testbed; real-TPU
performance is *estimated* in DESIGN.md §Perf from the VMEM footprint and
MXU utilization of these block shapes.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool):
    # One (batch, head) slice: q/k/v refs are [T, D] VMEM blocks.
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    # MXU matmul, then stable softmax entirely in VMEM.
    scores = jnp.dot(q, k.T) * scale  # [T, T]
    if causal:
        t = q.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        scores = jnp.where(row >= col, scores, jnp.float32(-1e30))
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v)


def _attention_impl(q, k, v, causal):
    b, h, t, d = q.shape
    grid = (b, h)
    spec = pl.BlockSpec((1, 1, t, d), lambda i, j: (i, j, 0, 0))
    kernel = functools.partial(_attn_kernel_wrapped, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), jnp.float32),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal=True):
    """Fused attention. q/k/v: [B, H, T, D] f32 -> [B, H, T, D].

    Forward runs the Pallas kernel; the backward pass uses the analytic
    VJP of the reference formulation (interpret-mode pallas_call has no
    reverse-mode rule — on a real TPU the backward would be a second
    Pallas kernel, see DESIGN.md §Hardware-Adaptation).
    """
    return _attention_impl(q, k, v, causal)


def _attention_fwd(q, k, v, causal):
    return _attention_impl(q, k, v, causal), (q, k, v)


def _attention_bwd(causal, res, g):
    from .ref import attention_ref

    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: attention_ref(a, b, c, causal), q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)


def _attn_kernel_wrapped(q_ref, k_ref, v_ref, o_ref, *, causal):
    # Block shapes come in as [1, 1, T, D]; squeeze the unit dims.
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    scores = jnp.dot(q, k.T) * scale
    if causal:
        t = q.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
        scores = jnp.where(row >= col, scores, jnp.float32(-1e30))
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v)


def vmem_footprint_bytes(t: int, d: int) -> int:
    """Estimated VMEM bytes per program instance (DESIGN.md §Perf):
    q+k+v+o blocks [T, D] + scores/probs [T, T], all f32."""
    return 4 * (4 * t * d + 2 * t * t)
