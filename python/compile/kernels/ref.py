"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness).

These are the ground truth the kernels are pytest-verified against, and the
semantics the Rust eager backend mirrors.
"""

import jax.numpy as jnp


def softmax_last(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v, causal=True):
    """Scaled dot-product attention with optional causal mask.

    q, k, v: [B, H, T, D] (f32). Returns [B, H, T, D].
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        t = q.shape[-2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    return jnp.einsum("bhts,bhsd->bhtd", softmax_last(scores), v)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis. x: [..., D]; gamma/beta: [D]."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
