//! Property-style randomized tests (in-tree harness; the offline
//! environment has no proptest — see DESIGN.md §8).
//!
//! The tensor-graph properties run over the **seeded graph generator** in
//! `tests/support` (shared with `tests/conformance.rs`): broadcasting
//! binary ops, matmuls across the k-blocked kernel threshold, and
//! const-operand (folding) shapes, all deterministic per seed.

mod support;

use std::sync::Arc;

use depyf::backend::eager::{self, ExecPlan};
use depyf::bytecode::{decode, encode, BinOp, CmpOp, Instr, IsaVersion, UnOp};
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::graph::{parse_graph, render_graph};
use depyf::tensor::Rng;
use depyf::vm::Vm;

/// Generate a random but *well-formed* instruction stream: valid jump
/// targets, ends with a return.
fn random_stream(rng: &mut Rng, len: usize) -> Vec<Instr> {
    let mut out = Vec::with_capacity(len + 1);
    for i in 0..len {
        let pick = rng.below(14);
        let arg = rng.below(300) as u32; // exercises EXTENDED_ARG
        let target = rng.below(len + 1) as u32;
        out.push(match pick {
            0 => Instr::LoadConst(arg),
            1 => Instr::LoadFast(arg % 32),
            2 => Instr::StoreFast(arg % 32),
            3 => Instr::LoadGlobal(arg % 64),
            4 => Instr::Binary(match rng.below(8) {
                0 => BinOp::Add, 1 => BinOp::Sub, 2 => BinOp::Mul, 3 => BinOp::Div,
                4 => BinOp::FloorDiv, 5 => BinOp::Mod, 6 => BinOp::Pow, _ => BinOp::MatMul,
            }),
            5 => Instr::Compare(match rng.below(6) {
                0 => CmpOp::Lt, 1 => CmpOp::Le, 2 => CmpOp::Eq, 3 => CmpOp::Ne, 4 => CmpOp::Gt, _ => CmpOp::Ge,
            }),
            6 => Instr::Unary(match rng.below(3) { 0 => UnOp::Neg, 1 => UnOp::Not, _ => UnOp::Pos }),
            7 => Instr::Jump(target),
            8 => Instr::PopJumpIfFalse(target),
            9 => Instr::PopJumpIfTrue(target),
            10 => Instr::Call(arg % 8),
            11 => Instr::BuildList(arg % 8),
            12 => Instr::ContainsOp(rng.below(2) == 0),
            _ => if i + 1 < len { Instr::ForIter(((i + 1) + rng.below(len - i)) as u32) } else { Instr::Nop },
        });
    }
    out.push(Instr::ReturnValue);
    out
}

/// decode(encode(stream)) == stream for arbitrary well-formed streams, on
/// every ISA version — 200 random cases each.
#[test]
fn fuzz_encode_decode_roundtrip() {
    let mut rng = Rng::new(0xDEC0DE);
    for case in 0..200 {
        let len = 1 + rng.below(60);
        let stream = random_stream(&mut rng, len);
        for v in IsaVersion::ALL {
            let raw = encode(&stream, v);
            let back = decode(&raw, v).unwrap_or_else(|e| panic!("case {} on {}: {}\n{:?}", case, v, e, stream));
            assert_eq!(back, stream, "case {} on {}", case, v);
        }
    }
}

/// Random arithmetic expressions: VM semantics must be stable across all
/// four ISA encodings (differential testing of the encoder/VM).
#[test]
fn fuzz_arith_cross_version() {
    let mut rng = Rng::new(7777);
    for _ in 0..60 {
        // Build a random integer expression program.
        let a = rng.below(50) as i64;
        let b = 1 + rng.below(9) as i64;
        let c = 1 + rng.below(20) as i64; // nonzero: expressions may divide by z
        let ops = ["+", "-", "*", "//", "%"];
        let o1 = ops[rng.below(5)];
        let o2 = ops[rng.below(5)];
        let src = format!("x = {}\ny = {}\nz = {}\nprint(x {} y {} z, x > y, y != z)\n", a, b, c, o1, o2);
        let mut outs = Vec::new();
        for v in IsaVersion::ALL {
            let vm = Vm::new();
            vm.exec_source(&src, v).unwrap_or_else(|e| panic!("{}\n{}", e, src));
            outs.push(vm.take_output());
        }
        assert!(outs.windows(2).all(|w| w[0] == w[1]), "{}\n{:?}", src, outs);
    }
}

/// Guard-overflow behavior: a function whose guard always misses keeps
/// producing correct results; at the cache limit the LRU guard entry is
/// evicted and the fresh specialization compiles (the table never grows
/// past the limit, and nothing runs uncompiled).
#[test]
fn cache_limit_evicts_lru_instead_of_running_uncompiled() {
    let src = "\
counter = 0
def f(x, k):
    return (x * k).sum()
t = torch.ones([2])
total = 0.0
for k in range(20):
    total += f(t, k).item()
print(total)
";
    let plain = Vm::new();
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig { cache_limit: 4, ..Default::default() });
    vm.eval_hook = Some(d.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    assert_eq!(vm.take_output(), expected);
    // Every distinct k recompiles (k is ConstEq-guarded); the table holds
    // at most cache_limit entries thanks to LRU eviction.
    assert_eq!(d.metrics.captures.get(), 20, "{:?}", d.metrics.report());
    assert_eq!(d.metrics.evictions.get(), 20 - 4, "{:?}", d.metrics.report());
    assert!(d.metrics.guard_failures.get() >= 1);
    assert!(d.log().iter().any(|l| l.contains("evicted LRU entry")), "{:?}", d.log());
}

/// The thrash backstop: a code object cycling through unbounded
/// specializations stops recompiling after cache_limit * 8 evictions and
/// runs uncompiled from then on — correct output, bounded compile work.
#[test]
fn sustained_guard_cache_thrashing_trips_the_skip_backstop() {
    // cache_limit 2: backstop arms at 16 evictions (18 captures); the 60
    // distinct k values would otherwise compile 60 times.
    let src = "\
def f(x, k):
    return (x * k).sum()
t = torch.ones([2])
total = 0.0
for k in range(60):
    total += f(t, k).item()
print(total)
";
    let plain = Vm::new();
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig { cache_limit: 2, ..Default::default() });
    vm.eval_hook = Some(d.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    assert_eq!(vm.take_output(), expected);
    assert_eq!(d.metrics.evictions.get(), 16, "{:?}", d.metrics.report());
    assert_eq!(d.metrics.captures.get(), 18, "compiles stop at the backstop: {:?}", d.metrics.report());
    assert!(d.log().iter().any(|l| l.contains("thrashing")), "{:?}", d.log());
}

/// Eviction respects recency: re-dispatching to an old entry keeps it
/// cached while colder entries get evicted, so a hot shape stays a cache
/// hit even after many one-off specializations flow through.
#[test]
fn lru_keeps_hot_entries_dispatchable() {
    // Shape [2] is hot (re-used every iteration); shapes [3]..[12] are
    // one-off. With cache_limit 4, the hot entry must survive the churn.
    let src = "\
def f(x):
    return (x * 2).sum()
hot = torch.ones([2])
total = 0.0
for n in range(3, 13):
    total += f(hot).item()
    total += f(torch.ones([n])).item()
total += f(hot).item()
print(total)
";
    let plain = Vm::new();
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig { cache_limit: 4, ..Default::default() });
    vm.eval_hook = Some(d.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    assert_eq!(vm.take_output(), expected);
    // 1 hot capture + 10 one-off captures; the hot entry is dispatched on
    // every loop iteration so it is never the LRU victim.
    assert_eq!(d.metrics.captures.get(), 11, "{:?}", d.metrics.report());
    assert!(d.metrics.evictions.get() >= 7, "{:?}", d.metrics.report());
    // The hot entry's repeated dispatches are all cache hits: 9 in-loop
    // re-dispatches plus the final call.
    assert!(d.metrics.cache_hits.get() >= 10, "{:?}", d.metrics.report());
}

/// The planned eager executor (const pre-materialization, liveness,
/// stride-based broadcasting, k-blocked matmul, fast paths) must be
/// **bitwise** equal to the naive traced walk on 200 generated graphs —
/// the traced walk is the oracle the fast paths are judged against.
#[test]
fn fuzz_exec_plan_matches_traced_oracle() {
    let mut gen = support::GraphGen::new(0xE5C_A1A);
    let mut rng = Rng::new(0xFEED);
    let mut fused_graphs = 0usize;
    for case in 0..200 {
        let g = Arc::new(gen.next_graph());
        let inputs = support::rand_inputs(&g, &mut rng);
        // ExecPlan::new fuses elementwise chains; the unfused plan is the
        // pre-fusion executor. Both must match the traced walk bitwise.
        let plan = ExecPlan::new(Arc::clone(&g));
        let unfused = ExecPlan::unfused(Arc::clone(&g));
        fused_graphs += (plan.fused_regions() > 0) as usize;
        let fast = plan.run(&inputs).unwrap_or_else(|e| panic!("case {} ({}): plan: {}", case, g.name, e));
        let slow =
            eager::execute(&g, &inputs).unwrap_or_else(|e| panic!("case {} ({}): oracle: {}", case, g.name, e));
        let mid = unfused
            .run(&inputs)
            .unwrap_or_else(|e| panic!("case {} ({}): unfused plan: {}", case, g.name, e));
        assert_eq!(fast.len(), slow.len(), "case {}", case);
        for ((f, s), m) in fast.iter().zip(slow.iter()).zip(mid.iter()) {
            assert_eq!(f.shape(), s.shape(), "case {} ({})", case, g.name);
            let fb: Vec<u32> = f.data().iter().map(|v| v.to_bits()).collect();
            let sb: Vec<u32> = s.data().iter().map(|v| v.to_bits()).collect();
            let mb: Vec<u32> = m.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(fb, sb, "case {} ({}): fused executor diverged bitwise", case, g.name);
            assert_eq!(mb, sb, "case {} ({}): unfused executor diverged bitwise", case, g.name);
        }
        // Planned execution must also be self-deterministic (arena reuse
        // must not leak state between calls).
        let again = plan.run(&inputs).unwrap();
        for (f, a) in fast.iter().zip(again.iter()) {
            assert_eq!(f.data(), a.data(), "case {}: second run differs", case);
        }
    }
    // The generator's elementwise chains must actually exercise fusion:
    // every 8th graph is a matmul+bias+tanh chain whose add/tanh pair
    // fuses by construction, so 25 fused graphs are guaranteed.
    assert!(fused_graphs >= 25, "only {}/200 generated graphs fused", fused_graphs);
}

/// The generator actually covers the features it exists for: true
/// broadcasting (operand shape mismatch), matmuls whose B panel crosses
/// the 64 KiB blocking threshold, and constant operands feeding ops.
#[test]
fn fuzz_generator_covers_broadcast_blocking_and_consts() {
    let mut gen = support::GraphGen::new(0x5EED_C0DE); // the conformance seed
    let (mut broadcast, mut big_mm, mut consts) = (0usize, 0usize, 0usize);
    for _ in 0..200 {
        let g = gen.next_graph();
        broadcast += support::has_broadcast(&g) as usize;
        big_mm += support::has_big_matmul(&g) as usize;
        consts += support::has_const_operand(&g) as usize;
    }
    // Every 8th graph is a big-matmul-with-bias graph by construction:
    // that alone guarantees 25 broadcasting and 25 blocked-matmul graphs.
    assert!(broadcast >= 25, "only {}/200 graphs broadcast", broadcast);
    assert!(big_mm >= 20, "only {}/200 graphs cross the matmul blocking threshold", big_mm);
    assert!(consts >= 10, "only {}/200 graphs have const operands", consts);
}

/// Lossless serialization property over generated graphs: the parsed
/// graph hashes identically and executes to bitwise-identical outputs.
#[test]
fn fuzz_graph_serde_round_trip_is_bit_exact() {
    let mut gen = support::GraphGen::new(0xD15C);
    let mut rng = Rng::new(0xD15C ^ 7);
    for case in 0..100 {
        let g = Arc::new(gen.next_graph());
        let back = Arc::new(
            parse_graph(&render_graph(&g))
                .unwrap_or_else(|e| panic!("case {} ({}): reparse: {}", case, g.name, e)),
        );
        assert_eq!(back.content_hash(), g.content_hash(), "case {} ({})", case, g.name);
        let inputs = support::rand_inputs(&g, &mut rng);
        let a = eager::execute(&g, &inputs).unwrap();
        let b = eager::execute(&back, &inputs).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.shape(), y.shape(), "case {}", case);
            let xb: Vec<u32> = x.data().iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "case {}: reparsed graph executed differently", case);
        }
    }
}

/// Error behavior must survive compilation: a runtime error inside a
/// compiled region surfaces identically (inline raise path).
#[test]
fn errors_survive_compilation() {
    let src = "def f(x):\n    if x.sum().item() > 0:\n        raise 'positive sum'\n    return x\nf(torch.ones([2]))\n";
    let plain = Vm::new();
    let e1 = plain.exec_source(src, IsaVersion::V310).unwrap_err();
    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(d);
    let e2 = vm.exec_source(src, IsaVersion::V310).unwrap_err();
    assert_eq!(e1.message, e2.message);
    assert!(e1.message.contains("positive sum"));
}
