//! Property-style randomized tests (in-tree harness; the offline
//! environment has no proptest — see DESIGN.md §8).

use depyf::bytecode::{decode, encode, BinOp, CmpOp, Instr, IsaVersion, UnOp};
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::tensor::Rng;
use depyf::vm::Vm;

/// Generate a random but *well-formed* instruction stream: valid jump
/// targets, ends with a return.
fn random_stream(rng: &mut Rng, len: usize) -> Vec<Instr> {
    let mut out = Vec::with_capacity(len + 1);
    for i in 0..len {
        let pick = rng.below(14);
        let arg = rng.below(300) as u32; // exercises EXTENDED_ARG
        let target = rng.below(len + 1) as u32;
        out.push(match pick {
            0 => Instr::LoadConst(arg),
            1 => Instr::LoadFast(arg % 32),
            2 => Instr::StoreFast(arg % 32),
            3 => Instr::LoadGlobal(arg % 64),
            4 => Instr::Binary(match rng.below(8) {
                0 => BinOp::Add, 1 => BinOp::Sub, 2 => BinOp::Mul, 3 => BinOp::Div,
                4 => BinOp::FloorDiv, 5 => BinOp::Mod, 6 => BinOp::Pow, _ => BinOp::MatMul,
            }),
            5 => Instr::Compare(match rng.below(6) {
                0 => CmpOp::Lt, 1 => CmpOp::Le, 2 => CmpOp::Eq, 3 => CmpOp::Ne, 4 => CmpOp::Gt, _ => CmpOp::Ge,
            }),
            6 => Instr::Unary(match rng.below(3) { 0 => UnOp::Neg, 1 => UnOp::Not, _ => UnOp::Pos }),
            7 => Instr::Jump(target),
            8 => Instr::PopJumpIfFalse(target),
            9 => Instr::PopJumpIfTrue(target),
            10 => Instr::Call(arg % 8),
            11 => Instr::BuildList(arg % 8),
            12 => Instr::ContainsOp(rng.below(2) == 0),
            _ => if i + 1 < len { Instr::ForIter(((i + 1) + rng.below(len - i)) as u32) } else { Instr::Nop },
        });
    }
    out.push(Instr::ReturnValue);
    out
}

/// decode(encode(stream)) == stream for arbitrary well-formed streams, on
/// every ISA version — 200 random cases each.
#[test]
fn fuzz_encode_decode_roundtrip() {
    let mut rng = Rng::new(0xDEC0DE);
    for case in 0..200 {
        let len = 1 + rng.below(60);
        let stream = random_stream(&mut rng, len);
        for v in IsaVersion::ALL {
            let raw = encode(&stream, v);
            let back = decode(&raw, v).unwrap_or_else(|e| panic!("case {} on {}: {}\n{:?}", case, v, e, stream));
            assert_eq!(back, stream, "case {} on {}", case, v);
        }
    }
}

/// Random arithmetic expressions: VM semantics must be stable across all
/// four ISA encodings (differential testing of the encoder/VM).
#[test]
fn fuzz_arith_cross_version() {
    let mut rng = Rng::new(7777);
    for _ in 0..60 {
        // Build a random integer expression program.
        let a = rng.below(50) as i64;
        let b = 1 + rng.below(9) as i64;
        let c = 1 + rng.below(20) as i64; // nonzero: expressions may divide by z
        let ops = ["+", "-", "*", "//", "%"];
        let o1 = ops[rng.below(5)];
        let o2 = ops[rng.below(5)];
        let src = format!("x = {}\ny = {}\nz = {}\nprint(x {} y {} z, x > y, y != z)\n", a, b, c, o1, o2);
        let mut outs = Vec::new();
        for v in IsaVersion::ALL {
            let vm = Vm::new();
            vm.exec_source(&src, v).unwrap_or_else(|e| panic!("{}\n{}", e, src));
            outs.push(vm.take_output());
        }
        assert!(outs.windows(2).all(|w| w[0] == w[1]), "{}\n{:?}", src, outs);
    }
}

/// Guard-overflow behavior: a function whose guard always misses must stop
/// recompiling at the cache limit and keep producing correct results.
#[test]
fn cache_limit_falls_back_gracefully() {
    let src = "\
counter = 0
def f(x, k):
    return (x * k).sum()
t = torch.ones([2])
total = 0.0
for k in range(20):
    total += f(t, k).item()
print(total)
";
    let plain = Vm::new();
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig { cache_limit: 4, ..Default::default() });
    vm.eval_hook = Some(d.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    assert_eq!(vm.take_output(), expected);
    // Captures stop at the limit; the remaining calls run uncompiled.
    assert!(d.metrics.captures.get() <= 5, "{:?}", d.metrics.report());
    assert!(d.metrics.guard_failures.get() >= 1);
}

/// Error behavior must survive compilation: a runtime error inside a
/// compiled region surfaces identically (inline raise path).
#[test]
fn errors_survive_compilation() {
    let src = "def f(x):\n    if x.sum().item() > 0:\n        raise 'positive sum'\n    return x\nf(torch.ones([2]))\n";
    let plain = Vm::new();
    let e1 = plain.exec_source(src, IsaVersion::V310).unwrap_err();
    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(d);
    let e2 = vm.exec_source(src, IsaVersion::V310).unwrap_err();
    assert_eq!(e1.message, e2.message);
    assert!(e1.message.contains("positive sum"));
}
