//! Replays the committed fuzz-regression corpus (`tests/fuzz_regressions/
//! *.json`) bitwise on every backend.
//!
//! Each bundle is a finding the program-level fuzzer (`depyf fuzz`) once
//! made — or a hand-distilled pin of a fixed panic — in the committed
//! [`depyf::fuzz::FuzzBundle`] format. For every bundle the harness:
//!
//! 1. runs the source on the plain VM: it must never panic; `expect_error`
//!    bundles must end in a *typed* error, `strict` bundles must reproduce
//!    their recorded rendering exactly;
//! 2. runs it dynamo-hooked on eager, sharded, batched, codegen and
//!    resilient:codegen at opt levels 0 and 2, demanding bitwise agreement
//!    with the plain run ([`depyf::fuzz::compare`] returns `None`).
//!
//! To commit a new regression, drop the bundle `depyf fuzz` wrote into
//! `tests/fuzz_regressions/` (see `tests/README.md`).

use std::panic;
use std::path::PathBuf;

use depyf::api::OptLevel;
use depyf::fuzz::{compare, resolve_backend, run_program, FuzzBundle, RunStatus, DEFAULT_BUDGET};

/// The replay sweep's backend set: every registered graph compiler the
/// oracle holds to bit-exactness, plus one wrapper composition.
const BACKENDS: &[&str] = &["eager", "sharded", "batched", "codegen", "resilient:codegen"];
const OPT_LEVELS: &[OptLevel] = &[OptLevel::O0, OptLevel::O2];

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fuzz_regressions")
}

fn load_corpus() -> Vec<FuzzBundle> {
    let dir = corpus_dir();
    let mut out = Vec::new();
    for entry in std::fs::read_dir(&dir).unwrap_or_else(|e| panic!("read {}: {}", dir.display(), e)) {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let b = FuzzBundle::load(&path).unwrap_or_else(|e| panic!("{}: {}", path.display(), e));
        let stem = path.file_stem().unwrap().to_string_lossy().into_owned();
        assert_eq!(b.name, stem, "{}: bundle name must match its file stem", path.display());
        out.push(b);
    }
    out.sort_by(|a, b| a.name.cmp(&b.name));
    out
}

#[test]
fn corpus_is_nonempty_and_well_formed() {
    let corpus = load_corpus();
    assert!(corpus.len() >= 10, "expected a committed corpus, found {} bundle(s)", corpus.len());
    for b in &corpus {
        assert!(!b.source.is_empty(), "{}: empty source", b.name);
        assert!(!(b.strict && b.expect_error && b.expected.starts_with("status: ok")), "{}: contradictory flags", b.name);
    }
}

#[test]
fn every_bundle_replays_bitwise_on_every_backend() {
    let corpus = load_corpus();
    // The oracle traps panics itself; silence the default hook so a
    // regressed panic shows up as one readable failure line, not a
    // backtrace mid-run.
    let prev = panic::take_hook();
    panic::set_hook(Box::new(|_| {}));
    let mut failures: Vec<String> = Vec::new();
    for b in &corpus {
        let plain = run_program(&b.source, None, DEFAULT_BUDGET);
        if let RunStatus::Panic(m) = &plain.status {
            failures.push(format!("{}: plain run panicked: {}", b.name, m));
            continue;
        }
        if plain.status == RunStatus::Budget {
            failures.push(format!("{}: plain run tripped the instruction budget", b.name));
            continue;
        }
        if b.expect_error && !matches!(plain.status, RunStatus::Error(_)) {
            failures.push(format!("{}: expected a typed error, got:\n{}", b.name, plain.render()));
        }
        if b.strict && plain.render() != b.expected {
            failures.push(format!("{}: strict rendering drifted:\nwant:\n{}\ngot:\n{}", b.name, b.expected, plain.render()));
        }
        for name in BACKENDS {
            let backend = match resolve_backend(name) {
                Ok(be) => be,
                Err(e) => {
                    failures.push(format!("{}: backend {}: {}", b.name, name, e));
                    continue;
                }
            };
            for &opt in OPT_LEVELS {
                let hooked = run_program(&b.source, Some((backend.clone(), opt)), DEFAULT_BUDGET);
                if let Some(kind) = compare(&plain, &hooked) {
                    failures.push(format!(
                        "{}: {} on {} at O{}:\nplain:\n{}\nhooked:\n{}",
                        b.name,
                        kind.as_str(),
                        name,
                        opt.as_u8(),
                        plain.render(),
                        hooked.render()
                    ));
                }
            }
        }
    }
    panic::set_hook(prev);
    assert!(failures.is_empty(), "{} regression(s):\n{}", failures.len(), failures.join("\n---\n"));
}

/// Bundles tagged `serve:<inner>` came from (or pin) the concurrent
/// dispatch path: replay each with several OS threads racing one shared
/// [`depyf::serve::ModuleCache`] — the `depyf fuzz --serve` topology —
/// and demand every thread's outcome agrees bitwise with the
/// single-thread plain run.
#[test]
fn serve_bundles_replay_concurrently_through_shared_cache() {
    use depyf::serve::{CachingBackend, ModuleCache};
    use std::sync::Arc;
    const THREADS: usize = 4;
    let corpus: Vec<FuzzBundle> =
        load_corpus().into_iter().filter(|b| b.backend.starts_with("serve:")).collect();
    assert!(!corpus.is_empty(), "expected at least one committed serve: bundle");
    let mut failures: Vec<String> = Vec::new();
    for b in &corpus {
        let inner_name = b.backend.strip_prefix("serve:").unwrap();
        let plain = run_program(&b.source, None, DEFAULT_BUDGET);
        assert!(
            !matches!(plain.status, RunStatus::Panic(_) | RunStatus::Budget),
            "{}: plain run must complete: {}",
            b.name,
            plain.render()
        );
        for &opt in OPT_LEVELS {
            let inner = match resolve_backend(inner_name) {
                Ok(be) => be,
                Err(e) => {
                    failures.push(format!("{}: backend {}: {}", b.name, inner_name, e));
                    continue;
                }
            };
            let cache = Arc::new(ModuleCache::new());
            let shared: Arc<dyn depyf::api::Backend> =
                Arc::new(CachingBackend::new(inner, Arc::clone(&cache)));
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    let shared = Arc::clone(&shared);
                    let src = b.source.clone();
                    std::thread::spawn(move || run_program(&src, Some((shared, opt)), DEFAULT_BUDGET))
                })
                .collect();
            for (t, h) in handles.into_iter().enumerate() {
                let hooked = h.join().expect("replay thread");
                if let Some(kind) = compare(&plain, &hooked) {
                    failures.push(format!(
                        "{}: {} on thread {} ({} at O{}):\nplain:\n{}\nhooked:\n{}",
                        b.name,
                        kind.as_str(),
                        t,
                        b.backend,
                        opt.as_u8(),
                        plain.render(),
                        hooked.render()
                    ));
                }
            }
            assert!(
                cache.hits() + cache.misses() > 0,
                "{}: the shared module cache was never exercised",
                b.name
            );
        }
    }
    assert!(failures.is_empty(), "{} regression(s):\n{}", failures.len(), failures.join("\n---\n"));
}
