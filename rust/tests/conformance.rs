//! Corpus-driven differential conformance harness for all backends.
//!
//! Strategy (see `tests/README.md`): the **eager executor is the oracle**.
//! Programs/graphs are run once under the `recording` wrapper so every
//! compiled-fn call is captured into a `__trace_*.json` bundle; each
//! bundle is then pushed through the **text round-trip** (parse of the
//! rendered bundle — the serialization layer is under test too) and
//! replayed on every other backend in differential mode. sharded/batched
//! lower to eager partitions here (no runtime) and codegen's loop
//! programs replicate the eager kernels' accumulation order exactly, so
//! all three must be **bit-exact**; XLA fuses and reorders float math,
//! so it gets an eps.
//!
//! Two graph sources feed the sweep:
//! * the full table1 model corpus (140 programs through dynamo), and
//! * ≥200 deterministic generated graphs per backend (seeded generator in
//!   `tests/support`, shared with `tests/proptests.rs`).
//!
//! Every mismatch dumps a minimized repro bundle (single-op culprit
//! subgraph when localization pins one, else the single failing call)
//! into `$DEPYF_CONFORMANCE_OUT` (default `conformance_failures/`) — CI
//! uploads that directory when the job fails. `DEPYF_CONFORMANCE_QUICK=1`
//! (or `DEPYF_BENCH_QUICK=1`) shrinks the sweep for smoke runs.

mod support;

use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use depyf::api::{
    ArtifactKind, Backend, CompileRequest, EagerBackend, FallbackPolicy, OptLevel, TraceBundle,
    XlaBackend,
};
use depyf::backend::{
    replay_bundle, single_call_bundle, BatchedBackend, RecordingBackend, ReplayOptions,
    ShardedBackend,
};
use depyf::bytecode::IsaVersion;
use depyf::codegen::CodegenBackend;
use depyf::corpus::model_cases;
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::runtime::Runtime;
use depyf::tensor::Rng;
use depyf::vm::Vm;

/// Seed of the generated-graph sweep: same seed → same graphs → same
/// inputs, across machines and runs.
const GEN_SEED: u64 = 0x5EED_C0DE;
/// Full-mode generated graph count per backend (acceptance floor: 200).
const GEN_GRAPHS: usize = 200;

fn quick() -> bool {
    std::env::var("DEPYF_CONFORMANCE_QUICK").is_ok() || std::env::var("DEPYF_BENCH_QUICK").is_ok()
}

fn repro_dir() -> PathBuf {
    std::env::var("DEPYF_CONFORMANCE_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("conformance_failures"))
}

/// Write a minimized repro bundle; returns its path for the panic text.
fn dump_repro(bundle: &TraceBundle, tag: &str) -> String {
    let dir = repro_dir();
    let _ = std::fs::create_dir_all(&dir);
    let safe: String = bundle
        .name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    let path = dir.join(format!("repro_{}_{}.json", tag, safe));
    let _ = std::fs::write(&path, bundle.to_json());
    path.display().to_string()
}

/// Replay `bundle` on `backend` (differentially against the eager oracle
/// when `differential`, else against the recorded outputs) and panic with
/// a minimized repro on any mismatch.
fn assert_conforms(bundle: &TraceBundle, backend: &dyn Backend, eps: f32, differential: bool, tag: &str) {
    let opts = ReplayOptions { eps, runtime: None, localize: true, ..Default::default() };
    let oracle: Option<&dyn Backend> = if differential { Some(&EagerBackend) } else { None };
    let report = replay_bundle(bundle, backend, oracle, &opts)
        .unwrap_or_else(|e| panic!("[{}] {} failed to replay {}: {}", tag, backend.name(), bundle.name, e));
    if report.ok() {
        return;
    }
    let m = &report.mismatches[0];
    let repro = m
        .culprit
        .as_ref()
        .map(|c| c.repro.clone())
        .unwrap_or_else(|| single_call_bundle(bundle, m.call));
    let path = dump_repro(&repro, tag);
    panic!(
        "[{}] backend '{}' diverged from the eager oracle:\n{}\nminimized repro dumped to {}",
        tag,
        backend.name(),
        report.render(),
        path
    );
}

/// Run one program source under dynamo with the recording wrapper and
/// collect every trace bundle — parsed back from its rendered JSON, so
/// the on-disk representation is what gets replayed.
fn record_program(source: &str, label: &str) -> Vec<TraceBundle> {
    let rec: Arc<dyn Backend> = Arc::new(RecordingBackend::new(Arc::new(EagerBackend)));
    let dynamo = Dynamo::new(DynamoConfig { backend: rec, ..Default::default() });
    let mut vm = Vm::new();
    vm.eval_hook = Some(dynamo.clone());
    vm.exec_source(source, IsaVersion::V310)
        .unwrap_or_else(|e| panic!("{} failed under the recording backend: {}", label, e));
    let mut bundles = Vec::new();
    for f in dynamo.compiled() {
        for art in f.module.artifacts() {
            if art.kind == ArtifactKind::Trace {
                let bundle = TraceBundle::parse(&art.content)
                    .unwrap_or_else(|e| panic!("{}: trace bundle does not parse: {}", label, e));
                if !bundle.calls.is_empty() {
                    bundles.push(bundle);
                }
            }
        }
    }
    bundles
}

/// The table1 corpus sweep: record every model's compiled graphs with
/// their real runtime inputs, then cross-check sharded and batched against
/// the eager oracle bit-for-bit. Recording fidelity is checked first: the
/// eager replay of the round-tripped bundle must equal the recorded
/// outputs exactly.
#[test]
fn table1_corpus_record_replay_cross_backend() {
    let cases = model_cases();
    let step = if quick() { 10 } else { 1 };
    let mut total_bundles = 0usize;
    let mut total_calls = 0usize;
    for case in cases.iter().step_by(step) {
        for bundle in record_program(&case.source, &case.name) {
            total_bundles += 1;
            total_calls += bundle.calls.len();
            let tag = format!("corpus_{}", case.name);
            // Recording fidelity + serialization: eager must reproduce the
            // recorded outputs bit-for-bit.
            assert_conforms(&bundle, &EagerBackend, 0.0, false, &tag);
            // Differential conformance, eager as oracle, bitwise.
            assert_conforms(&bundle, &ShardedBackend::new(), 0.0, true, &tag);
            assert_conforms(&bundle, &ShardedBackend::with_max_ops(1), 0.0, true, &tag);
            assert_conforms(&bundle, &BatchedBackend::new(), 0.0, true, &tag);
            assert_conforms(&bundle, &CodegenBackend::new(), 0.0, true, &tag);
        }
    }
    assert!(total_bundles >= if quick() { 10 } else { 100 }, "only {} bundles recorded", total_bundles);
    assert!(total_calls >= total_bundles, "bundles must carry real calls");
}

/// XLA conformance on recorded corpus traces. PJRT reorders/fuses float
/// math, so the comparison is eps-based, and the whole test skips (with a
/// note) where no PJRT client can start.
#[test]
fn table1_corpus_traces_replay_on_xla_within_eps() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping xla conformance: no PJRT client in this environment");
        return;
    };
    let cases = model_cases();
    // Full-capture families cover every op family xla lowers; graph-break
    // families re-cover the same graph shapes, so sample those.
    let step = if quick() { 10 } else { 4 };
    let mut checked = 0usize;
    for case in cases.iter().step_by(step) {
        for bundle in record_program(&case.source, &case.name) {
            let opts = ReplayOptions {
                eps: 1e-4,
                runtime: Some(Arc::clone(&rt)),
                localize: true,
                ..Default::default()
            };
            let report = replay_bundle(&bundle, &XlaBackend, None, &opts)
                .unwrap_or_else(|e| panic!("xla replay of {} failed: {}", case.name, e));
            if !report.ok() {
                let m = &report.mismatches[0];
                let repro = m
                    .culprit
                    .as_ref()
                    .map(|c| c.repro.clone())
                    .unwrap_or_else(|| single_call_bundle(&bundle, m.call));
                let path = dump_repro(&repro, &format!("xla_{}", case.name));
                panic!("xla diverged on {}:\n{}\nrepro at {}", case.name, report.render(), path);
            }
            checked += 1;
        }
    }
    assert!(checked > 0, "xla sweep replayed nothing");
}

/// The generated-graph sweep: ≥200 seeded graphs recorded on eager, each
/// round-tripped through the trace text and replayed differentially on
/// sharded (two shard budgets) and batched. Bit-exact, no runtime.
#[test]
fn generated_graphs_conform_across_backends() {
    let n = if quick() { 40 } else { GEN_GRAPHS };
    let mut gen = support::GraphGen::new(GEN_SEED);
    let mut input_rng = Rng::new(GEN_SEED ^ 0x9E37_79B9);
    for i in 0..n {
        let g = Arc::new(gen.next_graph());
        let name = g.name.clone();
        let req = CompileRequest::new(&name, Arc::clone(&g));
        let rec = RecordingBackend::new(Arc::new(EagerBackend));
        let module = rec
            .compile(&req)
            .unwrap_or_else(|e| panic!("graph {} failed to compile on eager: {}", name, e));
        for _ in 0..2 {
            let inputs = support::rand_inputs(&g, &mut input_rng);
            module
                .call(&inputs)
                .unwrap_or_else(|e| panic!("graph {} failed to execute on eager: {}", name, e));
        }
        let art = module
            .artifacts()
            .into_iter()
            .find(|a| a.kind == ArtifactKind::Trace)
            .expect("recording module emits a trace artifact");
        let bundle = TraceBundle::parse(&art.content)
            .unwrap_or_else(|e| panic!("graph {}: bundle does not parse: {}", name, e));
        let tag = format!("gen_{}", i);
        assert_conforms(&bundle, &EagerBackend, 0.0, false, &tag);
        assert_conforms(&bundle, &ShardedBackend::new(), 0.0, true, &tag);
        assert_conforms(&bundle, &ShardedBackend::with_max_ops(1), 0.0, true, &tag);
        assert_conforms(&bundle, &BatchedBackend::new(), 0.0, true, &tag);
        assert_conforms(&bundle, &CodegenBackend::new(), 0.0, true, &tag);
    }
}

/// Compile `bundle.graph` on `backend` at `level` and run every recorded
/// call, returning the raw outputs (FallbackPolicy::Error: a backend that
/// cannot compile is a failed sweep, not a silent eager degrade).
fn outputs_at(
    bundle: &TraceBundle,
    backend: &dyn Backend,
    level: OptLevel,
    tag: &str,
) -> Vec<Vec<depyf::tensor::Tensor>> {
    let graph = Arc::new(bundle.graph.clone());
    let req = CompileRequest::new(&bundle.name, Arc::clone(&graph))
        .with_fallback(FallbackPolicy::Error)
        .with_opt_level(level);
    let module = backend
        .compile(&req)
        .unwrap_or_else(|e| panic!("[{}] {} failed to compile at -O{}: {}", tag, backend.name(), level, e));
    bundle
        .calls
        .iter()
        .map(|call| {
            let inputs: Vec<Rc<depyf::tensor::Tensor>> =
                call.inputs.iter().cloned().map(Rc::new).collect();
            module.call(&inputs).unwrap_or_else(|e| {
                panic!("[{}] {} failed to execute at -O{}: {}", tag, backend.name(), level, e)
            })
        })
        .collect()
}

/// Assert the opt-level sweep invariant for one bundle on one backend:
/// `--opt-level 0` and `2` produce **bitwise identical** outputs — the
/// optimizer (folding, CSE, DCE, algebraic rewrites) and eager fusion
/// must never change results.
fn assert_opt_levels_agree(bundle: &TraceBundle, backend: &dyn Backend, tag: &str) {
    let o0 = outputs_at(bundle, backend, OptLevel::O0, tag);
    let o2 = outputs_at(bundle, backend, OptLevel::O2, tag);
    assert_eq!(o0.len(), o2.len(), "[{}] call-count drift", tag);
    for (ci, (c0, c2)) in o0.iter().zip(o2.iter()).enumerate() {
        assert_eq!(c0.len(), c2.len(), "[{}] call {} arity drift", tag, ci);
        for (oi, (a, b)) in c0.iter().zip(c2.iter()).enumerate() {
            assert_eq!(a.shape(), b.shape(), "[{}] call {} output {} shape drift", tag, ci, oi);
            let bitwise =
                a.data().iter().zip(b.data().iter()).all(|(x, y)| x.to_bits() == y.to_bits());
            if !bitwise {
                let path = dump_repro(&single_call_bundle(bundle, ci), &format!("optlevel_{}", tag));
                panic!(
                    "[{}] backend '{}' diverged between -O0 and -O2 at call {} output {}\nrepro dumped to {}",
                    tag,
                    backend.name(),
                    ci,
                    oi,
                    path
                );
            }
        }
    }
}

/// Satellite sweep: every table1-corpus trace AND generated-corpus graph
/// replayed at `--opt-level 0` vs `2` must be bitwise-equal on
/// eager/sharded/batched. This is the optimizer's acceptance gate —
/// fusion and folding never change results.
#[test]
fn opt_level_0_vs_2_is_bitwise_clean_across_backends() {
    let backends: Vec<Box<dyn Fn() -> Box<dyn Backend>>> = vec![
        Box::new(|| Box::new(EagerBackend)),
        Box::new(|| Box::new(ShardedBackend::new())),
        Box::new(|| Box::new(ShardedBackend::with_max_ops(1))),
        Box::new(|| Box::new(BatchedBackend::new())),
        Box::new(|| Box::new(CodegenBackend::new())),
        // Threaded row-tiling preserves per-element accumulation order, so
        // the multi-threaded loop programs sit under the same bitwise gate.
        Box::new(|| Box::new(CodegenBackend::with_threads(4))),
    ];
    // Table1 corpus (sampled — full-capture families cover every op shape).
    let cases = model_cases();
    let step = if quick() { 20 } else { 4 };
    let mut swept = 0usize;
    for case in cases.iter().step_by(step) {
        for bundle in record_program(&case.source, &case.name) {
            let tag = format!("corpus_{}", case.name);
            for make in &backends {
                assert_opt_levels_agree(&bundle, make().as_ref(), &tag);
            }
            swept += 1;
        }
    }
    assert!(swept > 0, "corpus sweep replayed nothing");
    // Generated corpus: fresh graphs (distinct seed from the main sweep so
    // the two tests don't shadow each other's coverage).
    let n = if quick() { 15 } else { 60 };
    let mut gen = support::GraphGen::new(GEN_SEED ^ 0x0717);
    let mut input_rng = Rng::new(GEN_SEED ^ 0x0718);
    for i in 0..n {
        let g = Arc::new(gen.next_graph());
        let name = g.name.clone();
        let req = CompileRequest::new(&name, Arc::clone(&g));
        let rec = RecordingBackend::new(Arc::new(EagerBackend));
        let module = rec.compile(&req).unwrap_or_else(|e| panic!("graph {}: {}", name, e));
        for _ in 0..2 {
            module.call(&support::rand_inputs(&g, &mut input_rng)).unwrap();
        }
        let art = module
            .artifacts()
            .into_iter()
            .find(|a| a.kind == ArtifactKind::Trace)
            .expect("recording module emits a trace artifact");
        let bundle = TraceBundle::parse(&art.content).unwrap();
        let tag = format!("gen_{}", i);
        for make in &backends {
            assert_opt_levels_agree(&bundle, make().as_ref(), &tag);
        }
    }
}

/// Determinism acceptance: two generators with the same seed produce the
/// same graph sequence (content hashes) and the sequence is diverse.
#[test]
fn generated_graph_sweep_is_deterministic() {
    let hashes = |seed: u64| -> Vec<u64> {
        let mut gen = support::GraphGen::new(seed);
        (0..GEN_GRAPHS).map(|_| gen.next_graph().content_hash()).collect()
    };
    let a = hashes(GEN_SEED);
    let b = hashes(GEN_SEED);
    assert_eq!(a, b, "same seed must generate the same {} graphs", GEN_GRAPHS);
    let distinct: std::collections::HashSet<u64> = a.iter().copied().collect();
    assert!(distinct.len() > GEN_GRAPHS / 2, "generator collapsed: {} distinct graphs", distinct.len());
    let c = hashes(GEN_SEED + 1);
    assert_ne!(a, c, "different seeds must differ");
}

/// A dynamo session with the recording wrapper indexes the trace in
/// manifest.json, and `TraceBundle::load` reads it back from disk — the
/// full `depyf dump --backend recording` → `depyf replay` file contract.
#[test]
fn session_dump_indexes_trace_artifacts() {
    use depyf::api::{load_manifest, Session};
    let dir = std::env::temp_dir().join(format!("depyf_conformance_dump_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut s = Session::builder().dump_to(&dir).backend_named("recording").build().unwrap();
    s.run_source(
        "main",
        "def f(x):\n    return ((x * 2) + 1).relu().sum()\nprint(f(torch.ones([3])).item())\nprint(f(torch.ones([3])).item())\n",
    )
    .unwrap();
    let artifacts = s.finish().unwrap();
    let traces: Vec<_> = artifacts.iter().filter(|a| a.kind == ArtifactKind::Trace).collect();
    assert_eq!(traces.len(), 1, "{:?}", artifacts);
    // Indexed in the manifest with the same path.
    let indexed = load_manifest(&dir).unwrap();
    assert!(indexed.iter().any(|a| a.kind == ArtifactKind::Trace && a.path == traces[0].path));
    // Loads from disk and replays clean on eager and batched.
    let bundle = TraceBundle::load(&traces[0].path).unwrap();
    assert_eq!(bundle.calls.len(), 2, "both calls recorded");
    assert!(!bundle.guards.is_empty(), "guard context travels with the trace");
    assert_conforms(&bundle, &EagerBackend, 0.0, false, "session_dump");
    assert_conforms(&bundle, &BatchedBackend::new(), 0.0, true, "session_dump");
    std::fs::remove_dir_all(&dir).ok();
}

/// Tentpole satellite: concurrent dispatch equivalence. N threads calling
/// the same `Arc<dyn CompiledModule>` handles (compiled once, on the
/// `recording:eager` wrapper) must produce results **bitwise equal** to
/// the single-thread eager oracle, and the trace bundles recorded under
/// that contention must neither lose calls nor collide in `(kind, name)`.
#[test]
fn multithread_dispatch_is_bitwise_equal_to_single_thread_eager() {
    use depyf::api::CompiledModule;
    use depyf::backend::eager;
    use depyf::tensor::Tensor;

    const THREADS: usize = 4;
    const CALLS_PER_GRAPH: usize = 3;
    let n_graphs = if quick() { 16 } else { 48 };

    struct Work {
        name: String,
        module: Arc<dyn CompiledModule>,
        /// Owned input sets (tensors cross threads; workers rebuild `Rc`s).
        input_sets: Vec<Vec<depyf::tensor::Tensor>>,
        /// Single-thread eager oracle outputs, as raw f32 bits.
        want: Vec<Vec<Vec<u32>>>,
    }

    let mut gen = support::GraphGen::new(GEN_SEED ^ 0xA11CE);
    let mut input_rng = Rng::new(GEN_SEED ^ 0xA11CF);
    let mut works = Vec::new();
    for i in 0..n_graphs {
        let g = Arc::new(gen.next_graph());
        let name = format!("__compiled_fn_{}", i + 1);
        let req = CompileRequest::new(&name, Arc::clone(&g));
        let rec = RecordingBackend::new(Arc::new(EagerBackend));
        let module = rec.compile(&req).unwrap_or_else(|e| panic!("{}: compile: {}", name, e));
        let mut input_sets = Vec::new();
        let mut want = Vec::new();
        for _ in 0..CALLS_PER_GRAPH {
            let inputs = support::rand_inputs(&g, &mut input_rng);
            let oracle = eager::execute(&g, &inputs)
                .unwrap_or_else(|e| panic!("{}: eager oracle: {}", name, e));
            want.push(
                oracle
                    .iter()
                    .map(|t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect())
                    .collect(),
            );
            input_sets.push(inputs.iter().map(|t| (**t).clone()).collect());
        }
        works.push(Work { name, module, input_sets, want });
    }
    let works = Arc::new(works);

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let works = Arc::clone(&works);
            std::thread::spawn(move || {
                for w in works.iter() {
                    for (ci, inputs) in w.input_sets.iter().enumerate() {
                        let handles: Vec<Rc<depyf::tensor::Tensor>> =
                            inputs.iter().cloned().map(Rc::new).collect();
                        let got = w
                            .module
                            .call(&handles)
                            .unwrap_or_else(|e| panic!("thread {}: {}: {}", t, w.name, e));
                        assert_eq!(got.len(), w.want[ci].len(), "thread {}: {}", t, w.name);
                        for (oi, out) in got.iter().enumerate() {
                            let bits: Vec<u32> = out.data().iter().map(|v| v.to_bits()).collect();
                            assert_eq!(
                                bits, w.want[ci][oi],
                                "thread {}: {} call {} output {} diverged from single-thread eager",
                                t, w.name, ci, oi
                            );
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("dispatch thread panicked");
    }

    // Trace bundles recorded under contention: every call present, every
    // (kind, name) slot unique across the whole fleet of modules.
    let mut seen = std::collections::HashSet::new();
    for w in works.iter() {
        let traces: Vec<_> =
            w.module.artifacts().into_iter().filter(|a| a.kind == ArtifactKind::Trace).collect();
        assert_eq!(traces.len(), 1, "{}: expected one trace artifact", w.name);
        let art = &traces[0];
        assert!(
            seen.insert((art.kind, art.name.clone())),
            "(kind, name) collision on {:?}/{}",
            art.kind,
            art.name
        );
        let bundle = TraceBundle::parse(&art.content)
            .unwrap_or_else(|e| panic!("{}: trace does not parse: {}", w.name, e));
        assert_eq!(
            bundle.calls.len(),
            THREADS * CALLS_PER_GRAPH,
            "{}: concurrent recording lost calls",
            w.name
        );
    }
}
