//! Cross-module integration tests: the whole stack composing.

use std::rc::Rc;
use std::sync::Arc;

use depyf::api::{
    load_manifest, lookup_backend, register_backend, Artifact, ArtifactKind, Backend, Capabilities,
    CompilePlan, CompileRequest, CompiledModule, DepyfError, FallbackPolicy, Session, TraceMode,
    XlaBackend,
};
use depyf::backend::{eager, BatchedBackend, ShardedBackend};
use depyf::bytecode::IsaVersion;
use depyf::corpus::{model_cases, run_syntax_suite, syntax_cases};
use depyf::decompiler::baselines::DepyfRs;
use depyf::decompiler::{decompile, DecompilerTool};
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::graph::Graph;
use depyf::pylang::compile_module;
use depyf::runtime::Runtime;
use depyf::tensor::{Rng, Tensor};
use depyf::value::Value;
use depyf::vm::Vm;

/// Property-style invariant: for every syntax case and every ISA version,
/// the canonical decoder must reproduce the compiler's instruction stream
/// from the raw bytes (decode ∘ encode = id), recursively.
#[test]
fn decode_encode_roundtrip_whole_corpus() {
    fn check(code: &Rc<depyf::bytecode::CodeObject>) {
        let back = depyf::bytecode::decode(&code.raw, code.version).expect("decode");
        assert_eq!(back, code.instrs, "raw decode mismatch in {}", code.name);
        for inner in code.nested_codes() {
            check(&inner);
        }
    }
    for case in syntax_cases() {
        for v in IsaVersion::ALL {
            let code = compile_module(case.source, "<t>", v).unwrap();
            check(&code);
        }
    }
}

/// Dynamo + XLA backend: same results as eager for a multi-break model.
#[test]
fn dynamo_xla_end_to_end_with_breaks() {
    let src = "\
torch.manual_seed(3)
W = torch.randn([6, 6])
def forward(x):
    h = x @ W
    print('stage')
    if h.sum() >= 0:
        h = h.relu()
    return h.mean()
print(forward(torch.ones([2, 6])).item())
print(forward(torch.ones([2, 6]) * -1).item())
";
    let plain = Vm::new();
    plain.seed(9);
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let rt = Runtime::cpu().expect("pjrt");
    let mut vm = Vm::new();
    vm.seed(9);
    let dynamo = Dynamo::with_runtime(DynamoConfig { backend: Arc::new(XlaBackend), ..Default::default() }, rt);
    vm.eval_hook = Some(dynamo.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    // XLA fuses differently than the eager reference: compare numerically
    // (float lines within 1e-5), not textually.
    let got = vm.take_output();
    let pairs: Vec<(&str, &str)> = expected.lines().zip(got.lines()).collect();
    assert_eq!(expected.lines().count(), got.lines().count());
    for (e, g) in pairs {
        match (e.parse::<f64>(), g.parse::<f64>()) {
            (Ok(ev), Ok(gv)) => assert!((ev - gv).abs() < 1e-5, "{} vs {}", e, g),
            _ => assert_eq!(e, g),
        }
    }
    assert!(dynamo.metrics.graph_breaks.get() >= 1);
}

/// The session produces a dump dir whose decompiled artifacts recompile,
/// and `finish()` types every artifact + writes a manifest that indexes
/// exactly the files on disk.
#[test]
fn session_dumps_recompile_and_manifest_round_trips() {
    let dir = std::env::temp_dir().join(format!("depyf_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut s = Session::builder().dump_to(&dir).isa(IsaVersion::V311).build().unwrap();
    s.run_source("main", "def f(x):\n    return (x * 3).relu().sum()\nprint(f(torch.ones([4])).item())\n").unwrap();
    let artifacts = s.finish().unwrap();
    let mut checked = 0;
    for a in &artifacts {
        assert!(a.path.exists(), "artifact file missing: {:?}", a);
        if a.kind == ArtifactKind::TransformedSource {
            let text = std::fs::read_to_string(&a.path).unwrap();
            assert!(!text.contains("decompilation failed"), "{}:\n{}", a.name, text);
            compile_module(&text, "<dump>", IsaVersion::V311)
                .unwrap_or_else(|e| panic!("dump {} does not recompile: {}\n{}", a.name, e, text));
            checked += 1;
        }
    }
    assert!(checked >= 1, "no transformed dumps written");
    // manifest.json indexes exactly what finish() returned.
    let indexed = load_manifest(&dir).unwrap();
    assert_eq!(indexed, artifacts);
    std::fs::remove_dir_all(&dir).ok();
}

/// Acceptance: a custom backend registered through the `Backend` trait
/// compiles and executes a captured graph end-to-end via `SessionBuilder`.
#[test]
fn custom_backend_end_to_end_via_session_builder() {
    struct TaggingEager;
    impl Backend for TaggingEager {
        fn name(&self) -> &str {
            "tagging-eager"
        }
        fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
            Ok(CompilePlan::monolithic("tagging-eager", req, "eager"))
        }
        fn lower(
            &self,
            req: &CompileRequest,
            _plan: &CompilePlan,
        ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
            Ok(Arc::new(eager::EagerModule::with_name(Arc::clone(&req.graph), "tagging-eager".into())))
        }
    }
    register_backend(Arc::new(TaggingEager));
    assert!(lookup_backend("tagging-eager").is_some());

    let src = "def f(x, y):\n    return ((x @ y) + 1).relu().sum()\nprint(f(torch.ones([4, 4]), torch.ones([4, 4])).item())\n";
    let plain = Vm::new();
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let dir = std::env::temp_dir().join(format!("depyf_custom_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut s = Session::builder()
        .dump_to(&dir)
        .backend_named("tagging-eager")
        .isa(IsaVersion::V310)
        .fallback(FallbackPolicy::Error)
        .build()
        .unwrap();
    s.run_source("main", src).unwrap();
    assert_eq!(s.vm.take_output(), expected);
    // The installed compiled graph ran through the custom backend.
    let g = s.vm.get_global("__compiled_fn_1").expect("compiled fn installed");
    match g {
        Value::CompiledGraph(f) => {
            assert_eq!(f.backend_name, "tagging-eager");
            assert!(f.calls.get() >= 1, "graph was never executed");
        }
        other => panic!("expected compiled graph, got {:?}", other),
    }
    let artifacts = s.finish().unwrap();
    assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::CompiledGraph));
    std::fs::remove_dir_all(&dir).ok();
}

/// Builder misconfiguration surfaces as a typed `DepyfError::Builder`.
#[test]
fn builder_misconfiguration_errors() {
    let err = Session::builder().build().unwrap_err();
    assert_eq!(err.layer(), "builder");

    let dir = std::env::temp_dir().join(format!("depyf_cfg_{}", std::process::id()));
    let err = Session::builder()
        .dump_to(&dir)
        .backend_named("xla")
        .fallback(FallbackPolicy::Error)
        .build()
        .unwrap_err();
    assert_eq!(err.layer(), "builder");
    assert!(err.to_string().contains("requires a runtime"), "{}", err);
    std::fs::remove_dir_all(&dir).ok();
}

/// Guard semantics under dynamo: shape-specializations accumulate and
/// dispatch correctly (values stay correct across interleaved shapes).
#[test]
fn multi_shape_specialization_correctness() {
    let src = "\
def f(x):
    return (x * 2 + 1).sum()
a = torch.ones([2, 2])
b = torch.ones([3])
print(f(a).item(), f(b).item(), f(a).item(), f(b).item())
";
    let plain = Vm::new();
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();
    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(d.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    assert_eq!(vm.take_output(), expected);
    assert_eq!(d.metrics.captures.get(), 2);
    assert!(d.metrics.cache_hits.get() >= 2);
}

/// depyf decompiles dynamo's output for a function it later re-executes —
/// the full Figure-1 + Table-1 pipeline in one test.
#[test]
fn figure1_pipeline() {
    let src = "\
def f(a, b):
    x = a / (abs(a) + 1)
    if b.sum() >= 0:
        b = b * -1
    return x * b
print(f(torch.ones([4]), torch.ones([4])).sum().item())
";
    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(d.clone());
    vm.exec_source(src, IsaVersion::V310).unwrap();
    let gen = d.generated_codes();
    assert!(gen.len() >= 3, "expected transformed + resumes, got {:?}", gen.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>());
    for (name, code) in gen.iter() {
        let text = decompile(code).unwrap_or_else(|e| panic!("{}: {}", name, e));
        compile_module(&text, "<rt>", code.version).unwrap_or_else(|e| panic!("{} recompile: {}\n{}", name, e, text));
    }
}

/// The full syntax suite passes for depyf on the 3.11 encoding (the
/// hardest: RESUME/PRECALL/CACHE/relative jumps).
#[test]
fn depyf_v311_suite() {
    let (cell, failures) = run_syntax_suite(&DepyfRs, IsaVersion::V311);
    assert_eq!(cell.pass, cell.total, "{:#?}", failures);
}

/// Tensors flow correctly through a compiled-graph callable installed as a
/// global (the CompiledGraph value type).
#[test]
fn compiled_graph_value_call() {
    let mut vm = Vm::new();
    let d = Dynamo::new(DynamoConfig::default());
    vm.eval_hook = Some(d.clone());
    vm.exec_source("def f(x):\n    return x.relu()\nr = f(torch.ones([2]))\n", IsaVersion::V310).unwrap();
    // The installed global __compiled_fn_1 is directly callable.
    let g = vm.get_global("__compiled_fn_1").expect("compiled fn installed");
    let out = vm.call(&g, &[Value::tensor(Tensor::new(vec![2], vec![-1.0, 5.0]))]).unwrap();
    match out {
        Value::Tuple(t) => {
            let Value::Tensor(r) = &t[0] else { panic!() };
            assert_eq!(r.data(), &[0.0, 5.0]);
        }
        other => panic!("expected tuple, got {:?}", other),
    }
}

/// Capture every graph the (fully-capturable) table1 model corpus
/// produces under dynamo.
fn corpus_graphs() -> Vec<(String, Arc<Graph>)> {
    let mut out = Vec::new();
    for case in model_cases().into_iter().filter(|c| c.full_capture) {
        let mut vm = Vm::new();
        vm.seed(13);
        let d = Dynamo::new(DynamoConfig::default());
        vm.eval_hook = Some(d.clone());
        vm.exec_source(&case.source, IsaVersion::V310)
            .unwrap_or_else(|e| panic!("{} failed: {}", case.name, e));
        for (name, g) in d.graphs().iter() {
            out.push((format!("{}::{}", case.name, name), Arc::clone(g)));
        }
    }
    assert!(out.len() >= 20, "corpus produced only {} graphs", out.len());
    out
}

/// Positive inputs keep integer-valued placeholders (embedding ids,
/// cross-entropy targets) valid: they all floor to 0.
fn positive_inputs(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
    let mut rng = Rng::new(seed);
    g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::rand(&s, &mut rng))).collect()
}

/// Acceptance: the sharded and batched backends are bitwise-equivalent to
/// the eager reference on every graph captured from the table1 corpus.
#[test]
fn sharded_and_batched_match_eager_on_table1_corpus_graphs() {
    let sharded = ShardedBackend::with_max_ops(2);
    let batched = BatchedBackend::new();
    for (tag, g) in corpus_graphs() {
        let inputs = positive_inputs(&g, 0xC0FFEE);
        let want = eager::execute(&g, &inputs).unwrap_or_else(|e| panic!("{}: eager failed: {}", tag, e));
        for (bname, backend) in [("sharded", &sharded as &dyn Backend), ("batched", &batched)] {
            let req = CompileRequest::new(&tag, Arc::clone(&g));
            let module = backend
                .compile(&req)
                .unwrap_or_else(|e| panic!("{}: {} compile failed: {}", tag, bname, e));
            let got = module
                .call(&inputs)
                .unwrap_or_else(|e| panic!("{}: {} call failed: {}", tag, bname, e));
            assert_eq!(got.len(), want.len(), "{}: {}", tag, bname);
            for (a, b) in got.iter().zip(want.iter()) {
                assert_eq!(a.shape(), b.shape(), "{}: {}", tag, bname);
                assert_eq!(a.data(), b.data(), "{}: {} diverged bitwise", tag, bname);
            }
        }
    }
}

/// `depyf dump --backend sharded` workflow: the session compiles through
/// the sharded backend, output matches plain execution, and the plan
/// artifact lands typed in the manifest.
#[test]
fn sharded_session_dumps_plan_artifacts() {
    let src = "\
torch.manual_seed(4)
W1 = torch.randn([6, 6])
W2 = torch.randn([6, 6])
def forward(x):
    h = (x @ W1).relu()
    return (h @ W2).softmax().sum()
print(forward(torch.ones([3, 6])).item())
print(forward(torch.ones([3, 6])).item())
";
    let plain = Vm::new();
    plain.seed(2);
    plain.exec_source(src, IsaVersion::V310).unwrap();
    let expected = plain.take_output();

    let dir = std::env::temp_dir().join(format!("depyf_sharded_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut s = Session::builder()
        .dump_to(&dir)
        .backend_named("sharded")
        .isa(IsaVersion::V310)
        .fallback(FallbackPolicy::Error)
        .build()
        .unwrap();
    s.vm.seed(2);
    s.run_source("main", src).unwrap();
    assert_eq!(s.vm.take_output(), expected);
    let artifacts = s.finish().unwrap();
    let plan = artifacts.iter().find(|a| a.kind == ArtifactKind::Plan).expect("plan artifact dumped");
    let parsed = CompilePlan::parse(&std::fs::read_to_string(&plan.path).unwrap()).unwrap();
    assert_eq!(parsed.backend, "sharded");
    assert!(parsed.partitions.len() >= 2, "graph should shard: {:?}", parsed.partitions.len());
    // The manifest indexes the plan artifact with its typed kind.
    let indexed = load_manifest(&dir).unwrap();
    assert_eq!(indexed, artifacts);
    // metrics.json carries per-module backend stats.
    let metrics = artifacts.iter().find(|a| a.kind == ArtifactKind::Metrics).unwrap();
    let doc = depyf::api::json::parse(&std::fs::read_to_string(&metrics.path).unwrap()).unwrap();
    let modules = doc.get("modules").and_then(|m| m.as_arr()).expect("modules array");
    assert!(!modules.is_empty());
    assert!(
        modules[0].get("partitions").and_then(|v| v.as_f64()).unwrap() >= 2.0,
        "module stats must record the partition count"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Capability misconfiguration is rejected at build() under
/// FallbackPolicy::Error, and absorbed under the default eager policy.
#[test]
fn capability_requirements_validated_at_build() {
    let dir = std::env::temp_dir().join(format!("depyf_caps_{}", std::process::id()));
    let err = Session::builder()
        .dump_to(&dir)
        .backend_named("eager")
        .require(Capabilities::PARTITION)
        .fallback(FallbackPolicy::Error)
        .build()
        .unwrap_err();
    assert_eq!(err.layer(), "builder");
    assert!(err.to_string().contains("partition"), "{}", err);
    // A backend that declares the capability builds.
    Session::builder()
        .dump_to(&dir)
        .backend_named("sharded")
        .require(Capabilities::PARTITION)
        .fallback(FallbackPolicy::Error)
        .build()
        .unwrap();
    Session::builder()
        .dump_to(&dir)
        .backend_named("batched")
        .require(Capabilities::DYNAMIC_BATCH)
        .fallback(FallbackPolicy::Error)
        .build()
        .unwrap();
    // Under the default eager policy the fallback absorbs the gap.
    Session::builder()
        .dump_to(&dir)
        .backend_named("eager")
        .require(Capabilities::DYNAMIC_BATCH)
        .build()
        .unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Step-through debugging works through the builder (`TraceMode::StepGraphs`).
#[test]
fn step_graphs_through_builder() {
    let dir = std::env::temp_dir().join(format!("depyf_it_dbg_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut s = Session::builder().dump_to(&dir).trace(TraceMode::StepGraphs).build().unwrap();
    s.debugger.break_at("__compiled_fn_1.py", 2);
    s.run_source("main", "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([3])).item())\n").unwrap();
    let artifacts: Vec<Artifact> = s.finish().unwrap();
    assert!(artifacts.iter().any(|a| a.kind == ArtifactKind::Guards));
    assert!(s.debugger.events().iter().any(|e| e.file.ends_with("__compiled_fn_1.py")));
    std::fs::remove_dir_all(&dir).ok();
}
