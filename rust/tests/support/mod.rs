//! Shared test support: the **seeded graph generator** used by both the
//! conformance harness (`tests/conformance.rs`) and the property tests
//! (`tests/proptests.rs`).
//!
//! Determinism is a hard requirement (same seed → same graphs → same
//! inputs), so everything is driven by the crate's own
//! [`depyf::tensor::Rng`] — no `Date::now`, no global randomness. The
//! generator deliberately steers into the features the backends treat
//! specially: broadcasting binary ops (rank/extent-1 mismatches), matmuls
//! sized across the eager executor's k-blocked kernel threshold (a B
//! panel larger than 64 KiB), constant scalar/tensor operands (const
//! folding shapes), reductions with and without axes, reshapes, permutes
//! and softmax/layernorm rows.

#![allow(dead_code)]

use std::rc::Rc;

use depyf::graph::{Graph, NodeKind, OpKind};
use depyf::tensor::{Rng, Tensor};

/// The eager matmul kernel switches to k-blocking when the B panel
/// (k × n × 4 bytes) outgrows ~64 KiB; generated "big" matmuls cross it.
pub const BLOCKED_MATMUL_B_PANEL_BYTES: usize = 64 * 1024;

/// Deterministic, seeded graph generator.
pub struct GraphGen {
    rng: Rng,
    count: usize,
}

impl GraphGen {
    pub fn new(seed: u64) -> GraphGen {
        GraphGen { rng: Rng::new(seed), count: 0 }
    }

    fn dim(&mut self) -> usize {
        // Extent 1 is deliberately common: it is what broadcasting keys on.
        [1, 2, 3, 4, 5][self.rng.below(5)]
    }

    fn shape(&mut self) -> Vec<usize> {
        let rank = 1 + self.rng.below(3);
        (0..rank).map(|_| self.dim()).collect()
    }

    /// Generate the next graph. Graph `name`s carry a running index so
    /// two generators with the same seed produce identical sequences.
    pub fn next_graph(&mut self) -> Graph {
        let idx = self.count;
        self.count += 1;
        // Every 8th graph exercises the k-blocked matmul kernel.
        if idx % 8 == 7 {
            return self.big_matmul_graph(idx);
        }
        let mut g = Graph::new(&format!("gen_{}", idx));
        let n_inputs = 1 + self.rng.below(3);
        let mut pool: Vec<usize> = Vec::new();
        for i in 0..n_inputs {
            let shape = self.shape();
            pool.push(g.placeholder(&format!("x{}", i), &shape));
        }
        // Constant operands: scalars and small tensors (const folding).
        if self.rng.below(2) == 0 {
            pool.push(g.const_scalar((self.rng.uniform() as f64) * 4.0 - 2.0));
        }
        if self.rng.below(3) == 0 {
            let d = self.dim();
            pool.push(g.const_tensor(Tensor::randn(&[d], &mut self.rng)));
        }
        let n_ops = 3 + self.rng.below(6);
        let mut exp_used = false;
        for _ in 0..n_ops {
            self.add_random_op(&mut g, &mut pool, &mut exp_used);
        }
        // 1–2 outputs: the most recent op result, plus occasionally an
        // earlier op (ops only — every backend path treats op outputs
        // uniformly; placeholder outputs are not what models return).
        let last_op = *pool
            .iter()
            .rev()
            .find(|&&id| matches!(g.nodes[id].kind, NodeKind::Op(..)))
            .expect("the fallback arm guarantees at least one op");
        let mut outputs = vec![last_op];
        if self.rng.below(2) == 0 {
            let extra = pool[self.rng.below(pool.len())];
            if matches!(g.nodes[extra].kind, NodeKind::Op(..)) && !outputs.contains(&extra) {
                outputs.push(extra);
            }
        }
        g.set_outputs(outputs);
        g
    }

    /// A `[m, k] @ [k, n]` chain whose B panel crosses the blocking
    /// threshold, composed with an elementwise epilogue.
    fn big_matmul_graph(&mut self, idx: usize) -> Graph {
        let mut g = Graph::new(&format!("gen_{}", idx));
        let m = 4 + self.rng.below(5);
        let k = 96;
        let n = 180 + self.rng.below(40); // k*n*4 ≥ 69 KB > 64 KiB
        let x = g.placeholder("x", &[m, k]);
        let w = g.placeholder("w", &[k, n]);
        let b = g.placeholder("b", &[n]); // broadcast along rows
        let mm = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let biased = g.add_op(OpKind::Add, vec![mm, b]).unwrap();
        let act = g.add_op(OpKind::Tanh, vec![biased]).unwrap();
        let red = g.add_op(OpKind::Sum(Some(1)), vec![act]).unwrap();
        g.set_outputs(vec![red]);
        g
    }

    /// Append one random (shape-valid) op, favoring feature coverage.
    fn add_random_op(&mut self, g: &mut Graph, pool: &mut Vec<usize>, exp_used: &mut bool) {
        for _attempt in 0..8 {
            let choice = self.rng.below(10);
            let added = match choice {
                // Binary elementwise (broadcasting). Div/Pow excluded: the
                // generator keeps values finite so eps-mode replays (XLA)
                // aren't dominated by inf/NaN plumbing.
                0 | 1 | 2 => {
                    let a = pool[self.rng.below(pool.len())];
                    let b = pool[self.rng.below(pool.len())];
                    let ops = [OpKind::Add, OpKind::Sub, OpKind::Mul, OpKind::Maximum, OpKind::Minimum];
                    let op = ops[self.rng.below(5)].clone();
                    g.add_op(op, vec![a, b]).ok()
                }
                // Squashing unaries keep magnitudes bounded under chaining.
                3 | 4 => {
                    let a = pool[self.rng.below(pool.len())];
                    let ops =
                        [OpKind::Neg, OpKind::Relu, OpKind::Tanh, OpKind::Sigmoid, OpKind::Abs, OpKind::Gelu];
                    let op = ops[self.rng.below(6)].clone();
                    g.add_op(op, vec![a]).ok()
                }
                // One exp per graph (over a sigmoid, so it stays bounded),
                // and sqrt only over abs (stays finite).
                5 => {
                    let a = pool[self.rng.below(pool.len())];
                    if *exp_used {
                        match g.add_op(OpKind::Abs, vec![a]) {
                            Ok(ab) => g.add_op(OpKind::Sqrt, vec![ab]).ok(),
                            Err(_) => None,
                        }
                    } else {
                        match g.add_op(OpKind::Sigmoid, vec![a]) {
                            Ok(sq) => {
                                *exp_used = true;
                                g.add_op(OpKind::Exp, vec![sq]).ok()
                            }
                            Err(_) => None,
                        }
                    }
                }
                // Small matmul between a rank-2 pool value and a fresh weight.
                6 => match pool.iter().rev().find(|&&id| g.nodes[id].shape.len() == 2).copied() {
                    None => None,
                    Some(a) => {
                        let k = g.nodes[a].shape[1];
                        let n = self.dim();
                        let w = g.placeholder(&format!("w{}", g.nodes.len()), &[k, n]);
                        g.add_op(OpKind::MatMul, vec![a, w]).ok()
                    }
                },
                // Reductions, with and without axes.
                7 => {
                    let a = pool[self.rng.below(pool.len())];
                    let rank = g.nodes[a].shape.len();
                    let axis = if rank > 0 && self.rng.below(2) == 0 {
                        Some(self.rng.below(rank))
                    } else {
                        None
                    };
                    let op = match self.rng.below(4) {
                        0 => OpKind::Sum(axis),
                        1 => OpKind::Mean(axis),
                        2 => OpKind::Max(axis),
                        _ => OpKind::Min(axis),
                    };
                    g.add_op(op, vec![a]).ok()
                }
                // Shape ops: transpose / permute / row-preserving reshape.
                8 => {
                    let a = pool[self.rng.below(pool.len())];
                    let rank = g.nodes[a].shape.len();
                    if rank >= 2 && self.rng.below(2) == 0 {
                        g.add_op(OpKind::Transpose, vec![a]).ok()
                    } else if rank >= 2 {
                        let mut perm: Vec<usize> = (0..rank).collect();
                        // Deterministic Fisher-Yates.
                        for i in (1..rank).rev() {
                            perm.swap(i, self.rng.below(i + 1));
                        }
                        g.add_op(OpKind::Permute(perm), vec![a]).ok()
                    } else {
                        g.add_op(OpKind::Reshape(vec![-1]), vec![a]).ok()
                    }
                }
                // Softmax rows (rank >= 1).
                _ => {
                    let a = pool[self.rng.below(pool.len())];
                    if g.nodes[a].shape.is_empty() {
                        None
                    } else {
                        g.add_op(OpKind::Softmax, vec![a]).ok()
                    }
                }
            };
            if let Some(id) = added {
                pool.push(id);
                return;
            }
        }
        // All attempts were shape-invalid: fall back to a guaranteed op.
        let a = pool[self.rng.below(pool.len())];
        let id = g.add_op(OpKind::Neg, vec![a]).expect("neg always infers");
        pool.push(id);
    }
}

/// Deterministic random inputs for a generated graph.
pub fn rand_inputs(g: &Graph, rng: &mut Rng) -> Vec<Rc<Tensor>> {
    g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, rng))).collect()
}

// ---- coverage predicates (used by proptests to assert the generator
// actually hits the features it claims to) ----

/// Some binary op whose operand shapes differ (true broadcasting).
pub fn has_broadcast(g: &Graph) -> bool {
    g.nodes.iter().any(|n| match &n.kind {
        NodeKind::Op(
            OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Maximum | OpKind::Minimum,
            args,
        ) => g.nodes[args[0]].shape != g.nodes[args[1]].shape,
        _ => false,
    })
}

/// Some matmul whose B panel crosses the k-blocked kernel threshold.
pub fn has_big_matmul(g: &Graph) -> bool {
    g.nodes.iter().any(|n| match &n.kind {
        NodeKind::Op(OpKind::MatMul, args) => {
            let b = &g.nodes[args[1]].shape;
            b.len() == 2 && b[0] * b[1] * 4 > BLOCKED_MATMUL_B_PANEL_BYTES
        }
        _ => false,
    })
}

/// Some constant node feeding an op (const-folding shapes).
pub fn has_const_operand(g: &Graph) -> bool {
    g.nodes.iter().any(|n| match &n.kind {
        NodeKind::Op(_, args) => args.iter().any(|&a| {
            matches!(g.nodes[a].kind, NodeKind::ConstScalar(_) | NodeKind::ConstTensor(_))
        }),
        _ => false,
    })
}
