//! Chaos conformance: seeded fault injection over the serving stack.
//!
//! Every round installs a deterministic [`depyf::faults::FaultPlan`]
//! (exactly what `DEPYF_FAULTS=<spec>` would install), drives the table1
//! corpus through `depyf serve`'s engine — [`serve_once_with`], 4
//! concurrent threads against one shared module cache — and then
//! *reconciles* the injected-fault counters against the resilience
//! counters they must have produced:
//!
//! * `module.call` error/panic rounds:  `fired == retries + degraded_calls`
//! * compile (`backend.plan`/`lower`) rounds:
//!   `fired + breaker_skips == retries + degraded_compiles`
//! * delay-under-deadline rounds:       `fired == timeouts == degraded_calls`
//! * `worker_pool.submit` rounds:       `fired == retries + degraded_calls`
//!   (an injected submit rejection reaches the caller as a typed
//!   *transient* error — retried once, then degraded)
//! * `worker.heartbeat` delay rounds:   `fired == watchdog_kills == respawns`
//!   (every wedged job is killed exactly once and every kill is matched
//!   by a respawn) and `fired == retries + degraded_calls` (every
//!   abandoned call surfaces exactly one transient error)
//! * `serve.admission` error rounds:    `fired == sheds == degraded_calls`
//!   (a shed is `Overloaded` — deliberately not transient, so it is
//!   never retried into the full queue)
//!
//! Throughout, `report.errors` must stay 0 — every degraded call is served
//! by the eager fallback, which is bitwise-equal to the single-thread
//! reference the corpus was built against — and no thread may die and no
//! lock may stay poisoned (each panic round is followed by a clean serve
//! in the same process).
//!
//! The global fault plan is process-wide, so every test here serializes
//! on one mutex; the in-crate unit tests never install an *armed* global
//! plan (see `src/faults/mod.rs`), which keeps the two binaries from
//! interfering even under `cargo test`'s parallelism.
//!
//! On failure a round dumps a repro bundle — the exact fault spec, whose
//! embedded seed is the entire source of randomness — into
//! `$DEPYF_CHAOS_OUT` (default `chaos_failures/`); CI uploads that
//! directory. Reproduce locally with
//! `cargo test -q --test chaos <round_test_name>`.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Duration;

use depyf::api::{
    lookup_backend, register_backend, Backend, CompilePlan, CompileRequest, CompiledModule,
    DepyfError, EagerBackend,
};
use depyf::faults::{self, FaultPlan, Site};
use depyf::runtime::DiskCache;
use depyf::serve::{serve_once_tuned, serve_once_with, ServeTuning, WorkerPool};

/// Armed fault plans are process-global: chaos rounds must never overlap.
/// Poison-recovering so one failed round cannot abort the rest.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(PoisonError::into_inner)
}

/// Run one chaos round; on failure, write a repro bundle (round name, the
/// exact `DEPYF_FAULTS` spec, the failure text) into `$DEPYF_CHAOS_OUT`
/// before re-raising the panic.
fn round<T>(name: &str, spec: &str, f: impl FnOnce() -> T) -> T {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic payload>");
            let dir = std::env::var("DEPYF_CHAOS_OUT")
                .map(std::path::PathBuf::from)
                .unwrap_or_else(|_| std::path::PathBuf::from("chaos_failures"));
            if std::fs::create_dir_all(&dir).is_ok() {
                let _ = std::fs::write(
                    dir.join(format!("{}.txt", name)),
                    format!(
                        "chaos round: {}\nfault spec:  DEPYF_FAULTS=\"{}\"\nfailure:     {}\n\n\
                         The seed inside the spec is the entire source of randomness: the same\n\
                         spec fires the same faults. Reproduce with\n\
                           cargo test -q --test chaos {}\n",
                        name, spec, msg, name
                    ),
                );
            }
            resume_unwind(payload)
        }
    }
}

fn install(spec: &str) -> faults::FaultGuard {
    faults::install(FaultPlan::parse(spec).expect("chaos spec parses"))
}

/// Full-rate `module.call` errors: every dispatch fails, is retried once
/// (injected faults are transient), then degrades to the eager fallback —
/// which must be bitwise-equal to the single-thread reference.
#[test]
fn module_call_errors_degrade_to_bitwise_correct_eager() {
    let _serial = chaos_lock();
    let spec = "seed=11;module.call=error";
    round("module_call_error", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 2, "eager", 3, None).expect("serve");
        let st = faults::stats(Site::ModuleCall);
        drop(guard);
        assert_eq!(report.errors, 0, "degraded calls must stay bitwise-correct: {:?}", report.failures);
        assert_eq!(report.dead_threads, 0);
        assert!(st.fired > 0, "full-rate plan must fire (hits {})", st.hits);
        let m = &report.metrics;
        assert!(m.retries > 0 && m.degraded_calls > 0, "retries {} degraded {}", m.retries, m.degraded_calls);
        assert_eq!(
            st.fired,
            m.retries + m.degraded_calls,
            "every injected fault is either retried or degraded (hits {})",
            st.hits
        );
        assert_eq!(m.timeouts, 0);
        assert_eq!(m.panics_caught, 0, "error faults do not unwind");
    });
}

/// The same call-fault round through the codegen backend
/// (`resilient:codegen`): failing loop-program dispatches retry, then
/// degrade to the eager fallback — bitwise-equal to the reference by the
/// conformance gate — and the counters reconcile exactly as for eager.
#[test]
fn codegen_under_call_faults_degrades_bitwise_correctly() {
    let _serial = chaos_lock();
    let spec = "seed=41;module.call=error@1/2";
    round("codegen_call_error", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 2, "resilient:codegen", 3, None).expect("serve");
        let st = faults::stats(Site::ModuleCall);
        drop(guard);
        assert_eq!(
            report.errors, 0,
            "degraded codegen calls must stay bitwise-correct: {:?}",
            report.failures
        );
        assert_eq!(report.dead_threads, 0);
        assert!(st.fired > 0, "plan fired nothing over {} hits", st.hits);
        let m = &report.metrics;
        assert_eq!(
            st.fired,
            m.retries + m.degraded_calls,
            "every injected fault is either retried or degraded (hits {})",
            st.hits
        );
    });
}

/// The acceptance-criteria round: `module.call` panics in some threads
/// must never fail a request on any thread, never kill a serving thread,
/// and never leave a lock poisoned — proven by a clean serve in the same
/// process immediately after.
#[test]
fn module_call_panics_never_fail_other_threads_or_poison_locks() {
    let _serial = chaos_lock();
    let spec = "seed=23;module.call=panic@1/2";
    round("module_call_panic", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 2, "eager", 3, None).expect("serve");
        let st = faults::stats(Site::ModuleCall);
        drop(guard);
        assert_eq!(
            report.errors, 0,
            "a panicking call in one thread must not fail any request: {:?}",
            report.failures
        );
        assert_eq!(report.dead_threads, 0, "panics are caught at the dispatch layer; threads never die");
        assert!(st.fired > 0, "plan fired nothing over {} hits", st.hits);
        let m = &report.metrics;
        assert_eq!(m.panics_caught, st.fired, "every injected panic is caught exactly once");
        assert_eq!(st.fired, m.retries + m.degraded_calls, "hits {}", st.hits);

        // Same process, plan uninstalled: serving is clean and every
        // resilience counter stays at zero — nothing was left poisoned,
        // no breaker stays tripped, no fault machinery stays engaged.
        let clean = serve_once_with(4, 1, "eager", 3, None).expect("clean serve after panic round");
        assert_eq!(clean.errors, 0, "{:?}", clean.failures);
        assert_eq!(clean.dead_threads, 0);
        let c = &clean.metrics;
        assert_eq!(
            (c.retries, c.degraded_calls, c.degraded_compiles, c.breaker_trips, c.timeouts, c.panics_caught),
            (0, 0, 0, 0, 0, 0),
            "no resilience counter moves once the plan is uninstalled"
        );
    });
}

/// A full compiler outage (`backend.plan` always fails): compiles retry,
/// the breaker trips, later compiles are skipped fail-fast, and *every*
/// case is still answered correctly by the eager fallback.
#[test]
fn full_compile_outage_trips_the_breaker_and_serves_eager() {
    let _serial = chaos_lock();
    let spec = "seed=5;backend.plan=error";
    round("backend_plan_outage", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 2, "eager", 2, None).expect("serve");
        let st = faults::stats(Site::BackendPlan);
        drop(guard);
        assert_eq!(
            report.errors, 0,
            "an unavailable compiler degrades to eager; it never serves wrong answers: {:?}",
            report.failures
        );
        let m = &report.metrics;
        assert!(m.degraded_compiles > 0, "every compile must degrade");
        assert!(m.retries > 0, "injected plan faults are transient and retried first");
        assert!(m.breaker_trips > 0, "consecutive failures must trip the breaker");
        // Reconciliation: every compile that reached the backend ends a
        // fired-fault retry chain; every breaker skip degrades a compile
        // *without* a fired fault.
        assert_eq!(
            st.fired + m.breaker_skips,
            m.retries + m.degraded_compiles,
            "fired {} skips {} retries {} degraded {} (hits {})",
            st.fired, m.breaker_skips, m.retries, m.degraded_compiles, st.hits
        );
        assert_eq!(m.degraded_calls, 0, "a compile-level outage never reaches the call path");
    });
}

/// Same reconciliation for the `backend.lower` site (shared-cache misses
/// route through it; a permanently failing lower keeps the module cache
/// cold, so the gate stays hot).
#[test]
fn backend_lower_faults_reconcile_with_compile_counters() {
    let _serial = chaos_lock();
    let spec = "seed=9;backend.lower=error";
    round("backend_lower_outage", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 1, "eager", 2, None).expect("serve");
        let st = faults::stats(Site::BackendLower);
        drop(guard);
        assert_eq!(report.errors, 0, "{:?}", report.failures);
        let m = &report.metrics;
        assert!(st.fired > 0, "lower must be exercised (hits {})", st.hits);
        assert!(m.degraded_compiles > 0);
        assert_eq!(
            st.fired + m.breaker_skips,
            m.retries + m.degraded_compiles,
            "fired {} skips {} retries {} degraded {}",
            st.fired, m.breaker_skips, m.retries, m.degraded_compiles
        );
    });
}

/// Injected 600ms stalls against a 120ms deadline: every stalled call is
/// abandoned (never retried — the module is presumed stuck) and served by
/// the eager fallback; the stage/worker threads never deadlock.
#[test]
fn deadline_abandons_stuck_calls_and_serves_the_fallback() {
    let _serial = chaos_lock();
    let spec = "seed=31;module.call=delay:600";
    round("deadline_delay", spec, || {
        let guard = install(spec);
        let report = serve_once_with(2, 1, "eager", 1, Some(120)).expect("serve");
        // Abandoned watchdog threads may still be inside the injected
        // sleep; wait them out so every fired delay is on the books.
        std::thread::sleep(Duration::from_millis(800));
        let st = faults::stats(Site::ModuleCall);
        drop(guard);
        assert_eq!(report.errors, 0, "{:?}", report.failures);
        let m = &report.metrics;
        assert!(m.timeouts > 0, "600ms injected delays must overrun a 120ms deadline");
        assert_eq!(m.timeouts, st.fired, "every fired delay times out; nothing else does");
        assert_eq!(m.degraded_calls, m.timeouts, "every abandoned call is served by the fallback");
        assert_eq!(m.retries, 0, "timed-out calls are abandoned, never retried");
    });
}

/// `worker_pool.submit` faults reject the job at the queue's edge; the
/// call's future resolves with a typed *transient* error (never a hang,
/// never a silently dropped promise), so the dispatch path retries once
/// and then degrades to the eager fallback.
#[test]
fn rejected_pool_submissions_degrade_instead_of_hanging() {
    let _serial = chaos_lock();
    let spec = "seed=17;worker_pool.submit=error@1/2";
    round("worker_submit", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 2, "async:eager", 2, None).expect("serve");
        let st = faults::stats(Site::WorkerSubmit);
        drop(guard);
        assert_eq!(report.errors, 0, "{:?}", report.failures);
        assert_eq!(report.dead_threads, 0);
        let m = &report.metrics;
        assert!(st.hits > 0, "async dispatch must reach the pool");
        assert!(st.fired > 0, "plan fired nothing over {} hits", st.hits);
        assert_eq!(
            st.fired,
            m.retries + m.degraded_calls,
            "each rejected submission is retried or degraded exactly once (hits {})",
            st.hits
        );
        assert_eq!(m.timeouts, 0);
    });
}

/// Injected `worker.heartbeat` delays wedge supervised jobs far past the
/// stall budget: the watchdog must kill each wedged worker exactly once,
/// respawn a replacement for every kill, and resolve the abandoned call
/// with a transient error that the dispatch path retries/degrades — so
/// the serve stays bitwise-correct with zero errors. Exact ledger:
/// `fired == watchdog_kills == respawns` and
/// `fired == retries + degraded_calls`.
#[test]
fn stalled_workers_are_killed_respawned_and_reconciled_exactly() {
    let _serial = chaos_lock();
    let spec = "seed=53;worker.heartbeat=delay:250@1/4";
    round("worker_heartbeat_stall", spec, || {
        let guard = install(spec);
        // Raise the restart budget far above any plausible fire count so
        // the give-up path cannot break the 1:1:1 reconciliation, and
        // shrink the stall budget well under the 250ms injected wedge
        // (while staying far above a legitimate sub-ms eager call).
        let tuning = ServeTuning { stall_ms: 60, max_restarts: 100_000, ..ServeTuning::default() };
        let report = serve_once_tuned(2, 1, "async:eager", 2, tuning).expect("serve");
        let st = faults::stats(Site::WorkerHeartbeat);
        drop(guard);
        assert_eq!(report.errors, 0, "abandoned calls must degrade bitwise-correctly: {:?}", report.failures);
        assert_eq!(report.dead_threads, 0, "kills hit pool workers, never serving threads");
        assert!(st.fired > 0, "plan fired nothing over {} hits", st.hits);
        let m = &report.metrics;
        assert_eq!(
            m.watchdog_kills, st.fired,
            "every wedged job is killed exactly once (hits {})",
            st.hits
        );
        assert_eq!(m.respawns, st.fired, "every kill is matched by a respawn");
        assert_eq!(
            st.fired,
            m.retries + m.degraded_calls,
            "every abandonment surfaces exactly one transient error"
        );
        assert_eq!(m.timeouts, 0, "no deadline in play; abandonment is not a timeout");
        assert_eq!(m.sheds, 0, "the queue never overflowed");
    });
}

/// Injected `serve.admission` faults force a shed at the supervisor's
/// front door: the caller sees a typed `Overloaded` error — deliberately
/// *not* transient, so it is never retried into the (notionally full)
/// queue — and is served by the eager fallback. Exact ledger:
/// `fired == sheds == degraded_calls` with zero retries and timeouts.
#[test]
fn admission_faults_shed_and_still_serve_correct_answers() {
    let _serial = chaos_lock();
    let spec = "seed=61;serve.admission=error@1/3";
    round("serve_admission_shed", spec, || {
        let guard = install(spec);
        let report = serve_once_with(4, 2, "async:eager", 2, None).expect("serve");
        let st = faults::stats(Site::ServeAdmission);
        drop(guard);
        assert_eq!(report.errors, 0, "shed calls must be served correctly by eager: {:?}", report.failures);
        assert_eq!(report.dead_threads, 0);
        assert!(st.fired > 0, "plan fired nothing over {} hits", st.hits);
        let m = &report.metrics;
        assert_eq!(m.sheds, st.fired, "every fired admission fault sheds exactly once");
        assert_eq!(m.sheds, m.degraded_calls, "every shed is served by the fallback");
        assert_eq!(m.retries, 0, "Overloaded is not transient; sheds are never retried");
        assert_eq!(m.timeouts, 0);
        assert_eq!(m.watchdog_kills, 0, "admission faults never touch healthy workers");
    });
}

/// Compile faults and worker stalls *together*: while the circuit breaker
/// is tripping/half-open-probing recompiles, the watchdog is concurrently
/// killing and respawning wedged workers. The two recovery mechanisms
/// must not interfere: the combined ledger reconciles every injected
/// failure event as exactly one retry or one degrade, kills stay matched
/// with respawns, no serving thread dies, and a clean serve in the same
/// process proves nothing stays latched or poisoned.
#[test]
fn breaker_probes_race_respawns_without_interference() {
    let _serial = chaos_lock();
    let spec = "seed=37;backend.plan=error@1/2;worker.heartbeat=delay:250@1/5";
    round("breaker_vs_respawn", spec, || {
        let guard = install(spec);
        let tuning = ServeTuning { stall_ms: 60, max_restarts: 100_000, ..ServeTuning::default() };
        let report = serve_once_tuned(2, 2, "async:eager", 2, tuning).expect("serve");
        let st_plan = faults::stats(Site::BackendPlan);
        let st_hb = faults::stats(Site::WorkerHeartbeat);
        drop(guard);
        assert_eq!(report.errors, 0, "{:?}", report.failures);
        assert_eq!(report.dead_threads, 0);
        assert!(st_plan.hits > 0, "compiles must reach the faulted planner");
        let m = &report.metrics;
        assert_eq!(m.watchdog_kills, st_hb.fired, "kills track fired stalls exactly");
        assert_eq!(m.respawns, m.watchdog_kills, "every kill is matched by a respawn");
        // The combined ledger: every fired plan fault, breaker skip and
        // fired stall is accounted as exactly one retry or one degrade.
        assert_eq!(
            st_plan.fired + m.breaker_skips + st_hb.fired,
            m.retries + m.degraded_compiles + m.degraded_calls,
            "plan fired {} skips {} stalls fired {} retries {} degraded compiles {} degraded calls {}",
            st_plan.fired, m.breaker_skips, st_hb.fired, m.retries, m.degraded_compiles, m.degraded_calls
        );

        // Same process, plan uninstalled: a fresh serve is clean — no
        // breaker stays tripped, no supervisor state leaks across runs.
        let clean = serve_once_with(2, 1, "async:eager", 2, None).expect("clean serve after chaos");
        assert_eq!(clean.errors, 0, "{:?}", clean.failures);
        assert_eq!(clean.dead_threads, 0);
        let c = &clean.metrics;
        assert_eq!(
            (c.retries, c.degraded_calls, c.degraded_compiles, c.watchdog_kills, c.respawns, c.sheds),
            (0, 0, 0, 0, 0, 0),
            "no resilience or supervision counter moves once the plan is uninstalled"
        );
    });
}

/// `pipeline.stage` faults — errors *and* panics — fail exactly one
/// in-flight packet. The stage thread survives (a dead stage would
/// deadlock every later call), the failed call retries or degrades, and
/// the counters reconcile like any other call-path fault.
#[test]
fn pipeline_stage_faults_fail_one_packet_not_the_pipeline() {
    let _serial = chaos_lock();
    for (name, spec) in [
        ("pipeline_stage_error", "seed=13;pipeline.stage=error@1/3"),
        ("pipeline_stage_panic", "seed=29;pipeline.stage=panic@1/3"),
    ] {
        round(name, spec, || {
            let guard = install(spec);
            let report = serve_once_with(4, 2, "pipelined", 2, None).expect("serve");
            let st = faults::stats(Site::PipelineStage);
            drop(guard);
            assert_eq!(report.errors, 0, "{}: {:?}", name, report.failures);
            assert_eq!(report.dead_threads, 0, "{}", name);
            let m = &report.metrics;
            assert!(st.fired > 0, "{}: plan fired nothing over {} hits", name, st.hits);
            assert_eq!(
                st.fired,
                m.retries + m.degraded_calls,
                "{}: every failed packet is retried or degraded (hits {})",
                name, st.hits
            );
        });
    }
}

/// Disk-cache faults degrade to *misses*, never errors: a faulted read
/// reports a miss while the entry stays intact; a faulted write is
/// skipped, leaving the cache cold but consistent.
#[test]
fn disk_cache_faults_degrade_to_misses_not_failures() {
    let _serial = chaos_lock();
    let dir = std::env::temp_dir().join(format!("depyf-chaos-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = DiskCache::open(&dir).expect("open cache");
    cache.put("graph:k", "HloModule chaos\n", 2);
    assert_eq!(cache.get("graph:k"), Some(("HloModule chaos\n".to_string(), 2)));

    let read_spec = "seed=3;disk_cache.read=error";
    round("disk_cache_read", read_spec, || {
        let guard = install(read_spec);
        assert_eq!(cache.get("graph:k"), None, "an injected read fault is a miss, not an error");
        let st = faults::stats(Site::DiskCacheRead);
        assert_eq!((st.hits, st.fired), (1, 1));
        drop(guard);
        assert!(cache.get("graph:k").is_some(), "the entry is intact once the fault clears");
    });

    let write_spec = "seed=3;disk_cache.write=error";
    round("disk_cache_write", write_spec, || {
        let guard = install(write_spec);
        cache.put("graph:k2", "HloModule dropped\n", 1);
        let st = faults::stats(Site::DiskCacheWrite);
        assert_eq!((st.hits, st.fired), (1, 1));
        drop(guard);
        assert_eq!(cache.get("graph:k2"), None, "the faulted write was skipped");
        assert!(cache.get("graph:k").is_some(), "other entries are untouched");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// A panic raised *while the process-wide backend-registry lock is held*
/// (`register_backend` evaluates `backend.name()` under the write guard)
/// must not lock later callers out: every acquisition in the crate
/// recovers from poison.
#[test]
fn poisoned_registry_lock_recovers_for_later_callers() {
    let _serial = chaos_lock();
    struct PanickyName;
    impl Backend for PanickyName {
        fn name(&self) -> &str {
            panic!("chaos: name() panics while the registry write lock is held")
        }
        fn plan(&self, _req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
            unreachable!("never registered")
        }
        fn lower(
            &self,
            _req: &CompileRequest,
            _plan: &CompilePlan,
        ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
            unreachable!("never registered")
        }
    }
    let poisoned = catch_unwind(AssertUnwindSafe(|| register_backend(Arc::new(PanickyName))));
    assert!(poisoned.is_err(), "name() must panic under the registry lock");
    // Reads and writes both recover from the poison.
    assert!(lookup_backend("eager").is_some(), "lookups survive a poisoned registry");
    register_backend(Arc::new(EagerBackend));
    assert!(lookup_backend("eager").is_some(), "registration works after recovery too");
}

/// A job that panics kills one pool worker; the queue mutex (released
/// before the job runs) is not poisoned, and the surviving worker drains
/// every later job. Pool teardown joins the dead worker without hanging.
#[test]
fn pool_survives_a_panicking_job() {
    let _serial = chaos_lock();
    let pool = WorkerPool::new(2);
    let (tx, rx) = std::sync::mpsc::channel();
    assert!(
        pool.submit(Box::new(|| panic!("chaos: job panics on a worker thread"))).is_ok(),
        "a live pool accepts the job"
    );
    for i in 0..4 {
        let tx = tx.clone();
        let accepted = pool.submit(Box::new(move || {
            let _ = tx.send(i);
        }));
        assert!(accepted.is_ok(), "a live pool accepts follow-up jobs");
    }
    let mut got: Vec<i32> = (0..4)
        .map(|_| rx.recv_timeout(Duration::from_secs(10)).expect("surviving worker drains the queue"))
        .collect();
    got.sort_unstable();
    assert_eq!(got, vec![0, 1, 2, 3]);
    drop(pool); // joins every worker, including the dead one — must not hang
}

/// The reproducibility contract behind the repro bundles: with one
/// serving thread (no scheduling nondeterminism), the same spec produces
/// the same hits, the same fired faults and the same counter movements.
#[test]
fn same_seed_fires_the_same_faults() {
    let _serial = chaos_lock();
    let spec = "seed=47;module.call=error@1/4";
    round("determinism", spec, || {
        let mut runs = Vec::new();
        for _ in 0..2 {
            let guard = install(spec);
            let report = serve_once_with(1, 2, "eager", 2, None).expect("serve");
            let st = faults::stats(Site::ModuleCall);
            drop(guard);
            assert_eq!(report.errors, 0, "{:?}", report.failures);
            let m = &report.metrics;
            assert_eq!(st.fired, m.retries + m.degraded_calls, "hits {}", st.hits);
            runs.push((st.hits, st.fired, m.retries, m.degraded_calls));
        }
        assert_eq!(runs[0], runs[1], "single-threaded chaos rounds replay bit-identically from the seed");
    });
}
