//! The `codegen` backend: compiles an optimized graph into a flat,
//! register-allocated **loop program** instead of interpreting a `Step`
//! list per call.
//!
//! Where the eager executor ([`crate::backend::eager::ExecPlan`]) walks
//! node-indexed env slots with per-op dispatch, `Backend::lower` here runs
//! a real (if small) compiler:
//!
//! 1. **Instruction selection** — elementwise runs collapse into
//!    [`ElemLoop`]s (one chunked pass, specialized per [`ElemKind`]), 2-D
//!    matmuls become [`MatMulInstr`]s with their single-consumer
//!    elementwise tails folded in as **fused epilogues** (bias-add /
//!    activation applied to the output tile while it is cache-hot), and
//!    everything else (reductions, softmax, shape ops, batched matmul)
//!    falls back to one [`crate::backend::eager::eval_op`] call per node —
//!    bitwise-identical by construction.
//! 2. **Stride-class resolution** — every loop input is classified at
//!    lower time as `dense` (read straight from the source buffer),
//!    `splat` (scalar broadcast), `row` (innermost-axis vector broadcast,
//!    gathered by segment memcpy) or `strided` (general broadcast walked
//!    by the chunk odometer). The common cases never touch a per-element
//!    odometer.
//! 3. **Register allocation** — values live in a slot-numbered arena;
//!    liveness analysis frees a slot after its last reader, so slots (and
//!    their `f32` buffers, recycled through a free list) are reused across
//!    instructions. `peak_live` in the dump shows the win over the eager
//!    plan's one-slot-per-node env.
//!
//! The program renders as a readable `__loopir_*.txt` dump artifact
//! ([`crate::api::ArtifactKind::LoopIr`], indexed in `manifest.json`) —
//! the paper's transparency story applied to our own compiler. Execution
//! is proven **bitwise equal** to the eager oracle by the conformance
//! sweep (`tests/conformance.rs`) and unit tests below: every per-element
//! scalar op is the same code the unfused kernels run, matmul replicates
//! the eager kernel's exact accumulation order (including the k-blocked
//! path and its `av == 0.0` skip), and multi-threaded row tiling via
//! [`crate::serve::WorkerPool`] never changes any per-element order.

use std::rc::Rc;
use std::sync::{Arc, Mutex, TryLockError};

use crate::api::{
    ArtifactKind, Backend, CompilePlan, CompileRequest, CompiledModule, DepyfError, ModuleArtifact,
    ModuleStats,
};
use crate::backend::eager::eval_op;
use crate::graph::{Graph, NodeId, NodeKind, OpKind};
use crate::serve::future::{call_channel, WorkerPool};
use crate::tensor::{self, Tensor};

/// Chunk size of the loop executor — matches the eager fused executor so
/// both keep their working set cache-resident.
const CHUNK: usize = 4096;

/// Matmul k-blocking parameters — **must** mirror `tensor::ops`'s private
/// kernel constants so the plain/blocked path decision (and therefore the
/// bitwise result) is identical to the oracle.
const MM_KBLOCK: usize = 64;
const MM_BLOCK_MIN_PANEL: usize = 64 * 1024 / 4; // ~64 KiB of f32

/// Minimum `m * k * n` before a matmul is row-tiled across the pool.
const MM_PAR_MIN_WORK: usize = 1 << 20;
/// Minimum output elements before an elementwise loop is range-split.
const ELEM_PAR_MIN: usize = 1 << 16;
/// Recycled output buffers kept across calls.
const FREE_BUFS_MAX: usize = 32;

/// The 16 elementwise kinds a loop may contain. Per-element math is
/// bit-for-bit the kernels in `tensor::ops` (gelu/sigmoid literally share
/// one function), so fused loops and unfused per-op execution agree on
/// every bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElemKind {
    Add,
    Sub,
    Mul,
    Div,
    Pow,
    Maximum,
    Minimum,
    Neg,
    Relu,
    Gelu,
    Tanh,
    Sigmoid,
    Exp,
    Log,
    Sqrt,
    Abs,
}

impl ElemKind {
    fn from_op(op: &OpKind) -> Option<ElemKind> {
        Some(match op {
            OpKind::Add => ElemKind::Add,
            OpKind::Sub => ElemKind::Sub,
            OpKind::Mul => ElemKind::Mul,
            OpKind::Div => ElemKind::Div,
            OpKind::Pow => ElemKind::Pow,
            OpKind::Maximum => ElemKind::Maximum,
            OpKind::Minimum => ElemKind::Minimum,
            OpKind::Neg => ElemKind::Neg,
            OpKind::Relu => ElemKind::Relu,
            OpKind::Gelu => ElemKind::Gelu,
            OpKind::Tanh => ElemKind::Tanh,
            OpKind::Sigmoid => ElemKind::Sigmoid,
            OpKind::Exp => ElemKind::Exp,
            OpKind::Log => ElemKind::Log,
            OpKind::Sqrt => ElemKind::Sqrt,
            OpKind::Abs => ElemKind::Abs,
            _ => return None,
        })
    }

    fn is_binary(self) -> bool {
        matches!(
            self,
            ElemKind::Add
                | ElemKind::Sub
                | ElemKind::Mul
                | ElemKind::Div
                | ElemKind::Pow
                | ElemKind::Maximum
                | ElemKind::Minimum
        )
    }

    fn name(self) -> &'static str {
        match self {
            ElemKind::Add => "add",
            ElemKind::Sub => "sub",
            ElemKind::Mul => "mul",
            ElemKind::Div => "div",
            ElemKind::Pow => "pow",
            ElemKind::Maximum => "maximum",
            ElemKind::Minimum => "minimum",
            ElemKind::Neg => "neg",
            ElemKind::Relu => "relu",
            ElemKind::Gelu => "gelu",
            ElemKind::Tanh => "tanh",
            ElemKind::Sigmoid => "sigmoid",
            ElemKind::Exp => "exp",
            ElemKind::Log => "log",
            ElemKind::Sqrt => "sqrt",
            ElemKind::Abs => "abs",
        }
    }

    /// Binary per-element application (epilogue path; the chunk path uses
    /// [`apply_kind_chunk`] so the kind match hoists out of the loop).
    #[inline]
    fn apply2(self, x: f32, y: f32) -> f32 {
        match self {
            ElemKind::Add => x + y,
            ElemKind::Sub => x - y,
            ElemKind::Mul => x * y,
            ElemKind::Div => x / y,
            ElemKind::Pow => x.powf(y),
            ElemKind::Maximum => f32::max(x, y),
            ElemKind::Minimum => f32::min(x, y),
            _ => self.apply1(x),
        }
    }

    /// Unary per-element application.
    #[inline]
    fn apply1(self, x: f32) -> f32 {
        match self {
            ElemKind::Neg => -x,
            ElemKind::Relu => x.max(0.0),
            ElemKind::Gelu => tensor::gelu_scalar(x),
            ElemKind::Tanh => f32::tanh(x),
            ElemKind::Sigmoid => tensor::sigmoid_scalar(x),
            ElemKind::Exp => f32::exp(x),
            ElemKind::Log => f32::ln(x),
            ElemKind::Sqrt => f32::sqrt(x),
            ElemKind::Abs => f32::abs(x),
            _ => unreachable!("binary kind {:?} applied as unary", self),
        }
    }
}

/// Apply one kind over chunk slices, dispatching **once per chunk** so
/// each arm is a tight, vectorizable loop — the same structure (and the
/// same per-element bodies) as the eager fused executor's `apply_chunk`.
fn apply_kind_chunk(kind: ElemKind, a: &[f32], b: &[f32], dst: &mut [f32]) {
    macro_rules! bin {
        ($f:expr) => {
            for ((d, &x), &y) in dst.iter_mut().zip(a.iter()).zip(b.iter()) {
                *d = $f(x, y);
            }
        };
    }
    macro_rules! un {
        ($f:expr) => {
            for (d, &x) in dst.iter_mut().zip(a.iter()) {
                *d = $f(x);
            }
        };
    }
    match kind {
        ElemKind::Add => bin!(|x, y| x + y),
        ElemKind::Sub => bin!(|x, y| x - y),
        ElemKind::Mul => bin!(|x, y| x * y),
        ElemKind::Div => bin!(|x, y| x / y),
        ElemKind::Pow => bin!(|x: f32, y: f32| x.powf(y)),
        ElemKind::Maximum => bin!(f32::max),
        ElemKind::Minimum => bin!(f32::min),
        ElemKind::Neg => un!(|x: f32| -x),
        ElemKind::Relu => un!(|x: f32| x.max(0.0)),
        ElemKind::Gelu => un!(tensor::gelu_scalar),
        ElemKind::Tanh => un!(f32::tanh),
        ElemKind::Sigmoid => un!(tensor::sigmoid_scalar),
        ElemKind::Exp => un!(f32::exp),
        ElemKind::Log => un!(f32::ln),
        ElemKind::Sqrt => un!(f32::sqrt),
        ElemKind::Abs => un!(f32::abs),
    }
}

/// How a loop input is read, resolved at lower time from static shapes.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Access {
    /// Shape equals the loop's output shape: read the buffer directly.
    Dense,
    /// One element broadcast everywhere: pre-filled chunk buffer.
    Splat,
    /// Innermost-axis vector broadcast (`[n]` onto `[.., n]`): gathered by
    /// wrapping segment memcpy, no odometer.
    Row { period: usize },
    /// General broadcast: per-axis strides onto the output shape, walked
    /// by the shared chunk odometer (the uncommon case).
    Strided(Vec<usize>),
}

impl Access {
    /// Classify `shape` read at `out_shape` resolution.
    fn classify(shape: &[usize], out_shape: &[usize]) -> Access {
        if shape == out_shape {
            return Access::Dense;
        }
        let numel: usize = shape.iter().product();
        if numel <= 1 {
            return Access::Splat;
        }
        let strides = tensor::broadcast_strides_for(shape, out_shape.len());
        let rank = out_shape.len();
        let last = out_shape[rank - 1];
        if strides[rank - 1] == 1 && strides[..rank - 1].iter().all(|&s| s == 0) && numel == last {
            return Access::Row { period: last };
        }
        Access::Strided(strides)
    }
}

/// Where one loop step reads each operand.
#[derive(Clone, Copy, Debug)]
enum Src {
    /// External value: index into [`ElemLoop::inputs`].
    In(usize),
    /// Result of an earlier step in the same loop (register index).
    Reg(usize),
}

#[derive(Clone, Debug)]
struct ElemStep {
    kind: ElemKind,
    a: Src,
    /// Mirrors `a` for unary kinds (ignored).
    b: Src,
}

#[derive(Clone, Debug)]
struct LoopInput {
    slot: usize,
    access: Access,
}

/// A fused elementwise region compiled to one chunked, stride-resolved
/// pass over the output index space.
#[derive(Clone, Debug)]
struct ElemLoop {
    out_shape: Vec<usize>,
    numel: usize,
    inputs: Vec<LoopInput>,
    /// Steps in topological order; the last one writes the output.
    ops: Vec<ElemStep>,
}

/// One fused epilogue step applied to the matmul output tile.
#[derive(Clone, Debug)]
struct EpiStep {
    kind: ElemKind,
    operand: Option<EpiOperand>,
}

/// The non-accumulator operand of a binary epilogue step, read through
/// row/col strides resolved at lower time (`0` on broadcast axes).
#[derive(Clone, Debug)]
struct EpiOperand {
    slot: usize,
    row_stride: usize,
    col_stride: usize,
    /// True when the accumulator is the op's left-hand side.
    acc_is_lhs: bool,
}

/// A 2-D matmul with its fused elementwise tail.
#[derive(Clone, Debug)]
struct MatMulInstr {
    a_slot: usize,
    b_slot: usize,
    m: usize,
    k: usize,
    n: usize,
    epilogue: Vec<EpiStep>,
}

impl MatMulInstr {
    fn blocked(&self) -> bool {
        self.k * self.n >= MM_BLOCK_MIN_PANEL
    }
}

/// Fallback: evaluate one graph node through the eager reference kernels.
#[derive(Clone, Debug)]
struct EvalInstr {
    node: NodeId,
    /// `(graph node id, arena slot)` per argument.
    args: Vec<(NodeId, usize)>,
}

#[derive(Clone, Debug)]
enum InstrOp {
    /// Bind call input `index` into a slot.
    Input { index: usize },
    Loop(ElemLoop),
    MatMul(MatMulInstr),
    Eval(EvalInstr),
}

#[derive(Clone, Debug)]
struct Instr {
    op: InstrOp,
    /// The arena slot this instruction writes.
    out_slot: usize,
    /// Slots whose value dies after this instruction (freed eagerly; their
    /// buffers are recycled when uniquely owned).
    dead_after: Vec<usize>,
}

/// Reusable per-module execution state (arena, chunk buffers, the eval
/// fallback env and the recycled output buffers).
#[derive(Default)]
struct Scratch {
    arena: Vec<Option<Tensor>>,
    env: Vec<Option<Tensor>>,
    bufs: LoopBufs,
    free: Vec<Vec<f32>>,
}

/// Chunk-sized loop buffers, reused across instructions and calls.
#[derive(Default)]
struct LoopBufs {
    regs: Vec<Vec<f32>>,
    inbuf: Vec<Vec<f32>>,
    coords: Vec<usize>,
    gidx: Vec<usize>,
}

/// The compiled loop program: a linear instruction buffer over a
/// slot-numbered value arena.
pub struct LoopProgram {
    graph: Arc<Graph>,
    /// Arena template with constants pre-materialized at their slots.
    template: Vec<Option<Tensor>>,
    /// `(slot, node)` of each pre-materialized constant (for the dump).
    const_slots: Vec<(usize, NodeId)>,
    instrs: Vec<Instr>,
    /// Output slots, in graph-output order.
    outputs: Vec<usize>,
    n_slots: usize,
    peak_live: usize,
}

/// Take (or allocate) an output buffer of `numel` zeros.
fn take_buf(free: &mut Vec<Vec<f32>>, numel: usize) -> Vec<f32> {
    match free.pop() {
        Some(mut b) => {
            b.clear();
            b.resize(numel, 0.0);
            b
        }
        None => vec![0.0f32; numel],
    }
}

/// `od += ad(rows i0..i1 of am×ak) @ bd(ak×bn)`, `od` covering only rows
/// `i0..i1` (zeroed). Replicates the eager matmul kernel exactly — same
/// plain/blocked threshold on the full `ak*bn` panel, same strictly
/// ascending k order per output element, same `av == 0.0` skip — so any
/// row tiling of the output is bitwise identical to the full kernel.
fn matmul_rows(ad: &[f32], bd: &[f32], od: &mut [f32], i0: usize, i1: usize, ak: usize, bn: usize) {
    if ak * bn < MM_BLOCK_MIN_PANEL {
        for i in i0..i1 {
            for k in 0..ak {
                let av = ad[i * ak + k];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[k * bn..(k + 1) * bn];
                let orow = &mut od[(i - i0) * bn..(i - i0 + 1) * bn];
                for j in 0..bn {
                    orow[j] += av * brow[j];
                }
            }
        }
        return;
    }
    for k0 in (0..ak).step_by(MM_KBLOCK) {
        let k1 = (k0 + MM_KBLOCK).min(ak);
        for i in i0..i1 {
            let arow = &ad[i * ak..(i + 1) * ak];
            let orow = &mut od[(i - i0) * bn..(i - i0 + 1) * bn];
            for k in k0..k1 {
                let av = arow[k];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[k * bn..(k + 1) * bn];
                for j in 0..bn {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Apply the fused epilogue to output rows `i0..i1` (`od` covers exactly
/// those rows). `operands` is parallel to `steps` (resolved tensors for
/// binary steps). Element-at-a-time in step order — the same scalar
/// sequence the unfused per-op tensors would apply, so bitwise identical.
fn apply_epilogue_rows(
    steps: &[EpiStep],
    operands: &[Option<Tensor>],
    od: &mut [f32],
    i0: usize,
    i1: usize,
    bn: usize,
) {
    for (step, operand) in steps.iter().zip(operands.iter()) {
        match (&step.operand, operand) {
            (None, _) => {
                for x in od.iter_mut() {
                    *x = step.kind.apply1(*x);
                }
            }
            (Some(o), Some(t)) => {
                let data = t.data();
                for i in i0..i1 {
                    let row = &mut od[(i - i0) * bn..(i - i0 + 1) * bn];
                    let base = i * o.row_stride;
                    if o.col_stride == 0 {
                        let v = data[base];
                        if o.acc_is_lhs {
                            for x in row.iter_mut() {
                                *x = step.kind.apply2(*x, v);
                            }
                        } else {
                            for x in row.iter_mut() {
                                *x = step.kind.apply2(v, *x);
                            }
                        }
                    } else {
                        let src = &data[base..base + bn];
                        if o.acc_is_lhs {
                            for (x, &v) in row.iter_mut().zip(src.iter()) {
                                *x = step.kind.apply2(*x, v);
                            }
                        } else {
                            for (x, &v) in row.iter_mut().zip(src.iter()) {
                                *x = step.kind.apply2(v, *x);
                            }
                        }
                    }
                }
            }
            (Some(_), None) => unreachable!("binary epilogue step without resolved operand"),
        }
    }
}

/// Resolve one step operand to its chunk slice.
fn pick<'a>(
    src: Src,
    el: &ElemLoop,
    srcs: &'a [&'a Tensor],
    inbuf: &'a [Vec<f32>],
    done: &'a [Vec<f32>],
    start: usize,
    len: usize,
) -> &'a [f32] {
    match src {
        Src::In(p) => match el.inputs[p].access {
            Access::Dense => &srcs[p].data()[start..start + len],
            _ => &inbuf[p][..len],
        },
        Src::Reg(r) => &done[r][..len],
    }
}

/// Execute `el` over the flat output range `lo..hi`, writing into `dst`
/// (`dst.len() == hi - lo`). Pure per-element maps, so any range split
/// computes the same bits — the parallel path tiles exactly this.
fn run_elem_range(
    el: &ElemLoop,
    srcs: &[&Tensor],
    lo: usize,
    hi: usize,
    bufs: &mut LoopBufs,
    dst: &mut [f32],
) {
    let rank = el.out_shape.len();
    let chunk = el.numel.min(CHUNK).max(1);
    let last = el.ops.len() - 1;
    bufs.regs.resize_with(last, Vec::new);
    for b in bufs.regs.iter_mut() {
        b.clear();
        b.resize(chunk, 0.0);
    }
    bufs.inbuf.resize_with(el.inputs.len(), Vec::new);
    let mut any_strided = false;
    for (p, inp) in el.inputs.iter().enumerate() {
        let buf = &mut bufs.inbuf[p];
        buf.clear();
        match &inp.access {
            Access::Dense => {}
            Access::Splat => {
                buf.resize(chunk, srcs[p].data()[0]);
            }
            Access::Row { .. } => buf.resize(chunk, 0.0),
            Access::Strided(_) => {
                any_strided = true;
                buf.resize(chunk, 0.0);
            }
        }
    }
    // Seed the shared odometer at flat index `lo`.
    bufs.coords.clear();
    bufs.coords.resize(rank, 0);
    if any_strided {
        let mut rem = lo;
        for ax in (0..rank).rev() {
            let d = el.out_shape[ax];
            bufs.coords[ax] = rem % d;
            rem /= d;
        }
    }
    bufs.gidx.clear();
    bufs.gidx.resize(el.inputs.len(), 0);
    for (p, inp) in el.inputs.iter().enumerate() {
        if let Access::Strided(s) = &inp.access {
            bufs.gidx[p] = bufs.coords.iter().zip(s.iter()).map(|(c, st)| c * st).sum();
        }
    }
    let mut start = lo;
    while start < hi {
        let len = (hi - start).min(chunk);
        for (p, inp) in el.inputs.iter().enumerate() {
            if let Access::Row { period } = inp.access {
                // Wrapping segment copy: no odometer, no div/mod per
                // element.
                let src = srcs[p].data();
                let buf = &mut bufs.inbuf[p];
                let mut i = 0;
                let mut off = start % period;
                while i < len {
                    let take = (period - off).min(len - i);
                    buf[i..i + take].copy_from_slice(&src[off..off + take]);
                    i += take;
                    off = 0;
                }
            }
        }
        if any_strided {
            // Odometer walk shared by every strided input (mirrors the
            // eager fused gather).
            for i in 0..len {
                for (p, inp) in el.inputs.iter().enumerate() {
                    if let Access::Strided(_) = inp.access {
                        bufs.inbuf[p][i] = srcs[p].data()[bufs.gidx[p]];
                    }
                }
                for ax in (0..rank).rev() {
                    bufs.coords[ax] += 1;
                    for (p, inp) in el.inputs.iter().enumerate() {
                        if let Access::Strided(s) = &inp.access {
                            bufs.gidx[p] += s[ax];
                        }
                    }
                    if bufs.coords[ax] < el.out_shape[ax] {
                        break;
                    }
                    bufs.coords[ax] = 0;
                    for (p, inp) in el.inputs.iter().enumerate() {
                        if let Access::Strided(s) = &inp.access {
                            bufs.gidx[p] -= s[ax] * el.out_shape[ax];
                        }
                    }
                }
            }
        }
        for (si, step) in el.ops.iter().enumerate() {
            let (done, rest) = bufs.regs.split_at_mut(si);
            let done: &[Vec<f32>] = done;
            let a = pick(step.a, el, srcs, &bufs.inbuf, done, start, len);
            let b = pick(step.b, el, srcs, &bufs.inbuf, done, start, len);
            if si == last {
                apply_kind_chunk(step.kind, a, b, &mut dst[start - lo..start - lo + len]);
            } else {
                apply_kind_chunk(step.kind, a, b, &mut rest[0][..len]);
            }
        }
        start += len;
    }
}

/// Contiguous row-range splits for the parallel paths.
fn split_ranges(total: usize, tiles: usize) -> Vec<(usize, usize)> {
    let tiles = tiles.max(1).min(total.max(1));
    let per = total.div_ceil(tiles);
    let mut out = Vec::new();
    let mut start = 0;
    while start < total {
        let end = (start + per).min(total);
        out.push((start, end));
        start = end;
    }
    out
}

/// The op kind and args of node `id`, `None` for non-op nodes.
fn node_op(g: &Graph, id: NodeId) -> Option<(&OpKind, &[NodeId])> {
    match &g.nodes[id].kind {
        NodeKind::Op(op, args) => Some((op, args.as_slice())),
        _ => None,
    }
}

impl LoopProgram {
    /// Compile `graph` into a loop program. Infallible: anything the
    /// specialized instructions cannot express lowers to an eval-fallback
    /// instruction.
    pub fn compile(graph: Arc<Graph>) -> LoopProgram {
        let g = &*graph;
        let n = g.nodes.len();
        let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for (id, node) in g.nodes.iter().enumerate() {
            if let NodeKind::Op(_, args) = &node.kind {
                for &a in args {
                    consumers[a].push(id);
                }
            }
        }
        let mut is_output = vec![false; n];
        for &o in &g.outputs {
            is_output[o] = true;
        }

        // --- 1. Matmul chains: 2-D matmuls grow a fused elementwise
        // epilogue through their single-consumer tails. `mm_claim` marks
        // the matmul and every chain node; the instruction materializes at
        // the chain's last node.
        struct ChainSpec {
            a: NodeId,
            b: NodeId,
            m: usize,
            k: usize,
            n: usize,
            steps: Vec<(ElemKind, Option<(NodeId, bool)>)>,
        }
        let mut mm_claim = vec![false; n];
        let mut mm_at: Vec<Option<ChainSpec>> = (0..n).map(|_| None).collect();
        for id in 0..n {
            let Some((op, args)) = node_op(g, id) else { continue };
            if !matches!(op, OpKind::MatMul) {
                continue;
            }
            let (a, b) = (args[0], args[1]);
            if g.nodes[a].shape.len() != 2 || g.nodes[b].shape.len() != 2 {
                continue; // batched / higher-rank: eval fallback
            }
            let (m, k) = (g.nodes[a].shape[0], g.nodes[a].shape[1]);
            let nn = g.nodes[b].shape[1];
            let out_shape = g.nodes[id].shape.clone();
            let mut steps: Vec<(ElemKind, Option<(NodeId, bool)>)> = Vec::new();
            let mut cur = id;
            loop {
                if is_output[cur] || consumers[cur].len() != 1 {
                    break;
                }
                let c = consumers[cur][0];
                if mm_claim[c] {
                    break;
                }
                let Some((cop, cargs)) = node_op(g, c) else { break };
                let Some(kind) = ElemKind::from_op(cop) else { break };
                if g.nodes[c].shape != out_shape {
                    break;
                }
                if kind.is_binary() {
                    let (other, acc_is_lhs) =
                        if cargs[0] == cur { (cargs[1], true) } else { (cargs[0], false) };
                    let oshape = &g.nodes[other].shape;
                    if oshape.len() > 2 {
                        break;
                    }
                    let fits = tensor::broadcast_shapes(oshape, &out_shape)
                        .map(|s| s == out_shape)
                        .unwrap_or(false);
                    if !fits {
                        break;
                    }
                    steps.push((kind, Some((other, acc_is_lhs))));
                } else {
                    steps.push((kind, None));
                }
                cur = c;
            }
            mm_claim[id] = true;
            // Claim the chain nodes: they are the successive single
            // consumers the loop above walked.
            let mut c = id;
            for _ in 0..steps.len() {
                c = consumers[c][0];
                mm_claim[c] = true;
            }
            mm_at[cur] = Some(ChainSpec { a, b, m, k, n: nn, steps });
        }

        // --- 2. Elementwise regions over the remaining nodes. Mirrors the
        // eager fuser (roots descending, fixpoint growth, broadcast-onto
        // gate) but keeps singletons: every elementwise op runs as a
        // stride-resolved loop.
        let fusible_at = |id: NodeId| -> bool {
            !mm_claim[id]
                && node_op(g, id).map(|(op, _)| ElemKind::from_op(op).is_some()).unwrap_or(false)
        };
        let broadcasts_onto = |inner: NodeId, root: NodeId| -> bool {
            tensor::broadcast_shapes(&g.nodes[inner].shape, &g.nodes[root].shape)
                .map(|s| s == g.nodes[root].shape)
                .unwrap_or(false)
        };
        let mut region_of: Vec<Option<usize>> = vec![None; n];
        let mut regions: Vec<Vec<NodeId>> = Vec::new();
        for root in (0..n).rev() {
            if region_of[root].is_some() || !fusible_at(root) {
                continue;
            }
            let mut members = vec![root];
            loop {
                let mut grew = false;
                let mut mi = 0;
                while mi < members.len() {
                    let m = members[mi];
                    mi += 1;
                    let (_, args) = node_op(g, m).expect("members are ops");
                    for &a in args.iter() {
                        if members.contains(&a) || region_of[a].is_some() || is_output[a] {
                            continue;
                        }
                        if !fusible_at(a)
                            || !consumers[a].iter().all(|c| members.contains(c))
                            || !broadcasts_onto(a, root)
                        {
                            continue;
                        }
                        members.push(a);
                        grew = true;
                    }
                }
                if !grew {
                    break;
                }
            }
            let rid = regions.len();
            for &m in &members {
                region_of[m] = Some(rid);
            }
            members.sort_unstable();
            regions.push(members);
        }

        // --- 3. Emission order: inputs first, then op instructions at
        // their emit node's position (region root / chain end / node).
        enum Emit {
            Input(usize),
            Region(usize),
            Chain(NodeId),
            Eval(NodeId),
        }
        let mut emits: Vec<(NodeId, Emit)> = Vec::new();
        for (idx, &inp) in g.inputs.iter().enumerate() {
            emits.push((inp, Emit::Input(idx)));
        }
        for (id, node) in g.nodes.iter().enumerate() {
            if !matches!(node.kind, NodeKind::Op(..)) {
                continue;
            }
            if mm_claim[id] {
                if mm_at[id].is_some() {
                    emits.push((id, Emit::Chain(id)));
                }
                continue;
            }
            match region_of[id] {
                Some(rid) if *regions[rid].last().unwrap() == id => {
                    emits.push((id, Emit::Region(rid)));
                }
                Some(_) => {} // interior member: computed inside its loop
                None => emits.push((id, Emit::Eval(id))),
            }
        }

        // --- 4. Slot allocation: liveness-driven reuse. Constants take
        // the first slots (the arena template); each instruction allocates
        // its output slot *before* freeing its dying operands, so an
        // output never aliases a buffer the same instruction reads.
        let mut slot_of: Vec<Option<usize>> = vec![None; n];
        let mut next_slot = 0usize;
        let mut free_slots: Vec<usize> = Vec::new();
        let mut const_slots: Vec<(usize, NodeId)> = Vec::new();
        for (id, node) in g.nodes.iter().enumerate() {
            if matches!(node.kind, NodeKind::ConstScalar(_) | NodeKind::ConstTensor(_)) {
                slot_of[id] = Some(next_slot);
                const_slots.push((next_slot, id));
                next_slot += 1;
            }
        }
        // Per-emit read sets (graph node ids), used for last-read liveness.
        let reads_of = |e: &Emit| -> Vec<NodeId> {
            let mut r: Vec<NodeId> = Vec::new();
            let mut push = |a: NodeId| {
                if !r.contains(&a) {
                    r.push(a);
                }
            };
            match e {
                Emit::Input(_) => {}
                Emit::Region(rid) => {
                    let members = &regions[*rid];
                    for &m in members {
                        let (_, args) = node_op(g, m).expect("members are ops");
                        for &a in args {
                            if !members.contains(&a) {
                                push(a);
                            }
                        }
                    }
                }
                Emit::Chain(end) => {
                    let spec = mm_at[*end].as_ref().expect("chain spec at end node");
                    push(spec.a);
                    push(spec.b);
                    for (_, operand) in &spec.steps {
                        if let Some((o, _)) = operand {
                            push(*o);
                        }
                    }
                }
                Emit::Eval(id) => {
                    let (_, args) = node_op(g, *id).expect("eval emits are ops");
                    for &a in args {
                        push(a);
                    }
                }
            }
            r
        };
        let mut last_read: Vec<Option<usize>> = vec![None; n];
        for (ei, (_, e)) in emits.iter().enumerate() {
            for a in reads_of(e) {
                last_read[a] = Some(ei);
            }
        }
        // A constant nobody reads stays pinned in its template slot; an
        // unread instruction output is freed right after it is produced.
        let mut live = const_slots.len();
        let mut peak_live = live;
        let mut instr_slots: Vec<usize> = Vec::new();
        let mut instr_dead: Vec<Vec<usize>> = Vec::new();
        for (ei, (node, e)) in emits.iter().enumerate() {
            let out_slot = free_slots.pop().unwrap_or_else(|| {
                let s = next_slot;
                next_slot += 1;
                s
            });
            slot_of[*node] = Some(out_slot);
            live += 1;
            peak_live = peak_live.max(live);
            let mut dead: Vec<usize> = Vec::new();
            for a in reads_of(e) {
                if last_read[a] == Some(ei) && !is_output[a] {
                    if let Some(s) = slot_of[a] {
                        dead.push(s);
                        free_slots.push(s);
                        live -= 1;
                    }
                }
            }
            if last_read[*node].is_none() && !is_output[*node] {
                dead.push(out_slot);
                free_slots.push(out_slot);
                live -= 1;
            }
            instr_slots.push(out_slot);
            instr_dead.push(dead);
        }
        let n_slots = next_slot;

        // --- 5. Materialize the instruction buffer.
        let slot = |id: NodeId| -> usize { slot_of[id].expect("read of unmaterialized node") };
        let mut instrs: Vec<Instr> = Vec::with_capacity(emits.len());
        for (ei, (node, e)) in emits.iter().enumerate() {
            let op = match e {
                Emit::Input(idx) => InstrOp::Input { index: *idx },
                Emit::Region(rid) => {
                    let members = &regions[*rid];
                    let root = *members.last().unwrap();
                    let out_shape = g.nodes[root].shape.clone();
                    let mut reg_index: Vec<(NodeId, usize)> = Vec::new();
                    let mut input_nodes: Vec<NodeId> = Vec::new();
                    let mut ops = Vec::with_capacity(members.len());
                    for (si, &m) in members.iter().enumerate() {
                        reg_index.push((m, si));
                        let (mop, args) = node_op(g, m).expect("members are ops");
                        let kind = ElemKind::from_op(mop).expect("members are elementwise");
                        let mut resolve = |a: NodeId| -> Src {
                            if let Some(&(_, r)) = reg_index.iter().find(|(x, _)| *x == a) {
                                return Src::Reg(r);
                            }
                            match input_nodes.iter().position(|&x| x == a) {
                                Some(p) => Src::In(p),
                                None => {
                                    input_nodes.push(a);
                                    Src::In(input_nodes.len() - 1)
                                }
                            }
                        };
                        let a = resolve(args[0]);
                        let b = if args.len() > 1 { resolve(args[1]) } else { a };
                        ops.push(ElemStep { kind, a, b });
                    }
                    let inputs: Vec<LoopInput> = input_nodes
                        .iter()
                        .map(|&a| LoopInput {
                            slot: slot(a),
                            access: Access::classify(&g.nodes[a].shape, &out_shape),
                        })
                        .collect();
                    let numel = out_shape.iter().product();
                    InstrOp::Loop(ElemLoop { out_shape, numel, inputs, ops })
                }
                Emit::Chain(end) => {
                    let spec = mm_at[*end].as_ref().expect("chain spec at end node");
                    let epilogue: Vec<EpiStep> = spec
                        .steps
                        .iter()
                        .map(|(kind, operand)| EpiStep {
                            kind: *kind,
                            operand: operand.map(|(o, acc_is_lhs)| {
                                let strides = tensor::broadcast_strides_for(&g.nodes[o].shape, 2);
                                EpiOperand {
                                    slot: slot(o),
                                    row_stride: strides[0],
                                    col_stride: strides[1],
                                    acc_is_lhs,
                                }
                            }),
                        })
                        .collect();
                    InstrOp::MatMul(MatMulInstr {
                        a_slot: slot(spec.a),
                        b_slot: slot(spec.b),
                        m: spec.m,
                        k: spec.k,
                        n: spec.n,
                        epilogue,
                    })
                }
                Emit::Eval(id) => {
                    let (_, args) = node_op(g, *id).expect("eval emits are ops");
                    InstrOp::Eval(EvalInstr {
                        node: *id,
                        args: args.iter().map(|&a| (a, slot(a))).collect(),
                    })
                }
            };
            instrs.push(Instr {
                op,
                out_slot: instr_slots[ei],
                dead_after: instr_dead[ei].clone(),
            });
        }
        let mut template: Vec<Option<Tensor>> = vec![None; n_slots];
        for &(s, id) in &const_slots {
            template[s] = Some(match &g.nodes[id].kind {
                NodeKind::ConstScalar(v) => Tensor::scalar(*v as f32),
                NodeKind::ConstTensor(t) => t.clone(),
                _ => unreachable!("const slot points at a non-const node"),
            });
        }
        let outputs = g.outputs.iter().map(|&o| slot(o)).collect();
        LoopProgram { graph, template, const_slots, instrs, outputs, n_slots, peak_live }
    }

    /// Execute the program. `pool` (when present) row-tiles large matmuls
    /// and range-splits large elementwise loops; a dropped pool job is
    /// recomputed inline, so execution never fails or hangs structurally.
    fn run(
        &self,
        inputs: &[Rc<Tensor>],
        scratch: &mut Scratch,
        pool: Option<&Arc<WorkerPool>>,
    ) -> Result<Vec<Tensor>, DepyfError> {
        let g = &*self.graph;
        let Scratch { arena, env, bufs, free } = scratch;
        arena.clear();
        arena.extend(self.template.iter().cloned());
        env.clear();
        env.resize(g.nodes.len(), None);
        for instr in &self.instrs {
            let value = match &instr.op {
                InstrOp::Input { index } => (*inputs[*index]).clone(),
                InstrOp::Loop(el) => run_loop(el, arena, bufs, free, pool)?,
                InstrOp::MatMul(mm) => run_matmul(mm, arena, free, pool)?,
                InstrOp::Eval(ev) => {
                    for &(a, s) in &ev.args {
                        env[a] = arena[s].clone();
                    }
                    let t = eval_op(g, ev.node, env)?;
                    for &(a, _) in &ev.args {
                        env[a] = None;
                    }
                    t
                }
            };
            arena[instr.out_slot] = Some(value);
            for &s in &instr.dead_after {
                if let Some(t) = arena[s].take() {
                    if free.len() < FREE_BUFS_MAX {
                        if let Some(buf) = t.into_data() {
                            free.push(buf);
                        }
                    }
                }
            }
        }
        let out = self
            .outputs
            .iter()
            .map(|&s| {
                arena[s]
                    .clone()
                    .ok_or_else(|| DepyfError::Backend(format!("output slot {} unevaluated", s)))
            })
            .collect();
        arena.clear();
        out
    }

    /// Slots in the arena (constants + peak concurrent values).
    pub fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Maximum values live at once — the liveness win over the eager
    /// plan's one-slot-per-node env.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Render the loop IR as the `__loopir_*.txt` dump text.
    pub fn render(&self) -> String {
        let g = &*self.graph;
        let mut out = String::new();
        out.push_str(&format!("loop program {} (backend codegen)\n", g.name));
        out.push_str(&format!(
            "slots: {}  peak live: {}  instrs: {}\n",
            self.n_slots,
            self.peak_live,
            self.instrs.len()
        ));
        for &(s, id) in &self.const_slots {
            out.push_str(&format!("const s{} = node {} {:?}\n", s, id, g.nodes[id].shape));
        }
        for (i, instr) in self.instrs.iter().enumerate() {
            match &instr.op {
                InstrOp::Input { index } => {
                    let node = g.inputs[*index];
                    let name = match &g.nodes[node].kind {
                        NodeKind::Placeholder { name } => name.as_str(),
                        _ => "?",
                    };
                    out.push_str(&format!(
                        "i{:<3} input  s{} = arg{} \"{}\" {:?}",
                        i, instr.out_slot, index, name, g.nodes[node].shape
                    ));
                }
                InstrOp::Loop(el) => {
                    out.push_str(&format!(
                        "i{:<3} loop   s{} = {:?} <{} elems, {} ops>",
                        i, instr.out_slot, el.out_shape, el.numel, el.ops.len()
                    ));
                    for (p, inp) in el.inputs.iter().enumerate() {
                        let access = match &inp.access {
                            Access::Dense => "dense".to_string(),
                            Access::Splat => "splat".to_string(),
                            Access::Row { period } => format!("row(period={})", period),
                            Access::Strided(s) => format!("strided{:?}", s),
                        };
                        out.push_str(&format!("\n        in{} = s{} {}", p, inp.slot, access));
                    }
                    for (si, step) in el.ops.iter().enumerate() {
                        let fmt = |s: Src| match s {
                            Src::In(p) => format!("in{}", p),
                            Src::Reg(r) => format!("r{}", r),
                        };
                        if step.kind.is_binary() {
                            out.push_str(&format!(
                                "\n        r{} = {} {}, {}",
                                si,
                                step.kind.name(),
                                fmt(step.a),
                                fmt(step.b)
                            ));
                        } else {
                            out.push_str(&format!(
                                "\n        r{} = {} {}",
                                si,
                                step.kind.name(),
                                fmt(step.a)
                            ));
                        }
                    }
                }
                InstrOp::MatMul(mm) => {
                    out.push_str(&format!(
                        "i{:<3} matmul s{} = s{} @ s{} [m={} k={} n={}] path={}",
                        i,
                        instr.out_slot,
                        mm.a_slot,
                        mm.b_slot,
                        mm.m,
                        mm.k,
                        mm.n,
                        if mm.blocked() { "blocked" } else { "plain" }
                    ));
                    if !mm.epilogue.is_empty() {
                        let steps: Vec<String> = mm
                            .epilogue
                            .iter()
                            .map(|s| match &s.operand {
                                Some(o) => format!(
                                    "{} s{} (rs={} cs={}{})",
                                    s.kind.name(),
                                    o.slot,
                                    o.row_stride,
                                    o.col_stride,
                                    if o.acc_is_lhs { "" } else { ", acc-rhs" }
                                ),
                                None => s.kind.name().to_string(),
                            })
                            .collect();
                        out.push_str(&format!("\n        epilogue: {}", steps.join("; ")));
                    }
                }
                InstrOp::Eval(ev) => {
                    let opname = match &g.nodes[ev.node].kind {
                        NodeKind::Op(op, _) => op.method_name(),
                        _ => "?",
                    };
                    let args: Vec<String> =
                        ev.args.iter().map(|&(_, s)| format!("s{}", s)).collect();
                    out.push_str(&format!(
                        "i{:<3} eval   s{} = {}(node {}; reads {})",
                        i,
                        instr.out_slot,
                        opname,
                        ev.node,
                        args.join(", ")
                    ));
                }
            }
            if !instr.dead_after.is_empty() {
                let freed: Vec<String> =
                    instr.dead_after.iter().map(|s| format!("s{}", s)).collect();
                out.push_str(&format!("  free [{}]", freed.join(", ")));
            }
            out.push('\n');
        }
        let outs: Vec<String> = self.outputs.iter().map(|s| format!("s{}", s)).collect();
        out.push_str(&format!("outputs: {}\n", outs.join(", ")));
        out
    }
}

/// Execute one elementwise loop (serial, or range-split across the pool).
fn run_loop(
    el: &ElemLoop,
    arena: &[Option<Tensor>],
    bufs: &mut LoopBufs,
    free: &mut Vec<Vec<f32>>,
    pool: Option<&Arc<WorkerPool>>,
) -> Result<Tensor, DepyfError> {
    let mut srcs: Vec<&Tensor> = Vec::with_capacity(el.inputs.len());
    for inp in &el.inputs {
        srcs.push(fetch_slot(arena, inp.slot)?);
    }
    if let Some(pool) = pool {
        if pool.size() > 1 && el.numel >= ELEM_PAR_MIN {
            let owned: Vec<Tensor> = srcs.iter().map(|t| (*t).clone()).collect();
            let ranges = split_ranges(el.numel, pool.size());
            let mut waits = Vec::with_capacity(ranges.len());
            for &(lo, hi) in &ranges {
                let (promise, future) = call_channel();
                let el = el.clone();
                let owned = owned.clone();
                // A draining/faulted pool hands the job back with a typed
                // error; running it inline computes the same bits and
                // fulfills the future, so the wait below still succeeds.
                if let Err((_e, job)) = pool.submit(Box::new(move || {
                    let refs: Vec<&Tensor> = owned.iter().collect();
                    let mut tile = vec![0.0f32; hi - lo];
                    run_elem_range(&el, &refs, lo, hi, &mut LoopBufs::default(), &mut tile);
                    promise.fulfill(Ok(vec![Tensor::new(vec![hi - lo], tile)]));
                })) {
                    job();
                }
                waits.push((future, lo, hi));
            }
            let mut out: Vec<f32> = Vec::with_capacity(el.numel);
            for (future, lo, hi) in waits {
                match future.wait() {
                    Ok(parts) => out.extend_from_slice(parts[0].data()),
                    Err(_) => {
                        // Dropped pool job (fault injection / shutdown):
                        // recompute the range inline, same bits.
                        let refs: Vec<&Tensor> = owned.iter().collect();
                        let mut tile = vec![0.0f32; hi - lo];
                        run_elem_range(el, &refs, lo, hi, &mut LoopBufs::default(), &mut tile);
                        out.extend_from_slice(&tile);
                    }
                }
            }
            return Ok(Tensor::new(el.out_shape.clone(), out));
        }
    }
    let mut out = take_buf(free, el.numel);
    run_elem_range(el, &srcs, 0, el.numel, bufs, &mut out);
    Ok(Tensor::new(el.out_shape.clone(), out))
}

/// Read an arena slot that the emission order guarantees is populated.
fn fetch_slot(arena: &[Option<Tensor>], s: usize) -> Result<&Tensor, DepyfError> {
    arena[s]
        .as_ref()
        .ok_or_else(|| DepyfError::Backend(format!("input slot {} unevaluated", s)))
}

/// Execute one matmul instruction (serial, or row-tiled across the pool).
fn run_matmul(
    mm: &MatMulInstr,
    arena: &[Option<Tensor>],
    free: &mut Vec<Vec<f32>>,
    pool: Option<&Arc<WorkerPool>>,
) -> Result<Tensor, DepyfError> {
    let a = fetch_slot(arena, mm.a_slot)?;
    let b = fetch_slot(arena, mm.b_slot)?;
    let mut operands: Vec<Option<Tensor>> = Vec::with_capacity(mm.epilogue.len());
    for step in &mm.epilogue {
        operands.push(match &step.operand {
            Some(o) => Some(fetch_slot(arena, o.slot)?.clone()),
            None => None,
        });
    }
    let (m, k, n) = (mm.m, mm.k, mm.n);
    if let Some(pool) = pool {
        if pool.size() > 1 && m >= 2 && m * k * n >= MM_PAR_MIN_WORK {
            let ranges = split_ranges(m, pool.size());
            let mut waits = Vec::with_capacity(ranges.len());
            for &(i0, i1) in &ranges {
                let (promise, future) = call_channel();
                let (a, b) = (a.clone(), b.clone());
                let steps = mm.epilogue.clone();
                let ops = operands.clone();
                // Same inline-recompute contract as the elementwise path:
                // a rejected submit runs the tile on this thread instead.
                if let Err((_e, job)) = pool.submit(Box::new(move || {
                    let mut od = vec![0.0f32; (i1 - i0) * n];
                    matmul_rows(a.data(), b.data(), &mut od, i0, i1, k, n);
                    apply_epilogue_rows(&steps, &ops, &mut od, i0, i1, n);
                    promise.fulfill(Ok(vec![Tensor::new(vec![i1 - i0, n], od)]));
                })) {
                    job();
                }
                waits.push((future, i0, i1));
            }
            let mut out: Vec<f32> = Vec::with_capacity(m * n);
            for (future, i0, i1) in waits {
                match future.wait() {
                    Ok(parts) => out.extend_from_slice(parts[0].data()),
                    Err(_) => {
                        let mut od = vec![0.0f32; (i1 - i0) * n];
                        matmul_rows(a.data(), b.data(), &mut od, i0, i1, k, n);
                        apply_epilogue_rows(&mm.epilogue, &operands, &mut od, i0, i1, n);
                        out.extend_from_slice(&od);
                    }
                }
            }
            return Ok(Tensor::new(vec![m, n], out));
        }
    }
    let mut od = take_buf(free, m * n);
    matmul_rows(a.data(), b.data(), &mut od, 0, m, k, n);
    apply_epilogue_rows(&mm.epilogue, &operands, &mut od, 0, m, n);
    Ok(Tensor::new(vec![m, n], od))
}

/// The codegen backend's [`CompiledModule`]: a [`LoopProgram`] built once
/// at lower time, with reusable scratch and an optional worker pool.
pub struct CodegenModule {
    name: String,
    program: LoopProgram,
    scratch: Mutex<Scratch>,
    pool: Option<Arc<WorkerPool>>,
}

impl CodegenModule {
    pub fn new(name: &str, graph: Arc<Graph>, pool: Option<Arc<WorkerPool>>) -> CodegenModule {
        CodegenModule {
            name: name.to_string(),
            program: LoopProgram::compile(graph),
            scratch: Mutex::new(Scratch::default()),
            pool,
        }
    }

    pub fn program(&self) -> &LoopProgram {
        &self.program
    }
}

impl CompiledModule for CodegenModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.program.graph.check_inputs(inputs)?;
        let mut borrowed;
        let mut local;
        // Same try-lock idiom as the eager arena: concurrent callers that
        // lose the race use local scratch instead of serializing, and a
        // poisoned holder's state is harmless (reset before any read).
        let scratch: &mut Scratch = match self.scratch.try_lock() {
            Ok(b) => {
                borrowed = b;
                &mut *borrowed
            }
            Err(TryLockError::Poisoned(b)) => {
                borrowed = b.into_inner();
                &mut *borrowed
            }
            Err(TryLockError::WouldBlock) => {
                local = Scratch::default();
                &mut local
            }
        };
        self.program.run(inputs, scratch, self.pool.as_ref())
    }

    fn backend_name(&self) -> &str {
        "codegen"
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        let stem = crate::backend::sanitize(&self.name);
        vec![ModuleArtifact {
            kind: ArtifactKind::LoopIr,
            name: self.name.clone(),
            file: format!("__loopir_{}.txt", stem),
            content: self.program.render(),
        }]
    }

    fn stats(&self) -> ModuleStats {
        ModuleStats { partitions: 1, ..Default::default() }
    }
}

/// The `codegen` backend: `plan` emits the monolithic plan, `lower`
/// compiles the optimized graph into a [`LoopProgram`]. The registered
/// instance is single-threaded; [`CodegenBackend::with_threads`] shares
/// one [`WorkerPool`] across every module it lowers for row-tiled
/// matmuls and range-split elementwise loops.
pub struct CodegenBackend {
    pool: Option<Arc<WorkerPool>>,
}

impl CodegenBackend {
    pub fn new() -> CodegenBackend {
        CodegenBackend { pool: None }
    }

    /// A codegen backend whose modules tile large loops/panels across
    /// `threads` workers. Bitwise identical to the single-threaded path:
    /// tiling never reorders any per-element accumulation.
    pub fn with_threads(threads: usize) -> CodegenBackend {
        let pool = if threads > 1 { Some(Arc::new(WorkerPool::new(threads))) } else { None };
        CodegenBackend { pool }
    }
}

impl Default for CodegenBackend {
    fn default() -> Self {
        CodegenBackend::new()
    }
}

impl Backend for CodegenBackend {
    fn name(&self) -> &str {
        "codegen"
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendPlan)?;
        Ok(CompilePlan::monolithic("codegen", req, "codegen"))
    }

    fn lower(
        &self,
        req: &CompileRequest,
        _plan: &CompilePlan,
    ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendLower)?;
        let opt = req.optimized();
        Ok(Arc::new(CodegenModule::new(&req.name, Arc::clone(&opt.graph), self.pool.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::eager::EagerModule;
    use crate::graph::Graph;

    fn assert_bitwise_eq(a: &[Tensor], b: &[Tensor], what: &str) {
        assert_eq!(a.len(), b.len(), "{}: output arity", what);
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.shape(), y.shape(), "{}: output {} shape", what, i);
            let xb: Vec<u32> = x.data().iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u32> = y.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "{}: output {} bits", what, i);
        }
    }

    fn run_both(g: &Arc<Graph>, inputs: &[Rc<Tensor>], what: &str) -> Vec<Tensor> {
        let eager = EagerModule::with_fusion(Arc::clone(g), "eager".into(), false);
        let module = CodegenModule::new(&g.name, Arc::clone(g), None);
        let want = eager.call(inputs).unwrap();
        let got = module.call(inputs).unwrap();
        assert_bitwise_eq(&got, &want, what);
        got
    }

    /// x[3,4] * c + bias, gelu, sigmoid, + residual — the eager test
    /// chain, with a splat and a row-broadcast input.
    fn elementwise_chain() -> Arc<Graph> {
        let mut g = Graph::new("chain");
        let x = g.placeholder("x", &[3, 4]);
        let b = g.placeholder("b", &[4]);
        let c = g.const_scalar(0.7);
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let a = g.add_op(OpKind::Add, vec![m, b]).unwrap();
        let ge = g.add_op(OpKind::Gelu, vec![a]).unwrap();
        let s = g.add_op(OpKind::Sigmoid, vec![ge]).unwrap();
        let r = g.add_op(OpKind::Add, vec![s, x]).unwrap();
        g.set_outputs(vec![r]);
        Arc::new(g)
    }

    fn chain_inputs() -> Vec<Rc<Tensor>> {
        vec![
            Rc::new(Tensor::new(
                vec![3, 4],
                vec![-2.0, -0.5, 0.0, 0.5, 1.0, 1.5, -1.0, 3.0, -0.0, 2.5, 0.25, -3.0],
            )),
            Rc::new(Tensor::new(vec![4], vec![0.1, -0.2, 0.3, -0.4])),
        ]
    }

    #[test]
    fn elementwise_chain_is_bitwise_equal_to_eager() {
        run_both(&elementwise_chain(), &chain_inputs(), "elementwise chain");
    }

    #[test]
    fn chain_compiles_to_one_loop_with_resolved_strides() {
        let module = CodegenModule::new("chain", elementwise_chain(), None);
        let loops: Vec<&ElemLoop> = module
            .program
            .instrs
            .iter()
            .filter_map(|i| match &i.op {
                InstrOp::Loop(el) => Some(el),
                _ => None,
            })
            .collect();
        assert_eq!(loops.len(), 1, "whole chain fuses into one loop");
        let el = loops[0];
        assert_eq!(el.ops.len(), 5);
        // Stride classes resolved at lower time: x dense, bias a row
        // broadcast, the const scalar a splat — no general odometer.
        assert!(el.inputs.iter().any(|i| i.access == Access::Dense));
        assert!(el.inputs.iter().any(|i| i.access == Access::Splat));
        assert!(el.inputs.iter().any(|i| matches!(i.access, Access::Row { period: 4 })));
        assert!(!el.inputs.iter().any(|i| matches!(i.access, Access::Strided(_))));
        let ir = module.program.render();
        assert!(ir.contains("row(period=4)"), "dump shows the stride class:\n{}", ir);
    }

    #[test]
    fn stride_classes_cover_splat_row_and_strided() {
        // [3,1] onto [3,4] needs real strides; [4] is a row; [1] a splat.
        let mut g = Graph::new("strides");
        let x = g.placeholder("x", &[3, 4]);
        let col = g.placeholder("col", &[3, 1]);
        let row = g.placeholder("row", &[4]);
        let one = g.placeholder("one", &[1]);
        let a = g.add_op(OpKind::Add, vec![x, col]).unwrap();
        let m = g.add_op(OpKind::Mul, vec![a, row]).unwrap();
        let s = g.add_op(OpKind::Sub, vec![m, one]).unwrap();
        g.set_outputs(vec![s]);
        let g = Arc::new(g);
        assert_eq!(Access::classify(&[3, 1], &[3, 4]), Access::Strided(vec![1, 0]));
        assert_eq!(Access::classify(&[4], &[3, 4]), Access::Row { period: 4 });
        assert_eq!(Access::classify(&[1], &[3, 4]), Access::Splat);
        assert_eq!(Access::classify(&[3, 4], &[3, 4]), Access::Dense);
        let inputs = vec![
            Rc::new(Tensor::new(vec![3, 4], (0..12).map(|i| i as f32 - 5.5).collect())),
            Rc::new(Tensor::new(vec![3, 1], vec![0.5, -1.5, 2.0])),
            Rc::new(Tensor::new(vec![4], vec![1.0, -2.0, 0.0, 0.25])),
            Rc::new(Tensor::new(vec![1], vec![0.125])),
        ];
        run_both(&g, &inputs, "stride classes");
    }

    #[test]
    fn slot_reuse_frees_dead_values() {
        // A long dependency chain: unary ops x -> .. -> out. With liveness
        // the program needs far fewer slots than values.
        let mut g = Graph::new("slots");
        let x = g.placeholder("x", &[8]);
        // Non-fusible ops force one instruction per node (no region), so
        // slot reuse across instructions is what's being measured.
        let mut cur = x;
        for _ in 0..6 {
            cur = g.add_op(OpKind::Sum(Some(0)), vec![cur]).unwrap();
            cur = g.add_op(OpKind::Reshape(vec![1]), vec![cur]).unwrap();
        }
        g.set_outputs(vec![cur]);
        let program = LoopProgram::compile(Arc::new(g));
        // 13 values (input + 12 op results) but peak liveness is 2.
        assert!(program.peak_live() <= 3, "peak live {} too high", program.peak_live());
        assert!(
            program.n_slots() <= 3,
            "liveness should reuse slots: {} allocated",
            program.n_slots()
        );
        let freed: usize = program.instrs.iter().map(|i| i.dead_after.len()).sum();
        assert!(freed >= 12, "dead values are freed eagerly (freed {})", freed);
    }

    #[test]
    fn matmul_epilogue_fuses_bias_and_activation() {
        let mut g = Graph::new("mm_epi");
        let x = g.placeholder("x", &[3, 5]);
        let w = g.placeholder("w", &[5, 4]);
        let b = g.placeholder("b", &[4]);
        let mm = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let add = g.add_op(OpKind::Add, vec![mm, b]).unwrap();
        let act = g.add_op(OpKind::Gelu, vec![add]).unwrap();
        g.set_outputs(vec![act]);
        let g = Arc::new(g);
        let program = LoopProgram::compile(Arc::clone(&g));
        let mms: Vec<&MatMulInstr> = program
            .instrs
            .iter()
            .filter_map(|i| match &i.op {
                InstrOp::MatMul(mm) => Some(mm),
                _ => None,
            })
            .collect();
        assert_eq!(mms.len(), 1);
        assert_eq!(mms[0].epilogue.len(), 2, "bias add + gelu fold into the epilogue");
        let bias = mms[0].epilogue[0].operand.as_ref().unwrap();
        assert_eq!((bias.row_stride, bias.col_stride), (0, 1), "bias reads row-broadcast");
        assert!(!program.instrs.iter().any(|i| matches!(i.op, InstrOp::Loop(_))));
        let inputs = vec![
            Rc::new(Tensor::new(vec![3, 5], (0..15).map(|i| (i as f32) * 0.3 - 2.0).collect())),
            Rc::new(Tensor::new(vec![5, 4], (0..20).map(|i| (i as f32) * 0.1 - 1.0).collect())),
            Rc::new(Tensor::new(vec![4], vec![0.5, -0.5, 1.5, 0.0])),
        ];
        run_both(&g, &inputs, "matmul epilogue");
    }

    #[test]
    fn epilogue_fusion_respects_outputs_and_multi_consumers() {
        // The matmul result is itself a graph output: nothing may fold
        // into an epilogue past it.
        let mut g = Graph::new("mm_out");
        let x = g.placeholder("x", &[2, 3]);
        let w = g.placeholder("w", &[3, 2]);
        let mm = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let act = g.add_op(OpKind::Relu, vec![mm]).unwrap();
        g.set_outputs(vec![mm, act]);
        let g = Arc::new(g);
        let program = LoopProgram::compile(Arc::clone(&g));
        let mm_instr = program
            .instrs
            .iter()
            .find_map(|i| match &i.op {
                InstrOp::MatMul(mm) => Some(mm),
                _ => None,
            })
            .expect("matmul instruction");
        assert!(mm_instr.epilogue.is_empty(), "output matmul must not grow an epilogue");
        let inputs = vec![
            Rc::new(Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.0, 0.0, 0.5, -0.5])),
            Rc::new(Tensor::new(vec![3, 2], vec![0.1, 0.2, -0.3, 0.4, 0.5, -0.6])),
        ];
        run_both(&g, &inputs, "matmul output");

        // Two consumers of the matmul: the chain cannot claim either.
        let mut g2 = Graph::new("mm_two");
        let x = g2.placeholder("x", &[2, 3]);
        let w = g2.placeholder("w", &[3, 2]);
        let mm = g2.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let r = g2.add_op(OpKind::Relu, vec![mm]).unwrap();
        let t = g2.add_op(OpKind::Tanh, vec![mm]).unwrap();
        let s = g2.add_op(OpKind::Add, vec![r, t]).unwrap();
        g2.set_outputs(vec![s]);
        let g2 = Arc::new(g2);
        let program2 = LoopProgram::compile(Arc::clone(&g2));
        let mm2 = program2
            .instrs
            .iter()
            .find_map(|i| match &i.op {
                InstrOp::MatMul(mm) => Some(mm),
                _ => None,
            })
            .expect("matmul instruction");
        assert!(mm2.epilogue.is_empty(), "multi-consumer matmul must stay bare");
        run_both(
            &g2,
            &[
                Rc::new(Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.0, 0.0, 0.5, -0.5])),
                Rc::new(Tensor::new(vec![3, 2], vec![0.1, 0.2, -0.3, 0.4, 0.5, -0.6])),
            ],
            "multi-consumer matmul",
        );
    }

    #[test]
    fn blocked_matmul_path_is_bitwise_equal() {
        // ak*bn = 130*140 > MM_BLOCK_MIN_PANEL forces the k-blocked path,
        // ak deliberately not a multiple of MM_KBLOCK, with zeros salted
        // in to exercise the av == 0.0 skip.
        let (m, k, n) = (6, 130, 140);
        assert!(k * n >= MM_BLOCK_MIN_PANEL);
        let mut g = Graph::new("mm_blocked");
        let a = g.placeholder("a", &[m, k]);
        let b = g.placeholder("b", &[k, n]);
        let bias = g.placeholder("bias", &[n]);
        let mm = g.add_op(OpKind::MatMul, vec![a, b]).unwrap();
        let add = g.add_op(OpKind::Add, vec![mm, bias]).unwrap();
        let act = g.add_op(OpKind::Tanh, vec![add]).unwrap();
        g.set_outputs(vec![act]);
        let g = Arc::new(g);
        let ad: Vec<f32> =
            (0..m * k).map(|i| if i % 7 == 0 { 0.0 } else { (i as f32 * 0.37).sin() }).collect();
        let bd: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.11).cos()).collect();
        let biasd: Vec<f32> = (0..n).map(|i| (i as f32) * 0.01 - 0.5).collect();
        let inputs = vec![
            Rc::new(Tensor::new(vec![m, k], ad)),
            Rc::new(Tensor::new(vec![k, n], bd)),
            Rc::new(Tensor::new(vec![n], biasd)),
        ];
        run_both(&g, &inputs, "blocked matmul epilogue");
        let program = LoopProgram::compile(Arc::clone(&g));
        assert!(program.render().contains("path=blocked"));
    }

    #[test]
    fn eval_fallback_covers_non_loop_ops() {
        let mut g = Graph::new("fallback");
        let x = g.placeholder("x", &[4, 6]);
        let sm = g.add_op(OpKind::Softmax, vec![x]).unwrap();
        let t = g.add_op(OpKind::Transpose, vec![sm]).unwrap();
        let s = g.add_op(OpKind::Sum(Some(1)), vec![t]).unwrap();
        g.set_outputs(vec![s]);
        let g = Arc::new(g);
        let inputs =
            vec![Rc::new(Tensor::new(vec![4, 6], (0..24).map(|i| i as f32 * 0.2 - 2.5).collect()))];
        run_both(&g, &inputs, "eval fallback");
        let program = LoopProgram::compile(Arc::clone(&g));
        let evals = program.instrs.iter().filter(|i| matches!(i.op, InstrOp::Eval(_))).count();
        assert_eq!(evals, 3, "softmax/transpose/sum all eval-fallback");
    }

    #[test]
    fn threaded_execution_is_bitwise_equal_to_serial() {
        // Large enough to cross both parallel thresholds.
        let (m, k, n) = (64, 130, 140);
        let mut g = Graph::new("par");
        let a = g.placeholder("a", &[m, k]);
        let b = g.placeholder("b", &[k, n]);
        let bias = g.placeholder("bias", &[n]);
        let mm = g.add_op(OpKind::MatMul, vec![a, b]).unwrap();
        let add = g.add_op(OpKind::Add, vec![mm, bias]).unwrap();
        let act = g.add_op(OpKind::Gelu, vec![add]).unwrap();
        g.set_outputs(vec![act]);
        let g = Arc::new(g);
        let inputs = vec![
            Rc::new(Tensor::new(
                vec![m, k],
                (0..m * k)
                    .map(|i| if i % 5 == 0 { 0.0 } else { (i as f32 * 0.13).sin() })
                    .collect(),
            )),
            Rc::new(Tensor::new(vec![k, n], (0..k * n).map(|i| (i as f32 * 0.07).cos()).collect())),
            Rc::new(Tensor::new(vec![n], (0..n).map(|i| (i as f32) * 0.02 - 1.0).collect())),
        ];
        let serial = CodegenModule::new("par", Arc::clone(&g), None);
        let pool = Some(Arc::new(WorkerPool::new(4)));
        let threaded = CodegenModule::new("par", Arc::clone(&g), pool);
        let want = serial.call(&inputs).unwrap();
        for _ in 0..3 {
            let got = threaded.call(&inputs).unwrap();
            assert_bitwise_eq(&got, &want, "threaded matmul");
        }

        // Elementwise range-split path (numel >= ELEM_PAR_MIN).
        let rows = 300;
        let cols = 256;
        let mut g2 = Graph::new("par_elem");
        let x = g2.placeholder("x", &[rows, cols]);
        let bias = g2.placeholder("b", &[cols]);
        let a2 = g2.add_op(OpKind::Add, vec![x, bias]).unwrap();
        let ge = g2.add_op(OpKind::Gelu, vec![a2]).unwrap();
        let out = g2.add_op(OpKind::Add, vec![ge, x]).unwrap();
        g2.set_outputs(vec![out]);
        let g2 = Arc::new(g2);
        assert!(rows * cols >= ELEM_PAR_MIN);
        let inputs2 = vec![
            Rc::new(Tensor::new(
                vec![rows, cols],
                (0..rows * cols).map(|i| (i as f32 * 0.003).sin() * 2.0).collect(),
            )),
            Rc::new(Tensor::new(vec![cols], (0..cols).map(|i| (i as f32) * 0.01 - 1.2).collect())),
        ];
        let serial2 = CodegenModule::new("par_elem", Arc::clone(&g2), None);
        let threaded2 =
            CodegenModule::new("par_elem", Arc::clone(&g2), Some(Arc::new(WorkerPool::new(4))));
        let want2 = serial2.call(&inputs2).unwrap();
        let got2 = threaded2.call(&inputs2).unwrap();
        assert_bitwise_eq(&got2, &want2, "threaded elementwise");
    }

    #[test]
    fn loop_ir_artifact_is_dumped_and_readable() {
        let module = CodegenModule::new("__compiled_fn_1", elementwise_chain(), None);
        let arts = module.artifacts();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].kind, ArtifactKind::LoopIr);
        assert_eq!(arts[0].file, "__loopir___compiled_fn_1.txt");
        assert!(arts[0].content.contains("loop program chain"));
        assert!(arts[0].content.contains("peak live"));
        assert!(arts[0].content.contains("outputs: "));
        // The render names every instruction form it uses.
        assert!(arts[0].content.contains("input"));
        assert!(arts[0].content.contains("loop"));
    }

    #[test]
    fn backend_contract_plan_and_lower() {
        let g = elementwise_chain();
        let req = CompileRequest::new("__compiled_fn_9", Arc::clone(&g));
        let backend = CodegenBackend::new();
        let plan = backend.plan(&req).unwrap();
        assert_eq!(plan.backend, "codegen");
        assert_eq!(plan.partitions.len(), 1);
        let module = backend.lower(&req, &plan).unwrap();
        assert_eq!(module.backend_name(), "codegen");
        let out = module.call(&chain_inputs()).unwrap();
        let eager = EagerModule::with_fusion(Arc::clone(&g), "eager".into(), false);
        assert_bitwise_eq(&out, &eager.call(&chain_inputs()).unwrap(), "backend contract");
        assert_eq!(module.stats().partitions, 1);
    }

    #[test]
    fn codegen_is_registered_and_composes_with_wrappers() {
        let b = crate::api::lookup_backend("codegen").expect("codegen registered");
        assert_eq!(b.name(), "codegen");
        let g = elementwise_chain();
        let req = CompileRequest::new("wrapped", Arc::clone(&g));
        let resilient = crate::backend::ResilientBackend::new(Arc::new(CodegenBackend::new()));
        let module = resilient.compile(&req).unwrap();
        let out = module.call(&chain_inputs()).unwrap();
        let eager = EagerModule::with_fusion(Arc::clone(&g), "eager".into(), false);
        assert_bitwise_eq(&out, &eager.call(&chain_inputs()).unwrap(), "resilient:codegen");
    }

    #[test]
    fn scalar_output_and_identity_graphs_work() {
        // Output is a placeholder (no ops at all).
        let mut g = Graph::new("ident");
        let x = g.placeholder("x", &[3]);
        g.set_outputs(vec![x]);
        let g = Arc::new(g);
        let inputs = vec![Rc::new(Tensor::new(vec![3], vec![1.0, -0.0, f32::NAN]))];
        let module = CodegenModule::new("ident", Arc::clone(&g), None);
        let out = module.call(&inputs).unwrap();
        assert_eq!(out[0].data()[0].to_bits(), 1.0f32.to_bits());
        assert_eq!(out[0].data()[1].to_bits(), (-0.0f32).to_bits());
        assert!(out[0].data()[2].is_nan());

        // Scalar (rank-0) elementwise output.
        let mut g2 = Graph::new("scalar");
        let a = g2.placeholder("a", &[]);
        let c = g2.const_scalar(2.0);
        let r = g2.add_op(OpKind::Mul, vec![a, c]).unwrap();
        g2.set_outputs(vec![r]);
        let g2 = Arc::new(g2);
        run_both(&g2, &[Rc::new(Tensor::new(vec![], vec![3.5]))], "scalar graph");
    }
}
