//! Tokenizer for the `pylang` Python subset: significant indentation
//! (INDENT/DEDENT), keywords, numbers, strings, and the operator set the
//! grammar needs.

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    // layout
    Newline,
    Indent,
    Dedent,
    EndOfFile,
    // literals & names
    Name(String),
    Int(i64),
    Float(f64),
    Str(String),
    // keywords
    KwDef,
    KwIf,
    KwElif,
    KwElse,
    KwWhile,
    KwFor,
    KwIn,
    KwNot,
    KwAnd,
    KwOr,
    KwReturn,
    KwBreak,
    KwContinue,
    KwPass,
    KwNone,
    KwTrue,
    KwFalse,
    KwIs,
    KwLambda,
    KwAssert,
    KwRaise,
    KwGlobal,
    KwNonlocal,
    // punctuation / operators
    LParen,
    RParen,
    LBracket,
    RBracket,
    LBrace,
    RBrace,
    Comma,
    Colon,
    Dot,
    Assign,
    Plus,
    Minus,
    Star,
    DoubleStar,
    Slash,
    DoubleSlash,
    Percent,
    At,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    PlusAssign,
    MinusAssign,
    StarAssign,
    SlashAssign,
}

/// A token plus its 1-based source line.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

#[derive(Clone, Debug)]
pub struct LexError {
    pub message: String,
    pub line: u32,
}

impl std::fmt::Display for LexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lex error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for LexError {}

fn keyword(s: &str) -> Option<Tok> {
    Some(match s {
        "def" => Tok::KwDef,
        "if" => Tok::KwIf,
        "elif" => Tok::KwElif,
        "else" => Tok::KwElse,
        "while" => Tok::KwWhile,
        "for" => Tok::KwFor,
        "in" => Tok::KwIn,
        "not" => Tok::KwNot,
        "and" => Tok::KwAnd,
        "or" => Tok::KwOr,
        "return" => Tok::KwReturn,
        "break" => Tok::KwBreak,
        "continue" => Tok::KwContinue,
        "pass" => Tok::KwPass,
        "None" => Tok::KwNone,
        "True" => Tok::KwTrue,
        "False" => Tok::KwFalse,
        "is" => Tok::KwIs,
        "lambda" => Tok::KwLambda,
        "assert" => Tok::KwAssert,
        "raise" => Tok::KwRaise,
        "global" => Tok::KwGlobal,
        "nonlocal" => Tok::KwNonlocal,
        _ => return None,
    })
}

/// Tokenize a whole module.
pub fn lex(src: &str) -> Result<Vec<Token>, LexError> {
    let mut out: Vec<Token> = Vec::new();
    let mut indents: Vec<usize> = vec![0];
    // Bracket nesting suppresses NEWLINE/indentation (implicit line joining).
    let mut depth = 0usize;

    for (lineno0, raw_line) in src.lines().enumerate() {
        let line = lineno0 as u32 + 1;
        // When inside brackets, the entire physical line is continuation.
        if depth == 0 {
            // Indentation handling.
            let stripped = raw_line.trim_start_matches(|c| c == ' ');
            let indent = raw_line.len() - stripped.len();
            if raw_line.trim().is_empty() || stripped.starts_with('#') {
                continue; // blank/comment line
            }
            if raw_line.contains('\t') {
                return Err(LexError { message: "tabs are not supported; use spaces".into(), line });
            }
            let current = *indents.last().unwrap();
            if indent > current {
                indents.push(indent);
                out.push(Token { tok: Tok::Indent, line });
            } else if indent < current {
                while *indents.last().unwrap() > indent {
                    indents.pop();
                    out.push(Token { tok: Tok::Dedent, line });
                }
                if *indents.last().unwrap() != indent {
                    return Err(LexError { message: "inconsistent dedent".into(), line });
                }
            }
        }

        // Tokenize the line content.
        let chars: Vec<char> = raw_line.chars().collect();
        let mut i = if depth == 0 { raw_line.len() - raw_line.trim_start_matches(' ').len() } else { 0 };
        while i < chars.len() {
            let c = chars[i];
            match c {
                ' ' => {
                    i += 1;
                }
                '#' => break,
                '(' | '[' | '{' => {
                    depth += 1;
                    out.push(Token {
                        tok: match c {
                            '(' => Tok::LParen,
                            '[' => Tok::LBracket,
                            _ => Tok::LBrace,
                        },
                        line,
                    });
                    i += 1;
                }
                ')' | ']' | '}' => {
                    depth = depth.saturating_sub(1);
                    out.push(Token {
                        tok: match c {
                            ')' => Tok::RParen,
                            ']' => Tok::RBracket,
                            _ => Tok::RBrace,
                        },
                        line,
                    });
                    i += 1;
                }
                ',' => {
                    out.push(Token { tok: Tok::Comma, line });
                    i += 1;
                }
                ':' => {
                    out.push(Token { tok: Tok::Colon, line });
                    i += 1;
                }
                '.' => {
                    // Could be a float like .5? Require leading digit; dot is attribute access.
                    out.push(Token { tok: Tok::Dot, line });
                    i += 1;
                }
                '+' => {
                    if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::PlusAssign, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Plus, line });
                        i += 1;
                    }
                }
                '-' => {
                    if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::MinusAssign, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Minus, line });
                        i += 1;
                    }
                }
                '*' => {
                    if chars.get(i + 1) == Some(&'*') {
                        out.push(Token { tok: Tok::DoubleStar, line });
                        i += 2;
                    } else if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::StarAssign, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Star, line });
                        i += 1;
                    }
                }
                '/' => {
                    if chars.get(i + 1) == Some(&'/') {
                        out.push(Token { tok: Tok::DoubleSlash, line });
                        i += 2;
                    } else if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::SlashAssign, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Slash, line });
                        i += 1;
                    }
                }
                '%' => {
                    out.push(Token { tok: Tok::Percent, line });
                    i += 1;
                }
                '@' => {
                    out.push(Token { tok: Tok::At, line });
                    i += 1;
                }
                '=' => {
                    if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::Eq, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Assign, line });
                        i += 1;
                    }
                }
                '!' => {
                    if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::Ne, line });
                        i += 2;
                    } else {
                        return Err(LexError { message: "unexpected '!'".into(), line });
                    }
                }
                '<' => {
                    if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::Le, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Lt, line });
                        i += 1;
                    }
                }
                '>' => {
                    if chars.get(i + 1) == Some(&'=') {
                        out.push(Token { tok: Tok::Ge, line });
                        i += 2;
                    } else {
                        out.push(Token { tok: Tok::Gt, line });
                        i += 1;
                    }
                }
                '\'' | '"' => {
                    let quote = c;
                    let mut s = String::new();
                    let mut j = i + 1;
                    let mut closed = false;
                    while j < chars.len() {
                        if chars[j] == '\\' && j + 1 < chars.len() {
                            let esc = chars[j + 1];
                            s.push(match esc {
                                'n' => '\n',
                                't' => '\t',
                                '\\' => '\\',
                                '\'' => '\'',
                                '"' => '"',
                                other => other,
                            });
                            j += 2;
                        } else if chars[j] == quote {
                            closed = true;
                            j += 1;
                            break;
                        } else {
                            s.push(chars[j]);
                            j += 1;
                        }
                    }
                    if !closed {
                        return Err(LexError { message: "unterminated string".into(), line });
                    }
                    out.push(Token { tok: Tok::Str(s), line });
                    i = j;
                }
                d if d.is_ascii_digit() => {
                    let mut j = i;
                    let mut is_float = false;
                    while j < chars.len() && (chars[j].is_ascii_digit() || chars[j] == '.' || chars[j] == 'e' || chars[j] == 'E' || ((chars[j] == '+' || chars[j] == '-') && j > i && (chars[j - 1] == 'e' || chars[j - 1] == 'E'))) {
                        if chars[j] == '.' {
                            // "1." then a name means attribute on int literal: not supported; treat as float
                            if is_float {
                                break;
                            }
                            // `1.method()` not supported; digits then dot then digit = float
                            if chars.get(j + 1).map(|c| c.is_ascii_digit()).unwrap_or(false) {
                                is_float = true;
                            } else {
                                break;
                            }
                        }
                        if chars[j] == 'e' || chars[j] == 'E' {
                            is_float = true;
                        }
                        j += 1;
                    }
                    let text: String = chars[i..j].iter().collect();
                    if is_float {
                        let v: f64 = text.parse().map_err(|_| LexError { message: format!("bad float '{}'", text), line })?;
                        out.push(Token { tok: Tok::Float(v), line });
                    } else {
                        let v: i64 = text.parse().map_err(|_| LexError { message: format!("bad int '{}'", text), line })?;
                        out.push(Token { tok: Tok::Int(v), line });
                    }
                    i = j;
                }
                a if a.is_ascii_alphabetic() || a == '_' => {
                    let mut j = i;
                    while j < chars.len() && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
                        j += 1;
                    }
                    let text: String = chars[i..j].iter().collect();
                    out.push(Token { tok: keyword(&text).unwrap_or(Tok::Name(text)), line });
                    i = j;
                }
                other => {
                    return Err(LexError { message: format!("unexpected character '{}'", other), line });
                }
            }
        }
        if depth == 0 {
            out.push(Token { tok: Tok::Newline, line });
        }
    }
    // Close remaining indents.
    let last_line = src.lines().count() as u32;
    while indents.len() > 1 {
        indents.pop();
        out.push(Token { tok: Tok::Dedent, line: last_line });
    }
    out.push(Token { tok: Tok::EndOfFile, line: last_line });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn simple_assignment() {
        assert_eq!(
            toks("x = 1\n"),
            vec![Tok::Name("x".into()), Tok::Assign, Tok::Int(1), Tok::Newline, Tok::EndOfFile]
        );
    }

    #[test]
    fn indentation() {
        let ts = toks("if x:\n    y = 1\nz = 2\n");
        assert!(ts.contains(&Tok::Indent));
        assert!(ts.contains(&Tok::Dedent));
    }

    #[test]
    fn operators() {
        let ts = toks("a += b ** 2 // 3 != c @ d\n");
        assert!(ts.contains(&Tok::PlusAssign));
        assert!(ts.contains(&Tok::DoubleStar));
        assert!(ts.contains(&Tok::DoubleSlash));
        assert!(ts.contains(&Tok::Ne));
        assert!(ts.contains(&Tok::At));
    }

    #[test]
    fn strings_and_escapes() {
        let ts = toks("s = 'a\\nb'\n");
        assert!(ts.contains(&Tok::Str("a\nb".into())));
    }

    #[test]
    fn floats_and_ints() {
        let ts = toks("a = 1.5\nb = 2e3\nc = 10\n");
        assert!(ts.contains(&Tok::Float(1.5)));
        assert!(ts.contains(&Tok::Float(2000.0)));
        assert!(ts.contains(&Tok::Int(10)));
    }

    #[test]
    fn implicit_line_joining_in_brackets() {
        let ts = toks("a = [1,\n     2]\n");
        // No NEWLINE between 1, and 2
        let newline_count = ts.iter().filter(|t| **t == Tok::Newline).count();
        assert_eq!(newline_count, 1);
        assert!(!ts.contains(&Tok::Indent));
    }

    #[test]
    fn comments_skipped() {
        let ts = toks("# comment\nx = 1  # trailing\n");
        assert_eq!(ts.iter().filter(|t| matches!(t, Tok::Int(_))).count(), 1);
    }

    #[test]
    fn keywords_vs_names() {
        let ts = toks("for x in xs:\n    pass\n");
        assert!(ts.contains(&Tok::KwFor));
        assert!(ts.contains(&Tok::KwIn));
        assert!(ts.contains(&Tok::Name("xs".into())));
        assert!(ts.contains(&Tok::KwPass));
    }

    #[test]
    fn error_on_tab() {
        assert!(lex("if x:\n\ty = 1\n").is_err());
    }

    #[test]
    fn multi_dedent() {
        let ts = toks("if a:\n    if b:\n        c = 1\nd = 2\n");
        let dedents = ts.iter().filter(|t| **t == Tok::Dedent).count();
        assert_eq!(dedents, 2);
    }
}
