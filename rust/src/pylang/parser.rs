//! Recursive-descent parser for the `pylang` Python subset.

use super::ast::*;
use super::lexer::{lex, Tok, Token};
use crate::bytecode::{BinOp, CmpOp, UnOp};

#[derive(Clone, Debug)]
pub struct ParseError {
    pub message: String,
    pub line: u32,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a module.
pub fn parse(src: &str) -> Result<Module, ParseError> {
    let toks = lex(src).map_err(|e| ParseError { message: e.message, line: e.line })?;
    let mut p = Parser { toks, pos: 0 };
    p.module()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn peek2(&self) -> &Tok {
        self.toks.get(self.pos + 1).map(|t| &t.tok).unwrap_or(&Tok::EndOfFile)
    }

    fn line(&self) -> u32 {
        self.toks[self.pos].line
    }

    fn bump(&mut self) -> Tok {
        let t = self.toks[self.pos].tok.clone();
        if self.pos < self.toks.len() - 1 {
            self.pos += 1;
        }
        t
    }

    fn eat(&mut self, t: &Tok) -> bool {
        if self.peek() == t {
            self.bump();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, t: &Tok, what: &str) -> Result<(), ParseError> {
        if self.eat(t) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {}, found {:?}", what, self.peek())))
        }
    }

    fn err(&self, message: &str) -> ParseError {
        ParseError { message: message.to_string(), line: self.line() }
    }

    fn module(&mut self) -> Result<Module, ParseError> {
        let mut body = Vec::new();
        while *self.peek() != Tok::EndOfFile {
            if self.eat(&Tok::Newline) {
                continue;
            }
            body.push(self.stmt()?);
        }
        Ok(Module { body })
    }

    fn block(&mut self) -> Result<Vec<Stmt>, ParseError> {
        self.expect(&Tok::Colon, "':'")?;
        self.expect(&Tok::Newline, "newline")?;
        self.expect(&Tok::Indent, "indented block")?;
        let mut body = Vec::new();
        while *self.peek() != Tok::Dedent && *self.peek() != Tok::EndOfFile {
            if self.eat(&Tok::Newline) {
                continue;
            }
            body.push(self.stmt()?);
        }
        self.expect(&Tok::Dedent, "dedent")?;
        if body.is_empty() {
            return Err(self.err("empty block"));
        }
        Ok(body)
    }

    fn stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        match self.peek().clone() {
            Tok::KwDef => self.funcdef(),
            Tok::KwIf => self.if_stmt(),
            Tok::KwWhile => self.while_stmt(),
            Tok::KwFor => self.for_stmt(),
            Tok::KwReturn => {
                self.bump();
                let value = if *self.peek() == Tok::Newline { None } else { Some(self.testlist()?) };
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(StmtKind::Return(value), line))
            }
            Tok::KwBreak => {
                self.bump();
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(StmtKind::Break, line))
            }
            Tok::KwContinue => {
                self.bump();
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(StmtKind::Continue, line))
            }
            Tok::KwPass => {
                self.bump();
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(StmtKind::Pass, line))
            }
            Tok::KwGlobal | Tok::KwNonlocal => {
                let is_global = self.bump() == Tok::KwGlobal;
                let mut names = vec![self.name()?];
                while self.eat(&Tok::Comma) {
                    names.push(self.name()?);
                }
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(if is_global { StmtKind::Global(names) } else { StmtKind::Nonlocal(names) }, line))
            }
            Tok::KwAssert => {
                self.bump();
                let cond = self.test()?;
                let msg = if self.eat(&Tok::Comma) { Some(self.test()?) } else { None };
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(StmtKind::Assert { cond, msg }, line))
            }
            Tok::KwRaise => {
                self.bump();
                let e = self.test()?;
                self.expect(&Tok::Newline, "newline")?;
                Ok(Stmt::new(StmtKind::Raise(e), line))
            }
            _ => self.expr_stmt(),
        }
    }

    fn name(&mut self) -> Result<String, ParseError> {
        match self.bump() {
            Tok::Name(s) => Ok(s),
            other => Err(self.err(&format!("expected name, found {:?}", other))),
        }
    }

    fn funcdef(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.bump(); // def
        let name = self.name()?;
        self.expect(&Tok::LParen, "'('")?;
        let mut params = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                let pname = self.name()?;
                let default = if self.eat(&Tok::Assign) { Some(self.test()?) } else { None };
                params.push(Param { name: pname, default });
                if !self.eat(&Tok::Comma) {
                    break;
                }
            }
        }
        self.expect(&Tok::RParen, "')'")?;
        let body = self.block()?;
        Ok(Stmt::new(StmtKind::FuncDef { name, params, body }, line))
    }

    fn if_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.bump(); // if / elif
        let cond = self.test()?;
        let then = self.block()?;
        let orelse = if *self.peek() == Tok::KwElif {
            vec![self.if_stmt_from_elif()?]
        } else if self.eat(&Tok::KwElse) {
            self.block()?
        } else {
            Vec::new()
        };
        Ok(Stmt::new(StmtKind::If { cond, then, orelse }, line))
    }

    fn if_stmt_from_elif(&mut self) -> Result<Stmt, ParseError> {
        // `elif` parses exactly like a nested `if`.
        self.if_stmt()
    }

    fn while_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.bump();
        let cond = self.test()?;
        let body = self.block()?;
        let orelse = if self.eat(&Tok::KwElse) { self.block()? } else { Vec::new() };
        Ok(Stmt::new(StmtKind::While { cond, body, orelse }, line))
    }

    fn for_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        self.bump();
        let target_expr = self.target_list()?;
        let target = expr_to_target(target_expr).map_err(|m| self.err(&m))?;
        self.expect(&Tok::KwIn, "'in'")?;
        let iter = self.testlist()?;
        let body = self.block()?;
        let orelse = if self.eat(&Tok::KwElse) { self.block()? } else { Vec::new() };
        Ok(Stmt::new(StmtKind::For { target, iter, body, orelse }, line))
    }

    /// Comma-separated names/subscripts before `in` (for-loop targets).
    fn target_list(&mut self) -> Result<Expr, ParseError> {
        let first = self.postfix()?;
        if *self.peek() == Tok::Comma {
            let mut items = vec![first];
            while self.eat(&Tok::Comma) {
                if *self.peek() == Tok::KwIn {
                    break;
                }
                items.push(self.postfix()?);
            }
            Ok(Expr::Tuple(items))
        } else {
            Ok(first)
        }
    }

    fn expr_stmt(&mut self) -> Result<Stmt, ParseError> {
        let line = self.line();
        let first = self.testlist()?;
        let kind = match self.peek().clone() {
            Tok::Assign => {
                self.bump();
                let value = self.testlist()?;
                let target = expr_to_target(first).map_err(|m| self.err(&m))?;
                StmtKind::Assign { target, value }
            }
            Tok::PlusAssign | Tok::MinusAssign | Tok::StarAssign | Tok::SlashAssign => {
                let op = match self.bump() {
                    Tok::PlusAssign => BinOp::Add,
                    Tok::MinusAssign => BinOp::Sub,
                    Tok::StarAssign => BinOp::Mul,
                    _ => BinOp::Div,
                };
                let value = self.testlist()?;
                let target = expr_to_target(first).map_err(|m| self.err(&m))?;
                StmtKind::AugAssign { target, op, value }
            }
            _ => StmtKind::Expr(first),
        };
        self.expect(&Tok::Newline, "newline")?;
        Ok(Stmt::new(kind, line))
    }

    /// `test (',' test)*` — a tuple when more than one.
    fn testlist(&mut self) -> Result<Expr, ParseError> {
        let first = self.test()?;
        if *self.peek() == Tok::Comma {
            let mut items = vec![first];
            while self.eat(&Tok::Comma) {
                // Trailing comma before a closer/assign.
                if matches!(self.peek(), Tok::Newline | Tok::Assign | Tok::RParen | Tok::RBracket) {
                    break;
                }
                items.push(self.test()?);
            }
            Ok(Expr::Tuple(items))
        } else {
            Ok(first)
        }
    }

    /// Conditional expression / lambda.
    fn test(&mut self) -> Result<Expr, ParseError> {
        if *self.peek() == Tok::KwLambda {
            self.bump();
            let mut params = Vec::new();
            if *self.peek() != Tok::Colon {
                loop {
                    params.push(self.name()?);
                    if !self.eat(&Tok::Comma) {
                        break;
                    }
                }
            }
            self.expect(&Tok::Colon, "':'")?;
            let body = Box::new(self.test()?);
            return Ok(Expr::Lambda { params, body });
        }
        let body = self.or_test()?;
        if self.eat(&Tok::KwIf) {
            let cond = Box::new(self.or_test()?);
            self.expect(&Tok::KwElse, "'else'")?;
            let orelse = Box::new(self.test()?);
            return Ok(Expr::IfExp { cond, then: Box::new(body), orelse });
        }
        Ok(body)
    }

    fn or_test(&mut self) -> Result<Expr, ParseError> {
        let first = self.and_test()?;
        if *self.peek() != Tok::KwOr {
            return Ok(first);
        }
        let mut items = vec![first];
        while self.eat(&Tok::KwOr) {
            items.push(self.and_test()?);
        }
        Ok(Expr::BoolOp(BoolOpKind::Or, items))
    }

    fn and_test(&mut self) -> Result<Expr, ParseError> {
        let first = self.not_test()?;
        if *self.peek() != Tok::KwAnd {
            return Ok(first);
        }
        let mut items = vec![first];
        while self.eat(&Tok::KwAnd) {
            items.push(self.not_test()?);
        }
        Ok(Expr::BoolOp(BoolOpKind::And, items))
    }

    fn not_test(&mut self) -> Result<Expr, ParseError> {
        if self.eat(&Tok::KwNot) {
            let inner = self.not_test()?;
            return Ok(Expr::UnaryOp(UnOp::Not, Box::new(inner)));
        }
        self.comparison()
    }

    fn comparison(&mut self) -> Result<Expr, ParseError> {
        let left = self.arith()?;
        let mut ops = Vec::new();
        let mut comparators = Vec::new();
        loop {
            let op = match self.peek() {
                Tok::Lt => CompareKind::Cmp(CmpOp::Lt),
                Tok::Le => CompareKind::Cmp(CmpOp::Le),
                Tok::Gt => CompareKind::Cmp(CmpOp::Gt),
                Tok::Ge => CompareKind::Cmp(CmpOp::Ge),
                Tok::Eq => CompareKind::Cmp(CmpOp::Eq),
                Tok::Ne => CompareKind::Cmp(CmpOp::Ne),
                Tok::KwIn => CompareKind::In,
                Tok::KwIs => {
                    // `is` / `is not`
                    if *self.peek2() == Tok::KwNot {
                        self.bump();
                        self.bump();
                        ops.push(CompareKind::IsNot);
                        comparators.push(self.arith()?);
                        continue;
                    }
                    CompareKind::Is
                }
                Tok::KwNot => {
                    // `not in`
                    if *self.peek2() == Tok::KwIn {
                        self.bump();
                        self.bump();
                        ops.push(CompareKind::NotIn);
                        comparators.push(self.arith()?);
                        continue;
                    }
                    break;
                }
                _ => break,
            };
            self.bump();
            ops.push(op);
            comparators.push(self.arith()?);
        }
        if ops.is_empty() {
            Ok(left)
        } else {
            Ok(Expr::Compare { left: Box::new(left), ops, comparators })
        }
    }

    fn arith(&mut self) -> Result<Expr, ParseError> {
        let mut left = self.term()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let right = self.term()?;
            left = Expr::BinOp(op, Box::new(left), Box::new(right));
        }
        Ok(left)
    }

    fn term(&mut self) -> Result<Expr, ParseError> {
        let mut left = self.factor()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::DoubleSlash => BinOp::FloorDiv,
                Tok::Percent => BinOp::Mod,
                Tok::At => BinOp::MatMul,
                _ => break,
            };
            self.bump();
            let right = self.factor()?;
            left = Expr::BinOp(op, Box::new(left), Box::new(right));
        }
        Ok(left)
    }

    fn factor(&mut self) -> Result<Expr, ParseError> {
        if self.eat(&Tok::Minus) {
            let inner = self.factor()?;
            // Fold negative literals.
            return Ok(match inner {
                Expr::Int(i) => Expr::Int(-i),
                Expr::Float(f) => Expr::Float(-f),
                other => Expr::UnaryOp(UnOp::Neg, Box::new(other)),
            });
        }
        if self.eat(&Tok::Plus) {
            let inner = self.factor()?;
            return Ok(Expr::UnaryOp(UnOp::Pos, Box::new(inner)));
        }
        self.power()
    }

    fn power(&mut self) -> Result<Expr, ParseError> {
        let base = self.postfix()?;
        if self.eat(&Tok::DoubleStar) {
            let exp = self.factor()?; // right-assoc
            return Ok(Expr::BinOp(BinOp::Pow, Box::new(base), Box::new(exp)));
        }
        Ok(base)
    }

    fn postfix(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.atom()?;
        loop {
            match self.peek().clone() {
                Tok::LParen => {
                    self.bump();
                    let args = self.call_args()?;
                    e = match e {
                        Expr::Attribute { value, name } => Expr::MethodCall { recv: value, name, args },
                        other => Expr::Call { func: Box::new(other), args },
                    };
                }
                Tok::Dot => {
                    self.bump();
                    let name = self.name()?;
                    e = Expr::Attribute { value: Box::new(e), name };
                }
                Tok::LBracket => {
                    self.bump();
                    let index = self.subscript()?;
                    self.expect(&Tok::RBracket, "']'")?;
                    e = Expr::Subscript { value: Box::new(e), index: Box::new(index) };
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn call_args(&mut self) -> Result<Vec<Expr>, ParseError> {
        let mut args = Vec::new();
        if *self.peek() != Tok::RParen {
            loop {
                args.push(self.test()?);
                if !self.eat(&Tok::Comma) {
                    break;
                }
                if *self.peek() == Tok::RParen {
                    break;
                }
            }
        }
        self.expect(&Tok::RParen, "')'")?;
        Ok(args)
    }

    fn subscript(&mut self) -> Result<Expr, ParseError> {
        // Possible slice: [a:b:c] with any part empty.
        let start = if matches!(self.peek(), Tok::Colon) { None } else { Some(Box::new(self.test()?)) };
        if !self.eat(&Tok::Colon) {
            return Ok(*start.unwrap());
        }
        let stop = if matches!(self.peek(), Tok::Colon | Tok::RBracket) { None } else { Some(Box::new(self.test()?)) };
        let step = if self.eat(&Tok::Colon) {
            if matches!(self.peek(), Tok::RBracket) {
                None
            } else {
                Some(Box::new(self.test()?))
            }
        } else {
            None
        };
        Ok(Expr::Slice { start, stop, step })
    }

    fn atom(&mut self) -> Result<Expr, ParseError> {
        match self.bump() {
            Tok::Name(s) => Ok(Expr::Name(s)),
            Tok::Int(i) => Ok(Expr::Int(i)),
            Tok::Float(f) => Ok(Expr::Float(f)),
            Tok::Str(s) => Ok(Expr::Str(s)),
            Tok::KwNone => Ok(Expr::NoneLit),
            Tok::KwTrue => Ok(Expr::Bool(true)),
            Tok::KwFalse => Ok(Expr::Bool(false)),
            Tok::LParen => {
                if self.eat(&Tok::RParen) {
                    return Ok(Expr::Tuple(vec![]));
                }
                let inner = self.testlist()?;
                self.expect(&Tok::RParen, "')'")?;
                Ok(inner)
            }
            Tok::LBracket => {
                if self.eat(&Tok::RBracket) {
                    return Ok(Expr::List(vec![]));
                }
                let first = self.test()?;
                if *self.peek() == Tok::KwFor {
                    // list comprehension
                    self.bump();
                    let target_expr = self.target_list()?;
                    let target = expr_to_target(target_expr).map_err(|m| self.err(&m))?;
                    self.expect(&Tok::KwIn, "'in'")?;
                    let iter = self.or_test()?;
                    let mut conds = Vec::new();
                    while self.eat(&Tok::KwIf) {
                        conds.push(self.or_test()?);
                    }
                    self.expect(&Tok::RBracket, "']'")?;
                    return Ok(Expr::ListComp {
                        elt: Box::new(first),
                        target: Box::new(target),
                        iter: Box::new(iter),
                        conds,
                    });
                }
                let mut items = vec![first];
                while self.eat(&Tok::Comma) {
                    if *self.peek() == Tok::RBracket {
                        break;
                    }
                    items.push(self.test()?);
                }
                self.expect(&Tok::RBracket, "']'")?;
                Ok(Expr::List(items))
            }
            Tok::LBrace => {
                let mut items = Vec::new();
                if *self.peek() != Tok::RBrace {
                    loop {
                        let k = self.test()?;
                        self.expect(&Tok::Colon, "':'")?;
                        let v = self.test()?;
                        items.push((k, v));
                        if !self.eat(&Tok::Comma) {
                            break;
                        }
                        if *self.peek() == Tok::RBrace {
                            break;
                        }
                    }
                }
                self.expect(&Tok::RBrace, "'}'")?;
                Ok(Expr::Dict(items))
            }
            other => Err(self.err(&format!("unexpected token {:?}", other))),
        }
    }
}

/// Convert an expression that appeared in target position into a [`Target`].
pub fn expr_to_target(e: Expr) -> Result<Target, String> {
    match e {
        Expr::Name(n) => Ok(Target::Name(n)),
        Expr::Tuple(items) | Expr::List(items) => {
            let ts: Result<Vec<Target>, String> = items.into_iter().map(expr_to_target).collect();
            Ok(Target::Tuple(ts?))
        }
        Expr::Subscript { value, index } => Ok(Target::Subscript { value: *value, index: *index }),
        other => Err(format!("invalid assignment target: {:?}", other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ok(src: &str) -> Module {
        parse(src).unwrap_or_else(|e| panic!("{} in:\n{}", e, src))
    }

    #[test]
    fn assignment_and_arith() {
        let m = parse_ok("x = 1 + 2 * 3\n");
        assert_eq!(m.body.len(), 1);
        match &m.body[0].kind {
            StmtKind::Assign { target: Target::Name(n), value } => {
                assert_eq!(n, "x");
                // precedence: 1 + (2*3)
                assert!(matches!(value, Expr::BinOp(BinOp::Add, _, r) if matches!(**r, Expr::BinOp(BinOp::Mul, _, _))));
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn chained_comparison() {
        let m = parse_ok("r = 1 < x <= 10\n");
        match &m.body[0].kind {
            StmtKind::Assign { value: Expr::Compare { ops, comparators, .. }, .. } => {
                assert_eq!(ops.len(), 2);
                assert_eq!(comparators.len(), 2);
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn funcdef_with_defaults() {
        let m = parse_ok("def f(a, b=2):\n    return a + b\n");
        match &m.body[0].kind {
            StmtKind::FuncDef { name, params, body } => {
                assert_eq!(name, "f");
                assert_eq!(params.len(), 2);
                assert!(params[1].default.is_some());
                assert_eq!(body.len(), 1);
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn if_elif_else() {
        let m = parse_ok("if a:\n    x = 1\nelif b:\n    x = 2\nelse:\n    x = 3\n");
        match &m.body[0].kind {
            StmtKind::If { orelse, .. } => {
                assert_eq!(orelse.len(), 1);
                assert!(matches!(&orelse[0].kind, StmtKind::If { orelse: e2, .. } if e2.len() == 1));
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn loops_with_else() {
        parse_ok("while x > 0:\n    x -= 1\nelse:\n    y = 1\n");
        parse_ok("for i in range(10):\n    if i == 5:\n        break\nelse:\n    y = 2\n");
    }

    #[test]
    fn tuple_unpack_for() {
        let m = parse_ok("for k, v in items:\n    pass\n");
        match &m.body[0].kind {
            StmtKind::For { target: Target::Tuple(ts), .. } => assert_eq!(ts.len(), 2),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn method_vs_attribute() {
        let m = parse_ok("y = x.relu()\nz = x.shape\n");
        assert!(matches!(&m.body[0].kind, StmtKind::Assign { value: Expr::MethodCall { .. }, .. }));
        assert!(matches!(&m.body[1].kind, StmtKind::Assign { value: Expr::Attribute { .. }, .. }));
    }

    #[test]
    fn list_comp_with_conds() {
        let m = parse_ok("ys = [x * 2 for x in xs if x > 0 if x < 10]\n");
        match &m.body[0].kind {
            StmtKind::Assign { value: Expr::ListComp { conds, .. }, .. } => assert_eq!(conds.len(), 2),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn slices() {
        let m = parse_ok("a = xs[1:3]\nb = xs[:2]\nc = xs[::2]\nd = xs[1]\n");
        assert_eq!(m.body.len(), 4);
        assert!(matches!(
            &m.body[0].kind,
            StmtKind::Assign { value: Expr::Subscript { index, .. }, .. } if matches!(**index, Expr::Slice { .. })
        ));
        assert!(matches!(
            &m.body[3].kind,
            StmtKind::Assign { value: Expr::Subscript { index, .. }, .. } if matches!(**index, Expr::Int(1))
        ));
    }

    #[test]
    fn lambda_and_ternary() {
        parse_ok("f = lambda a, b: a + b\ny = 1 if c else 2\n");
    }

    #[test]
    fn ternary_nested() {
        let m = parse_ok("y = 1 if a else 2 if b else 3\n");
        match &m.body[0].kind {
            StmtKind::Assign { value: Expr::IfExp { orelse, .. }, .. } => {
                assert!(matches!(**orelse, Expr::IfExp { .. }));
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn boolops_collect() {
        let m = parse_ok("r = a and b and c or d\n");
        match &m.body[0].kind {
            StmtKind::Assign { value: Expr::BoolOp(BoolOpKind::Or, items), .. } => {
                assert_eq!(items.len(), 2);
                assert!(matches!(&items[0], Expr::BoolOp(BoolOpKind::And, inner) if inner.len() == 3));
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn is_not_and_not_in() {
        let m = parse_ok("a = x is not None\nb = y not in xs\n");
        assert_eq!(m.body.len(), 2);
        match &m.body[0].kind {
            StmtKind::Assign { value: Expr::Compare { ops, .. }, .. } => assert_eq!(ops[0], CompareKind::IsNot),
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn subscript_store() {
        let m = parse_ok("d['k'] = 3\n");
        assert!(matches!(&m.body[0].kind, StmtKind::Assign { target: Target::Subscript { .. }, .. }));
    }

    #[test]
    fn error_cases() {
        assert!(parse("x = = 1\n").is_err());
        assert!(parse("if x\n    pass\n").is_err());
        assert!(parse("1 = x\n").is_err());
    }
}
