//! AST → source text (precedence-aware). Used by the decompiler to render
//! reconstructed ASTs, and by tests to round-trip corpus programs.

use super::ast::*;
use crate::bytecode::{BinOp, UnOp};

/// Operator precedence (higher binds tighter). Mirrors Python's table.
fn prec(e: &Expr) -> u8 {
    match e {
        Expr::Lambda { .. } => 1,
        Expr::IfExp { .. } => 2,
        Expr::BoolOp(BoolOpKind::Or, _) => 3,
        Expr::BoolOp(BoolOpKind::And, _) => 4,
        Expr::UnaryOp(UnOp::Not, _) => 5,
        Expr::Compare { .. } => 6,
        Expr::BinOp(BinOp::Add | BinOp::Sub, ..) => 9,
        Expr::BinOp(BinOp::Mul | BinOp::Div | BinOp::FloorDiv | BinOp::Mod | BinOp::MatMul, ..) => 10,
        Expr::UnaryOp(UnOp::Neg | UnOp::Pos, _) => 11,
        Expr::BinOp(BinOp::Pow, ..) => 12,
        _ => 14, // atoms, calls, subscripts, attributes
    }
}

/// Render an expression, parenthesizing children of lower precedence.
pub fn unparse_expr(e: &Expr) -> String {
    let paren = |child: &Expr, min: u8| -> String {
        let s = unparse_expr(child);
        if prec(child) < min {
            format!("({})", s)
        } else {
            s
        }
    };
    match e {
        Expr::NoneLit => "None".into(),
        Expr::Bool(true) => "True".into(),
        Expr::Bool(false) => "False".into(),
        Expr::Int(i) => i.to_string(),
        Expr::Float(f) => {
            if f.fract() == 0.0 && f.abs() < 1e16 {
                format!("{:.1}", f)
            } else {
                format!("{}", f)
            }
        }
        Expr::Str(s) => format!("'{}'", s.replace('\\', "\\\\").replace('\'', "\\'").replace('\n', "\\n").replace('\t', "\\t")),
        Expr::Name(n) => n.clone(),
        Expr::List(items) => format!("[{}]", items.iter().map(unparse_expr).collect::<Vec<_>>().join(", ")),
        Expr::Tuple(items) => {
            if items.is_empty() {
                "()".into()
            } else if items.len() == 1 {
                format!("({},)", unparse_expr(&items[0]))
            } else {
                format!("({})", items.iter().map(unparse_expr).collect::<Vec<_>>().join(", "))
            }
        }
        Expr::Dict(kvs) => format!(
            "{{{}}}",
            kvs.iter().map(|(k, v)| format!("{}: {}", unparse_expr(k), unparse_expr(v))).collect::<Vec<_>>().join(", ")
        ),
        Expr::BinOp(op, a, b) => {
            let p = prec(e);
            match op {
                // Right-associative.
                BinOp::Pow => format!("{} ** {}", paren(a, p + 1), paren(b, p)),
                _ => format!("{} {} {}", paren(a, p), op.symbol(), paren(b, p + 1)),
            }
        }
        Expr::UnaryOp(op, a) => {
            let p = prec(e);
            format!("{}{}", op.symbol(), paren(a, p))
        }
        Expr::BoolOp(kind, items) => {
            let p = prec(e);
            let sep = match kind {
                BoolOpKind::And => " and ",
                BoolOpKind::Or => " or ",
            };
            items.iter().map(|i| paren(i, p + 1)).collect::<Vec<_>>().join(sep)
        }
        Expr::Compare { left, ops, comparators } => {
            let p = prec(e);
            let mut s = paren(left, p + 1);
            for (op, c) in ops.iter().zip(comparators.iter()) {
                s.push_str(&format!(" {} {}", op.symbol(), paren(c, p + 1)));
            }
            s
        }
        Expr::Call { func, args } => {
            format!("{}({})", paren(func, 14), args.iter().map(unparse_expr).collect::<Vec<_>>().join(", "))
        }
        Expr::MethodCall { recv, name, args } => {
            format!("{}.{}({})", paren(recv, 14), name, args.iter().map(unparse_expr).collect::<Vec<_>>().join(", "))
        }
        Expr::Attribute { value, name } => format!("{}.{}", paren(value, 14), name),
        Expr::Subscript { value, index } => format!("{}[{}]", paren(value, 14), unparse_expr(index)),
        Expr::Slice { start, stop, step } => {
            let part = |o: &Option<Box<Expr>>| o.as_ref().map(|e| unparse_expr(e)).unwrap_or_default();
            match step {
                Some(_) => format!("{}:{}:{}", part(start), part(stop), part(step)),
                None => format!("{}:{}", part(start), part(stop)),
            }
        }
        Expr::IfExp { cond, then, orelse } => {
            let p = prec(e);
            format!("{} if {} else {}", paren(then, p + 1), paren(cond, p + 1), paren(orelse, p))
        }
        Expr::Lambda { params, body } => format!("lambda {}: {}", params.join(", "), unparse_expr(body)),
        Expr::ListComp { elt, target, iter, conds } => {
            let mut s = format!("[{} for {} in {}", unparse_expr(elt), unparse_target(target), paren(iter, 3));
            for c in conds {
                s.push_str(&format!(" if {}", paren(c, 3)));
            }
            s.push(']');
            s
        }
    }
}

pub fn unparse_target(t: &Target) -> String {
    match t {
        Target::Name(n) => n.clone(),
        Target::Tuple(ts) if ts.len() == 1 => format!("{},", unparse_target(&ts[0])),
        Target::Tuple(ts) => ts.iter().map(unparse_target).collect::<Vec<_>>().join(", "),
        Target::Subscript { value, index } => format!("{}[{}]", unparse_expr(value), unparse_expr(index)),
    }
}

fn unparse_block(body: &[Stmt], indent: usize, out: &mut String) {
    if body.is_empty() {
        out.push_str(&"    ".repeat(indent));
        out.push_str("pass\n");
        return;
    }
    for s in body {
        unparse_stmt(s, indent, out);
    }
}

pub fn unparse_stmt(s: &Stmt, indent: usize, out: &mut String) {
    let pad = "    ".repeat(indent);
    match &s.kind {
        StmtKind::Expr(e) => out.push_str(&format!("{}{}\n", pad, unparse_expr(e))),
        StmtKind::Assign { target, value } => out.push_str(&format!("{}{} = {}\n", pad, unparse_target(target), unparse_expr(value))),
        StmtKind::AugAssign { target, op, value } => {
            out.push_str(&format!("{}{} {}= {}\n", pad, unparse_target(target), op.symbol(), unparse_expr(value)))
        }
        StmtKind::If { cond, then, orelse } => {
            out.push_str(&format!("{}if {}:\n", pad, unparse_expr(cond)));
            unparse_block(then, indent + 1, out);
            if !orelse.is_empty() {
                // elif chains render as nested `else: if:` — flatten one level.
                if orelse.len() == 1 {
                    if let StmtKind::If { .. } = &orelse[0].kind {
                        let mut tmp = String::new();
                        unparse_stmt(&orelse[0], indent, &mut tmp);
                        let flat = tmp.replacen(&format!("{}if ", pad), &format!("{}elif ", pad), 1);
                        out.push_str(&flat);
                        return;
                    }
                }
                out.push_str(&format!("{}else:\n", pad));
                unparse_block(orelse, indent + 1, out);
            }
        }
        StmtKind::While { cond, body, orelse } => {
            out.push_str(&format!("{}while {}:\n", pad, unparse_expr(cond)));
            unparse_block(body, indent + 1, out);
            if !orelse.is_empty() {
                out.push_str(&format!("{}else:\n", pad));
                unparse_block(orelse, indent + 1, out);
            }
        }
        StmtKind::For { target, iter, body, orelse } => {
            out.push_str(&format!("{}for {} in {}:\n", pad, unparse_target(target), unparse_expr(iter)));
            unparse_block(body, indent + 1, out);
            if !orelse.is_empty() {
                out.push_str(&format!("{}else:\n", pad));
                unparse_block(orelse, indent + 1, out);
            }
        }
        StmtKind::FuncDef { name, params, body } => {
            let ps: Vec<String> = params
                .iter()
                .map(|p| match &p.default {
                    Some(d) => format!("{}={}", p.name, unparse_expr(d)),
                    None => p.name.clone(),
                })
                .collect();
            out.push_str(&format!("{}def {}({}):\n", pad, name, ps.join(", ")));
            unparse_block(body, indent + 1, out);
        }
        StmtKind::Return(v) => match v {
            Some(e) => out.push_str(&format!("{}return {}\n", pad, unparse_expr(e))),
            None => out.push_str(&format!("{}return\n", pad)),
        },
        StmtKind::Break => out.push_str(&format!("{}break\n", pad)),
        StmtKind::Continue => out.push_str(&format!("{}continue\n", pad)),
        StmtKind::Pass => out.push_str(&format!("{}pass\n", pad)),
        StmtKind::Global(names) => out.push_str(&format!("{}global {}\n", pad, names.join(", "))),
        StmtKind::Nonlocal(names) => out.push_str(&format!("{}nonlocal {}\n", pad, names.join(", "))),
        StmtKind::Assert { cond, msg } => match msg {
            Some(m) => out.push_str(&format!("{}assert {}, {}\n", pad, unparse_expr(cond), unparse_expr(m))),
            None => out.push_str(&format!("{}assert {}\n", pad, unparse_expr(cond))),
        },
        StmtKind::Raise(e) => out.push_str(&format!("{}raise {}\n", pad, unparse_expr(e))),
    }
}

/// Render a whole module.
pub fn unparse_module(m: &Module) -> String {
    let mut out = String::new();
    for s in &m.body {
        unparse_stmt(s, 0, &mut out);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;

    /// Parse → unparse → parse must be a fixpoint (same AST).
    fn stable(src: &str) {
        let m1 = parse(src).unwrap();
        let text = unparse_module(&m1);
        let m2 = parse(&text).unwrap_or_else(|e| panic!("{}\nunparsed was:\n{}", e, text));
        // Compare ignoring line numbers.
        let t2 = unparse_module(&m2);
        assert_eq!(text, t2, "unparse not stable for:\n{}", src);
    }

    #[test]
    fn roundtrip_arith_precedence() {
        stable("x = (1 + 2) * 3 - 4 ** 2 ** 2\n");
        stable("y = -x ** 2\n");
        stable("z = (a + b) % (c - d) // e\n");
    }

    #[test]
    fn roundtrip_bool_and_compare() {
        stable("r = a and (b or c) and not d\n");
        stable("r = 1 < x <= 10 != y\n");
        stable("r = x is not None and y not in xs\n");
    }

    #[test]
    fn roundtrip_statements() {
        stable("def f(a, b=1):\n    if a > b:\n        return a\n    elif a == b:\n        return 0\n    else:\n        return b\n");
        stable("for i, v in pairs:\n    total += v\nelse:\n    done = True\n");
        stable("while n > 0:\n    n -= 1\n");
    }

    #[test]
    fn roundtrip_comprehension_and_lambda() {
        stable("ys = [f(x) for x in xs if x > 0]\n");
        stable("g = lambda a, b: a * b + 1\n");
    }

    #[test]
    fn roundtrip_calls_slices() {
        stable("v = d['k'][1:3]\nw = xs[::2]\nu = obj.method(1, x + 2).attr\n");
    }

    #[test]
    fn ternary_parens() {
        stable("y = (1 if a else 2) + 3\n");
        stable("y = 1 if a else 2 if b else 3\n");
    }
}
