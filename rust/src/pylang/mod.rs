//! `pylang` — the Python-subset source language: lexer, parser, AST,
//! bytecode compiler, and unparser.
//!
//! This is the substrate standing in for CPython's source level: it gives us
//! source-compiled bytecode to decompile (the paper's 85-case syntax suite)
//! and the model programs that dynamo traces (the 140-model suite).

pub mod ast;
pub mod compiler;
pub mod lexer;
pub mod parser;
pub mod unparse;

pub use compiler::{compile_module, compile_module_ast, CompileError};
pub use parser::{parse, ParseError};
pub use unparse::{unparse_expr, unparse_module, unparse_stmt};
