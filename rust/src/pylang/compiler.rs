//! AST → bytecode compiler, targeting any of the four ISA versions.
//!
//! Scoping follows CPython: names assigned in a function are locals; `global`
//! / `nonlocal` declarations override; names captured by nested functions
//! become cells; free reads resolve to enclosing function scopes or fall
//! back to globals. Comprehensions are compiled inline (an accumulator list
//! kept on the stack) rather than as nested code objects — a documented
//! simplification that preserves behaviour for our subset.

use std::collections::HashSet;
use std::rc::Rc;

use super::ast::*;
use super::parser::parse;
use crate::bytecode::{CodeObject, Const, Instr, IsaVersion};

#[derive(Clone, Debug)]
pub struct CompileError {
    pub message: String,
    pub line: u32,
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "compile error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for CompileError {}

/// Compile source text to a module code object.
pub fn compile_module(src: &str, file: &str, version: IsaVersion) -> Result<Rc<CodeObject>, CompileError> {
    let module = parse(src).map_err(|e| CompileError { message: e.message, line: e.line })?;
    compile_module_ast(&module, file, version)
}

/// Compile a parsed module.
pub fn compile_module_ast(module: &Module, file: &str, version: IsaVersion) -> Result<Rc<CodeObject>, CompileError> {
    let mut ctx = FnCtx::new("<module>", version, file.to_string(), true);
    ctx.compile_body(&module.body)?;
    let c = ctx.add_const(Const::None);
    ctx.emit(Instr::LoadConst(c), 0);
    ctx.emit(Instr::ReturnValue, 0);
    Ok(Rc::new(ctx.finish(0, vec![], vec![], 1)))
}

// ---------------------------------------------------------------- analysis

/// Names assigned anywhere in `body` (order-preserving, unique), not
/// descending into nested function bodies.
fn assigned_names(body: &[Stmt], out: &mut Vec<String>) {
    fn add(out: &mut Vec<String>, n: &str) {
        if !out.iter().any(|x| x == n) {
            out.push(n.to_string());
        }
    }
    fn target(out: &mut Vec<String>, t: &Target) {
        match t {
            Target::Name(n) => add(out, n),
            Target::Tuple(ts) => ts.iter().for_each(|t| target(out, t)),
            Target::Subscript { .. } => {}
        }
    }
    fn expr(out: &mut Vec<String>, e: &Expr) {
        // Comprehension targets bind in the enclosing scope (inlined).
        match e {
            Expr::ListComp { elt, target: t, iter, conds } => {
                target(out, t);
                expr(out, elt);
                expr(out, iter);
                conds.iter().for_each(|c| expr(out, c));
            }
            Expr::BinOp(_, a, b) => {
                expr(out, a);
                expr(out, b);
            }
            Expr::UnaryOp(_, a) => expr(out, a),
            Expr::BoolOp(_, items) | Expr::List(items) | Expr::Tuple(items) => items.iter().for_each(|i| expr(out, i)),
            Expr::Dict(kvs) => kvs.iter().for_each(|(k, v)| {
                expr(out, k);
                expr(out, v);
            }),
            Expr::Compare { left, comparators, .. } => {
                expr(out, left);
                comparators.iter().for_each(|c| expr(out, c));
            }
            Expr::Call { func, args } => {
                expr(out, func);
                args.iter().for_each(|a| expr(out, a));
            }
            Expr::MethodCall { recv, args, .. } => {
                expr(out, recv);
                args.iter().for_each(|a| expr(out, a));
            }
            Expr::Attribute { value, .. } => expr(out, value),
            Expr::Subscript { value, index } => {
                expr(out, value);
                expr(out, index);
            }
            Expr::Slice { start, stop, step } => {
                [start, stop, step].iter().for_each(|o| {
                    if let Some(e) = o {
                        expr(out, e);
                    }
                });
            }
            Expr::IfExp { cond, then, orelse } => {
                expr(out, cond);
                expr(out, then);
                expr(out, orelse);
            }
            _ => {}
        }
    }
    for s in body {
        match &s.kind {
            StmtKind::Assign { target: t, value } => {
                expr(out, value);
                target(out, t);
            }
            StmtKind::AugAssign { target: t, value, .. } => {
                expr(out, value);
                target(out, t);
            }
            StmtKind::Expr(e) => expr(out, e),
            StmtKind::If { cond, then, orelse } => {
                expr(out, cond);
                assigned_names(then, out);
                assigned_names(orelse, out);
            }
            StmtKind::While { cond, body: b, orelse } => {
                expr(out, cond);
                assigned_names(b, out);
                assigned_names(orelse, out);
            }
            StmtKind::For { target: t, iter, body: b, orelse } => {
                expr(out, iter);
                target(out, t);
                assigned_names(b, out);
                assigned_names(orelse, out);
            }
            StmtKind::FuncDef { name, .. } => add(out, name),
            StmtKind::Return(Some(e)) | StmtKind::Raise(e) => expr(out, e),
            StmtKind::Assert { cond, msg } => {
                expr(out, cond);
                if let Some(m) = msg {
                    expr(out, m);
                }
            }
            _ => {}
        }
    }
}

/// Names read anywhere in `body`, not descending into nested functions.
fn read_names(body: &[Stmt], out: &mut HashSet<String>) {
    fn expr(out: &mut HashSet<String>, e: &Expr) {
        match e {
            Expr::Name(n) => {
                out.insert(n.clone());
            }
            Expr::BinOp(_, a, b) => {
                expr(out, a);
                expr(out, b);
            }
            Expr::UnaryOp(_, a) => expr(out, a),
            Expr::BoolOp(_, items) | Expr::List(items) | Expr::Tuple(items) => items.iter().for_each(|i| expr(out, i)),
            Expr::Dict(kvs) => kvs.iter().for_each(|(k, v)| {
                expr(out, k);
                expr(out, v);
            }),
            Expr::Compare { left, comparators, .. } => {
                expr(out, left);
                comparators.iter().for_each(|c| expr(out, c));
            }
            Expr::Call { func, args } => {
                expr(out, func);
                args.iter().for_each(|a| expr(out, a));
            }
            Expr::MethodCall { recv, args, .. } => {
                expr(out, recv);
                args.iter().for_each(|a| expr(out, a));
            }
            Expr::Attribute { value, .. } => expr(out, value),
            Expr::Subscript { value, index } => {
                expr(out, value);
                expr(out, index);
            }
            Expr::Slice { start, stop, step } => {
                [start, stop, step].iter().for_each(|o| {
                    if let Some(e) = o {
                        expr(out, e);
                    }
                });
            }
            Expr::IfExp { cond, then, orelse } => {
                expr(out, cond);
                expr(out, then);
                expr(out, orelse);
            }
            Expr::ListComp { elt, iter, conds, .. } => {
                expr(out, elt);
                expr(out, iter);
                conds.iter().for_each(|c| expr(out, c));
            }
            _ => {}
        }
    }
    fn target_reads(out: &mut HashSet<String>, t: &Target) {
        if let Target::Subscript { value, index } = t {
            expr(out, value);
            expr(out, index);
        } else if let Target::Tuple(ts) = t {
            ts.iter().for_each(|t| target_reads(out, t));
        }
    }
    for s in body {
        match &s.kind {
            StmtKind::Assign { target, value } => {
                expr(out, value);
                target_reads(out, target);
            }
            StmtKind::AugAssign { target, value, .. } => {
                expr(out, value);
                target_reads(out, target);
                // aug-assign also reads a Name target
                if let Target::Name(n) = target {
                    out.insert(n.clone());
                }
            }
            StmtKind::Expr(e) => expr(out, e),
            StmtKind::If { cond, then, orelse } => {
                expr(out, cond);
                read_names(then, out);
                read_names(orelse, out);
            }
            StmtKind::While { cond, body, orelse } => {
                expr(out, cond);
                read_names(body, out);
                read_names(orelse, out);
            }
            StmtKind::For { target, iter, body, orelse } => {
                expr(out, iter);
                target_reads(out, target);
                read_names(body, out);
                read_names(orelse, out);
            }
            StmtKind::Return(Some(e)) | StmtKind::Raise(e) => expr(out, e),
            StmtKind::Assert { cond, msg } => {
                expr(out, cond);
                if let Some(m) = msg {
                    expr(out, m);
                }
            }
            _ => {}
        }
    }
}

/// Direct nested functions (defs + lambdas) of `body`, not descending into
/// them.
fn nested_functions(body: &[Stmt]) -> Vec<(Vec<String>, Vec<Stmt>)> {
    let mut out = Vec::new();
    fn from_expr(out: &mut Vec<(Vec<String>, Vec<Stmt>)>, e: &Expr) {
        match e {
            Expr::Lambda { params, body } => {
                out.push((params.clone(), vec![Stmt::new(StmtKind::Return(Some((**body).clone())), 0)]));
            }
            Expr::BinOp(_, a, b) => {
                from_expr(out, a);
                from_expr(out, b);
            }
            Expr::UnaryOp(_, a) => from_expr(out, a),
            Expr::BoolOp(_, items) | Expr::List(items) | Expr::Tuple(items) => items.iter().for_each(|i| from_expr(out, i)),
            Expr::Dict(kvs) => kvs.iter().for_each(|(k, v)| {
                from_expr(out, k);
                from_expr(out, v);
            }),
            Expr::Compare { left, comparators, .. } => {
                from_expr(out, left);
                comparators.iter().for_each(|c| from_expr(out, c));
            }
            Expr::Call { func, args } => {
                from_expr(out, func);
                args.iter().for_each(|a| from_expr(out, a));
            }
            Expr::MethodCall { recv, args, .. } => {
                from_expr(out, recv);
                args.iter().for_each(|a| from_expr(out, a));
            }
            Expr::Attribute { value, .. } => from_expr(out, value),
            Expr::Subscript { value, index } => {
                from_expr(out, value);
                from_expr(out, index);
            }
            Expr::IfExp { cond, then, orelse } => {
                from_expr(out, cond);
                from_expr(out, then);
                from_expr(out, orelse);
            }
            Expr::ListComp { elt, iter, conds, .. } => {
                from_expr(out, elt);
                from_expr(out, iter);
                conds.iter().for_each(|c| from_expr(out, c));
            }
            _ => {}
        }
    }
    fn walk(out: &mut Vec<(Vec<String>, Vec<Stmt>)>, body: &[Stmt]) {
        for s in body {
            match &s.kind {
                StmtKind::FuncDef { params, body: b, .. } => {
                    out.push((params.iter().map(|p| p.name.clone()).collect(), b.clone()));
                    // Defaults evaluate in the enclosing scope.
                    for p in params {
                        if let Some(d) = &p.default {
                            from_expr(out, d);
                        }
                    }
                }
                StmtKind::Assign { value, .. } => from_expr(out, value),
                StmtKind::AugAssign { value, .. } => from_expr(out, value),
                StmtKind::Expr(e) | StmtKind::Return(Some(e)) | StmtKind::Raise(e) => from_expr(out, e),
                StmtKind::If { cond, then, orelse } => {
                    from_expr(out, cond);
                    walk(out, then);
                    walk(out, orelse);
                }
                StmtKind::While { cond, body, orelse } => {
                    from_expr(out, cond);
                    walk(out, body);
                    walk(out, orelse);
                }
                StmtKind::For { iter, body, orelse, .. } => {
                    from_expr(out, iter);
                    walk(out, body);
                    walk(out, orelse);
                }
                StmtKind::Assert { cond, msg } => {
                    from_expr(out, cond);
                    if let Some(m) = msg {
                        from_expr(out, m);
                    }
                }
                _ => {}
            }
        }
    }
    walk(&mut out, body);
    out
}

fn declared(body: &[Stmt]) -> (HashSet<String>, HashSet<String>) {
    let mut globals = HashSet::new();
    let mut nonlocals = HashSet::new();
    fn walk(body: &[Stmt], g: &mut HashSet<String>, n: &mut HashSet<String>) {
        for s in body {
            match &s.kind {
                StmtKind::Global(names) => names.iter().for_each(|x| {
                    g.insert(x.clone());
                }),
                StmtKind::Nonlocal(names) => names.iter().for_each(|x| {
                    n.insert(x.clone());
                }),
                StmtKind::If { then, orelse, .. } => {
                    walk(then, g, n);
                    walk(orelse, g, n);
                }
                StmtKind::While { body, orelse, .. } | StmtKind::For { body, orelse, .. } => {
                    walk(body, g, n);
                    walk(orelse, g, n);
                }
                _ => {}
            }
        }
    }
    walk(body, &mut globals, &mut nonlocals);
    (globals, nonlocals)
}

/// Names a function (params, body) might capture from enclosing function
/// scopes (recursively includes its nested functions' needs).
fn candidate_free(params: &[String], body: &[Stmt]) -> HashSet<String> {
    let (globals, nonlocals) = declared(body);
    let mut locals: Vec<String> = params.to_vec();
    assigned_names(body, &mut locals);
    let locals: HashSet<String> = locals.into_iter().filter(|n| !globals.contains(n) && !nonlocals.contains(n)).collect();
    let mut reads = HashSet::new();
    read_names(body, &mut reads);
    for (ps, b) in nested_functions(body) {
        reads.extend(candidate_free(&ps, &b));
    }
    reads.extend(nonlocals.iter().cloned());
    reads.retain(|n| !locals.contains(n) && !globals.contains(n));
    reads
}

// ---------------------------------------------------------------- emission

struct LoopCtx {
    header: usize, // instruction index of the loop test / FOR_ITER
    is_for: bool,
    /// Indices of emitted `Jump(PLACEHOLDER)` instrs to patch to loop end.
    break_jumps: Vec<usize>,
}

const PLACEHOLDER: u32 = u32::MAX;

struct FnCtx {
    name: String,
    version: IsaVersion,
    file: String,
    is_module: bool,
    varnames: Vec<String>,
    names: Vec<String>,
    consts: Vec<Const>,
    instrs: Vec<Instr>,
    lines: Vec<u32>,
    cur_line: u32,
    cellvars: Vec<String>,
    freevars: Vec<String>,
    locals: HashSet<String>,
    global_decls: HashSet<String>,
    nonlocal_decls: HashSet<String>,
    /// Bindings of enclosing function scopes (innermost first).
    enclosing: Vec<HashSet<String>>,
    loops: Vec<LoopCtx>,
}

impl FnCtx {
    fn new(name: &str, version: IsaVersion, file: String, is_module: bool) -> FnCtx {
        FnCtx {
            name: name.to_string(),
            version,
            file,
            is_module,
            varnames: Vec::new(),
            names: Vec::new(),
            consts: Vec::new(),
            instrs: Vec::new(),
            lines: Vec::new(),
            cur_line: 0,
            cellvars: Vec::new(),
            freevars: Vec::new(),
            locals: HashSet::new(),
            global_decls: HashSet::new(),
            nonlocal_decls: HashSet::new(),
            enclosing: Vec::new(),
            loops: Vec::new(),
        }
    }

    fn emit(&mut self, i: Instr, line: u32) -> usize {
        self.instrs.push(i);
        self.lines.push(if line == 0 { self.cur_line } else { line });
        self.instrs.len() - 1
    }

    fn here(&self) -> u32 {
        self.instrs.len() as u32
    }

    fn patch(&mut self, idx: usize, target: u32) {
        self.instrs[idx] = self.instrs[idx].with_jump_target(target);
    }

    fn add_const(&mut self, c: Const) -> u32 {
        if let Some(i) = self.consts.iter().position(|e| e.same(&c)) {
            return i as u32;
        }
        self.consts.push(c);
        (self.consts.len() - 1) as u32
    }

    fn add_name(&mut self, n: &str) -> u32 {
        if let Some(i) = self.names.iter().position(|e| e == n) {
            return i as u32;
        }
        self.names.push(n.to_string());
        (self.names.len() - 1) as u32
    }

    fn add_varname(&mut self, n: &str) -> u32 {
        if let Some(i) = self.varnames.iter().position(|e| e == n) {
            return i as u32;
        }
        self.varnames.push(n.to_string());
        (self.varnames.len() - 1) as u32
    }

    fn deref_index(&self, n: &str) -> Option<u32> {
        if let Some(i) = self.cellvars.iter().position(|e| e == n) {
            return Some(i as u32);
        }
        self.freevars.iter().position(|e| e == n).map(|i| (self.cellvars.len() + i) as u32)
    }

    fn err(&self, message: &str, line: u32) -> CompileError {
        CompileError { message: message.to_string(), line }
    }

    fn finish(mut self, argcount: usize, cellvars: Vec<String>, freevars: Vec<String>, first_line: u32) -> CodeObject {
        // Sanity: no placeholder jumps left.
        debug_assert!(!self.instrs.iter().any(|i| i.jump_target() == Some(PLACEHOLDER)), "unpatched jump in {}", self.name);
        let name = std::mem::take(&mut self.name);
        let code = CodeObject::new(
            &name,
            self.version,
            argcount,
            std::mem::take(&mut self.varnames),
            std::mem::take(&mut self.names),
            std::mem::take(&mut self.consts),
            std::mem::take(&mut self.instrs),
            std::mem::take(&mut self.lines),
        )
        .with_closure_vars(cellvars, freevars);
        code.with_source(&self.file, first_line)
    }

    // ---- name access ----

    fn load_name(&mut self, n: &str, line: u32) {
        if let Some(i) = self.deref_index(n) {
            self.emit(Instr::LoadDeref(i), line);
        } else if !self.is_module && self.locals.contains(n) {
            let i = self.add_varname(n);
            self.emit(Instr::LoadFast(i), line);
        } else {
            let i = self.add_name(n);
            self.emit(Instr::LoadGlobal(i), line);
        }
    }

    fn store_name(&mut self, n: &str, line: u32) {
        if self.global_decls.contains(n) || self.is_module {
            let i = self.add_name(n);
            self.emit(Instr::StoreGlobal(i), line);
        } else if let Some(i) = self.deref_index(n) {
            self.emit(Instr::StoreDeref(i), line);
        } else {
            let i = self.add_varname(n);
            self.emit(Instr::StoreFast(i), line);
        }
    }

    // ---- statements ----

    fn compile_body(&mut self, body: &[Stmt]) -> Result<(), CompileError> {
        for s in body {
            self.compile_stmt(s)?;
        }
        Ok(())
    }

    fn compile_stmt(&mut self, s: &Stmt) -> Result<(), CompileError> {
        self.cur_line = s.line;
        let line = s.line;
        match &s.kind {
            StmtKind::Pass | StmtKind::Global(_) | StmtKind::Nonlocal(_) => Ok(()),
            StmtKind::Expr(e) => {
                self.compile_expr(e)?;
                self.emit(Instr::PopTop, line);
                Ok(())
            }
            StmtKind::Assign { target, value } => {
                self.compile_expr(value)?;
                self.compile_store(target, line)
            }
            StmtKind::AugAssign { target, op, value } => match target {
                Target::Name(n) => {
                    self.load_name(n, line);
                    self.compile_expr(value)?;
                    self.emit(Instr::Binary(*op), line);
                    self.store_name(n, line);
                    Ok(())
                }
                Target::Subscript { value: obj, index } => {
                    // Re-evaluates obj/index (documented subset semantics).
                    self.compile_expr(obj)?;
                    self.compile_expr(index)?;
                    self.emit(Instr::BinarySubscr, line);
                    self.compile_expr(value)?;
                    self.emit(Instr::Binary(*op), line);
                    self.compile_expr(obj)?;
                    self.compile_expr(index)?;
                    self.emit(Instr::StoreSubscr, line);
                    Ok(())
                }
                Target::Tuple(_) => Err(self.err("cannot aug-assign to tuple", line)),
            },
            StmtKind::Return(v) => {
                match v {
                    Some(e) => self.compile_expr(e)?,
                    None => {
                        let c = self.add_const(Const::None);
                        self.emit(Instr::LoadConst(c), line);
                    }
                }
                self.emit(Instr::ReturnValue, line);
                Ok(())
            }
            StmtKind::If { cond, then, orelse } => {
                self.compile_expr(cond)?;
                let jf = self.emit(Instr::PopJumpIfFalse(PLACEHOLDER), line);
                self.compile_body(then)?;
                if orelse.is_empty() {
                    let t = self.here();
                    self.patch(jf, t);
                } else {
                    let jend = self.emit(Instr::Jump(PLACEHOLDER), line);
                    let t = self.here();
                    self.patch(jf, t);
                    self.compile_body(orelse)?;
                    let end = self.here();
                    self.patch(jend, end);
                }
                Ok(())
            }
            StmtKind::While { cond, body, orelse } => {
                let header = self.here() as usize;
                self.compile_expr(cond)?;
                let jf = self.emit(Instr::PopJumpIfFalse(PLACEHOLDER), line);
                self.loops.push(LoopCtx { header, is_for: false, break_jumps: Vec::new() });
                self.compile_body(body)?;
                self.emit(Instr::Jump(header as u32), line);
                let else_start = self.here();
                self.patch(jf, else_start);
                let lp = self.loops.pop().ok_or_else(|| self.err("loop context lost compiling 'while'", line))?;
                self.compile_body(orelse)?;
                let end = self.here();
                for b in lp.break_jumps {
                    self.patch(b, end);
                }
                Ok(())
            }
            StmtKind::For { target, iter, body, orelse } => {
                self.compile_expr(iter)?;
                self.emit(Instr::GetIter, line);
                let header = self.here() as usize;
                let fi = self.emit(Instr::ForIter(PLACEHOLDER), line);
                self.compile_store(target, line)?;
                self.loops.push(LoopCtx { header, is_for: true, break_jumps: Vec::new() });
                self.compile_body(body)?;
                self.emit(Instr::Jump(header as u32), line);
                let else_start = self.here();
                self.patch(fi, else_start);
                let lp = self.loops.pop().ok_or_else(|| self.err("loop context lost compiling 'for'", line))?;
                self.compile_body(orelse)?;
                let end = self.here();
                for b in lp.break_jumps {
                    self.patch(b, end);
                }
                Ok(())
            }
            StmtKind::Break => {
                let is_for = match self.loops.last() {
                    Some(lp) => lp.is_for,
                    None => return Err(self.err("'break' outside loop", line)),
                };
                if is_for {
                    // Discard the loop iterator.
                    self.emit(Instr::PopTop, line);
                }
                let j = self.emit(Instr::Jump(PLACEHOLDER), line);
                if let Some(lp) = self.loops.last_mut() {
                    lp.break_jumps.push(j);
                }
                Ok(())
            }
            StmtKind::Continue => {
                let header = self.loops.last().ok_or_else(|| self.err("'continue' outside loop", line))?.header;
                self.emit(Instr::Jump(header as u32), line);
                Ok(())
            }
            StmtKind::Assert { cond, msg } => {
                self.compile_expr(cond)?;
                let jt = self.emit(Instr::PopJumpIfTrue(PLACEHOLDER), line);
                match msg {
                    Some(m) => self.compile_expr(m)?,
                    None => {
                        let c = self.add_const(Const::Str("AssertionError".into()));
                        self.emit(Instr::LoadConst(c), line);
                    }
                }
                self.emit(Instr::Raise, line);
                let t = self.here();
                self.patch(jt, t);
                Ok(())
            }
            StmtKind::Raise(e) => {
                self.compile_expr(e)?;
                self.emit(Instr::Raise, line);
                Ok(())
            }
            StmtKind::FuncDef { name, params, body } => {
                self.compile_function_object(name, params, body, line)?;
                self.store_name(name, line);
                Ok(())
            }
        }
    }

    /// Emit code leaving a new function object on the stack.
    fn compile_function_object(&mut self, name: &str, params: &[Param], body: &[Stmt], line: u32) -> Result<(), CompileError> {
        let param_names: Vec<String> = params.iter().map(|p| p.name.clone()).collect();

        // Child scope analysis.
        let (child_globals, child_nonlocals) = declared(body);
        let mut child_locals_v: Vec<String> = param_names.clone();
        assigned_names(body, &mut child_locals_v);
        let child_locals: HashSet<String> =
            child_locals_v.iter().filter(|n| !child_globals.contains(*n) && !child_nonlocals.contains(*n)).cloned().collect();

        // Which enclosing bindings can the child capture?
        let mut enclosing_for_child: Vec<HashSet<String>> = Vec::new();
        if !self.is_module {
            let mut mine: HashSet<String> = self.locals.clone();
            mine.extend(self.cellvars.iter().cloned());
            mine.extend(self.freevars.iter().cloned());
            enclosing_for_child.push(mine);
            enclosing_for_child.extend(self.enclosing.iter().cloned());
        }

        let cand = candidate_free(&param_names, body);
        let mut child_freevars: Vec<String> = cand
            .iter()
            .filter(|n| enclosing_for_child.iter().any(|b| b.contains(*n)))
            .cloned()
            .collect();
        child_freevars.sort();

        // Child's own cellvars: locals captured by ITS nested functions.
        let mut grandchild_cand: HashSet<String> = HashSet::new();
        for (ps, b) in nested_functions(body) {
            grandchild_cand.extend(candidate_free(&ps, &b));
        }
        let mut child_cellvars: Vec<String> = child_locals.iter().filter(|n| grandchild_cand.contains(*n)).cloned().collect();
        child_cellvars.sort();

        // Compile the child.
        let mut child = FnCtx::new(name, self.version, self.file.clone(), false);
        child.locals = child_locals;
        child.global_decls = child_globals;
        child.nonlocal_decls = child_nonlocals;
        child.cellvars = child_cellvars.clone();
        child.freevars = child_freevars.clone();
        child.enclosing = enclosing_for_child;
        for p in &param_names {
            child.add_varname(p);
        }
        child.compile_body(body)?;
        // Implicit `return None`.
        let c = child.add_const(Const::None);
        child.emit(Instr::LoadConst(c), 0);
        child.emit(Instr::ReturnValue, 0);
        let code = Rc::new(child.finish(param_names.len(), child_cellvars, child_freevars.clone(), line));

        // Defaults tuple.
        let mut flags = 0u32;
        let n_defaults = params.iter().filter(|p| p.default.is_some()).count();
        if n_defaults > 0 {
            // Defaults must be trailing.
            let first_default = params.iter().position(|p| p.default.is_some()).unwrap();
            if params[first_default..].iter().any(|p| p.default.is_none()) {
                return Err(self.err("non-default argument follows default argument", line));
            }
            for p in &params[first_default..] {
                self.compile_expr(p.default.as_ref().unwrap())?;
            }
            self.emit(Instr::BuildTuple(n_defaults as u32), line);
            flags |= 1;
        }
        // Closure tuple.
        if !child_freevars.is_empty() {
            for fv in &child_freevars {
                let idx = self
                    .deref_index(fv)
                    .ok_or_else(|| self.err(&format!("cannot capture '{}': not a cell in enclosing scope", fv), line))?;
                self.emit(Instr::LoadClosure(idx), line);
            }
            self.emit(Instr::BuildTuple(child_freevars.len() as u32), line);
            flags |= 2;
        }
        let ci = self.add_const(Const::Code(code));
        self.emit(Instr::LoadConst(ci), line);
        self.emit(Instr::MakeFunction(flags), line);
        Ok(())
    }

    fn compile_store(&mut self, target: &Target, line: u32) -> Result<(), CompileError> {
        match target {
            Target::Name(n) => {
                self.store_name(n, line);
                Ok(())
            }
            Target::Tuple(ts) => {
                self.emit(Instr::UnpackSequence(ts.len() as u32), line);
                for t in ts {
                    self.compile_store(t, line)?;
                }
                Ok(())
            }
            Target::Subscript { value, index } => {
                // stack: [val]; push obj, key; STORE_SUBSCR pops all three.
                self.compile_expr(value)?;
                self.compile_expr(index)?;
                self.emit(Instr::StoreSubscr, line);
                Ok(())
            }
        }
    }

    // ---- expressions ----

    fn compile_expr(&mut self, e: &Expr) -> Result<(), CompileError> {
        let line = self.cur_line;
        match e {
            Expr::NoneLit => {
                let c = self.add_const(Const::None);
                self.emit(Instr::LoadConst(c), line);
            }
            Expr::Bool(b) => {
                let c = self.add_const(Const::Bool(*b));
                self.emit(Instr::LoadConst(c), line);
            }
            Expr::Int(i) => {
                let c = self.add_const(Const::Int(*i));
                self.emit(Instr::LoadConst(c), line);
            }
            Expr::Float(f) => {
                let c = self.add_const(Const::Float(*f));
                self.emit(Instr::LoadConst(c), line);
            }
            Expr::Str(s) => {
                let c = self.add_const(Const::Str(s.clone()));
                self.emit(Instr::LoadConst(c), line);
            }
            Expr::Name(n) => self.load_name(n, line),
            Expr::List(items) => {
                for i in items {
                    self.compile_expr(i)?;
                }
                self.emit(Instr::BuildList(items.len() as u32), line);
            }
            Expr::Tuple(items) => {
                for i in items {
                    self.compile_expr(i)?;
                }
                self.emit(Instr::BuildTuple(items.len() as u32), line);
            }
            Expr::Dict(kvs) => {
                for (k, v) in kvs {
                    self.compile_expr(k)?;
                    self.compile_expr(v)?;
                }
                self.emit(Instr::BuildMap(kvs.len() as u32), line);
            }
            Expr::BinOp(op, a, b) => {
                self.compile_expr(a)?;
                self.compile_expr(b)?;
                self.emit(Instr::Binary(*op), line);
            }
            Expr::UnaryOp(op, a) => {
                self.compile_expr(a)?;
                self.emit(Instr::Unary(*op), line);
            }
            Expr::BoolOp(kind, items) => {
                // a and b and c: JUMP_IF_FALSE_OR_POP chains to the end.
                let mut jumps = Vec::new();
                for (i, item) in items.iter().enumerate() {
                    self.compile_expr(item)?;
                    if i + 1 < items.len() {
                        let j = match kind {
                            BoolOpKind::And => self.emit(Instr::JumpIfFalseOrPop(PLACEHOLDER), line),
                            BoolOpKind::Or => self.emit(Instr::JumpIfTrueOrPop(PLACEHOLDER), line),
                        };
                        jumps.push(j);
                    }
                }
                let end = self.here();
                for j in jumps {
                    self.patch(j, end);
                }
            }
            Expr::Compare { left, ops, comparators } => {
                if ops.len() == 1 {
                    self.compile_expr(left)?;
                    self.compile_expr(&comparators[0])?;
                    self.emit_compare(&ops[0], line);
                } else {
                    // Chained: a < b <= c  =>  evaluate pairwise with DUP/ROT,
                    // exactly like CPython.
                    self.compile_expr(left)?;
                    let mut false_jumps = Vec::new();
                    for (i, (op, comp)) in ops.iter().zip(comparators.iter()).enumerate() {
                        let last = i + 1 == ops.len();
                        self.compile_expr(comp)?;
                        if !last {
                            self.emit(Instr::DupTop, line);
                            self.emit(Instr::RotThree, line);
                            // stack now: [next, prev, next]; compare pops two
                        }
                        // For the non-last case the stack is [next, prev, next];
                        // Compare consumes [prev, next].
                        self.emit_compare(op, line);
                        if !last {
                            let j = self.emit(Instr::JumpIfFalseOrPop(PLACEHOLDER), line);
                            false_jumps.push(j);
                        }
                    }
                    if !false_jumps.is_empty() {
                        let jend = self.emit(Instr::Jump(PLACEHOLDER), line);
                        let cleanup = self.here();
                        for j in false_jumps {
                            self.patch(j, cleanup);
                        }
                        // On short-circuit the leftover `next` sits under the
                        // False result: [next, False] -> swap & pop.
                        self.emit(Instr::RotTwo, line);
                        self.emit(Instr::PopTop, line);
                        let end = self.here();
                        self.patch(jend, end);
                    }
                }
            }
            Expr::Call { func, args } => {
                self.compile_expr(func)?;
                for a in args {
                    self.compile_expr(a)?;
                }
                self.emit(Instr::Call(args.len() as u32), line);
            }
            Expr::MethodCall { recv, name, args } => {
                self.compile_expr(recv)?;
                let ni = self.add_name(name);
                self.emit(Instr::LoadMethod(ni), line);
                for a in args {
                    self.compile_expr(a)?;
                }
                self.emit(Instr::CallMethod(args.len() as u32), line);
            }
            Expr::Attribute { value, name } => {
                self.compile_expr(value)?;
                let ni = self.add_name(name);
                self.emit(Instr::LoadAttr(ni), line);
            }
            Expr::Subscript { value, index } => {
                self.compile_expr(value)?;
                self.compile_expr(index)?;
                self.emit(Instr::BinarySubscr, line);
            }
            Expr::Slice { start, stop, step } => {
                let parts: [&Option<Box<Expr>>; 3] = [start, stop, step];
                let n = if step.is_some() { 3 } else { 2 };
                for p in parts.iter().take(n) {
                    match p {
                        Some(e) => self.compile_expr(e)?,
                        None => {
                            let c = self.add_const(Const::None);
                            self.emit(Instr::LoadConst(c), line);
                        }
                    }
                }
                self.emit(Instr::BuildSlice(n as u32), line);
            }
            Expr::IfExp { cond, then, orelse } => {
                self.compile_expr(cond)?;
                let jf = self.emit(Instr::PopJumpIfFalse(PLACEHOLDER), line);
                self.compile_expr(then)?;
                let jend = self.emit(Instr::Jump(PLACEHOLDER), line);
                let t = self.here();
                self.patch(jf, t);
                self.compile_expr(orelse)?;
                let end = self.here();
                self.patch(jend, end);
            }
            Expr::Lambda { params, body } => {
                let ps: Vec<Param> = params.iter().map(|p| Param { name: p.clone(), default: None }).collect();
                let body_stmts = vec![Stmt::new(StmtKind::Return(Some((**body).clone())), line)];
                self.compile_function_object("<lambda>", &ps, &body_stmts, line)?;
            }
            Expr::ListComp { elt, target, iter, conds } => {
                // Inline: [], iter on stack; loop appends.
                self.emit(Instr::BuildList(0), line);
                self.compile_expr(iter)?;
                self.emit(Instr::GetIter, line);
                let header = self.here();
                let fi = self.emit(Instr::ForIter(PLACEHOLDER), line);
                self.compile_store(target, line)?;
                for c in conds {
                    self.compile_expr(c)?;
                    self.emit(Instr::PopJumpIfFalse(header), line);
                }
                self.compile_expr(elt)?;
                self.emit(Instr::ListAppend(2), line);
                self.emit(Instr::Jump(header), line);
                let end = self.here();
                self.patch(fi, end);
            }
        }
        Ok(())
    }

    fn emit_compare(&mut self, op: &CompareKind, line: u32) {
        match op {
            CompareKind::Cmp(c) => {
                self.emit(Instr::Compare(*c), line);
            }
            CompareKind::In => {
                self.emit(Instr::ContainsOp(false), line);
            }
            CompareKind::NotIn => {
                self.emit(Instr::ContainsOp(true), line);
            }
            CompareKind::Is => {
                self.emit(Instr::IsOp(false), line);
            }
            CompareKind::IsNot => {
                self.emit(Instr::IsOp(true), line);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::decode;

    fn compile(src: &str) -> Rc<CodeObject> {
        compile_module(src, "<test>", IsaVersion::V310).unwrap_or_else(|e| panic!("{}\n{}", e, src))
    }

    #[test]
    fn module_compiles_and_encodes() {
        let code = compile("x = 1\ny = x + 2\n");
        assert!(code.instrs.len() >= 6);
        // raw round-trips through the canonical decoder
        let back = decode(&code.raw, code.version).unwrap();
        assert_eq!(back, code.instrs);
    }

    #[test]
    fn function_scoping() {
        let code = compile("def f(a):\n    b = a + 1\n    return b\n");
        let inner = code.nested_codes();
        assert_eq!(inner.len(), 1);
        let f = &inner[0];
        assert_eq!(f.argcount, 1);
        assert_eq!(f.varnames, vec!["a".to_string(), "b".to_string()]);
        // all accesses are LoadFast/StoreFast
        assert!(f.instrs.iter().any(|i| matches!(i, Instr::LoadFast(_))));
        assert!(!f.instrs.iter().any(|i| matches!(i, Instr::LoadGlobal(_))));
    }

    #[test]
    fn global_read_in_function() {
        let code = compile("g = 1\ndef f():\n    return g\n");
        let f = &code.nested_codes()[0];
        assert!(f.instrs.iter().any(|i| matches!(i, Instr::LoadGlobal(_))));
    }

    #[test]
    fn closure_cells() {
        let code = compile("def outer():\n    x = 1\n    def inner():\n        return x\n    return inner\n");
        let outer = &code.nested_codes()[0];
        assert_eq!(outer.cellvars, vec!["x".to_string()]);
        let inner = &outer.nested_codes()[0];
        assert_eq!(inner.freevars, vec!["x".to_string()]);
        assert!(inner.instrs.iter().any(|i| matches!(i, Instr::LoadDeref(_))));
        assert!(outer.instrs.iter().any(|i| matches!(i, Instr::LoadClosure(_))));
    }

    #[test]
    fn nonlocal_write() {
        let code = compile(
            "def outer():\n    x = 0\n    def bump():\n        nonlocal x\n        x = x + 1\n    bump()\n    return x\n",
        );
        let outer = &code.nested_codes()[0];
        assert_eq!(outer.cellvars, vec!["x".to_string()]);
        let bump = &outer.nested_codes()[0];
        assert!(bump.instrs.iter().any(|i| matches!(i, Instr::StoreDeref(_))));
    }

    #[test]
    fn loops_compile() {
        let code = compile("total = 0\nfor i in range(10):\n    if i == 3:\n        continue\n    if i == 7:\n        break\n    total += i\n");
        assert!(code.instrs.iter().any(|i| matches!(i, Instr::ForIter(_))));
        let back = decode(&code.raw, code.version).unwrap();
        assert_eq!(back, code.instrs);
    }

    #[test]
    fn comprehension_inline() {
        let code = compile("ys = [x * 2 for x in range(5) if x > 1]\n");
        assert!(code.instrs.iter().any(|i| matches!(i, Instr::ListAppend(2))));
    }

    #[test]
    fn all_versions_compile() {
        for v in IsaVersion::ALL {
            let code = compile_module("def f(x):\n    return x + 1\nr = f(1)\n", "<t>", v).unwrap();
            let back = decode(&code.raw, v).unwrap();
            assert_eq!(back, code.instrs, "version {}", v);
            let f = &code.nested_codes()[0];
            let back_f = decode(&f.raw, v).unwrap();
            assert_eq!(back_f, f.instrs, "version {}", v);
        }
    }

    #[test]
    fn default_arg_order_enforced() {
        assert!(compile_module("def f(a=1, b):\n    return a\n", "<t>", IsaVersion::V310).is_err());
    }

    #[test]
    fn break_outside_loop_rejected() {
        let e = compile_module("break\n", "<t>", IsaVersion::V310).unwrap_err();
        assert!(e.to_string().contains("'break' outside loop"), "{}", e);
        assert_eq!(e.line, 1);
    }

    #[test]
    fn continue_outside_loop_rejected_at_module_scope() {
        let e = compile_module("x = 1\ncontinue\n", "<t>", IsaVersion::V310).unwrap_err();
        assert!(e.to_string().contains("'continue' outside loop"), "{}", e);
        assert_eq!(e.line, 2);
    }

    #[test]
    fn break_outside_loop_rejected_at_function_scope() {
        let e = compile_module("def f(x):\n    break\n    return x\n", "<t>", IsaVersion::V310).unwrap_err();
        assert!(e.to_string().contains("'break' outside loop"), "{}", e);
        assert_eq!(e.line, 2);
    }

    #[test]
    fn continue_outside_loop_rejected_at_function_scope() {
        let e = compile_module("def f(x):\n    continue\n", "<t>", IsaVersion::V310).unwrap_err();
        assert!(e.to_string().contains("'continue' outside loop"), "{}", e);
        assert_eq!(e.line, 2);
    }

    #[test]
    fn break_in_function_defined_inside_loop_rejected() {
        // The enclosing `for` must NOT leak a loop context into the nested
        // function body — `break` there is still outside any loop.
        let src = "for i in range(3):\n    def f():\n        break\n";
        let e = compile_module(src, "<t>", IsaVersion::V310).unwrap_err();
        assert!(e.to_string().contains("'break' outside loop"), "{}", e);
        assert_eq!(e.line, 3);
    }

    #[test]
    fn break_and_continue_inside_loops_still_compile() {
        compile("while True:\n    break\n");
        compile("def f():\n    for i in range(4):\n        if i == 1:\n            continue\n        if i == 2:\n            break\n    return i\n");
    }
}
