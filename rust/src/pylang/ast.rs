//! Abstract syntax tree for the `pylang` Python subset.
//!
//! The same AST is produced by the parser (source → AST) and by the
//! decompiler (bytecode → AST), which then renders it back to source via
//! [`super::unparse`].

use crate::bytecode::{BinOp, CmpOp, UnOp};

/// One link of a (possibly chained) comparison.
#[derive(Clone, Debug, PartialEq)]
pub enum CompareKind {
    Cmp(CmpOp),
    In,
    NotIn,
    Is,
    IsNot,
}

impl CompareKind {
    pub fn symbol(&self) -> &'static str {
        match self {
            CompareKind::Cmp(c) => c.symbol(),
            CompareKind::In => "in",
            CompareKind::NotIn => "not in",
            CompareKind::Is => "is",
            CompareKind::IsNot => "is not",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoolOpKind {
    And,
    Or,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    NoneLit,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Name(String),
    List(Vec<Expr>),
    Tuple(Vec<Expr>),
    Dict(Vec<(Expr, Expr)>),
    BinOp(BinOp, Box<Expr>, Box<Expr>),
    UnaryOp(UnOp, Box<Expr>),
    /// `a and b and c` / `a or b` (short-circuit, value-producing).
    BoolOp(BoolOpKind, Vec<Expr>),
    /// `a < b <= c`: left, then op/comparator pairs.
    Compare { left: Box<Expr>, ops: Vec<CompareKind>, comparators: Vec<Expr> },
    Call { func: Box<Expr>, args: Vec<Expr> },
    /// `recv.name(args)` — kept distinct from Call(Attribute) because the
    /// bytecode uses LOAD_METHOD / CALL_METHOD.
    MethodCall { recv: Box<Expr>, name: String, args: Vec<Expr> },
    Attribute { value: Box<Expr>, name: String },
    Subscript { value: Box<Expr>, index: Box<Expr> },
    /// Only valid directly under `Subscript.index`.
    Slice { start: Option<Box<Expr>>, stop: Option<Box<Expr>>, step: Option<Box<Expr>> },
    IfExp { cond: Box<Expr>, then: Box<Expr>, orelse: Box<Expr> },
    Lambda { params: Vec<String>, body: Box<Expr> },
    /// Single-`for` list comprehension `[elt for var in iter if cond...]`.
    ListComp { elt: Box<Expr>, target: Box<Target>, iter: Box<Expr>, conds: Vec<Expr> },
}

/// Assignment targets.
#[derive(Clone, Debug, PartialEq)]
pub enum Target {
    Name(String),
    Tuple(Vec<Target>),
    Subscript { value: Expr, index: Expr },
}

/// A function parameter (with optional default).
#[derive(Clone, Debug, PartialEq)]
pub struct Param {
    pub name: String,
    pub default: Option<Expr>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Stmt {
    pub kind: StmtKind,
    pub line: u32,
}

impl Stmt {
    pub fn new(kind: StmtKind, line: u32) -> Stmt {
        Stmt { kind, line }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum StmtKind {
    Expr(Expr),
    Assign { target: Target, value: Expr },
    AugAssign { target: Target, op: BinOp, value: Expr },
    If { cond: Expr, then: Vec<Stmt>, orelse: Vec<Stmt> },
    While { cond: Expr, body: Vec<Stmt>, orelse: Vec<Stmt> },
    For { target: Target, iter: Expr, body: Vec<Stmt>, orelse: Vec<Stmt> },
    FuncDef { name: String, params: Vec<Param>, body: Vec<Stmt> },
    Return(Option<Expr>),
    Break,
    Continue,
    Pass,
    Global(Vec<String>),
    Nonlocal(Vec<String>),
    Assert { cond: Expr, msg: Option<Expr> },
    Raise(Expr),
}

/// A parsed module (top-level statement list).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Module {
    pub body: Vec<Stmt>,
}

impl Expr {
    /// Is this a constant literal?
    pub fn is_const(&self) -> bool {
        matches!(self, Expr::NoneLit | Expr::Bool(_) | Expr::Int(_) | Expr::Float(_) | Expr::Str(_))
    }
}
