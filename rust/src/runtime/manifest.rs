//! The artifact manifest written by `python/compile/aot.py`.
//!
//! Plain-text line format (no serde_json in the offline environment):
//!
//! ```text
//! # name file n_outputs in=<shape>;<shape>... out=<shape>;...
//! attention attention.hlo.txt 1 in=4,8,64;4,8,64;4,8,64 out=4,8,64
//! ```
//!
//! Shapes are comma-separated dims; scalar = empty string.

use std::collections::HashMap;
use std::path::Path;

use crate::api::DepyfError;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub n_outputs: usize,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, Artifact>,
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>, DepyfError> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(';')
        .map(|shape| {
            if shape.is_empty() || shape == "scalar" {
                return Ok(vec![]);
            }
            shape
                .split(',')
                .map(|d| {
                    d.parse::<usize>().map_err(|e| DepyfError::Parse(format!("bad dim '{}': {}", d, e)))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, DepyfError> {
        let mut entries = HashMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                return Err(DepyfError::Parse(format!(
                    "manifest line {}: expected 5 fields, got {}",
                    lineno + 1,
                    parts.len()
                )));
            }
            let n_outputs: usize = parts[2]
                .parse()
                .map_err(|e| DepyfError::Parse(format!("manifest line {}: {}", lineno + 1, e)))?;
            let ins = parts[3]
                .strip_prefix("in=")
                .ok_or_else(|| DepyfError::Parse(format!("manifest line {}: missing in=", lineno + 1)))?;
            let outs = parts[4]
                .strip_prefix("out=")
                .ok_or_else(|| DepyfError::Parse(format!("manifest line {}: missing out=", lineno + 1)))?;
            let art = Artifact {
                name: parts[0].to_string(),
                file: parts[1].to_string(),
                n_outputs,
                input_shapes: parse_shapes(ins)?,
                output_shapes: parse_shapes(outs)?,
            };
            entries.insert(art.name.clone(), art);
        }
        Ok(Manifest { entries })
    }

    pub fn load(path: &Path) -> Result<Manifest, DepyfError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| DepyfError::io(format!("read {}", path.display()), e))?;
        Manifest::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.entries.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let text = "# comment\nattention attention.hlo.txt 1 in=4,8,64;4,8,64;4,8,64 out=4,8,64\nloss loss.hlo.txt 2 in=8,16 out=;8,16\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.len(), 2);
        let a = m.get("attention").unwrap();
        assert_eq!(a.input_shapes.len(), 3);
        assert_eq!(a.input_shapes[0], vec![4, 8, 64]);
        let l = m.get("loss").unwrap();
        assert_eq!(l.output_shapes[0], Vec::<usize>::new()); // scalar
        assert_eq!(l.output_shapes[1], vec![8, 16]);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Manifest::parse("too few fields\n").is_err());
        assert!(Manifest::parse("a b notanum in= out=\n").is_err());
    }
}
