//! PJRT runtime: loads HLO **text** (AOT artifacts from `python/compile/`,
//! or codegen output from `backend::xla`), compiles it on the CPU PJRT
//! client, and executes with [`Tensor`] inputs. Python never runs here —
//! this is the request path.
//!
//! Caching is two-level. In-process, compiled executables are memoized by
//! key (the XLA backend keys on [`crate::graph::Graph::content_hash`], so
//! identical graphs compile once per process no matter how many sessions
//! produce them — [`Runtime::shared`] is the process-wide handle the CLI
//! uses). On disk, an optional [`DiskCache`] persists an HLO→artifact
//! index so a repeated run skips graph lowering and reuses the exact HLO
//! text across processes.

mod manifest;

pub use manifest::{Artifact, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::api::DepyfError;
use crate::tensor::Tensor;

/// Environment variable overriding the CLI's persistent HLO cache
/// directory (default `.depyf_cache` under the working directory).
pub const CACHE_DIR_ENV: &str = "DEPYF_CACHE_DIR";

/// A persistent HLO→artifact cache: `index.txt` maps cache keys to
/// `n_outputs` and an `.hlo` text file in the same directory. Appends are
/// line-atomic, so sequential CLI invocations share one index.
pub struct DiskCache {
    dir: PathBuf,
    index: RefCell<HashMap<String, (usize, String)>>,
}

impl DiskCache {
    const INDEX: &'static str = "index.txt";

    /// Open (creating if needed) a cache directory and load its index.
    pub fn open(dir: impl AsRef<Path>) -> Result<DiskCache, DepyfError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(|e| DepyfError::io(format!("mkdir {}", dir.display()), e))?;
        let mut index = HashMap::new();
        let path = dir.join(Self::INDEX);
        if let Ok(text) = std::fs::read_to_string(&path) {
            for line in text.lines() {
                let mut parts = line.splitn(3, '\t');
                if let (Some(key), Some(n), Some(file)) = (parts.next(), parts.next(), parts.next()) {
                    if let Ok(n) = n.parse::<usize>() {
                        index.insert(key.to_string(), (n, file.to_string()));
                    }
                }
            }
        }
        Ok(DiskCache { dir, index: RefCell::new(index) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of indexed entries.
    pub fn len(&self) -> usize {
        self.index.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.borrow().is_empty()
    }

    /// Look up the HLO text + output arity persisted under `key`.
    pub fn get(&self, key: &str) -> Option<(String, usize)> {
        let (n, file) = self.index.borrow().get(key).cloned()?;
        let text = std::fs::read_to_string(self.dir.join(&file)).ok()?;
        Some((text, n))
    }

    /// Persist HLO text under `key`, overwriting any existing entry — a
    /// stale/corrupt record (e.g. a bad `n_outputs`) is repaired the next
    /// time the key is re-lowered instead of poisoning the cache forever.
    /// Best-effort: IO failures leave the cache cold but never fail a
    /// compile.
    pub fn put(&self, key: &str, text: &str, n_outputs: usize) {
        // File name = sanitized key + FNV of the *raw* key: two distinct
        // keys that sanitize identically (`a:b` vs `a_b`) cannot clobber
        // each other's .hlo file.
        let file = format!("{}-{:016x}.hlo", sanitize_key(key), crate::fnv::hash_str(key));
        if std::fs::write(self.dir.join(&file), text).is_err() {
            return;
        }
        use std::io::Write as _;
        let line = format!("{}\t{}\t{}\n", key, n_outputs, file);
        let appended = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.dir.join(Self::INDEX))
            .and_then(|mut f| f.write_all(line.as_bytes()));
        if appended.is_ok() {
            self.index.borrow_mut().insert(key.to_string(), (n_outputs, file));
        }
    }
}

fn sanitize_key(k: &str) -> String {
    k.chars().map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '-' { c } else { '_' }).collect()
}

/// An execution input: f32 data, or f32-held integers to be passed as s32.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a Tensor),
}

impl<'a> Arg<'a> {
    fn tensor(&self) -> &'a Tensor {
        match self {
            Arg::F32(t) | Arg::I32(t) => t,
        }
    }
}

/// A compiled executable plus its output arity metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// HLO modules lowered from jax with `return_tuple=True` produce a
    /// 1-level output tuple; our own codegen does the same.
    pub n_outputs: usize,
}

/// The PJRT runtime wrapper.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Where `make artifacts` put the AOT outputs.
    pub artifacts_dir: Option<PathBuf>,
    manifest: Option<Manifest>,
    /// Optional persistent HLO cache consulted by the XLA backend.
    disk: Option<DiskCache>,
    /// Compile + execute counters.
    pub compiles: std::cell::Cell<u64>,
    pub executions: std::cell::Cell<u64>,
    /// HLO texts served from the persistent cache (lowering skipped).
    pub disk_hits: std::cell::Cell<u64>,
}

thread_local! {
    /// The process-wide runtime handle (the stack is single-threaded and
    /// `Rc`-based): every CLI command and any session asking for
    /// [`Runtime::shared`] gets the same client and executable cache.
    static SHARED: RefCell<Option<Rc<Runtime>>> = const { RefCell::new(None) };
}

impl Runtime {
    fn new_with(
        artifacts_dir: Option<PathBuf>,
        manifest: Option<Manifest>,
        disk: Option<DiskCache>,
    ) -> Result<Rc<Runtime>, DepyfError> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| DepyfError::Runtime(format!("PjRtClient::cpu: {}", e)))?;
        Ok(Rc::new(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            artifacts_dir,
            manifest,
            disk,
            compiles: std::cell::Cell::new(0),
            executions: std::cell::Cell::new(0),
            disk_hits: std::cell::Cell::new(0),
        }))
    }

    /// CPU PJRT client. Fails if libxla_extension is unavailable.
    pub fn cpu() -> Result<Rc<Runtime>, DepyfError> {
        Runtime::new_with(None, None, None)
    }

    /// CPU client with an artifact directory (containing `manifest.txt`).
    pub fn cpu_with_artifacts(dir: impl AsRef<Path>) -> Result<Rc<Runtime>, DepyfError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        Runtime::new_with(Some(dir), Some(manifest), None)
    }

    /// CPU client with a persistent HLO disk cache at `dir`.
    pub fn cpu_with_disk_cache(dir: impl AsRef<Path>) -> Result<Rc<Runtime>, DepyfError> {
        Runtime::new_with(None, None, Some(DiskCache::open(dir)?))
    }

    /// The process-wide shared runtime: one PJRT client + executable cache
    /// for the whole process, with a persistent disk cache at
    /// `$DEPYF_CACHE_DIR` (default `.depyf_cache`). Repeated `depyf dump`
    /// invocations share the persisted index; repeated loads of identical
    /// HLO within a process compile exactly once.
    pub fn shared() -> Result<Rc<Runtime>, DepyfError> {
        SHARED.with(|s| {
            if let Some(rt) = s.borrow().as_ref() {
                return Ok(Rc::clone(rt));
            }
            let dir = std::env::var(CACHE_DIR_ENV).unwrap_or_else(|_| ".depyf_cache".into());
            // A broken cache dir must not take down the runtime.
            let disk = DiskCache::open(&dir).ok();
            let rt = Runtime::new_with(None, None, disk)?;
            *s.borrow_mut() = Some(Rc::clone(&rt));
            Ok(rt)
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// The persistent HLO cache, if this runtime has one.
    pub fn disk_cache(&self) -> Option<&DiskCache> {
        self.disk.as_ref()
    }

    /// In-process executable cache lookup (no compile).
    pub fn cached_executable(&self, key: &str) -> Option<Rc<Executable>> {
        self.cache.borrow().get(key).map(Rc::clone)
    }

    /// Persistent-cache lookup of HLO text + output arity; bumps
    /// `disk_hits` so "lowering skipped" is observable.
    pub fn cached_hlo(&self, key: &str) -> Option<(String, usize)> {
        let hit = self.disk.as_ref()?.get(key)?;
        self.disk_hits.set(self.disk_hits.get() + 1);
        Some(hit)
    }

    /// Persist HLO text for `key` (no-op without a disk cache).
    pub fn store_hlo(&self, key: &str, text: &str, n_outputs: usize) {
        if let Some(d) = &self.disk {
            d.put(key, text, n_outputs);
        }
    }

    /// Compile HLO text under a cache key.
    pub fn compile_hlo_text(
        &self,
        key: &str,
        text: &str,
        n_outputs: usize,
    ) -> Result<Rc<Executable>, DepyfError> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(Rc::clone(e));
        }
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(text.as_bytes())
            .map_err(|e| DepyfError::Parse(format!("HLO parse failed for '{}': {}", key, e)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| DepyfError::Runtime(format!("PJRT compile failed for '{}': {}", key, e)))?;
        self.compiles.set(self.compiles.get() + 1);
        let exec = Rc::new(Executable { exe, n_outputs });
        self.cache.borrow_mut().insert(key.to_string(), Rc::clone(&exec));
        Ok(exec)
    }

    /// Load + compile a named artifact from the manifest.
    pub fn load_artifact(&self, name: &str) -> Result<(Rc<Executable>, Artifact), DepyfError> {
        let m = self
            .manifest
            .as_ref()
            .ok_or_else(|| DepyfError::Runtime("runtime has no artifact manifest".into()))?;
        let art = m
            .get(name)
            .ok_or_else(|| DepyfError::Runtime(format!("artifact '{}' not in manifest", name)))?
            .clone();
        let dir = self
            .artifacts_dir
            .as_ref()
            .ok_or_else(|| DepyfError::Runtime("runtime has no artifacts dir".into()))?;
        let path = dir.join(&art.file);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| DepyfError::io(format!("read {}", path.display()), e))?;
        let exe = self.compile_hlo_text(name, &text, art.n_outputs)?;
        Ok((exe, art))
    }

    /// Execute with f32 tensor inputs; outputs are unpacked from the
    /// 1-level output tuple.
    pub fn execute(&self, exe: &Executable, inputs: &[&Tensor]) -> Result<Vec<Tensor>, DepyfError> {
        let args: Vec<Arg> = inputs.iter().map(|t| Arg::F32(t)).collect();
        self.execute_args(exe, &args)
    }

    /// Execute with mixed f32/i32 inputs (token ids are s32 in the jax
    /// artifacts; `Arg::I32` casts the f32-held values).
    pub fn execute_args(&self, exe: &Executable, inputs: &[Arg]) -> Result<Vec<Tensor>, DepyfError> {
        let rt_err = |what: &str, e: &dyn std::fmt::Display| DepyfError::Runtime(format!("{}: {}", what, e));
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| {
                let t = a.tensor();
                let flat = match a {
                    Arg::F32(_) => xla::Literal::vec1(t.data()),
                    Arg::I32(_) => {
                        let ints: Vec<i32> = t.data().iter().map(|&v| v as i32).collect();
                        xla::Literal::vec1(&ints)
                    }
                };
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                flat.reshape(&dims).map_err(|e| rt_err("literal reshape", &e))
            })
            .collect::<Result<_, DepyfError>>()?;
        let result =
            exe.exe.execute::<xla::Literal>(&literals).map_err(|e| rt_err("execute", &e))?;
        self.executions.set(self.executions.get() + 1);
        let out0 = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| DepyfError::Runtime("no output buffer".into()))?
            .to_literal_sync()
            .map_err(|e| rt_err("to_literal", &e))?;
        let parts = out0.to_tuple().map_err(|e| rt_err("output tuple", &e))?;
        if parts.len() != exe.n_outputs {
            return Err(DepyfError::Runtime(format!(
                "expected {} outputs, got {}",
                exe.n_outputs,
                parts.len()
            )));
        }
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| rt_err("shape", &e))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data: Vec<f32> = lit.to_vec().map_err(|e| rt_err("to_vec", &e))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("depyf_diskcache_{}_{}", tag, std::process::id()))
    }

    /// The persistent index round-trips across handles (= across
    /// processes) without any PJRT involvement.
    #[test]
    fn disk_cache_round_trips_across_handles() {
        let dir = tmp("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let c = DiskCache::open(&dir).unwrap();
        assert!(c.is_empty());
        c.put("graph:00ff", "HloModule m\n", 2);
        assert_eq!(c.get("graph:00ff"), Some(("HloModule m\n".to_string(), 2)));
        assert_eq!(c.get("graph:missing"), None);
        // Re-putting the same key overwrites (stale records self-heal) —
        // the last index line wins on reload.
        c.put("graph:00ff", "HloModule repaired\n", 3);
        assert_eq!(c.get("graph:00ff"), Some(("HloModule repaired\n".to_string(), 3)));
        // Distinct keys that sanitize to the same file stem must not
        // clobber each other's artifacts.
        c.put("graph_00ff", "HloModule collide\n", 1);
        assert_eq!(c.get("graph:00ff").unwrap().0, "HloModule repaired\n");
        assert_eq!(c.get("graph_00ff").unwrap().0, "HloModule collide\n");
        let c2 = DiskCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get("graph:00ff"), Some(("HloModule repaired\n".to_string(), 3)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_runtime_is_one_handle_per_process() {
        let dir = tmp("shared");
        let _ = std::fs::remove_dir_all(&dir);
        std::env::set_var(CACHE_DIR_ENV, &dir);
        let a = Runtime::shared().expect("pjrt");
        let b = Runtime::shared().unwrap();
        assert!(Rc::ptr_eq(&a, &b), "shared() must return the same runtime");
        assert!(a.disk_cache().is_some(), "shared runtime carries the persistent cache");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Hand-written HLO text (the dialect our codegen emits) must compile
    /// and run on the PJRT CPU client.
    #[test]
    fn compile_and_run_handwritten_hlo() {
        let hlo = r#"HloModule test_add

ENTRY main {
  p0 = f32[2,2] parameter(0)
  p1 = f32[2,2] parameter(1)
  sum = f32[2,2] add(p0, p1)
  ROOT out = (f32[2,2]) tuple(sum)
}
"#;
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let exe = rt.compile_hlo_text("test_add", hlo, 1).expect("compile");
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::ones(&[2, 2]);
        let out = rt.execute(&exe, &[&a, &b]).expect("execute");
        assert_eq!(out[0].shape(), &[2, 2]);
        assert_eq!(out[0].data(), &[2.0, 3.0, 4.0, 5.0]);
        // Cached second compile.
        rt.compile_hlo_text("test_add", hlo, 1).unwrap();
        assert_eq!(rt.compiles.get(), 1);
    }

    #[test]
    fn dot_and_reduce_hlo() {
        // The constructs backend::xla relies on: dot, reduce with a scoped
        // computation, broadcast, constant.
        let hlo = r#"HloModule test_dot

add_f32 {
  lhs = f32[] parameter(0)
  rhs = f32[] parameter(1)
  ROOT add = f32[] add(lhs, rhs)
}

ENTRY main {
  x = f32[2,3] parameter(0)
  w = f32[3,4] parameter(1)
  d = f32[2,4] dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  zero = f32[] constant(0)
  s = f32[] reduce(d, zero), dimensions={0,1}, to_apply=add_f32
  ROOT out = (f32[2,4], f32[]) tuple(d, s)
}
"#;
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let exe = rt.compile_hlo_text("test_dot", hlo, 2).expect("compile");
        let x = Tensor::ones(&[2, 3]);
        let w = Tensor::ones(&[3, 4]);
        let out = rt.execute(&exe, &[&x, &w]).expect("execute");
        assert_eq!(out[0].shape(), &[2, 4]);
        assert!(out[0].data().iter().all(|&v| v == 3.0));
        assert_eq!(out[1].item(), 24.0);
    }
}
