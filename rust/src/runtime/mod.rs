//! PJRT runtime: loads HLO **text** (AOT artifacts from `python/compile/`,
//! or codegen output from `backend::xla`), compiles it on the CPU PJRT
//! client, and executes with [`Tensor`] inputs. Python never runs here —
//! this is the request path.
//!
//! Caching is two-level. In-process, compiled executables are memoized by
//! key (the XLA backend keys on [`crate::graph::Graph::content_hash`], so
//! identical graphs compile once per process no matter how many sessions
//! produce them — [`Runtime::shared`] is the process-wide handle the CLI
//! uses). On disk, an optional [`DiskCache`] persists an HLO→artifact
//! index so a repeated run skips graph lowering and reuses the exact HLO
//! text across processes.
//!
//! # Thread safety
//!
//! The runtime handle is `Send + Sync`: the executable cache and counters
//! are lock-/atomic-based, [`Runtime::shared`] hands every thread the same
//! `Arc`, and [`DiskCache`] rewrites its index via atomic rename so
//! concurrent writers never corrupt it. The PJRT client and loaded
//! executables themselves are **thread-confined** ([`ThreadBound`]):
//! compile/execute must happen on the thread that created the runtime —
//! off-thread use returns a typed error instead of UB. That is why the
//! concurrent serving path (`depyf serve`) only drives CPU backends and
//! why `REQUIRES_RUNTIME` backends are excluded from multi-threaded
//! dispatch.

mod manifest;

pub use manifest::{Artifact, Manifest};

use std::collections::HashMap;
use std::mem::ManuallyDrop;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::ThreadId;

use crate::api::DepyfError;
use crate::tensor::Tensor;

/// A monotonically increasing counter with the same `get()` surface the
/// old `Cell<u64>` fields had, but atomic — observable from any thread.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn bump(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
}

/// Confines a non-`Send` value (PJRT client, loaded executable) to the
/// thread that created it while letting the *container* cross threads.
///
/// `get()` succeeds only on the owning thread; any other thread gets a
/// typed [`DepyfError::Runtime`] instead of undefined behavior. Dropping
/// from a foreign thread leaks the value rather than running a
/// thread-affine destructor off-thread — the shared runtime lives for the
/// process anyway.
pub struct ThreadBound<T> {
    value: ManuallyDrop<T>,
    owner: ThreadId,
}

// SAFETY: the inner value is only ever reachable (`get`) or dropped on
// `owner`; foreign threads see errors (or a leak on drop), never `&T`.
unsafe impl<T> Send for ThreadBound<T> {}
unsafe impl<T> Sync for ThreadBound<T> {}

impl<T> ThreadBound<T> {
    pub fn new(value: T) -> ThreadBound<T> {
        ThreadBound { value: ManuallyDrop::new(value), owner: std::thread::current().id() }
    }

    /// The wrapped value — errors when called off the owning thread.
    pub fn get(&self) -> Result<&T, DepyfError> {
        if std::thread::current().id() == self.owner {
            Ok(&self.value)
        } else {
            Err(DepyfError::Runtime(
                "PJRT handle used off its owning thread (the client is thread-confined; \
                 serve/multi-thread dispatch must use CPU backends)"
                    .into(),
            ))
        }
    }
}

impl<T> Drop for ThreadBound<T> {
    fn drop(&mut self) {
        if std::thread::current().id() == self.owner {
            // SAFETY: dropped exactly once, on the owning thread.
            unsafe { ManuallyDrop::drop(&mut self.value) }
        }
    }
}

/// Environment variable overriding the CLI's persistent HLO cache
/// directory (default `.depyf_cache` under the working directory).
pub const CACHE_DIR_ENV: &str = "DEPYF_CACHE_DIR";

/// One indexed record: output arity, `.hlo` file name, and the FNV-1a
/// checksum of the file's text at write time (`None` for entries written
/// by older versions — those read back unverified).
type IndexEntry = (usize, String, Option<u64>);

/// Parse one `index.txt` line: `key\tn_outputs\tfile[\tchecksum_hex]`.
/// The checksum field is additive — 3-field lines from older caches stay
/// readable.
fn parse_index_line(line: &str) -> Option<(String, IndexEntry)> {
    let mut parts = line.splitn(4, '\t');
    let (key, n, file) = (parts.next()?, parts.next()?, parts.next()?);
    let n = n.parse::<usize>().ok()?;
    let checksum = match parts.next() {
        Some(hex) => Some(u64::from_str_radix(hex, 16).ok()?),
        None => None,
    };
    Some((key.to_string(), (n, file.to_string(), checksum)))
}

/// A persistent HLO→artifact cache: `index.txt` maps cache keys to
/// `n_outputs`, an `.hlo` text file in the same directory, and the file's
/// content checksum. Reads verify the checksum: a corrupted payload is
/// quarantined (renamed to `<file>.quarantined`, kept for post-mortem)
/// and reported as a miss, so the caller recompiles instead of executing
/// garbage.
///
/// Writes go through **atomic rename**: `put` re-reads the on-disk index,
/// merges it with the in-memory view, writes the merged snapshot to a
/// unique temp file and renames it over `index.txt`. Readers (this or
/// another process) therefore always see a complete, well-formed index —
/// never a torn line — and concurrent writers merge instead of clobbering.
pub struct DiskCache {
    dir: PathBuf,
    index: Mutex<HashMap<String, IndexEntry>>,
    /// Distinguishes temp files of concurrent in-process writers.
    writes: Counter,
}

impl DiskCache {
    const INDEX: &'static str = "index.txt";

    /// Open (creating if needed) a cache directory and load its index.
    pub fn open(dir: impl AsRef<Path>) -> Result<DiskCache, DepyfError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).map_err(|e| DepyfError::io(format!("mkdir {}", dir.display()), e))?;
        let mut index = HashMap::new();
        let path = dir.join(Self::INDEX);
        if let Ok(text) = std::fs::read_to_string(&path) {
            for line in text.lines() {
                if let Some((key, entry)) = parse_index_line(line) {
                    index.insert(key, entry);
                }
            }
        }
        Ok(DiskCache { dir, index: Mutex::new(index), writes: Counter::new() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of indexed entries.
    pub fn len(&self) -> usize {
        self.index.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up the HLO text + output arity persisted under `key`,
    /// verifying the payload checksum. A corrupted file is quarantined
    /// and treated as a miss (the caller recompiles, and the next `put`
    /// repairs the entry). An injected `disk_cache.read` fault is also a
    /// miss — never an error: cache degradation must not fail compiles.
    pub fn get(&self, key: &str) -> Option<(String, usize)> {
        if crate::faults::gate(crate::faults::Site::DiskCacheRead).is_err() {
            return None;
        }
        let (n, file, checksum) =
            self.index.lock().unwrap_or_else(PoisonError::into_inner).get(key).cloned()?;
        let path = self.dir.join(&file);
        let text = std::fs::read_to_string(&path).ok()?;
        if let Some(want) = checksum {
            if crate::fnv::hash_str(&text) != want {
                let _ = std::fs::rename(&path, self.dir.join(format!("{}.quarantined", file)));
                self.index.lock().unwrap_or_else(PoisonError::into_inner).remove(key);
                return None;
            }
        }
        Some((text, n))
    }

    /// Read whatever index is on disk right now (for merging).
    fn read_disk_index(&self) -> HashMap<String, IndexEntry> {
        let mut index = HashMap::new();
        if let Ok(text) = std::fs::read_to_string(self.dir.join(Self::INDEX)) {
            for line in text.lines() {
                if let Some((key, entry)) = parse_index_line(line) {
                    index.insert(key, entry);
                }
            }
        }
        index
    }

    /// Persist HLO text under `key`, overwriting any existing entry — a
    /// stale/corrupt record (e.g. a bad `n_outputs`) is repaired the next
    /// time the key is re-lowered instead of poisoning the cache forever.
    /// Best-effort: IO failures leave the cache cold but never fail a
    /// compile.
    ///
    /// Concurrency: the in-memory index lock serializes writers within the
    /// process; the merged snapshot + atomic rename keeps the on-disk
    /// index well-formed under concurrent *processes* too (a racing
    /// process can at worst drop the other's newest entry — a cold cache
    /// line, never a torn one).
    pub fn put(&self, key: &str, text: &str, n_outputs: usize) {
        // An injected disk_cache.write fault skips the write — same
        // contract as a full disk: the cache stays cold, compiles succeed.
        if crate::faults::gate(crate::faults::Site::DiskCacheWrite).is_err() {
            return;
        }
        // File name = sanitized key + FNV of the *raw* key: two distinct
        // keys that sanitize identically (`a:b` vs `a_b`) cannot clobber
        // each other's .hlo file.
        let file = format!("{}-{:016x}.hlo", sanitize_key(key), crate::fnv::hash_str(key));
        if std::fs::write(self.dir.join(&file), text).is_err() {
            return;
        }
        let mut index = self.index.lock().unwrap_or_else(PoisonError::into_inner);
        // Merge: disk entries from other writers + everything we know +
        // the new record.
        let mut merged = self.read_disk_index();
        for (k, v) in index.iter() {
            merged.insert(k.clone(), v.clone());
        }
        merged.insert(key.to_string(), (n_outputs, file.clone(), Some(crate::fnv::hash_str(text))));
        let mut lines: Vec<String> = merged
            .iter()
            .map(|(k, (n, f, c))| match c {
                Some(c) => format!("{}\t{}\t{}\t{:016x}\n", k, n, f, c),
                None => format!("{}\t{}\t{}\n", k, n, f),
            })
            .collect();
        lines.sort();
        self.writes.bump();
        let tmp = self
            .dir
            .join(format!(".index.tmp.{}.{}", std::process::id(), self.writes.get()));
        let written = std::fs::write(&tmp, lines.concat())
            .and_then(|_| std::fs::rename(&tmp, self.dir.join(Self::INDEX)));
        if written.is_ok() {
            *index = merged;
        } else {
            let _ = std::fs::remove_file(&tmp);
        }
    }
}

fn sanitize_key(k: &str) -> String {
    k.chars().map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == '-' { c } else { '_' }).collect()
}

/// An execution input: f32 data, or f32-held integers to be passed as s32.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a Tensor),
}

impl<'a> Arg<'a> {
    fn tensor(&self) -> &'a Tensor {
        match self {
            Arg::F32(t) | Arg::I32(t) => t,
        }
    }
}

/// A compiled executable plus its output arity metadata. `Send + Sync`
/// as a handle (so modules holding it can cross threads), but the PJRT
/// executable inside is thread-confined — `Runtime::execute` errors off
/// the owning thread.
pub struct Executable {
    exe: ThreadBound<xla::PjRtLoadedExecutable>,
    /// HLO modules lowered from jax with `return_tuple=True` produce a
    /// 1-level output tuple; our own codegen does the same.
    pub n_outputs: usize,
}

/// The PJRT runtime wrapper.
pub struct Runtime {
    client: ThreadBound<xla::PjRtClient>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Where `make artifacts` put the AOT outputs.
    pub artifacts_dir: Option<PathBuf>,
    manifest: Option<Manifest>,
    /// Optional persistent HLO cache consulted by the XLA backend.
    disk: Option<DiskCache>,
    /// Compile + execute counters.
    pub compiles: Counter,
    pub executions: Counter,
    /// HLO texts served from the persistent cache (lowering skipped).
    pub disk_hits: Counter,
}

/// The process-wide runtime handle: every CLI command, session, or serve
/// thread asking for [`Runtime::shared`] gets the same client and
/// executable cache. Initialization is double-checked under the mutex —
/// two racing first callers produce exactly one client.
static SHARED: Mutex<Option<Arc<Runtime>>> = Mutex::new(None);

impl Runtime {
    fn new_with(
        artifacts_dir: Option<PathBuf>,
        manifest: Option<Manifest>,
        disk: Option<DiskCache>,
    ) -> Result<Arc<Runtime>, DepyfError> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| DepyfError::Runtime(format!("PjRtClient::cpu: {}", e)))?;
        Ok(Arc::new(Runtime {
            client: ThreadBound::new(client),
            cache: Mutex::new(HashMap::new()),
            artifacts_dir,
            manifest,
            disk,
            compiles: Counter::new(),
            executions: Counter::new(),
            disk_hits: Counter::new(),
        }))
    }

    /// CPU PJRT client. Fails if libxla_extension is unavailable.
    pub fn cpu() -> Result<Arc<Runtime>, DepyfError> {
        Runtime::new_with(None, None, None)
    }

    /// CPU client with an artifact directory (containing `manifest.txt`).
    pub fn cpu_with_artifacts(dir: impl AsRef<Path>) -> Result<Arc<Runtime>, DepyfError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        Runtime::new_with(Some(dir), Some(manifest), None)
    }

    /// CPU client with a persistent HLO disk cache at `dir`.
    pub fn cpu_with_disk_cache(dir: impl AsRef<Path>) -> Result<Arc<Runtime>, DepyfError> {
        Runtime::new_with(None, None, Some(DiskCache::open(dir)?))
    }

    /// The process-wide shared runtime: one PJRT client + executable cache
    /// for the whole process, with a persistent disk cache at
    /// `$DEPYF_CACHE_DIR` (default `.depyf_cache`). Repeated `depyf dump`
    /// invocations share the persisted index; repeated loads of identical
    /// HLO within a process compile exactly once.
    ///
    /// Thread-safe: concurrent first callers race to the lock; whichever
    /// wins initializes, the rest observe the stored handle. (Note the
    /// client stays confined to the winning thread — see [`ThreadBound`].)
    pub fn shared() -> Result<Arc<Runtime>, DepyfError> {
        let mut slot = SHARED.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(rt) = slot.as_ref() {
            return Ok(Arc::clone(rt));
        }
        let dir = std::env::var(CACHE_DIR_ENV).unwrap_or_else(|_| ".depyf_cache".into());
        // A broken cache dir must not take down the runtime.
        let disk = DiskCache::open(&dir).ok();
        let rt = Runtime::new_with(None, None, disk)?;
        *slot = Some(Arc::clone(&rt));
        Ok(rt)
    }

    pub fn platform(&self) -> String {
        self.client
            .get()
            .map(|c| c.platform_name())
            .unwrap_or_else(|_| "unavailable (off-thread)".into())
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// The persistent HLO cache, if this runtime has one.
    pub fn disk_cache(&self) -> Option<&DiskCache> {
        self.disk.as_ref()
    }

    /// In-process executable cache lookup (no compile).
    pub fn cached_executable(&self, key: &str) -> Option<Arc<Executable>> {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner).get(key).map(Arc::clone)
    }

    /// Persistent-cache lookup of HLO text + output arity; bumps
    /// `disk_hits` so "lowering skipped" is observable.
    pub fn cached_hlo(&self, key: &str) -> Option<(String, usize)> {
        let hit = self.disk.as_ref()?.get(key)?;
        self.disk_hits.bump();
        Some(hit)
    }

    /// Persist HLO text for `key` (no-op without a disk cache).
    pub fn store_hlo(&self, key: &str, text: &str, n_outputs: usize) {
        if let Some(d) = &self.disk {
            d.put(key, text, n_outputs);
        }
    }

    /// Compile HLO text under a cache key. The compile itself runs outside
    /// the cache lock (PJRT compiles can be slow; dispatch must not block
    /// on a compile in flight) — two racing threads may both compile, the
    /// first insert wins and both get a usable executable.
    pub fn compile_hlo_text(
        &self,
        key: &str,
        text: &str,
        n_outputs: usize,
    ) -> Result<Arc<Executable>, DepyfError> {
        if let Some(e) = self.cache.lock().unwrap_or_else(PoisonError::into_inner).get(key) {
            return Ok(Arc::clone(e));
        }
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(text.as_bytes())
            .map_err(|e| DepyfError::Parse(format!("HLO parse failed for '{}': {}", key, e)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .get()?
            .compile(&comp)
            .map_err(|e| DepyfError::Runtime(format!("PJRT compile failed for '{}': {}", key, e)))?;
        self.compiles.bump();
        let exec = Arc::new(Executable { exe: ThreadBound::new(exe), n_outputs });
        let mut cache = self.cache.lock().unwrap_or_else(PoisonError::into_inner);
        Ok(Arc::clone(cache.entry(key.to_string()).or_insert(exec)))
    }

    /// Load + compile a named artifact from the manifest.
    pub fn load_artifact(&self, name: &str) -> Result<(Arc<Executable>, Artifact), DepyfError> {
        let m = self
            .manifest
            .as_ref()
            .ok_or_else(|| DepyfError::Runtime("runtime has no artifact manifest".into()))?;
        let art = m
            .get(name)
            .ok_or_else(|| DepyfError::Runtime(format!("artifact '{}' not in manifest", name)))?
            .clone();
        let dir = self
            .artifacts_dir
            .as_ref()
            .ok_or_else(|| DepyfError::Runtime("runtime has no artifacts dir".into()))?;
        let path = dir.join(&art.file);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| DepyfError::io(format!("read {}", path.display()), e))?;
        let exe = self.compile_hlo_text(name, &text, art.n_outputs)?;
        Ok((exe, art))
    }

    /// Execute with f32 tensor inputs; outputs are unpacked from the
    /// 1-level output tuple.
    pub fn execute(&self, exe: &Executable, inputs: &[&Tensor]) -> Result<Vec<Tensor>, DepyfError> {
        let args: Vec<Arg> = inputs.iter().map(|t| Arg::F32(t)).collect();
        self.execute_args(exe, &args)
    }

    /// Execute with mixed f32/i32 inputs (token ids are s32 in the jax
    /// artifacts; `Arg::I32` casts the f32-held values).
    pub fn execute_args(&self, exe: &Executable, inputs: &[Arg]) -> Result<Vec<Tensor>, DepyfError> {
        let rt_err = |what: &str, e: &dyn std::fmt::Display| DepyfError::Runtime(format!("{}: {}", what, e));
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| {
                let t = a.tensor();
                let flat = match a {
                    Arg::F32(_) => xla::Literal::vec1(t.data()),
                    Arg::I32(_) => {
                        let ints: Vec<i32> = t.data().iter().map(|&v| v as i32).collect();
                        xla::Literal::vec1(&ints)
                    }
                };
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                flat.reshape(&dims).map_err(|e| rt_err("literal reshape", &e))
            })
            .collect::<Result<_, DepyfError>>()?;
        let result =
            exe.exe.get()?.execute::<xla::Literal>(&literals).map_err(|e| rt_err("execute", &e))?;
        self.executions.bump();
        let out0 = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| DepyfError::Runtime("no output buffer".into()))?
            .to_literal_sync()
            .map_err(|e| rt_err("to_literal", &e))?;
        let parts = out0.to_tuple().map_err(|e| rt_err("output tuple", &e))?;
        if parts.len() != exe.n_outputs {
            return Err(DepyfError::Runtime(format!(
                "expected {} outputs, got {}",
                exe.n_outputs,
                parts.len()
            )));
        }
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| rt_err("shape", &e))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data: Vec<f32> = lit.to_vec().map_err(|e| rt_err("to_vec", &e))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("depyf_diskcache_{}_{}", tag, std::process::id()))
    }

    /// The persistent index round-trips across handles (= across
    /// processes) without any PJRT involvement.
    #[test]
    fn disk_cache_round_trips_across_handles() {
        let dir = tmp("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let c = DiskCache::open(&dir).unwrap();
        assert!(c.is_empty());
        c.put("graph:00ff", "HloModule m\n", 2);
        assert_eq!(c.get("graph:00ff"), Some(("HloModule m\n".to_string(), 2)));
        assert_eq!(c.get("graph:missing"), None);
        // Re-putting the same key overwrites (stale records self-heal) —
        // the last index line wins on reload.
        c.put("graph:00ff", "HloModule repaired\n", 3);
        assert_eq!(c.get("graph:00ff"), Some(("HloModule repaired\n".to_string(), 3)));
        // Distinct keys that sanitize to the same file stem must not
        // clobber each other's artifacts.
        c.put("graph_00ff", "HloModule collide\n", 1);
        assert_eq!(c.get("graph:00ff").unwrap().0, "HloModule repaired\n");
        assert_eq!(c.get("graph_00ff").unwrap().0, "HloModule collide\n");
        let c2 = DiskCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get("graph:00ff"), Some(("HloModule repaired\n".to_string(), 3)));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Checksum verification: a payload corrupted on disk is quarantined
    /// (kept as `<file>.quarantined` for post-mortem), reported as a miss,
    /// and repaired by the next `put`. Legacy 3-field index lines (no
    /// checksum) still read back unverified.
    #[test]
    fn disk_cache_quarantines_corrupt_entries_and_recovers() {
        let dir = tmp("quarantine");
        let _ = std::fs::remove_dir_all(&dir);
        let c = DiskCache::open(&dir).unwrap();
        c.put("graph:aa", "HloModule good\n", 1);
        assert!(c.get("graph:aa").is_some());
        // Corrupt the payload behind the index's back.
        let hlo: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".hlo"))
            .collect();
        assert_eq!(hlo.len(), 1);
        std::fs::write(hlo[0].path(), "HloModule tampered\n").unwrap();
        // A fresh handle (checksum loaded from the index) detects it.
        let c2 = DiskCache::open(&dir).unwrap();
        assert_eq!(c2.get("graph:aa"), None, "corrupt entry must read as a miss");
        let quarantined = format!("{}.quarantined", hlo[0].file_name().to_string_lossy());
        assert!(dir.join(&quarantined).exists(), "payload kept for post-mortem");
        assert!(!hlo[0].path().exists(), "corrupt file moved out of the live cache");
        // Recompile-and-put repairs the entry.
        c2.put("graph:aa", "HloModule recompiled\n", 1);
        assert_eq!(c2.get("graph:aa"), Some(("HloModule recompiled\n".to_string(), 1)));
        // Legacy line without a checksum field reads back unverified.
        let legacy = dir.join("legacy.hlo");
        std::fs::write(&legacy, "HloModule legacy\n").unwrap();
        let index = std::fs::read_to_string(dir.join("index.txt")).unwrap();
        std::fs::write(dir.join("index.txt"), format!("{}graph:old\t2\tlegacy.hlo\n", index)).unwrap();
        let c3 = DiskCache::open(&dir).unwrap();
        assert_eq!(c3.get("graph:old"), Some(("HloModule legacy\n".to_string(), 2)));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The satellite contended-writer case: many threads `put` distinct
    /// keys into one cache concurrently. The atomic-rename index must end
    /// up complete and well-formed — every entry present, no torn lines —
    /// when re-opened by a fresh handle.
    #[test]
    fn disk_cache_survives_contended_writers() {
        let dir = tmp("contended");
        let _ = std::fs::remove_dir_all(&dir);
        let c = std::sync::Arc::new(DiskCache::open(&dir).unwrap());
        let n_threads = 8;
        let per_thread = 4;
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0..per_thread {
                        let key = format!("graph:t{}:{}", t, i);
                        c.put(&key, &format!("HloModule m_{}_{}\n", t, i), t + 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.len(), n_threads * per_thread);
        // A fresh handle (= another process) sees the complete index.
        let c2 = DiskCache::open(&dir).unwrap();
        assert_eq!(c2.len(), n_threads * per_thread, "index lost entries under contention");
        for t in 0..n_threads {
            for i in 0..per_thread {
                let key = format!("graph:t{}:{}", t, i);
                let (text, n) = c2.get(&key).unwrap_or_else(|| panic!("missing {}", key));
                assert_eq!(text, format!("HloModule m_{}_{}\n", t, i));
                assert_eq!(n, t + 1);
            }
        }
        // No stray temp files left behind.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".index.tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp index files leaked: {:?}", leftovers);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_runtime_is_one_handle_per_process() {
        let dir = tmp("shared");
        let _ = std::fs::remove_dir_all(&dir);
        std::env::set_var(CACHE_DIR_ENV, &dir);
        let a = Runtime::shared().expect("pjrt");
        let b = Runtime::shared().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "shared() must return the same runtime");
        assert!(a.disk_cache().is_some(), "shared runtime carries the persistent cache");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Hand-written HLO text (the dialect our codegen emits) must compile
    /// and run on the PJRT CPU client.
    #[test]
    fn compile_and_run_handwritten_hlo() {
        let hlo = r#"HloModule test_add

ENTRY main {
  p0 = f32[2,2] parameter(0)
  p1 = f32[2,2] parameter(1)
  sum = f32[2,2] add(p0, p1)
  ROOT out = (f32[2,2]) tuple(sum)
}
"#;
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let exe = rt.compile_hlo_text("test_add", hlo, 1).expect("compile");
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::ones(&[2, 2]);
        let out = rt.execute(&exe, &[&a, &b]).expect("execute");
        assert_eq!(out[0].shape(), &[2, 2]);
        assert_eq!(out[0].data(), &[2.0, 3.0, 4.0, 5.0]);
        // Cached second compile.
        rt.compile_hlo_text("test_add", hlo, 1).unwrap();
        assert_eq!(rt.compiles.get(), 1);
    }

    #[test]
    fn dot_and_reduce_hlo() {
        // The constructs backend::xla relies on: dot, reduce with a scoped
        // computation, broadcast, constant.
        let hlo = r#"HloModule test_dot

add_f32 {
  lhs = f32[] parameter(0)
  rhs = f32[] parameter(1)
  ROOT add = f32[] add(lhs, rhs)
}

ENTRY main {
  x = f32[2,3] parameter(0)
  w = f32[3,4] parameter(1)
  d = f32[2,4] dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  zero = f32[] constant(0)
  s = f32[] reduce(d, zero), dimensions={0,1}, to_apply=add_f32
  ROOT out = (f32[2,4], f32[]) tuple(d, s)
}
"#;
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let exe = rt.compile_hlo_text("test_dot", hlo, 2).expect("compile");
        let x = Tensor::ones(&[2, 3]);
        let w = Tensor::ones(&[3, 4]);
        let out = rt.execute(&exe, &[&x, &w]).expect("execute");
        assert_eq!(out[0].shape(), &[2, 4]);
        assert!(out[0].data().iter().all(|&v| v == 3.0));
        assert_eq!(out[1].item(), 24.0);
    }
}
