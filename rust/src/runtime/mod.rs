//! PJRT runtime: loads HLO **text** (AOT artifacts from `python/compile/`,
//! or codegen output from `backend::xla`), compiles it on the CPU PJRT
//! client, and executes with [`Tensor`] inputs. Python never runs here —
//! this is the request path.

mod manifest;

pub use manifest::{Artifact, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::api::DepyfError;
use crate::tensor::Tensor;

/// An execution input: f32 data, or f32-held integers to be passed as s32.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a Tensor),
}

impl<'a> Arg<'a> {
    fn tensor(&self) -> &'a Tensor {
        match self {
            Arg::F32(t) | Arg::I32(t) => t,
        }
    }
}

/// A compiled executable plus its output arity metadata.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// HLO modules lowered from jax with `return_tuple=True` produce a
    /// 1-level output tuple; our own codegen does the same.
    pub n_outputs: usize,
}

/// The PJRT runtime wrapper.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Where `make artifacts` put the AOT outputs.
    pub artifacts_dir: Option<PathBuf>,
    manifest: Option<Manifest>,
    /// Compile + execute counters.
    pub compiles: std::cell::Cell<u64>,
    pub executions: std::cell::Cell<u64>,
}

impl Runtime {
    /// CPU PJRT client. Fails if libxla_extension is unavailable.
    pub fn cpu() -> Result<Rc<Runtime>, DepyfError> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| DepyfError::Runtime(format!("PjRtClient::cpu: {}", e)))?;
        Ok(Rc::new(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            artifacts_dir: None,
            manifest: None,
            compiles: std::cell::Cell::new(0),
            executions: std::cell::Cell::new(0),
        }))
    }

    /// CPU client with an artifact directory (containing `manifest.txt`).
    pub fn cpu_with_artifacts(dir: impl AsRef<Path>) -> Result<Rc<Runtime>, DepyfError> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| DepyfError::Runtime(format!("PjRtClient::cpu: {}", e)))?;
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        Ok(Rc::new(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            artifacts_dir: Some(dir),
            manifest: Some(manifest),
            compiles: std::cell::Cell::new(0),
            executions: std::cell::Cell::new(0),
        }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// Compile HLO text under a cache key.
    pub fn compile_hlo_text(
        &self,
        key: &str,
        text: &str,
        n_outputs: usize,
    ) -> Result<Rc<Executable>, DepyfError> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(Rc::clone(e));
        }
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(text.as_bytes())
            .map_err(|e| DepyfError::Parse(format!("HLO parse failed for '{}': {}", key, e)))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| DepyfError::Runtime(format!("PJRT compile failed for '{}': {}", key, e)))?;
        self.compiles.set(self.compiles.get() + 1);
        let exec = Rc::new(Executable { exe, n_outputs });
        self.cache.borrow_mut().insert(key.to_string(), Rc::clone(&exec));
        Ok(exec)
    }

    /// Load + compile a named artifact from the manifest.
    pub fn load_artifact(&self, name: &str) -> Result<(Rc<Executable>, Artifact), DepyfError> {
        let m = self
            .manifest
            .as_ref()
            .ok_or_else(|| DepyfError::Runtime("runtime has no artifact manifest".into()))?;
        let art = m
            .get(name)
            .ok_or_else(|| DepyfError::Runtime(format!("artifact '{}' not in manifest", name)))?
            .clone();
        let dir = self
            .artifacts_dir
            .as_ref()
            .ok_or_else(|| DepyfError::Runtime("runtime has no artifacts dir".into()))?;
        let path = dir.join(&art.file);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| DepyfError::io(format!("read {}", path.display()), e))?;
        let exe = self.compile_hlo_text(name, &text, art.n_outputs)?;
        Ok((exe, art))
    }

    /// Execute with f32 tensor inputs; outputs are unpacked from the
    /// 1-level output tuple.
    pub fn execute(&self, exe: &Executable, inputs: &[&Tensor]) -> Result<Vec<Tensor>, DepyfError> {
        let args: Vec<Arg> = inputs.iter().map(|t| Arg::F32(t)).collect();
        self.execute_args(exe, &args)
    }

    /// Execute with mixed f32/i32 inputs (token ids are s32 in the jax
    /// artifacts; `Arg::I32` casts the f32-held values).
    pub fn execute_args(&self, exe: &Executable, inputs: &[Arg]) -> Result<Vec<Tensor>, DepyfError> {
        let rt_err = |what: &str, e: &dyn std::fmt::Display| DepyfError::Runtime(format!("{}: {}", what, e));
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| {
                let t = a.tensor();
                let flat = match a {
                    Arg::F32(_) => xla::Literal::vec1(t.data()),
                    Arg::I32(_) => {
                        let ints: Vec<i32> = t.data().iter().map(|&v| v as i32).collect();
                        xla::Literal::vec1(&ints)
                    }
                };
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                flat.reshape(&dims).map_err(|e| rt_err("literal reshape", &e))
            })
            .collect::<Result<_, DepyfError>>()?;
        let result =
            exe.exe.execute::<xla::Literal>(&literals).map_err(|e| rt_err("execute", &e))?;
        self.executions.set(self.executions.get() + 1);
        let out0 = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| DepyfError::Runtime("no output buffer".into()))?
            .to_literal_sync()
            .map_err(|e| rt_err("to_literal", &e))?;
        let parts = out0.to_tuple().map_err(|e| rt_err("output tuple", &e))?;
        if parts.len() != exe.n_outputs {
            return Err(DepyfError::Runtime(format!(
                "expected {} outputs, got {}",
                exe.n_outputs,
                parts.len()
            )));
        }
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| rt_err("shape", &e))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data: Vec<f32> = lit.to_vec().map_err(|e| rt_err("to_vec", &e))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written HLO text (the dialect our codegen emits) must compile
    /// and run on the PJRT CPU client.
    #[test]
    fn compile_and_run_handwritten_hlo() {
        let hlo = r#"HloModule test_add

ENTRY main {
  p0 = f32[2,2] parameter(0)
  p1 = f32[2,2] parameter(1)
  sum = f32[2,2] add(p0, p1)
  ROOT out = (f32[2,2]) tuple(sum)
}
"#;
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let exe = rt.compile_hlo_text("test_add", hlo, 1).expect("compile");
        let a = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::ones(&[2, 2]);
        let out = rt.execute(&exe, &[&a, &b]).expect("execute");
        assert_eq!(out[0].shape(), &[2, 2]);
        assert_eq!(out[0].data(), &[2.0, 3.0, 4.0, 5.0]);
        // Cached second compile.
        rt.compile_hlo_text("test_add", hlo, 1).unwrap();
        assert_eq!(rt.compiles.get(), 1);
    }

    #[test]
    fn dot_and_reduce_hlo() {
        // The constructs backend::xla relies on: dot, reduce with a scoped
        // computation, broadcast, constant.
        let hlo = r#"HloModule test_dot

add_f32 {
  lhs = f32[] parameter(0)
  rhs = f32[] parameter(1)
  ROOT add = f32[] add(lhs, rhs)
}

ENTRY main {
  x = f32[2,3] parameter(0)
  w = f32[3,4] parameter(1)
  d = f32[2,4] dot(x, w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  zero = f32[] constant(0)
  s = f32[] reduce(d, zero), dimensions={0,1}, to_apply=add_f32
  ROOT out = (f32[2,4], f32[]) tuple(d, s)
}
"#;
        let rt = Runtime::cpu().expect("pjrt cpu client");
        let exe = rt.compile_hlo_text("test_dot", hlo, 2).expect("compile");
        let x = Tensor::ones(&[2, 3]);
        let w = Tensor::ones(&[3, 4]);
        let out = rt.execute(&exe, &[&x, &w]).expect("execute");
        assert_eq!(out[0].shape(), &[2, 4]);
        assert!(out[0].data().iter().all(|&v| v == 3.0));
        assert_eq!(out[1].item(), 24.0);
    }
}
