//! depyf-rs CLI — the leader entrypoint.
//!
//! Run `depyf help` for the full usage text. Usage errors (unknown
//! commands, flags or flag values) exit with code 2; runtime failures exit
//! with code 1.
//!
//! (Hand-rolled arg parsing: the offline environment has no clap.)

use std::sync::Arc;

use depyf::api::{
    backend_names, load_manifest, lookup_backend, ArtifactKind, Backend, Capabilities, OptLevel,
    Session, TraceBundle,
};
use depyf::backend::{replay_bundle, RecordingBackend, ReplayOptions};
use depyf::bytecode::{disassemble, IsaVersion};
use depyf::corpus::{render_table1, run_table1};
use depyf::decompiler::baselines::all_tools_rc;
use depyf::decompiler::DecompilerTool;
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::pylang::compile_module;
use depyf::runtime::Runtime;
use depyf::vm::Vm;
use depyf::DepyfError;

const USAGE: &str = "\
depyf — open the opaque box of the pylang compiler

usage:
  depyf run <file.py> [--compile] [--backend <name>] [--version <V>]
            [--opt-level 0|1|2]
      Execute a program; with --compile (or --backend) it runs under the
      dynamo frontend and reports compiler metrics.
  depyf disasm <file.py> [--version <V>]
      Compile and print the bytecode disassembly.
  depyf decompile <file.py> [--tool depyf|pycdc|decompyle3|uncompyle6] [--version <V>]
      Compile, then decompile the bytecode back to source.
  depyf dump <file.py> <dir> [--backend <name>] [--version <V>]
             [--opt-level 0|1|2]
      prepare_debug: run under the compiler and dump every artifact
      (full_code.py, __compiled_fn_*.py, __optimized_*.{txt,json},
      __transformed_*.py, disassembly, guards) plus a machine-readable
      manifest.json into <dir>.
  depyf table1
      Regenerate the paper's Table 1 correctness matrix.
  depyf serve [--threads N] [--backend <name>] [--iters M] [--out <dir>]
              [--deadline-ms D] [--admission block|shed|deadline-aware]
              [--queue-cap Q] [--pool-workers W] [--stall-ms S]
      Concurrent serving mode: N worker threads (default 4) each drive an
      independent session over the table1 model corpus, dispatching through
      the shared thread-safe backend registry and module cache. The inner
      backend is always wrapped in the resilient decorator (retry + circuit
      breaker); --deadline-ms abandons calls that exceed D milliseconds and
      serves them from the eager fallback — the deadline propagates into
      pipeline stages and the compile path, which abort early instead of
      finishing doomed work. With an async:<inner> backend the worker pool
      runs under a supervisor: W pool workers (default 4) heartbeat per
      job; a worker silent past S ms (default 1000) is declared lost,
      killed and respawned under a restart budget, and its abandoned calls
      degrade to the eager fallback instead of hanging. The supervisor
      queue holds at most Q jobs (default 64); --admission picks what
      happens at the bound: block (backpressure, the default), shed (typed
      Overloaded error, served eagerly), or deadline-aware (shed only jobs
      whose remaining deadline cannot cover the observed p50 service
      time). Writes merged per-thread metrics (compiles, cache hits,
      evictions, retries, degrades, breaker trips, timeouts, sheds,
      respawns, watchdog kills, deadline-propagated aborts, queue-depth
      p99, p50/p99 call latency) to <dir>/metrics.json and a throughput
      record to <dir>/BENCH_serve.json (default dir: serve_out). Exits
      non-zero if any serving thread died. Backends that require the PJRT
      runtime (xla) are rejected — the runtime is thread-confined; use
      eager/sharded/batched/codegen/pipelined/recording/async/resilient.
      Compiled plans spill to an on-disk cache (DEPYF_CACHE_DIR, default
      .depyf_cache) so repeat fleets skip recompilation.
  depyf replay <trace.json|dump-dir> [--backend <name>|recorded]
               [--against <oracle>] [--eps <tol>] [--no-localize]
               [--opt-level 0|1|2]
      Re-execute recorded __trace_*.json bundles (written by the recording
      backend) on any registered backend. A dump-dir argument replays every
      trace indexed in its manifest.json. --backend recorded re-runs each
      bundle on the backend it was originally recorded against (degraded
      calls carry a per-call "served_by" tag naming the fallback that
      actually served them). Default comparison is bit-exact against the
      recorded outputs; --against <oracle> recomputes the reference with
      another backend (differential mode), --eps switches to |a-b| <= tol.
      Mismatches are localized to the first diverging op (disable with
      --no-localize) and exit with code 1.
  depyf fuzz [--seed N] [--iters M] [--backend <name>] [--opt-level 0|1|2]
             [--out <dir>] [--no-shrink] [--serve [--threads T]]
             [--bisect-opt]
      Program-level differential fuzzing: generate M seeded pylang
      programs (branches, loops with break/continue, closures, container
      mutation, guard-boundary shape changes), mutate them, and run each
      twice — plain VM vs dynamo — demanding bitwise agreement (printed
      output, result bit patterns, error messages). Sweeps eager, sharded,
      batched, codegen and resilient:codegen at opt levels 0 and 2 unless
      --backend / --opt-level narrow it. Divergences and caught panics are
      auto-shrunk (disable with --no-shrink), chained into the replay
      localizer, written as regression bundles to <dir> (default
      fuzz_out), and exit with code 1. Fully deterministic in --seed.
      --serve switches to concurrent-dispatch fuzzing: T threads (default
      4) race each program through one shared module cache per backend ×
      opt level and every thread's outcome is diffed against the
      single-thread reference (findings are not shrunk — shrinking can
      mask a race). --bisect-opt re-runs each divergence single-threaded
      at O0/O1/O2 and records the first exhibiting level in the bundle's
      first_divergent_opt field.
  depyf help
      Print this text.

flags:
  --version <V>    ISA version: 3.8, 3.9, 3.10 or 3.11 (default 3.11)
  --opt-level <N>  Graph-optimizer level (default 2):
                     0  capture verbatim: no passes, no elementwise fusion
                     1  const folding + CSE + dead-code elimination
                     2  level 1 + algebraic rewrites (x*1, x-0, double-neg,
                        transpose∘transpose, reshape∘reshape, gated x+0/x*0)
                        + fused elementwise chains in the eager executor
                   Optimization never changes results: levels 0 and 2 are
                   bitwise-identical on eager/sharded/batched/codegen (the
                   conformance suite enforces it). Traces record the
                   pre-optimizer graph, so `depyf replay --opt-level 0`
                   vs `2` bisects optimizer/fusion suspicions.
  --backend <name> A registered graph backend; custom backends plug in via
                   depyf::api::register_backend. Built-ins:
                     eager      node-by-node CPU reference executor
                     xla        one PJRT executable per captured graph
                     sharded    splits graphs at articulation points into
                                several PJRT/eager executables and stitches
                                outputs (dumps __plan_*.json + __hlo_*.txt)
                     batched    pads/buckets the dynamic leading dim so one
                                executable serves multiple guard entries
                     codegen    compiles the optimized graph to a flat,
                                register-allocated loop program (bitwise-
                                equal to eager; dumps __loopir_*.txt)
                     recording  wraps eager and records every call into a
                                replayable __trace_*.json bundle; wrap any
                                other backend as recording:<name>
                                (e.g. --backend recording:sharded)
                     async      wraps eager; modules accept submissions and
                                return futures resolved by a worker pool
                                (Capabilities::ASYNC); wrap any other
                                backend as async:<name>
                     pipelined  the sharded partition chain with one stage
                                thread per shard: shard k of call i overlaps
                                shard k+1 of call i-1
                     resilient  wraps eager with retry-with-backoff for
                                transient compile failures plus a circuit
                                breaker that fails fast after repeated
                                failures; wrap any other backend as
                                resilient:<name>
                   sharded/batched lower to PJRT when the shared runtime is
                   available and to the eager executor otherwise.

exit codes: 0 success, 1 runtime error (incl. replay mismatches), 2 usage error
";

/// CLI failure, split by exit code: 2 for usage errors, 1 for runtime.
enum CliError {
    Usage(String),
    Run(String),
}

impl From<DepyfError> for CliError {
    fn from(e: DepyfError) -> CliError {
        CliError::Run(e.to_string())
    }
}

fn usage(msg: impl Into<String>) -> CliError {
    CliError::Usage(msg.into())
}

fn run_err(e: impl std::fmt::Display) -> CliError {
    CliError::Run(e.to_string())
}

fn parse_version(args: &[String]) -> Result<IsaVersion, CliError> {
    match flag_value(args, "--version").as_deref() {
        Some("3.8") => Ok(IsaVersion::V38),
        Some("3.9") => Ok(IsaVersion::V39),
        Some("3.10") => Ok(IsaVersion::V310),
        Some("3.11") | None => Ok(IsaVersion::V311),
        Some(other) => Err(usage(format!("unknown --version '{}' (expected 3.8, 3.9, 3.10 or 3.11)", other))),
    }
}

fn parse_opt_level(args: &[String]) -> Result<OptLevel, CliError> {
    match flag_value(args, "--opt-level") {
        None => Ok(OptLevel::default()),
        Some(v) => OptLevel::parse(&v)
            .ok_or_else(|| usage(format!("unknown --opt-level '{}' (expected 0, 1 or 2)", v))),
    }
}

/// Resolve `--backend <name>` against the registry; absent flag → None.
/// `recording:<inner>` wraps any registered backend in the recording
/// decorator (bare `recording` is the pre-registered eager wrapper);
/// `async:<inner>` wraps one in the future-returning async decorator;
/// `resilient[:<inner>]` wraps one in the retry/circuit-breaker decorator.
fn parse_backend(args: &[String]) -> Result<Option<Arc<dyn Backend>>, CliError> {
    match flag_value(args, "--backend") {
        None => Ok(None),
        Some(name) => resolve_backend(&name).map(Some),
    }
}

fn resolve_backend(name: &str) -> Result<Arc<dyn Backend>, CliError> {
    if let Some(inner) = name.strip_prefix("recording:") {
        return RecordingBackend::wrapping(inner)
            .map(|b| Arc::new(b) as Arc<dyn Backend>)
            .map_err(|e| usage(e.to_string()));
    }
    if let Some(inner) = name.strip_prefix("async:") {
        return depyf::serve::AsyncBackend::wrapping(inner)
            .map(|b| Arc::new(b) as Arc<dyn Backend>)
            .map_err(|e| usage(e.to_string()));
    }
    if name == "resilient" || name.starts_with("resilient:") {
        let inner = name.strip_prefix("resilient:").unwrap_or("eager");
        return depyf::backend::ResilientBackend::wrapping(inner)
            .map(|b| Arc::new(b) as Arc<dyn Backend>)
            .map_err(|e| usage(e.to_string()));
    }
    lookup_backend(name).ok_or_else(|| {
        usage(format!(
            "unknown --backend '{}' (registered: {}; wrappers: recording:<inner>, \
             async:<inner>, resilient:<inner>)",
            name,
            backend_names().join(", ")
        ))
    })
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn read_source(path: &str) -> Result<String, CliError> {
    std::fs::read_to_string(path).map_err(|e| run_err(format!("read {}: {}", path, e)))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run_cli(&args);
    std::process::exit(code);
}

fn run_cli(args: &[String]) -> i32 {
    let Some(cmd) = args.first() else {
        eprint!("{}", USAGE);
        return 2;
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "run" => cmd_run(rest),
        "disasm" => cmd_disasm(rest),
        "decompile" => cmd_decompile(rest),
        "dump" => cmd_dump(rest),
        "table1" => cmd_table1(rest),
        "serve" => cmd_serve(rest),
        "replay" => cmd_replay(rest),
        "fuzz" => cmd_fuzz(rest),
        "help" | "--help" | "-h" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => Err(usage(format!("unknown command '{}'", other))),
    };
    match result {
        Ok(()) => 0,
        Err(CliError::Usage(m)) => {
            eprintln!("error: {}\n", m);
            eprint!("{}", USAGE);
            2
        }
        Err(CliError::Run(m)) => {
            eprintln!("error: {}", m);
            1
        }
    }
}

fn cmd_run(args: &[String]) -> Result<(), CliError> {
    let file = args
        .first()
        .ok_or_else(|| usage("run needs a file: depyf run <file.py> [--compile] [--backend <name>]"))?;
    let version = parse_version(args)?;
    let backend = parse_backend(args)?;
    let opt_level = parse_opt_level(args)?;
    let src = read_source(file)?;
    let mut vm = Vm::new();
    let dynamo = if has_flag(args, "--compile") || backend.is_some() {
        let backend = match backend {
            Some(b) => b,
            None => lookup_backend("eager").expect("eager is always registered"),
        };
        let runtime = provision_runtime(&[&backend])?;
        let config = DynamoConfig { backend, opt_level, ..Default::default() };
        let d = match runtime {
            Some(rt) => Dynamo::with_runtime(config, rt),
            None => Dynamo::new(config),
        };
        vm.eval_hook = Some(d.clone());
        Some(d)
    } else {
        None
    };
    vm.exec_source(&src, version).map_err(run_err)?;
    print!("{}", vm.take_output());
    if let Some(d) = dynamo {
        eprintln!("[depyf] {}", d.metrics.report());
    }
    Ok(())
}

fn cmd_disasm(args: &[String]) -> Result<(), CliError> {
    let file = args.first().ok_or_else(|| usage("disasm needs a file: depyf disasm <file.py>"))?;
    let version = parse_version(args)?;
    let src = read_source(file)?;
    let code = compile_module(&src, file, version).map_err(run_err)?;
    print!("{}", disassemble(&code));
    Ok(())
}

fn cmd_decompile(args: &[String]) -> Result<(), CliError> {
    let file = args.first().ok_or_else(|| {
        usage("decompile needs a file: depyf decompile <file.py> [--tool depyf|pycdc|decompyle3|uncompyle6]")
    })?;
    let version = parse_version(args)?;
    let src = read_source(file)?;
    let toolname = flag_value(args, "--tool").unwrap_or_else(|| "depyf".into());
    let tool = all_tools_rc()
        .into_iter()
        .find(|t| t.name() == toolname)
        .ok_or_else(|| usage(format!("unknown --tool '{}' (expected depyf, pycdc, decompyle3 or uncompyle6)", toolname)))?;
    let code = compile_module(&src, file, version).map_err(run_err)?;
    let out = tool.decompile_module(&code).map_err(run_err)?;
    print!("{}", out);
    Ok(())
}

fn cmd_dump(args: &[String]) -> Result<(), CliError> {
    let file = args.first().ok_or_else(|| usage("dump needs a file and a dir: depyf dump <file.py> <dir>"))?;
    let dir = args.get(1).ok_or_else(|| usage("dump needs a dir: depyf dump <file.py> <dir>"))?;
    let version = parse_version(args)?;
    let backend = parse_backend(args)?;
    let opt_level = parse_opt_level(args)?;
    let src = read_source(file)?;
    let mut builder = Session::builder().dump_to(dir).isa(version).opt_level(opt_level);
    if let Some(b) = backend {
        if let Some(rt) = provision_runtime(&[&b])? {
            builder = builder.runtime(rt);
        }
        builder = builder.backend(b);
    }
    let mut session = builder.build()?;
    session.run_source("main", &src).map_err(run_err)?;
    print!("{}", session.vm.take_output());
    let artifacts = session.finish()?;
    eprintln!("[depyf] dumped {} artifacts (+ manifest.json) into {}", artifacts.len(), dir);
    Ok(())
}

fn cmd_table1(_args: &[String]) -> Result<(), CliError> {
    let t = run_table1();
    print!("{}", render_table1(&t));
    Ok(())
}

/// The one runtime-provisioning policy, shared by `run`, `dump` and
/// `replay`: backends that *require* a runtime get the shared process-wide
/// PJRT client (one executable cache + the persistent HLO disk cache
/// across sequential invocations) or fail hard; `USES_RUNTIME` backends
/// (sharded/batched) take it when the client starts and fall back to
/// eager lowering otherwise; everything else runs runtime-free.
fn provision_runtime(backends: &[&Arc<dyn Backend>]) -> Result<Option<Arc<Runtime>>, CliError> {
    if backends.iter().any(|b| b.requires_runtime()) {
        return Ok(Some(Runtime::shared()?));
    }
    if backends.iter().any(|b| b.capabilities().contains(Capabilities::USES_RUNTIME)) {
        return Ok(Runtime::shared().ok());
    }
    Ok(None)
}

/// `depyf serve`: concurrent dispatch over the table1 corpus.
fn cmd_serve(args: &[String]) -> Result<(), CliError> {
    let threads: usize = match flag_value(args, "--threads") {
        None => 4,
        Some(s) => s
            .parse()
            .ok()
            .filter(|&n: &usize| n >= 1 && n <= 256)
            .ok_or_else(|| usage(format!("bad --threads '{}' (expected 1..=256)", s)))?,
    };
    let iters: usize = match flag_value(args, "--iters") {
        None => 4,
        Some(s) => s
            .parse()
            .ok()
            .filter(|&n: &usize| n >= 1)
            .ok_or_else(|| usage(format!("bad --iters '{}' (expected >= 1)", s)))?,
    };
    let backend_name = flag_value(args, "--backend").unwrap_or_else(|| "eager".into());
    // Validate the name up front (usage error, exit 2, for typos) and
    // reject runtime-requiring backends: the PJRT client is
    // thread-confined, so xla cannot serve from worker threads.
    let backend = resolve_backend(&backend_name)?;
    if backend.requires_runtime() {
        return Err(usage(format!(
            "--backend {} requires the PJRT runtime, which is thread-confined; \
             serve supports eager, sharded, batched, codegen, pipelined, \
             recording:<b>, async:<b> and resilient:<b>",
            backend_name
        )));
    }
    let deadline_ms: Option<u64> = match flag_value(args, "--deadline-ms") {
        None => None,
        Some(s) => Some(
            s.parse()
                .ok()
                .filter(|&n: &u64| n >= 1)
                .ok_or_else(|| usage(format!("bad --deadline-ms '{}' (expected >= 1)", s)))?,
        ),
    };
    // Supervision tuning (only bites when the backend resolves to an
    // `async:` wrapper, whose worker pool runs under the supervisor).
    let defaults = depyf::serve::SupervisorConfig::default();
    let admission = match flag_value(args, "--admission") {
        None => defaults.policy,
        Some(s) => depyf::serve::AdmissionPolicy::parse(&s).ok_or_else(|| {
            usage(format!("bad --admission '{}' (expected block, shed or deadline-aware)", s))
        })?,
    };
    let queue_cap: usize = match flag_value(args, "--queue-cap") {
        None => defaults.queue_cap,
        Some(s) => s
            .parse()
            .ok()
            .filter(|&n: &usize| n >= 1)
            .ok_or_else(|| usage(format!("bad --queue-cap '{}' (expected >= 1)", s)))?,
    };
    let pool_workers: usize = match flag_value(args, "--pool-workers") {
        None => defaults.workers,
        Some(s) => s
            .parse()
            .ok()
            .filter(|&n: &usize| n >= 1 && n <= 64)
            .ok_or_else(|| usage(format!("bad --pool-workers '{}' (expected 1..=64)", s)))?,
    };
    let stall_ms: u64 = match flag_value(args, "--stall-ms") {
        None => defaults.stall_ms,
        Some(s) => s
            .parse()
            .ok()
            .filter(|&n: &u64| n >= 1)
            .ok_or_else(|| usage(format!("bad --stall-ms '{}' (expected >= 1)", s)))?,
    };
    let out_dir = flag_value(args, "--out").unwrap_or_else(|| "serve_out".into());
    let opts = depyf::serve::ServeOptions {
        threads,
        iters,
        backend: backend_name,
        out_dir: std::path::PathBuf::from(out_dir),
        deadline_ms,
        admission,
        queue_cap,
        pool_workers,
        stall_ms,
    };
    let report = depyf::serve::run_serve(&opts)?;
    print!("{}", report.render());
    Ok(())
}

fn cmd_replay(args: &[String]) -> Result<(), CliError> {
    let path = args.first().ok_or_else(|| {
        usage("replay needs a trace: depyf replay <trace.json|dump-dir> [--backend <name>|recorded] [--against <oracle>]")
    })?;
    // `--backend recorded` defers the choice to each bundle: re-run it on
    // the backend it was originally recorded against.
    let fixed_backend: Option<Arc<dyn Backend>> = match flag_value(args, "--backend") {
        None => Some(lookup_backend("eager").expect("eager is always registered")),
        Some(name) if name == "recorded" => None,
        Some(name) => Some(resolve_backend(&name)?),
    };
    let oracle = match flag_value(args, "--against") {
        None => None,
        Some(name) => Some(resolve_backend(&name)?),
    };
    let eps: f32 = match flag_value(args, "--eps") {
        None => 0.0,
        Some(s) => s
            .parse()
            .ok()
            .filter(|v: &f32| v.is_finite() && *v >= 0.0)
            .ok_or_else(|| usage(format!("bad --eps '{}' (expected a non-negative float)", s)))?,
    };
    let localize = !has_flag(args, "--no-localize");
    let opt_level = parse_opt_level(args)?;

    // A dump dir replays every Trace artifact its manifest indexes; a
    // file is a single bundle.
    let p = std::path::Path::new(path);
    let mut bundles = Vec::new();
    if p.is_dir() {
        for a in load_manifest(p)? {
            if a.kind == ArtifactKind::Trace {
                bundles.push(TraceBundle::load(&a.path)?);
            }
        }
        if bundles.is_empty() {
            return Err(run_err(format!("no trace artifacts indexed in {}/manifest.json", path)));
        }
    } else {
        bundles.push(TraceBundle::load(p)?);
    }

    let per_bundle: Vec<Arc<dyn Backend>> = bundles
        .iter()
        .map(|b| match &fixed_backend {
            Some(be) => Ok(Arc::clone(be)),
            None => resolve_backend(&b.backend).map_err(|e| {
                let m = match e {
                    CliError::Usage(m) | CliError::Run(m) => m,
                };
                run_err(format!("replay: bundle '{}' was recorded on backend '{}': {}", b.name, b.backend, m))
            }),
        })
        .collect::<Result<_, _>>()?;

    let mut consulted: Vec<&Arc<dyn Backend>> = per_bundle.iter().collect();
    if let Some(o) = &oracle {
        consulted.push(o);
    }
    let runtime = provision_runtime(&consulted)?;
    let opts = ReplayOptions { eps, runtime, localize, opt_level };
    let mut mismatches = 0usize;
    for (b, backend) in bundles.iter().zip(per_bundle.iter()) {
        let report = replay_bundle(b, backend.as_ref(), oracle.as_deref(), &opts)?;
        println!("{}", report.render());
        mismatches += report.mismatches.len();
    }
    if mismatches > 0 {
        return Err(run_err(format!("{} mismatch(es) across {} bundle(s)", mismatches, bundles.len())));
    }
    let on = match &fixed_backend {
        Some(be) => be.name().to_string(),
        None => "their recorded backends".to_string(),
    };
    eprintln!("[depyf] replayed {} bundle(s) on {}: no mismatches", bundles.len(), on);
    Ok(())
}

/// `depyf fuzz`: seeded program-level differential fuzzing.
fn cmd_fuzz(args: &[String]) -> Result<(), CliError> {
    let seed: u64 = match flag_value(args, "--seed") {
        None => 42,
        Some(s) => s.parse().map_err(|_| usage(format!("bad --seed '{}' (expected a u64)", s)))?,
    };
    let iters: u64 = match flag_value(args, "--iters") {
        None => 100,
        Some(s) => s
            .parse()
            .ok()
            .filter(|&n: &u64| n >= 1)
            .ok_or_else(|| usage(format!("bad --iters '{}' (expected >= 1)", s)))?,
    };
    let backends = match flag_value(args, "--backend") {
        None => Vec::new(), // the default sweep set
        Some(name) => {
            resolve_backend(&name)?; // typos are usage errors before any work
            vec![name]
        }
    };
    let opt_levels = match flag_value(args, "--opt-level") {
        None => Vec::new(), // O0 and O2
        Some(v) => vec![
            OptLevel::parse(&v).ok_or_else(|| usage(format!("unknown --opt-level '{}' (expected 0, 1 or 2)", v)))?,
        ],
    };
    let serve_threads: Option<usize> = if has_flag(args, "--serve") {
        Some(match flag_value(args, "--threads") {
            None => 4,
            Some(s) => s
                .parse()
                .ok()
                .filter(|&n: &usize| n >= 1 && n <= 64)
                .ok_or_else(|| usage(format!("bad --threads '{}' (expected 1..=64)", s)))?,
        })
    } else {
        if flag_value(args, "--threads").is_some() {
            return Err(usage("--threads only applies to fuzz --serve mode"));
        }
        None
    };
    let out_dir = flag_value(args, "--out").unwrap_or_else(|| "fuzz_out".into());
    let opts = depyf::fuzz::FuzzOptions {
        seed,
        iters,
        backends,
        opt_levels,
        budget: depyf::fuzz::DEFAULT_BUDGET,
        shrink: !has_flag(args, "--no-shrink"),
        serve_threads,
        bisect_opt: has_flag(args, "--bisect-opt"),
    };
    // The oracle traps panics with catch_unwind and reports them as
    // findings; silence the default hook so expected trips don't spray
    // backtraces over the report.
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let report = depyf::fuzz::run_fuzz(&opts);
    std::panic::set_hook(prev);
    let report = report.map_err(run_err)?;
    println!("{}", report.render());
    if !report.ok() {
        let dir = std::path::Path::new(&out_dir);
        for f in &report.failures {
            let p = f.save(dir).map_err(run_err)?;
            eprintln!("[depyf] wrote {}", p.display());
        }
        return Err(run_err(format!(
            "{} divergence(s); repro bundles in {} (replay a shrunken source with `depyf run`, \
             its trace with `depyf replay`)",
            report.failures.len(),
            out_dir
        )));
    }
    eprintln!("[depyf] fuzz: no divergences");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_is_usage_error() {
        assert_eq!(run_cli(&["bogus".to_string()]), 2);
        assert_eq!(run_cli(&[]), 2);
    }

    #[test]
    fn help_prints_and_succeeds() {
        assert_eq!(run_cli(&["help".to_string()]), 0);
    }

    #[test]
    fn unknown_backend_value_is_usage_error() {
        let args = vec!["run".to_string(), "nope.py".to_string(), "--backend".to_string(), "bogus".to_string()];
        assert_eq!(run_cli(&args), 2);
    }

    #[test]
    fn unknown_backend_error_lists_wrapper_grammar() {
        let Err(CliError::Usage(msg)) = resolve_backend("bogus") else {
            panic!("bogus backend must be a usage error");
        };
        assert!(msg.contains("codegen"), "registered list names codegen: {}", msg);
        assert!(msg.contains("recording:<inner>"), "wrapper grammar in error: {}", msg);
        assert!(msg.contains("async:<inner>"), "wrapper grammar in error: {}", msg);
        assert!(msg.contains("resilient:<inner>"), "wrapper grammar in error: {}", msg);
    }

    #[test]
    fn unknown_version_value_is_usage_error() {
        let args = vec!["disasm".to_string(), "nope.py".to_string(), "--version".to_string(), "2.7".to_string()];
        assert_eq!(run_cli(&args), 2);
    }

    #[test]
    fn missing_file_is_runtime_error() {
        let args = vec!["disasm".to_string(), "/definitely/not/here.py".to_string()];
        assert_eq!(run_cli(&args), 1);
    }

    fn s(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|p| p.to_string()).collect()
    }

    #[test]
    fn replay_usage_and_runtime_errors() {
        assert_eq!(run_cli(&s(&["replay"])), 2, "missing path is a usage error");
        assert_eq!(run_cli(&s(&["replay", "x.json", "--eps", "banana"])), 2);
        assert_eq!(run_cli(&s(&["replay", "x.json", "--opt-level", "9"])), 2);
        assert_eq!(run_cli(&s(&["replay", "x.json", "--eps", "-1"])), 2);
        assert_eq!(run_cli(&s(&["replay", "x.json", "--backend", "bogus"])), 2);
        assert_eq!(run_cli(&s(&["replay", "x.json", "--against", "bogus"])), 2);
        assert_eq!(run_cli(&s(&["replay", "/definitely/not/here.json"])), 1);
    }

    #[test]
    fn serve_usage_errors() {
        assert_eq!(run_cli(&s(&["serve", "--threads", "banana"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--threads", "0"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--threads", "999"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--iters", "0"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--deadline-ms", "0"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--deadline-ms", "soon"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--backend", "bogus"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--backend", "resilient:bogus"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--backend", "async:bogus"])), 2);
        // xla needs the PJRT runtime, which is thread-confined — serve
        // refuses it up front rather than crashing a worker.
        assert_eq!(run_cli(&s(&["serve", "--backend", "xla"])), 2);
        // Supervision tuning flags validate before any work starts.
        assert_eq!(run_cli(&s(&["serve", "--admission", "panic-wildly"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--queue-cap", "0"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--queue-cap", "lots"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--pool-workers", "0"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--pool-workers", "banana"])), 2);
        assert_eq!(run_cli(&s(&["serve", "--stall-ms", "0"])), 2);
    }

    #[test]
    fn fuzz_usage_errors() {
        assert_eq!(run_cli(&s(&["fuzz", "--seed", "banana"])), 2);
        assert_eq!(run_cli(&s(&["fuzz", "--iters", "0"])), 2);
        assert_eq!(run_cli(&s(&["fuzz", "--backend", "bogus"])), 2);
        assert_eq!(run_cli(&s(&["fuzz", "--opt-level", "9"])), 2);
        assert_eq!(run_cli(&s(&["fuzz", "--serve", "--threads", "0"])), 2);
        assert_eq!(run_cli(&s(&["fuzz", "--serve", "--threads", "999"])), 2);
        // --threads without --serve is a likely typo for serve mode.
        assert_eq!(run_cli(&s(&["fuzz", "--threads", "4"])), 2);
    }

    #[test]
    fn fuzz_serve_smoke_run_is_clean() {
        // Concurrent-dispatch mode end to end: two programs raced by two
        // threads through a shared cache on eager at O0, plus bisect
        // plumbing (a clean sweep just leaves first_divergent_opt unset).
        assert_eq!(
            run_cli(&s(&[
                "fuzz", "--seed", "1", "--iters", "2", "--backend", "eager", "--opt-level", "0",
                "--serve", "--threads", "2", "--bisect-opt",
            ])),
            0
        );
    }

    #[test]
    fn fuzz_smoke_run_is_clean() {
        // Tiny but real: two programs, differential on eager at O0.
        assert_eq!(run_cli(&s(&["fuzz", "--seed", "1", "--iters", "2", "--backend", "eager", "--opt-level", "0"])), 0);
    }

    #[test]
    fn async_wrapper_backend_names_resolve() {
        let wrapped = resolve_backend("async:eager").unwrap();
        assert!(wrapped.capabilities().contains(Capabilities::WRAPPER));
        assert!(wrapped.capabilities().contains(Capabilities::ASYNC));
        assert!(matches!(resolve_backend("async:nope"), Err(CliError::Usage(_))));
    }

    #[test]
    fn resilient_wrapper_backend_names_resolve() {
        let bare = resolve_backend("resilient").unwrap();
        assert_eq!(bare.name(), "eager", "transparent wrapper around eager");
        assert!(bare.capabilities().contains(Capabilities::WRAPPER));
        let wrapped = resolve_backend("resilient:sharded").unwrap();
        assert_eq!(wrapped.name(), "sharded");
        assert!(matches!(resolve_backend("resilient:nope"), Err(CliError::Usage(_))));
    }

    #[test]
    fn recording_wrapper_backend_names_resolve() {
        assert!(resolve_backend("recording").is_ok());
        let wrapped = resolve_backend("recording:sharded").unwrap();
        assert!(wrapped.capabilities().contains(Capabilities::WRAPPER));
        assert!(matches!(resolve_backend("recording:nope"), Err(CliError::Usage(_))));
    }

    /// End-to-end: record a dump with the recording backend, then replay
    /// the whole dump dir — plain, on sharded, and differentially.
    #[test]
    fn dump_with_recording_then_replay_round_trips() {
        let base = std::env::temp_dir().join(format!("depyf_cli_replay_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let prog = base.join("prog.py");
        std::fs::write(
            &prog,
            "def f(x):\n    return ((x @ x) + 1).relu().softmax().sum()\nprint(f(torch.ones([4, 4])).item())\nprint(f(torch.ones([4, 4])).item())\n",
        )
        .unwrap();
        let dump = base.join("dump");
        let dump_s = dump.to_string_lossy().into_owned();
        let prog_s = prog.to_string_lossy().into_owned();
        assert_eq!(run_cli(&s(&["dump", &prog_s, &dump_s, "--backend", "recording"])), 0);
        assert!(dump.join("manifest.json").exists());
        let traces: Vec<_> = std::fs::read_dir(&dump)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with("__trace_"))
            .collect();
        assert_eq!(traces.len(), 1, "one compiled fn, one trace bundle");
        // Replay against recorded outputs (bit-exact on the recording
        // backend's own executor), re-execute on sharded, and differential
        // sharded-vs-eager. sharded/batched may lower to PJRT when the
        // shared runtime starts, so those replays use the XLA tolerance.
        assert_eq!(run_cli(&s(&["replay", &dump_s])), 0);
        // --backend recorded resolves each bundle's originally-recorded
        // backend (eager here, via the recording wrapper).
        assert_eq!(run_cli(&s(&["replay", &dump_s, "--backend", "recorded"])), 0);
        // Bisection workflow: the same trace replays bitwise-clean with the
        // optimizer off entirely.
        assert_eq!(run_cli(&s(&["replay", &dump_s, "--opt-level", "0"])), 0);
        assert_eq!(run_cli(&s(&["replay", &dump_s, "--backend", "sharded", "--eps", "1e-4"])), 0);
        assert_eq!(
            run_cli(&s(&["replay", &dump_s, "--backend", "sharded", "--against", "eager", "--eps", "1e-4"])),
            0
        );
        // A single-bundle file path works too.
        let trace_path = traces[0].path().to_string_lossy().into_owned();
        assert_eq!(run_cli(&s(&["replay", &trace_path, "--backend", "batched", "--eps", "1e-4"])), 0);
        std::fs::remove_dir_all(&base).ok();
    }
}
