//! depyf-rs CLI — the leader entrypoint.
//!
//! Run `depyf help` for the full usage text. Usage errors (unknown
//! commands, flags or flag values) exit with code 2; runtime failures exit
//! with code 1.
//!
//! (Hand-rolled arg parsing: the offline environment has no clap.)

use std::rc::Rc;

use depyf::api::{backend_names, lookup_backend, Backend, Capabilities, Session};
use depyf::bytecode::{disassemble, IsaVersion};
use depyf::corpus::{render_table1, run_table1};
use depyf::decompiler::baselines::all_tools_rc;
use depyf::decompiler::DecompilerTool;
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::pylang::compile_module;
use depyf::runtime::Runtime;
use depyf::vm::Vm;
use depyf::DepyfError;

const USAGE: &str = "\
depyf — open the opaque box of the pylang compiler

usage:
  depyf run <file.py> [--compile] [--backend <name>] [--version <V>]
      Execute a program; with --compile (or --backend) it runs under the
      dynamo frontend and reports compiler metrics.
  depyf disasm <file.py> [--version <V>]
      Compile and print the bytecode disassembly.
  depyf decompile <file.py> [--tool depyf|pycdc|decompyle3|uncompyle6] [--version <V>]
      Compile, then decompile the bytecode back to source.
  depyf dump <file.py> <dir> [--backend <name>] [--version <V>]
      prepare_debug: run under the compiler and dump every artifact
      (full_code.py, __compiled_fn_*.py, __transformed_*.py, disassembly,
      guards) plus a machine-readable manifest.json into <dir>.
  depyf table1
      Regenerate the paper's Table 1 correctness matrix.
  depyf help
      Print this text.

flags:
  --version <V>    ISA version: 3.8, 3.9, 3.10 or 3.11 (default 3.11)
  --backend <name> A registered graph backend; custom backends plug in via
                   depyf::api::register_backend. Built-ins:
                     eager    node-by-node CPU reference executor
                     xla      one PJRT executable per captured graph
                     sharded  splits graphs at articulation points into
                              several PJRT/eager executables and stitches
                              outputs (dumps __plan_*.json + __hlo_*.txt)
                     batched  pads/buckets the dynamic leading dim so one
                              executable serves multiple guard entries
                   sharded/batched lower to PJRT when the shared runtime is
                   available and to the eager executor otherwise.

exit codes: 0 success, 1 runtime error, 2 usage error
";

/// CLI failure, split by exit code: 2 for usage errors, 1 for runtime.
enum CliError {
    Usage(String),
    Run(String),
}

impl From<DepyfError> for CliError {
    fn from(e: DepyfError) -> CliError {
        CliError::Run(e.to_string())
    }
}

fn usage(msg: impl Into<String>) -> CliError {
    CliError::Usage(msg.into())
}

fn run_err(e: impl std::fmt::Display) -> CliError {
    CliError::Run(e.to_string())
}

fn parse_version(args: &[String]) -> Result<IsaVersion, CliError> {
    match flag_value(args, "--version").as_deref() {
        Some("3.8") => Ok(IsaVersion::V38),
        Some("3.9") => Ok(IsaVersion::V39),
        Some("3.10") => Ok(IsaVersion::V310),
        Some("3.11") | None => Ok(IsaVersion::V311),
        Some(other) => Err(usage(format!("unknown --version '{}' (expected 3.8, 3.9, 3.10 or 3.11)", other))),
    }
}

/// Resolve `--backend <name>` against the registry; absent flag → None.
fn parse_backend(args: &[String]) -> Result<Option<Rc<dyn Backend>>, CliError> {
    match flag_value(args, "--backend") {
        None => Ok(None),
        Some(name) => lookup_backend(&name).map(Some).ok_or_else(|| {
            usage(format!("unknown --backend '{}' (registered: {})", name, backend_names().join(", ")))
        }),
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn read_source(path: &str) -> Result<String, CliError> {
    std::fs::read_to_string(path).map_err(|e| run_err(format!("read {}: {}", path, e)))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run_cli(&args);
    std::process::exit(code);
}

fn run_cli(args: &[String]) -> i32 {
    let Some(cmd) = args.first() else {
        eprint!("{}", USAGE);
        return 2;
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "run" => cmd_run(rest),
        "disasm" => cmd_disasm(rest),
        "decompile" => cmd_decompile(rest),
        "dump" => cmd_dump(rest),
        "table1" => cmd_table1(rest),
        "help" | "--help" | "-h" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => Err(usage(format!("unknown command '{}'", other))),
    };
    match result {
        Ok(()) => 0,
        Err(CliError::Usage(m)) => {
            eprintln!("error: {}\n", m);
            eprint!("{}", USAGE);
            2
        }
        Err(CliError::Run(m)) => {
            eprintln!("error: {}", m);
            1
        }
    }
}

fn cmd_run(args: &[String]) -> Result<(), CliError> {
    let file = args
        .first()
        .ok_or_else(|| usage("run needs a file: depyf run <file.py> [--compile] [--backend <name>]"))?;
    let version = parse_version(args)?;
    let backend = parse_backend(args)?;
    let src = read_source(file)?;
    let mut vm = Vm::new();
    let dynamo = if has_flag(args, "--compile") || backend.is_some() {
        let backend = match backend {
            Some(b) => b,
            None => lookup_backend("eager").expect("eager is always registered"),
        };
        let needs_runtime = backend.requires_runtime();
        let wants_runtime = backend.capabilities().contains(Capabilities::USES_RUNTIME);
        let config = DynamoConfig { backend, ..Default::default() };
        let d = if needs_runtime {
            // Process-wide runtime: one PJRT client, one executable cache,
            // plus the persistent HLO cache shared across invocations.
            let rt = Runtime::shared()?;
            Dynamo::with_runtime(config, rt)
        } else if wants_runtime {
            // sharded/batched accelerate with PJRT when available but run
            // fine on the eager executor when the client cannot start.
            match Runtime::shared() {
                Ok(rt) => Dynamo::with_runtime(config, rt),
                Err(_) => Dynamo::new(config),
            }
        } else {
            Dynamo::new(config)
        };
        vm.eval_hook = Some(d.clone());
        Some(d)
    } else {
        None
    };
    vm.exec_source(&src, version).map_err(run_err)?;
    print!("{}", vm.take_output());
    if let Some(d) = dynamo {
        eprintln!("[depyf] {}", d.metrics.report());
    }
    Ok(())
}

fn cmd_disasm(args: &[String]) -> Result<(), CliError> {
    let file = args.first().ok_or_else(|| usage("disasm needs a file: depyf disasm <file.py>"))?;
    let version = parse_version(args)?;
    let src = read_source(file)?;
    let code = compile_module(&src, file, version).map_err(run_err)?;
    print!("{}", disassemble(&code));
    Ok(())
}

fn cmd_decompile(args: &[String]) -> Result<(), CliError> {
    let file = args.first().ok_or_else(|| {
        usage("decompile needs a file: depyf decompile <file.py> [--tool depyf|pycdc|decompyle3|uncompyle6]")
    })?;
    let version = parse_version(args)?;
    let src = read_source(file)?;
    let toolname = flag_value(args, "--tool").unwrap_or_else(|| "depyf".into());
    let tool = all_tools_rc()
        .into_iter()
        .find(|t| t.name() == toolname)
        .ok_or_else(|| usage(format!("unknown --tool '{}' (expected depyf, pycdc, decompyle3 or uncompyle6)", toolname)))?;
    let code = compile_module(&src, file, version).map_err(run_err)?;
    let out = tool.decompile_module(&code).map_err(run_err)?;
    print!("{}", out);
    Ok(())
}

fn cmd_dump(args: &[String]) -> Result<(), CliError> {
    let file = args.first().ok_or_else(|| usage("dump needs a file and a dir: depyf dump <file.py> <dir>"))?;
    let dir = args.get(1).ok_or_else(|| usage("dump needs a dir: depyf dump <file.py> <dir>"))?;
    let version = parse_version(args)?;
    let backend = parse_backend(args)?;
    let src = read_source(file)?;
    let mut builder = Session::builder().dump_to(dir).isa(version);
    if let Some(b) = backend {
        if b.requires_runtime() {
            // Shared process-wide runtime: sequential `depyf dump` runs
            // reuse the persisted HLO cache index instead of spinning up
            // a cold client + cold cache every time.
            builder = builder.runtime(Runtime::shared()?);
        } else if b.capabilities().contains(Capabilities::USES_RUNTIME) {
            // Optional acceleration (sharded/batched): take the shared
            // runtime when PJRT starts, fall back to eager partitions
            // otherwise.
            if let Ok(rt) = Runtime::shared() {
                builder = builder.runtime(rt);
            }
        }
        builder = builder.backend(b);
    }
    let mut session = builder.build()?;
    session.run_source("main", &src).map_err(run_err)?;
    print!("{}", session.vm.take_output());
    let artifacts = session.finish()?;
    eprintln!("[depyf] dumped {} artifacts (+ manifest.json) into {}", artifacts.len(), dir);
    Ok(())
}

fn cmd_table1(_args: &[String]) -> Result<(), CliError> {
    let t = run_table1();
    print!("{}", render_table1(&t));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_is_usage_error() {
        assert_eq!(run_cli(&["bogus".to_string()]), 2);
        assert_eq!(run_cli(&[]), 2);
    }

    #[test]
    fn help_prints_and_succeeds() {
        assert_eq!(run_cli(&["help".to_string()]), 0);
    }

    #[test]
    fn unknown_backend_value_is_usage_error() {
        let args = vec!["run".to_string(), "nope.py".to_string(), "--backend".to_string(), "bogus".to_string()];
        assert_eq!(run_cli(&args), 2);
    }

    #[test]
    fn unknown_version_value_is_usage_error() {
        let args = vec!["disasm".to_string(), "nope.py".to_string(), "--version".to_string(), "2.7".to_string()];
        assert_eq!(run_cli(&args), 2);
    }

    #[test]
    fn missing_file_is_runtime_error() {
        let args = vec!["disasm".to_string(), "/definitely/not/here.py".to_string()];
        assert_eq!(run_cli(&args), 1);
    }
}
