//! depyf-rs CLI — the leader entrypoint.
//!
//! ```text
//! depyf run <file.py> [--compile] [--backend eager|xla] [--version 3.8..3.11]
//! depyf disasm <file.py> [--version V]       # compile + disassemble
//! depyf decompile <file.py> [--tool NAME]    # bytecode -> source
//! depyf dump <file.py> <dir>                 # prepare_debug: run + dump all
//! depyf table1                               # regenerate the paper's Table 1
//! ```
//!
//! (Hand-rolled arg parsing: the offline environment has no clap.)

use depyf::backend::BackendKind;
use depyf::bytecode::{disassemble, IsaVersion};
use depyf::corpus::{render_table1, run_table1};
use depyf::decompiler::baselines::all_tools_rc;
use depyf::dynamo::{Dynamo, DynamoConfig};
use depyf::pylang::compile_module;
use depyf::runtime::Runtime;
use depyf::session::DebugSession;
use depyf::vm::Vm;

fn parse_version(args: &[String]) -> IsaVersion {
    match flag_value(args, "--version").as_deref() {
        Some("3.8") => IsaVersion::V38,
        Some("3.9") => IsaVersion::V39,
        Some("3.10") => IsaVersion::V310,
        Some("3.11") | None => IsaVersion::V311,
        Some(other) => {
            eprintln!("unknown version '{}', using 3.11", other);
            IsaVersion::V311
        }
    }
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn has_flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn read_source(path: &str) -> Result<String, String> {
    std::fs::read_to_string(path).map_err(|e| format!("read {}: {}", path, e))
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run_cli(&args);
    std::process::exit(code);
}

fn run_cli(args: &[String]) -> i32 {
    let Some(cmd) = args.first() else {
        eprintln!("usage: depyf <run|disasm|decompile|dump|table1> ...");
        return 2;
    };
    let rest = &args[1..];
    let result = match cmd.as_str() {
        "run" => cmd_run(rest),
        "disasm" => cmd_disasm(rest),
        "decompile" => cmd_decompile(rest),
        "dump" => cmd_dump(rest),
        "table1" => cmd_table1(rest),
        other => Err(format!("unknown command '{}'", other)),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {}", e);
            1
        }
    }
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let file = args.first().ok_or("usage: depyf run <file.py> [--compile] [--backend eager|xla]")?;
    let src = read_source(file)?;
    let version = parse_version(args);
    let mut vm = Vm::new();
    let dynamo = if has_flag(args, "--compile") {
        let backend = match flag_value(args, "--backend").as_deref() {
            Some("xla") => BackendKind::Xla,
            _ => BackendKind::Eager,
        };
        let d = if backend == BackendKind::Xla {
            let rt = Runtime::cpu()?;
            Dynamo::with_runtime(DynamoConfig { backend, ..Default::default() }, rt)
        } else {
            Dynamo::new(DynamoConfig { backend, ..Default::default() })
        };
        vm.eval_hook = Some(d.clone());
        Some(d)
    } else {
        None
    };
    vm.exec_source(&src, version).map_err(|e| e.to_string())?;
    print!("{}", vm.take_output());
    if let Some(d) = dynamo {
        eprintln!("[depyf] {}", d.metrics.report());
    }
    Ok(())
}

fn cmd_disasm(args: &[String]) -> Result<(), String> {
    let file = args.first().ok_or("usage: depyf disasm <file.py>")?;
    let src = read_source(file)?;
    let version = parse_version(args);
    let code = compile_module(&src, file, version).map_err(|e| e.to_string())?;
    print!("{}", disassemble(&code));
    Ok(())
}

fn cmd_decompile(args: &[String]) -> Result<(), String> {
    let file = args.first().ok_or("usage: depyf decompile <file.py> [--tool depyf|pycdc|decompyle3|uncompyle6]")?;
    let src = read_source(file)?;
    let version = parse_version(args);
    let toolname = flag_value(args, "--tool").unwrap_or_else(|| "depyf".into());
    let tool = all_tools_rc()
        .into_iter()
        .find(|t| t.name() == toolname)
        .ok_or_else(|| format!("unknown tool '{}'", toolname))?;
    let code = compile_module(&src, file, version).map_err(|e| e.to_string())?;
    let out = tool.decompile_module(&code).map_err(|e| e.to_string())?;
    print!("{}", out);
    Ok(())
}

fn cmd_dump(args: &[String]) -> Result<(), String> {
    let file = args.first().ok_or("usage: depyf dump <file.py> <dir>")?;
    let dir = args.get(1).ok_or("usage: depyf dump <file.py> <dir>")?;
    let src = read_source(file)?;
    let mut session = DebugSession::prepare_debug(dir, BackendKind::Eager)?;
    session.set_version(parse_version(args));
    session.run_source("main", &src).map_err(|e| e.to_string())?;
    print!("{}", session.vm.take_output());
    let files = session.finish()?;
    eprintln!("[depyf] dumped {} files into {}", files.len(), dir);
    Ok(())
}

fn cmd_table1(_args: &[String]) -> Result<(), String> {
    let t = run_table1();
    print!("{}", render_table1(&t));
    Ok(())
}
