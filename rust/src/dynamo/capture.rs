//! Symbolic evaluation of bytecode: dynamo's frontend.
//!
//! Executes a function's bytecode over [`Sym`] values: Python-level
//! computation (ints, lists, loops over ranges) runs *concretely* — loops
//! unroll, branches fold — while tensor operations become graph nodes. The
//! first operation that cannot be represented produces a graph **break**
//! ([`Outcome::Break`] / [`Outcome::Branch`]); unsupported constructs abort
//! the capture entirely (the function then runs uncompiled).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use super::guards::Guard;
use super::sym::{Origin, Sym};
use crate::bytecode::{BinOp, CmpOp, CodeObject, Instr, UnOp};
use crate::graph::{Graph, NodeId, OpKind};
use crate::value::Value;
use crate::vm;

/// What the transformed bytecode must replay inline at the break site.
#[derive(Clone, Debug)]
pub enum InlineEmit {
    /// `callee(args...)` — operands: [callee, arg0..argn-1].
    CallFn(u32),
    /// `recv.name(args...)` — operands: [recv, arg0..argn-1].
    CallMethod { name: String, argc: u32 },
    /// `iter(obj)` — operands: [obj].
    GetIterOp,
    /// `obj[idx]` — operands: [obj, idx].
    Subscr,
    /// tensor-op the graph can't hold — operands: [a, b].
    BinaryInline(BinOp),
    CompareInline(CmpOp),
    ContainsInline(bool),
    UnaryInline(UnOp),
    /// `global = value` — operands: [value]; no result.
    StoreGlobalInline(String),
    /// `obj[idx] = value` — operands: [value, obj, idx]; no result.
    StoreSubscrInline,
    /// `raise value` — operands: [value]; no resume.
    RaiseInline,
    /// unpack a tensor — operands: [seq]; results = n.
    UnpackInline(u32),
}

impl InlineEmit {
    pub fn results(&self) -> usize {
        match self {
            InlineEmit::StoreGlobalInline(_) | InlineEmit::StoreSubscrInline | InlineEmit::RaiseInline => 0,
            InlineEmit::UnpackInline(n) => *n as usize,
            _ => 1,
        }
    }

    pub fn describe(&self) -> String {
        match self {
            InlineEmit::CallFn(n) => format!("call({})", n),
            InlineEmit::CallMethod { name, argc } => format!(".{}({})", name, argc),
            InlineEmit::GetIterOp => "iter()".into(),
            InlineEmit::Subscr => "subscript".into(),
            InlineEmit::BinaryInline(op) => format!("binary {}", op.symbol()),
            InlineEmit::CompareInline(op) => format!("compare {}", op.symbol()),
            InlineEmit::ContainsInline(_) => "contains".into(),
            InlineEmit::UnaryInline(op) => format!("unary {}", op.symbol().trim()),
            InlineEmit::StoreGlobalInline(n) => format!("store global {}", n),
            InlineEmit::StoreSubscrInline => "store subscript".into(),
            InlineEmit::RaiseInline => "raise".into(),
            InlineEmit::UnpackInline(n) => format!("unpack {}", n),
        }
    }
}

#[derive(Debug)]
pub enum Outcome {
    /// Ran to RETURN_VALUE: full-graph capture.
    Return(Sym),
    /// Graph break: replay `emit` over `operands` inline, then resume at
    /// `at + 1` with `results` extra stack values.
    Break { at: usize, emit: InlineEmit, operands: Vec<Sym>, stack: Vec<Sym>, locals: Vec<Option<Sym>>, reason: String },
    /// Data-dependent branch on a tensor: two resume points.
    Branch { at: usize, cond: Sym, true_at: usize, false_at: usize, stack: Vec<Sym>, locals: Vec<Option<Sym>>, reason: String },
}

/// A completed capture.
pub struct Capture {
    pub graph: Graph,
    /// Origin of each graph input (parallel to `graph.inputs`).
    pub input_origins: Vec<Origin>,
    pub guards: Vec<Guard>,
    pub outcome: Outcome,
    pub traced_instrs: usize,
}

/// Capture failure → the function runs uncompiled.
#[derive(Debug, Clone)]
pub struct Abort(pub String);

pub struct Limits {
    pub max_instrs: usize,
    pub max_nodes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_instrs: 20_000, max_nodes: 2_000 }
    }
}

struct Tracer<'a> {
    code: &'a CodeObject,
    globals: &'a HashMap<String, Value>,
    graph: Graph,
    input_origins: Vec<Origin>,
    lifted: HashMap<String, NodeId>,
    guards: Vec<Guard>,
    guard_keys: std::collections::HashSet<String>,
    stack: Vec<Sym>,
    locals: Vec<Option<Sym>>,
    limits: Limits,
    traced: usize,
}

type Step = Result<Option<Outcome>, Abort>;

pub fn capture(
    code: &Rc<CodeObject>,
    args: &[Value],
    globals: &HashMap<String, Value>,
    graph_name: &str,
    limits: Limits,
) -> Result<Capture, Abort> {
    if !code.freevars.is_empty() || !code.cellvars.is_empty() {
        return Err(Abort("function uses closures".into()));
    }
    if args.len() != code.argcount {
        return Err(Abort(format!("arity mismatch: {} args for {}", args.len(), code.argcount)));
    }
    let mut t = Tracer {
        code,
        globals,
        graph: Graph::new(graph_name),
        input_origins: Vec::new(),
        lifted: HashMap::new(),
        guards: Vec::new(),
        guard_keys: std::collections::HashSet::new(),
        stack: Vec::new(),
        locals: vec![None; code.varnames.len().max(code.argcount)],
        limits,
        traced: 0,
    };
    for (i, a) in args.iter().enumerate() {
        let sym = t.value_to_sym(a, Some(Origin::Arg(i)))?;
        t.locals[i] = Some(sym);
    }
    let outcome = t.run()?;
    Ok(Capture {
        graph: t.graph,
        input_origins: t.input_origins,
        guards: t.guards,
        outcome,
        traced_instrs: t.traced,
    })
}

impl<'a> Tracer<'a> {
    // ---- guards & lifting ----

    fn add_guard(&mut self, g: Guard) {
        let key = g.describe();
        if self.guard_keys.insert(key) {
            self.guards.push(g);
        }
    }

    fn lift_tensor(&mut self, t: &crate::tensor::Tensor, origin: Origin) -> NodeId {
        let key = origin.describe();
        if let Some(&id) = self.lifted.get(&key) {
            return id;
        }
        let id = self.graph.placeholder(&format!("l_{}", key), t.shape());
        self.lifted.insert(key, id);
        self.input_origins.push(origin.clone());
        self.add_guard(Guard::TensorShape { origin, shape: t.shape().to_vec() });
        id
    }

    /// Convert a concrete runtime value into a Sym, adding guards.
    fn value_to_sym(&mut self, v: &Value, origin: Option<Origin>) -> Result<Sym, Abort> {
        match v {
            Value::Tensor(t) => match origin {
                Some(o) => Ok(Sym::Tensor(self.lift_tensor(t, o))),
                None => Ok(Sym::Tensor(self.graph.const_tensor((**t).clone()))),
            },
            Value::None | Value::Bool(_) | Value::Int(_) | Value::Float(_) | Value::Str(_) | Value::Range(..) => {
                if let Some(o) = &origin {
                    self.add_guard(Guard::ConstEq { origin: o.clone(), value: v.clone() });
                }
                Ok(Sym::Const { value: v.clone(), origin })
            }
            Value::Builtin(_) | Value::Func(_) | Value::Dict(_) | Value::CompiledGraph(_) => {
                if let Some(o) = &origin {
                    self.add_guard(Guard::Identity { origin: o.clone(), value: v.clone() });
                }
                Ok(Sym::Const { value: v.clone(), origin })
            }
            Value::List(l) => {
                let o = origin.ok_or_else(|| Abort("list value without origin".into()))?;
                self.add_guard(Guard::Len { origin: o.clone(), len: l.borrow().len() });
                let items: Result<Vec<Sym>, Abort> = l
                    .borrow()
                    .iter()
                    .enumerate()
                    .map(|(i, e)| self.value_to_sym(e, Some(o.clone().index(Value::Int(i as i64)))))
                    .collect();
                Ok(Sym::List { items: Rc::new(RefCell::new(items?)), external: true })
            }
            Value::Tuple(t) => {
                let o = origin.ok_or_else(|| Abort("tuple value without origin".into()))?;
                self.add_guard(Guard::Len { origin: o.clone(), len: t.len() });
                let items: Result<Vec<Sym>, Abort> = t
                    .iter()
                    .enumerate()
                    .map(|(i, e)| self.value_to_sym(e, Some(o.clone().index(Value::Int(i as i64)))))
                    .collect();
                Ok(Sym::Tuple(Rc::new(items?)))
            }
            Value::Iter(it) => {
                let o = origin.ok_or_else(|| Abort("iterator without origin".into()))?;
                let b = it.borrow();
                self.add_guard(Guard::IterRemaining { origin: o.clone(), len: b.items.len().saturating_sub(b.pos) });
                let items: Result<Vec<Sym>, Abort> = b.items[b.pos..]
                    .iter()
                    .enumerate()
                    .map(|(i, e)| self.value_to_sym(e, Some(o.clone().index(Value::Int(i as i64)))))
                    .collect();
                Ok(Sym::Iter { items: Rc::new(RefCell::new(items?)), pos: 0 })
            }
            Value::Slice(_) => Ok(Sym::Const { value: v.clone(), origin }),
            other => Err(Abort(format!("unsupported argument type {}", other.type_name()))),
        }
    }

    /// Tensor node for a sym participating in a tensor op.
    fn tensorify(&mut self, s: &Sym) -> Result<NodeId, Abort> {
        match s {
            Sym::Tensor(id) => Ok(*id),
            Sym::Const { value, .. } => match value {
                Value::Int(i) => Ok(self.graph.const_scalar(*i as f64)),
                Value::Float(f) => Ok(self.graph.const_scalar(*f)),
                Value::Bool(b) => Ok(self.graph.const_scalar(*b as i64 as f64)),
                other => Err(Abort(format!("cannot use {} in tensor op", other.type_name()))),
            },
            other => Err(Abort(format!("cannot use {} in tensor op", other.type_desc()))),
        }
    }

    fn is_tensorish(s: &Sym) -> bool {
        matches!(s, Sym::Tensor(_))
    }

    fn add_node(&mut self, op: OpKind, args: Vec<NodeId>) -> Result<Sym, Abort> {
        if self.graph.nodes.len() > self.limits.max_nodes {
            return Err(Abort("graph too large".into()));
        }
        let id = self.graph.add_op(op, args).map_err(|e| Abort(e.to_string()))?;
        Ok(Sym::Tensor(id))
    }

    // ---- driver ----

    fn pop(&mut self) -> Result<Sym, Abort> {
        self.stack.pop().ok_or_else(|| Abort("symbolic stack underflow".into()))
    }

    fn popn(&mut self, n: usize) -> Result<Vec<Sym>, Abort> {
        if self.stack.len() < n {
            return Err(Abort("symbolic stack underflow".into()));
        }
        Ok(self.stack.split_off(self.stack.len() - n))
    }

    fn brk(&mut self, at: usize, emit: InlineEmit, operands: Vec<Sym>, reason: &str) -> Outcome {
        Outcome::Break {
            at,
            emit,
            operands,
            stack: self.stack.clone(),
            locals: self.locals.clone(),
            reason: reason.to_string(),
        }
    }

    fn run(&mut self) -> Result<Outcome, Abort> {
        let mut ip = 0usize;
        loop {
            self.traced += 1;
            if self.traced > self.limits.max_instrs {
                return Err(Abort("trace budget exceeded (unbounded python loop?)".into()));
            }
            let Some(instr) = self.code.instrs.get(ip).cloned() else {
                return Err(Abort(format!("symbolic ip {} out of range", ip)));
            };
            let cur = ip;
            ip += 1;
            match self.step(&instr, cur, &mut ip)? {
                Some(outcome) => return Ok(outcome),
                None => continue,
            }
        }
    }

    /// Execute one instruction; Some(outcome) ends the capture.
    fn step(&mut self, instr: &Instr, cur: usize, ip: &mut usize) -> Step {
        match instr {
            Instr::Nop => {}
            Instr::LoadConst(c) => {
                let v = vm_const(self.code, *c)?;
                self.stack.push(Sym::constant(v));
            }
            Instr::LoadFast(i) => {
                let s = self.locals.get(*i as usize).cloned().flatten().ok_or_else(|| {
                    Abort(format!(
                        "local '{}' referenced before assignment",
                        self.code.varnames.get(*i as usize).cloned().unwrap_or_default()
                    ))
                })?;
                self.stack.push(s);
            }
            Instr::StoreFast(i) => {
                let s = self.pop()?;
                let idx = *i as usize;
                if idx >= self.locals.len() {
                    self.locals.resize(idx + 1, None);
                }
                self.locals[idx] = Some(s);
            }
            Instr::LoadGlobal(n) => {
                let name = self.code.names[*n as usize].clone();
                let v = self
                    .globals
                    .get(&name)
                    .cloned()
                    .ok_or_else(|| Abort(format!("global '{}' not defined at capture", name)))?;
                let s = self.value_to_sym(&v, Some(Origin::Global(name)))?;
                self.stack.push(s);
            }
            Instr::StoreGlobal(n) => {
                let name = self.code.names[*n as usize].clone();
                let val = self.pop()?;
                return Ok(Some(self.brk(cur, InlineEmit::StoreGlobalInline(name.clone()), vec![val], &format!("side effect: global store to '{}'", name))));
            }
            Instr::LoadDeref(_) | Instr::StoreDeref(_) | Instr::LoadClosure(_) => {
                return Err(Abort("closure variable access".into()));
            }
            Instr::MakeFunction(_) => return Err(Abort("nested function construction".into())),
            Instr::PopTop => {
                self.pop()?;
            }
            Instr::DupTop => {
                let s = self.stack.last().cloned().ok_or_else(|| Abort("underflow".into()))?;
                self.stack.push(s);
            }
            Instr::RotTwo => {
                let n = self.stack.len();
                if n < 2 {
                    return Err(Abort("underflow".into()));
                }
                self.stack.swap(n - 1, n - 2);
            }
            Instr::RotThree => {
                let c = self.pop()?;
                let b = self.pop()?;
                let a = self.pop()?;
                self.stack.push(c);
                self.stack.push(a);
                self.stack.push(b);
            }
            Instr::Binary(op) => {
                let b = self.pop()?;
                let a = self.pop()?;
                return self.binary(cur, *op, a, b);
            }
            Instr::Unary(op) => {
                let a = self.pop()?;
                match (op, &a) {
                    (UnOp::Neg, Sym::Tensor(id)) => {
                        let s = self.add_node(OpKind::Neg, vec![*id])?;
                        self.stack.push(s);
                    }
                    (UnOp::Pos, Sym::Tensor(_)) => self.stack.push(a),
                    (UnOp::Not, Sym::Tensor(_)) => {
                        return Ok(Some(self.brk(cur, InlineEmit::UnaryInline(*op), vec![a], "data-dependent `not tensor`")));
                    }
                    _ => match a.as_value() {
                        Some(v) => {
                            let r = match op {
                                UnOp::Not => Value::Bool(!v.truthy().map_err(|e| Abort(e.into()))?),
                                UnOp::Neg => vm::binary_op_values(BinOp::Sub, &Value::Int(0), &v).map_err(Abort)?,
                                UnOp::Pos => v,
                            };
                            self.stack.push(Sym::constant(r));
                        }
                        None => return Err(Abort(format!("unary {} on {}", op.symbol().trim(), a.type_desc()))),
                    },
                }
            }
            Instr::Compare(cmp) => {
                let b = self.pop()?;
                let a = self.pop()?;
                if Self::is_tensorish(&a) || Self::is_tensorish(&b) {
                    return Ok(Some(self.brk(cur, InlineEmit::CompareInline(*cmp), vec![a, b], "tensor comparison materializes a value")));
                }
                match (a.as_value(), b.as_value()) {
                    (Some(x), Some(y)) => {
                        let r = vm::interp_compare(*cmp, &x, &y).map_err(Abort)?;
                        self.stack.push(Sym::constant(r));
                    }
                    _ => return Err(Abort("comparison on traced structure".into())),
                }
            }
            Instr::ContainsOp(inv) => {
                let container = self.pop()?;
                let item = self.pop()?;
                if Self::is_tensorish(&container) || Self::is_tensorish(&item) {
                    return Ok(Some(self.brk(cur, InlineEmit::ContainsInline(*inv), vec![item, container], "tensor containment")));
                }
                match (item.as_value(), container.as_value()) {
                    (Some(i), Some(c)) => {
                        let found = vm::interp_contains(&c, &i).map_err(Abort)?;
                        self.stack.push(Sym::constant(Value::Bool(found != *inv)));
                    }
                    _ => return Err(Abort("containment on traced structure".into())),
                }
            }
            Instr::IsOp(inv) => {
                let b = self.pop()?;
                let a = self.pop()?;
                // `tensor is None` folds to False.
                let r = match (&a, &b) {
                    (Sym::Tensor(_), Sym::Const { value: Value::None, .. }) | (Sym::Const { value: Value::None, .. }, Sym::Tensor(_)) => false,
                    _ => match (a.as_value(), b.as_value()) {
                        (Some(x), Some(y)) => x.is_identical(&y),
                        _ => return Err(Abort("identity test on traced structure".into())),
                    },
                };
                self.stack.push(Sym::constant(Value::Bool(r != *inv)));
            }
            Instr::Jump(t) => {
                *ip = *t as usize;
            }
            Instr::PopJumpIfFalse(t) | Instr::PopJumpIfTrue(t) => {
                let jump_on = matches!(instr, Instr::PopJumpIfTrue(_));
                let cond = self.pop()?;
                if Self::is_tensorish(&cond) {
                    let (true_at, false_at) = if jump_on { (*t as usize, cur + 1) } else { (cur + 1, *t as usize) };
                    return Ok(Some(Outcome::Branch {
                        at: cur,
                        cond,
                        true_at,
                        false_at,
                        stack: self.stack.clone(),
                        locals: self.locals.clone(),
                        reason: "data-dependent control flow on a tensor".into(),
                    }));
                }
                let v = cond.as_value().ok_or_else(|| Abort("branch on traced structure".into()))?;
                let truth = v.truthy().map_err(|e| Abort(e.into()))?;
                if truth == jump_on {
                    *ip = *t as usize;
                }
            }
            Instr::JumpIfFalseOrPop(t) | Instr::JumpIfTrueOrPop(t) => {
                let jump_on = matches!(instr, Instr::JumpIfTrueOrPop(_));
                let cond = self.stack.last().cloned().ok_or_else(|| Abort("underflow".into()))?;
                if Self::is_tensorish(&cond) {
                    return Err(Abort("boolean operator on tensor".into()));
                }
                let v = cond.as_value().ok_or_else(|| Abort("bool-op on traced structure".into()))?;
                let truth = v.truthy().map_err(|e| Abort(e.into()))?;
                if truth == jump_on {
                    *ip = *t as usize;
                } else {
                    self.stack.pop();
                }
            }
            Instr::GetIter => {
                let obj = self.pop()?;
                match &obj {
                    Sym::List { items, .. } => {
                        let its = items.borrow().clone();
                        self.stack.push(Sym::Iter { items: Rc::new(RefCell::new(its)), pos: 0 });
                    }
                    Sym::Tuple(items) => {
                        self.stack.push(Sym::Iter { items: Rc::new(RefCell::new(items.to_vec())), pos: 0 });
                    }
                    Sym::Iter { .. } => self.stack.push(obj),
                    Sym::Const { value, origin } => {
                        let iter_v = vm::make_iter(value).map_err(Abort)?;
                        let Value::Iter(it) = &iter_v else { unreachable!() };
                        let items: Result<Vec<Sym>, Abort> = it
                            .borrow()
                            .items
                            .iter()
                            .enumerate()
                            .map(|(i, e)| match e {
                                // Encodable scalars need no origin.
                                Value::None | Value::Bool(_) | Value::Int(_) | Value::Float(_) | Value::Str(_) => {
                                    Ok(Sym::constant(e.clone()))
                                }
                                _ => {
                                    let o = origin
                                        .clone()
                                        .ok_or_else(|| Abort("iterating unmaterializable container".into()))?
                                        .index(Value::Int(i as i64));
                                    self.value_to_sym(e, Some(o))
                                }
                            })
                            .collect();
                        self.stack.push(Sym::Iter { items: Rc::new(RefCell::new(items?)), pos: 0 });
                    }
                    Sym::Tensor(_) => {
                        return Ok(Some(self.brk(cur, InlineEmit::GetIterOp, vec![obj], "iteration over a tensor")));
                    }
                    other => return Err(Abort(format!("not iterable: {}", other.type_desc()))),
                }
            }
            Instr::ForIter(t) => {
                let top = self.pop()?;
                let Sym::Iter { items, pos } = top else {
                    return Err(Abort("FOR_ITER on non-iterator sym".into()));
                };
                let item = items.borrow().get(pos).cloned();
                match item {
                    Some(s) => {
                        self.stack.push(Sym::Iter { items, pos: pos + 1 });
                        self.stack.push(s);
                    }
                    None => {
                        *ip = *t as usize;
                    }
                }
            }
            Instr::Call(n) => {
                let args = self.popn(*n as usize)?;
                let callee = self.pop()?;
                return self.call(cur, callee, args);
            }
            Instr::LoadMethod(nidx) => {
                let name = self.code.names[*nidx as usize].clone();
                let obj = self.pop()?;
                match &obj {
                    // Module-style dicts resolve functions.
                    Sym::Const { value: Value::Dict(d), origin } => {
                        let item = d.borrow().get(&crate::value::DictKey::Str(name.clone())).cloned();
                        match item {
                            Some(f) => {
                                let o = origin.clone().map(|o| o.index(Value::str(&name)));
                                let s = self.value_to_sym(&f, o)?;
                                self.stack.push(s);
                            }
                            None => return Err(Abort(format!("module has no attribute '{}'", name))),
                        }
                    }
                    _ => self.stack.push(Sym::MethodRef { recv: Box::new(obj), name }),
                }
            }
            Instr::CallMethod(n) => {
                let args = self.popn(*n as usize)?;
                let callee = self.pop()?;
                match callee {
                    Sym::MethodRef { recv, name } => return self.call_method(cur, *recv, name, args),
                    other => return self.call(cur, other, args),
                }
            }
            Instr::LoadAttr(nidx) => {
                let name = self.code.names[*nidx as usize].clone();
                let obj = self.pop()?;
                match (&obj, name.as_str()) {
                    (Sym::Tensor(id), "shape") => {
                        let shape = self.graph.nodes[*id].shape.clone();
                        self.stack.push(Sym::constant(Value::tuple(shape.iter().map(|&d| Value::Int(d as i64)).collect())));
                    }
                    (Sym::Tensor(id), "ndim") => {
                        let r = self.graph.nodes[*id].shape.len();
                        self.stack.push(Sym::constant(Value::Int(r as i64)));
                    }
                    (Sym::Tensor(id), "T") => {
                        let s = self.add_node(OpKind::Transpose, vec![*id])?;
                        self.stack.push(s);
                    }
                    (Sym::Const { value: Value::Dict(d), origin }, _) => {
                        let item = d.borrow().get(&crate::value::DictKey::Str(name.clone())).cloned();
                        match item {
                            Some(v) => {
                                let o = origin.clone().map(|o| o.index(Value::str(&name)));
                                let s = self.value_to_sym(&v, o)?;
                                self.stack.push(s);
                            }
                            None => return Err(Abort(format!("no attribute '{}'", name))),
                        }
                    }
                    _ => return Err(Abort(format!("attribute '{}' on {}", name, obj.type_desc()))),
                }
            }
            Instr::BinarySubscr => {
                let idx = self.pop()?;
                let obj = self.pop()?;
                return self.subscript(cur, obj, idx);
            }
            Instr::StoreSubscr => {
                let idx = self.pop()?;
                let obj = self.pop()?;
                let val = self.pop()?;
                match &obj {
                    Sym::List { items, external: false } => {
                        let i = idx
                            .as_value()
                            .and_then(|v| v.as_int().ok())
                            .ok_or_else(|| Abort("non-constant list index store".into()))?;
                        let len = items.borrow().len() as i64;
                        let j = if i < 0 { i + len } else { i };
                        if j < 0 || j >= len {
                            return Err(Abort("list index out of range at capture".into()));
                        }
                        items.borrow_mut()[j as usize] = val;
                    }
                    _ => {
                        return Ok(Some(self.brk(
                            cur,
                            InlineEmit::StoreSubscrInline,
                            vec![val, obj, idx],
                            "side effect: store into caller-visible container",
                        )));
                    }
                }
            }
            Instr::BuildSlice(n) => {
                let parts = self.popn(*n as usize)?;
                let vals: Option<Vec<Value>> = parts.iter().map(|s| s.as_value()).collect();
                match vals {
                    Some(mut v) => {
                        if v.len() == 2 {
                            v.push(Value::None);
                        }
                        let slice = Value::Slice(Rc::new((v[0].clone(), v[1].clone(), v[2].clone())));
                        self.stack.push(Sym::constant(slice));
                    }
                    None => return Err(Abort("non-constant slice".into())),
                }
            }
            Instr::BuildList(n) => {
                let items = self.popn(*n as usize)?;
                self.stack.push(Sym::List { items: Rc::new(RefCell::new(items)), external: false });
            }
            Instr::BuildTuple(n) => {
                let items = self.popn(*n as usize)?;
                self.stack.push(Sym::Tuple(Rc::new(items)));
            }
            Instr::BuildMap(n) => {
                let kvs = self.popn(2 * *n as usize)?;
                // Traced dicts only as concrete values.
                let vals: Option<Vec<Value>> = kvs.iter().map(|s| s.as_value()).collect();
                match vals {
                    Some(v) => {
                        let d = Value::dict();
                        if let Value::Dict(map) = &d {
                            let mut m = map.borrow_mut();
                            for pair in v.chunks(2) {
                                let k = crate::value::DictKey::from_value(&pair[0]).map_err(|e| Abort(e.into()))?;
                                m.insert(k, pair[1].clone());
                            }
                        }
                        self.stack.push(Sym::constant(d));
                    }
                    None => return Err(Abort("dict of traced tensors".into())),
                }
            }
            Instr::ListAppend(depth) => {
                let elt = self.pop()?;
                let idx = self
                    .stack
                    .len()
                    .checked_sub(*depth as usize)
                    .ok_or_else(|| Abort("LIST_APPEND depth".into()))?;
                match &self.stack[idx] {
                    Sym::List { items, .. } => items.borrow_mut().push(elt),
                    other => return Err(Abort(format!("LIST_APPEND on {}", other.type_desc()))),
                }
            }
            Instr::UnpackSequence(n) => {
                let seq = self.pop()?;
                match &seq {
                    Sym::Tuple(items) => {
                        if items.len() != *n as usize {
                            return Err(Abort("unpack arity mismatch".into()));
                        }
                        for s in items.iter().rev() {
                            self.stack.push(s.clone());
                        }
                    }
                    Sym::List { items, .. } => {
                        let it = items.borrow();
                        if it.len() != *n as usize {
                            return Err(Abort("unpack arity mismatch".into()));
                        }
                        for s in it.iter().rev() {
                            self.stack.push(s.clone());
                        }
                    }
                    Sym::Const { value, origin } => {
                        let iter_v = vm::make_iter(value).map_err(Abort)?;
                        let Value::Iter(itr) = &iter_v else { unreachable!() };
                        let items = itr.borrow().items.clone();
                        if items.len() != *n as usize {
                            return Err(Abort("unpack arity mismatch".into()));
                        }
                        for (i, e) in items.iter().enumerate().rev() {
                            let o = origin.clone().map(|o| o.index(Value::Int(i as i64)));
                            let s = self.value_to_sym(e, o)?;
                            self.stack.push(s);
                        }
                    }
                    Sym::Tensor(_) => {
                        return Ok(Some(self.brk(cur, InlineEmit::UnpackInline(*n), vec![seq], "unpacking a tensor")));
                    }
                    other => return Err(Abort(format!("cannot unpack {}", other.type_desc()))),
                }
            }
            Instr::Raise => {
                let v = self.pop()?;
                return Ok(Some(self.brk(cur, InlineEmit::RaiseInline, vec![v], "exception raised")));
            }
            Instr::ReturnValue => {
                let s = self.pop()?;
                return Ok(Some(Outcome::Return(s)));
            }
        }
        Ok(None)
    }

    // ---- op dispatch helpers ----

    fn binary(&mut self, cur: usize, op: BinOp, a: Sym, b: Sym) -> Step {
        let any_tensor = Self::is_tensorish(&a) || Self::is_tensorish(&b);
        if any_tensor {
            let kind = match op {
                BinOp::Add => Some(OpKind::Add),
                BinOp::Sub => Some(OpKind::Sub),
                BinOp::Mul => Some(OpKind::Mul),
                BinOp::Div => Some(OpKind::Div),
                BinOp::Pow => Some(OpKind::Pow),
                BinOp::MatMul => Some(OpKind::MatMul),
                BinOp::FloorDiv | BinOp::Mod => None,
            };
            match kind {
                Some(k) => {
                    let (na, nb) = (self.tensorify(&a)?, self.tensorify(&b)?);
                    let s = self.add_node(k, vec![na, nb])?;
                    self.stack.push(s);
                    return Ok(None);
                }
                None => {
                    return Ok(Some(self.brk(cur, InlineEmit::BinaryInline(op), vec![a, b], "tensor op not representable in graph")));
                }
            }
        }
        // Structural list concat.
        if op == BinOp::Add {
            if let (Sym::List { items: ia, .. }, Sym::List { items: ib, .. }) = (&a, &b) {
                let mut out = ia.borrow().clone();
                out.extend(ib.borrow().iter().cloned());
                self.stack.push(Sym::List { items: Rc::new(RefCell::new(out)), external: false });
                return Ok(None);
            }
        }
        match (a.as_value(), b.as_value()) {
            (Some(x), Some(y)) => {
                let r = vm::binary_op_values(op, &x, &y).map_err(Abort)?;
                self.stack.push(Sym::constant(r));
                Ok(None)
            }
            _ => Err(Abort(format!("binary {} on {} and {}", op.symbol(), a.type_desc(), b.type_desc()))),
        }
    }

    fn subscript(&mut self, cur: usize, obj: Sym, idx: Sym) -> Step {
        match &obj {
            Sym::Tensor(_) => {
                return Ok(Some(self.brk(cur, InlineEmit::Subscr, vec![obj, idx], "tensor indexing materializes data")));
            }
            Sym::List { items, .. } => {
                let i = idx.as_value().and_then(|v| v.as_int().ok()).ok_or_else(|| Abort("non-constant list index".into()))?;
                let it = items.borrow();
                let len = it.len() as i64;
                let j = if i < 0 { i + len } else { i };
                if j < 0 || j >= len {
                    return Err(Abort("list index out of range at capture".into()));
                }
                self.stack.push(it[j as usize].clone());
            }
            Sym::Tuple(items) => {
                let i = idx.as_value().and_then(|v| v.as_int().ok()).ok_or_else(|| Abort("non-constant tuple index".into()))?;
                let len = items.len() as i64;
                let j = if i < 0 { i + len } else { i };
                if j < 0 || j >= len {
                    return Err(Abort("tuple index out of range at capture".into()));
                }
                self.stack.push(items[j as usize].clone());
            }
            Sym::Const { value, origin } => {
                let key = idx.as_value().ok_or_else(|| Abort("non-constant subscript".into()))?;
                let elem = crate::vm::apply_subscript(value, &key).map_err(|e| Abort(e.into()))?;
                let o = origin.clone().map(|o| o.index(key));
                let s = self.value_to_sym(&elem, o)?;
                self.stack.push(s);
            }
            other => return Err(Abort(format!("subscript on {}", other.type_desc()))),
        }
        Ok(None)
    }

    fn call(&mut self, cur: usize, callee: Sym, args: Vec<Sym>) -> Step {
        let Sym::Const { value, .. } = &callee else {
            return Err(Abort(format!("call of {}", callee.type_desc())));
        };
        match value {
            Value::Builtin(b) => {
                let name = b.name.clone();
                self.call_builtin(cur, callee.clone(), &name, args)
            }
            Value::Func(_) | Value::CompiledGraph(_) => {
                // No inlining of user functions: graph break, run it for real.
                let n = args.len() as u32;
                let mut operands = vec![callee];
                operands.extend(args);
                Ok(Some(self.brk(cur, InlineEmit::CallFn(n), operands, "call to user function (not inlined)")))
            }
            other => Err(Abort(format!("call of non-callable {}", other.type_name()))),
        }
    }

    fn call_builtin(&mut self, cur: usize, callee: Sym, name: &str, args: Vec<Sym>) -> Step {
        let any_tensor = args.iter().any(|a| {
            let mut ids = Vec::new();
            a.collect_tensors(&mut ids);
            !ids.is_empty()
        });
        // Tensor-graph ops.
        let unary_op = |n: &str| -> Option<OpKind> {
            Some(match n {
                "relu" => OpKind::Relu,
                "gelu" => OpKind::Gelu,
                "tanh" => OpKind::Tanh,
                "softmax" => OpKind::Softmax,
                _ => return None,
            })
        };
        if any_tensor {
            match name {
                "matmul" | "maximum" | "minimum" if args.len() == 2 => {
                    let k = match name {
                        "matmul" => OpKind::MatMul,
                        "maximum" => OpKind::Maximum,
                        _ => OpKind::Minimum,
                    };
                    let na = self.tensorify(&args[0])?;
                    let nb = self.tensorify(&args[1])?;
                    let s = self.add_node(k, vec![na, nb])?;
                    self.stack.push(s);
                    return Ok(None);
                }
                _ if unary_op(name).is_some() && args.len() == 1 => {
                    // The guard established `is_some`; bind with `if let` so a
                    // disagreeing re-evaluation falls through to the generic
                    // tensor-arg graph break below instead of panicking.
                    if let Some(k) = unary_op(name) {
                        let na = self.tensorify(&args[0])?;
                        let s = self.add_node(k, vec![na])?;
                        self.stack.push(s);
                        return Ok(None);
                    }
                    let operands = vec![callee, args[0].clone()];
                    return Ok(Some(self.brk(cur, InlineEmit::CallFn(1), operands, &format!("builtin '{}' with tensor args", name))));
                }
                "layernorm" if args.len() == 3 => {
                    let ns: Result<Vec<NodeId>, Abort> = args.iter().map(|a| self.tensorify(a)).collect();
                    let s = self.add_node(OpKind::LayerNorm, ns?)?;
                    self.stack.push(s);
                    return Ok(None);
                }
                "embedding" | "cross_entropy" if args.len() == 2 => {
                    let k = if name == "embedding" { OpKind::Embedding } else { OpKind::CrossEntropy };
                    let na = self.tensorify(&args[0])?;
                    let nb = self.tensorify(&args[1])?;
                    let s = self.add_node(k, vec![na, nb])?;
                    self.stack.push(s);
                    return Ok(None);
                }
                "abs" if args.len() == 1 => {
                    let na = self.tensorify(&args[0])?;
                    let s = self.add_node(OpKind::Abs, vec![na])?;
                    self.stack.push(s);
                    return Ok(None);
                }
                "len" if args.len() == 1 => {
                    if let Sym::Tensor(id) = &args[0] {
                        let d0 = *self.graph.nodes[*id].shape.first().unwrap_or(&0);
                        self.stack.push(Sym::constant(Value::Int(d0 as i64)));
                        return Ok(None);
                    }
                }
                "sum" if args.len() == 1 => {
                    // sum over a python list of tensors -> chained adds.
                    if let Sym::List { items, .. } = &args[0] {
                        let its = items.borrow().clone();
                        if !its.is_empty() {
                            let mut acc = self.tensorify(&its[0])?;
                            for s in &its[1..] {
                                let n = self.tensorify(s)?;
                                let Sym::Tensor(a2) = self.add_node(OpKind::Add, vec![acc, n])? else { unreachable!() };
                                acc = a2;
                            }
                            self.stack.push(Sym::Tensor(acc));
                            return Ok(None);
                        }
                    }
                }
                // Data-dependent escapes: break and run for real.
                "print" | "int" | "float" | "bool" | "str" | "min" | "max" | "sorted" => {
                    let n = args.len() as u32;
                    let mut operands = vec![callee];
                    operands.extend(args);
                    let reason = if name == "print" { "side effect: print of a tensor" } else { "data-dependent conversion of a tensor" };
                    return Ok(Some(self.brk(cur, InlineEmit::CallFn(n), operands, reason)));
                }
                _ => {
                    let n = args.len() as u32;
                    let mut operands = vec![callee];
                    operands.extend(args);
                    return Ok(Some(self.brk(cur, InlineEmit::CallFn(n), operands, &format!("builtin '{}' with tensor args", name))));
                }
            }
        }
        // print is a side effect even on constants.
        if name == "print" || name == "manual_seed" {
            let n = args.len() as u32;
            let mut operands = vec![callee];
            operands.extend(args);
            return Ok(Some(self.brk(cur, InlineEmit::CallFn(n), operands, &format!("side effect: {}", name))));
        }
        // Random tensor creation cannot be baked into the graph.
        if matches!(name, "randn" | "rand" | "randint") {
            let n = args.len() as u32;
            let mut operands = vec![callee];
            operands.extend(args);
            return Ok(Some(self.brk(cur, InlineEmit::CallFn(n), operands, "nondeterministic tensor creation")));
        }
        // Deterministic tensor creation folds into a graph constant.
        if matches!(name, "zeros" | "ones" | "arange" | "tensor") {
            let vals: Option<Vec<Value>> = args.iter().map(|a| a.as_value()).collect();
            let Some(vals) = vals else {
                return Err(Abort(format!("torch.{} with traced args", name)));
            };
            let Sym::Const { value: Value::Builtin(b), .. } = &callee else {
                return Err(Abort("lost builtin".into()));
            };
            let out = (b.func)(&vals).map_err(Abort)?;
            let Value::Tensor(t) = out else {
                return Err(Abort(format!("torch.{} did not produce a tensor", name)));
            };
            let id = self.graph.const_tensor((*t).clone());
            self.stack.push(Sym::Tensor(id));
            return Ok(None);
        }
        // Structural folds.
        match name {
            "enumerate" if args.len() == 1 => {
                if let Some(items) = iter_items(&args[0]) {
                    let out: Vec<Sym> = items
                        .into_iter()
                        .enumerate()
                        .map(|(i, s)| Sym::Tuple(Rc::new(vec![Sym::constant(Value::Int(i as i64)), s])))
                        .collect();
                    self.stack.push(Sym::List { items: Rc::new(RefCell::new(out)), external: false });
                    return Ok(None);
                }
            }
            "zip" if args.len() >= 2 => {
                let lists: Option<Vec<Vec<Sym>>> = args.iter().map(iter_items).collect();
                if let Some(lists) = lists {
                    let n = lists.iter().map(|l| l.len()).min().unwrap_or(0);
                    let out: Vec<Sym> =
                        (0..n).map(|i| Sym::Tuple(Rc::new(lists.iter().map(|l| l[i].clone()).collect()))).collect();
                    self.stack.push(Sym::List { items: Rc::new(RefCell::new(out)), external: false });
                    return Ok(None);
                }
            }
            "list" if args.len() == 1 => {
                if let Some(items) = iter_items(&args[0]) {
                    self.stack.push(Sym::List { items: Rc::new(RefCell::new(items)), external: false });
                    return Ok(None);
                }
            }
            "tuple" if args.len() == 1 => {
                if let Some(items) = iter_items(&args[0]) {
                    self.stack.push(Sym::Tuple(Rc::new(items)));
                    return Ok(None);
                }
            }
            "len" if args.len() == 1 => {
                if let Some(items) = iter_items(&args[0]) {
                    self.stack.push(Sym::constant(Value::Int(items.len() as i64)));
                    return Ok(None);
                }
            }
            _ => {}
        }
        // Pure fold over concrete values.
        let vals: Option<Vec<Value>> = args.iter().map(|a| a.as_value()).collect();
        match vals {
            Some(vals) => {
                let Sym::Const { value: Value::Builtin(b), .. } = &callee else {
                    return Err(Abort("lost builtin".into()));
                };
                let r = (b.func)(&vals).map_err(Abort)?;
                let s = self.value_to_sym(&r, None).or_else(|_| {
                    // Non-materializable results (fresh lists) become traced lists.
                    match &r {
                        Value::List(l) => {
                            let items: Vec<Sym> = l.borrow().iter().map(|v| Sym::constant(v.clone())).collect();
                            Ok(Sym::List { items: Rc::new(RefCell::new(items)), external: false })
                        }
                        other => Err(Abort(format!("builtin '{}' result {} not traceable", name, other.type_name()))),
                    }
                })?;
                self.stack.push(s);
                Ok(None)
            }
            None => Err(Abort(format!("builtin '{}' with traced args", name))),
        }
    }

    fn call_method(&mut self, cur: usize, recv: Sym, name: String, args: Vec<Sym>) -> Step {
        match &recv {
            Sym::Tensor(id) => return self.tensor_method(cur, *id, recv.clone(), &name, args),
            Sym::List { items, external } => {
                match name.as_str() {
                    "append" if !external && args.len() == 1 => {
                        items.borrow_mut().push(args[0].clone());
                        self.stack.push(Sym::constant(Value::None));
                        return Ok(None);
                    }
                    "extend" if !external && args.len() == 1 => {
                        if let Some(more) = iter_items(&args[0]) {
                            items.borrow_mut().extend(more);
                            self.stack.push(Sym::constant(Value::None));
                            return Ok(None);
                        }
                    }
                    "pop" if !external && args.is_empty() => {
                        let v = items.borrow_mut().pop().ok_or_else(|| Abort("pop from empty list".into()))?;
                        self.stack.push(v);
                        return Ok(None);
                    }
                    _ => {}
                }
                // Caller-visible mutation (or unsupported method): break.
                let argc = args.len() as u32;
                let mut operands = vec![recv];
                operands.extend(args);
                return Ok(Some(self.brk(
                    cur,
                    InlineEmit::CallMethod { name: name.clone(), argc },
                    operands,
                    "side effect: mutation of caller-visible list",
                )));
            }
            Sym::Const { value, .. } => {
                let vals: Option<Vec<Value>> = args.iter().map(|a| a.as_value()).collect();
                if let Some(vals) = vals {
                    // Pure const-method fold (str methods, dict.get, ...).
                    if !matches!(name.as_str(), "append" | "extend" | "pop" | "insert" | "sort" | "reverse") {
                        let r = vm::call_method_pure(value, &name, &vals).map_err(|e| Abort(e.into()))?;
                        let s = self.value_to_sym(&r, None).unwrap_or(Sym::constant(r));
                        self.stack.push(s);
                        return Ok(None);
                    }
                }
                let argc = args.len() as u32;
                let mut operands = vec![recv];
                operands.extend(args);
                return Ok(Some(self.brk(
                    cur,
                    InlineEmit::CallMethod { name: name.clone(), argc },
                    operands,
                    "method call with side effects or traced args",
                )));
            }
            Sym::Tuple(items) => {
                if name == "index" || name == "count" {
                    let vals: Option<Vec<Value>> = args.iter().map(|a| a.as_value()).collect();
                    let tup: Option<Vec<Value>> = items.iter().map(|s| s.as_value()).collect();
                    if let (Some(vals), Some(tup)) = (vals, tup) {
                        let r = vm::call_method_pure(&Value::tuple(tup), &name, &vals).map_err(|e| Abort(e.into()))?;
                        self.stack.push(Sym::constant(r));
                        return Ok(None);
                    }
                }
            }
            _ => {}
        }
        Err(Abort(format!("method '{}' on {}", name, recv.type_desc())))
    }

    fn tensor_method(&mut self, cur: usize, id: NodeId, recv: Sym, name: &str, args: Vec<Sym>) -> Step {
        let simple = |n: &str| -> Option<OpKind> {
            Some(match n {
                "relu" => OpKind::Relu,
                "gelu" => OpKind::Gelu,
                "tanh" => OpKind::Tanh,
                "sigmoid" => OpKind::Sigmoid,
                "exp" => OpKind::Exp,
                "log" => OpKind::Log,
                "sqrt" => OpKind::Sqrt,
                "abs" => OpKind::Abs,
                "neg" => OpKind::Neg,
                "softmax" => OpKind::Softmax,
                "t" => OpKind::Transpose,
                _ => return None,
            })
        };
        if let Some(k) = simple(name) {
            if args.is_empty() {
                let s = self.add_node(k, vec![id])?;
                self.stack.push(s);
                return Ok(None);
            }
        }
        match name {
            "matmul" | "add" | "sub" | "mul" | "div" | "pow" | "maximum" | "minimum" if args.len() == 1 => {
                let k = match name {
                    "matmul" => OpKind::MatMul,
                    "add" => OpKind::Add,
                    "sub" => OpKind::Sub,
                    "mul" => OpKind::Mul,
                    "div" => OpKind::Div,
                    "pow" => OpKind::Pow,
                    "maximum" => OpKind::Maximum,
                    _ => OpKind::Minimum,
                };
                let nb = self.tensorify(&args[0])?;
                let s = self.add_node(k, vec![id, nb])?;
                self.stack.push(s);
                Ok(None)
            }
            "sum" | "mean" | "max" | "min" => {
                let axis = match args.first() {
                    None => None,
                    Some(s) => match s.as_value() {
                        Some(Value::Int(i)) => Some(i as usize),
                        Some(Value::None) => None,
                        _ => return Err(Abort("non-constant reduction axis".into())),
                    },
                };
                let k = match name {
                    "sum" => OpKind::Sum(axis),
                    "mean" => OpKind::Mean(axis),
                    "max" => OpKind::Max(axis),
                    _ => OpKind::Min(axis),
                };
                let s = self.add_node(k, vec![id])?;
                self.stack.push(s);
                Ok(None)
            }
            "reshape" | "view" if args.len() == 1 => {
                let spec = args[0]
                    .as_value()
                    .and_then(|v| match v {
                        Value::List(l) => l.borrow().iter().map(|x| x.as_int().ok()).collect::<Option<Vec<i64>>>(),
                        Value::Tuple(t) => t.iter().map(|x| x.as_int().ok()).collect::<Option<Vec<i64>>>(),
                        _ => None,
                    })
                    .ok_or_else(|| Abort("non-constant reshape spec".into()))?;
                let s = self.add_node(OpKind::Reshape(spec), vec![id])?;
                self.stack.push(s);
                Ok(None)
            }
            "permute" if args.len() == 1 => {
                let perm = args[0]
                    .as_value()
                    .and_then(|v| match v {
                        Value::List(l) => l.borrow().iter().map(|x| x.as_int().ok().map(|i| i as usize)).collect::<Option<Vec<usize>>>(),
                        Value::Tuple(t) => t.iter().map(|x| x.as_int().ok().map(|i| i as usize)).collect::<Option<Vec<usize>>>(),
                        _ => None,
                    })
                    .ok_or_else(|| Abort("non-constant permute spec".into()))?;
                let s = self.add_node(OpKind::Permute(perm), vec![id])?;
                self.stack.push(s);
                Ok(None)
            }
            "numel" => {
                let n: usize = self.graph.nodes[id].shape.iter().product();
                self.stack.push(Sym::constant(Value::Int(n as i64)));
                Ok(None)
            }
            // Data escapes: break, run for real, resume.
            "item" | "tolist" => {
                let argc = args.len() as u32;
                let mut operands = vec![recv];
                operands.extend(args);
                Ok(Some(self.brk(
                    cur,
                    InlineEmit::CallMethod { name: name.to_string(), argc },
                    operands,
                    &format!("data-dependent .{}() reads tensor contents", name),
                )))
            }
            // Anything else — an unknown method name, or a known one with an
            // arity the graph arms above don't model — degrades to a graph
            // break: the VM replays the call for real (and raises its own
            // error for a genuinely unsupported method) instead of the whole
            // capture aborting or, worse, panicking.
            other => {
                let argc = args.len() as u32;
                let mut operands = vec![recv];
                operands.extend(args);
                Ok(Some(self.brk(
                    cur,
                    InlineEmit::CallMethod { name: other.to_string(), argc },
                    operands,
                    &format!("tensor method '{}' unsupported in graph", other),
                )))
            }
        }
    }
}

fn vm_const(code: &CodeObject, idx: u32) -> Result<Value, Abort> {
    let c = code.consts.get(idx as usize).ok_or_else(|| Abort("bad const".into()))?;
    match c {
        crate::bytecode::Const::Code(_) => Err(Abort("code constant in compiled region".into())),
        other => Ok(crate::vm::const_to_runtime(other)),
    }
}

/// Items of an iterable sym, if structurally known.
fn iter_items(s: &Sym) -> Option<Vec<Sym>> {
    match s {
        Sym::List { items, .. } => Some(items.borrow().clone()),
        Sym::Tuple(items) => Some(items.to_vec()),
        Sym::Iter { items, pos } => Some(items.borrow()[*pos..].to_vec()),
        Sym::Const { value, origin } => {
            let it = vm::make_iter(value).ok()?;
            let Value::Iter(itr) = &it else { return None };
            let out: Option<Vec<Sym>> = itr
                .borrow()
                .items
                .iter()
                .enumerate()
                .map(|(i, e)| match e {
                    Value::None | Value::Bool(_) | Value::Int(_) | Value::Float(_) | Value::Str(_) => {
                        Some(Sym::constant(e.clone()))
                    }
                    Value::Tuple(t) => {
                        // tuples of scalars (enumerate/zip results)
                        if t.iter().all(|x| matches!(x, Value::Int(_) | Value::Float(_) | Value::Str(_) | Value::Bool(_) | Value::None)) {
                            Some(Sym::constant(e.clone()))
                        } else {
                            let _ = (i, origin);
                            None
                        }
                    }
                    _ => None,
                })
                .collect();
            out
        }
        _ => None,
    }
}
