//! Dynamo: the graph-capturing compiler frontend (the paper's "opaque box").
//!
//! Installed as the VM's frame-evaluation hook. On each call of a user
//! function it either (a) returns cached transformed bytecode whose guards
//! pass, (b) symbolically evaluates the function, compiles the captured
//! tensor graph with a backend, synthesizes transformed + resume bytecode,
//! and installs the callables as globals, or (c) marks the function as
//! skipped and lets it run uncompiled.

pub mod capture;
pub mod emit;
pub mod guards;
pub mod sym;

pub use capture::{Capture, InlineEmit, Limits, Outcome};
pub use emit::{emit_transformed, make_resume, select_outputs, CodeBuilder};
pub use guards::{Guard, GuardTable};
pub use sym::{Origin, Sym};

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::sync::Arc;

use crate::api::{
    compile_with_policy, module_from_fn, Backend, CompileRequest, DepyfError, EagerBackend, FallbackPolicy,
};
use crate::bytecode::CodeObject;
use crate::graph::opt::{OptLevel, Optimized};
use crate::graph::Graph;
use crate::metrics::Metrics;
use crate::runtime::Runtime;
use crate::value::{Function, Value};
use crate::vm::EvalHook;

/// Per-node trace callback for the debugger ("step through the compiled
/// graph line by line"). Forces the eager backend.
pub trait GraphTracer {
    fn on_node(&self, graph_name: &str, node_id: usize, value: &crate::tensor::Tensor);
}

/// How chatty the frontend log (`full_code`) is. The cache-hit path only
/// logs at `Trace`, and the gate is applied *before* the format string is
/// built, so steady-state dispatch allocates nothing for logging.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verbosity {
    /// No frontend log at all.
    Quiet,
    /// Compile-time events: captures, graph breaks, guards, fallbacks.
    #[default]
    Info,
    /// Everything, including per-call cache-hit events.
    Trace,
}

/// Configuration of the dynamo instance.
pub struct DynamoConfig {
    /// The graph compiler — any [`Backend`] implementation (built-in or
    /// registered via [`crate::api::register_backend`]).
    pub backend: Arc<dyn Backend>,
    /// What happens when the backend fails on a captured graph. The degrade
    /// (or error) is always recorded in the frontend log — never silent.
    pub fallback: FallbackPolicy,
    /// Max cache entries per code object. Reaching it no longer means
    /// "run uncompiled": the least-recently-used guard entry is evicted
    /// (per-entry hit counter + recency stamp, see
    /// [`GuardTable::evict_lru`]) and the new specialization compiles.
    /// Sustained churn is still bounded: past
    /// `cache_limit * THRASH_EVICTIONS_FACTOR` evictions the code object
    /// is marked skip (thrash backstop — an unbounded specialization
    /// cycle would otherwise recompile on every call).
    pub cache_limit: usize,
    pub max_trace_instrs: usize,
    pub max_graph_nodes: usize,
    /// Frontend log verbosity (default [`Verbosity::Info`]).
    pub verbosity: Verbosity,
    /// Graph-optimizer level applied at `Backend::plan` time
    /// (`--opt-level`, default 2). `StepGraphs` tracing bypasses the
    /// optimizer — the debugger steps the captured graph verbatim.
    pub opt_level: OptLevel,
    /// Per-call deadline for compiled-graph dispatch (`--deadline-ms`).
    /// A call that outlives it is abandoned on its watchdog thread and
    /// served by the eager fallback (under [`FallbackPolicy::Eager`]) or
    /// surfaced as [`DepyfError::Timeout`] (under `Error`). `None`
    /// (default): calls run inline with no watchdog thread.
    pub deadline_ms: Option<u64>,
    /// Present in `TraceMode::StepGraphs` sessions: forces eager execution
    /// with per-node callbacks. Debugger-only and thread-confined: the
    /// traced module wraps the tracer in [`crate::runtime::ThreadBound`],
    /// so stepping works on the session's own thread and errors cleanly if
    /// a traced module ever leaks into multi-thread dispatch.
    pub tracer: Option<Rc<dyn GraphTracer>>,
}

impl Default for DynamoConfig {
    fn default() -> Self {
        DynamoConfig {
            backend: Arc::new(EagerBackend),
            fallback: FallbackPolicy::Eager,
            cache_limit: 8,
            max_trace_instrs: 20_000,
            max_graph_nodes: 2_000,
            verbosity: Verbosity::Info,
            opt_level: OptLevel::default(),
            deadline_ms: None,
            tracer: None,
        }
    }
}

/// Per-`cache_limit` multiplier before eviction churn is declared
/// thrashing: a code object that has evicted `cache_limit *
/// THRASH_EVICTIONS_FACTOR` entries is cycling through more
/// specializations than the cache can hold (the classic LRU pathology —
/// every call would recompile forever), so it is marked skip and runs
/// uncompiled from then on, like a capture failure.
const THRASH_EVICTIONS_FACTOR: usize = 8;

#[derive(Default)]
struct CodeCache {
    /// Precompiled two-stage guard dispatcher over the cached entries.
    table: GuardTable,
    skip: bool,
    skip_reason: Option<String>,
    /// Total LRU evictions this code object has caused (thrash detector).
    evictions: usize,
}

#[derive(Default)]
struct State {
    cache: HashMap<usize, CodeCache>,
    /// Code objects produced by us — never re-hooked.
    own_output: HashSet<usize>,
    next_id: usize,
    /// `full_code`-style event log.
    log: Vec<String>,
    /// Captured graphs (name -> graph) for dumps & benches.
    graphs: Vec<(String, Arc<Graph>)>,
    /// Transformed + resume code objects for dumps.
    generated_codes: Vec<(String, Rc<CodeObject>)>,
    /// Compiled-graph callables in compile order — the session reads
    /// their modules' `artifacts()`/`stats()` at `finish()`.
    compiled: Vec<Rc<crate::graph::CompiledGraphFn>>,
    /// Optimizer results per compiled graph (name → memoized run) — the
    /// session dumps `__optimized_*.{txt,json}` and per-module pass stats
    /// from these at `finish()`.
    optimizations: Vec<(String, Arc<Optimized>)>,
    /// Cached read-path snapshots, invalidated on write. Read accessors
    /// hand out `Rc` clones of these instead of deep-copying the vectors.
    log_snap: Option<Rc<[String]>>,
    graphs_snap: Option<Rc<[(String, Arc<Graph>)]>>,
    codes_snap: Option<Rc<[(String, Rc<CodeObject>)]>>,
}

/// The dynamo compiler instance. Install with
/// `vm.eval_hook = Some(dynamo.clone())`.
pub struct Dynamo {
    pub config: DynamoConfig,
    pub runtime: Option<Arc<Runtime>>,
    pub metrics: Metrics,
    /// Call-time resilience counters (retries, degraded calls, timeouts,
    /// caught panics), shared with every compiled fn this instance
    /// installs; folded into [`Dynamo::metrics_snapshot`].
    pub call_counters: Arc<crate::graph::CallCounters>,
    state: RefCell<State>,
}

impl Dynamo {
    pub fn new(config: DynamoConfig) -> Rc<Dynamo> {
        Rc::new(Dynamo {
            config,
            runtime: None,
            metrics: Metrics::new(),
            call_counters: Arc::new(crate::graph::CallCounters::default()),
            state: RefCell::new(State::default()),
        })
    }

    pub fn with_runtime(config: DynamoConfig, runtime: Arc<Runtime>) -> Rc<Dynamo> {
        Rc::new(Dynamo {
            config,
            runtime: Some(runtime),
            metrics: Metrics::new(),
            call_counters: Arc::new(crate::graph::CallCounters::default()),
            state: RefCell::new(State::default()),
        })
    }

    /// [`Metrics::snapshot`] plus the dispatch-path resilience counters
    /// the compiled fns accumulated — the complete per-session picture
    /// that `Session::finish()` and the serve workers report.
    pub fn metrics_snapshot(&self) -> crate::metrics::MetricsSnapshot {
        let mut snap = self.metrics.snapshot();
        self.call_counters.fold_into(&mut snap);
        snap
    }

    /// The `full_code`-style decision log. Returns a shared snapshot —
    /// repeated calls between compiles are O(1), not a vector deep-copy.
    pub fn log(&self) -> Rc<[String]> {
        let mut st = self.state.borrow_mut();
        if st.log_snap.is_none() {
            st.log_snap = Some(Rc::from(st.log.as_slice()));
        }
        Rc::clone(st.log_snap.as_ref().unwrap())
    }

    /// Captured graphs, in compile order (shared snapshot).
    pub fn graphs(&self) -> Rc<[(String, Arc<Graph>)]> {
        let mut st = self.state.borrow_mut();
        if st.graphs_snap.is_none() {
            st.graphs_snap = Some(Rc::from(st.graphs.as_slice()));
        }
        Rc::clone(st.graphs_snap.as_ref().unwrap())
    }

    /// Program-generated code objects (transformed bodies + resume fns),
    /// as a shared snapshot.
    pub fn generated_codes(&self) -> Rc<[(String, Rc<CodeObject>)]> {
        let mut st = self.state.borrow_mut();
        if st.codes_snap.is_none() {
            st.codes_snap = Some(Rc::from(st.generated_codes.as_slice()));
        }
        Rc::clone(st.codes_snap.as_ref().unwrap())
    }

    /// The compiled-graph callables installed so far, in compile order.
    /// Each carries its backend [`crate::api::CompiledModule`], whose
    /// `artifacts()` and `stats()` the session dumps at `finish()`.
    pub fn compiled(&self) -> Vec<Rc<crate::graph::CompiledGraphFn>> {
        self.state.borrow().compiled.clone()
    }

    /// Optimizer runs per compiled graph, in compile order (the memoized
    /// [`CompileRequest::optimized`] results the backends planned with).
    pub fn optimizations(&self) -> Vec<(String, Arc<Optimized>)> {
        self.state.borrow().optimizations.clone()
    }

    fn note(&self, msg: String) {
        if self.config.verbosity >= Verbosity::Info {
            let mut st = self.state.borrow_mut();
            st.log_snap = None;
            st.log.push(msg);
        }
    }

    /// Trace-level note: the message closure only runs (and the format
    /// string is only built) when `verbosity >= Trace`, so the cache-hit
    /// path performs no formatting at default verbosity.
    fn note_trace(&self, msg: impl FnOnce() -> String) {
        if self.config.verbosity >= Verbosity::Trace {
            let mut st = self.state.borrow_mut();
            st.log_snap = None;
            st.log.push(msg());
        }
    }

    fn compile_backend(&self, name: &str, graph: Arc<Graph>, guards: &[Guard]) -> Value {
        // Debug tracing forces the eager executor with per-node callbacks.
        // The tracer is Rc-based (it reaches back into the session), so the
        // traced module confines it to this thread: `get()` errors instead
        // of racing if such a module crosses threads.
        if let Some(tracer) = &self.config.tracer {
            let t = crate::runtime::ThreadBound::new(Rc::clone(tracer));
            let gname = name.to_string();
            let g2 = Arc::clone(&graph);
            let module = module_from_fn("eager+trace", move |inputs| {
                let t = t.get()?;
                crate::backend::eager::execute_traced(&g2, inputs, |id, v| t.on_node(&gname, id, v))
            });
            return self.install_compiled(crate::graph::CompiledGraphFn::from_module(name, graph, module));
        }
        let req = CompileRequest::new(name, Arc::clone(&graph))
            .with_runtime(self.runtime.clone())
            .with_guards(guards.iter().map(|g| g.describe()).collect())
            .with_verbosity(self.config.verbosity)
            .with_fallback(self.config.fallback)
            .with_opt_level(self.config.opt_level);
        let backend = self.config.backend.as_ref();
        let mut optimizer_engaged = false;
        let f = match compile_with_policy(backend, &req) {
            Ok(pc) => {
                if let Some(reason) = &pc.fallback_reason {
                    // Fallback engaged: record it in the frontend log.
                    Metrics::bump(&self.metrics.degraded_compiles);
                    self.note(format!(
                        "  backend: {} degraded to eager on {}: {}",
                        backend.name(),
                        name,
                        reason
                    ));
                } else {
                    optimizer_engaged = true;
                    // Composite-backend decisions are observable in the
                    // frontend log, not just in the plan artifact.
                    let stats = pc.f.module.stats();
                    if stats.partitions > 1 {
                        self.note(format!(
                            "  backend: {} split {} into {} partitions",
                            backend.name(),
                            name,
                            stats.partitions
                        ));
                    }
                    if let Some(bucket) = stats.bucket {
                        self.note(format!(
                            "  backend: {} padded {} into bucket {} ({})",
                            backend.name(),
                            name,
                            bucket,
                            if stats.cache_hits > 0 { "shared executable" } else { "new executable" }
                        ));
                    }
                }
                pc.f
            }
            Err(e) => {
                // FallbackPolicy::Error: the failure is logged here and
                // surfaced as a VM error when the graph is first called.
                self.note(format!("  backend: {} failed on {}: {}", backend.name(), name, e));
                let msg = format!("backend '{}' failed to compile {}: {}", backend.name(), name, e);
                let module = module_from_fn(format!("error({})", backend.name()), move |_| {
                    Err(DepyfError::Backend(msg.clone()))
                });
                crate::graph::CompiledGraphFn::from_module(name, graph, module)
            }
        };
        // Record the optimizer run (memoized on the request — the backend
        // consumed it during plan/lower) for finish()-time `__optimized_*`
        // dumps, and surface real rewrites in the log — but ONLY when the
        // backend actually shipped the optimized graph. The eager fallback
        // and the error module execute the captured graph verbatim, so
        // recording pass deltas for them would misattribute what ran.
        if optimizer_engaged {
            let opt = req.optimized();
            if opt.changed() {
                self.note(format!(
                    "  optimizer: {} {} -> {} nodes at -O{} ({} rewrites)",
                    name,
                    req.graph.nodes.len(),
                    opt.graph.nodes.len(),
                    opt.level,
                    opt.total_rewrites()
                ));
            }
            self.state.borrow_mut().optimizations.push((name.to_string(), opt));
        }
        // Every dispatch-path callable gets call-time resilience wired to
        // the session policy: panic isolation is always on; retry/degrade
        // and the deadline watchdog follow the configured fallback.
        let f = f.with_resilience(crate::graph::CallResilience::new(
            self.config.fallback,
            self.config.deadline_ms.map(std::time::Duration::from_millis),
            Arc::clone(&self.call_counters),
        ));
        self.install_compiled(f)
    }

    /// Record the compiled callable for `finish()`-time artifact/stat
    /// dumps and wrap it as a VM value.
    fn install_compiled(&self, f: crate::graph::CompiledGraphFn) -> Value {
        let f = Rc::new(f);
        self.state.borrow_mut().compiled.push(Rc::clone(&f));
        Value::CompiledGraph(f)
    }
}

impl EvalHook for Dynamo {
    fn eval_frame(
        &self,
        func: &Rc<Function>,
        args: &[Value],
        globals: &Rc<RefCell<HashMap<String, Value>>>,
    ) -> Option<Rc<CodeObject>> {
        let ptr = Rc::as_ptr(&func.code) as usize;
        let hit = {
            let st = self.state.borrow();
            if st.own_output.contains(&ptr) {
                return None;
            }
            match st.cache.get(&ptr) {
                None => None,
                Some(cc) if cc.skip => return None,
                Some(cc) => {
                    Metrics::bump(&self.metrics.guard_checks);
                    let g = globals.borrow();
                    match cc.table.lookup(args, &g) {
                        Some(entry) => Some(Rc::clone(&entry.code)),
                        None => {
                            // Miss: recompile. A full table evicts its LRU
                            // entry at insert time instead of running the
                            // call uncompiled.
                            Metrics::bump(&self.metrics.guard_failures);
                            None
                        }
                    }
                }
            }
        };
        if let Some(code) = hit {
            Metrics::bump(&self.metrics.cache_hits);
            self.note_trace(|| format!("cache hit: {} dispatched to {}", func.name, code.name));
            return Some(code);
        }
        Metrics::bump(&self.metrics.cache_misses);

        // ---- compile ----
        let result = self.metrics.time_compile(|| {
            let id = {
                let mut st = self.state.borrow_mut();
                st.next_id += 1;
                st.next_id
            };
            let graph_name = format!("__compiled_fn_{}", id);
            let resume_base = format!("__resume_{}", id);
            let limits = Limits { max_instrs: self.config.max_trace_instrs, max_nodes: self.config.max_graph_nodes };

            let cap_result = {
                let g = globals.borrow();
                capture::capture(&func.code, args, &g, &graph_name, limits)
            };
            let mut cap = match cap_result {
                Ok(c) => c,
                Err(capture::Abort(reason)) => {
                    self.note(format!("skip {}: {}", func.name, reason));
                    Metrics::bump(&self.metrics.fallbacks);
                    let mut st = self.state.borrow_mut();
                    st.cache.entry(ptr).or_default().skip = true;
                    st.cache.entry(ptr).or_default().skip_reason = Some(reason);
                    return None;
                }
            };

            // Pure-python functions gain nothing from compilation.
            if cap.graph.num_ops() == 0 && matches!(cap.outcome, Outcome::Return(_)) {
                self.note(format!("skip {}: no tensor operations", func.name));
                Metrics::bump(&self.metrics.fallbacks);
                let mut st = self.state.borrow_mut();
                st.cache.entry(ptr).or_default().skip = true;
                return None;
            }

            emit::select_outputs(&mut cap);
            let transformed = match emit::emit_transformed(&func.code, &cap, &graph_name, &resume_base) {
                Ok(t) => t,
                Err(emit::EmitError(reason)) => {
                    self.note(format!("skip {}: cannot materialize state ({})", func.name, reason));
                    Metrics::bump(&self.metrics.fallbacks);
                    let mut st = self.state.borrow_mut();
                    st.cache.entry(ptr).or_default().skip = true;
                    return None;
                }
            };

            Metrics::bump(&self.metrics.captures);
            match &cap.outcome {
                Outcome::Return(_) => self.note(format!(
                    "compiled {} -> {} ({} ops, {} guards, full graph)",
                    func.name,
                    graph_name,
                    cap.graph.num_ops(),
                    cap.guards.len()
                )),
                Outcome::Break { at, reason, .. } => {
                    Metrics::bump(&self.metrics.graph_breaks);
                    self.note(format!(
                        "compiled {} -> {} ({} ops, {} guards) with graph break at instr {}: {}",
                        func.name,
                        graph_name,
                        cap.graph.num_ops(),
                        cap.guards.len(),
                        at,
                        reason
                    ));
                }
                Outcome::Branch { at, reason, .. } => {
                    Metrics::bump(&self.metrics.graph_breaks);
                    self.note(format!(
                        "compiled {} -> {} ({} ops, {} guards) with branch break at instr {}: {}",
                        func.name,
                        graph_name,
                        cap.graph.num_ops(),
                        cap.guards.len(),
                        at,
                        reason
                    ));
                }
            }
            for g in &cap.guards {
                self.note(format!("  guard: {}", g.describe()));
            }

            // Install the compiled graph + resume functions as globals.
            // The graph and guard set are *moved* out of the capture — the
            // read path must not pay for wholesale clones.
            let graph = Arc::new(std::mem::take(&mut cap.graph));
            {
                let mut gm = globals.borrow_mut();
                if transformed.graph_used {
                    gm.insert(
                        graph_name.clone(),
                        self.compile_backend(&graph_name, Arc::clone(&graph), &cap.guards),
                    );
                }
                for (rname, rcode) in &transformed.resume_codes {
                    gm.insert(
                        rname.clone(),
                        Value::Func(Rc::new(Function {
                            name: rname.clone(),
                            code: Rc::clone(rcode),
                            defaults: Vec::new(),
                            closure: Vec::new(),
                        })),
                    );
                }
            }

            // Book-keeping for dumps and the no-rehook set.
            let evicted = {
                let mut st = self.state.borrow_mut();
                st.graphs_snap = None;
                st.codes_snap = None;
                st.own_output.insert(Rc::as_ptr(&transformed.code) as usize);
                if transformed.graph_used {
                    st.graphs.push((graph_name.clone(), Arc::clone(&graph)));
                }
                st.generated_codes.push((transformed.code.name.clone(), Rc::clone(&transformed.code)));
                for (rname, rcode) in &transformed.resume_codes {
                    st.generated_codes.push((rname.clone(), Rc::clone(rcode)));
                }
                let guards = std::mem::take(&mut cap.guards);
                let cc = st.cache.entry(ptr).or_default();
                // LRU eviction at the cache limit: drop the entry with the
                // stalest dispatch stamp so the fresh specialization always
                // compiles (the old behaviour ran uncompiled forever). A
                // code object that keeps churning — more than
                // cache_limit * THRASH_EVICTIONS_FACTOR evictions — is
                // cycling through unbounded specializations; further calls
                // run uncompiled instead of recompiling every time.
                let at_capacity =
                    self.config.cache_limit > 0 && cc.table.len() >= self.config.cache_limit;
                let evicted = if at_capacity { cc.table.evict_lru() } else { None };
                if evicted.is_some() {
                    cc.evictions += 1;
                    Metrics::bump(&self.metrics.evictions);
                }
                let thrashing = self.config.cache_limit > 0
                    && cc.evictions >= self.config.cache_limit * THRASH_EVICTIONS_FACTOR;
                if thrashing {
                    cc.skip = true;
                    cc.skip_reason = Some(format!(
                        "guard-cache thrashing: {} evictions at cache_limit {}",
                        cc.evictions, self.config.cache_limit
                    ));
                }
                cc.table.insert(guards, Rc::clone(&transformed.code));
                (evicted, thrashing)
            };
            let (evicted, thrashing) = evicted;
            if let Some((idx, code)) = evicted {
                self.note(format!(
                    "  cache: evicted LRU entry {} ({}) of {} at cache_limit {}",
                    idx, code.name, func.name, self.config.cache_limit
                ));
            }
            if thrashing {
                Metrics::bump(&self.metrics.fallbacks);
                self.note(format!(
                    "  cache: {} is thrashing ({}x cache_limit evictions); future calls run uncompiled",
                    func.name, THRASH_EVICTIONS_FACTOR
                ));
            }
            Some(transformed.code)
        });
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;
    use crate::vm::Vm;

    /// Run a module source twice: once plain, once under dynamo; outputs
    /// must match and (for the hooked run) compilation must have happened.
    fn check(src: &str) -> (Rc<Dynamo>, String) {
        let plain = Vm::new();
        plain.seed(7);
        plain.exec_source(src, IsaVersion::V310).unwrap_or_else(|e| panic!("plain run failed: {}\n{}", e, src));
        let expected = plain.take_output();

        let mut vm = Vm::new();
        vm.seed(7);
        let dynamo = Dynamo::new(DynamoConfig::default());
        vm.eval_hook = Some(dynamo.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap_or_else(|e| panic!("dynamo run failed: {}\n{}", e, src));
        let got = vm.take_output();
        assert_eq!(got, expected, "behaviour changed under dynamo for:\n{}", src);
        (dynamo, got)
    }

    #[test]
    fn full_graph_capture() {
        let (d, _) = check(
            "def f(x, y):\n    return (x @ y).relu().sum()\na = torch.ones([4, 4])\nb = torch.ones([4, 4])\nprint(f(a, b).item())\nprint(f(a, b).item())\n",
        );
        assert_eq!(d.metrics.captures.get(), 1);
        assert!(d.metrics.cache_hits.get() >= 1, "second call should hit cache");
        assert_eq!(d.metrics.graph_breaks.get(), 0);
        let graphs = d.graphs();
        assert_eq!(graphs.len(), 1);
        assert!(graphs[0].1.num_ops() >= 3);
    }

    #[test]
    fn graph_break_on_print() {
        let (d, _) = check(
            "def f(x):\n    y = x * 2\n    print('mid', y.sum().item())\n    return (y + 1).sum()\nprint(f(torch.ones([3])).item())\n",
        );
        assert!(d.metrics.graph_breaks.get() >= 1, "print must cause a graph break: {:?}", d.log());
    }

    #[test]
    fn branch_break_two_resumes() {
        // The paper's Figure 1 example: data-dependent branch.
        let src = "def f(a, b):\n    x = a / (abs(a) + 1)\n    if b.sum() >= 0:\n        b = b * -1\n    return x * b\nprint(f(torch.ones([4]), torch.ones([4])).sum().item())\nprint(f(torch.ones([4]), (torch.ones([4]) * -1)).sum().item())\n";
        let (d, _) = check(src);
        assert!(d.metrics.graph_breaks.get() >= 1);
        // Two resume functions => at least 3 generated code objects.
        let gen = d.generated_codes();
        assert!(gen.len() >= 3, "{:?}", gen.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>());
        assert!(gen.iter().any(|(n, _)| n.contains("__resume_")));
    }

    #[test]
    fn guards_trigger_recompile_on_shape_change() {
        let src = "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2, 2])).item())\nprint(f(torch.ones([3, 3])).item())\nprint(f(torch.ones([2, 2])).item())\n";
        let (d, _) = check(src);
        assert_eq!(d.metrics.captures.get(), 2, "shape change must recompile: {:?}", d.log());
        assert!(d.metrics.cache_hits.get() >= 1, "third call should reuse the first entry");
    }

    #[test]
    fn python_loop_unrolls_into_graph() {
        let src = "def f(x):\n    for i in range(4):\n        x = x.relu() + i\n    return x.sum()\nprint(f(torch.ones([8])).item())\n";
        let (d, _) = check(src);
        assert_eq!(d.metrics.graph_breaks.get(), 0);
        let graphs = d.graphs();
        assert!(graphs[0].1.num_ops() >= 8, "loop should unroll into the graph");
    }

    #[test]
    fn scalar_arg_guard() {
        let src = "def f(x, k):\n    return (x * k).sum()\nprint(f(torch.ones([2]), 3).item())\nprint(f(torch.ones([2]), 4).item())\n";
        let (d, _) = check(src);
        assert_eq!(d.metrics.captures.get(), 2, "int arg is guarded, change recompiles: {:?}", d.log());
    }

    #[test]
    fn global_weights_are_lifted_and_guarded() {
        let src = "W = torch.ones([3, 3])\ndef f(x):\n    return (x @ W).sum()\nprint(f(torch.ones([2, 3])).item())\n";
        let (d, _) = check(src);
        let graphs = d.graphs();
        assert_eq!(graphs[0].1.inputs.len(), 2, "global W lifted as input");
    }

    #[test]
    fn user_function_call_breaks() {
        let src = "def helper(t):\n    return t * 3\ndef f(x):\n    y = x + 1\n    z = helper(y)\n    return z.sum()\nprint(f(torch.ones([4])).item())\n";
        let (d, _) = check(src);
        assert!(d.metrics.graph_breaks.get() >= 1, "{:?}", d.log());
    }

    #[test]
    fn item_breaks_then_resumes() {
        let src = "def f(x):\n    m = x.mean()\n    v = m.item()\n    if v > 0:\n        return x * 2\n    return x * -2\nprint(f(torch.ones([4])).sum().item())\n";
        let (d, _) = check(src);
        assert!(d.metrics.graph_breaks.get() >= 1);
    }

    #[test]
    fn skip_list_for_unsupported() {
        // Closures abort the capture; behaviour must still be correct.
        let src = "def outer():\n    n = torch.ones([2])\n    def inner():\n        return n\n    return inner\ng = outer()\nprint(g().sum().item())\n";
        let (d, _) = check(src);
        assert!(d.metrics.fallbacks.get() >= 1);
    }

    #[test]
    fn cache_hit_path_is_silent_by_default() {
        let (d, _) = check(
            "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\nprint(f(torch.ones([2])).item())\n",
        );
        assert!(d.metrics.cache_hits.get() >= 1);
        assert!(!d.log().iter().any(|l| l.contains("cache hit")), "{:?}", d.log());
    }

    #[test]
    fn verbosity_gate_controls_hit_logging() {
        let src = "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\nprint(f(torch.ones([2])).item())\n";
        let mut vm = Vm::new();
        let d = Dynamo::new(DynamoConfig { verbosity: Verbosity::Trace, ..Default::default() });
        vm.eval_hook = Some(d.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap();
        assert!(d.log().iter().any(|l| l.contains("cache hit")), "{:?}", d.log());

        let mut vm2 = Vm::new();
        let q = Dynamo::new(DynamoConfig { verbosity: Verbosity::Quiet, ..Default::default() });
        vm2.eval_hook = Some(q.clone());
        vm2.exec_source(src, IsaVersion::V310).unwrap();
        assert!(q.log().is_empty(), "{:?}", q.log());
        assert!(q.metrics.cache_hits.get() >= 1, "quiet mode must still dispatch");
    }

    #[test]
    fn read_snapshots_are_shared_not_copied() {
        let (d, _) = check(
            "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\n",
        );
        let (a, b) = (d.log(), d.log());
        assert!(Rc::ptr_eq(&a, &b), "log snapshots must share storage");
        let (g1, g2) = (d.graphs(), d.graphs());
        assert!(Rc::ptr_eq(&g1, &g2));
        let (c1, c2) = (d.generated_codes(), d.generated_codes());
        assert!(Rc::ptr_eq(&c1, &c2));
    }

    #[test]
    fn xla_backend_end_to_end() {
        let src = "def f(x, y):\n    return ((x @ y) + 1).relu().sum()\nprint(f(torch.ones([4, 4]), torch.ones([4, 4])).item())\n";
        let plain = Vm::new();
        plain.exec_source(src, IsaVersion::V310).unwrap();
        let expected = plain.take_output();

        let rt = Runtime::cpu().expect("pjrt");
        let mut vm = Vm::new();
        let dynamo = Dynamo::with_runtime(
            DynamoConfig { backend: Arc::new(crate::api::XlaBackend), ..Default::default() },
            rt,
        );
        vm.eval_hook = Some(dynamo.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap();
        assert_eq!(vm.take_output(), expected);
        assert_eq!(dynamo.metrics.captures.get(), 1);
    }

    #[test]
    fn sharded_backend_end_to_end() {
        let src = "def f(x, y):\n    return ((x @ y) + 1).relu().softmax().sum()\nprint(f(torch.ones([4, 4]), torch.ones([4, 4])).item())\n";
        let plain = Vm::new();
        plain.exec_source(src, IsaVersion::V310).unwrap();
        let expected = plain.take_output();

        let mut vm = Vm::new();
        let dynamo = Dynamo::new(DynamoConfig {
            backend: Arc::new(crate::backend::ShardedBackend::with_max_ops(2)),
            fallback: FallbackPolicy::Error,
            ..Default::default()
        });
        vm.eval_hook = Some(dynamo.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap();
        assert_eq!(vm.take_output(), expected);
        let compiled = dynamo.compiled();
        assert_eq!(compiled.len(), 1);
        assert_eq!(compiled[0].backend_name, "sharded");
        assert!(compiled[0].module.stats().partitions >= 2, "{:?}", compiled[0].module.stats());
        assert!(
            dynamo.log().iter().any(|l| l.contains("split") && l.contains("partitions")),
            "{:?}",
            dynamo.log()
        );
    }

    #[test]
    fn batched_backend_shares_bucket_across_guard_entries() {
        // Two shape-specialized guard entries (batch 5 and 6) land in
        // bucket 8: one executable serves both.
        let src = "def f(x):\n    return (x * 2).relu()\nprint(f(torch.ones([5, 4])).sum().item())\nprint(f(torch.ones([6, 4])).sum().item())\n";
        let plain = Vm::new();
        plain.exec_source(src, IsaVersion::V310).unwrap();
        let expected = plain.take_output();

        let mut vm = Vm::new();
        let dynamo = Dynamo::new(DynamoConfig {
            backend: Arc::new(crate::backend::BatchedBackend::new()),
            fallback: FallbackPolicy::Error,
            ..Default::default()
        });
        vm.eval_hook = Some(dynamo.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap();
        assert_eq!(vm.take_output(), expected);
        assert_eq!(dynamo.metrics.captures.get(), 2, "shape change still recompiles bytecode");
        let compiled = dynamo.compiled();
        assert_eq!(compiled.len(), 2);
        assert_eq!(compiled[0].module.stats().bucket, Some(8));
        assert_eq!(compiled[0].module.stats().cache_hits, 0);
        assert_eq!(compiled[1].module.stats().bucket, Some(8));
        assert_eq!(compiled[1].module.stats().cache_hits, 1, "second entry must reuse the bucket");
        assert!(
            dynamo.log().iter().any(|l| l.contains("shared executable")),
            "{:?}",
            dynamo.log()
        );
    }

    #[test]
    fn fallback_error_policy_surfaces_backend_failure() {
        // Xla without a runtime under FallbackPolicy::Error: capture
        // succeeds, but calling the compiled graph raises a VM error.
        let src = "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\n";
        let mut vm = Vm::new();
        let dynamo = Dynamo::new(DynamoConfig {
            backend: Arc::new(crate::api::XlaBackend),
            fallback: FallbackPolicy::Error,
            ..Default::default()
        });
        vm.eval_hook = Some(dynamo.clone());
        let err = vm.exec_source(src, IsaVersion::V310).unwrap_err();
        assert!(err.message.contains("failed to compile"), "{}", err);
        assert!(dynamo.log().iter().any(|l| l.contains("backend: xla failed")), "{:?}", dynamo.log());
    }

    #[test]
    fn fallback_eager_policy_degrades_and_logs() {
        // Same misconfiguration under the default policy: output stays
        // correct and the degrade is recorded in the frontend log.
        let src = "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\n";
        let plain = Vm::new();
        plain.exec_source(src, IsaVersion::V310).unwrap();
        let expected = plain.take_output();

        let mut vm = Vm::new();
        let dynamo = Dynamo::new(DynamoConfig {
            backend: Arc::new(crate::api::XlaBackend),
            ..Default::default()
        });
        vm.eval_hook = Some(dynamo.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap();
        assert_eq!(vm.take_output(), expected);
        assert!(
            dynamo.log().iter().any(|l| l.contains("backend: xla degraded to eager")),
            "{:?}",
            dynamo.log()
        );
        // The fallback executor ran the captured graph verbatim — no
        // optimizer run may be recorded (or dumped) for it.
        assert!(dynamo.optimizations().is_empty(), "{:?}", dynamo.log());
        assert!(!dynamo.log().iter().any(|l| l.contains("optimizer:")), "{:?}", dynamo.log());
    }

    #[test]
    fn custom_backend_name_is_not_misreported_as_degrade() {
        // A custom backend may stamp a backend_name different from name();
        // that must not be logged as a fallback.
        struct Tagger;
        impl crate::api::Backend for Tagger {
            fn name(&self) -> &str {
                "tagger"
            }
            fn plan(&self, req: &CompileRequest) -> Result<crate::api::CompilePlan, DepyfError> {
                Ok(crate::api::CompilePlan::monolithic("tagger", req, "eager"))
            }
            fn lower(
                &self,
                req: &CompileRequest,
                _plan: &crate::api::CompilePlan,
            ) -> Result<Arc<dyn crate::api::CompiledModule>, DepyfError> {
                Ok(Arc::new(crate::backend::eager::EagerModule::with_name(
                    Arc::clone(&req.graph),
                    "tagger-v2".into(),
                )))
            }
        }
        let src = "def f(x):\n    return (x * 2).sum()\nprint(f(torch.ones([2])).item())\n";
        let mut vm = Vm::new();
        let dynamo = Dynamo::new(DynamoConfig { backend: Arc::new(Tagger), ..Default::default() });
        vm.eval_hook = Some(dynamo.clone());
        vm.exec_source(src, IsaVersion::V310).unwrap();
        assert!(
            !dynamo.log().iter().any(|l| l.contains("degraded")),
            "spurious degrade note: {:?}",
            dynamo.log()
        );
    }

    /// Run a failing module twice (plain and hooked); the error messages
    /// must agree, and the capture must not have aborted into a skip.
    fn check_err(src: &str) -> (Rc<Dynamo>, String) {
        let plain = Vm::new();
        plain.seed(7);
        let expected = plain.exec_source(src, IsaVersion::V310).unwrap_err().message;

        let mut vm = Vm::new();
        vm.seed(7);
        let dynamo = Dynamo::new(DynamoConfig::default());
        vm.eval_hook = Some(dynamo.clone());
        let got = vm.exec_source(src, IsaVersion::V310).unwrap_err().message;
        assert_eq!(got, expected, "error changed under dynamo for:\n{}", src);
        (dynamo, got)
    }

    // Fuzzer-derived: an unknown tensor method used to abort the whole
    // capture; now it graph-breaks and the VM replays the call (raising the
    // same error the plain run raises).
    #[test]
    fn unknown_tensor_method_breaks_instead_of_aborting() {
        let src = "def f(x):\n    y = x * 2\n    return y.clamp()\nprint(f(torch.ones([3])).sum().item())\n";
        let (d, msg) = check_err(src);
        assert!(msg.contains("clamp"), "{}", msg);
        assert!(d.metrics.graph_breaks.get() >= 1, "unknown method must graph-break: {:?}", d.log());
        assert!(d.metrics.captures.get() >= 1, "prefix before the break must still compile: {:?}", d.log());
    }

    // Fuzzer-derived: a known unary method called with the wrong arity falls
    // through every graph arm; it must degrade to the VM, not panic.
    #[test]
    fn wrong_arity_tensor_method_degrades_to_vm() {
        let src = "def f(x):\n    return x.relu(1)\nprint(f(torch.ones([2])).sum().item())\n";
        let (d, _) = check_err(src);
        assert!(d.metrics.graph_breaks.get() >= 1, "{:?}", d.log());
    }

    // The graceful break also covers calls the VM *does* execute: the break
    // resumes and the program completes with the plain-VM output.
    #[test]
    fn data_dependent_method_arg_still_runs_correctly() {
        let src = "def f(x):\n    a = int(x.mean().item()) * 0\n    y = x + 1\n    return y.sum(a)\nprint(f(torch.ones([2, 3])).sum().item())\n";
        let (d, _) = check(src);
        assert!(d.metrics.graph_breaks.get() >= 1, "{:?}", d.log());
    }
}
