//! Guards: the conditions under which a cached compiled entry is valid.
//! Checked on every hooked call; a miss triggers recompilation (up to the
//! cache-size limit), exactly like TorchDynamo's guard system.
//!
//! Dispatch is two-stage (see [`GuardTable`]): each distinct [`Origin`]
//! across all of a code object's entries is resolved **at most once per
//! call** into a memoized slot vector, and entries are bucketed by a cheap
//! discriminant (the rank of the first-argument tensor) so shape-polymorphic
//! recompiles don't pay for each other's guard sets. Identity and constant
//! guards compare pre-computed tokens/fingerprints before falling back to
//! structural equality.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use super::sym::Origin;
use crate::bytecode::CodeObject;
use crate::fnv::Fnv;
use crate::value::Value;

#[derive(Clone, Debug)]
pub enum Guard {
    /// A lifted tensor input must keep its capture-time shape.
    TensorShape { origin: Origin, shape: Vec<usize> },
    /// A Python scalar that was baked into the trace must be unchanged.
    ConstEq { origin: Origin, value: Value },
    /// A callable / module object must be the same object.
    Identity { origin: Origin, value: Value },
    /// Container length (lists/tuples seen structurally).
    Len { origin: Origin, len: usize },
    /// Remaining items of an iterator argument (resume functions).
    IterRemaining { origin: Origin, len: usize },
}

impl Guard {
    /// Does this guard hold for the given call state?
    pub fn check(&self, args: &[Value], globals: &HashMap<String, Value>) -> bool {
        let resolved = self.origin().resolve(args, globals);
        self.holds_for(resolved.as_ref())
    }

    /// The origin this guard re-resolves on every call.
    pub fn origin(&self) -> &Origin {
        match self {
            Guard::TensorShape { origin, .. }
            | Guard::ConstEq { origin, .. }
            | Guard::Identity { origin, .. }
            | Guard::Len { origin, .. }
            | Guard::IterRemaining { origin, .. } => origin,
        }
    }

    /// Guard predicate against an already-resolved value (`None` = the
    /// origin's path no longer exists, which always fails).
    pub fn holds_for(&self, resolved: Option<&Value>) -> bool {
        match self {
            Guard::TensorShape { shape, .. } => match resolved {
                Some(Value::Tensor(t)) => t.shape() == &shape[..],
                _ => false,
            },
            Guard::ConstEq { value, .. } => match resolved {
                Some(v) => v.eq_value(value),
                None => false,
            },
            Guard::Identity { value, .. } => match resolved {
                Some(v) => v.is_identical(value),
                None => false,
            },
            Guard::Len { len, .. } => match resolved {
                Some(Value::List(l)) => l.borrow().len() == *len,
                Some(Value::Tuple(t)) => t.len() == *len,
                Some(Value::Dict(d)) => d.borrow().len() == *len,
                _ => false,
            },
            Guard::IterRemaining { len, .. } => match resolved {
                Some(Value::Iter(it)) => {
                    let it = it.borrow();
                    // `pos` can run past `len` if the iterator was advanced
                    // after capture; that is a miss, not an underflow panic.
                    it.items.len().checked_sub(it.pos) == Some(*len)
                }
                _ => false,
            },
        }
    }

    /// Rendered into `full_code` dumps.
    pub fn describe(&self) -> String {
        match self {
            Guard::TensorShape { origin, shape } => format!("check_tensor({}, shape={:?})", origin.describe(), shape),
            Guard::ConstEq { origin, value } => format!("{} == {}", origin.describe(), value.repr()),
            Guard::Identity { origin, value } => format!("{} is {}", origin.describe(), value.repr()),
            Guard::Len { origin, len } => format!("len({}) == {}", origin.describe(), len),
            Guard::IterRemaining { origin, len } => format!("iter_remaining({}) == {}", origin.describe(), len),
        }
    }
}

/// Check a full guard set (the reference linear-scan semantics; the hot
/// path goes through [`GuardTable::lookup`] instead).
pub fn check_all(guards: &[Guard], args: &[Value], globals: &HashMap<String, Value>) -> bool {
    guards.iter().all(|g| g.check(args, globals))
}

// ---- two-stage dispatch ----

/// Cheap FNV-1a fingerprint of scalar-ish values, precomputed for
/// [`Guard::ConstEq`] so a mismatch is rejected on a u64 compare without
/// walking string/struct contents. `None` for values with no cheap
/// fingerprint (containers, tensors) — those fall back to `eq_value`.
fn value_fingerprint(v: &Value) -> Option<u64> {
    // Invariant: `a.eq_value(&b)` implies equal fingerprints (a mismatch
    // rejects without the structural compare; a match is still confirmed).
    // Numeric cross-type equality (1 == 1.0 == True) goes through lossy
    // f64 casts in `eq_value`, so every numeric hashes its f64 image, with
    // -0.0 normalized onto 0.0.
    fn num_fp(f: f64) -> u64 {
        let f = if f == 0.0 { 0.0 } else { f };
        let mut h = Fnv::new();
        h.num(1);
        h.num(f.to_bits());
        h.finish()
    }
    Some(match v {
        Value::None => {
            let mut h = Fnv::new();
            h.num(0);
            h.finish()
        }
        Value::Bool(b) => num_fp(*b as i64 as f64),
        Value::Int(i) => num_fp(*i as f64),
        Value::Float(f) => num_fp(*f),
        Value::Str(s) => {
            let mut h = Fnv::new();
            h.num(4);
            h.bytes(s.as_bytes());
            h.finish()
        }
        _ => return None,
    })
}

/// Identity token: (type tag, address-or-value) such that token equality
/// is exactly [`Value::is_identical`] for the tagged types. Ints are
/// widened to u64 (not usize) so distinct i64s never share a token on
/// 32-bit targets. `None` for types without a token — those fall back to
/// `is_identical`.
fn identity_token(v: &Value) -> Option<(u8, u64)> {
    Some(match v {
        Value::None => (0, 0),
        Value::Bool(b) => (1, *b as u64),
        Value::Int(i) => (2, *i as u64),
        Value::Str(s) => (3, Rc::as_ptr(s) as *const u8 as usize as u64),
        Value::List(l) => (4, Rc::as_ptr(l) as usize as u64),
        Value::Tuple(t) => (5, Rc::as_ptr(t) as *const u8 as usize as u64),
        Value::Dict(d) => (6, Rc::as_ptr(d) as usize as u64),
        Value::Tensor(t) => (7, Rc::as_ptr(t) as usize as u64),
        Value::Func(f) => (8, Rc::as_ptr(f) as usize as u64),
        Value::Builtin(b) => (9, Rc::as_ptr(b) as usize as u64),
        _ => return None,
    })
}

/// The check half of a compiled guard, with pre-computed comparison keys.
#[derive(Debug)]
enum Check {
    TensorShape { shape: Vec<usize> },
    ConstEq { value: Value, fp: Option<u64> },
    Identity { value: Value, token: Option<(u8, u64)> },
    Len { len: usize },
    IterRemaining { len: usize },
}

impl Check {
    fn holds(&self, resolved: Option<&Value>) -> bool {
        match self {
            Check::TensorShape { shape } => match resolved {
                Some(Value::Tensor(t)) => t.shape() == &shape[..],
                _ => false,
            },
            Check::ConstEq { value, fp } => match resolved {
                Some(v) => {
                    if let (Some(a), Some(b)) = (fp, value_fingerprint(v)) {
                        if *a != b {
                            return false;
                        }
                    }
                    v.eq_value(value)
                }
                None => false,
            },
            Check::Identity { value, token } => match resolved {
                Some(v) => {
                    if let (Some(a), Some(b)) = (token, identity_token(v)) {
                        return *a == b;
                    }
                    v.is_identical(value)
                }
                None => false,
            },
            Check::Len { len } => match resolved {
                Some(Value::List(l)) => l.borrow().len() == *len,
                Some(Value::Tuple(t)) => t.len() == *len,
                Some(Value::Dict(d)) => d.borrow().len() == *len,
                _ => false,
            },
            Check::IterRemaining { len } => match resolved {
                Some(Value::Iter(it)) => {
                    let it = it.borrow();
                    it.items.len().checked_sub(it.pos) == Some(*len)
                }
                _ => false,
            },
        }
    }
}

/// One guard compiled against the table's slot map: the origin is replaced
/// by a slot index into the per-call resolved vector.
#[derive(Debug)]
struct CompiledGuard {
    slot: usize,
    check: Check,
}

/// Bucket discriminant. An entry carrying a `TensorShape` guard on exactly
/// `Origin::Arg(0)` can only match calls whose first argument is a tensor
/// of that rank; everything else is a wildcard checked on every call.
/// Sound by construction: a rank (or type) mismatch on `arg0` fails that
/// guard under linear scan too, so skipping the entry never changes the
/// dispatch result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct Arg0Rank(usize);

fn entry_disc(guards: &[Guard]) -> Option<Arg0Rank> {
    guards.iter().find_map(|g| match g {
        Guard::TensorShape { origin: Origin::Arg(0), shape } => Some(Arg0Rank(shape.len())),
        _ => None,
    })
}

fn call_disc(args: &[Value]) -> Option<Arg0Rank> {
    match args.first() {
        Some(Value::Tensor(t)) => Some(Arg0Rank(t.rank())),
        _ => None,
    }
}

/// One cached compiled entry: the original guards (for dumps and for the
/// linear-scan equivalence tests) plus their compiled form and the usage
/// tracking ([`GuardTable::lookup`] hits + recency stamp) the LRU
/// eviction policy reads.
///
/// Usage tracking is atomic: a dispatch bumps hits/recency through a
/// shared reference, so readers holding `&GuardTable` never need the
/// mutable borrow the old `Cell`s implied, and interleaved readers can't
/// tear a counter. (The table as a whole is still session-confined —
/// guards hold `Rc`-based [`Value`]s — each serve thread owns its own
/// table; see `src/serve/`.)
pub struct TableEntry {
    pub guards: Vec<Guard>,
    pub code: Rc<CodeObject>,
    compiled: Vec<CompiledGuard>,
    /// Successful dispatches through this entry.
    hits: AtomicU64,
    /// Logical clock of the last dispatch (insertion counts as a use, so
    /// a brand-new entry is never the immediate eviction victim).
    last_used: AtomicU64,
}

impl TableEntry {
    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn last_used(&self) -> u64 {
        self.last_used.load(Ordering::Relaxed)
    }
}

/// Precompiled guard dispatcher for one hooked code object.
///
/// Stage 1: compute the call discriminant and merge the matching bucket
/// with the wildcard list (in insertion order, so dispatch picks the same
/// entry a linear scan would). Stage 2: check each candidate's compiled
/// guards against the memoized resolved-slot vector — each distinct origin
/// is resolved at most once per call, however many entries share it.
#[derive(Default)]
pub struct GuardTable {
    origins: Vec<Origin>,
    slot_by_key: HashMap<String, usize>,
    entries: Vec<TableEntry>,
    buckets: HashMap<Arg0Rank, Vec<usize>>,
    wildcard: Vec<usize>,
    /// Reused per-call resolved-slot scratch: steady-state dispatch does no
    /// heap allocation once capacity is warm (cleared after every lookup so
    /// resolved values don't outlive the call).
    scratch: RefCell<Vec<Option<Option<Value>>>>,
    /// Monotonic logical clock stamping entry usage (LRU recency).
    /// Atomic so ticks from lookups through `&self` are race-free.
    clock: AtomicU64,
}

impl GuardTable {
    pub fn new() -> GuardTable {
        GuardTable::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Number of distinct origins across all entries (= resolved slots).
    pub fn num_slots(&self) -> usize {
        self.origins.len()
    }

    pub fn entries(&self) -> &[TableEntry] {
        &self.entries
    }

    fn slot_for(&mut self, origin: &Origin) -> usize {
        let key = origin.cache_key();
        if let Some(&s) = self.slot_by_key.get(&key) {
            return s;
        }
        let s = self.origins.len();
        self.origins.push(origin.clone());
        self.slot_by_key.insert(key, s);
        s
    }

    /// Compile and insert a new entry (most recent last, like the old
    /// linear scan's push order).
    pub fn insert(&mut self, guards: Vec<Guard>, code: Rc<CodeObject>) {
        let compiled: Vec<CompiledGuard> = guards
            .iter()
            .map(|g| {
                let slot = self.slot_for(g.origin());
                let check = match g {
                    Guard::TensorShape { shape, .. } => Check::TensorShape { shape: shape.clone() },
                    Guard::ConstEq { value, .. } => {
                        Check::ConstEq { value: value.clone(), fp: value_fingerprint(value) }
                    }
                    Guard::Identity { value, .. } => {
                        Check::Identity { value: value.clone(), token: identity_token(value) }
                    }
                    Guard::Len { len, .. } => Check::Len { len: *len },
                    Guard::IterRemaining { len, .. } => Check::IterRemaining { len: *len },
                };
                CompiledGuard { slot, check }
            })
            .collect();
        let idx = self.entries.len();
        match entry_disc(&guards) {
            Some(d) => self.buckets.entry(d).or_default().push(idx),
            None => self.wildcard.push(idx),
        }
        let stamp = self.tick();
        self.entries.push(TableEntry {
            guards,
            code,
            compiled,
            hits: AtomicU64::new(0),
            last_used: AtomicU64::new(stamp),
        });
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Evict the least-recently-used entry (ties broken by fewer hits,
    /// then lowest index — fully deterministic), returning its index and
    /// code object. This is what dynamo runs at `cache_limit` instead of
    /// giving up and running uncompiled.
    pub fn evict_lru(&mut self) -> Option<(usize, Rc<CodeObject>)> {
        let victim = (0..self.entries.len()).min_by_key(|&i| {
            (self.entries[i].last_used(), self.entries[i].hit_count(), i)
        })?;
        let code = self.remove(victim)?;
        Some((victim, code))
    }

    /// Remove the entry at `idx` (cache eviction), returning its code
    /// object. Bucket and wildcard index lists are rebased so the
    /// remaining entries keep their exact linear-scan dispatch order; the
    /// origin slot map is left as-is (an orphaned slot is never resolved
    /// because no surviving compiled guard references it).
    pub fn remove(&mut self, idx: usize) -> Option<Rc<CodeObject>> {
        if idx >= self.entries.len() {
            return None;
        }
        let entry = self.entries.remove(idx);
        fn rebase(v: &mut Vec<usize>, removed: usize) {
            v.retain(|&e| e != removed);
            for e in v.iter_mut() {
                if *e > removed {
                    *e -= 1;
                }
            }
        }
        for bucket in self.buckets.values_mut() {
            rebase(bucket, idx);
        }
        self.buckets.retain(|_, v| !v.is_empty());
        rebase(&mut self.wildcard, idx);
        Some(entry.code)
    }

    /// Find the first entry whose guards all pass, resolving origins with
    /// `resolve` (called at most once per distinct origin). Returns the
    /// entry index — the same index a linear scan over `entries()` yields.
    pub fn lookup_with(
        &self,
        args: &[Value],
        resolve: &mut dyn FnMut(&Origin) -> Option<Value>,
    ) -> Option<usize> {
        // Memoized resolved-slot vector: outer None = not yet resolved,
        // inner Option = resolution result (a dead path stays dead). The
        // buffer is a reused scratch (no per-call allocation in steady
        // state); the try_borrow fallback covers a resolver that re-enters
        // this same table.
        let mut borrowed;
        let mut local;
        let slots: &mut Vec<Option<Option<Value>>> = match self.scratch.try_borrow_mut() {
            Ok(b) => {
                borrowed = b;
                &mut *borrowed
            }
            Err(_) => {
                local = Vec::new();
                &mut local
            }
        };
        slots.clear();
        slots.resize(self.origins.len(), None);
        let empty: Vec<usize> = Vec::new();
        let bucket = match call_disc(args) {
            Some(d) => self.buckets.get(&d).unwrap_or(&empty),
            None => &empty,
        };
        // Merge bucket + wildcard in ascending entry order (both are
        // sorted by construction) to preserve linear-scan priority.
        let (mut bi, mut wi) = (0usize, 0usize);
        let result = loop {
            let idx = match (bucket.get(bi), self.wildcard.get(wi)) {
                (Some(&b), Some(&w)) => {
                    if b < w {
                        bi += 1;
                        b
                    } else {
                        wi += 1;
                        w
                    }
                }
                (Some(&b), None) => {
                    bi += 1;
                    b
                }
                (None, Some(&w)) => {
                    wi += 1;
                    w
                }
                (None, None) => break None,
            };
            let entry = &self.entries[idx];
            let mut ok = true;
            for g in &entry.compiled {
                if slots[g.slot].is_none() {
                    slots[g.slot] = Some(resolve(&self.origins[g.slot]));
                }
                let v = slots[g.slot].as_ref().unwrap().as_ref();
                if !g.check.holds(v) {
                    ok = false;
                    break;
                }
            }
            if ok {
                break Some(idx);
            }
        };
        // Drop resolved values now — the scratch keeps only capacity.
        slots.clear();
        result
    }

    /// Production lookup against concrete call state. Successful
    /// dispatches bump the entry's hit counter and recency stamp (the LRU
    /// signal); the reference [`GuardTable::lookup_with`] stays
    /// side-effect-free for the equivalence tests.
    pub fn lookup(&self, args: &[Value], globals: &HashMap<String, Value>) -> Option<&TableEntry> {
        let idx = self.lookup_with(args, &mut |o| o.resolve(args, globals))?;
        let entry = &self.entries[idx];
        entry.hits.fetch_add(1, Ordering::Relaxed);
        entry.last_used.store(self.tick(), Ordering::Relaxed);
        Some(entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;
    use crate::tensor::Tensor;
    use crate::value::ValueIter;
    use std::cell::RefCell;

    #[test]
    fn shape_guard() {
        let g = Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2, 3] };
        let globals = HashMap::new();
        assert!(g.check(&[Value::tensor(Tensor::zeros(&[2, 3]))], &globals));
        assert!(!g.check(&[Value::tensor(Tensor::zeros(&[3, 2]))], &globals));
        assert!(!g.check(&[Value::Int(1)], &globals));
    }

    #[test]
    fn const_and_identity_guards() {
        let globals = HashMap::new();
        let g = Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(4) };
        assert!(g.check(&[Value::Int(4)], &globals));
        assert!(!g.check(&[Value::Int(5)], &globals));

        let f = Value::builtin("f", |_| Ok(Value::None));
        let gi = Guard::Identity { origin: Origin::Arg(0), value: f.clone() };
        assert!(gi.check(&[f.clone()], &globals));
        let f2 = Value::builtin("f", |_| Ok(Value::None));
        assert!(!gi.check(&[f2], &globals));
    }

    #[test]
    fn len_guard() {
        let globals = HashMap::new();
        let g = Guard::Len { origin: Origin::Arg(0), len: 2 };
        assert!(g.check(&[Value::list(vec![Value::Int(1), Value::Int(2)])], &globals));
        assert!(!g.check(&[Value::list(vec![Value::Int(1)])], &globals));
    }

    #[test]
    fn iter_remaining_overrun_fails_instead_of_panicking() {
        let globals = HashMap::new();
        let g = Guard::IterRemaining { origin: Origin::Arg(0), len: 1 };
        // pos beyond items.len(): the iterator advanced past the captured
        // state. The old `len - pos` underflowed here.
        let it = Value::Iter(Rc::new(RefCell::new(ValueIter { items: vec![Value::Int(1)], pos: 3 })));
        assert!(!g.check(&[it], &globals));
        let ok = Value::Iter(Rc::new(RefCell::new(ValueIter {
            items: vec![Value::Int(1), Value::Int(2)],
            pos: 1,
        })));
        assert!(g.check(&[ok], &globals));
    }

    #[test]
    fn fingerprints_respect_cross_type_equality() {
        // 1 == 1.0 == True must not be split by the fingerprint fast path.
        let pairs = [
            (Value::Int(1), Value::Float(1.0)),
            (Value::Bool(true), Value::Int(1)),
            (Value::Float(0.0), Value::Float(-0.0)),
        ];
        for (a, b) in pairs {
            assert!(a.eq_value(&b));
            assert_eq!(value_fingerprint(&a), value_fingerprint(&b), "{:?} vs {:?}", a, b);
        }
        assert_ne!(value_fingerprint(&Value::Int(1)), value_fingerprint(&Value::Int(2)));
        assert_ne!(value_fingerprint(&Value::str("a")), value_fingerprint(&Value::str("b")));
    }

    fn dummy_code(tag: &str) -> Rc<CodeObject> {
        Rc::new(CodeObject::new(tag, IsaVersion::V311, 0, vec![], vec![], vec![], vec![], vec![]))
    }

    /// Entries that mirror dynamo's shape-polymorphic recompiles: same fn,
    /// different arg0 shapes, plus a scalar-guarded variant.
    fn polymorphic_table() -> GuardTable {
        let w = Value::tensor(Tensor::ones(&[3, 3]));
        let mut t = GuardTable::new();
        t.insert(
            vec![
                Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2, 2] },
                Guard::Identity { origin: Origin::Global("W".into()), value: w.clone() },
            ],
            dummy_code("e0"),
        );
        t.insert(
            vec![
                Guard::TensorShape { origin: Origin::Arg(0), shape: vec![3, 3] },
                Guard::Identity { origin: Origin::Global("W".into()), value: w.clone() },
            ],
            dummy_code("e1"),
        );
        t.insert(
            vec![
                Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(7) },
                Guard::ConstEq { origin: Origin::Arg(1), value: Value::Int(9) },
            ],
            dummy_code("e2"),
        );
        t
    }

    fn linear_scan(t: &GuardTable, args: &[Value], globals: &HashMap<String, Value>) -> Option<usize> {
        t.entries().iter().position(|e| check_all(&e.guards, args, globals))
    }

    #[test]
    fn table_dispatch_matches_linear_scan() {
        let t = polymorphic_table();
        let w = match &t.entries()[0].guards[1] {
            Guard::Identity { value, .. } => value.clone(),
            _ => unreachable!(),
        };
        let mut globals: HashMap<String, Value> = HashMap::new();
        globals.insert("W".into(), w);
        let cases: Vec<Vec<Value>> = vec![
            vec![Value::tensor(Tensor::ones(&[2, 2]))],
            vec![Value::tensor(Tensor::ones(&[3, 3]))],
            vec![Value::tensor(Tensor::ones(&[4, 4]))], // rank hit, shape miss
            vec![Value::tensor(Tensor::ones(&[2, 2, 2]))], // rank miss everywhere
            vec![Value::Int(7), Value::Int(9)],         // wildcard entry
            vec![Value::Int(7), Value::Int(8)],         // wildcard miss
            vec![],
        ];
        for args in &cases {
            let scan = linear_scan(&t, args, &globals);
            let table = t.lookup_with(args, &mut |o| o.resolve(args, &globals));
            assert_eq!(table, scan, "diverged on {:?}", args);
            assert_eq!(t.lookup(args, &globals).map(|e| e.code.name.clone()),
                scan.map(|i| t.entries()[i].code.name.clone()));
        }
        // Stale global: identity guard must fail in both strategies.
        let mut g2: HashMap<String, Value> = HashMap::new();
        g2.insert("W".into(), Value::tensor(Tensor::ones(&[3, 3])));
        let args = vec![Value::tensor(Tensor::ones(&[2, 2]))];
        assert_eq!(t.lookup_with(&args, &mut |o| o.resolve(&args, &g2)), None);
        assert_eq!(linear_scan(&t, &args, &g2), None);
    }

    #[test]
    fn distinct_origins_resolved_at_most_once_per_call() {
        let t = polymorphic_table();
        // 3 entries share Arg(0); two share Global("W"): 3 distinct origins.
        assert_eq!(t.num_slots(), 3);
        let args = vec![Value::tensor(Tensor::ones(&[4, 4]))]; // forces a full miss
        let globals: HashMap<String, Value> = HashMap::new();
        let counts: RefCell<HashMap<String, usize>> = RefCell::new(HashMap::new());
        let got = t.lookup_with(&args, &mut |o| {
            *counts.borrow_mut().entry(o.cache_key()).or_insert(0) += 1;
            o.resolve(&args, &globals)
        });
        assert_eq!(got, None);
        for (key, n) in counts.borrow().iter() {
            assert_eq!(*n, 1, "origin {} resolved {} times", key, n);
        }
    }

    /// Satellite: dispatch must stay exactly linear-scan-equivalent while
    /// entries are removed, whatever the bucket/wildcard interleaving.
    #[test]
    fn removal_preserves_linear_scan_equivalence() {
        // b = bucketed (TensorShape on arg0), w = wildcard. Layout:
        // [b2, w, b2, w, b3] — removal must rebase both index lists.
        let build = || -> GuardTable {
            let mut t = GuardTable::new();
            t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("b0"));
            t.insert(vec![Guard::Len { origin: Origin::Arg(1), len: 0 }], dummy_code("w1"));
            t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("b2"));
            t.insert(vec![Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(5) }], dummy_code("w3"));
            t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![3, 3] }], dummy_code("b4"));
            t
        };
        let globals: HashMap<String, Value> = HashMap::new();
        let cases: Vec<Vec<Value>> = vec![
            vec![Value::tensor(Tensor::ones(&[2])), Value::list(vec![])],
            vec![Value::tensor(Tensor::ones(&[3, 3])), Value::list(vec![])],
            vec![Value::Int(5)],
            vec![Value::Int(6), Value::list(vec![])],
            vec![Value::tensor(Tensor::ones(&[7])), Value::list(vec![Value::Int(1)])],
        ];
        let check_equiv = |t: &GuardTable, note: &str| {
            for args in &cases {
                let scan = linear_scan(t, args, &globals);
                let table = t.lookup_with(args, &mut |o| o.resolve(args, &globals));
                assert_eq!(table, scan, "{}: diverged on {:?}", note, args);
            }
        };
        // Remove each position in turn from a fresh table.
        for victim in 0..5 {
            let mut t = build();
            let code = t.remove(victim).expect("in range");
            assert_eq!(t.len(), 4);
            assert!(
                t.entries().iter().all(|e| !Rc::ptr_eq(&e.code, &code)),
                "removed entry {} still present",
                victim
            );
            check_equiv(&t, &format!("after removing {}", victim));
        }
        // Drain one table entry by entry, front-biased, checking at every
        // intermediate shape (wildcards and buckets interleave throughout).
        let mut t = build();
        for step in 0..5 {
            t.remove(0).expect("non-empty");
            check_equiv(&t, &format!("drain step {}", step));
        }
        assert!(t.is_empty());
        assert!(t.remove(0).is_none(), "out-of-range removal is None");
        // Removing the first matching bucketed entry promotes the next one
        // in linear-scan order, not an arbitrary bucket neighbour. (arg1 is
        // a non-empty list so the Len==0 wildcard stays out of the way.)
        let mut t = build();
        let args = vec![Value::tensor(Tensor::ones(&[2])), Value::list(vec![Value::Int(1)])];
        assert_eq!(t.lookup(&args, &globals).map(|e| e.code.name.as_str()), Some("b0"));
        t.remove(0);
        assert_eq!(t.lookup(&args, &globals).map(|e| e.code.name.as_str()), Some("b2"));
        // And inserting after removal keeps working (indices stay dense).
        t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("b5"));
        check_equiv(&t, "after post-removal insert");
        assert_eq!(t.lookup(&args, &globals).map(|e| e.code.name.as_str()), Some("b2"));
    }

    /// Satellite: LRU eviction picks the least-recently-dispatched entry
    /// (insert counts as a use; ties fall to hit count then index), and
    /// dispatch stays exactly linear-scan-equivalent afterwards.
    #[test]
    fn lru_eviction_tracks_real_usage() {
        let globals: HashMap<String, Value> = HashMap::new();
        let mut t = GuardTable::new();
        t.insert(vec![Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(0) }], dummy_code("e0"));
        t.insert(vec![Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(1) }], dummy_code("e1"));
        t.insert(vec![Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(2) }], dummy_code("e2"));
        // Touch e0 and e2; e1 becomes the LRU victim.
        assert_eq!(t.lookup(&[Value::Int(0)], &globals).map(|e| e.code.name.as_str()), Some("e0"));
        assert_eq!(t.lookup(&[Value::Int(2)], &globals).map(|e| e.code.name.as_str()), Some("e2"));
        assert_eq!(t.entries()[0].hit_count(), 1);
        assert_eq!(t.entries()[1].hit_count(), 0);
        let (idx, code) = t.evict_lru().expect("non-empty");
        assert_eq!((idx, code.name.as_str()), (1, "e1"));
        assert_eq!(t.len(), 2);
        // Surviving entries still dispatch in linear-scan order.
        for (arg, want) in [(0i64, Some("e0")), (1, None), (2, Some("e2"))] {
            assert_eq!(
                t.lookup(&[Value::Int(arg)], &globals).map(|e| e.code.name.as_str()),
                want,
                "after eviction, arg {}",
                arg
            );
            let scan = linear_scan(&t, &[Value::Int(arg)], &globals);
            assert_eq!(scan.map(|i| t.entries()[i].code.name.as_str()), want);
        }
        // A fresh insert is never the immediate next victim: with e0/e2
        // untouched since their stamps above, e0 (older stamp) goes first.
        t.insert(vec![Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(3) }], dummy_code("e3"));
        let (_, code) = t.evict_lru().unwrap();
        assert_eq!(code.name, "e0");
        // Drain to empty; eviction on an empty table is None.
        assert!(t.evict_lru().is_some() && t.evict_lru().is_some());
        assert!(t.evict_lru().is_none());
    }

    /// Eviction keeps bucket/wildcard interleavings linear-scan-faithful
    /// even when the victims are interior bucketed entries.
    #[test]
    fn lru_eviction_preserves_dispatch_order_across_kinds() {
        let globals: HashMap<String, Value> = HashMap::new();
        let mut t = GuardTable::new();
        t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("b0"));
        t.insert(vec![Guard::Len { origin: Origin::Arg(1), len: 0 }], dummy_code("w1"));
        t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("b2"));
        // Use b0 repeatedly; w1 and b2 stay cold. Evictions go w1 then b2.
        let args2 = vec![Value::tensor(Tensor::ones(&[2])), Value::list(vec![Value::Int(1)])];
        for _ in 0..3 {
            assert_eq!(t.lookup(&args2, &globals).map(|e| e.code.name.as_str()), Some("b0"));
        }
        let (_, c1) = t.evict_lru().unwrap();
        assert_eq!(c1.name, "w1");
        let (_, c2) = t.evict_lru().unwrap();
        assert_eq!(c2.name, "b2");
        assert_eq!(t.lookup(&args2, &globals).map(|e| e.code.name.as_str()), Some("b0"));
    }

    /// Satellite: a deterministic interleaving of reader steps (lookups
    /// through `&GuardTable`, bumping the atomic usage counters) with
    /// writer steps (`remove`, `insert`, `evict_lru`). The whole schedule
    /// is replayed twice and must produce the identical eviction sequence
    /// (atomics + logical clock make recency deterministic), and after
    /// every writer step dispatch stays linear-scan-equivalent — `remove`
    /// rebasing is safe with readers still dispatching between steps.
    #[test]
    fn interleaved_readers_and_removals_keep_lru_deterministic() {
        let globals: HashMap<String, Value> = HashMap::new();
        let run_schedule = || -> Vec<String> {
            let mut t = GuardTable::new();
            for i in 0..4 {
                t.insert(
                    vec![Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(i) }],
                    dummy_code(&format!("e{}", i)),
                );
            }
            let mut evicted = Vec::new();
            // Interleave: readers touch e3, e1, e3; writer removes index 0
            // (e0); readers touch e2 twice; writer evicts twice.
            let reads = [3i64, 1, 3];
            for a in reads {
                // Reader step: shared-ref dispatch, counters bump atomically.
                let hit = t.lookup(&[Value::Int(a)], &globals).map(|e| e.code.name.clone());
                assert_eq!(hit.as_deref(), Some(format!("e{}", a).as_str()));
            }
            assert_eq!(t.entries()[3].hit_count(), 2);
            let removed = t.remove(0).expect("e0 present");
            assert_eq!(removed.name, "e0");
            // Readers keep dispatching against the rebased table.
            for _ in 0..2 {
                let hit = t.lookup(&[Value::Int(2)], &globals).map(|e| e.code.name.clone());
                assert_eq!(hit.as_deref(), Some("e2"));
                let scan = t
                    .entries()
                    .iter()
                    .position(|e| check_all(&e.guards, &[Value::Int(2)], &globals));
                assert_eq!(scan.map(|i| t.entries()[i].code.name.as_str()), Some("e2"));
            }
            while let Some((_, code)) = t.evict_lru() {
                evicted.push(code.name.clone());
            }
            evicted
        };
        let first = run_schedule();
        // Recency after the schedule: e1 (stamp from read 2) is older than
        // e3 (read 3) which is older than e2 (last reads) — eviction order
        // follows exactly.
        assert_eq!(first, vec!["e1".to_string(), "e3".to_string(), "e2".to_string()]);
        // Determinism: the identical schedule replays to the identical
        // eviction sequence.
        assert_eq!(first, run_schedule());
    }

    #[test]
    fn bucketing_never_skips_a_matching_wildcard() {
        // A wildcard entry inserted *between* two bucketed ones must keep
        // its linear-scan priority.
        let mut t = GuardTable::new();
        t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("a"));
        t.insert(vec![Guard::Len { origin: Origin::Arg(1), len: 0 }], dummy_code("b"));
        t.insert(vec![Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2] }], dummy_code("c"));
        let globals = HashMap::new();
        // Both entry 0 and entry 1 match this call; linear scan says 0.
        let args = vec![Value::tensor(Tensor::ones(&[2])), Value::list(vec![])];
        assert_eq!(t.lookup(&args, &globals).map(|e| e.code.name.as_str()), Some("a"));
        // Only the wildcard matches a non-tensor arg0.
        let args = vec![Value::Int(1), Value::list(vec![])];
        assert_eq!(t.lookup(&args, &globals).map(|e| e.code.name.as_str()), Some("b"));
        assert_eq!(linear_scan(&t, &args, &globals), Some(1));
    }
}
