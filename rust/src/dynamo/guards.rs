//! Guards: the conditions under which a cached compiled entry is valid.
//! Checked on every hooked call; a miss triggers recompilation (up to the
//! cache-size limit), exactly like TorchDynamo's guard system.

use std::collections::HashMap;

use super::sym::Origin;
use crate::value::Value;

#[derive(Clone, Debug)]
pub enum Guard {
    /// A lifted tensor input must keep its capture-time shape.
    TensorShape { origin: Origin, shape: Vec<usize> },
    /// A Python scalar that was baked into the trace must be unchanged.
    ConstEq { origin: Origin, value: Value },
    /// A callable / module object must be the same object.
    Identity { origin: Origin, value: Value },
    /// Container length (lists/tuples seen structurally).
    Len { origin: Origin, len: usize },
    /// Remaining items of an iterator argument (resume functions).
    IterRemaining { origin: Origin, len: usize },
}

impl Guard {
    /// Does this guard hold for the given call state?
    pub fn check(&self, args: &[Value], globals: &HashMap<String, Value>) -> bool {
        match self {
            Guard::TensorShape { origin, shape } => match origin.resolve(args, globals) {
                Some(Value::Tensor(t)) => t.shape() == &shape[..],
                _ => false,
            },
            Guard::ConstEq { origin, value } => match origin.resolve(args, globals) {
                Some(v) => v.eq_value(value),
                None => false,
            },
            Guard::Identity { origin, value } => match origin.resolve(args, globals) {
                Some(v) => v.is_identical(value),
                None => false,
            },
            Guard::Len { origin, len } => match origin.resolve(args, globals) {
                Some(Value::List(l)) => l.borrow().len() == *len,
                Some(Value::Tuple(t)) => t.len() == *len,
                Some(Value::Dict(d)) => d.borrow().len() == *len,
                _ => false,
            },
            Guard::IterRemaining { origin, len } => match origin.resolve(args, globals) {
                Some(Value::Iter(it)) => {
                    let it = it.borrow();
                    it.items.len() - it.pos == *len
                }
                _ => false,
            },
        }
    }

    /// Rendered into `full_code` dumps.
    pub fn describe(&self) -> String {
        match self {
            Guard::TensorShape { origin, shape } => format!("check_tensor({}, shape={:?})", origin.describe(), shape),
            Guard::ConstEq { origin, value } => format!("{} == {}", origin.describe(), value.repr()),
            Guard::Identity { origin, value } => format!("{} is {}", origin.describe(), value.repr()),
            Guard::Len { origin, len } => format!("len({}) == {}", origin.describe(), len),
            Guard::IterRemaining { origin, len } => format!("iter_remaining({}) == {}", origin.describe(), len),
        }
    }
}

/// Check a full guard set.
pub fn check_all(guards: &[Guard], args: &[Value], globals: &HashMap<String, Value>) -> bool {
    guards.iter().all(|g| g.check(args, globals))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn shape_guard() {
        let g = Guard::TensorShape { origin: Origin::Arg(0), shape: vec![2, 3] };
        let globals = HashMap::new();
        assert!(g.check(&[Value::tensor(Tensor::zeros(&[2, 3]))], &globals));
        assert!(!g.check(&[Value::tensor(Tensor::zeros(&[3, 2]))], &globals));
        assert!(!g.check(&[Value::Int(1)], &globals));
    }

    #[test]
    fn const_and_identity_guards() {
        let globals = HashMap::new();
        let g = Guard::ConstEq { origin: Origin::Arg(0), value: Value::Int(4) };
        assert!(g.check(&[Value::Int(4)], &globals));
        assert!(!g.check(&[Value::Int(5)], &globals));

        let f = Value::builtin("f", |_| Ok(Value::None));
        let gi = Guard::Identity { origin: Origin::Arg(0), value: f.clone() };
        assert!(gi.check(&[f.clone()], &globals));
        let f2 = Value::builtin("f", |_| Ok(Value::None));
        assert!(!gi.check(&[f2], &globals));
    }

    #[test]
    fn len_guard() {
        let globals = HashMap::new();
        let g = Guard::Len { origin: Origin::Arg(0), len: 2 };
        assert!(g.check(&[Value::list(vec![Value::Int(1), Value::Int(2)])], &globals));
        assert!(!g.check(&[Value::list(vec![Value::Int(1)])], &globals));
    }
}
