//! Symbolic values for dynamo's bytecode-level symbolic evaluation.
//!
//! A [`Sym`] is what lives on the *symbolic* stack during capture: either a
//! proxy for a tensor graph node, a concrete Python value known at capture
//! time (with a provenance [`Origin`] when it can be re-materialized in
//! transformed bytecode), or trace-side structure (lists/tuples/iterators
//! built while unrolling Python-level control flow).

use std::cell::RefCell;
use std::rc::Rc;

use crate::graph::NodeId;
use crate::value::Value;

/// Where a concrete value came from — how transformed bytecode can reload
/// it at run time, and how guards re-resolve it on later calls.
#[derive(Clone, Debug)]
pub enum Origin {
    /// The i-th positional argument of the intercepted function.
    Arg(usize),
    /// A module global.
    Global(String),
    /// `base[key]` with a constant key (also resolves dict-module
    /// attributes like `torch.matmul`).
    Index(Box<Origin>, Value),
}

impl Origin {
    pub fn index(self, key: Value) -> Origin {
        Origin::Index(Box::new(self), key)
    }

    /// Resolve against concrete call state. Returns None if the path no
    /// longer exists (guards treat that as failure).
    pub fn resolve(
        &self,
        args: &[Value],
        globals: &std::collections::HashMap<String, Value>,
    ) -> Option<Value> {
        match self {
            Origin::Arg(i) => args.get(*i).cloned(),
            Origin::Global(n) => globals.get(n).cloned(),
            Origin::Index(base, key) => {
                let b = base.resolve(args, globals)?;
                match (&b, key) {
                    (Value::Iter(it), Value::Int(k)) => {
                        let it = it.borrow();
                        it.items.get(it.pos + *k as usize).cloned()
                    }
                    _ => crate::vm::apply_subscript(&b, key).ok(),
                }
            }
        }
    }

    /// Human-readable form (used in dumps and placeholder names).
    pub fn describe(&self) -> String {
        match self {
            Origin::Arg(i) => format!("arg{}", i),
            Origin::Global(n) => format!("g_{}", n),
            Origin::Index(base, k) => format!("{}_{}", base.describe(), sanitize(&k.to_display())),
        }
    }

    /// Stable identity of the resolution *path* (not the value it currently
    /// resolves to). Two origins with equal keys resolve identically for any
    /// call state, so the guard dispatcher deduplicates them into one
    /// resolved slot. Unlike [`Origin::describe`] this is injective: index
    /// keys are netstring-style length-prefixed, so a key whose `repr()`
    /// happens to contain bracket/quote characters cannot collide with a
    /// differently-nested path (e.g. `arg0["x']['y"]` vs `arg0["x"]["y"]`).
    pub fn cache_key(&self) -> String {
        match self {
            Origin::Arg(i) => format!("a{}", i),
            Origin::Global(n) => format!("g:{}", n),
            Origin::Index(base, k) => {
                let kr = k.repr();
                format!("{}[{}:{}]", base.cache_key(), kr.len(), kr)
            }
        }
    }
}

fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// A symbolic value.
#[derive(Clone, Debug)]
pub enum Sym {
    /// A tensor proxy: graph node id.
    Tensor(NodeId),
    /// A concrete Python value known at capture time.
    Const { value: Value, origin: Option<Origin> },
    /// A list built (or unaliased from an argument) during tracing.
    /// `external` marks lists that alias caller-visible state — mutating
    /// those forces a graph break.
    List { items: Rc<RefCell<Vec<Sym>>>, external: bool },
    Tuple(Rc<Vec<Sym>>),
    /// A trace-side iterator (Python loops unroll during capture).
    Iter { items: Rc<RefCell<Vec<Sym>>>, pos: usize },
    /// `recv.name` awaiting CALL_METHOD.
    MethodRef { recv: Box<Sym>, name: String },
}

impl Sym {
    pub fn constant(value: Value) -> Sym {
        Sym::Const { value, origin: None }
    }

    pub fn with_origin(value: Value, origin: Origin) -> Sym {
        Sym::Const { value, origin: Some(origin) }
    }

    /// Is this a concrete Python value (usable for constant folding)?
    pub fn as_value(&self) -> Option<Value> {
        match self {
            Sym::Const { value, .. } => Some(value.clone()),
            Sym::Tuple(items) => {
                let vs: Option<Vec<Value>> = items.iter().map(|s| s.as_value()).collect();
                vs.map(Value::tuple)
            }
            Sym::List { items, .. } => {
                let vs: Option<Vec<Value>> = items.borrow().iter().map(|s| s.as_value()).collect();
                vs.map(Value::list)
            }
            _ => None,
        }
    }

    /// All tensor node ids referenced by this sym (for graph-output
    /// selection at a break).
    pub fn collect_tensors(&self, out: &mut Vec<NodeId>) {
        match self {
            Sym::Tensor(id) => {
                if !out.contains(id) {
                    out.push(*id);
                }
            }
            Sym::List { items, .. } | Sym::Iter { items, .. } => {
                for s in items.borrow().iter() {
                    s.collect_tensors(out);
                }
            }
            Sym::Tuple(items) => {
                for s in items.iter() {
                    s.collect_tensors(out);
                }
            }
            Sym::MethodRef { recv, .. } => recv.collect_tensors(out),
            Sym::Const { .. } => {}
        }
    }

    pub fn type_desc(&self) -> String {
        match self {
            Sym::Tensor(id) => format!("TensorProxy(node {})", id),
            Sym::Const { value, .. } => format!("Const({})", value.type_name()),
            Sym::List { .. } => "List".into(),
            Sym::Tuple(_) => "Tuple".into(),
            Sym::Iter { .. } => "Iter".into(),
            Sym::MethodRef { name, .. } => format!("MethodRef(.{})", name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn origin_resolution() {
        let args = vec![Value::Int(5), Value::list(vec![Value::Int(10), Value::Int(20)])];
        let globals: HashMap<String, Value> = [("w".to_string(), Value::Float(1.5))].into_iter().collect();
        assert!(Origin::Arg(0).resolve(&args, &globals).unwrap().eq_value(&Value::Int(5)));
        assert!(Origin::Global("w".into()).resolve(&args, &globals).unwrap().eq_value(&Value::Float(1.5)));
        let idx = Origin::Arg(1).index(Value::Int(1));
        assert!(idx.resolve(&args, &globals).unwrap().eq_value(&Value::Int(20)));
        assert!(Origin::Arg(7).resolve(&args, &globals).is_none());
        assert!(Origin::Global("nope".into()).resolve(&args, &globals).is_none());
    }

    #[test]
    fn cache_key_is_injective_for_bracketed_keys() {
        // A single key whose repr embeds quote/bracket chars must not
        // collide with a nested two-level path.
        let tricky = Origin::Arg(0).index(Value::str("x']['y"));
        let nested = Origin::Arg(0).index(Value::str("x")).index(Value::str("y"));
        assert_ne!(tricky.cache_key(), nested.cache_key());
        // Stability: same path, same key.
        assert_eq!(tricky.cache_key(), Origin::Arg(0).index(Value::str("x']['y")).cache_key());
        assert_ne!(Origin::Arg(0).cache_key(), Origin::Arg(1).cache_key());
    }

    #[test]
    fn collect_tensor_ids() {
        let s = Sym::Tuple(Rc::new(vec![
            Sym::Tensor(3),
            Sym::constant(Value::Int(1)),
            Sym::List { items: Rc::new(RefCell::new(vec![Sym::Tensor(5), Sym::Tensor(3)])), external: false },
        ]));
        let mut ids = Vec::new();
        s.collect_tensors(&mut ids);
        assert_eq!(ids, vec![3, 5]);
    }
}
