//! Counters and timers for the compiler stack (captures, cache hits, graph
//! breaks, backend calls). Cheap `Cell`-based, suitable for the hot path.

use std::cell::Cell;
use std::time::{Duration, Instant};

#[derive(Default, Debug)]
pub struct Metrics {
    pub captures: Cell<u64>,
    pub cache_hits: Cell<u64>,
    pub cache_misses: Cell<u64>,
    pub graph_breaks: Cell<u64>,
    pub fallbacks: Cell<u64>,
    pub guard_checks: Cell<u64>,
    pub guard_failures: Cell<u64>,
    pub compile_ns: Cell<u64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn bump(c: &Cell<u64>) {
        c.set(c.get() + 1);
    }

    /// Time a closure, accumulating into `compile_ns`.
    pub fn time_compile<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.compile_ns.set(self.compile_ns.get() + t0.elapsed().as_nanos() as u64);
        r
    }

    pub fn compile_time(&self) -> Duration {
        Duration::from_nanos(self.compile_ns.get())
    }

    pub fn report(&self) -> String {
        format!(
            "captures={} cache_hits={} cache_misses={} graph_breaks={} fallbacks={} guard_checks={} guard_failures={} compile_time={:?}",
            self.captures.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.graph_breaks.get(),
            self.fallbacks.get(),
            self.guard_checks.get(),
            self.guard_failures.get(),
            self.compile_time(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timer() {
        let m = Metrics::new();
        Metrics::bump(&m.captures);
        Metrics::bump(&m.captures);
        assert_eq!(m.captures.get(), 2);
        let v = m.time_compile(|| 42);
        assert_eq!(v, 42);
        assert!(m.report().contains("captures=2"));
    }
}
