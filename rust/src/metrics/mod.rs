//! Counters and timers for the compiler stack (captures, cache hits, graph
//! breaks, backend calls). Cheap `Cell`-based, suitable for the hot path.

use std::cell::Cell;
use std::time::{Duration, Instant};

#[derive(Default, Debug)]
pub struct Metrics {
    pub captures: Cell<u64>,
    pub cache_hits: Cell<u64>,
    pub cache_misses: Cell<u64>,
    pub graph_breaks: Cell<u64>,
    pub fallbacks: Cell<u64>,
    pub guard_checks: Cell<u64>,
    pub guard_failures: Cell<u64>,
    /// Guard-table entries evicted by the LRU policy at `cache_limit`.
    pub evictions: Cell<u64>,
    /// Transient compile/call failures retried by the resilience layer.
    pub retries: Cell<u64>,
    /// Calls whose module failed and were served by the eager fallback.
    pub degraded_calls: Cell<u64>,
    /// Compiles degraded to eager under `FallbackPolicy::Eager`.
    pub degraded_compiles: Cell<u64>,
    /// Circuit-breaker transitions into the open state.
    pub breaker_trips: Cell<u64>,
    /// Compiles failed fast by an open circuit breaker.
    pub breaker_skips: Cell<u64>,
    /// Calls abandoned at their deadline and served by the fallback.
    pub timeouts: Cell<u64>,
    /// Panics converted to `DepyfError::Panic` by `catch_unwind` isolation.
    pub panics_caught: Cell<u64>,
    /// Requests rejected by admission control (queue full or insufficient
    /// remaining deadline) before any work ran.
    pub sheds: Cell<u64>,
    /// Replacement workers spawned by the supervisor's watchdog.
    pub respawns: Cell<u64>,
    /// Wedged workers the watchdog marked lost (heartbeat past the stall
    /// budget) and abandoned.
    pub watchdog_kills: Cell<u64>,
    /// Work aborted early because a propagated deadline was already
    /// exhausted (queued jobs, pipeline stages, cache-miss compiles).
    pub deadline_propagated_aborts: Cell<u64>,
    /// Peak-tail queue depth (p99 of per-enqueue depth samples) — a
    /// gauge, not a counter; merges take the max.
    pub queue_depth_p99: Cell<u64>,
    pub compile_ns: Cell<u64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn bump(c: &Cell<u64>) {
        c.set(c.get() + 1);
    }

    /// Time a closure, accumulating into `compile_ns`.
    pub fn time_compile<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let r = f();
        self.compile_ns.set(self.compile_ns.get() + t0.elapsed().as_nanos() as u64);
        r
    }

    pub fn compile_time(&self) -> Duration {
        Duration::from_nanos(self.compile_ns.get())
    }

    pub fn report(&self) -> String {
        format!(
            "captures={} cache_hits={} cache_misses={} graph_breaks={} fallbacks={} guard_checks={} guard_failures={} evictions={} retries={} degraded_calls={} degraded_compiles={} breaker_trips={} breaker_skips={} timeouts={} panics_caught={} sheds={} respawns={} watchdog_kills={} deadline_propagated_aborts={} queue_depth_p99={} compile_time={:?}",
            self.captures.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.graph_breaks.get(),
            self.fallbacks.get(),
            self.guard_checks.get(),
            self.guard_failures.get(),
            self.evictions.get(),
            self.retries.get(),
            self.degraded_calls.get(),
            self.degraded_compiles.get(),
            self.breaker_trips.get(),
            self.breaker_skips.get(),
            self.timeouts.get(),
            self.panics_caught.get(),
            self.sheds.get(),
            self.respawns.get(),
            self.watchdog_kills.get(),
            self.deadline_propagated_aborts.get(),
            self.queue_depth_p99.get(),
            self.compile_time(),
        )
    }

    /// The `metrics.json` session artifact: every counter plus compile
    /// time, as a flat JSON object (keys are stable; values are u64).
    pub fn to_json(&self) -> String {
        self.to_json_with(None)
    }

    /// Like [`Metrics::to_json`] with one extra pre-rendered JSON field
    /// appended — the session uses it to inline per-module backend stats
    /// (`("modules", "[...]")`).
    pub fn to_json_with(&self, extra: Option<(&str, &str)>) -> String {
        let mut out = format!(
            "{{\n  \"captures\": {},\n  \"cache_hits\": {},\n  \"cache_misses\": {},\n  \"graph_breaks\": {},\n  \"fallbacks\": {},\n  \"guard_checks\": {},\n  \"guard_failures\": {},\n  \"evictions\": {},\n  \"retries\": {},\n  \"degraded_calls\": {},\n  \"degraded_compiles\": {},\n  \"breaker_trips\": {},\n  \"breaker_skips\": {},\n  \"timeouts\": {},\n  \"panics_caught\": {},\n  \"sheds\": {},\n  \"respawns\": {},\n  \"watchdog_kills\": {},\n  \"deadline_propagated_aborts\": {},\n  \"queue_depth_p99\": {},\n  \"compile_ns\": {}",
            self.captures.get(),
            self.cache_hits.get(),
            self.cache_misses.get(),
            self.graph_breaks.get(),
            self.fallbacks.get(),
            self.guard_checks.get(),
            self.guard_failures.get(),
            self.evictions.get(),
            self.retries.get(),
            self.degraded_calls.get(),
            self.degraded_compiles.get(),
            self.breaker_trips.get(),
            self.breaker_skips.get(),
            self.timeouts.get(),
            self.panics_caught.get(),
            self.sheds.get(),
            self.respawns.get(),
            self.watchdog_kills.get(),
            self.deadline_propagated_aborts.get(),
            self.queue_depth_p99.get(),
            self.compile_ns.get(),
        );
        if let Some((key, value)) = extra {
            out.push_str(&format!(",\n  \"{}\": {}", key, value));
        }
        out.push_str("\n}\n");
        out
    }
}

/// A plain-data copy of [`Metrics`] that crosses threads.
///
/// `Metrics` itself is `Cell`-based (cheap, session-local, deliberately
/// not `Sync`). Serve workers each own their sessions' `Metrics`, take a
/// `snapshot()` at the end of the run, and the driver `merge`s the
/// snapshots into the one `metrics.json` it writes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub captures: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub graph_breaks: u64,
    pub fallbacks: u64,
    pub guard_checks: u64,
    pub guard_failures: u64,
    pub evictions: u64,
    pub retries: u64,
    pub degraded_calls: u64,
    pub degraded_compiles: u64,
    pub breaker_trips: u64,
    pub breaker_skips: u64,
    pub timeouts: u64,
    pub panics_caught: u64,
    pub sheds: u64,
    pub respawns: u64,
    pub watchdog_kills: u64,
    pub deadline_propagated_aborts: u64,
    /// Gauge: per-run p99 queue depth; [`MetricsSnapshot::merge`] takes
    /// the max instead of summing.
    pub queue_depth_p99: u64,
    pub compile_ns: u64,
}

impl Metrics {
    /// Copy the current counter values into a `Send`-able snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            captures: self.captures.get(),
            cache_hits: self.cache_hits.get(),
            cache_misses: self.cache_misses.get(),
            graph_breaks: self.graph_breaks.get(),
            fallbacks: self.fallbacks.get(),
            guard_checks: self.guard_checks.get(),
            guard_failures: self.guard_failures.get(),
            evictions: self.evictions.get(),
            retries: self.retries.get(),
            degraded_calls: self.degraded_calls.get(),
            degraded_compiles: self.degraded_compiles.get(),
            breaker_trips: self.breaker_trips.get(),
            breaker_skips: self.breaker_skips.get(),
            timeouts: self.timeouts.get(),
            panics_caught: self.panics_caught.get(),
            sheds: self.sheds.get(),
            respawns: self.respawns.get(),
            watchdog_kills: self.watchdog_kills.get(),
            deadline_propagated_aborts: self.deadline_propagated_aborts.get(),
            queue_depth_p99: self.queue_depth_p99.get(),
            compile_ns: self.compile_ns.get(),
        }
    }
}

impl MetricsSnapshot {
    /// Field-wise accumulate another snapshot into this one.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.captures += other.captures;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.graph_breaks += other.graph_breaks;
        self.fallbacks += other.fallbacks;
        self.guard_checks += other.guard_checks;
        self.guard_failures += other.guard_failures;
        self.evictions += other.evictions;
        self.retries += other.retries;
        self.degraded_calls += other.degraded_calls;
        self.degraded_compiles += other.degraded_compiles;
        self.breaker_trips += other.breaker_trips;
        self.breaker_skips += other.breaker_skips;
        self.timeouts += other.timeouts;
        self.panics_caught += other.panics_caught;
        self.sheds += other.sheds;
        self.respawns += other.respawns;
        self.watchdog_kills += other.watchdog_kills;
        self.deadline_propagated_aborts += other.deadline_propagated_aborts;
        // Depth is a gauge: the merged tail is the worst per-run tail.
        self.queue_depth_p99 = self.queue_depth_p99.max(other.queue_depth_p99);
        self.compile_ns += other.compile_ns;
    }

    /// Same flat-object layout as [`Metrics::to_json_with`], so a merged
    /// serve `metrics.json` has the exact keys a session dump has.
    pub fn to_json_with(&self, extra: Option<(&str, &str)>) -> String {
        let mut out = format!(
            "{{\n  \"captures\": {},\n  \"cache_hits\": {},\n  \"cache_misses\": {},\n  \"graph_breaks\": {},\n  \"fallbacks\": {},\n  \"guard_checks\": {},\n  \"guard_failures\": {},\n  \"evictions\": {},\n  \"retries\": {},\n  \"degraded_calls\": {},\n  \"degraded_compiles\": {},\n  \"breaker_trips\": {},\n  \"breaker_skips\": {},\n  \"timeouts\": {},\n  \"panics_caught\": {},\n  \"sheds\": {},\n  \"respawns\": {},\n  \"watchdog_kills\": {},\n  \"deadline_propagated_aborts\": {},\n  \"queue_depth_p99\": {},\n  \"compile_ns\": {}",
            self.captures,
            self.cache_hits,
            self.cache_misses,
            self.graph_breaks,
            self.fallbacks,
            self.guard_checks,
            self.guard_failures,
            self.evictions,
            self.retries,
            self.degraded_calls,
            self.degraded_compiles,
            self.breaker_trips,
            self.breaker_skips,
            self.timeouts,
            self.panics_caught,
            self.sheds,
            self.respawns,
            self.watchdog_kills,
            self.deadline_propagated_aborts,
            self.queue_depth_p99,
            self.compile_ns,
        );
        if let Some((key, value)) = extra {
            out.push_str(&format!(",\n  \"{}\": {}", key, value));
        }
        out.push_str("\n}\n");
        out
    }

    pub fn to_json(&self) -> String {
        self.to_json_with(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timer() {
        let m = Metrics::new();
        Metrics::bump(&m.captures);
        Metrics::bump(&m.captures);
        assert_eq!(m.captures.get(), 2);
        let v = m.time_compile(|| 42);
        assert_eq!(v, 42);
        assert!(m.report().contains("captures=2"));
    }

    #[test]
    fn json_with_extra_field_parses() {
        let m = Metrics::new();
        let text = m.to_json_with(Some(("modules", "[\n    {\"name\": \"g\"}\n  ]")));
        let doc = crate::api::json::parse(&text).expect("valid json");
        assert!(doc.get("modules").is_some(), "{}", text);
        assert!(doc.get("compile_ns").is_some());
    }

    #[test]
    fn snapshot_merge_and_json() {
        let m = Metrics::new();
        Metrics::bump(&m.captures);
        Metrics::bump(&m.cache_hits);
        let mut merged = m.snapshot();
        let other = MetricsSnapshot { captures: 2, evictions: 1, ..Default::default() };
        merged.merge(&other);
        assert_eq!(merged.captures, 3);
        assert_eq!(merged.cache_hits, 1);
        assert_eq!(merged.evictions, 1);
        let doc = crate::api::json::parse(&merged.to_json()).expect("valid json");
        assert_eq!(doc.get("captures").and_then(|v| v.as_f64()), Some(3.0));
        // Snapshots cross threads: merge results from spawned workers.
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(|| {
                    let m = Metrics::new();
                    Metrics::bump(&m.guard_checks);
                    m.snapshot()
                })
            })
            .collect();
        let mut total = MetricsSnapshot::default();
        for h in handles {
            total.merge(&h.join().expect("worker"));
        }
        assert_eq!(total.guard_checks, 4);
    }

    #[test]
    fn json_dump_is_parseable_and_complete() {
        let m = Metrics::new();
        Metrics::bump(&m.captures);
        Metrics::bump(&m.guard_checks);
        Metrics::bump(&m.cache_hits);
        let doc = crate::api::json::parse(&m.to_json()).expect("valid json");
        assert_eq!(doc.get("captures").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(doc.get("cache_hits").and_then(|v| v.as_f64()), Some(1.0));
        for key in [
            "captures",
            "cache_hits",
            "cache_misses",
            "graph_breaks",
            "fallbacks",
            "guard_checks",
            "guard_failures",
            "evictions",
            "retries",
            "degraded_calls",
            "degraded_compiles",
            "breaker_trips",
            "breaker_skips",
            "timeouts",
            "panics_caught",
            "sheds",
            "respawns",
            "watchdog_kills",
            "deadline_propagated_aborts",
            "queue_depth_p99",
            "compile_ns",
        ] {
            assert!(doc.get(key).is_some(), "missing {}", key);
        }
    }

    #[test]
    fn resilience_counters_flow_through_snapshot_and_json() {
        let m = Metrics::new();
        Metrics::bump(&m.retries);
        Metrics::bump(&m.retries);
        Metrics::bump(&m.degraded_calls);
        Metrics::bump(&m.breaker_trips);
        Metrics::bump(&m.timeouts);
        Metrics::bump(&m.panics_caught);
        assert!(m.report().contains("retries=2"));
        assert!(m.report().contains("degraded_calls=1"));
        let mut snap = m.snapshot();
        snap.merge(&MetricsSnapshot { breaker_skips: 3, degraded_compiles: 1, ..Default::default() });
        let doc = crate::api::json::parse(&snap.to_json()).expect("valid json");
        assert_eq!(doc.get("retries").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(doc.get("degraded_compiles").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(doc.get("breaker_skips").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(doc.get("timeouts").and_then(|v| v.as_f64()), Some(1.0));
    }

    #[test]
    fn supervision_counters_sum_but_depth_gauge_takes_max() {
        let mut a = MetricsSnapshot { sheds: 2, respawns: 1, watchdog_kills: 1, queue_depth_p99: 7, ..Default::default() };
        let b = MetricsSnapshot {
            sheds: 3,
            deadline_propagated_aborts: 4,
            queue_depth_p99: 5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.sheds, 5);
        assert_eq!(a.respawns, 1);
        assert_eq!(a.watchdog_kills, 1);
        assert_eq!(a.deadline_propagated_aborts, 4);
        assert_eq!(a.queue_depth_p99, 7, "gauge merges by max, not sum");
        let doc = crate::api::json::parse(&a.to_json()).expect("valid json");
        assert_eq!(doc.get("sheds").and_then(|v| v.as_f64()), Some(5.0));
        assert_eq!(doc.get("queue_depth_p99").and_then(|v| v.as_f64()), Some(7.0));
    }
}
