//! Global builtins (`print`, `range`, `len`, ...) and the `torch` module —
//! the eager tensor API that dynamo intercepts.

use std::rc::Rc;

use super::Vm;
use crate::tensor::{self, Rng, Tensor};
use crate::value::{DictKey, Value, ValueError};

/// Hard ceiling on tensor elements a builtin constructor will allocate
/// (64 Mi elements = 256 MiB of `f32`). Shapes past it — including the
/// `-1 as usize` wraparound a malformed shape used to produce — become a
/// `ValueError` instead of a capacity panic or an uncatchable OOM abort.
pub const MAX_TENSOR_ELEMS: usize = 1 << 26;

fn nested_list_to_tensor(v: &Value) -> Result<(Vec<usize>, Vec<f32>), String> {
    match v {
        Value::List(l) => {
            let items = l.borrow();
            // Leaf level? (An empty list is a leaf with zero elements.)
            let is_leaf = items.first().map(|x| !matches!(x, Value::List(_))).unwrap_or(true);
            if is_leaf {
                let data: Result<Vec<f32>, String> = items.iter().map(|x| Ok(x.as_float()? as f32)).collect();
                let data = data?;
                Ok((vec![data.len()], data))
            } else {
                let mut shape: Option<Vec<usize>> = None;
                let mut data = Vec::new();
                for item in items.iter() {
                    let (s, d) = nested_list_to_tensor(item)?;
                    match &mut shape {
                        slot @ None => *slot = Some(s),
                        Some(prev) => {
                            if *prev != s {
                                return Err(ValueError::Msg("ragged nested list".into()).into());
                            }
                        }
                    }
                    data.extend(d);
                }
                let mut full = vec![items.len()];
                if let Some(inner) = shape {
                    full.extend(inner);
                }
                Ok((full, data))
            }
        }
        Value::Int(i) => Ok((vec![], vec![*i as f32])),
        Value::Float(f) => Ok((vec![], vec![*f as f32])),
        other => Err(ValueError::Msg(format!("cannot build tensor from {}", other.type_name())).into()),
    }
}

/// One dimension of a shape argument: must be a non-negative integer.
/// Rejecting negatives here matters — `as usize` on `-1` wraps to 2^64-1
/// and the subsequent allocation panics (or aborts) instead of erroring.
fn shape_dim(v: &Value) -> Result<usize, String> {
    let i = v.as_int()?;
    if i < 0 {
        return Err(ValueError::Msg(format!("negative dimension {} in tensor shape", i)).into());
    }
    Ok(i as usize)
}

/// Validate a full shape: every dim non-negative, element count within
/// [`MAX_TENSOR_ELEMS`] (checked multiply, so `[2^40, 2^40]` can't wrap).
fn checked_shape(dims: Vec<usize>) -> Result<Vec<usize>, String> {
    let mut elems: usize = 1;
    for &d in &dims {
        elems = elems
            .checked_mul(d)
            .filter(|&n| n <= MAX_TENSOR_ELEMS)
            .ok_or_else(|| -> String {
                ValueError::Msg(format!("tensor shape {:?} exceeds {} elements", dims, MAX_TENSOR_ELEMS)).into()
            })?;
    }
    Ok(dims)
}

fn shape_arg(v: &Value) -> Result<Vec<usize>, String> {
    let dims: Vec<usize> = match v {
        Value::List(l) => l.borrow().iter().map(shape_dim).collect::<Result<_, _>>()?,
        Value::Tuple(t) => t.iter().map(shape_dim).collect::<Result<_, _>>()?,
        Value::Int(_) => vec![shape_dim(v)?],
        other => return Err(ValueError::Msg(format!("expected shape list, got {}", other.type_name())).into()),
    };
    checked_shape(dims)
}

fn values_as_iterable(v: &Value) -> Result<Vec<Value>, String> {
    match super::interp::make_iter(v)? {
        Value::Iter(it) => Ok(it.borrow().items.clone()),
        _ => unreachable!(),
    }
}

/// Install all builtins + the `torch` module into the VM globals.
pub fn install(vm: &Vm) {
    let g = &vm.globals;
    let mut globals = g.borrow_mut();

    // print — captures to vm.output (tests compare output), echoes if asked.
    {
        let out = Rc::clone(&vm.output);
        let echo = vm.echo;
        globals.insert(
            "print".into(),
            Value::builtin("print", move |args| {
                let line = args.iter().map(|a| a.to_display()).collect::<Vec<_>>().join(" ");
                out.borrow_mut().push_str(&line);
                out.borrow_mut().push('\n');
                if echo {
                    println!("{}", line);
                }
                Ok(Value::None)
            }),
        );
    }

    globals.insert(
        "range".into(),
        Value::builtin("range", |args| match args {
            [stop] => Ok(Value::Range(0, stop.as_int()?, 1)),
            [start, stop] => Ok(Value::Range(start.as_int()?, stop.as_int()?, 1)),
            [start, stop, step] => {
                let s = step.as_int()?;
                if s == 0 {
                    return Err("range() arg 3 must not be zero".into());
                }
                Ok(Value::Range(start.as_int()?, stop.as_int()?, s))
            }
            _ => Err(format!("range expected 1..3 arguments, got {}", args.len())),
        }),
    );

    globals.insert(
        "len".into(),
        Value::builtin("len", |args| match args {
            [Value::List(l)] => Ok(Value::Int(l.borrow().len() as i64)),
            [Value::Tuple(t)] => Ok(Value::Int(t.len() as i64)),
            [Value::Str(s)] => Ok(Value::Int(s.chars().count() as i64)),
            [Value::Dict(d)] => Ok(Value::Int(d.borrow().len() as i64)),
            [Value::Range(a, b, s)] => {
                let n = if *s > 0 { (b - a + s - 1) / s } else { (a - b - s - 1) / (-s) };
                Ok(Value::Int(n.max(0)))
            }
            [Value::Tensor(t)] => Ok(Value::Int(*t.shape().first().unwrap_or(&0) as i64)),
            [other] => Err(format!("object of type '{}' has no len()", other.type_name())),
            _ => Err("len() takes exactly one argument".into()),
        }),
    );

    globals.insert(
        "abs".into(),
        Value::builtin("abs", |args| match args {
            [Value::Int(i)] => Ok(Value::Int(i.abs())),
            [Value::Float(f)] => Ok(Value::Float(f.abs())),
            [Value::Tensor(t)] => Ok(Value::tensor(tensor::abs(t))),
            [other] => Err(format!("bad operand for abs(): {}", other.type_name())),
            _ => Err("abs() takes exactly one argument".into()),
        }),
    );

    globals.insert(
        "sum".into(),
        Value::builtin("sum", |args| match args {
            [v] => {
                let items = values_as_iterable(v)?;
                let mut acc = Value::Int(0);
                for it in items {
                    acc = super::interp::binary_op_values(crate::bytecode::BinOp::Add, &acc, &it)?;
                }
                Ok(acc)
            }
            _ => Err("sum() takes one argument".into()),
        }),
    );

    globals.insert(
        "min".into(),
        Value::builtin("min", |args| {
            let items = if args.len() == 1 { values_as_iterable(&args[0])? } else { args.to_vec() };
            let mut best: Option<Value> = None;
            for it in items {
                best = Some(match best {
                    None => it,
                    Some(b) => {
                        if it.cmp_value(&b)? == std::cmp::Ordering::Less {
                            it
                        } else {
                            b
                        }
                    }
                });
            }
            best.ok_or_else(|| "min() arg is an empty sequence".into())
        }),
    );

    globals.insert(
        "max".into(),
        Value::builtin("max", |args| {
            let items = if args.len() == 1 { values_as_iterable(&args[0])? } else { args.to_vec() };
            let mut best: Option<Value> = None;
            for it in items {
                best = Some(match best {
                    None => it,
                    Some(b) => {
                        if it.cmp_value(&b)? == std::cmp::Ordering::Greater {
                            it
                        } else {
                            b
                        }
                    }
                });
            }
            best.ok_or_else(|| "max() arg is an empty sequence".into())
        }),
    );

    globals.insert(
        "int".into(),
        Value::builtin("int", |args| match args {
            [v] => Ok(Value::Int(v.as_int()?)),
            _ => Err("int() takes one argument".into()),
        }),
    );

    globals.insert(
        "float".into(),
        Value::builtin("float", |args| match args {
            [v] => Ok(Value::Float(v.as_float()?)),
            _ => Err("float() takes one argument".into()),
        }),
    );

    globals.insert(
        "bool".into(),
        Value::builtin("bool", |args| match args {
            [v] => Ok(Value::Bool(v.truthy()?)),
            _ => Err("bool() takes one argument".into()),
        }),
    );

    globals.insert(
        "str".into(),
        Value::builtin("str", |args| match args {
            [v] => Ok(Value::str(&v.to_display())),
            _ => Err("str() takes one argument".into()),
        }),
    );

    globals.insert(
        "list".into(),
        Value::builtin("list", |args| match args {
            [] => Ok(Value::list(vec![])),
            [v] => Ok(Value::list(values_as_iterable(v)?)),
            _ => Err("list() takes at most one argument".into()),
        }),
    );

    globals.insert(
        "tuple".into(),
        Value::builtin("tuple", |args| match args {
            [] => Ok(Value::tuple(vec![])),
            [v] => Ok(Value::tuple(values_as_iterable(v)?)),
            _ => Err("tuple() takes at most one argument".into()),
        }),
    );

    globals.insert(
        "iter".into(),
        Value::builtin("iter", |args| match args {
            [v] => super::interp::make_iter(v),
            _ => Err("iter() takes one argument".into()),
        }),
    );

    globals.insert(
        "sorted".into(),
        Value::builtin("sorted", |args| match args {
            [v] => {
                let mut items = values_as_iterable(v)?;
                let mut err = None;
                items.sort_by(|a, b| match a.cmp_value(b) {
                    Ok(o) => o,
                    Err(e) => {
                        err = Some(e);
                        std::cmp::Ordering::Equal
                    }
                });
                match err {
                    Some(e) => Err(e.into()),
                    None => Ok(Value::list(items)),
                }
            }
            _ => Err("sorted() takes one argument".into()),
        }),
    );

    globals.insert(
        "enumerate".into(),
        Value::builtin("enumerate", |args| match args {
            [v] => {
                let items = values_as_iterable(v)?;
                Ok(Value::list(
                    items.into_iter().enumerate().map(|(i, x)| Value::tuple(vec![Value::Int(i as i64), x])).collect(),
                ))
            }
            _ => Err("enumerate() takes one argument".into()),
        }),
    );

    globals.insert(
        "zip".into(),
        Value::builtin("zip", |args| {
            let lists: Result<Vec<Vec<Value>>, String> = args.iter().map(values_as_iterable).collect();
            let lists = lists?;
            let n = lists.iter().map(|l| l.len()).min().unwrap_or(0);
            Ok(Value::list(
                (0..n).map(|i| Value::tuple(lists.iter().map(|l| l[i].clone()).collect())).collect(),
            ))
        }),
    );

    // ---- torch module ----
    let torch = Value::dict();
    if let Value::Dict(td) = &torch {
        let mut t = td.borrow_mut();
        let rng = &vm.rng;

        t.insert(DictKey::Str("tensor".into()), Value::builtin("tensor", |args| match args {
            [v] => {
                let (shape, data) = nested_list_to_tensor(v)?;
                Ok(Value::tensor(Tensor::new(shape, data)))
            }
            _ => Err("torch.tensor() takes one argument".into()),
        }));

        t.insert(DictKey::Str("zeros".into()), Value::builtin("zeros", |args| match args {
            [s] => Ok(Value::tensor(Tensor::zeros(&shape_arg(s)?))),
            _ => Err("torch.zeros(shape)".into()),
        }));

        t.insert(DictKey::Str("ones".into()), Value::builtin("ones", |args| match args {
            [s] => Ok(Value::tensor(Tensor::ones(&shape_arg(s)?))),
            _ => Err("torch.ones(shape)".into()),
        }));

        t.insert(DictKey::Str("arange".into()), Value::builtin("arange", |args| match args {
            [n] => {
                // Like Python's range/arange: a negative bound is empty, it
                // must not wrap through `as usize` into a 2^63-element alloc.
                let n = n.as_int()?.max(0) as usize;
                if n > MAX_TENSOR_ELEMS {
                    return Err(ValueError::Msg(format!("torch.arange({}) exceeds {} elements", n, MAX_TENSOR_ELEMS)).into());
                }
                Ok(Value::tensor(Tensor::arange(n)))
            }
            _ => Err("torch.arange(n)".into()),
        }));

        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("randn".into()), Value::builtin("randn", move |args| match args {
                [s] => Ok(Value::tensor(Tensor::randn(&shape_arg(s)?, &mut rng.borrow_mut()))),
                _ => Err("torch.randn(shape)".into()),
            }));
        }
        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("rand".into()), Value::builtin("rand", move |args| match args {
                [s] => Ok(Value::tensor(Tensor::rand(&shape_arg(s)?, &mut rng.borrow_mut()))),
                _ => Err("torch.rand(shape)".into()),
            }));
        }
        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("randint".into()), Value::builtin("randint", move |args| match args {
                [hi, s] => {
                    let hi = hi.as_int()?.max(1) as u64;
                    let shape = shape_arg(s)?;
                    let n: usize = shape.iter().product();
                    let mut r = rng.borrow_mut();
                    let data: Vec<f32> = (0..n).map(|_| (r.next_u64() % hi) as f32).collect();
                    Ok(Value::tensor(Tensor::new(shape, data)))
                }
                _ => Err("torch.randint(high, shape)".into()),
            }));
        }
        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("manual_seed".into()), Value::builtin("manual_seed", move |args| match args {
                [s] => {
                    *rng.borrow_mut() = Rng::new(s.as_int()? as u64);
                    Ok(Value::None)
                }
                _ => Err("torch.manual_seed(n)".into()),
            }));
        }

        t.insert(DictKey::Str("matmul".into()), Value::builtin("matmul", |args| match args {
            [a, b] => Ok(Value::tensor(tensor::matmul(&*a.as_tensor()?, &*b.as_tensor()?)?)),
            _ => Err("torch.matmul(a, b)".into()),
        }));

        t.insert(DictKey::Str("maximum".into()), Value::builtin("maximum", |args| match args {
            [a, b] => Ok(Value::tensor(tensor::maximum(&*a.as_tensor()?, &*b.as_tensor()?)?)),
            _ => Err("torch.maximum(a, b)".into()),
        }));

        t.insert(DictKey::Str("minimum".into()), Value::builtin("minimum", |args| match args {
            [a, b] => Ok(Value::tensor(tensor::minimum(&*a.as_tensor()?, &*b.as_tensor()?)?)),
            _ => Err("torch.minimum(a, b)".into()),
        }));

        t.insert(DictKey::Str("softmax".into()), Value::builtin("softmax", |args| match args {
            [x] => Ok(Value::tensor(tensor::softmax(&*x.as_tensor()?)?)),
            _ => Err("torch.softmax(x)".into()),
        }));

        t.insert(DictKey::Str("relu".into()), Value::builtin("relu", |args| match args {
            [x] => Ok(Value::tensor(tensor::relu(&*x.as_tensor()?))),
            _ => Err("torch.relu(x)".into()),
        }));

        t.insert(DictKey::Str("gelu".into()), Value::builtin("gelu", |args| match args {
            [x] => Ok(Value::tensor(tensor::gelu(&*x.as_tensor()?))),
            _ => Err("torch.gelu(x)".into()),
        }));

        t.insert(DictKey::Str("tanh".into()), Value::builtin("tanh", |args| match args {
            [x] => Ok(Value::tensor(tensor::tanh(&*x.as_tensor()?))),
            _ => Err("torch.tanh(x)".into()),
        }));

        t.insert(DictKey::Str("layernorm".into()), Value::builtin("layernorm", |args| match args {
            [x, g, b] => Ok(Value::tensor(tensor::layernorm(&*x.as_tensor()?, &*g.as_tensor()?, &*b.as_tensor()?, 1e-5)?)),
            _ => Err("torch.layernorm(x, gamma, beta)".into()),
        }));

        t.insert(DictKey::Str("embedding".into()), Value::builtin("embedding", |args| match args {
            [table, ids] => Ok(Value::tensor(tensor::embedding(&*table.as_tensor()?, &*ids.as_tensor()?)?)),
            _ => Err("torch.embedding(table, ids)".into()),
        }));

        t.insert(DictKey::Str("cross_entropy".into()), Value::builtin("cross_entropy", |args| match args {
            [logits, targets] => Ok(Value::tensor(tensor::cross_entropy(&*logits.as_tensor()?, &*targets.as_tensor()?)?)),
            _ => Err("torch.cross_entropy(logits, targets)".into()),
        }));
    }
    globals.insert("torch".into(), torch);
}

#[cfg(test)]
mod tests {
    use crate::bytecode::IsaVersion;
    use crate::vm::Vm;

    fn run_err(src: &str) -> String {
        let vm = Vm::new();
        vm.exec_source(src, IsaVersion::V310).unwrap_err().message
    }

    fn run_ok(src: &str) -> String {
        let vm = Vm::new();
        vm.exec_source(src, IsaVersion::V310).unwrap_or_else(|e| panic!("{}\n{}", e, src));
        vm.take_output()
    }

    // Fuzzer-derived: `torch.zeros([-1])` used to wrap `-1 as usize` into a
    // 2^64-element allocation and panic with a capacity overflow.
    #[test]
    fn negative_shape_dim_is_a_value_error_not_a_panic() {
        let e = run_err("t = torch.zeros([-1])\n");
        assert!(e.contains("negative dimension -1"), "{}", e);
        let e = run_err("t = torch.ones([2, -3])\n");
        assert!(e.contains("negative dimension -3"), "{}", e);
        let e = run_err("t = torch.rand([-4])\n");
        assert!(e.contains("negative dimension -4"), "{}", e);
        let e = run_err("t = torch.randint(5, [-1])\n");
        assert!(e.contains("negative dimension -1"), "{}", e);
    }

    // Fuzzer-derived: an oversized product used to reach the allocator and
    // abort the process (OOM is not unwindable), killing the whole session.
    #[test]
    fn oversized_shape_is_a_value_error_not_an_abort() {
        let e = run_err("t = torch.ones([65536, 65536])\n");
        assert!(e.contains("exceeds"), "{}", e);
        // Product wraps u64 without the checked multiply.
        let e = run_err("t = torch.zeros([1099511627776, 1099511627776])\n");
        assert!(e.contains("exceeds"), "{}", e);
        let e = run_err("t = torch.arange(268435457)\n");
        assert!(e.contains("exceeds"), "{}", e);
    }

    // Fuzzer-derived: `arange` of a negative bound also wrapped through
    // `as usize`; Python semantics say it is simply empty.
    #[test]
    fn arange_negative_is_empty() {
        assert_eq!(run_ok("t = torch.arange(-5)\nprint(t.numel())\n"), "0\n");
        assert_eq!(run_ok("t = torch.arange(0)\nprint(t.numel())\n"), "0\n");
    }

    #[test]
    fn tensor_literals_still_build_after_hardening() {
        assert_eq!(run_ok("t = torch.tensor([[1, 2], [3, 4]])\nprint(t.sum().item())\n"), "10.0\n");
        assert_eq!(run_ok("t = torch.tensor([])\nprint(t.numel())\n"), "0\n");
        assert_eq!(run_ok("t = torch.tensor([[], []])\nprint(t.numel())\n"), "0\n");
        assert_eq!(run_ok("t = torch.ones([2, 3])\nprint(t.numel())\n"), "6\n");
    }

    #[test]
    fn ragged_nested_list_is_an_error() {
        let e = run_err("t = torch.tensor([[1, 2], [3]])\n");
        assert!(e.contains("ragged"), "{}", e);
    }
}
