//! Global builtins (`print`, `range`, `len`, ...) and the `torch` module —
//! the eager tensor API that dynamo intercepts.

use std::rc::Rc;

use super::Vm;
use crate::tensor::{self, Rng, Tensor};
use crate::value::{DictKey, Value};

fn nested_list_to_tensor(v: &Value) -> Result<(Vec<usize>, Vec<f32>), String> {
    match v {
        Value::List(l) => {
            let items = l.borrow();
            if items.is_empty() {
                return Ok((vec![0], vec![]));
            }
            // Leaf level?
            let is_leaf = !matches!(items[0], Value::List(_));
            if is_leaf {
                let data: Result<Vec<f32>, String> = items.iter().map(|x| Ok(x.as_float()? as f32)).collect();
                let data = data?;
                Ok((vec![data.len()], data))
            } else {
                let mut shape: Option<Vec<usize>> = None;
                let mut data = Vec::new();
                for item in items.iter() {
                    let (s, d) = nested_list_to_tensor(item)?;
                    match &shape {
                        None => shape = Some(s),
                        Some(prev) => {
                            if *prev != s {
                                return Err("ragged nested list".into());
                            }
                        }
                    }
                    data.extend(d);
                }
                let mut full = vec![items.len()];
                full.extend(shape.unwrap());
                Ok((full, data))
            }
        }
        Value::Int(i) => Ok((vec![], vec![*i as f32])),
        Value::Float(f) => Ok((vec![], vec![*f as f32])),
        other => Err(format!("cannot build tensor from {}", other.type_name())),
    }
}

fn shape_arg(v: &Value) -> Result<Vec<usize>, String> {
    match v {
        Value::List(l) => l.borrow().iter().map(|x| Ok(x.as_int()? as usize)).collect(),
        Value::Tuple(t) => t.iter().map(|x| Ok(x.as_int()? as usize)).collect(),
        Value::Int(i) => Ok(vec![*i as usize]),
        other => Err(format!("expected shape list, got {}", other.type_name())),
    }
}

fn values_as_iterable(v: &Value) -> Result<Vec<Value>, String> {
    match super::interp::make_iter(v)? {
        Value::Iter(it) => Ok(it.borrow().items.clone()),
        _ => unreachable!(),
    }
}

/// Install all builtins + the `torch` module into the VM globals.
pub fn install(vm: &Vm) {
    let g = &vm.globals;
    let mut globals = g.borrow_mut();

    // print — captures to vm.output (tests compare output), echoes if asked.
    {
        let out = Rc::clone(&vm.output);
        let echo = vm.echo;
        globals.insert(
            "print".into(),
            Value::builtin("print", move |args| {
                let line = args.iter().map(|a| a.to_display()).collect::<Vec<_>>().join(" ");
                out.borrow_mut().push_str(&line);
                out.borrow_mut().push('\n');
                if echo {
                    println!("{}", line);
                }
                Ok(Value::None)
            }),
        );
    }

    globals.insert(
        "range".into(),
        Value::builtin("range", |args| match args {
            [stop] => Ok(Value::Range(0, stop.as_int()?, 1)),
            [start, stop] => Ok(Value::Range(start.as_int()?, stop.as_int()?, 1)),
            [start, stop, step] => {
                let s = step.as_int()?;
                if s == 0 {
                    return Err("range() arg 3 must not be zero".into());
                }
                Ok(Value::Range(start.as_int()?, stop.as_int()?, s))
            }
            _ => Err(format!("range expected 1..3 arguments, got {}", args.len())),
        }),
    );

    globals.insert(
        "len".into(),
        Value::builtin("len", |args| match args {
            [Value::List(l)] => Ok(Value::Int(l.borrow().len() as i64)),
            [Value::Tuple(t)] => Ok(Value::Int(t.len() as i64)),
            [Value::Str(s)] => Ok(Value::Int(s.chars().count() as i64)),
            [Value::Dict(d)] => Ok(Value::Int(d.borrow().len() as i64)),
            [Value::Range(a, b, s)] => {
                let n = if *s > 0 { (b - a + s - 1) / s } else { (a - b - s - 1) / (-s) };
                Ok(Value::Int(n.max(0)))
            }
            [Value::Tensor(t)] => Ok(Value::Int(*t.shape().first().unwrap_or(&0) as i64)),
            [other] => Err(format!("object of type '{}' has no len()", other.type_name())),
            _ => Err("len() takes exactly one argument".into()),
        }),
    );

    globals.insert(
        "abs".into(),
        Value::builtin("abs", |args| match args {
            [Value::Int(i)] => Ok(Value::Int(i.abs())),
            [Value::Float(f)] => Ok(Value::Float(f.abs())),
            [Value::Tensor(t)] => Ok(Value::tensor(tensor::abs(t))),
            [other] => Err(format!("bad operand for abs(): {}", other.type_name())),
            _ => Err("abs() takes exactly one argument".into()),
        }),
    );

    globals.insert(
        "sum".into(),
        Value::builtin("sum", |args| match args {
            [v] => {
                let items = values_as_iterable(v)?;
                let mut acc = Value::Int(0);
                for it in items {
                    acc = super::interp::binary_op_values(crate::bytecode::BinOp::Add, &acc, &it)?;
                }
                Ok(acc)
            }
            _ => Err("sum() takes one argument".into()),
        }),
    );

    globals.insert(
        "min".into(),
        Value::builtin("min", |args| {
            let items = if args.len() == 1 { values_as_iterable(&args[0])? } else { args.to_vec() };
            let mut best: Option<Value> = None;
            for it in items {
                best = Some(match best {
                    None => it,
                    Some(b) => {
                        if it.cmp_value(&b)? == std::cmp::Ordering::Less {
                            it
                        } else {
                            b
                        }
                    }
                });
            }
            best.ok_or_else(|| "min() arg is an empty sequence".into())
        }),
    );

    globals.insert(
        "max".into(),
        Value::builtin("max", |args| {
            let items = if args.len() == 1 { values_as_iterable(&args[0])? } else { args.to_vec() };
            let mut best: Option<Value> = None;
            for it in items {
                best = Some(match best {
                    None => it,
                    Some(b) => {
                        if it.cmp_value(&b)? == std::cmp::Ordering::Greater {
                            it
                        } else {
                            b
                        }
                    }
                });
            }
            best.ok_or_else(|| "max() arg is an empty sequence".into())
        }),
    );

    globals.insert(
        "int".into(),
        Value::builtin("int", |args| match args {
            [v] => Ok(Value::Int(v.as_int()?)),
            _ => Err("int() takes one argument".into()),
        }),
    );

    globals.insert(
        "float".into(),
        Value::builtin("float", |args| match args {
            [v] => Ok(Value::Float(v.as_float()?)),
            _ => Err("float() takes one argument".into()),
        }),
    );

    globals.insert(
        "bool".into(),
        Value::builtin("bool", |args| match args {
            [v] => Ok(Value::Bool(v.truthy()?)),
            _ => Err("bool() takes one argument".into()),
        }),
    );

    globals.insert(
        "str".into(),
        Value::builtin("str", |args| match args {
            [v] => Ok(Value::str(&v.to_display())),
            _ => Err("str() takes one argument".into()),
        }),
    );

    globals.insert(
        "list".into(),
        Value::builtin("list", |args| match args {
            [] => Ok(Value::list(vec![])),
            [v] => Ok(Value::list(values_as_iterable(v)?)),
            _ => Err("list() takes at most one argument".into()),
        }),
    );

    globals.insert(
        "tuple".into(),
        Value::builtin("tuple", |args| match args {
            [] => Ok(Value::tuple(vec![])),
            [v] => Ok(Value::tuple(values_as_iterable(v)?)),
            _ => Err("tuple() takes at most one argument".into()),
        }),
    );

    globals.insert(
        "iter".into(),
        Value::builtin("iter", |args| match args {
            [v] => super::interp::make_iter(v),
            _ => Err("iter() takes one argument".into()),
        }),
    );

    globals.insert(
        "sorted".into(),
        Value::builtin("sorted", |args| match args {
            [v] => {
                let mut items = values_as_iterable(v)?;
                let mut err = None;
                items.sort_by(|a, b| match a.cmp_value(b) {
                    Ok(o) => o,
                    Err(e) => {
                        err = Some(e);
                        std::cmp::Ordering::Equal
                    }
                });
                match err {
                    Some(e) => Err(e.into()),
                    None => Ok(Value::list(items)),
                }
            }
            _ => Err("sorted() takes one argument".into()),
        }),
    );

    globals.insert(
        "enumerate".into(),
        Value::builtin("enumerate", |args| match args {
            [v] => {
                let items = values_as_iterable(v)?;
                Ok(Value::list(
                    items.into_iter().enumerate().map(|(i, x)| Value::tuple(vec![Value::Int(i as i64), x])).collect(),
                ))
            }
            _ => Err("enumerate() takes one argument".into()),
        }),
    );

    globals.insert(
        "zip".into(),
        Value::builtin("zip", |args| {
            let lists: Result<Vec<Vec<Value>>, String> = args.iter().map(values_as_iterable).collect();
            let lists = lists?;
            let n = lists.iter().map(|l| l.len()).min().unwrap_or(0);
            Ok(Value::list(
                (0..n).map(|i| Value::tuple(lists.iter().map(|l| l[i].clone()).collect())).collect(),
            ))
        }),
    );

    // ---- torch module ----
    let torch = Value::dict();
    if let Value::Dict(td) = &torch {
        let mut t = td.borrow_mut();
        let rng = &vm.rng;

        t.insert(DictKey::Str("tensor".into()), Value::builtin("tensor", |args| match args {
            [v] => {
                let (shape, data) = nested_list_to_tensor(v)?;
                Ok(Value::tensor(Tensor::new(shape, data)))
            }
            _ => Err("torch.tensor() takes one argument".into()),
        }));

        t.insert(DictKey::Str("zeros".into()), Value::builtin("zeros", |args| match args {
            [s] => Ok(Value::tensor(Tensor::zeros(&shape_arg(s)?))),
            _ => Err("torch.zeros(shape)".into()),
        }));

        t.insert(DictKey::Str("ones".into()), Value::builtin("ones", |args| match args {
            [s] => Ok(Value::tensor(Tensor::ones(&shape_arg(s)?))),
            _ => Err("torch.ones(shape)".into()),
        }));

        t.insert(DictKey::Str("arange".into()), Value::builtin("arange", |args| match args {
            [n] => Ok(Value::tensor(Tensor::arange(n.as_int()? as usize))),
            _ => Err("torch.arange(n)".into()),
        }));

        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("randn".into()), Value::builtin("randn", move |args| match args {
                [s] => Ok(Value::tensor(Tensor::randn(&shape_arg(s)?, &mut rng.borrow_mut()))),
                _ => Err("torch.randn(shape)".into()),
            }));
        }
        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("rand".into()), Value::builtin("rand", move |args| match args {
                [s] => Ok(Value::tensor(Tensor::rand(&shape_arg(s)?, &mut rng.borrow_mut()))),
                _ => Err("torch.rand(shape)".into()),
            }));
        }
        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("randint".into()), Value::builtin("randint", move |args| match args {
                [hi, s] => {
                    let hi = hi.as_int()?.max(1) as u64;
                    let shape = shape_arg(s)?;
                    let n: usize = shape.iter().product();
                    let mut r = rng.borrow_mut();
                    let data: Vec<f32> = (0..n).map(|_| (r.next_u64() % hi) as f32).collect();
                    Ok(Value::tensor(Tensor::new(shape, data)))
                }
                _ => Err("torch.randint(high, shape)".into()),
            }));
        }
        {
            let rng = Rc::clone(rng);
            t.insert(DictKey::Str("manual_seed".into()), Value::builtin("manual_seed", move |args| match args {
                [s] => {
                    *rng.borrow_mut() = Rng::new(s.as_int()? as u64);
                    Ok(Value::None)
                }
                _ => Err("torch.manual_seed(n)".into()),
            }));
        }

        t.insert(DictKey::Str("matmul".into()), Value::builtin("matmul", |args| match args {
            [a, b] => Ok(Value::tensor(tensor::matmul(&*a.as_tensor()?, &*b.as_tensor()?)?)),
            _ => Err("torch.matmul(a, b)".into()),
        }));

        t.insert(DictKey::Str("maximum".into()), Value::builtin("maximum", |args| match args {
            [a, b] => Ok(Value::tensor(tensor::maximum(&*a.as_tensor()?, &*b.as_tensor()?)?)),
            _ => Err("torch.maximum(a, b)".into()),
        }));

        t.insert(DictKey::Str("minimum".into()), Value::builtin("minimum", |args| match args {
            [a, b] => Ok(Value::tensor(tensor::minimum(&*a.as_tensor()?, &*b.as_tensor()?)?)),
            _ => Err("torch.minimum(a, b)".into()),
        }));

        t.insert(DictKey::Str("softmax".into()), Value::builtin("softmax", |args| match args {
            [x] => Ok(Value::tensor(tensor::softmax(&*x.as_tensor()?)?)),
            _ => Err("torch.softmax(x)".into()),
        }));

        t.insert(DictKey::Str("relu".into()), Value::builtin("relu", |args| match args {
            [x] => Ok(Value::tensor(tensor::relu(&*x.as_tensor()?))),
            _ => Err("torch.relu(x)".into()),
        }));

        t.insert(DictKey::Str("gelu".into()), Value::builtin("gelu", |args| match args {
            [x] => Ok(Value::tensor(tensor::gelu(&*x.as_tensor()?))),
            _ => Err("torch.gelu(x)".into()),
        }));

        t.insert(DictKey::Str("tanh".into()), Value::builtin("tanh", |args| match args {
            [x] => Ok(Value::tensor(tensor::tanh(&*x.as_tensor()?))),
            _ => Err("torch.tanh(x)".into()),
        }));

        t.insert(DictKey::Str("layernorm".into()), Value::builtin("layernorm", |args| match args {
            [x, g, b] => Ok(Value::tensor(tensor::layernorm(&*x.as_tensor()?, &*g.as_tensor()?, &*b.as_tensor()?, 1e-5)?)),
            _ => Err("torch.layernorm(x, gamma, beta)".into()),
        }));

        t.insert(DictKey::Str("embedding".into()), Value::builtin("embedding", |args| match args {
            [table, ids] => Ok(Value::tensor(tensor::embedding(&*table.as_tensor()?, &*ids.as_tensor()?)?)),
            _ => Err("torch.embedding(table, ids)".into()),
        }));

        t.insert(DictKey::Str("cross_entropy".into()), Value::builtin("cross_entropy", |args| match args {
            [logits, targets] => Ok(Value::tensor(tensor::cross_entropy(&*logits.as_tensor()?, &*targets.as_tensor()?)?)),
            _ => Err("torch.cross_entropy(logits, targets)".into()),
        }));
    }
    globals.insert("torch".into(), torch);
}
