//! The bytecode dispatch loop.

use std::cell::RefCell;
use std::cmp::Ordering;
use std::rc::Rc;

use super::methods::{apply_subscript, call_method_on, get_attr, store_subscript};
use super::{Vm, VmError};
use crate::bytecode::{BinOp, CodeObject, Const, Instr, UnOp};
use crate::tensor::{self, Tensor};
use crate::value::{Function, Value, ValueIter};

/// Convert a compile-time constant to a runtime value.
pub fn const_to_value(c: &Const) -> Value {
    match c {
        Const::None => Value::None,
        Const::Bool(b) => Value::Bool(*b),
        Const::Int(i) => Value::Int(*i),
        Const::Float(f) => Value::Float(*f),
        Const::Str(s) => Value::str(s),
        Const::Code(code) => Value::Code(Rc::clone(code)),
    }
}

/// Call any callable value.
pub fn call_value(vm: &Vm, callee: &Value, args: &[Value]) -> Result<Value, VmError> {
    match callee {
        Value::Func(f) => {
            // Frame-evaluation hook (PEP 523 analogue): dynamo may swap in
            // transformed bytecode. The hook sees every frame — including
            // dynamo's own resume functions, which are recursively analyzed
            // (the hook maintains its own skip set for transformed output).
            let mut code = Rc::clone(&f.code);
            if let Some(hook) = vm.eval_hook.clone() {
                if let Some(replacement) = hook.eval_frame(f, args, &vm.globals) {
                    code = replacement;
                }
            }
            run_function(vm, &code, f, args)
        }
        Value::Builtin(b) => (b.func)(args).map_err(VmError::new),
        Value::BoundMethod(m) => call_method_on(vm, &m.0, &m.1, args).map_err(VmError::new),
        Value::CompiledGraph(g) => {
            let tensors: Result<Vec<Rc<Tensor>>, crate::value::ValueError> = args.iter().map(|a| a.as_tensor()).collect();
            let outs = g.call(&tensors.map_err(VmError::new)?).map_err(|e| VmError::new(e.to_string()))?;
            Ok(Value::tuple(outs.into_iter().map(Value::tensor).collect()))
        }
        other => Err(VmError::new(format!("'{}' object is not callable", other.type_name()))),
    }
}

/// Bind arguments (with defaults) and run a function body.
fn run_function(vm: &Vm, code: &Rc<CodeObject>, f: &Rc<Function>, args: &[Value]) -> Result<Value, VmError> {
    let argc = code.argcount;
    if args.len() > argc || args.len() + f.defaults.len() < argc {
        return Err(VmError::new(format!(
            "{}() takes {} arguments but {} were given",
            f.name,
            argc,
            args.len()
        )));
    }
    let mut bound: Vec<Value> = args.to_vec();
    let missing = argc - args.len();
    let dstart = f.defaults.len() - missing;
    bound.extend(f.defaults[dstart..].iter().cloned());
    run_code(vm, code, &bound, &f.closure, Some(&f.name))
}

/// Execute a code object with pre-bound arguments.
pub fn run_code(
    vm: &Vm,
    code: &Rc<CodeObject>,
    args: &[Value],
    closure: &[Rc<RefCell<Value>>],
    func_name: Option<&str>,
) -> Result<Value, VmError> {
    let depth = vm.depth.get();
    if depth >= vm.max_depth {
        return Err(VmError::new("maximum recursion depth exceeded"));
    }
    vm.depth.set(depth + 1);
    let result = run_frame(vm, code, args, closure, func_name);
    vm.depth.set(depth);
    result.map_err(|mut e| {
        let line = e.traceback.last().map(|_| 0).unwrap_or(0);
        let _ = line;
        e.traceback.push((func_name.unwrap_or(&code.name).to_string(), 0));
        e
    })
}

fn run_frame(
    vm: &Vm,
    code: &Rc<CodeObject>,
    args: &[Value],
    closure: &[Rc<RefCell<Value>>],
    func_name: Option<&str>,
) -> Result<Value, VmError> {
    let name = func_name.unwrap_or(&code.name);
    // Locals.
    let mut locals: Vec<Option<Value>> = vec![None; code.varnames.len().max(code.argcount)];
    for (i, a) in args.iter().enumerate() {
        locals[i] = Some(a.clone());
    }
    // Cells: cellvars get fresh cells (seeded from params of the same name),
    // freevars come from the closure.
    let mut cells: Vec<Rc<RefCell<Value>>> = Vec::with_capacity(code.cellvars.len() + code.freevars.len());
    for cv in &code.cellvars {
        let init = code.varnames.iter().position(|v| v == cv).and_then(|i| locals.get(i).cloned().flatten());
        cells.push(Rc::new(RefCell::new(init.unwrap_or(Value::None))));
    }
    if closure.len() != code.freevars.len() {
        return Err(VmError::new(format!(
            "{}: closure length {} != freevars {}",
            name,
            closure.len(),
            code.freevars.len()
        )));
    }
    cells.extend(closure.iter().cloned());

    let mut stack: Vec<Value> = Vec::with_capacity(16);
    let mut ip: usize = 0;
    let mut last_line: u32 = 0;

    let fail = |msg: String, ip: usize| -> VmError {
        VmError { message: msg, traceback: vec![(name.to_string(), code.line_of(ip))] }
    };

    macro_rules! pop {
        () => {
            stack.pop().ok_or_else(|| fail("stack underflow".into(), ip))?
        };
    }

    loop {
        let budget = vm.instr_budget.get();
        if budget == 0 {
            return Err(fail("instruction budget exceeded".into(), ip));
        }
        vm.instr_budget.set(budget - 1);

        let Some(instr) = code.instrs.get(ip) else {
            return Err(fail(format!("instruction pointer {} out of range", ip), ip));
        };

        // Line tracing for the debugger.
        if let (Some(tracer), Some(src)) = (&vm.tracer, &code.source) {
            let line = code.line_of(ip);
            if line != 0 && line != last_line {
                last_line = line;
                let locs: Vec<(String, Value)> = code
                    .varnames
                    .iter()
                    .enumerate()
                    .filter_map(|(i, n)| locals.get(i).cloned().flatten().map(|v| (n.clone(), v)))
                    .collect();
                tracer.on_line(&src.file, line, name, &locs);
            }
        }

        let cur = ip;
        ip += 1;
        match instr {
            Instr::Nop => {}
            Instr::LoadConst(c) => {
                let k = code.consts.get(*c as usize).ok_or_else(|| fail(format!("bad const {}", c), cur))?;
                stack.push(const_to_value(k));
            }
            Instr::LoadFast(i) => {
                let v = locals
                    .get(*i as usize)
                    .cloned()
                    .flatten()
                    .ok_or_else(|| fail(format!("local variable '{}' referenced before assignment", code.varnames.get(*i as usize).cloned().unwrap_or_default()), cur))?;
                stack.push(v);
            }
            Instr::StoreFast(i) => {
                let v = pop!();
                let idx = *i as usize;
                if idx >= locals.len() {
                    locals.resize(idx + 1, None);
                }
                locals[idx] = Some(v);
            }
            Instr::LoadGlobal(n) => {
                let gname = code.names.get(*n as usize).ok_or_else(|| fail(format!("bad name {}", n), cur))?;
                let v = vm
                    .globals
                    .borrow()
                    .get(gname)
                    .cloned()
                    .ok_or_else(|| fail(format!("name '{}' is not defined", gname), cur))?;
                stack.push(v);
            }
            Instr::StoreGlobal(n) => {
                let gname = code.names[*n as usize].clone();
                let v = pop!();
                vm.globals.borrow_mut().insert(gname, v);
            }
            Instr::LoadDeref(i) => {
                let cell = cells.get(*i as usize).ok_or_else(|| fail(format!("bad deref {}", i), cur))?;
                let v = cell.borrow().clone();
                if v.is_none() && code.cell_and_free_name(*i as usize) != "None" {
                    // Allow None values; only truly-unset cells would be an
                    // error, but we initialize with None, so pass through.
                }
                stack.push(v);
            }
            Instr::StoreDeref(i) => {
                let v = pop!();
                let cell = cells.get(*i as usize).ok_or_else(|| fail(format!("bad deref {}", i), cur))?;
                *cell.borrow_mut() = v;
            }
            Instr::LoadClosure(i) => {
                let cell = cells.get(*i as usize).ok_or_else(|| fail(format!("bad closure {}", i), cur))?;
                stack.push(Value::Cell(Rc::clone(cell)));
            }
            Instr::LoadAttr(n) => {
                let obj = pop!();
                let aname = &code.names[*n as usize];
                stack.push(get_attr(&obj, aname).map_err(|m| fail(m.into(), cur))?);
            }
            Instr::LoadMethod(n) => {
                let obj = pop!();
                let mname = &code.names[*n as usize];
                // Dict "modules" (torch) expose functions as items.
                if let Value::Dict(d) = &obj {
                    if let Some(f) = d.borrow().get(&crate::value::DictKey::Str(mname.to_string())) {
                        stack.push(f.clone());
                        continue;
                    }
                }
                stack.push(Value::BoundMethod(Rc::new((obj, mname.to_string()))));
            }
            Instr::BinarySubscr => {
                let idx = pop!();
                let obj = pop!();
                stack.push(apply_subscript(&obj, &idx).map_err(|m| fail(m.into(), cur))?);
            }
            Instr::StoreSubscr => {
                let idx = pop!();
                let obj = pop!();
                let val = pop!();
                store_subscript(&obj, &idx, val).map_err(|m| fail(m.into(), cur))?;
            }
            Instr::BuildSlice(n) => {
                let step = if *n == 3 { pop!() } else { Value::None };
                let stop = pop!();
                let start = pop!();
                stack.push(Value::Slice(Rc::new((start, stop, step))));
            }
            Instr::PopTop => {
                pop!();
            }
            Instr::DupTop => {
                let v = stack.last().ok_or_else(|| fail("stack underflow".into(), cur))?.clone();
                stack.push(v);
            }
            Instr::RotTwo => {
                let len = stack.len();
                if len < 2 {
                    return Err(fail("stack underflow".into(), cur));
                }
                stack.swap(len - 1, len - 2);
            }
            Instr::RotThree => {
                // [a, b, c] -> [c, a, b]
                let c = pop!();
                let b = pop!();
                let a = pop!();
                stack.push(c);
                stack.push(a);
                stack.push(b);
            }
            Instr::Binary(op) => {
                let b = pop!();
                let a = pop!();
                stack.push(binary_op_values(*op, &a, &b).map_err(|m| fail(m.into(), cur))?);
            }
            Instr::Unary(op) => {
                let a = pop!();
                let v = match op {
                    UnOp::Not => Value::Bool(!a.truthy().map_err(|m| fail(m.into(), cur))?),
                    UnOp::Neg => match &a {
                        Value::Int(i) => Value::Int(-i),
                        Value::Float(f) => Value::Float(-f),
                        Value::Bool(b) => Value::Int(-(*b as i64)),
                        Value::Tensor(t) => Value::tensor(tensor::neg(t)),
                        other => return Err(fail(format!("bad operand for unary -: {}", other.type_name()), cur)),
                    },
                    UnOp::Pos => match &a {
                        Value::Int(_) | Value::Float(_) | Value::Tensor(_) => a,
                        Value::Bool(b) => Value::Int(*b as i64),
                        other => return Err(fail(format!("bad operand for unary +: {}", other.type_name()), cur)),
                    },
                };
                stack.push(v);
            }
            Instr::Compare(c) => {
                let b = pop!();
                let a = pop!();
                let r = compare_values(*c, &a, &b).map_err(|m| fail(m.into(), cur))?;
                stack.push(r);
            }
            Instr::ContainsOp(invert) => {
                let container = pop!();
                let item = pop!();
                let found = contains(&container, &item).map_err(|m| fail(m.into(), cur))?;
                stack.push(Value::Bool(found != *invert));
            }
            Instr::IsOp(invert) => {
                let b = pop!();
                let a = pop!();
                stack.push(Value::Bool(a.is_identical(&b) != *invert));
            }
            Instr::Jump(t) => {
                ip = *t as usize;
            }
            Instr::PopJumpIfFalse(t) => {
                let v = pop!();
                if !v.truthy().map_err(|m| fail(m.into(), cur))? {
                    ip = *t as usize;
                }
            }
            Instr::PopJumpIfTrue(t) => {
                let v = pop!();
                if v.truthy().map_err(|m| fail(m.into(), cur))? {
                    ip = *t as usize;
                }
            }
            Instr::JumpIfFalseOrPop(t) => {
                let v = stack.last().ok_or_else(|| fail("stack underflow".into(), cur))?;
                if !v.truthy().map_err(|m| fail(m.into(), cur))? {
                    ip = *t as usize;
                } else {
                    stack.pop();
                }
            }
            Instr::JumpIfTrueOrPop(t) => {
                let v = stack.last().ok_or_else(|| fail("stack underflow".into(), cur))?;
                if v.truthy().map_err(|m| fail(m.into(), cur))? {
                    ip = *t as usize;
                } else {
                    stack.pop();
                }
            }
            Instr::GetIter => {
                let v = pop!();
                stack.push(make_iter(&v).map_err(|m| fail(m.into(), cur))?);
            }
            Instr::ForIter(t) => {
                let Some(Value::Iter(it)) = stack.last() else {
                    return Err(fail("FOR_ITER on non-iterator".into(), cur));
                };
                let nxt = it.borrow_mut().next_item();
                match nxt {
                    Some(v) => stack.push(v),
                    None => {
                        stack.pop();
                        ip = *t as usize;
                    }
                }
            }
            Instr::Call(n) => {
                let argv: Vec<Value> = drain_top(&mut stack, *n as usize).map_err(|m| fail(m.into(), cur))?;
                let callee = pop!();
                let r = call_value(vm, &callee, &argv).map_err(|mut e| {
                    e.traceback.push((name.to_string(), code.line_of(cur)));
                    e
                })?;
                stack.push(r);
            }
            Instr::CallMethod(n) => {
                let argv: Vec<Value> = drain_top(&mut stack, *n as usize).map_err(|m| fail(m.into(), cur))?;
                let callee = pop!();
                let r = call_value(vm, &callee, &argv).map_err(|mut e| {
                    e.traceback.push((name.to_string(), code.line_of(cur)));
                    e
                })?;
                stack.push(r);
            }
            Instr::MakeFunction(flags) => {
                let Value::Code(fcode) = pop!() else {
                    return Err(fail("MAKE_FUNCTION without code".into(), cur));
                };
                let mut fclosure: Vec<Rc<RefCell<Value>>> = Vec::new();
                if flags & 2 != 0 {
                    let Value::Tuple(t) = pop!() else {
                        return Err(fail("MAKE_FUNCTION closure must be tuple".into(), cur));
                    };
                    for c in t.iter() {
                        let Value::Cell(cell) = c else {
                            return Err(fail("closure tuple must contain cells".into(), cur));
                        };
                        fclosure.push(Rc::clone(cell));
                    }
                }
                let mut defaults: Vec<Value> = Vec::new();
                if flags & 1 != 0 {
                    let Value::Tuple(t) = pop!() else {
                        return Err(fail("MAKE_FUNCTION defaults must be tuple".into(), cur));
                    };
                    defaults = t.to_vec();
                }
                let fname = fcode.name.clone();
                stack.push(Value::Func(Rc::new(Function { name: fname, code: fcode, defaults, closure: fclosure })));
            }
            Instr::ReturnValue => {
                return Ok(pop!());
            }
            Instr::BuildList(n) => {
                let items = drain_top(&mut stack, *n as usize).map_err(|m| fail(m.into(), cur))?;
                stack.push(Value::list(items));
            }
            Instr::BuildTuple(n) => {
                let items = drain_top(&mut stack, *n as usize).map_err(|m| fail(m.into(), cur))?;
                stack.push(Value::tuple(items));
            }
            Instr::BuildMap(n) => {
                let mut kvs = drain_top(&mut stack, 2 * *n as usize).map_err(|m| fail(m.into(), cur))?;
                let d = Value::dict();
                if let Value::Dict(map) = &d {
                    let mut m = map.borrow_mut();
                    for _ in 0..*n {
                        let k = kvs.remove(0);
                        let v = kvs.remove(0);
                        let key = crate::value::DictKey::from_value(&k).map_err(|e| fail(e.into(), cur))?;
                        m.insert(key, v);
                    }
                }
                stack.push(d);
            }
            Instr::ListAppend(depth) => {
                let elt = pop!();
                let idx = stack
                    .len()
                    .checked_sub(*depth as usize)
                    .ok_or_else(|| fail("LIST_APPEND depth".into(), cur))?;
                let Value::List(l) = &stack[idx] else {
                    return Err(fail("LIST_APPEND target is not a list".into(), cur));
                };
                l.borrow_mut().push(elt);
            }
            Instr::UnpackSequence(n) => {
                let v = pop!();
                let items: Vec<Value> = match &v {
                    Value::List(l) => l.borrow().clone(),
                    Value::Tuple(t) => t.to_vec(),
                    Value::Range(..) => match make_iter(&v) {
                        Ok(Value::Iter(it)) => it.borrow().items.clone(),
                        _ => return Err(fail("cannot unpack".into(), cur)),
                    },
                    other => return Err(fail(format!("cannot unpack {}", other.type_name()), cur)),
                };
                if items.len() != *n as usize {
                    return Err(fail(format!("expected {} values to unpack, got {}", n, items.len()), cur));
                }
                for item in items.into_iter().rev() {
                    stack.push(item);
                }
            }
            Instr::Raise => {
                let v = pop!();
                return Err(fail(v.to_display(), cur));
            }
        }
    }
}

fn drain_top(stack: &mut Vec<Value>, n: usize) -> Result<Vec<Value>, String> {
    if stack.len() < n {
        return Err("stack underflow".into());
    }
    Ok(stack.split_off(stack.len() - n))
}

/// Create an iterator value.
pub fn make_iter(v: &Value) -> Result<Value, String> {
    let items: Vec<Value> = match v {
        Value::List(l) => l.borrow().clone(),
        Value::Tuple(t) => t.to_vec(),
        Value::Str(s) => s.chars().map(|c| Value::str(&c.to_string())).collect(),
        Value::Dict(d) => d.borrow().keys().map(|k| k.to_value()).collect(),
        Value::Range(start, stop, step) => {
            let mut out = Vec::new();
            let (mut i, stop, step) = (*start, *stop, *step);
            if step == 0 {
                return Err("range() step must not be zero".into());
            }
            while (step > 0 && i < stop) || (step < 0 && i > stop) {
                out.push(Value::Int(i));
                i += step;
            }
            out
        }
        Value::Iter(_) => return Ok(v.clone()),
        other => return Err(format!("'{}' object is not iterable", other.type_name())),
    };
    Ok(Value::Iter(Rc::new(RefCell::new(ValueIter { items, pos: 0 }))))
}

/// Python `%` (sign of divisor) and `//` (floor) semantics for ints.
fn floordiv_i(a: i64, b: i64) -> Result<i64, String> {
    if b == 0 {
        return Err("integer division by zero".into());
    }
    let q = a / b;
    if a % b != 0 && ((a < 0) != (b < 0)) {
        Ok(q - 1)
    } else {
        Ok(q)
    }
}

fn mod_i(a: i64, b: i64) -> Result<i64, String> {
    if b == 0 {
        return Err("integer modulo by zero".into());
    }
    let r = a % b;
    if r != 0 && ((r < 0) != (b < 0)) {
        Ok(r + b)
    } else {
        Ok(r)
    }
}

/// The binary-operator semantics shared by the VM and the dynamo constant
/// folder.
pub fn binary_op_values(op: BinOp, a: &Value, b: &Value) -> Result<Value, String> {
    use Value as V;
    // Tensor ops (with scalar promotion).
    let tensorish = |v: &Value| -> Option<Tensor> {
        match v {
            V::Tensor(t) => Some((**t).clone()),
            V::Int(i) => Some(Tensor::scalar(*i as f32)),
            V::Float(f) => Some(Tensor::scalar(*f as f32)),
            V::Bool(x) => Some(Tensor::scalar(*x as i64 as f32)),
            _ => None,
        }
    };
    if matches!(a, V::Tensor(_)) || matches!(b, V::Tensor(_)) {
        let (ta, tb) = match (tensorish(a), tensorish(b)) {
            (Some(x), Some(y)) => (x, y),
            _ => return Err(format!("unsupported tensor op between {} and {}", a.type_name(), b.type_name())),
        };
        let r = match op {
            BinOp::Add => tensor::add(&ta, &tb)?,
            BinOp::Sub => tensor::sub(&ta, &tb)?,
            BinOp::Mul => tensor::mul(&ta, &tb)?,
            BinOp::Div => tensor::div(&ta, &tb)?,
            BinOp::Pow => tensor::pow(&ta, &tb)?,
            BinOp::MatMul => tensor::matmul(&ta, &tb)?,
            BinOp::FloorDiv => tensor::unary_op(&tensor::div(&ta, &tb)?, f32::floor),
            BinOp::Mod => return Err("tensor % not supported".into()),
        };
        return Ok(V::tensor(r));
    }
    // Numeric ops.
    let as_f = |v: &Value| -> Option<f64> {
        match v {
            V::Int(i) => Some(*i as f64),
            V::Float(f) => Some(*f),
            V::Bool(b) => Some(*b as i64 as f64),
            _ => None,
        }
    };
    let as_i = |v: &Value| -> Option<i64> {
        match v {
            V::Int(i) => Some(*i),
            V::Bool(b) => Some(*b as i64),
            _ => None,
        }
    };
    match op {
        BinOp::Add => match (a, b) {
            (V::Str(x), V::Str(y)) => return Ok(V::str(&format!("{}{}", x, y))),
            (V::List(x), V::List(y)) => {
                let mut out = x.borrow().clone();
                out.extend(y.borrow().iter().cloned());
                return Ok(V::list(out));
            }
            (V::Tuple(x), V::Tuple(y)) => {
                let mut out = x.to_vec();
                out.extend(y.iter().cloned());
                return Ok(V::tuple(out));
            }
            _ => {}
        },
        BinOp::Mul => match (a, b) {
            (V::Str(s), V::Int(n)) | (V::Int(n), V::Str(s)) => {
                return Ok(V::str(&s.repeat((*n).max(0) as usize)));
            }
            (V::List(l), V::Int(n)) | (V::Int(n), V::List(l)) => {
                let mut out = Vec::new();
                for _ in 0..(*n).max(0) {
                    out.extend(l.borrow().iter().cloned());
                }
                return Ok(V::list(out));
            }
            _ => {}
        },
        BinOp::Mod => {
            if let (V::Str(s), other) = (a, b) {
                // printf-style with a single %s / %d (subset).
                let formatted = s.replacen("%s", &other.to_display(), 1).replacen("%d", &other.to_display(), 1);
                return Ok(V::str(&formatted));
            }
        }
        _ => {}
    }
    // Int-preserving paths.
    if let (Some(x), Some(y)) = (as_i(a), as_i(b)) {
        return Ok(match op {
            BinOp::Add => V::Int(x + y),
            BinOp::Sub => V::Int(x - y),
            BinOp::Mul => V::Int(x * y),
            BinOp::Div => {
                if y == 0 {
                    return Err("division by zero".into());
                }
                V::Float(x as f64 / y as f64)
            }
            BinOp::FloorDiv => V::Int(floordiv_i(x, y)?),
            BinOp::Mod => V::Int(mod_i(x, y)?),
            BinOp::Pow => {
                if y >= 0 {
                    V::Int(x.pow(y.min(63) as u32))
                } else {
                    V::Float((x as f64).powi(y as i32))
                }
            }
            BinOp::MatMul => return Err("@ requires tensors".into()),
        });
    }
    if let (Some(x), Some(y)) = (as_f(a), as_f(b)) {
        return Ok(match op {
            BinOp::Add => V::Float(x + y),
            BinOp::Sub => V::Float(x - y),
            BinOp::Mul => V::Float(x * y),
            BinOp::Div => {
                if y == 0.0 {
                    return Err("float division by zero".into());
                }
                V::Float(x / y)
            }
            BinOp::FloorDiv => V::Float((x / y).floor()),
            BinOp::Mod => {
                let r = x % y;
                V::Float(if r != 0.0 && (r < 0.0) != (y < 0.0) { r + y } else { r })
            }
            BinOp::Pow => V::Float(x.powf(y)),
            BinOp::MatMul => return Err("@ requires tensors".into()),
        });
    }
    Err(format!(
        "unsupported operand type(s) for {}: '{}' and '{}'",
        op.symbol(),
        a.type_name(),
        b.type_name()
    ))
}

/// Comparison dispatch. Tensor comparisons are elementwise (0.0/1.0 masks),
/// like PyTorch.
pub fn compare_values(op: crate::bytecode::CmpOp, a: &Value, b: &Value) -> Result<Value, String> {
    use crate::bytecode::CmpOp;
    if matches!(a, Value::Tensor(_)) || matches!(b, Value::Tensor(_)) {
        let ta = match a {
            Value::Tensor(t) => (**t).clone(),
            v => Tensor::scalar(v.as_float()? as f32),
        };
        let tb = match b {
            Value::Tensor(t) => (**t).clone(),
            v => Tensor::scalar(v.as_float()? as f32),
        };
        let f: fn(f32, f32) -> f32 = match op {
            CmpOp::Lt => |x, y| (x < y) as i32 as f32,
            CmpOp::Le => |x, y| (x <= y) as i32 as f32,
            CmpOp::Gt => |x, y| (x > y) as i32 as f32,
            CmpOp::Ge => |x, y| (x >= y) as i32 as f32,
            CmpOp::Eq => |x, y| (x == y) as i32 as f32,
            CmpOp::Ne => |x, y| (x != y) as i32 as f32,
        };
        return Ok(Value::tensor(tensor::binary_op(&ta, &tb, f)?));
    }
    let r = match op {
        CmpOp::Eq => a.eq_value(b),
        CmpOp::Ne => !a.eq_value(b),
        CmpOp::Lt => a.cmp_value(b)? == Ordering::Less,
        CmpOp::Le => a.cmp_value(b)? != Ordering::Greater,
        CmpOp::Gt => a.cmp_value(b)? == Ordering::Greater,
        CmpOp::Ge => a.cmp_value(b)? != Ordering::Less,
    };
    Ok(Value::Bool(r))
}

pub fn contains(container: &Value, item: &Value) -> Result<bool, String> {
    match container {
        Value::List(l) => Ok(l.borrow().iter().any(|v| v.eq_value(item))),
        Value::Tuple(t) => Ok(t.iter().any(|v| v.eq_value(item))),
        Value::Dict(d) => {
            let k = crate::value::DictKey::from_value(item)?;
            Ok(d.borrow().contains_key(&k))
        }
        Value::Str(s) => match item {
            Value::Str(sub) => Ok(s.contains(&**sub)),
            other => Err(format!("'in <string>' requires string, got {}", other.type_name())),
        },
        Value::Range(start, stop, step) => match item {
            Value::Int(i) => {
                if *step > 0 {
                    Ok(*i >= *start && *i < *stop && (*i - *start) % *step == 0)
                } else {
                    Ok(*i <= *start && *i > *stop && (*start - *i) % (-*step) == 0)
                }
            }
            _ => Ok(false),
        },
        other => Err(format!("argument of type '{}' is not iterable", other.type_name())),
    }
}
