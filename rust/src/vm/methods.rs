//! Attribute access, subscripting, and built-in methods on values
//! (list/str/dict/tuple/tensor method tables). Failures are typed
//! [`ValueError`]s (wrapping [`crate::tensor::TensorError`] where a tensor
//! op is underneath), so callers can tell a shape error from a dtype/type
//! error without string matching.

use std::rc::Rc;

use super::Vm;
use crate::tensor::{self, Tensor};
use crate::value::{DictKey, Value, ValueError};

/// `obj.name` for non-call attribute access.
pub fn get_attr(obj: &Value, name: &str) -> Result<Value, ValueError> {
    match (obj, name) {
        (Value::Tensor(t), "shape") => Ok(Value::tuple(t.shape().iter().map(|&d| Value::Int(d as i64)).collect())),
        (Value::Tensor(t), "ndim") => Ok(Value::Int(t.rank() as i64)),
        (Value::Tensor(t), "T") => Ok(Value::tensor(tensor::transpose(t)?)),
        (Value::Dict(d), _) => d
            .borrow()
            .get(&DictKey::Str(name.to_string()))
            .cloned()
            .ok_or_else(|| ValueError::Msg(format!("'dict' object has no attribute '{}'", name))),
        (Value::Func(f), "__name__") => Ok(Value::str(&f.name)),
        // Unbound method reference (e.g. `m = x.relu`).
        (Value::Tensor(_) | Value::List(_) | Value::Str(_) | Value::Tuple(_), _) => {
            Ok(Value::BoundMethod(Rc::new((obj.clone(), name.to_string()))))
        }
        (other, _) => Err(ValueError::Msg(format!("'{}' object has no attribute '{}'", other.type_name(), name))),
    }
}

/// Resolve Python slice semantics into concrete indices.
fn slice_indices(len: i64, start: &Value, stop: &Value, step: &Value) -> Result<Vec<i64>, ValueError> {
    let step = match step {
        Value::None => 1,
        v => v.as_int()?,
    };
    if step == 0 {
        return Err("slice step cannot be zero".into());
    }
    let norm = |v: &Value, default: i64| -> Result<i64, ValueError> {
        match v {
            Value::None => Ok(default),
            other => {
                let mut i = other.as_int()?;
                if i < 0 {
                    i += len;
                }
                Ok(i)
            }
        }
    };
    let (dstart, dstop) = if step > 0 { (0, len) } else { (len - 1, -1) };
    let mut start = norm(start, dstart)?;
    let mut stop = norm(stop, dstop)?;
    if step > 0 {
        start = start.clamp(0, len);
        stop = stop.clamp(0, len);
    } else {
        start = start.clamp(-1, len - 1);
        stop = stop.clamp(-1, len - 1);
    }
    let mut idx = Vec::new();
    let mut i = start;
    while (step > 0 && i < stop) || (step < 0 && i > stop) {
        idx.push(i);
        i += step;
    }
    Ok(idx)
}

fn norm_index(len: usize, i: i64) -> Result<usize, ValueError> {
    let n = len as i64;
    let j = if i < 0 { i + n } else { i };
    if j < 0 || j >= n {
        Err(ValueError::Msg(format!("index {} out of range (len {})", i, len)))
    } else {
        Ok(j as usize)
    }
}

/// `obj[idx]`
pub fn apply_subscript(obj: &Value, idx: &Value) -> Result<Value, ValueError> {
    match obj {
        Value::List(l) => match idx {
            Value::Slice(s) => {
                let items = l.borrow();
                let picked = slice_indices(items.len() as i64, &s.0, &s.1, &s.2)?;
                Ok(Value::list(picked.into_iter().map(|i| items[i as usize].clone()).collect()))
            }
            other => {
                let i = norm_index(l.borrow().len(), other.as_int()?)?;
                Ok(l.borrow()[i].clone())
            }
        },
        Value::Tuple(t) => match idx {
            Value::Slice(s) => {
                let picked = slice_indices(t.len() as i64, &s.0, &s.1, &s.2)?;
                Ok(Value::tuple(picked.into_iter().map(|i| t[i as usize].clone()).collect()))
            }
            other => {
                let i = norm_index(t.len(), other.as_int()?)?;
                Ok(t[i].clone())
            }
        },
        Value::Str(s) => {
            let chars: Vec<char> = s.chars().collect();
            match idx {
                Value::Slice(sl) => {
                    let picked = slice_indices(chars.len() as i64, &sl.0, &sl.1, &sl.2)?;
                    Ok(Value::str(&picked.into_iter().map(|i| chars[i as usize]).collect::<String>()))
                }
                other => {
                    let i = norm_index(chars.len(), other.as_int()?)?;
                    Ok(Value::str(&chars[i].to_string()))
                }
            }
        }
        Value::Dict(d) => {
            let k = DictKey::from_value(idx)?;
            d.borrow().get(&k).cloned().ok_or_else(|| ValueError::Msg(format!("KeyError: {}", idx.repr())))
        }
        Value::Tensor(t) => {
            // Integer index along the first axis.
            let i = idx.as_int()?;
            if t.rank() == 0 {
                return Err("cannot index rank-0 tensor".into());
            }
            let rows = t.shape()[0];
            let j = norm_index(rows, i)?;
            let inner: usize = t.shape()[1..].iter().product::<usize>().max(1);
            let data = t.data()[j * inner..(j + 1) * inner].to_vec();
            Ok(Value::tensor(Tensor::new(t.shape()[1..].to_vec(), data)))
        }
        other => Err(ValueError::Msg(format!("'{}' object is not subscriptable", other.type_name()))),
    }
}

/// `obj[idx] = val`
pub fn store_subscript(obj: &Value, idx: &Value, val: Value) -> Result<(), ValueError> {
    match obj {
        Value::List(l) => {
            let i = norm_index(l.borrow().len(), idx.as_int()?)?;
            l.borrow_mut()[i] = val;
            Ok(())
        }
        Value::Dict(d) => {
            let k = DictKey::from_value(idx)?;
            d.borrow_mut().insert(k, val);
            Ok(())
        }
        other => Err(ValueError::Msg(format!("'{}' object does not support item assignment", other.type_name()))),
    }
}

/// Dispatch `recv.name(args)`.
pub fn call_method_on(_vm: &Vm, recv: &Value, name: &str, args: &[Value]) -> Result<Value, ValueError> {
    call_method_pure(recv, name, args)
}

/// Method dispatch without a VM handle (none of the built-in methods need
/// one) — used by dynamo's constant folder too.
pub fn call_method_pure(recv: &Value, name: &str, args: &[Value]) -> Result<Value, ValueError> {
    match recv {
        Value::List(l) => list_method(l, name, args),
        Value::Str(s) => str_method(s, name, args),
        Value::Dict(d) => dict_method(d, name, args),
        Value::Tuple(t) => tuple_method(t, name, args),
        Value::Tensor(t) => tensor_method(t, name, args),
        other => Err(ValueError::Msg(format!("'{}' object has no method '{}'", other.type_name(), name))),
    }
}

fn arity(args: &[Value], lo: usize, hi: usize, name: &str) -> Result<(), ValueError> {
    if args.len() < lo || args.len() > hi {
        Err(ValueError::Msg(format!("{}() takes {}..{} arguments, got {}", name, lo, hi, args.len())))
    } else {
        Ok(())
    }
}

fn list_method(l: &Rc<std::cell::RefCell<Vec<Value>>>, name: &str, args: &[Value]) -> Result<Value, ValueError> {
    match name {
        "append" => {
            arity(args, 1, 1, name)?;
            l.borrow_mut().push(args[0].clone());
            Ok(Value::None)
        }
        "extend" => {
            arity(args, 1, 1, name)?;
            match &args[0] {
                Value::List(o) => {
                    let items = o.borrow().clone();
                    l.borrow_mut().extend(items);
                }
                Value::Tuple(t) => l.borrow_mut().extend(t.iter().cloned()),
                other => return Err(ValueError::Msg(format!("extend expects list/tuple, got {}", other.type_name()))),
            }
            Ok(Value::None)
        }
        "pop" => {
            arity(args, 0, 1, name)?;
            let mut items = l.borrow_mut();
            if items.is_empty() {
                return Err("pop from empty list".into());
            }
            let i = if args.is_empty() { items.len() - 1 } else { norm_index(items.len(), args[0].as_int()?)? };
            Ok(items.remove(i))
        }
        "insert" => {
            arity(args, 2, 2, name)?;
            let mut items = l.borrow_mut();
            let i = (args[0].as_int()?).clamp(0, items.len() as i64) as usize;
            items.insert(i, args[1].clone());
            Ok(Value::None)
        }
        "index" => {
            arity(args, 1, 1, name)?;
            let items = l.borrow();
            items
                .iter()
                .position(|v| v.eq_value(&args[0]))
                .map(|i| Value::Int(i as i64))
                .ok_or_else(|| ValueError::Msg(format!("{} is not in list", args[0].repr())))
        }
        "count" => {
            arity(args, 1, 1, name)?;
            Ok(Value::Int(l.borrow().iter().filter(|v| v.eq_value(&args[0])).count() as i64))
        }
        "reverse" => {
            arity(args, 0, 0, name)?;
            l.borrow_mut().reverse();
            Ok(Value::None)
        }
        "sort" => {
            arity(args, 0, 0, name)?;
            let mut items = l.borrow_mut();
            let mut err = None;
            items.sort_by(|a, b| match a.cmp_value(b) {
                Ok(o) => o,
                Err(e) => {
                    err = Some(e);
                    std::cmp::Ordering::Equal
                }
            });
            match err {
                Some(e) => Err(e),
                None => Ok(Value::None),
            }
        }
        other => Err(ValueError::Msg(format!("'list' object has no method '{}'", other))),
    }
}

fn str_method(s: &Rc<str>, name: &str, args: &[Value]) -> Result<Value, ValueError> {
    match name {
        "upper" => Ok(Value::str(&s.to_uppercase())),
        "lower" => Ok(Value::str(&s.to_lowercase())),
        "strip" => Ok(Value::str(s.trim())),
        "startswith" => {
            arity(args, 1, 1, name)?;
            match &args[0] {
                Value::Str(p) => Ok(Value::Bool(s.starts_with(&**p))),
                other => Err(ValueError::Msg(format!("startswith expects str, got {}", other.type_name()))),
            }
        }
        "endswith" => {
            arity(args, 1, 1, name)?;
            match &args[0] {
                Value::Str(p) => Ok(Value::Bool(s.ends_with(&**p))),
                other => Err(ValueError::Msg(format!("endswith expects str, got {}", other.type_name()))),
            }
        }
        "split" => {
            let parts: Vec<Value> = match args.first() {
                None => s.split_whitespace().map(Value::str).collect(),
                Some(Value::Str(sep)) => s.split(&**sep).map(Value::str).collect(),
                Some(other) => return Err(ValueError::Msg(format!("split expects str, got {}", other.type_name()))),
            };
            Ok(Value::list(parts))
        }
        "join" => {
            arity(args, 1, 1, name)?;
            match &args[0] {
                Value::List(l) => {
                    let parts: Result<Vec<String>, ValueError> = l
                        .borrow()
                        .iter()
                        .map(|v| match v {
                            Value::Str(x) => Ok(x.to_string()),
                            other => Err(ValueError::Msg(format!("join expects strings, got {}", other.type_name()))),
                        })
                        .collect();
                    Ok(Value::str(&parts?.join(s)))
                }
                other => Err(ValueError::Msg(format!("join expects list, got {}", other.type_name()))),
            }
        }
        "replace" => {
            arity(args, 2, 2, name)?;
            match (&args[0], &args[1]) {
                (Value::Str(a), Value::Str(b)) => Ok(Value::str(&s.replace(&**a, b))),
                _ => Err("replace expects two strings".into()),
            }
        }
        other => Err(ValueError::Msg(format!("'str' object has no method '{}'", other))),
    }
}

fn dict_method(
    d: &Rc<std::cell::RefCell<std::collections::BTreeMap<DictKey, Value>>>,
    name: &str,
    args: &[Value],
) -> Result<Value, ValueError> {
    match name {
        "get" => {
            arity(args, 1, 2, name)?;
            let k = DictKey::from_value(&args[0])?;
            Ok(d.borrow().get(&k).cloned().unwrap_or_else(|| args.get(1).cloned().unwrap_or(Value::None)))
        }
        "keys" => Ok(Value::list(d.borrow().keys().map(|k| k.to_value()).collect())),
        "values" => Ok(Value::list(d.borrow().values().cloned().collect())),
        "items" => Ok(Value::list(d.borrow().iter().map(|(k, v)| Value::tuple(vec![k.to_value(), v.clone()])).collect())),
        "pop" => {
            arity(args, 1, 2, name)?;
            let k = DictKey::from_value(&args[0])?;
            match d.borrow_mut().remove(&k) {
                Some(v) => Ok(v),
                None => args.get(1).cloned().ok_or_else(|| ValueError::Msg(format!("KeyError: {}", args[0].repr()))),
            }
        }
        other => Err(ValueError::Msg(format!("'dict' object has no method '{}'", other))),
    }
}

fn tuple_method(t: &Rc<Vec<Value>>, name: &str, args: &[Value]) -> Result<Value, ValueError> {
    match name {
        "index" => {
            arity(args, 1, 1, name)?;
            t.iter()
                .position(|v| v.eq_value(&args[0]))
                .map(|i| Value::Int(i as i64))
                .ok_or_else(|| ValueError::Msg(format!("{} is not in tuple", args[0].repr())))
        }
        "count" => {
            arity(args, 1, 1, name)?;
            Ok(Value::Int(t.iter().filter(|v| v.eq_value(&args[0])).count() as i64))
        }
        other => Err(ValueError::Msg(format!("'tuple' object has no method '{}'", other))),
    }
}

fn value_to_axis(v: Option<&Value>) -> Result<Option<usize>, ValueError> {
    match v {
        None | Some(Value::None) => Ok(None),
        Some(other) => Ok(Some(other.as_int()? as usize)),
    }
}

fn int_list(v: &Value) -> Result<Vec<i64>, ValueError> {
    match v {
        Value::List(l) => l.borrow().iter().map(|x| x.as_int()).collect(),
        Value::Tuple(t) => t.iter().map(|x| x.as_int()).collect(),
        other => Err(ValueError::Msg(format!("expected list of ints, got {}", other.type_name()))),
    }
}

/// Tensor methods (`x.relu()`, `x.sum(1)`, `x.reshape([2, -1])`, ...).
pub fn tensor_method(t: &Rc<Tensor>, name: &str, args: &[Value]) -> Result<Value, ValueError> {
    let tv = |x: Tensor| Ok(Value::tensor(x));
    match name {
        "item" => {
            if t.numel() != 1 {
                return Err(ValueError::Msg(format!("item() on tensor with {} elements", t.numel())));
            }
            Ok(Value::Float(t.item() as f64))
        }
        "tolist" => {
            // 1-D only (enough for the corpus).
            Ok(Value::list(t.data().iter().map(|&v| Value::Float(v as f64)).collect()))
        }
        "numel" => Ok(Value::Int(t.numel() as i64)),
        "sum" => tv(tensor::sum(t, value_to_axis(args.first())?)?),
        "mean" => tv(tensor::mean(t, value_to_axis(args.first())?)?),
        "max" => tv(tensor::max_reduce(t, value_to_axis(args.first())?)?),
        "min" => tv(tensor::min_reduce(t, value_to_axis(args.first())?)?),
        "relu" => tv(tensor::relu(t)),
        "gelu" => tv(tensor::gelu(t)),
        "tanh" => tv(tensor::tanh(t)),
        "sigmoid" => tv(tensor::sigmoid(t)),
        "exp" => tv(tensor::exp(t)),
        "log" => tv(tensor::log(t)),
        "sqrt" => tv(tensor::sqrt(t)),
        "abs" => tv(tensor::abs(t)),
        "neg" => tv(tensor::neg(t)),
        "softmax" => tv(tensor::softmax(t)?),
        "t" => tv(tensor::transpose(t)?),
        "matmul" => {
            arity(args, 1, 1, name)?;
            tv(tensor::matmul(t, &*args[0].as_tensor()?)?)
        }
        "add" | "sub" | "mul" | "div" | "pow" | "maximum" | "minimum" => {
            arity(args, 1, 1, name)?;
            let other = match &args[0] {
                Value::Tensor(o) => (**o).clone(),
                v => Tensor::scalar(v.as_float()? as f32),
            };
            let r = match name {
                "add" => tensor::add(t, &other)?,
                "sub" => tensor::sub(t, &other)?,
                "mul" => tensor::mul(t, &other)?,
                "div" => tensor::div(t, &other)?,
                "pow" => tensor::pow(t, &other)?,
                "maximum" => tensor::maximum(t, &other)?,
                _ => tensor::minimum(t, &other)?,
            };
            tv(r)
        }
        "reshape" | "view" => {
            arity(args, 1, 1, name)?;
            let spec = int_list(&args[0])?;
            let shape = tensor::reshape_infer(t.numel(), &spec)?;
            tv(t.reshape(shape))
        }
        "permute" => {
            arity(args, 1, 1, name)?;
            let perm: Vec<usize> = int_list(&args[0])?.iter().map(|&i| i as usize).collect();
            tv(tensor::permute(t, &perm)?)
        }
        other => Err(ValueError::Msg(format!("'Tensor' object has no method '{}'", other))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_index_semantics() {
        assert_eq!(slice_indices(5, &Value::Int(1), &Value::Int(3), &Value::None).unwrap(), vec![1, 2]);
        assert_eq!(slice_indices(5, &Value::None, &Value::None, &Value::Int(2)).unwrap(), vec![0, 2, 4]);
        assert_eq!(slice_indices(5, &Value::None, &Value::None, &Value::Int(-1)).unwrap(), vec![4, 3, 2, 1, 0]);
        assert_eq!(slice_indices(5, &Value::Int(-2), &Value::None, &Value::None).unwrap(), vec![3, 4]);
        assert!(slice_indices(5, &Value::None, &Value::None, &Value::Int(0)).is_err());
    }

    #[test]
    fn tensor_attr_shape() {
        let t = Value::tensor(Tensor::zeros(&[2, 3]));
        let s = get_attr(&t, "shape").unwrap();
        assert_eq!(s.repr(), "(2, 3)");
    }

    #[test]
    fn tensor_index_row() {
        let t = Value::tensor(Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        let row = apply_subscript(&t, &Value::Int(1)).unwrap();
        match row {
            Value::Tensor(r) => assert_eq!(r.data(), &[3.0, 4.0]),
            other => panic!("{:?}", other),
        }
    }
}
