//! The `pylang` virtual machine: a stack-machine interpreter over
//! [`crate::bytecode`] with a PEP 523-style **frame-evaluation hook** — the
//! entry point dynamo uses to intercept user functions, and the mechanism
//! the paper's Figure 1 calls "the opaque box".

mod builtins;
mod interp;
mod methods;

pub use interp::{binary_op_values, compare_values as interp_compare, const_to_value as const_to_runtime, contains as interp_contains, make_iter};
pub use methods::{apply_subscript, call_method_on, call_method_pure, get_attr};

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::bytecode::CodeObject;
use crate::value::{Function, Value};

/// Runtime error with a lightweight traceback.
#[derive(Clone, Debug)]
pub struct VmError {
    pub message: String,
    /// (function name, source line) innermost last.
    pub traceback: Vec<(String, u32)>,
}

impl VmError {
    pub fn new(message: impl Into<String>) -> VmError {
        VmError { message: message.into(), traceback: Vec::new() }
    }
}

impl std::fmt::Display for VmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (name, line) in &self.traceback {
            writeln!(f, "  in {} (line {})", name, line)?;
        }
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for VmError {}

/// PEP 523 analogue: intercepts user-function frames before execution.
///
/// Returning `Some(code)` makes the VM execute `code` *instead of*
/// `func.code` (dynamo's transformed bytecode). The hook may install
/// globals (compiled graph callables, resume functions) through `globals`.
pub trait EvalHook {
    fn eval_frame(
        &self,
        func: &Rc<Function>,
        args: &[Value],
        globals: &Rc<RefCell<HashMap<String, Value>>>,
    ) -> Option<Rc<CodeObject>>;
}

/// Line-level tracer (the debugger's hook).
pub trait Tracer {
    /// Called when execution reaches a new source line of a code object
    /// that has an on-disk source file. `locals` are (name, value) pairs.
    fn on_line(&self, file: &str, line: u32, func: &str, locals: &[(String, Value)]);
}

/// The virtual machine.
pub struct Vm {
    pub globals: Rc<RefCell<HashMap<String, Value>>>,
    /// Captured `print` output (behavioural-equivalence oracle for tests).
    pub output: Rc<RefCell<String>>,
    /// Also echo print to stdout.
    pub echo: bool,
    /// Deterministic RNG shared with `torch.*` builtins.
    pub rng: Rc<RefCell<crate::tensor::Rng>>,
    /// The frame-evaluation hook (dynamo), if installed.
    pub eval_hook: Option<Rc<dyn EvalHook>>,
    /// Line tracer (debugger), if installed.
    pub tracer: Option<Rc<dyn Tracer>>,
    /// Recursion guard.
    pub max_depth: usize,
    pub(crate) depth: std::cell::Cell<usize>,
    /// Instruction budget (guards against runaway loops in fuzzed inputs).
    pub instr_budget: std::cell::Cell<u64>,
}

impl Default for Vm {
    fn default() -> Self {
        Self::new()
    }
}

impl Vm {
    pub fn new() -> Vm {
        let output = Rc::new(RefCell::new(String::new()));
        let rng = Rc::new(RefCell::new(crate::tensor::Rng::new(0)));
        let globals = Rc::new(RefCell::new(HashMap::new()));
        let vm = Vm {
            globals,
            output,
            echo: false,
            rng,
            eval_hook: None,
            tracer: None,
            // VM frames recurse on the Rust stack; keep headroom for 2 MiB
            // test-thread stacks (debug frames are large).
            max_depth: 64,
            depth: std::cell::Cell::new(0),
            instr_budget: std::cell::Cell::new(u64::MAX),
        };
        builtins::install(&vm);
        vm
    }

    /// Reset the deterministic RNG (torch.manual_seed).
    pub fn seed(&self, s: u64) {
        *self.rng.borrow_mut() = crate::tensor::Rng::new(s);
    }

    /// Take and clear captured print output.
    pub fn take_output(&self) -> String {
        std::mem::take(&mut self.output.borrow_mut())
    }

    pub fn set_global(&self, name: &str, v: Value) {
        self.globals.borrow_mut().insert(name.to_string(), v);
    }

    pub fn get_global(&self, name: &str) -> Option<Value> {
        self.globals.borrow().get(name).cloned()
    }

    /// Execute a module code object (top-level globals scope).
    pub fn run_module(&self, code: &Rc<CodeObject>) -> Result<Value, VmError> {
        interp::run_code(self, code, &[], &[], None)
    }

    /// Call any callable value with arguments.
    pub fn call(&self, callee: &Value, args: &[Value]) -> Result<Value, VmError> {
        interp::call_value(self, callee, args)
    }

    /// Compile + run a source module in one step (tests, examples).
    pub fn exec_source(&self, src: &str, version: crate::bytecode::IsaVersion) -> Result<Value, VmError> {
        let code = crate::pylang::compile_module(src, "<string>", version).map_err(|e| VmError::new(e.to_string()))?;
        self.run_module(&code)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;

    fn run(src: &str) -> String {
        let vm = Vm::new();
        vm.exec_source(src, IsaVersion::V310).unwrap_or_else(|e| panic!("{}\nsource:\n{}", e, src));
        vm.take_output()
    }

    #[test]
    fn hello_world() {
        assert_eq!(run("print('hello')\n"), "hello\n");
    }

    #[test]
    fn arithmetic_semantics() {
        assert_eq!(run("print(7 // 2, -7 // 2, 7 % 3, -7 % 3)\n"), "3 -4 1 2\n");
        assert_eq!(run("print(2 ** 10, 1 / 2)\n"), "1024 0.5\n");
    }

    #[test]
    fn control_flow() {
        assert_eq!(run("x = 3\nif x > 2:\n    print('big')\nelse:\n    print('small')\n"), "big\n");
        assert_eq!(run("t = 0\nfor i in range(5):\n    t += i\nprint(t)\n"), "10\n");
        assert_eq!(run("n = 3\nwhile n > 0:\n    n -= 1\nprint(n)\n"), "0\n");
    }

    #[test]
    fn break_continue_else() {
        assert_eq!(
            run("for i in range(5):\n    if i == 2:\n        break\nelse:\n    print('no break')\nprint(i)\n"),
            "2\n"
        );
        assert_eq!(
            run("for i in range(3):\n    pass\nelse:\n    print('done')\n"),
            "done\n"
        );
    }

    #[test]
    fn functions_and_recursion() {
        assert_eq!(run("def fib(n):\n    if n < 2:\n        return n\n    return fib(n - 1) + fib(n - 2)\nprint(fib(10))\n"), "55\n");
    }

    #[test]
    fn defaults_and_lambda() {
        assert_eq!(run("def f(a, b=10):\n    return a + b\nprint(f(1), f(1, 2))\n"), "11 3\n");
        assert_eq!(run("g = lambda x: x * 3\nprint(g(4))\n"), "12\n");
    }

    #[test]
    fn closures() {
        assert_eq!(
            run("def counter():\n    n = 0\n    def bump():\n        nonlocal n\n        n += 1\n        return n\n    return bump\nc = counter()\nc()\nc()\nprint(c())\n"),
            "3\n"
        );
    }

    #[test]
    fn collections() {
        assert_eq!(run("xs = [1, 2, 3]\nxs.append(4)\nprint(xs, len(xs), xs[0], xs[-1])\n"), "[1, 2, 3, 4] 4 1 4\n");
        assert_eq!(run("d = {'a': 1}\nd['b'] = 2\nprint(d['a'] + d['b'])\n"), "3\n");
        assert_eq!(run("t = (1, 2)\na, b = t\nprint(b, a)\n"), "2 1\n");
        assert_eq!(run("print([x * x for x in range(4) if x > 0])\n"), "[1, 4, 9]\n");
    }

    #[test]
    fn slices_and_strings() {
        assert_eq!(run("xs = [0, 1, 2, 3, 4]\nprint(xs[1:3], xs[:2], xs[::2])\n"), "[1, 2] [0, 1] [0, 2, 4]\n");
        assert_eq!(run("s = 'abc'\nprint(s + 'd', s * 2, len(s))\n"), "abcd abcabc 3\n");
    }

    #[test]
    fn chained_comparisons() {
        assert_eq!(run("x = 5\nprint(1 < x <= 5, 1 < x < 3)\n"), "True False\n");
        // middle evaluates once
        assert_eq!(run("def f():\n    print('f')\n    return 5\nprint(1 < f() < 10)\n"), "f\nTrue\n");
    }

    #[test]
    fn boolean_short_circuit() {
        assert_eq!(run("def t():\n    print('t')\n    return True\nr = False and t()\nprint(r)\n"), "False\n");
        assert_eq!(run("print(0 or 'x', 1 and 2)\n"), "x 2\n");
    }

    #[test]
    fn tensor_basics() {
        assert_eq!(run("x = torch.ones([2, 2])\ny = x + 1\nprint(y.sum().item())\n"), "8.0\n");
        assert_eq!(run("a = torch.arange(6).reshape([2, 3])\nprint(a.t().shape)\n"), "(3, 2)\n");
        assert_eq!(run("m = torch.ones([2, 3]).matmul(torch.ones([3, 4]))\nprint(m.shape, m.sum().item())\n"), "(2, 4) 24.0\n");
    }

    #[test]
    fn assert_and_raise() {
        let vm = Vm::new();
        assert!(vm.exec_source("assert 1 == 2, 'boom'\n", IsaVersion::V310).is_err());
        assert!(vm.exec_source("raise 'custom error'\n", IsaVersion::V310).is_err());
        assert!(vm.exec_source("assert 1 == 1\nprint('ok')\n", IsaVersion::V310).is_ok());
    }

    #[test]
    fn same_behaviour_across_isa_versions() {
        let src = "def f(n):\n    acc = 0\n    for i in range(n):\n        if i % 2 == 0:\n            acc += i\n        else:\n            acc -= 1\n    return acc\nprint(f(10))\n";
        let mut outs = Vec::new();
        for v in IsaVersion::ALL {
            let vm = Vm::new();
            vm.exec_source(src, v).unwrap();
            outs.push(vm.take_output());
        }
        assert!(outs.windows(2).all(|w| w[0] == w[1]), "{:?}", outs);
    }

    #[test]
    fn recursion_limit() {
        let vm = Vm::new();
        let r = vm.exec_source("def f():\n    return f()\nf()\n", IsaVersion::V310);
        assert!(r.is_err());
        assert!(r.unwrap_err().message.contains("recursion"));
    }

    #[test]
    fn enumerate_zip() {
        assert_eq!(run("for i, v in enumerate(['a', 'b']):\n    print(i, v)\n"), "0 a\n1 b\n");
        assert_eq!(run("print(zip([1, 2], [3, 4]))\n"), "[(1, 3), (2, 4)]\n");
    }

    #[test]
    fn dict_iteration_and_methods() {
        assert_eq!(run("d = {'b': 2, 'a': 1}\nfor k in d:\n    print(k)\n"), "a\nb\n");
        assert_eq!(run("d = {'x': 5}\nprint(d.get('x'), d.get('y', 0))\n"), "5 0\n");
    }

    #[test]
    fn global_statement() {
        assert_eq!(run("g = 1\ndef f():\n    global g\n    g = 5\nf()\nprint(g)\n"), "5\n");
    }
}
