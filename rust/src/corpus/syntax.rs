//! The 85-case syntax test suite — the analogue of depyf's
//! `tests/test.py` (Appendix C): one self-contained, printing program per
//! language-feature cluster. Every case must satisfy the behavioural
//! round-trip (decompile → recompile → identical output).

/// One syntax test case.
#[derive(Clone, Debug)]
pub struct SyntaxCase {
    pub id: usize,
    pub name: &'static str,
    pub source: &'static str,
}

/// All 85 cases.
pub fn syntax_cases() -> Vec<SyntaxCase> {
    let sources: Vec<(&'static str, &'static str)> = vec![
        // --- literals & basics (1-10) ---
        ("int_literals", "print(0, 42, -17)\n"),
        ("float_literals", "print(1.5, -2.25, 2e3)\n"),
        ("string_literals", "print('hello', 'a\\nb', '')\n"),
        ("bool_none", "print(True, False, None)\n"),
        ("list_literal", "print([1, 2, 3], [])\n"),
        ("tuple_literal", "print((1, 2), (5,), ())\n"),
        ("dict_literal", "print({'a': 1, 'b': 2})\n"),
        ("nested_literals", "print([[1, 2], [3, [4, 5]]], {'k': [1, (2, 3)]})\n"),
        ("guard_clause_or", "x = 0\ny = x or 5\nz = y and 'set'\nprint(y, z)\n"),
        ("for_else_break", "for i in range(9):\n    if i == 2:\n        break\nelse:\n    print('none')\nprint('end', i)\n"),
        // --- arithmetic (11-20) ---
        ("while_else_break", "n = 0\nwhile n < 10:\n    n += 1\n    if n == 4:\n        break\nelse:\n    print('no break')\nprint(n)\n"),
        ("precedence", "print(2 + 3 * 4, (2 + 3) * 4)\n"),
        ("power_operator", "print(2 ** 10, 3 ** 2 ** 2)\n"),
        ("floor_division", "print(7 // 2, -7 // 2, 9 // 3)\n"),
        ("modulo", "print(7 % 3, -7 % 3, 10 % 5)\n"),
        ("true_division", "print(7 / 2, 1 / 4)\n"),
        ("unary_ops", "x = 5\nprint(-x, +x, not x, not 0)\n"),
        ("aug_add_sub", "x = 10\nx += 5\nx -= 3\nprint(x)\n"),
        ("aug_mul_div", "x = 8\nx *= 3\nx /= 4\nprint(x)\n"),
        ("mixed_arith", "print(10 - 3 * 2 + 8 / 4)\n"),
        // --- comparisons & boolean logic (21-32) ---
        ("simple_compare", "x = 5\nprint(x < 10, x > 10, x == 5, x != 5, x <= 5, x >= 6)\n"),
        ("chained_compare_basic", "x = 5\nprint(1 < x <= 5)\nprint(1 < x < 3)\n"),
        ("chained_compare_long", "x = 5\nprint(0 <= x <= 9 <= 10)\n"),
        ("chained_compare_sideeffect", "def f():\n    print('eval once')\n    return 5\nprint(1 < f() < 10)\n"),
        ("and_value", "a = 0\nb = 7\nprint(a and b, b and a, 3 and 4)\n"),
        ("or_value", "a = 0\nb = 7\nprint(a or b, b or a, 0 or '')\n"),
        ("and_or_mixed", "a = 1\nb = 0\nc = 2\nprint(a and b or c)\nprint(b or a and c)\n"),
        ("not_combinations", "a = 1\nb = 0\nprint(not a and not b, not (a and b))\n"),
        ("short_circuit_and", "def t():\n    print('called')\n    return True\nr = False and t()\nprint(r)\n"),
        ("short_circuit_or", "def t():\n    print('called')\n    return True\nr = True or t()\nprint(r)\n"),
        ("bool_in_condition", "x = 3\ny = 4\nif x > 0 and y > 0:\n    print('both positive')\n"),
        ("default_idiom", "name = ''\nresolved = name or 'anonymous'\nprint(resolved)\n"),
        // --- is / in (33-36) ---
        ("is_none", "x = None\ny = 5\nprint(x is None, y is None, x is not None)\n"),
        ("in_list", "xs = [1, 2, 3]\nprint(2 in xs, 7 in xs, 7 not in xs)\n"),
        ("in_string_dict", "s = 'hello'\nd = {'k': 1}\nprint('ell' in s, 'k' in d, 'z' not in d)\n"),
        ("in_range", "print(3 in range(5), 7 in range(5))\n"),
        // --- conditionals (37-44) ---
        ("if_simple", "x = 5\nif x > 3:\n    print('big')\nprint('after')\n"),
        ("if_else", "x = 1\nif x > 3:\n    print('big')\nelse:\n    print('small')\n"),
        ("if_elif_else", "x = 2\nif x == 1:\n    print('one')\nelif x == 2:\n    print('two')\nelif x == 3:\n    print('three')\nelse:\n    print('many')\n"),
        ("nested_if", "x = 5\ny = 10\nif x > 0:\n    if y > 5:\n        print('both')\n    else:\n        print('x only')\n"),
        ("ternary_simple", "x = 4\nprint('even' if x % 2 == 0 else 'odd')\n"),
        ("ternary_nested", "x = 2\nprint(1 if x == 1 else 2 if x == 2 else 3)\n"),
        ("ternary_in_call", "x = 7\nprint(max(x if x > 0 else -x, 3))\n"),
        ("nested_bool_conditions", "x = 3\ny = 7\nif (x > 1 and y > 1) or x == 0:\n    print('yes')\nif x > 2 and y > 5 and x + y == 10:\n    print('sum ten')\n"),
        // --- while loops (45-50) ---
        ("while_countdown", "n = 5\nwhile n > 0:\n    n -= 1\nprint(n)\n"),
        ("flag_and_check", "a = True\nb = False\nif a and not b:\n    print('go')\nprint(a and b or not b)\n"),
        ("while_break", "n = 0\nwhile True:\n    n += 1\n    if n == 7:\n        break\nprint(n)\n"),
        ("while_continue", "n = 0\ns = 0\nwhile n < 10:\n    n += 1\n    if n > 5:\n        continue\n    s += n\nprint(s)\n"),
        ("while_else", "n = 3\nwhile n > 0:\n    n -= 1\nelse:\n    print('drained')\nprint(n)\n"),
        ("while_complex_cond", "a = 0\nb = 10\nwhile a < 5 and b > 5:\n    a += 1\n    b -= 1\nprint(a, b)\n"),
        // --- for loops (51-60) ---
        ("for_range", "t = 0\nfor i in range(5):\n    t += i\nprint(t)\n"),
        ("for_range_args", "for i in range(2, 10, 3):\n    print(i)\n"),
        ("for_list", "for x in [10, 20, 30]:\n    print(x)\n"),
        ("for_string", "for c in 'abc':\n    print(c)\n"),
        ("for_break_continue", "for i in range(10):\n    if i == 3:\n        continue\n    if i == 6:\n        break\n    print(i)\n"),
        ("for_else_nobreak", "for i in range(3):\n    print(i)\nelse:\n    print('completed')\n"),
        ("for_nested", "for i in range(3):\n    for j in range(2):\n        print(i * 10 + j)\n"),
        ("for_tuple_unpack", "for k, v in [(1, 'a'), (2, 'b')]:\n    print(k, v)\n"),
        ("for_enumerate", "for i, x in enumerate(['p', 'q']):\n    print(i, x)\n"),
        ("for_zip", "for a, b in zip([1, 2], [3, 4]):\n    print(a + b)\n"),
        // --- functions (61-70) ---
        ("func_simple", "def add(a, b):\n    return a + b\nprint(add(2, 3))\n"),
        ("func_defaults", "def greet(name, greeting='hi'):\n    return greeting + ' ' + name\nprint(greet('bob'), greet('al', 'yo'))\n"),
        ("func_recursion", "def fact(n):\n    if n <= 1:\n        return 1\n    return n * fact(n - 1)\nprint(fact(6))\n"),
        ("func_early_return", "def sign(x):\n    if x > 0:\n        return 1\n    if x < 0:\n        return -1\n    return 0\nprint(sign(5), sign(-5), sign(0))\n"),
        ("func_multiple", "def double(x):\n    return x * 2\ndef triple(x):\n    return x * 3\nprint(double(triple(2)))\n"),
        ("func_nested", "def outer():\n    x = 10\n    def inner():\n        return x + 1\n    return inner()\nprint(outer())\n"),
        ("func_closure_write", "def counter():\n    n = 0\n    def bump():\n        nonlocal n\n        n += 1\n        return n\n    return bump\nc = counter()\nc()\nprint(c())\n"),
        ("lambda_simple", "f = lambda a, b: a * b + 1\nprint(f(3, 4))\n"),
        ("lambda_in_call", "def apply(f, x):\n    return f(x)\nprint(apply(lambda v: v * v, 6))\n"),
        ("func_global", "g = 1\ndef setg():\n    global g\n    g = 99\nsetg()\nprint(g)\n"),
        // --- collections & subscripts (71-78) ---
        ("list_methods", "xs = [3, 1]\nxs.append(2)\nxs.sort()\nprint(xs, xs.pop(), xs)\n"),
        ("list_index_store", "xs = [0, 0, 0]\nxs[1] = 5\nxs[-1] = 9\nprint(xs)\n"),
        ("slices", "xs = [0, 1, 2, 3, 4, 5]\nprint(xs[1:3], xs[:2], xs[3:], xs[::2], xs[::-1])\n"),
        ("dict_ops", "d = {}\nd['a'] = 1\nd['b'] = d['a'] + 1\nprint(d, d.get('z', 0), len(d))\n"),
        ("tuple_unpack_assign", "a, b, c = 1, 2, 3\na, b = b, a\nprint(a, b, c)\n"),
        ("builtin_folds", "xs = [4, 2, 9]\nprint(len(xs), sum(xs), min(xs), max(xs), sorted(xs))\n"),
        ("str_methods", "s = ' Hello '\nprint(s.strip().upper(), s.strip().lower(), 'a,b'.split(','))\n"),
        ("aug_subscript", "d = {'n': 10}\nd['n'] += 5\nxs = [1, 2]\nxs[0] += 9\nprint(d['n'], xs)\n"),
        // --- comprehensions (79-81) ---
        ("comprehension_simple", "print([x * x for x in range(6)])\n"),
        ("comprehension_cond", "print([x for x in range(10) if x % 2 == 0])\n"),
        ("comprehension_two_conds", "print([x for x in range(20) if x % 2 == 0 if x % 3 == 0])\n"),
        // --- misc & integration (82-85) ---
        ("assert_stmt", "x = 5\nassert x == 5, 'boom'\nprint('ok')\n"),
        ("fizzbuzz", "for i in range(1, 16):\n    if i % 15 == 0:\n        print('fizzbuzz')\n    elif i % 3 == 0:\n        print('fizz')\n    elif i % 5 == 0:\n        print('buzz')\n    else:\n        print(i)\n"),
        ("gcd_euclid", "def gcd(a, b):\n    while b != 0:\n        a, b = b, a % b\n    return a\nprint(gcd(48, 36), gcd(17, 5))\n"),
        ("tensor_program", "t = torch.ones([2, 3])\nu = (t * 2 + 1).sum()\nprint(u.item())\nm = torch.arange(6).reshape([2, 3])\nprint(m.t().shape, (m @ m.t()).sum().item())\n"),
    ];
    assert_eq!(sources.len(), 85, "syntax corpus must have exactly 85 cases, has {}", sources.len());
    sources
        .into_iter()
        .enumerate()
        .map(|(i, (name, source))| SyntaxCase { id: i + 1, name, source })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;
    use crate::vm::Vm;

    #[test]
    fn exactly_85_cases_all_run() {
        let cases = syntax_cases();
        assert_eq!(cases.len(), 85);
        for c in &cases {
            let vm = Vm::new();
            vm.seed(1);
            vm.exec_source(c.source, IsaVersion::V310)
                .unwrap_or_else(|e| panic!("case {} ({}) failed to run: {}", c.id, c.name, e));
            assert!(!vm.take_output().is_empty(), "case {} ({}) printed nothing", c.id, c.name);
        }
    }

    #[test]
    fn cases_run_identically_on_all_versions() {
        for c in syntax_cases() {
            let mut outs = Vec::new();
            for v in IsaVersion::ALL {
                let vm = Vm::new();
                vm.seed(1);
                vm.exec_source(c.source, v).unwrap_or_else(|e| panic!("case {} on {}: {}", c.name, v, e));
                outs.push(vm.take_output());
            }
            assert!(outs.windows(2).all(|w| w[0] == w[1]), "case {} differs across versions", c.name);
        }
    }
}
