//! The 140-model suite — the analogue of the paper's Appendix B corpus
//! (TorchBench / HuggingFace / TIMM models). Each model is a complete
//! program: weight initialization, a `forward` (or `step`) function that
//! dynamo compiles, and a driver that calls it twice and prints results.
//!
//! Families mirror the failure surface real models exercise: pure-graph
//! models (full capture), training steps that log (print breaks),
//! data-dependent control flow (branch breaks), `.item()` escapes, helper
//! calls (user-function breaks), global state (store breaks), unrolled
//! recurrences, and multi-break pipelines.

/// One model program.
#[derive(Clone, Debug)]
pub struct ModelCase {
    pub id: usize,
    pub name: String,
    pub family: &'static str,
    pub source: String,
    /// Expected to capture without any graph break.
    pub full_capture: bool,
}

fn mlp(i: usize) -> (String, String) {
    let acts = ["relu", "tanh", "gelu", "sigmoid"];
    let act = acts[i % acts.len()];
    let d = 4 + 2 * (i % 3);
    let h = 8 + 4 * (i % 2);
    let src = format!(
        "torch.manual_seed({seed})\nW1 = torch.randn([{d}, {h}])\nb1 = torch.randn([{h}])\nW2 = torch.randn([{h}, 4])\ndef forward(x):\n    h1 = (x @ W1 + b1).{act}()\n    return (h1 @ W2).softmax()\nx = torch.randn([3, {d}])\nprint(forward(x).sum().item())\nprint(forward(x).mean().item())\n",
        seed = 100 + i,
        d = d,
        h = h,
        act = act
    );
    (format!("mlp_{}_{}", act, i), src)
}

fn attention(i: usize) -> (String, String) {
    let dk = 4 + 2 * (i % 3);
    let t = 3 + (i % 4);
    let src = format!(
        "torch.manual_seed({seed})\nWq = torch.randn([{dk}, {dk}])\nWk = torch.randn([{dk}, {dk}])\nWv = torch.randn([{dk}, {dk}])\ndef forward(x):\n    q = x @ Wq\n    k = x @ Wk\n    v = x @ Wv\n    scores = (q @ k.t()) / {scale}.0\n    att = scores.softmax()\n    return (att @ v).sum()\nx = torch.randn([{t}, {dk}])\nprint(forward(x).item())\nprint(forward(x).item())\n",
        seed = 200 + i,
        dk = dk,
        t = t,
        scale = dk
    );
    (format!("attention_d{}_{}", dk, i), src)
}

fn embed_classifier(i: usize) -> (String, String) {
    let vocab = 16 + 4 * (i % 3);
    let dim = 6 + 2 * (i % 2);
    let src = format!(
        "torch.manual_seed({seed})\nE = torch.randn([{vocab}, {dim}])\nWo = torch.randn([{dim}, 3])\ndef forward(ids):\n    emb = torch.embedding(E, ids)\n    pooled = emb.mean(0).reshape([1, {dim}])\n    return (pooled @ Wo).softmax()\nids = torch.randint({vocab}, [5])\nprint(forward(ids).sum().item())\nprint(forward(ids).max().item())\n",
        seed = 300 + i,
        vocab = vocab,
        dim = dim
    );
    (format!("embed_cls_v{}_{}", vocab, i), src)
}

fn conv_mixer(i: usize) -> (String, String) {
    // Conv-as-matmul over unfolded patches (classic im2col formulation).
    let c = 2 + (i % 2);
    let src = format!(
        "torch.manual_seed({seed})\nK = torch.randn([{c} * 4, 8])\nWo = torch.randn([8, 2])\ngamma = torch.ones([8])\nbeta = torch.zeros([8])\ndef forward(patches):\n    feats = (patches @ K).relu()\n    normed = torch.layernorm(feats, gamma, beta)\n    pooled = normed.mean(0).reshape([1, 8])\n    return pooled @ Wo\npatches = torch.randn([9, {c} * 4])\nprint(forward(patches).sum().item())\nprint(forward(patches).abs().sum().item())\n",
        seed = 400 + i,
        c = c
    );
    (format!("convmix_c{}_{}", c, i), src)
}

fn train_print(i: usize) -> (String, String) {
    let d = 4 + (i % 3);
    let classes = 3 + (i % 2);
    let src = format!(
        "torch.manual_seed({seed})\nW = torch.randn([{d}, {cls}])\ndef step(x, y):\n    logits = x @ W\n    loss = torch.cross_entropy(logits, y)\n    print('loss computed')\n    return loss + 0.0\nx = torch.randn([6, {d}])\ny = torch.randint({cls}, [6])\nprint(step(x, y).item())\nprint(step(x, y).item())\n",
        seed = 500 + i,
        d = d,
        cls = classes
    );
    (format!("train_print_{}", i), src)
}

fn branchy(i: usize) -> (String, String) {
    let d = 4 + (i % 4);
    let src = format!(
        "torch.manual_seed({seed})\nW = torch.randn([{d}, {d}])\ndef forward(x):\n    h = x @ W\n    if h.sum() >= 0:\n        h = h * 2\n    else:\n        h = h - 1\n    return h.mean()\nx = torch.randn([3, {d}])\nprint(forward(x).item())\nprint(forward(x * -1).item())\n",
        seed = 600 + i,
        d = d
    );
    (format!("branchy_{}", i), src)
}

fn item_log(i: usize) -> (String, String) {
    let d = 5 + (i % 3);
    let src = format!(
        "torch.manual_seed({seed})\nW = torch.randn([{d}, {d}])\ndef forward(x):\n    h = (x @ W).relu()\n    s = h.sum().item()\n    if s > 1000.0:\n        return h * 0\n    return h.softmax()\nx = torch.randn([2, {d}])\nprint(forward(x).sum().item())\nprint(forward(x + 1).sum().item())\n",
        seed = 700 + i,
        d = d
    );
    (format!("item_log_{}", i), src)
}

fn helper_call(i: usize) -> (String, String) {
    let d = 4 + (i % 3);
    let src = format!(
        "torch.manual_seed({seed})\nW = torch.randn([{d}, {d}])\ndef act(t):\n    return t.tanh() + 1\ndef forward(x):\n    h = x @ W\n    h = act(h)\n    return h.sum()\nx = torch.randn([3, {d}])\nprint(forward(x).item())\nprint(forward(x).item())\n",
        seed = 800 + i,
        d = d
    );
    (format!("helper_call_{}", i), src)
}

fn stateful(i: usize) -> (String, String) {
    let d = 3 + (i % 3);
    let src = format!(
        "torch.manual_seed({seed})\nW = torch.randn([{d}, {d}])\ncalls = 0\ndef forward(x):\n    global calls\n    calls = calls + 1\n    return (x @ W).sum()\nx = torch.randn([2, {d}])\nprint(forward(x).item())\nprint(forward(x).item())\nprint(calls)\n",
        seed = 900 + i,
        d = d
    );
    (format!("stateful_{}", i), src)
}

fn rnn_unrolled(i: usize) -> (String, String) {
    let d = 3 + (i % 3);
    let steps = 2 + (i % 3);
    let src = format!(
        "torch.manual_seed({seed})\nWh = torch.randn([{d}, {d}])\nWx = torch.randn([{d}, {d}])\ndef forward(x, h):\n    for t in range({steps}):\n        h = (h @ Wh + x @ Wx).tanh()\n    print('unrolled')\n    return h.sum()\nx = torch.randn([2, {d}])\nh0 = torch.zeros([2, {d}])\nprint(forward(x, h0).item())\nprint(forward(x, h0).item())\n",
        seed = 1000 + i,
        d = d,
        steps = steps
    );
    (format!("rnn_unrolled_s{}_{}", steps, i), src)
}

fn pipeline(i: usize) -> (String, String) {
    let d = 4 + (i % 2);
    let src = format!(
        "torch.manual_seed({seed})\nW1 = torch.randn([{d}, {d}])\nW2 = torch.randn([{d}, 2])\ngamma = torch.ones([{d}])\nbeta = torch.zeros([{d}])\ndef forward(x):\n    h = torch.layernorm(x @ W1, gamma, beta)\n    print('stage one done')\n    if h.mean() >= 0:\n        h = h.relu()\n    out = (h @ W2).softmax()\n    return out.sum()\nx = torch.randn([3, {d}])\nprint(forward(x).item())\nprint(forward(x * 2).item())\n",
        seed = 1100 + i,
        d = d
    );
    (format!("pipeline_{}", i), src)
}

/// The 140-model corpus.
pub fn model_cases() -> Vec<ModelCase> {
    let mut out: Vec<ModelCase> = Vec::new();
    let mut push = |family: &'static str, full: bool, n: usize, f: &dyn Fn(usize) -> (String, String)| {
        for i in 0..n {
            let (name, source) = f(i);
            out.push(ModelCase { id: 0, name, family, source, full_capture: full });
        }
    };
    // 27 fully-capturable models (the share pycdc can follow)…
    push("mlp", true, 7, &mlp);
    push("attention", true, 7, &attention);
    push("embed_cls", true, 7, &embed_classifier);
    push("convmix", true, 6, &conv_mixer);
    // …and 113 with graph breaks (program-generated resume functions).
    push("train_print", false, 17, &train_print);
    push("branchy", false, 16, &branchy);
    push("item_log", false, 16, &item_log);
    push("helper_call", false, 16, &helper_call);
    push("stateful", false, 16, &stateful);
    push("rnn_unrolled", false, 16, &rnn_unrolled);
    push("pipeline", false, 16, &pipeline);
    for (i, m) in out.iter_mut().enumerate() {
        m.id = i + 1;
    }
    assert_eq!(out.len(), 140, "model corpus must have exactly 140 cases, has {}", out.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;
    use crate::dynamo::{Dynamo, DynamoConfig};
    use crate::vm::Vm;

    #[test]
    fn exactly_140_models_all_run() {
        let cases = model_cases();
        assert_eq!(cases.len(), 140);
        // Spot-run one per family plainly.
        let mut seen = std::collections::HashSet::new();
        for c in &cases {
            if seen.insert(c.family) {
                let vm = Vm::new();
                vm.exec_source(&c.source, IsaVersion::V310)
                    .unwrap_or_else(|e| panic!("model {} failed: {}\n{}", c.name, e, c.source));
            }
        }
    }

    #[test]
    fn full_capture_flags_are_accurate() {
        // One representative per family: dynamo must agree with the flag.
        let cases = model_cases();
        let mut seen = std::collections::HashSet::new();
        for c in &cases {
            if !seen.insert(c.family) {
                continue;
            }
            let plain = Vm::new();
            plain.exec_source(&c.source, IsaVersion::V310).unwrap();
            let expected = plain.take_output();

            let mut vm = Vm::new();
            let d = Dynamo::new(DynamoConfig::default());
            vm.eval_hook = Some(d.clone());
            vm.exec_source(&c.source, IsaVersion::V310)
                .unwrap_or_else(|e| panic!("model {} under dynamo: {}\nlog: {:?}", c.name, e, d.log()));
            assert_eq!(vm.take_output(), expected, "output changed under dynamo for {}", c.name);
            let breaks = d.metrics.graph_breaks.get();
            if c.full_capture {
                assert_eq!(breaks, 0, "{} expected full capture, log: {:?}", c.name, d.log());
                assert!(d.metrics.captures.get() >= 1, "{} never captured: {:?}", c.name, d.log());
            } else {
                assert!(breaks >= 1, "{} expected graph breaks, log: {:?}", c.name, d.log());
            }
        }
    }
}
