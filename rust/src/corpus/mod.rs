//! Test corpora and the Table 1 harness: the 85-case syntax suite
//! (Appendix C analogue), the 140-model suite (Appendix B analogue), and
//! the correctness matrix runner.

pub mod models;
pub mod syntax;
pub mod table1;

pub use models::{model_cases, ModelCase};
pub use syntax::{syntax_cases, SyntaxCase};
pub use table1::{render_table1, run_model_suite, run_syntax_suite, run_table1, Cell, Table1};
