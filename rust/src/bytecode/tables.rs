//! Per-version raw opcode numbering tables.
//!
//! The numbers track real CPython closely enough that the version deltas are
//! the *same kind* that broke real decompilers: 3.9 splits `CONTAINS_OP` /
//! `IS_OP` out of `COMPARE_OP`; 3.10 reinterprets jump args as instruction
//! offsets; 3.11 removes `JUMP_ABSOLUTE`, adds `RESUME` / `PRECALL` /
//! `CACHE`, unifies arithmetic under `BINARY_OP`, and makes jumps relative.

use super::IsaVersion;

// ---- opcodes shared by all versions (numbers from CPython 3.8) ----
pub const POP_TOP: u8 = 1;
pub const ROT_TWO: u8 = 2;
pub const ROT_THREE: u8 = 3;
pub const DUP_TOP: u8 = 4;
pub const NOP: u8 = 9;
pub const UNARY_POSITIVE: u8 = 10;
pub const UNARY_NEGATIVE: u8 = 11;
pub const UNARY_NOT: u8 = 12;
pub const BINARY_MATRIX_MULTIPLY: u8 = 16;
pub const BINARY_POWER: u8 = 19;
pub const BINARY_MULTIPLY: u8 = 20;
pub const BINARY_MODULO: u8 = 22;
pub const BINARY_ADD: u8 = 23;
pub const BINARY_SUBTRACT: u8 = 24;
pub const BINARY_SUBSCR: u8 = 25;
pub const BINARY_FLOOR_DIVIDE: u8 = 26;
pub const BINARY_TRUE_DIVIDE: u8 = 27;
pub const STORE_SUBSCR: u8 = 60;
pub const GET_ITER: u8 = 68;
pub const RETURN_VALUE: u8 = 83;
pub const UNPACK_SEQUENCE: u8 = 92;
pub const FOR_ITER: u8 = 93;
pub const STORE_GLOBAL: u8 = 97;
pub const LOAD_CONST: u8 = 100;
pub const BUILD_TUPLE: u8 = 102;
pub const BUILD_LIST: u8 = 103;
pub const BUILD_MAP: u8 = 105;
pub const LOAD_ATTR: u8 = 106;
pub const COMPARE_OP: u8 = 107;
pub const JUMP_FORWARD: u8 = 110;
pub const JUMP_IF_FALSE_OR_POP: u8 = 111;
pub const JUMP_IF_TRUE_OR_POP: u8 = 112;
pub const JUMP_ABSOLUTE: u8 = 113; // absent in V311
pub const POP_JUMP_IF_FALSE: u8 = 114;
pub const POP_JUMP_IF_TRUE: u8 = 115;
pub const LOAD_GLOBAL: u8 = 116;
pub const IS_OP: u8 = 117; // V39+
pub const CONTAINS_OP: u8 = 118; // V39+
pub const LOAD_FAST: u8 = 124;
pub const STORE_FAST: u8 = 125;
pub const RAISE_VARARGS: u8 = 130;
pub const CALL_FUNCTION: u8 = 131; // pre-V311
pub const MAKE_FUNCTION: u8 = 132;
pub const BUILD_SLICE: u8 = 133;
pub const LOAD_CLOSURE: u8 = 135;
pub const LOAD_DEREF: u8 = 136;
pub const STORE_DEREF: u8 = 137;
pub const EXTENDED_ARG: u8 = 144;
pub const LIST_APPEND: u8 = 145;
pub const LOAD_METHOD: u8 = 160;
pub const CALL_METHOD: u8 = 161; // pre-V311

// ---- V311-only opcodes ----
pub const CACHE: u8 = 0;
pub const BINARY_OP_311: u8 = 122; // unified; operation in oparg
pub const JUMP_BACKWARD: u8 = 140;
pub const RESUME: u8 = 151;
pub const PRECALL: u8 = 166;
pub const CALL_311: u8 = 171;
pub const POP_JUMP_BACKWARD_IF_FALSE: u8 = 175;
pub const POP_JUMP_BACKWARD_IF_TRUE: u8 = 176;

/// `BINARY_OP` opargs for V311 (subset of `_nb_ops`).
pub const NB_ADD: u32 = 0;
pub const NB_SUB: u32 = 1;
pub const NB_MUL: u32 = 2;
pub const NB_TRUEDIV: u32 = 3;
pub const NB_FLOORDIV: u32 = 4;
pub const NB_MOD: u32 = 5;
pub const NB_POW: u32 = 6;
pub const NB_MATMUL: u32 = 7;

/// V38 `COMPARE_OP` args beyond the six orderings.
pub const CMP38_IN: u32 = 6;
pub const CMP38_NOT_IN: u32 = 7;
pub const CMP38_IS: u32 = 8;
pub const CMP38_IS_NOT: u32 = 9;

/// Number of inline CACHE units following an opcode in the V311 encoding
/// (0 for every opcode in earlier versions).
pub fn cache_slots(version: IsaVersion, opcode: u8) -> usize {
    if version != IsaVersion::V311 {
        return 0;
    }
    match opcode {
        CALL_311 | CALL_METHOD => 3,
        LOAD_METHOD => 3,
        LOAD_GLOBAL | LOAD_ATTR => 2,
        BINARY_OP_311 | COMPARE_OP => 1,
        _ => 0,
    }
}

/// Does this opcode's argument denote a jump target?
#[allow(dead_code)]
pub fn is_jump(version: IsaVersion, opcode: u8) -> bool {
    match opcode {
        JUMP_FORWARD | JUMP_IF_FALSE_OR_POP | JUMP_IF_TRUE_OR_POP | POP_JUMP_IF_FALSE | POP_JUMP_IF_TRUE | FOR_ITER => true,
        JUMP_ABSOLUTE => version != IsaVersion::V311,
        JUMP_BACKWARD | POP_JUMP_BACKWARD_IF_FALSE | POP_JUMP_BACKWARD_IF_TRUE => version == IsaVersion::V311,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_slots_only_311() {
        assert_eq!(cache_slots(IsaVersion::V38, CALL_FUNCTION), 0);
        assert_eq!(cache_slots(IsaVersion::V311, CALL_311), 3);
        assert_eq!(cache_slots(IsaVersion::V311, LOAD_GLOBAL), 2);
        assert_eq!(cache_slots(IsaVersion::V310, LOAD_GLOBAL), 0);
    }

    #[test]
    fn jump_classification() {
        assert!(is_jump(IsaVersion::V38, JUMP_ABSOLUTE));
        assert!(!is_jump(IsaVersion::V311, JUMP_ABSOLUTE));
        assert!(is_jump(IsaVersion::V311, JUMP_BACKWARD));
        assert!(!is_jump(IsaVersion::V38, LOAD_CONST));
    }
}
