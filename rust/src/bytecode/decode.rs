//! Versioned disassembler: raw bytes → abstract instruction stream.
//!
//! This is the decoder depyf-rs uses (complete over all four ISA versions).
//! The modeled baseline decompilers implement their *own* partial decoding
//! in `decompiler::baselines` — version lock-in is their failure mode, not
//! ours. `decode(encode(x)) == x` is property-tested.

use super::tables as t;
use super::{BinOp, CmpOp, Instr, IsaVersion, UnOp};

/// Decoding failures (what a decompiler reports as "unsupported input").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodeError {
    UnknownOpcode(u8),
    BadJumpTarget { from_unit: usize, to_unit: usize },
    BadCompareArg(u32),
    Truncated,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::UnknownOpcode(op) => write!(f, "unknown opcode {}", op),
            DecodeError::BadJumpTarget { from_unit, to_unit } => {
                write!(f, "jump from unit {} to non-instruction unit {}", from_unit, to_unit)
            }
            DecodeError::BadCompareArg(a) => write!(f, "bad COMPARE_OP arg {}", a),
            DecodeError::Truncated => write!(f, "truncated bytecode"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// A decoded logical instruction before jump-target resolution.
struct Decoded {
    /// Unit offset of the first unit of this instruction's block
    /// (including EXTENDED_ARG / PRECALL prefixes).
    block_start: usize,
    /// Unit offset of the opcode unit itself.
    op_unit: usize,
    opcode: u8,
    arg: u32,
}

/// Decode versioned raw bytes back into the abstract stream.
pub fn decode(raw: &[u8], version: IsaVersion) -> Result<Vec<Instr>, DecodeError> {
    if raw.len() % 2 != 0 {
        return Err(DecodeError::Truncated);
    }
    let units: Vec<(u8, u8)> = raw.chunks(2).map(|c| (c[0], c[1])).collect();
    let v311 = version == IsaVersion::V311;

    // Pass 1: gather logical instructions.
    let mut decoded: Vec<Decoded> = Vec::new();
    let mut i = 0usize;
    let mut ext: u32 = 0;
    let mut block_start: Option<usize> = None;
    while i < units.len() {
        let (op, argb) = units[i];
        if v311 && op == t::CACHE {
            // Inline cache unit (robustness: normally skipped below).
            i += 1;
            continue;
        }
        let start = *block_start.get_or_insert(i);
        let arg = (ext << 8) | argb as u32;
        match op {
            t::EXTENDED_ARG => {
                ext = arg;
                i += 1;
            }
            t::RESUME if v311 => {
                ext = 0;
                block_start = None;
                i += 1;
            }
            t::PRECALL if v311 => {
                // Redundant arity prefix of CALL / CALL_METHOD; the block
                // start stays where the PRECALL (or its ext args) began.
                ext = 0;
                i += 1;
            }
            _ => {
                decoded.push(Decoded { block_start: start, op_unit: i, opcode: op, arg });
                ext = 0;
                block_start = None;
                i += 1 + t::cache_slots(version, op);
            }
        }
    }

    // Unit offset of block start -> abstract index.
    let mut start_to_idx = std::collections::HashMap::new();
    for (idx, d) in decoded.iter().enumerate() {
        start_to_idx.insert(d.block_start, idx as u32);
    }
    // End-of-stream is a valid jump target (e.g. FOR_ITER out of a loop that
    // ends the function).
    let end_unit = units.len();
    let end_idx = decoded.len() as u32;

    // Pass 2: map opcodes to abstract instructions, resolving jump targets.
    let resolve = |d: &Decoded, target_unit: usize| -> Result<u32, DecodeError> {
        if target_unit == end_unit {
            return Ok(end_idx);
        }
        start_to_idx
            .get(&target_unit)
            .copied()
            .ok_or(DecodeError::BadJumpTarget { from_unit: d.op_unit, to_unit: target_unit })
    };
    let jump_target_unit = |d: &Decoded, relative: bool, backward: bool| -> usize {
        let next = d.op_unit + 1 + t::cache_slots(version, d.opcode);
        match version {
            IsaVersion::V38 | IsaVersion::V39 => {
                if relative {
                    next + (d.arg as usize) / 2
                } else {
                    (d.arg as usize) / 2
                }
            }
            IsaVersion::V310 => {
                if relative {
                    next + d.arg as usize
                } else {
                    d.arg as usize
                }
            }
            IsaVersion::V311 => {
                if backward {
                    next - d.arg as usize
                } else {
                    next + d.arg as usize
                }
            }
        }
    };

    let mut out = Vec::with_capacity(decoded.len());
    for d in &decoded {
        let instr = match d.opcode {
            t::POP_TOP => Instr::PopTop,
            t::ROT_TWO => Instr::RotTwo,
            t::ROT_THREE => Instr::RotThree,
            t::DUP_TOP => Instr::DupTop,
            t::NOP => Instr::Nop,
            t::UNARY_POSITIVE => Instr::Unary(UnOp::Pos),
            t::UNARY_NEGATIVE => Instr::Unary(UnOp::Neg),
            t::UNARY_NOT => Instr::Unary(UnOp::Not),
            t::BINARY_MATRIX_MULTIPLY if !v311 => Instr::Binary(BinOp::MatMul),
            t::BINARY_POWER if !v311 => Instr::Binary(BinOp::Pow),
            t::BINARY_MULTIPLY if !v311 => Instr::Binary(BinOp::Mul),
            t::BINARY_MODULO if !v311 => Instr::Binary(BinOp::Mod),
            t::BINARY_ADD if !v311 => Instr::Binary(BinOp::Add),
            t::BINARY_SUBTRACT if !v311 => Instr::Binary(BinOp::Sub),
            t::BINARY_FLOOR_DIVIDE if !v311 => Instr::Binary(BinOp::FloorDiv),
            t::BINARY_TRUE_DIVIDE if !v311 => Instr::Binary(BinOp::Div),
            t::BINARY_OP_311 if v311 => {
                let b = match d.arg {
                    t::NB_ADD => BinOp::Add,
                    t::NB_SUB => BinOp::Sub,
                    t::NB_MUL => BinOp::Mul,
                    t::NB_TRUEDIV => BinOp::Div,
                    t::NB_FLOORDIV => BinOp::FloorDiv,
                    t::NB_MOD => BinOp::Mod,
                    t::NB_POW => BinOp::Pow,
                    t::NB_MATMUL => BinOp::MatMul,
                    _ => return Err(DecodeError::BadCompareArg(d.arg)),
                };
                Instr::Binary(b)
            }
            t::BINARY_SUBSCR => Instr::BinarySubscr,
            t::STORE_SUBSCR => Instr::StoreSubscr,
            t::BUILD_SLICE => Instr::BuildSlice(d.arg),
            t::GET_ITER => Instr::GetIter,
            t::RETURN_VALUE => Instr::ReturnValue,
            t::UNPACK_SEQUENCE => Instr::UnpackSequence(d.arg),
            t::FOR_ITER => Instr::ForIter(resolve(d, jump_target_unit(d, true, false))?),
            t::STORE_GLOBAL => Instr::StoreGlobal(d.arg),
            t::LOAD_CONST => Instr::LoadConst(d.arg),
            t::BUILD_TUPLE => Instr::BuildTuple(d.arg),
            t::BUILD_LIST => Instr::BuildList(d.arg),
            t::BUILD_MAP => Instr::BuildMap(d.arg),
            t::LOAD_ATTR => Instr::LoadAttr(d.arg),
            t::COMPARE_OP => {
                if version == IsaVersion::V38 {
                    match d.arg {
                        t::CMP38_IN => Instr::ContainsOp(false),
                        t::CMP38_NOT_IN => Instr::ContainsOp(true),
                        t::CMP38_IS => Instr::IsOp(false),
                        t::CMP38_IS_NOT => Instr::IsOp(true),
                        a => Instr::Compare(CmpOp::from_index(a).ok_or(DecodeError::BadCompareArg(a))?),
                    }
                } else {
                    Instr::Compare(CmpOp::from_index(d.arg).ok_or(DecodeError::BadCompareArg(d.arg))?)
                }
            }
            t::JUMP_FORWARD => Instr::Jump(resolve(d, jump_target_unit(d, true, false))?),
            t::JUMP_IF_FALSE_OR_POP => {
                Instr::JumpIfFalseOrPop(resolve(d, jump_target_unit(d, !matches!(version, IsaVersion::V38 | IsaVersion::V39 | IsaVersion::V310), false))?)
            }
            t::JUMP_IF_TRUE_OR_POP => {
                Instr::JumpIfTrueOrPop(resolve(d, jump_target_unit(d, !matches!(version, IsaVersion::V38 | IsaVersion::V39 | IsaVersion::V310), false))?)
            }
            t::JUMP_ABSOLUTE if !v311 => Instr::Jump(resolve(d, jump_target_unit(d, false, false))?),
            t::POP_JUMP_IF_FALSE => Instr::PopJumpIfFalse(resolve(d, jump_target_unit(d, v311, false))?),
            t::POP_JUMP_IF_TRUE => Instr::PopJumpIfTrue(resolve(d, jump_target_unit(d, v311, false))?),
            t::JUMP_BACKWARD if v311 => Instr::Jump(resolve(d, jump_target_unit(d, true, true))?),
            t::POP_JUMP_BACKWARD_IF_FALSE if v311 => Instr::PopJumpIfFalse(resolve(d, jump_target_unit(d, true, true))?),
            t::POP_JUMP_BACKWARD_IF_TRUE if v311 => Instr::PopJumpIfTrue(resolve(d, jump_target_unit(d, true, true))?),
            t::LOAD_GLOBAL => Instr::LoadGlobal(d.arg),
            t::IS_OP if version != IsaVersion::V38 => Instr::IsOp(d.arg != 0),
            t::CONTAINS_OP if version != IsaVersion::V38 => Instr::ContainsOp(d.arg != 0),
            t::LOAD_FAST => Instr::LoadFast(d.arg),
            t::STORE_FAST => Instr::StoreFast(d.arg),
            t::RAISE_VARARGS => Instr::Raise,
            t::CALL_FUNCTION if !v311 => Instr::Call(d.arg),
            t::CALL_311 if v311 => Instr::Call(d.arg),
            t::MAKE_FUNCTION => Instr::MakeFunction(d.arg),
            t::LOAD_CLOSURE => Instr::LoadClosure(d.arg),
            t::LOAD_DEREF => Instr::LoadDeref(d.arg),
            t::STORE_DEREF => Instr::StoreDeref(d.arg),
            t::LIST_APPEND => Instr::ListAppend(d.arg),
            t::LOAD_METHOD => Instr::LoadMethod(d.arg),
            t::CALL_METHOD => Instr::CallMethod(d.arg),
            other => return Err(DecodeError::UnknownOpcode(other)),
        };
        out.push(instr);
    }

    // Jump targets currently index into `decoded`; those are already the
    // abstract indices, so we're done.
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::encode;
    use super::*;

    fn roundtrip(instrs: Vec<Instr>) {
        for v in IsaVersion::ALL {
            let raw = encode(&instrs, v);
            let back = decode(&raw, v).unwrap_or_else(|e| panic!("decode failed on {}: {}", v, e));
            assert_eq!(back, instrs, "roundtrip mismatch on {}", v);
        }
    }

    #[test]
    fn roundtrip_straightline() {
        roundtrip(vec![
            Instr::LoadFast(0),
            Instr::LoadConst(1),
            Instr::Binary(BinOp::Add),
            Instr::ReturnValue,
        ]);
    }

    #[test]
    fn roundtrip_branch() {
        roundtrip(vec![
            Instr::LoadFast(0),
            Instr::PopJumpIfFalse(5),
            Instr::LoadConst(0),
            Instr::StoreFast(1),
            Instr::Jump(7),
            Instr::LoadConst(1),
            Instr::StoreFast(1),
            Instr::LoadFast(1),
            Instr::ReturnValue,
        ]);
    }

    #[test]
    fn roundtrip_loop() {
        roundtrip(vec![
            Instr::LoadGlobal(0),
            Instr::LoadConst(0),
            Instr::Call(1),
            Instr::GetIter,
            Instr::ForIter(9),
            Instr::StoreFast(0),
            Instr::LoadFast(0),
            Instr::PopTop,
            Instr::Jump(4),
            Instr::LoadConst(1),
            Instr::ReturnValue,
        ]);
    }

    #[test]
    fn roundtrip_calls_and_methods() {
        roundtrip(vec![
            Instr::LoadFast(0),
            Instr::LoadMethod(0),
            Instr::LoadConst(0),
            Instr::CallMethod(1),
            Instr::LoadGlobal(1),
            Instr::LoadFast(0),
            Instr::Call(1),
            Instr::Binary(BinOp::Add),
            Instr::ReturnValue,
        ]);
    }

    #[test]
    fn roundtrip_wide_args() {
        roundtrip(vec![Instr::LoadConst(70000), Instr::LoadConst(257), Instr::Binary(BinOp::Add), Instr::ReturnValue]);
    }

    #[test]
    fn roundtrip_compare_contains_is() {
        roundtrip(vec![
            Instr::LoadFast(0),
            Instr::LoadFast(1),
            Instr::Compare(CmpOp::Le),
            Instr::LoadFast(0),
            Instr::LoadFast(1),
            Instr::ContainsOp(true),
            Instr::LoadFast(0),
            Instr::LoadConst(0),
            Instr::IsOp(false),
            Instr::BuildTuple(3),
            Instr::ReturnValue,
        ]);
    }

    #[test]
    fn roundtrip_jump_to_end() {
        roundtrip(vec![
            Instr::LoadFast(0),
            Instr::GetIter,
            Instr::ForIter(5),
            Instr::PopTop,
            Instr::Jump(2),
        ]);
    }

    #[test]
    fn unknown_opcode_rejected() {
        assert!(matches!(decode(&[200, 0], IsaVersion::V38), Err(DecodeError::UnknownOpcode(200))));
    }

    #[test]
    fn v38_contains_encoded_as_compare() {
        let raw = encode(&[Instr::LoadFast(0), Instr::LoadFast(1), Instr::ContainsOp(false), Instr::ReturnValue], IsaVersion::V38);
        // No CONTAINS_OP byte anywhere in V38 encoding.
        assert!(!raw.chunks(2).any(|c| c[0] == t::CONTAINS_OP));
        let raw39 = encode(&[Instr::LoadFast(0), Instr::LoadFast(1), Instr::ContainsOp(false), Instr::ReturnValue], IsaVersion::V39);
        assert!(raw39.chunks(2).any(|c| c[0] == t::CONTAINS_OP));
    }
}
