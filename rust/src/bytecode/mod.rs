//! Bytecode: the abstract instruction set, code objects, and four versioned
//! binary encodings modeled on CPython 3.8 / 3.9 / 3.10 / 3.11.
//!
//! Design (see DESIGN.md §6): the VM executes the **abstract** stream
//! ([`Instr`], jumps are instruction indices). Decompilers never see it —
//! they consume the **encoded bytes** (`CodeObject::raw`) and must decode
//! them per version, exactly like real decompilers consume `co_code`. The
//! version deltas replicate the CPython changes that broke real decompilers:
//!
//! * **V38**: 1-byte args + `EXTENDED_ARG`, jump args are absolute *byte*
//!   offsets, `in`/`is` folded into `COMPARE_OP`.
//! * **V39**: `CONTAINS_OP` / `IS_OP` split out of `COMPARE_OP`; opcode
//!   renumbering.
//! * **V310**: jump args become absolute *instruction* offsets (the
//!   "wordcode units" change).
//! * **V311**: all jumps relative (`JUMP_FORWARD`/`JUMP_BACKWARD`), `RESUME`
//!   prologue, `PRECALL`+`CALL` pairs, inline `CACHE` slots after selected
//!   opcodes, unified `BINARY_OP` with the operation in the oparg.

mod code;
mod decode;
mod encode;
pub(crate) mod tables;

pub use code::{CodeObject, Const, SourceInfo};
pub use decode::{decode, DecodeError};
pub use encode::encode;

use std::fmt;

/// ISA versions, mirroring the CPython versions in the paper's Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum IsaVersion {
    V38,
    V39,
    V310,
    V311,
}

impl IsaVersion {
    pub const ALL: [IsaVersion; 4] = [IsaVersion::V38, IsaVersion::V39, IsaVersion::V310, IsaVersion::V311];

    pub fn name(self) -> &'static str {
        match self {
            IsaVersion::V38 => "3.8",
            IsaVersion::V39 => "3.9",
            IsaVersion::V310 => "3.10",
            IsaVersion::V311 => "3.11",
        }
    }
}

impl fmt::Display for IsaVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Binary operators (including the inplace forms used by augmented assigns —
/// semantics are identical for our value types, but the encoding differs,
/// as in CPython).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    FloorDiv,
    Mod,
    Pow,
    MatMul,
}

impl BinOp {
    pub fn symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::FloorDiv => "//",
            BinOp::Mod => "%",
            BinOp::Pow => "**",
            BinOp::MatMul => "@",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Not,
    Pos,
}

impl UnOp {
    pub fn symbol(self) -> &'static str {
        match self {
            UnOp::Neg => "-",
            UnOp::Not => "not ",
            UnOp::Pos => "+",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Lt,
    Le,
    Eq,
    Ne,
    Gt,
    Ge,
}

impl CmpOp {
    pub fn symbol(self) -> &'static str {
        match self {
            CmpOp::Lt => "<",
            CmpOp::Le => "<=",
            CmpOp::Eq => "==",
            CmpOp::Ne => "!=",
            CmpOp::Gt => ">",
            CmpOp::Ge => ">=",
        }
    }

    pub fn from_index(i: u32) -> Option<CmpOp> {
        Some(match i {
            0 => CmpOp::Lt,
            1 => CmpOp::Le,
            2 => CmpOp::Eq,
            3 => CmpOp::Ne,
            4 => CmpOp::Gt,
            5 => CmpOp::Ge,
            _ => return None,
        })
    }

    pub fn index(self) -> u32 {
        match self {
            CmpOp::Lt => 0,
            CmpOp::Le => 1,
            CmpOp::Eq => 2,
            CmpOp::Ne => 3,
            CmpOp::Gt => 4,
            CmpOp::Ge => 5,
        }
    }
}

/// The abstract instruction set. Jump targets are indices into the abstract
/// instruction stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    // Constants & variables
    LoadConst(u32),
    LoadFast(u32),
    StoreFast(u32),
    LoadGlobal(u32),
    StoreGlobal(u32),
    LoadAttr(u32),
    LoadMethod(u32),
    // Closures
    LoadDeref(u32),
    StoreDeref(u32),
    LoadClosure(u32),
    // Subscripting
    BinarySubscr,
    StoreSubscr,
    BuildSlice(u32),
    // Stack manipulation
    PopTop,
    DupTop,
    RotTwo,
    RotThree,
    // Operators
    Binary(BinOp),
    Unary(UnOp),
    Compare(CmpOp),
    /// `in` (false) / `not in` (true)
    ContainsOp(bool),
    /// `is` (false) / `is not` (true)
    IsOp(bool),
    // Control flow
    Jump(u32),
    PopJumpIfFalse(u32),
    PopJumpIfTrue(u32),
    JumpIfFalseOrPop(u32),
    JumpIfTrueOrPop(u32),
    GetIter,
    /// Pushes next item, or jumps to target (popping the iterator) when
    /// exhausted.
    ForIter(u32),
    // Calls & functions
    Call(u32),
    CallMethod(u32),
    /// flags bit0 = has defaults tuple below code const, bit1 = has closure
    /// tuple.
    MakeFunction(u32),
    ReturnValue,
    // Builders
    BuildList(u32),
    BuildTuple(u32),
    BuildMap(u32),
    ListAppend(u32),
    UnpackSequence(u32),
    // Misc
    Raise,
    Nop,
}

impl Instr {
    /// Jump target, if this is a jumping instruction.
    pub fn jump_target(&self) -> Option<u32> {
        match self {
            Instr::Jump(t)
            | Instr::PopJumpIfFalse(t)
            | Instr::PopJumpIfTrue(t)
            | Instr::JumpIfFalseOrPop(t)
            | Instr::JumpIfTrueOrPop(t)
            | Instr::ForIter(t) => Some(*t),
            _ => None,
        }
    }

    /// Replace the jump target (no-op for non-jumps).
    pub fn with_jump_target(self, t: u32) -> Instr {
        match self {
            Instr::Jump(_) => Instr::Jump(t),
            Instr::PopJumpIfFalse(_) => Instr::PopJumpIfFalse(t),
            Instr::PopJumpIfTrue(_) => Instr::PopJumpIfTrue(t),
            Instr::JumpIfFalseOrPop(_) => Instr::JumpIfFalseOrPop(t),
            Instr::JumpIfTrueOrPop(_) => Instr::JumpIfTrueOrPop(t),
            Instr::ForIter(_) => Instr::ForIter(t),
            other => other,
        }
    }

    /// Whether control can fall through to the next instruction.
    pub fn falls_through(&self) -> bool {
        !matches!(self, Instr::Jump(_) | Instr::ReturnValue | Instr::Raise)
    }

    /// Net stack effect (pushes - pops). `MakeFunction`'s effect depends on
    /// its flags; `Call(n)` pops callee + n args and pushes 1, etc.
    pub fn stack_effect(&self) -> i32 {
        match self {
            Instr::LoadConst(_)
            | Instr::LoadFast(_)
            | Instr::LoadGlobal(_)
            | Instr::LoadDeref(_)
            | Instr::LoadClosure(_)
            | Instr::DupTop => 1,
            Instr::StoreFast(_) | Instr::StoreGlobal(_) | Instr::StoreDeref(_) | Instr::PopTop | Instr::ReturnValue | Instr::Raise => -1,
            Instr::LoadAttr(_) | Instr::LoadMethod(_) | Instr::GetIter | Instr::Unary(_) | Instr::Nop | Instr::RotTwo | Instr::RotThree | Instr::Jump(_) => 0,
            Instr::BinarySubscr | Instr::Binary(_) | Instr::Compare(_) | Instr::ContainsOp(_) | Instr::IsOp(_) => -1,
            Instr::StoreSubscr => -3,
            Instr::BuildSlice(n) => 1 - *n as i32,
            Instr::PopJumpIfFalse(_) | Instr::PopJumpIfTrue(_) => -1,
            // Conditional: -1 on the popping path, 0 when it jumps. Callers
            // that need exact depths handle these specially.
            Instr::JumpIfFalseOrPop(_) | Instr::JumpIfTrueOrPop(_) => 0,
            Instr::ForIter(_) => 1,
            Instr::Call(n) => -(*n as i32),
            Instr::CallMethod(n) => -(*n as i32),
            Instr::MakeFunction(flags) => {
                // pops code (+defaults) (+closure), pushes function
                let mut pops = 1;
                if flags & 1 != 0 {
                    pops += 1;
                }
                if flags & 2 != 0 {
                    pops += 1;
                }
                1 - pops
            }
            Instr::BuildList(n) | Instr::BuildTuple(n) => 1 - *n as i32,
            Instr::BuildMap(n) => 1 - 2 * *n as i32,
            Instr::ListAppend(_) => -1,
            Instr::UnpackSequence(n) => *n as i32 - 1,
        }
    }
}

/// Render one abstract instruction like `dis` output.
pub fn format_instr(i: usize, instr: &Instr, code: &CodeObject) -> String {
    let name_of = |idx: &u32| code.names.get(*idx as usize).cloned().unwrap_or_else(|| format!("<name {}>", idx));
    let var_of = |idx: &u32| code.varnames.get(*idx as usize).cloned().unwrap_or_else(|| format!("<var {}>", idx));
    let free_of = |idx: &u32| code.cell_and_free_name(*idx as usize);
    let body = match instr {
        Instr::LoadConst(c) => format!("LOAD_CONST           {} ({})", c, code.consts.get(*c as usize).map(|v| v.repr()).unwrap_or_default()),
        Instr::LoadFast(v) => format!("LOAD_FAST            {} ({})", v, var_of(v)),
        Instr::StoreFast(v) => format!("STORE_FAST           {} ({})", v, var_of(v)),
        Instr::LoadGlobal(n) => format!("LOAD_GLOBAL          {} ({})", n, name_of(n)),
        Instr::StoreGlobal(n) => format!("STORE_GLOBAL         {} ({})", n, name_of(n)),
        Instr::LoadAttr(n) => format!("LOAD_ATTR            {} ({})", n, name_of(n)),
        Instr::LoadMethod(n) => format!("LOAD_METHOD          {} ({})", n, name_of(n)),
        Instr::LoadDeref(n) => format!("LOAD_DEREF           {} ({})", n, free_of(n)),
        Instr::StoreDeref(n) => format!("STORE_DEREF          {} ({})", n, free_of(n)),
        Instr::LoadClosure(n) => format!("LOAD_CLOSURE         {} ({})", n, free_of(n)),
        Instr::BinarySubscr => "BINARY_SUBSCR".into(),
        Instr::StoreSubscr => "STORE_SUBSCR".into(),
        Instr::BuildSlice(n) => format!("BUILD_SLICE          {}", n),
        Instr::PopTop => "POP_TOP".into(),
        Instr::DupTop => "DUP_TOP".into(),
        Instr::RotTwo => "ROT_TWO".into(),
        Instr::RotThree => "ROT_THREE".into(),
        Instr::Binary(op) => format!("BINARY_OP            ({})", op.symbol()),
        Instr::Unary(op) => format!("UNARY_OP             ({})", op.symbol().trim()),
        Instr::Compare(op) => format!("COMPARE_OP           ({})", op.symbol()),
        Instr::ContainsOp(inv) => format!("CONTAINS_OP          {}", if *inv { "(not in)" } else { "(in)" }),
        Instr::IsOp(inv) => format!("IS_OP                {}", if *inv { "(is not)" } else { "(is)" }),
        Instr::Jump(t) => format!("JUMP                 -> {}", t),
        Instr::PopJumpIfFalse(t) => format!("POP_JUMP_IF_FALSE    -> {}", t),
        Instr::PopJumpIfTrue(t) => format!("POP_JUMP_IF_TRUE     -> {}", t),
        Instr::JumpIfFalseOrPop(t) => format!("JUMP_IF_FALSE_OR_POP -> {}", t),
        Instr::JumpIfTrueOrPop(t) => format!("JUMP_IF_TRUE_OR_POP  -> {}", t),
        Instr::GetIter => "GET_ITER".into(),
        Instr::ForIter(t) => format!("FOR_ITER             -> {}", t),
        Instr::Call(n) => format!("CALL                 {}", n),
        Instr::CallMethod(n) => format!("CALL_METHOD          {}", n),
        Instr::MakeFunction(f) => format!("MAKE_FUNCTION        {}", f),
        Instr::ReturnValue => "RETURN_VALUE".into(),
        Instr::BuildList(n) => format!("BUILD_LIST           {}", n),
        Instr::BuildTuple(n) => format!("BUILD_TUPLE          {}", n),
        Instr::BuildMap(n) => format!("BUILD_MAP            {}", n),
        Instr::ListAppend(n) => format!("LIST_APPEND          {}", n),
        Instr::UnpackSequence(n) => format!("UNPACK_SEQUENCE      {}", n),
        Instr::Raise => "RAISE_VARARGS        1".into(),
        Instr::Nop => "NOP".into(),
    };
    format!("{:>4}  {}", i, body)
}

/// Disassemble a whole code object (recursively lists nested code consts).
pub fn disassemble(code: &CodeObject) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Disassembly of <code {}> (version {}, argcount {}, {} instrs, {} raw bytes{})\n",
        code.name,
        code.version,
        code.argcount,
        code.instrs.len(),
        code.raw.len(),
        if code.generated { ", program-generated" } else { "" }
    ));
    for (i, instr) in code.instrs.iter().enumerate() {
        out.push_str(&format_instr(i, instr, code));
        out.push('\n');
    }
    for c in &code.consts {
        if let Const::Code(inner) = c {
            out.push('\n');
            out.push_str(&disassemble(inner));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_effects() {
        assert_eq!(Instr::LoadConst(0).stack_effect(), 1);
        assert_eq!(Instr::Call(2).stack_effect(), -2);
        assert_eq!(Instr::BuildMap(2).stack_effect(), -3);
        assert_eq!(Instr::UnpackSequence(3).stack_effect(), 2);
        assert_eq!(Instr::MakeFunction(3).stack_effect(), -2);
    }

    #[test]
    fn jump_target_roundtrip() {
        let j = Instr::PopJumpIfFalse(10);
        assert_eq!(j.jump_target(), Some(10));
        assert_eq!(j.with_jump_target(3).jump_target(), Some(3));
        assert_eq!(Instr::PopTop.jump_target(), None);
    }

    #[test]
    fn falls_through() {
        assert!(!Instr::Jump(0).falls_through());
        assert!(!Instr::ReturnValue.falls_through());
        assert!(Instr::PopJumpIfFalse(0).falls_through());
    }
}
