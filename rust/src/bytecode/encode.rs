//! Versioned assembler: abstract instruction stream → raw bytes.
//!
//! Raw format (all versions): a sequence of 2-byte units `(opcode, arg)`.
//! Args wider than one byte are carried by `EXTENDED_ARG` prefix units.
//! Jump-arg semantics and auxiliary units (RESUME / PRECALL / CACHE) differ
//! per version — see `tables.rs`.

use super::tables as t;
use super::{BinOp, Instr, IsaVersion, UnOp};

/// One raw unit before byte emission.
#[derive(Clone, Copy, Debug)]
struct RawOp {
    opcode: u8,
    arg: u32,
}

/// How many EXTENDED_ARG prefix units an arg needs.
fn ext_count(arg: u32) -> usize {
    match arg {
        0..=0xFF => 0,
        0x100..=0xFFFF => 1,
        0x1_0000..=0xFF_FFFF => 2,
        _ => 3,
    }
}

/// Units occupied by one raw op: EXTENDED_ARGs + the op + its caches.
fn op_units(version: IsaVersion, op: RawOp) -> usize {
    ext_count(op.arg) + 1 + t::cache_slots(version, op.opcode)
}

/// The raw ops for one abstract instruction, with jump args left as 0
/// (filled during layout). Returns (ops, jump_op_index_within_ops).
fn lower_instr(instr: &Instr, version: IsaVersion) -> (Vec<RawOp>, Option<usize>) {
    let v311 = version == IsaVersion::V311;
    let op = |opcode: u8, arg: u32| RawOp { opcode, arg };
    match instr {
        Instr::LoadConst(a) => (vec![op(t::LOAD_CONST, *a)], None),
        Instr::LoadFast(a) => (vec![op(t::LOAD_FAST, *a)], None),
        Instr::StoreFast(a) => (vec![op(t::STORE_FAST, *a)], None),
        Instr::LoadGlobal(a) => (vec![op(t::LOAD_GLOBAL, *a)], None),
        Instr::StoreGlobal(a) => (vec![op(t::STORE_GLOBAL, *a)], None),
        Instr::LoadAttr(a) => (vec![op(t::LOAD_ATTR, *a)], None),
        Instr::LoadMethod(a) => (vec![op(t::LOAD_METHOD, *a)], None),
        Instr::LoadDeref(a) => (vec![op(t::LOAD_DEREF, *a)], None),
        Instr::StoreDeref(a) => (vec![op(t::STORE_DEREF, *a)], None),
        Instr::LoadClosure(a) => (vec![op(t::LOAD_CLOSURE, *a)], None),
        Instr::BinarySubscr => (vec![op(t::BINARY_SUBSCR, 0)], None),
        Instr::StoreSubscr => (vec![op(t::STORE_SUBSCR, 0)], None),
        Instr::BuildSlice(n) => (vec![op(t::BUILD_SLICE, *n)], None),
        Instr::PopTop => (vec![op(t::POP_TOP, 0)], None),
        Instr::DupTop => (vec![op(t::DUP_TOP, 0)], None),
        Instr::RotTwo => (vec![op(t::ROT_TWO, 0)], None),
        Instr::RotThree => (vec![op(t::ROT_THREE, 0)], None),
        Instr::Binary(b) => {
            if v311 {
                let nb = match b {
                    BinOp::Add => t::NB_ADD,
                    BinOp::Sub => t::NB_SUB,
                    BinOp::Mul => t::NB_MUL,
                    BinOp::Div => t::NB_TRUEDIV,
                    BinOp::FloorDiv => t::NB_FLOORDIV,
                    BinOp::Mod => t::NB_MOD,
                    BinOp::Pow => t::NB_POW,
                    BinOp::MatMul => t::NB_MATMUL,
                };
                (vec![op(t::BINARY_OP_311, nb)], None)
            } else {
                let opcode = match b {
                    BinOp::Add => t::BINARY_ADD,
                    BinOp::Sub => t::BINARY_SUBTRACT,
                    BinOp::Mul => t::BINARY_MULTIPLY,
                    BinOp::Div => t::BINARY_TRUE_DIVIDE,
                    BinOp::FloorDiv => t::BINARY_FLOOR_DIVIDE,
                    BinOp::Mod => t::BINARY_MODULO,
                    BinOp::Pow => t::BINARY_POWER,
                    BinOp::MatMul => t::BINARY_MATRIX_MULTIPLY,
                };
                (vec![op(opcode, 0)], None)
            }
        }
        Instr::Unary(u) => {
            let opcode = match u {
                UnOp::Neg => t::UNARY_NEGATIVE,
                UnOp::Not => t::UNARY_NOT,
                UnOp::Pos => t::UNARY_POSITIVE,
            };
            (vec![op(opcode, 0)], None)
        }
        Instr::Compare(c) => (vec![op(t::COMPARE_OP, c.index())], None),
        Instr::ContainsOp(invert) => {
            if version == IsaVersion::V38 {
                (vec![op(t::COMPARE_OP, if *invert { t::CMP38_NOT_IN } else { t::CMP38_IN })], None)
            } else {
                (vec![op(t::CONTAINS_OP, *invert as u32)], None)
            }
        }
        Instr::IsOp(invert) => {
            if version == IsaVersion::V38 {
                (vec![op(t::COMPARE_OP, if *invert { t::CMP38_IS_NOT } else { t::CMP38_IS })], None)
            } else {
                (vec![op(t::IS_OP, *invert as u32)], None)
            }
        }
        // Jump opcodes are chosen during layout (direction matters on V311);
        // use a placeholder opcode here.
        Instr::Jump(_) => (vec![op(if v311 { t::JUMP_FORWARD } else { t::JUMP_ABSOLUTE }, 0)], Some(0)),
        Instr::PopJumpIfFalse(_) => (vec![op(t::POP_JUMP_IF_FALSE, 0)], Some(0)),
        Instr::PopJumpIfTrue(_) => (vec![op(t::POP_JUMP_IF_TRUE, 0)], Some(0)),
        Instr::JumpIfFalseOrPop(_) => (vec![op(t::JUMP_IF_FALSE_OR_POP, 0)], Some(0)),
        Instr::JumpIfTrueOrPop(_) => (vec![op(t::JUMP_IF_TRUE_OR_POP, 0)], Some(0)),
        Instr::GetIter => (vec![op(t::GET_ITER, 0)], None),
        Instr::ForIter(_) => (vec![op(t::FOR_ITER, 0)], Some(0)),
        Instr::Call(n) => {
            if v311 {
                (vec![op(t::PRECALL, *n), op(t::CALL_311, *n)], None)
            } else {
                (vec![op(t::CALL_FUNCTION, *n)], None)
            }
        }
        Instr::CallMethod(n) => {
            if v311 {
                (vec![op(t::PRECALL, *n), op(t::CALL_METHOD, *n)], None)
            } else {
                (vec![op(t::CALL_METHOD, *n)], None)
            }
        }
        Instr::MakeFunction(f) => (vec![op(t::MAKE_FUNCTION, *f)], None),
        Instr::ReturnValue => (vec![op(t::RETURN_VALUE, 0)], None),
        Instr::BuildList(n) => (vec![op(t::BUILD_LIST, *n)], None),
        Instr::BuildTuple(n) => (vec![op(t::BUILD_TUPLE, *n)], None),
        Instr::BuildMap(n) => (vec![op(t::BUILD_MAP, *n)], None),
        Instr::ListAppend(n) => (vec![op(t::LIST_APPEND, *n)], None),
        Instr::UnpackSequence(n) => (vec![op(t::UNPACK_SEQUENCE, *n)], None),
        Instr::Raise => (vec![op(t::RAISE_VARARGS, 1)], None),
        Instr::Nop => (vec![op(t::NOP, 0)], None),
    }
}

/// Assemble the abstract stream into the versioned binary encoding.
pub fn encode(instrs: &[Instr], version: IsaVersion) -> Vec<u8> {
    let v311 = version == IsaVersion::V311;
    // Lower every abstract instruction once; jump args patched per layout pass.
    let mut lowered: Vec<(Vec<RawOp>, Option<usize>)> = instrs.iter().map(|i| lower_instr(i, version)).collect();
    let base: usize = if v311 { 1 } else { 0 }; // RESUME prologue unit

    // Fixpoint layout: unit offset of each abstract instruction's block.
    let mut offsets = vec![0usize; instrs.len() + 1];
    for _round in 0..16 {
        // 1. offsets from current arg widths
        let mut off = base;
        for (i, (ops, _)) in lowered.iter().enumerate() {
            offsets[i] = off;
            off += ops.iter().map(|&o| op_units(version, o)).sum::<usize>();
        }
        offsets[instrs.len()] = off;

        // 2. recompute jump args + opcode direction
        let mut changed = false;
        for (i, instr) in instrs.iter().enumerate() {
            let Some(target) = instr.jump_target() else { continue };
            let (ops, jslot) = &mut lowered[i];
            let j = jslot.expect("jump instr must have a jump slot");
            // Unit index of the jump opcode itself (after any ext prefixes
            // of preceding ops in this block and its own ext prefix).
            let mut jump_unit = offsets[i];
            for (k, o) in ops.iter().enumerate() {
                if k == j {
                    jump_unit += ext_count(o.arg);
                    break;
                }
                jump_unit += op_units(version, *o);
            }
            let next_unit = jump_unit + 1 + t::cache_slots(version, ops[j].opcode);
            let target_unit = offsets[target as usize];
            let (new_opcode, new_arg): (u8, u32) = match version {
                IsaVersion::V38 | IsaVersion::V39 => match ops[j].opcode {
                    // Relative jumps measured in bytes from the next unit.
                    t::JUMP_FORWARD | t::FOR_ITER => (ops[j].opcode, ((target_unit - next_unit) * 2) as u32),
                    // Absolute jumps measured in byte offsets.
                    _ => (ops[j].opcode, (target_unit * 2) as u32),
                },
                IsaVersion::V310 => match ops[j].opcode {
                    // Same split, but args are unit offsets.
                    t::JUMP_FORWARD | t::FOR_ITER => (ops[j].opcode, (target_unit - next_unit) as u32),
                    _ => (ops[j].opcode, target_unit as u32),
                },
                IsaVersion::V311 => {
                    // All jumps relative; backward variants where needed.
                    if target_unit >= next_unit {
                        let fwd = (target_unit - next_unit) as u32;
                        let opc = match instrs[i] {
                            Instr::Jump(_) => t::JUMP_FORWARD,
                            Instr::PopJumpIfFalse(_) => t::POP_JUMP_IF_FALSE,
                            Instr::PopJumpIfTrue(_) => t::POP_JUMP_IF_TRUE,
                            Instr::JumpIfFalseOrPop(_) => t::JUMP_IF_FALSE_OR_POP,
                            Instr::JumpIfTrueOrPop(_) => t::JUMP_IF_TRUE_OR_POP,
                            Instr::ForIter(_) => t::FOR_ITER,
                            _ => unreachable!(),
                        };
                        (opc, fwd)
                    } else {
                        let bwd = (next_unit - target_unit) as u32;
                        let opc = match instrs[i] {
                            Instr::Jump(_) => t::JUMP_BACKWARD,
                            Instr::PopJumpIfFalse(_) => t::POP_JUMP_BACKWARD_IF_FALSE,
                            Instr::PopJumpIfTrue(_) => t::POP_JUMP_BACKWARD_IF_TRUE,
                            other => panic!("unsupported backward jump {:?} in V311 encoding", other),
                        };
                        (opc, bwd)
                    }
                }
            };
            if ops[j].opcode != new_opcode || ops[j].arg != new_arg {
                ops[j].opcode = new_opcode;
                ops[j].arg = new_arg;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    // 3. emit bytes
    let mut out: Vec<u8> = Vec::new();
    if v311 {
        out.push(t::RESUME);
        out.push(0);
    }
    for (ops, _) in &lowered {
        for o in ops {
            let n_ext = ext_count(o.arg);
            for k in (1..=n_ext).rev() {
                out.push(t::EXTENDED_ARG);
                out.push(((o.arg >> (8 * k)) & 0xFF) as u8);
            }
            out.push(o.opcode);
            out.push((o.arg & 0xFF) as u8);
            for _ in 0..t::cache_slots(version, o.opcode) {
                out.push(t::CACHE);
                out.push(0);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_encode_v38() {
        let instrs = vec![Instr::LoadFast(0), Instr::ReturnValue];
        let raw = encode(&instrs, IsaVersion::V38);
        assert_eq!(raw, vec![t::LOAD_FAST, 0, t::RETURN_VALUE, 0]);
    }

    #[test]
    fn v311_has_resume_and_caches() {
        let instrs = vec![Instr::LoadGlobal(0), Instr::Call(0), Instr::ReturnValue];
        let raw = encode(&instrs, IsaVersion::V311);
        assert_eq!(raw[0], t::RESUME);
        // RESUME, LOAD_GLOBAL + 2 caches, PRECALL, CALL + 3 caches, RETURN
        let units = raw.len() / 2;
        assert_eq!(units, 1 + 3 + 1 + 4 + 1);
    }

    #[test]
    fn extended_arg_emitted() {
        let instrs = vec![Instr::LoadConst(300), Instr::ReturnValue];
        let raw = encode(&instrs, IsaVersion::V38);
        assert_eq!(raw[0], t::EXTENDED_ARG);
        assert_eq!(raw[1], 1);
        assert_eq!(raw[2], t::LOAD_CONST);
        assert_eq!(raw[3], 44); // 300 = 0x12C
    }

    #[test]
    fn jump_args_differ_across_versions() {
        // 0: load 1: pjif->3 2: load 3: return
        let instrs = vec![
            Instr::LoadFast(0),
            Instr::PopJumpIfFalse(3),
            Instr::LoadFast(0),
            Instr::ReturnValue,
        ];
        let v38 = encode(&instrs, IsaVersion::V38);
        let v310 = encode(&instrs, IsaVersion::V310);
        // V38 arg = byte offset (unit 3 -> byte 6); V310 arg = unit 3.
        assert_eq!(v38[3], 6);
        assert_eq!(v310[3], 3);
    }

    #[test]
    fn v311_backward_jump() {
        // while-true style: 0: nop 1: jump->0
        let instrs = vec![Instr::Nop, Instr::Jump(0)];
        let raw = encode(&instrs, IsaVersion::V311);
        // RESUME, NOP, JUMP_BACKWARD
        assert_eq!(raw[4], t::JUMP_BACKWARD);
        assert_eq!(raw[5], 2); // next_unit(3) - target_unit(1)
    }
}
