//! The decompilation engine: symbolic execution of raw bytecode over an
//! expression stack, with structural reconstruction of loops, branches,
//! bool-ops, ternaries, chained comparisons and comprehensions.
//!
//! Works from `CodeObject::raw` (the versioned byte encoding), never from
//! the in-memory instruction stream — exactly the position a real
//! decompiler is in.

use std::rc::Rc;

use super::DecompilerOptions;
use crate::bytecode::{decode, BinOp, CodeObject, Const, Instr, IsaVersion, UnOp};
use crate::pylang::ast::*;

#[derive(Clone, Debug)]
pub struct DecompileError(pub String);

impl std::fmt::Display for DecompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "decompile error: {}", self.0)
    }
}

impl std::error::Error for DecompileError {}

fn err<T>(m: impl Into<String>) -> Result<T, DecompileError> {
    Err(DecompileError(m.into()))
}

/// Stack items: expressions, plus code objects awaiting MAKE_FUNCTION.
#[derive(Clone, Debug)]
enum Item {
    E(Expr),
    Code(Rc<CodeObject>),
}

impl Item {
    fn expr(self) -> Result<Expr, DecompileError> {
        match self {
            Item::E(e) => Ok(e),
            Item::Code(c) => err(format!("raw code object <{}> on stack", c.name)),
        }
    }
}

#[derive(Clone)]
struct LoopEnv {
    /// Continue target (while-cond start or FOR_ITER position).
    header: usize,
    /// First instruction after the loop body (the loop's exit-test target).
    exit: usize,
    is_for: bool,
}

struct Engine<'a> {
    code: &'a CodeObject,
    instrs: Vec<Instr>,
    opts: &'a DecompilerOptions,
    /// Names needing `global` declarations (function scope stores).
    global_decls: std::cell::RefCell<Vec<String>>,
    /// Names needing `nonlocal` declarations (freevar stores).
    nonlocal_decls: std::cell::RefCell<Vec<String>>,
    is_module: bool,
    /// Positions of backward `Jump`s, by target (precomputed once; the
    /// per-statement scan was the decompiler's hot spot — see
    /// EXPERIMENTS.md §Perf).
    back_jumps: std::collections::HashMap<usize, Vec<usize>>,
}

/// Decompile one code object into a statement list.
pub fn decompile_code_to_stmts(code: &Rc<CodeObject>, opts: &DecompilerOptions) -> Result<Vec<Stmt>, DecompileError> {
    if let Some(vs) = &opts.versions {
        if !vs.contains(&code.version) {
            return err(format!("unsupported bytecode version {}", code.version));
        }
    }
    let instrs = decode(&code.raw, code.version).map_err(|e| DecompileError(format!("decode: {}", e)))?;
    if code.version == IsaVersion::V311 && !opts.v311_full_binary {
        // Models pycdc's partial 3.11 BINARY_OP support.
        for i in &instrs {
            if matches!(i, Instr::Binary(BinOp::Pow | BinOp::MatMul | BinOp::FloorDiv | BinOp::Mod)) {
                return err("unhandled BINARY_OP oparg on 3.11");
            }
        }
    }
    let is_module = code.name == "<module>";
    let mut back_jumps: std::collections::HashMap<usize, Vec<usize>> = std::collections::HashMap::new();
    for (j, ins) in instrs.iter().enumerate() {
        if let Instr::Jump(t) = ins {
            if (*t as usize) <= j {
                back_jumps.entry(*t as usize).or_default().push(j);
            }
        }
    }
    let eng = Engine {
        code,
        instrs,
        opts,
        global_decls: Default::default(),
        nonlocal_decls: Default::default(),
        is_module,
        back_jumps,
    };

    // Program-generated entry prologue (resume functions): leading
    // LOAD_FASTs followed by a forward JUMP into the body.
    let mut stack: Vec<Item> = Vec::new();
    let mut start = 0usize;
    {
        let mut k = 0;
        while matches!(eng.instrs.get(k), Some(Instr::LoadFast(_))) {
            k += 1;
        }
        if let Some(Instr::Jump(t)) = eng.instrs.get(k) {
            let t = *t as usize;
            if t > k + 1 {
                if !opts.jump_entry {
                    return err("program-generated entry jump (resume function) not supported");
                }
                for i in 0..k {
                    let Instr::LoadFast(slot) = eng.instrs[i] else { unreachable!() };
                    stack.push(Item::E(Expr::Name(eng.varname(slot))));
                }
                start = t;
            }
        }
    }

    let mut stmts = eng.block(start, eng.instrs.len(), &mut stack, None)?;
    if !stack.is_empty() {
        return err(format!("{} values left on stack", stack.len()));
    }
    // Drop the trailing implicit `return None`.
    if let Some(Stmt { kind: StmtKind::Return(v), .. }) = stmts.last() {
        let implicit = matches!(v, None | Some(Expr::NoneLit));
        if implicit && (is_module || code.argcount > 0 || true) {
            // Only drop when it is the compiler's epilogue (last two raw
            // instructions LOAD_CONST None; RETURN_VALUE).
            let n = eng.instrs.len();
            if n >= 2 && matches!(eng.instrs[n - 1], Instr::ReturnValue) {
                if let Instr::LoadConst(c) = eng.instrs[n - 2] {
                    if matches!(eng.code.consts.get(c as usize), Some(Const::None)) {
                        stmts.pop();
                    }
                }
            }
        }
    }
    // Prepend scope declarations.
    let mut out = Vec::new();
    let nl = eng.nonlocal_decls.borrow();
    if !nl.is_empty() {
        out.push(Stmt::new(StmtKind::Nonlocal(nl.clone()), 0));
    }
    let gl = eng.global_decls.borrow();
    if !gl.is_empty() {
        out.push(Stmt::new(StmtKind::Global(gl.clone()), 0));
    }
    out.extend(stmts);
    if out.is_empty() {
        out.push(Stmt::new(StmtKind::Pass, 0));
    }
    Ok(out)
}

impl<'a> Engine<'a> {
    fn varname(&self, i: u32) -> String {
        self.code.varnames.get(i as usize).cloned().unwrap_or_else(|| format!("__v{}", i))
    }

    fn name(&self, i: u32) -> Result<String, DecompileError> {
        self.code.names.get(i as usize).cloned().ok_or_else(|| DecompileError(format!("bad name index {}", i)))
    }

    fn deref_name(&self, i: u32) -> String {
        self.code.cell_and_free_name(i as usize)
    }

    fn const_expr(&self, i: u32) -> Result<Item, DecompileError> {
        match self.code.consts.get(i as usize) {
            Some(Const::None) => Ok(Item::E(Expr::NoneLit)),
            Some(Const::Bool(b)) => Ok(Item::E(Expr::Bool(*b))),
            Some(Const::Int(v)) => Ok(Item::E(Expr::Int(*v))),
            Some(Const::Float(f)) => Ok(Item::E(Expr::Float(*f))),
            Some(Const::Str(s)) => Ok(Item::E(Expr::Str(s.clone()))),
            Some(Const::Code(c)) => Ok(Item::Code(Rc::clone(c))),
            None => err(format!("bad const index {}", i)),
        }
    }

    /// Innermost loop starting exactly at `ip` (a backward jump in
    /// [ip+1, end) targets ip). Returns the backward-jump position
    /// (outermost / furthest wins).
    fn backjump_to(&self, ip: usize, end: usize) -> Option<usize> {
        let end = end.min(self.instrs.len());
        self.back_jumps.get(&ip)?.iter().copied().filter(|&j| j > ip && j < end).max()
    }

    /// Evaluate a pure expression range: no statements may be produced.
    fn expr_range(&self, start: usize, end: usize) -> Result<Expr, DecompileError> {
        let mut stack = Vec::new();
        let stmts = self.block(start, end, &mut stack, None)?;
        if !stmts.is_empty() {
            return err("expected expression, found statements");
        }
        if stack.len() != 1 {
            return err(format!("expression range left {} values", stack.len()));
        }
        stack.pop().unwrap().expr()
    }

    /// Decompile [start, end) into statements, mutating the expression
    /// stack.
    fn block(&self, start: usize, end: usize, stack: &mut Vec<Item>, lp: Option<&LoopEnv>) -> Result<Vec<Stmt>, DecompileError> {
        let mut out: Vec<Stmt> = Vec::new();
        let mut ip = start;
        while ip < end {
            // While-loop at a statement boundary: a backward jump targets ip.
            if stack.is_empty() {
                if let Some(j) = self.backjump_to(ip, end) {
                    // Not a for-loop (those are detected at FOR_ITER).
                    if !matches!(self.instrs.get(ip), Some(Instr::ForIter(_))) {
                        let (stmt, next) = self.while_loop(ip, j, end)?;
                        out.push(stmt);
                        ip = next;
                        continue;
                    }
                }
            }
            let instr = self.instrs[ip].clone();
            match instr {
                Instr::Nop => ip += 1,
                Instr::LoadConst(c) => {
                    stack.push(self.const_expr(c)?);
                    ip += 1;
                }
                Instr::LoadFast(i) => {
                    stack.push(Item::E(Expr::Name(self.varname(i))));
                    ip += 1;
                }
                Instr::LoadGlobal(n) => {
                    stack.push(Item::E(Expr::Name(self.name(n)?)));
                    ip += 1;
                }
                Instr::LoadDeref(i) => {
                    stack.push(Item::E(Expr::Name(self.deref_name(i))));
                    ip += 1;
                }
                Instr::LoadClosure(i) => {
                    stack.push(Item::E(Expr::Name(self.deref_name(i))));
                    ip += 1;
                }
                Instr::StoreFast(i) => {
                    let v = stack.pop().ok_or_else(|| DecompileError("store with empty stack".into()))?.expr()?;
                    out.push(Stmt::new(StmtKind::Assign { target: Target::Name(self.varname(i)), value: v }, 0));
                    ip += 1;
                }
                Instr::StoreGlobal(n) => {
                    let name = self.name(n)?;
                    if !self.is_module {
                        let mut g = self.global_decls.borrow_mut();
                        if !g.contains(&name) {
                            g.push(name.clone());
                        }
                    }
                    let v = stack.pop().ok_or_else(|| DecompileError("store with empty stack".into()))?.expr()?;
                    out.push(Stmt::new(StmtKind::Assign { target: Target::Name(name), value: v }, 0));
                    ip += 1;
                }
                Instr::StoreDeref(i) => {
                    let name = self.deref_name(i);
                    if i as usize >= self.code.cellvars.len() {
                        let mut nl = self.nonlocal_decls.borrow_mut();
                        if !nl.contains(&name) {
                            nl.push(name.clone());
                        }
                    }
                    let v = stack.pop().ok_or_else(|| DecompileError("store with empty stack".into()))?.expr()?;
                    out.push(Stmt::new(StmtKind::Assign { target: Target::Name(name), value: v }, 0));
                    ip += 1;
                }
                Instr::StoreSubscr => {
                    let idx = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let obj = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let val = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    out.push(Stmt::new(StmtKind::Assign { target: Target::Subscript { value: obj, index: idx }, value: val }, 0));
                    ip += 1;
                }
                Instr::BinarySubscr => {
                    let idx = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let obj = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::Subscript { value: Box::new(obj), index: Box::new(idx) }));
                    ip += 1;
                }
                Instr::BuildSlice(n) => {
                    let parts: Vec<Expr> = self.pop_exprs(stack, n as usize)?;
                    let opt = |e: &Expr| -> Option<Box<Expr>> {
                        if matches!(e, Expr::NoneLit) {
                            None
                        } else {
                            Some(Box::new(e.clone()))
                        }
                    };
                    let slice = Expr::Slice {
                        start: opt(&parts[0]),
                        stop: opt(&parts[1]),
                        step: parts.get(2).and_then(opt),
                    };
                    stack.push(Item::E(slice));
                    ip += 1;
                }
                Instr::PopTop => {
                    // A bare POP_TOP with empty stack inside a for-loop is a
                    // `break` discarding the iterator.
                    if stack.is_empty() {
                        if let (Some(l), Some(Instr::Jump(t))) = (lp, self.instrs.get(ip + 1)) {
                            if l.is_for && *t as usize >= l.exit {
                                out.push(Stmt::new(StmtKind::Break, 0));
                                ip += 2;
                                continue;
                            }
                        }
                        return err("POP_TOP with empty stack");
                    }
                    let e = stack.pop().unwrap().expr()?;
                    out.push(Stmt::new(StmtKind::Expr(e), 0));
                    ip += 1;
                }
                Instr::DupTop => {
                    // Chained comparison: DUP_TOP; ROT_THREE; COMPARE; ...
                    if matches!(self.instrs.get(ip + 1), Some(Instr::RotThree)) {
                        ip = self.chained_compare(ip, stack)?;
                    } else {
                        return err("DUP_TOP outside chained comparison");
                    }
                }
                Instr::RotTwo | Instr::RotThree => return err("stray stack rotation"),
                Instr::Binary(op) => {
                    let b = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let a = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::BinOp(op, Box::new(a), Box::new(b))));
                    ip += 1;
                }
                Instr::Unary(op) => {
                    let a = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::UnaryOp(op, Box::new(a))));
                    ip += 1;
                }
                Instr::Compare(c) => {
                    let b = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let a = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::Compare {
                        left: Box::new(a),
                        ops: vec![CompareKind::Cmp(c)],
                        comparators: vec![b],
                    }));
                    ip += 1;
                }
                Instr::ContainsOp(inv) => {
                    let b = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let a = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let k = if inv { CompareKind::NotIn } else { CompareKind::In };
                    stack.push(Item::E(Expr::Compare { left: Box::new(a), ops: vec![k], comparators: vec![b] }));
                    ip += 1;
                }
                Instr::IsOp(inv) => {
                    let b = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let a = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let k = if inv { CompareKind::IsNot } else { CompareKind::Is };
                    stack.push(Item::E(Expr::Compare { left: Box::new(a), ops: vec![k], comparators: vec![b] }));
                    ip += 1;
                }
                Instr::JumpIfFalseOrPop(t) | Instr::JumpIfTrueOrPop(t) => {
                    if !self.opts.boolop_value {
                        return err("short-circuit boolean value reconstruction unsupported");
                    }
                    let kind = if matches!(instr, Instr::JumpIfFalseOrPop(_)) { BoolOpKind::And } else { BoolOpKind::Or };
                    let lhs = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let rhs = self.expr_range(ip + 1, t as usize)?;
                    let merged = match (kind, lhs) {
                        (k, Expr::BoolOp(k2, mut items)) if k == k2 => {
                            items.push(rhs);
                            Expr::BoolOp(k, items)
                        }
                        (k, l) => Expr::BoolOp(k, vec![l, rhs]),
                    };
                    stack.push(Item::E(merged));
                    ip = t as usize;
                }
                Instr::Jump(t) => {
                    let t = t as usize;
                    if let Some(l) = lp {
                        if t == l.header {
                            out.push(Stmt::new(StmtKind::Continue, 0));
                            ip += 1;
                            continue;
                        }
                        if t >= l.exit {
                            out.push(Stmt::new(StmtKind::Break, 0));
                            ip += 1;
                            continue;
                        }
                    }
                    if t < start {
                        return err("irreducible control flow (jump before block)");
                    }
                    if t <= end && stack.is_empty() {
                        // A statement-level forward jump whose construct was
                        // not consumed by any structure handler: the region
                        // in between is unreachable (e.g. the dead `else`
                        // branch inside a dynamo resume function). Skip it.
                        ip = t;
                        continue;
                    }
                    return err(format!("unstructured forward jump {} -> {}", ip, t));
                }
                Instr::PopJumpIfFalse(t) => {
                    let t = t as usize;
                    // Try ternary first (value-producing if).
                    if let Some(next) = self.try_ternary(ip, t, stack)? {
                        ip = next;
                        continue;
                    }
                    let cond = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    // Loop-exit conditions are handled by while_loop; here a
                    // forward target within block bounds is a statement if.
                    if t > end {
                        // `if cond: break`-style exit from enclosing loop.
                        if let Some(l) = lp {
                            if t >= l.exit {
                                out.push(Stmt::new(
                                    StmtKind::If {
                                        cond: Expr::UnaryOp(UnOp::Not, Box::new(cond)),
                                        then: vec![Stmt::new(StmtKind::Break, 0)],
                                        orelse: vec![],
                                    },
                                    0,
                                ));
                                ip += 1;
                                continue;
                            }
                        }
                        return err("conditional jump out of block");
                    }
                    // Does the then-branch end with a forward else-skip?
                    let mut then_end = t;
                    let mut orelse = Vec::new();
                    let mut next = t;
                    if t >= 1 && t <= end {
                        if let Some(Instr::Jump(e)) = self.instrs.get(t - 1) {
                            let e = *e as usize;
                            if e >= t && e <= end && !(lp.map(|l| e >= l.exit && e > end).unwrap_or(false)) {
                                then_end = t - 1;
                                let mut s2 = Vec::new();
                                orelse = self.block(t, e, &mut s2, lp)?;
                                if !s2.is_empty() {
                                    return err("else branch left values on stack");
                                }
                                next = e;
                            }
                        }
                    }
                    let mut s1 = Vec::new();
                    let then = self.block(ip + 1, then_end, &mut s1, lp)?;
                    if !s1.is_empty() {
                        return err("then branch left values on stack");
                    }
                    let then = if then.is_empty() { vec![Stmt::new(StmtKind::Pass, 0)] } else { then };
                    out.push(Stmt::new(StmtKind::If { cond, then, orelse }, 0));
                    ip = next;
                }
                Instr::PopJumpIfTrue(t) => {
                    let t = t as usize;
                    let cond = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    // assert pattern: [LOAD_CONST msg; RAISE] then target.
                    if t == ip + 3 {
                        if let (Some(Instr::LoadConst(m)), Some(Instr::Raise)) = (self.instrs.get(ip + 1), self.instrs.get(ip + 2)) {
                            let msg = self.const_expr(*m)?.expr()?;
                            let msg = if matches!(msg, Expr::Str(ref s) if s == "AssertionError") { None } else { Some(msg) };
                            out.push(Stmt::new(StmtKind::Assert { cond, msg }, 0));
                            ip = t;
                            continue;
                        }
                    }
                    // General: `if not cond: ...`
                    let mut s1 = Vec::new();
                    let then = self.block(ip + 1, t, &mut s1, lp)?;
                    if !s1.is_empty() {
                        return err("if-not branch left values".to_string());
                    }
                    out.push(Stmt::new(
                        StmtKind::If { cond: Expr::UnaryOp(UnOp::Not, Box::new(cond)), then, orelse: vec![] },
                        0,
                    ));
                    ip = t;
                }
                Instr::GetIter => {
                    // Part of a for-loop / comprehension when followed by
                    // FOR_ITER; otherwise an explicit iter(...) value.
                    if matches!(self.instrs.get(ip + 1), Some(Instr::ForIter(_))) {
                        let (work, next) = self.for_loop(ip, end, stack, lp)?;
                        match work {
                            ForResult::Stmt(s) => out.push(s),
                            ForResult::Value(e) => stack.push(Item::E(e)),
                        }
                        ip = next;
                    } else {
                        let e = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                        stack.push(Item::E(Expr::Call { func: Box::new(Expr::Name("iter".into())), args: vec![e] }));
                        ip += 1;
                    }
                }
                Instr::ForIter(_) => return err("FOR_ITER without GET_ITER"),
                Instr::Call(n) => {
                    let args = self.pop_exprs(stack, n as usize)?;
                    let f = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::Call { func: Box::new(f), args }));
                    ip += 1;
                }
                Instr::LoadMethod(n) => {
                    let name = self.name(n)?;
                    let obj = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::Attribute { value: Box::new(obj), name }));
                    ip += 1;
                }
                Instr::CallMethod(n) => {
                    let args = self.pop_exprs(stack, n as usize)?;
                    let f = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let Expr::Attribute { value, name } = f else {
                        return err("CALL_METHOD without method load");
                    };
                    stack.push(Item::E(Expr::MethodCall { recv: value, name, args }));
                    ip += 1;
                }
                Instr::LoadAttr(n) => {
                    let name = self.name(n)?;
                    let obj = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    stack.push(Item::E(Expr::Attribute { value: Box::new(obj), name }));
                    ip += 1;
                }
                Instr::BuildList(n) => {
                    let items = self.pop_exprs(stack, n as usize)?;
                    stack.push(Item::E(Expr::List(items)));
                    ip += 1;
                }
                Instr::BuildTuple(n) => {
                    let items = self.pop_exprs(stack, n as usize)?;
                    stack.push(Item::E(Expr::Tuple(items)));
                    ip += 1;
                }
                Instr::BuildMap(n) => {
                    let mut kvs = self.pop_exprs(stack, 2 * n as usize)?;
                    let mut pairs = Vec::new();
                    while !kvs.is_empty() {
                        let k = kvs.remove(0);
                        let v = kvs.remove(0);
                        pairs.push((k, v));
                    }
                    stack.push(Item::E(Expr::Dict(pairs)));
                    ip += 1;
                }
                Instr::ListAppend(_) => return err("LIST_APPEND outside comprehension"),
                Instr::UnpackSequence(n) => {
                    let value = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
                    let (target, next) = self.parse_unpack_targets(ip + 1, n as usize)?;
                    out.push(Stmt::new(StmtKind::Assign { target, value }, 0));
                    ip = next;
                }
                Instr::MakeFunction(flags) => {
                    let Item::Code(fcode) = stack.pop().ok_or_else(|| DecompileError("underflow".into()))? else {
                        return err("MAKE_FUNCTION without code constant");
                    };
                    if flags & 2 != 0 {
                        stack.pop(); // closure tuple — implicit in source form
                    }
                    let defaults: Vec<Expr> = if flags & 1 != 0 {
                        match stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()? {
                            Expr::Tuple(items) => items,
                            other => vec![other],
                        }
                    } else {
                        Vec::new()
                    };
                    // Lambda value or named def?
                    let body = decompile_code_to_stmts(&fcode, self.opts)?;
                    if fcode.name == "<lambda>" {
                        if body.len() != 1 {
                            return err("lambda body is not a single return");
                        }
                        let StmtKind::Return(Some(e)) = &body[0].kind else {
                            return err("lambda body is not a single return");
                        };
                        let params: Vec<String> = fcode.varnames.iter().take(fcode.argcount).cloned().collect();
                        stack.push(Item::E(Expr::Lambda { params, body: Box::new(e.clone()) }));
                        ip += 1;
                    } else {
                        // Must be stored next.
                        let (fname, next) = match self.instrs.get(ip + 1) {
                            Some(Instr::StoreFast(i)) => (self.varname(*i), ip + 2),
                            Some(Instr::StoreGlobal(n)) => (self.name(*n)?, ip + 2),
                            Some(Instr::StoreDeref(i)) => (self.deref_name(*i), ip + 2),
                            _ => return err("function object not stored"),
                        };
                        let nparams = fcode.argcount;
                        let n_def = defaults.len();
                        let params: Vec<Param> = fcode
                            .varnames
                            .iter()
                            .take(nparams)
                            .enumerate()
                            .map(|(i, p)| Param {
                                name: p.clone(),
                                default: if i + n_def >= nparams { Some(defaults[i + n_def - nparams].clone()) } else { None },
                            })
                            .collect();
                        out.push(Stmt::new(StmtKind::FuncDef { name: fname, params, body }, 0));
                        ip = next;
                    }
                }
                Instr::ReturnValue => {
                    let v = stack.pop().ok_or_else(|| DecompileError("return with empty stack".into()))?.expr()?;
                    out.push(Stmt::new(StmtKind::Return(Some(v)), 0));
                    ip += 1;
                    // Skip any unreachable padding up to the next jump target
                    // (the structurer delimits ranges, so just stop here if
                    // nothing follows).
                }
                Instr::Raise => {
                    let v = stack.pop().ok_or_else(|| DecompileError("raise with empty stack".into()))?.expr()?;
                    out.push(Stmt::new(StmtKind::Raise(v), 0));
                    ip += 1;
                }
            }
        }
        Ok(out)
    }

    fn pop_exprs(&self, stack: &mut Vec<Item>, n: usize) -> Result<Vec<Expr>, DecompileError> {
        if stack.len() < n {
            return err("stack underflow");
        }
        let items = stack.split_off(stack.len() - n);
        items.into_iter().map(|i| i.expr()).collect()
    }

    /// `while` loop whose condition starts at `h` and whose backward jump is
    /// at `j`. Returns (stmt, continuation ip).
    fn while_loop(&self, h: usize, j: usize, end: usize) -> Result<(Stmt, usize), DecompileError> {
        // Find the exit test: first PopJumpIfFalse in [h, j) at top level
        // whose target is beyond j.
        let mut p = None;
        for k in h..j {
            if let Instr::PopJumpIfFalse(t) = self.instrs[k] {
                if t as usize > j {
                    p = Some((k, t as usize));
                    break;
                }
            }
        }
        let Some((ptest, exit)) = p else {
            return err("while loop without exit test");
        };
        let cond = self.expr_range(h, ptest)?;
        // Break targets beyond the exit mark a while-else region.
        let mut break_target: Option<usize> = None;
        for k in ptest + 1..j {
            if let Instr::Jump(t) = self.instrs[k] {
                let t = t as usize;
                if t > exit && t <= end {
                    break_target = Some(break_target.map_or(t, |b: usize| b.max(t)));
                }
            }
        }
        let construct_end = break_target.unwrap_or(exit);
        let lp = LoopEnv { header: h, exit, is_for: false };
        let mut s = Vec::new();
        let body = self.block(ptest + 1, j, &mut s, Some(&lp))?;
        if !s.is_empty() {
            return err("while body left values on stack");
        }
        let orelse = if construct_end > exit {
            if !self.opts.loop_else {
                return err("while-else reconstruction unsupported");
            }
            let mut s2 = Vec::new();
            let o = self.block(exit, construct_end, &mut s2, None)?;
            if !s2.is_empty() {
                return err("while else left values on stack");
            }
            o
        } else {
            Vec::new()
        };
        Ok((Stmt::new(StmtKind::While { cond, body, orelse }, 0), construct_end))
    }

    /// A for-loop (or comprehension) at `GET_ITER` position `gi`.
    fn for_loop(
        &self,
        gi: usize,
        end: usize,
        stack: &mut Vec<Item>,
        _outer: Option<&LoopEnv>,
    ) -> Result<(ForResult, usize), DecompileError> {
        let h = gi + 1; // FOR_ITER position
        let Instr::ForIter(exit) = self.instrs[h] else {
            return err("expected FOR_ITER");
        };
        let exit = exit as usize;
        let Some(j) = self.backjump_to(h, end.max(exit)) else {
            return err("for loop without backward jump");
        };
        let iterable = stack.pop().ok_or_else(|| DecompileError("GET_ITER with empty stack".into()))?.expr()?;

        // Comprehension: empty-list accumulator directly below the iterable.
        let is_comp = matches!(stack.last(), Some(Item::E(Expr::List(items))) if items.is_empty())
            && (h + 1..j).any(|k| matches!(self.instrs[k], Instr::ListAppend(_)));
        if is_comp {
            if !self.opts.comprehension {
                return err("comprehension reconstruction unsupported");
            }
            stack.pop(); // the accumulator
            let (target, mut k) = self.parse_unpack_or_store(h + 1)?;
            // conds: POP_JUMP_IF_FALSE back to header.
            let mut conds = Vec::new();
            loop {
                // Scan one expression followed by PJIF(header)?
                let mut probe = k;
                let mut found = None;
                while probe < j {
                    if let Instr::PopJumpIfFalse(t) = self.instrs[probe] {
                        if t as usize == h {
                            found = Some(probe);
                        }
                        break;
                    }
                    if matches!(self.instrs[probe], Instr::ListAppend(_)) {
                        break;
                    }
                    probe += 1;
                }
                match found {
                    Some(p) => {
                        if !self.opts.comprehension_conds {
                            return err("comprehension condition reconstruction unsupported");
                        }
                        conds.push(self.expr_range(k, p)?);
                        k = p + 1;
                    }
                    None => break,
                }
            }
            // elt expression ends right before LIST_APPEND.
            let mut append_at = None;
            for q in k..j {
                if matches!(self.instrs[q], Instr::ListAppend(_)) {
                    append_at = Some(q);
                    break;
                }
            }
            let Some(app) = append_at else {
                return err("comprehension without LIST_APPEND");
            };
            let elt = self.expr_range(k, app)?;
            let comp = Expr::ListComp { elt: Box::new(elt), target: Box::new(target), iter: Box::new(iterable), conds };
            return Ok((ForResult::Value(comp), exit));
        }

        // Regular for-loop.
        let (target, body_start) = self.parse_unpack_or_store(h + 1)?;
        // Break targets beyond exit -> for-else.
        let mut break_target: Option<usize> = None;
        for q in body_start..j {
            if let Instr::Jump(t) = self.instrs[q] {
                let t = t as usize;
                if t > exit {
                    break_target = Some(break_target.map_or(t, |b: usize| b.max(t)));
                }
            }
        }
        let construct_end = break_target.unwrap_or(exit);
        let lp = LoopEnv { header: h, exit, is_for: true };
        let mut s = Vec::new();
        let body = self.block(body_start, j, &mut s, Some(&lp))?;
        if !s.is_empty() {
            return err("for body left values on stack");
        }
        let orelse = if construct_end > exit {
            if !self.opts.loop_else {
                return err("for-else reconstruction unsupported");
            }
            let mut s2 = Vec::new();
            let o = self.block(exit, construct_end, &mut s2, None)?;
            if !s2.is_empty() {
                return err("for else left values on stack");
            }
            o
        } else {
            Vec::new()
        };
        Ok((ForResult::Stmt(Stmt::new(StmtKind::For { target, iter: iterable, body, orelse }, 0)), construct_end))
    }

    /// Parse a store-target at `ip` (StoreFast / tuple unpack).
    fn parse_unpack_or_store(&self, ip: usize) -> Result<(Target, usize), DecompileError> {
        match self.instrs.get(ip) {
            Some(Instr::StoreFast(i)) => Ok((Target::Name(self.varname(*i)), ip + 1)),
            Some(Instr::StoreGlobal(n)) => Ok((Target::Name(self.name(*n)?), ip + 1)),
            Some(Instr::StoreDeref(i)) => Ok((Target::Name(self.deref_name(*i)), ip + 1)),
            Some(Instr::UnpackSequence(n)) => self.parse_unpack_targets(ip + 1, *n as usize),
            other => err(format!("expected store target, found {:?}", other)),
        }
    }

    fn parse_unpack_targets(&self, mut ip: usize, n: usize) -> Result<(Target, usize), DecompileError> {
        let mut ts = Vec::new();
        for _ in 0..n {
            let (t, next) = self.parse_unpack_or_store(ip)?;
            ts.push(t);
            ip = next;
        }
        Ok((Target::Tuple(ts), ip))
    }

    /// Ternary: PJIF(t); <then-expr>; JUMP(e); t: <else-expr>; e:
    /// Returns Some(next ip) and pushes the IfExp on success.
    fn try_ternary(&self, ip: usize, t: usize, stack: &mut Vec<Item>) -> Result<Option<usize>, DecompileError> {
        if t < 1 || t > self.instrs.len() {
            return Ok(None);
        }
        let Some(Instr::Jump(e)) = self.instrs.get(t - 1) else {
            return Ok(None);
        };
        let e = *e as usize;
        if e <= t {
            return Ok(None);
        }
        let Ok(then) = self.expr_range(ip + 1, t - 1) else {
            return Ok(None);
        };
        let Ok(orelse) = self.expr_range(t, e) else {
            return Ok(None);
        };
        if !self.opts.ternary {
            return err("ternary reconstruction unsupported");
        }
        if !self.opts.nested_ternary && (matches!(then, Expr::IfExp { .. }) || matches!(orelse, Expr::IfExp { .. })) {
            return err("nested ternary reconstruction unsupported");
        }
        let cond = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
        stack.push(Item::E(Expr::IfExp { cond: Box::new(cond), then: Box::new(then), orelse: Box::new(orelse) }));
        Ok(Some(e))
    }

    /// Chained comparison starting at the DUP_TOP of the first link.
    /// Stack on entry: [..., left, c1].
    fn chained_compare(&self, mut ip: usize, stack: &mut Vec<Item>) -> Result<usize, DecompileError> {
        if !self.opts.chained_compare {
            return err("chained comparison reconstruction unsupported");
        }
        let mut ops: Vec<CompareKind> = Vec::new();
        let mut comparators: Vec<Expr> = Vec::new();
        let first_right = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
        let left = stack.pop().ok_or_else(|| DecompileError("underflow".into()))?.expr()?;
        let mut pending_right = first_right;
        loop {
            // expect DUP_TOP, ROT_THREE, <compare-ish>, JIFOP(cleanup)
            if !matches!(self.instrs.get(ip), Some(Instr::DupTop)) || !matches!(self.instrs.get(ip + 1), Some(Instr::RotThree)) {
                return err("malformed comparison chain");
            }
            let op = self.compare_kind_at(ip + 2)?;
            let Some(Instr::JumpIfFalseOrPop(c)) = self.instrs.get(ip + 3) else {
                return err("malformed comparison chain (no short-circuit)");
            };
            ops.push(op);
            comparators.push(pending_right.clone());
            // Next comparator expression: up to the next DUP_TOP link or the
            // final compare (at cleanup-2).
            let clean = *c as usize;
            let final_cmp = clean.checked_sub(2).ok_or_else(|| DecompileError("bad chain cleanup".into()))?;
            let mut q = ip + 4;
            while q < final_cmp {
                if matches!(self.instrs[q], Instr::DupTop) && matches!(self.instrs.get(q + 1), Some(Instr::RotThree)) {
                    break;
                }
                q += 1;
            }
            pending_right = self.expr_range(ip + 4, q)?;
            if q == final_cmp {
                // final link: compare at q, then JUMP(end)
                let op = self.compare_kind_at(q)?;
                ops.push(op);
                comparators.push(pending_right);
                let Some(Instr::Jump(endt)) = self.instrs.get(q + 1) else {
                    return err("malformed chain tail");
                };
                let endt = *endt as usize;
                // cleanup block: ROT_TWO, POP_TOP
                stack.push(Item::E(Expr::Compare { left: Box::new(left), ops, comparators }));
                return Ok(endt);
            }
            ip = q;
        }
    }

    fn compare_kind_at(&self, ip: usize) -> Result<CompareKind, DecompileError> {
        match self.instrs.get(ip) {
            Some(Instr::Compare(c)) => Ok(CompareKind::Cmp(*c)),
            Some(Instr::ContainsOp(false)) => Ok(CompareKind::In),
            Some(Instr::ContainsOp(true)) => Ok(CompareKind::NotIn),
            Some(Instr::IsOp(false)) => Ok(CompareKind::Is),
            Some(Instr::IsOp(true)) => Ok(CompareKind::IsNot),
            other => err(format!("expected comparison op, found {:?}", other)),
        }
    }
}

enum ForResult {
    Stmt(Stmt),
    Value(Expr),
}
