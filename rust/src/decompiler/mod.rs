//! The depyf-rs decompiler: raw versioned bytecode → equivalent `pylang`
//! source, via **symbolic execution of the bytecode** (the paper's §3).
//!
//! The same engine powers the modeled baseline decompilers
//! ([`baselines`]) through [`DecompilerOptions`] feature gates: each
//! baseline is this engine minus the capabilities the real tool lacked
//! (version support, chained comparisons, loop-else, program-generated
//! entry jumps, ...), so Table 1 emerges from real decompilation runs.
//!
//! Correctness bar (same as the paper's CI): decompiled source must
//! *recompile and behave identically*, not match the original text.

pub mod baselines;
mod engine;

pub use baselines::{all_tools, DecompilerTool};
pub use engine::{decompile_code_to_stmts, DecompileError};

use std::rc::Rc;

use crate::bytecode::CodeObject;
use crate::pylang::ast::{Module, Param, Stmt, StmtKind};
use crate::pylang::unparse_module;

/// Feature gates for the decompilation engine. `depyf-rs` itself runs with
/// everything enabled; baselines disable what the real tools lacked.
#[derive(Clone, Debug)]
pub struct DecompilerOptions {
    /// Which ISA versions can be decoded (None = all).
    pub versions: Option<Vec<crate::bytecode::IsaVersion>>,
    /// `a < b <= c` (DUP_TOP/ROT_THREE link chains).
    pub chained_compare: bool,
    /// `while ... else` / `for ... else`.
    pub loop_else: bool,
    /// List comprehensions (accumulator-on-stack loops).
    pub comprehension: bool,
    /// Conditional filters inside comprehensions.
    pub comprehension_conds: bool,
    /// `x if c else y`.
    pub ternary: bool,
    /// Ternaries nested inside ternaries (`a if c1 else b if c2 else d`).
    pub nested_ternary: bool,
    /// `and` / `or` used as value-producing expressions.
    pub boolop_value: bool,
    /// Program-generated prologues that JUMP into the body (dynamo resume
    /// functions). This is the capability the paper's baselines lack.
    pub jump_entry: bool,
    /// V311 unified BINARY_OP opargs beyond +,-,* (pycdc's partial 3.11
    /// support).
    pub v311_full_binary: bool,
}

impl Default for DecompilerOptions {
    fn default() -> Self {
        DecompilerOptions {
            versions: None,
            chained_compare: true,
            loop_else: true,
            comprehension: true,
            comprehension_conds: true,
            ternary: true,
            nested_ternary: true,
            boolop_value: true,
            jump_entry: true,
            v311_full_binary: true,
        }
    }
}

/// The full-featured decompiler (what the paper calls depyf).
pub struct Decompiler {
    pub options: DecompilerOptions,
}

impl Default for Decompiler {
    fn default() -> Self {
        Decompiler { options: DecompilerOptions::default() }
    }
}

impl Decompiler {
    pub fn new() -> Decompiler {
        Decompiler::default()
    }

    pub fn with_options(options: DecompilerOptions) -> Decompiler {
        Decompiler { options }
    }

    /// Decompile a *module* code object to source text.
    pub fn decompile_module(&self, code: &Rc<CodeObject>) -> Result<String, DecompileError> {
        let stmts = engine::decompile_code_to_stmts(code, &self.options)?;
        Ok(unparse_module(&Module { body: stmts }))
    }

    /// Decompile a *function* code object to a `def` rendering.
    pub fn decompile_function(&self, code: &Rc<CodeObject>) -> Result<String, DecompileError> {
        let body = engine::decompile_code_to_stmts(code, &self.options)?;
        let params: Vec<Param> =
            code.varnames.iter().take(code.argcount).map(|n| Param { name: n.clone(), default: None }).collect();
        let def = Stmt::new(StmtKind::FuncDef { name: sanitize_name(&code.name), params, body }, 1);
        Ok(unparse_module(&Module { body: vec![def] }))
    }
}

/// Function names like `<lambda>` aren't valid identifiers in a `def`.
fn sanitize_name(n: &str) -> String {
    let s: String = n.chars().map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' }).collect();
    if s.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        format!("fn_{}", s)
    } else {
        s
    }
}

/// Convenience: full-featured decompilation of a function code object.
pub fn decompile(code: &Rc<CodeObject>) -> Result<String, DecompileError> {
    Decompiler::new().decompile_function(code)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bytecode::IsaVersion;
    use crate::pylang::compile_module;
    use crate::vm::Vm;

    /// The paper's correctness criterion: src -> bytecode -> decompile ->
    /// recompile -> identical behaviour (captured print output).
    fn roundtrip(src: &str) {
        for v in IsaVersion::ALL {
            let code = compile_module(src, "<orig>", v).unwrap_or_else(|e| panic!("{}\n{}", e, src));
            let vm = Vm::new();
            vm.seed(3);
            vm.run_module(&code).unwrap_or_else(|e| panic!("orig run: {}\n{}", e, src));
            let expected = vm.take_output();

            let d = Decompiler::new();
            let text = d.decompile_module(&code).unwrap_or_else(|e| panic!("decompile failed on {}: {}\nsource:\n{}", v, e, src));
            let code2 = compile_module(&text, "<decompiled>", v)
                .unwrap_or_else(|e| panic!("recompile failed: {}\ndecompiled was:\n{}", e, text));
            let vm2 = Vm::new();
            vm2.seed(3);
            vm2.run_module(&code2).unwrap_or_else(|e| panic!("decompiled run: {}\nsource:\n{}", e, text));
            assert_eq!(vm2.take_output(), expected, "behaviour mismatch on {} for:\n{}\ndecompiled:\n{}", v, src, text);
        }
    }

    #[test]
    fn straightline_and_arith() {
        roundtrip("x = 1 + 2 * 3\ny = x ** 2 % 7\nprint(x, y, x // 2, -x)\n");
    }

    #[test]
    fn conditionals() {
        roundtrip("x = 5\nif x > 3:\n    print('big')\nelse:\n    print('small')\nif x == 5:\n    print('five')\n");
        roundtrip("x = 2\nif x == 1:\n    print('a')\nelif x == 2:\n    print('b')\nelse:\n    print('c')\n");
    }

    #[test]
    fn loops() {
        roundtrip("t = 0\nfor i in range(5):\n    t += i\nprint(t)\n");
        roundtrip("n = 5\nwhile n > 0:\n    n -= 1\nprint(n)\n");
        roundtrip("for i in range(10):\n    if i == 3:\n        continue\n    if i == 6:\n        break\n    print(i)\n");
    }

    #[test]
    fn loop_else() {
        roundtrip("for i in range(3):\n    print(i)\nelse:\n    print('done')\n");
        roundtrip("for i in range(9):\n    if i == 2:\n        break\nelse:\n    print('no break')\nprint('after')\n");
        roundtrip("n = 2\nwhile n > 0:\n    n -= 1\nelse:\n    print('drained')\nprint(n)\n");
    }

    #[test]
    fn functions() {
        roundtrip("def add(a, b):\n    return a + b\nprint(add(2, 3))\n");
        roundtrip("def fib(n):\n    if n < 2:\n        return n\n    return fib(n - 1) + fib(n - 2)\nprint(fib(9))\n");
        roundtrip("def f(a, b=10):\n    return a * b\nprint(f(3), f(3, 4))\n");
    }

    #[test]
    fn ternary_and_boolops() {
        roundtrip("x = 4\ny = 'even' if x % 2 == 0 else 'odd'\nprint(y)\n");
        roundtrip("a = 0\nb = 7\nprint(a or b, a and b, not a)\n");
        roundtrip("x = 3\nr = 1 if x == 1 else 2 if x == 2 else 3\nprint(r)\n");
    }

    #[test]
    fn chained_comparison() {
        roundtrip("x = 5\nprint(1 < x <= 5)\nprint(1 < x < 3)\nprint(0 <= x <= 9 <= 10)\n");
    }

    #[test]
    fn collections_and_subscripts() {
        roundtrip("xs = [1, 2, 3]\nxs.append(4)\nxs[0] = 9\nd = {'a': 1}\nd['b'] = 2\nprint(xs, d, xs[1:3], xs[-1])\n");
        roundtrip("t = (1, 2, 3)\na, b, c = t\nprint(c, b, a)\n");
    }

    #[test]
    fn comprehensions() {
        roundtrip("ys = [x * x for x in range(6)]\nprint(ys)\n");
        roundtrip("ys = [x for x in range(10) if x % 2 == 0 if x > 2]\nprint(ys)\n");
    }

    #[test]
    fn assert_and_raise() {
        roundtrip("x = 5\nassert x == 5, 'must be five'\nprint('ok')\n");
    }

    #[test]
    fn is_in_operators() {
        roundtrip("x = None\nprint(x is None, x is not None)\nxs = [1, 2]\nprint(1 in xs, 5 not in xs)\n");
    }

    #[test]
    fn tensor_programs() {
        roundtrip("a = torch.ones([2, 2])\nb = (a @ a).relu()\nprint(b.sum().item())\n");
    }

    #[test]
    fn nested_functions_and_globals() {
        roundtrip("g = 1\ndef f():\n    global g\n    g = 5\nf()\nprint(g)\n");
        roundtrip("def outer():\n    x = 1\n    def inner():\n        return x + 1\n    return inner()\nprint(outer())\n");
    }

    #[test]
    fn lambdas() {
        roundtrip("f = lambda a, b: a * b + 1\nprint(f(3, 4))\n");
    }

    #[test]
    fn version_gate_blocks_decoding() {
        let code = compile_module("x = 1\n", "<t>", IsaVersion::V310).unwrap();
        let opts = DecompilerOptions { versions: Some(vec![IsaVersion::V38]), ..Default::default() };
        let d = Decompiler::with_options(opts);
        assert!(d.decompile_module(&code).is_err());
    }
}
