//! XLA backend: lowers a captured [`Graph`] to HLO **text**, compiles it on
//! the PJRT CPU client via [`Runtime`], and wraps execution in a
//! [`CompiledGraphFn`]. This is the "backend generates binary executables"
//! half of the paper's compiler, made real.
//!
//! The emitted dialect matches what `xla_extension` 0.5.1's HLO text parser
//! accepts (validated by `runtime::tests` and the eager-vs-xla cross-check
//! below).

use std::fmt::Write as _;
use std::rc::Rc;
use std::sync::Arc;

use crate::api::{ArtifactKind, CompiledModule, DepyfError, ModuleArtifact, ModuleStats};
use crate::graph::{CompiledGraphFn, Graph, NodeKind, OpKind};
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;

/// The executable-cache key for a graph: `graph:{content_hash}`.
pub fn cache_key(graph: &Graph) -> String {
    format!("graph:{:016x}", graph.content_hash())
}

/// The XLA backend's [`CompiledModule`]: a PJRT executable plus the HLO
/// text it was compiled from (dumped as a typed artifact at `finish()`).
pub struct XlaModule {
    name: String,
    graph: Arc<Graph>,
    rt: Arc<Runtime>,
    exe: Arc<Executable>,
    /// True when the executable was served from the runtime's
    /// content-hash cache instead of compiled fresh.
    pub cache_hit: bool,
}

impl CompiledModule for XlaModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.graph.check_inputs(inputs)?;
        let refs: Vec<&Tensor> = inputs.iter().map(|t| &**t).collect();
        self.rt.execute(&self.exe, &refs)
    }

    fn backend_name(&self) -> &str {
        "xla"
    }

    /// The HLO text is re-emitted on demand: `artifacts()` runs once at
    /// `finish()`, keeping the cache-hit compile path free of lowering.
    fn artifacts(&self) -> Vec<ModuleArtifact> {
        vec![ModuleArtifact {
            kind: ArtifactKind::Hlo,
            name: self.name.clone(),
            file: format!("__hlo_{}.txt", sanitize(&self.name)),
            content: emit_hlo(&self.graph).unwrap_or_else(|e| format!("# hlo emission failed: {}\n", e)),
        }]
    }

    fn stats(&self) -> ModuleStats {
        ModuleStats { partitions: 1, bucket: None, cache_hits: self.cache_hit as u64 }
    }
}

/// Compile a graph via HLO text + PJRT into an [`XlaModule`].
///
/// The executable cache key is `graph:{content_hash}` — structurally
/// identical graphs (whatever their `__compiled_fn_N` names, whichever
/// session captured them) compile **once per process** on a shared
/// [`Runtime`]. With a runtime disk cache, the lowered HLO is persisted
/// under the same key so repeated runs skip `emit_hlo` entirely and feed
/// PJRT the cached text.
pub fn compile_module(name: &str, graph: &Arc<Graph>, rt: &Arc<Runtime>) -> Result<XlaModule, DepyfError> {
    let key = cache_key(graph);
    let n_outputs = graph.outputs.len();
    let (exe, cache_hit) = match rt.cached_executable(&key) {
        Some(e) => (e, true),
        None => {
            let hlo = match rt.cached_hlo(&key) {
                Some((text, n)) if n == n_outputs => text,
                _ => {
                    let text = emit_hlo(graph)?;
                    rt.store_hlo(&key, &text, n_outputs);
                    text
                }
            };
            (rt.compile_hlo_text(&key, &hlo, n_outputs)?, false)
        }
    };
    Ok(XlaModule { name: name.to_string(), graph: Arc::clone(graph), rt: Arc::clone(rt), exe, cache_hit })
}

/// Compile a graph and wrap it as a [`CompiledGraphFn`] (tests, benches).
pub fn compile(name: &str, graph: &Arc<Graph>, rt: &Arc<Runtime>) -> Result<CompiledGraphFn, DepyfError> {
    let module = compile_module(name, graph, rt)?;
    Ok(CompiledGraphFn::from_module(name, Arc::clone(graph), Arc::new(module)))
}

fn f32ty(shape: &[usize]) -> String {
    if shape.is_empty() {
        "f32[]".into()
    } else {
        format!("f32[{}]", shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","))
    }
}

fn dims_attr(dims: &[usize]) -> String {
    format!("{{{}}}", dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(","))
}

/// Recursive braces for tensor constants.
fn const_braces(shape: &[usize], data: &[f32]) -> String {
    if shape.is_empty() {
        return format!("{}", data[0]);
    }
    let n = shape[0];
    let inner: usize = shape[1..].iter().product::<usize>().max(1);
    let parts: Vec<String> = (0..n).map(|i| const_braces(&shape[1..], &data[i * inner..(i + 1) * inner])).collect();
    format!("{{{}}}", parts.join(", "))
}

struct Emitter {
    body: String,
    /// Scoped reduce computations used (emitted before ENTRY).
    used_add: bool,
    used_max: bool,
    used_min: bool,
    tmp: usize,
}

impl Emitter {
    fn fresh(&mut self, base: &str) -> String {
        self.tmp += 1;
        format!("{}_t{}", base, self.tmp)
    }

    fn line(&mut self, s: &str) {
        self.body.push_str("  ");
        self.body.push_str(s);
        self.body.push('\n');
    }

    /// Broadcast `src` (shape `from`) to shape `to` (numpy semantics).
    fn broadcast_to(&mut self, src: &str, from: &[usize], to: &[usize]) -> String {
        if from == to {
            return src.to_string();
        }
        let offset = to.len() - from.len();
        // Keep dims that already match; squeeze size-1 dims that must grow.
        let mut kept_dims: Vec<usize> = Vec::new(); // positions in `to`
        let mut kept_sizes: Vec<usize> = Vec::new();
        for (i, &s) in from.iter().enumerate() {
            let tpos = i + offset;
            if s == to[tpos] {
                kept_dims.push(tpos);
                kept_sizes.push(s);
            } else {
                assert_eq!(s, 1, "unbroadcastable {:?} -> {:?}", from, to);
            }
        }
        let mut cur = src.to_string();
        if kept_sizes != from {
            let r = self.fresh(src);
            self.line(&format!("{} = {} reshape({})", r, f32ty(&kept_sizes), cur));
            cur = r;
        }
        let b = self.fresh(src);
        self.line(&format!("{} = {} broadcast({}), dimensions={}", b, f32ty(to), cur, dims_attr(&kept_dims)));
        b
    }

    /// Broadcast with an explicit dims mapping (`from[i] == to[kept[i]]`) —
    /// used to re-expand reduction results back over the reduced axis.
    fn broadcast_dims(&mut self, src: &str, to: &[usize], kept: &[usize]) -> String {
        let b = self.fresh(src);
        self.line(&format!("{} = {} broadcast({}), dimensions={}", b, f32ty(to), src, dims_attr(kept)));
        b
    }

    /// Scalar constant broadcast to a shape.
    fn scalar(&mut self, v: f32, shape: &[usize]) -> String {
        let c = self.fresh("c");
        self.line(&format!("{} = f32[] constant({})", c, v));
        if shape.is_empty() {
            c
        } else {
            self.broadcast_to(&c, &[], shape)
        }
    }

    /// Reduce `src` over `dims` with a named reduction, producing `out_shape`.
    fn reduce(&mut self, src: &str, src_shape: &[usize], dims: &[usize], kind: &str, out_shape: &[usize]) -> String {
        let (comp, init) = match kind {
            "add" => {
                self.used_add = true;
                ("add_f32", "0")
            }
            "max" => {
                self.used_max = true;
                ("max_f32", "-inf")
            }
            "min" => {
                self.used_min = true;
                ("min_f32", "inf")
            }
            _ => unreachable!(),
        };
        let z = self.fresh("z");
        self.line(&format!("{} = f32[] constant({})", z, init));
        let r = self.fresh(src);
        let _ = src_shape;
        self.line(&format!(
            "{} = {} reduce({}, {}), dimensions={}, to_apply={}",
            r,
            f32ty(out_shape),
            src,
            z,
            dims_attr(dims),
            comp
        ));
        r
    }
}

/// Emit a whole HLO module for the graph.
pub fn emit_hlo(g: &Graph) -> Result<String, DepyfError> {
    let mut e = Emitter { body: String::new(), used_add: false, used_max: false, used_min: false, tmp: 0 };
    let mut names: Vec<String> = vec![String::new(); g.nodes.len()];

    // Parameters first, in graph-input order.
    for (pi, &id) in g.inputs.iter().enumerate() {
        let n = format!("p{}", pi);
        e.line(&format!("{} = {} parameter({})", n, f32ty(&g.nodes[id].shape), pi));
        names[id] = n;
    }

    for (id, node) in g.nodes.iter().enumerate() {
        let out_shape = node.shape.clone();
        match &node.kind {
            NodeKind::Placeholder { .. } => {} // already a parameter
            NodeKind::ConstScalar(v) => {
                let n = format!("v{}", id);
                e.line(&format!("{} = f32[] constant({})", n, *v as f32));
                names[id] = n;
            }
            NodeKind::ConstTensor(t) => {
                let n = format!("v{}", id);
                e.line(&format!("{} = {} constant({})", n, f32ty(t.shape()), const_braces(t.shape(), t.data())));
                names[id] = n;
            }
            NodeKind::Op(op, args) => {
                let arg_name = |i: usize| names[args[i]].clone();
                let arg_shape = |i: usize| g.nodes[args[i]].shape.clone();
                let n = format!("v{}", id);
                match op {
                    OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Pow | OpKind::Maximum | OpKind::Minimum => {
                        let hop = match op {
                            OpKind::Add => "add",
                            OpKind::Sub => "subtract",
                            OpKind::Mul => "multiply",
                            OpKind::Div => "divide",
                            OpKind::Pow => "power",
                            OpKind::Maximum => "maximum",
                            _ => "minimum",
                        };
                        let a = e.broadcast_to(&arg_name(0), &arg_shape(0), &out_shape);
                        let b = e.broadcast_to(&arg_name(1), &arg_shape(1), &out_shape);
                        e.line(&format!("{} = {} {}({}, {})", n, f32ty(&out_shape), hop, a, b));
                    }
                    OpKind::Neg | OpKind::Exp | OpKind::Log | OpKind::Sqrt | OpKind::Abs | OpKind::Tanh | OpKind::Sigmoid => {
                        let hop = match op {
                            OpKind::Neg => "negate",
                            OpKind::Exp => "exponential",
                            OpKind::Log => "log",
                            OpKind::Sqrt => "sqrt",
                            OpKind::Abs => "abs",
                            OpKind::Tanh => "tanh",
                            _ => "logistic",
                        };
                        e.line(&format!("{} = {} {}({})", n, f32ty(&out_shape), hop, arg_name(0)));
                    }
                    OpKind::Relu => {
                        let zero = e.scalar(0.0, &out_shape);
                        e.line(&format!("{} = {} maximum({}, {})", n, f32ty(&out_shape), arg_name(0), zero));
                    }
                    OpKind::Gelu => {
                        // 0.5*x*(1+tanh(sqrt(2/pi)*(x+0.044715*x^3)))
                        let x = arg_name(0);
                        let x2 = e.fresh("g");
                        e.line(&format!("{} = {} multiply({}, {})", x2, f32ty(&out_shape), x, x));
                        let x3 = e.fresh("g");
                        e.line(&format!("{} = {} multiply({}, {})", x3, f32ty(&out_shape), x2, x));
                        let c1 = e.scalar(0.044715, &out_shape);
                        let t1 = e.fresh("g");
                        e.line(&format!("{} = {} multiply({}, {})", t1, f32ty(&out_shape), c1, x3));
                        let t2 = e.fresh("g");
                        e.line(&format!("{} = {} add({}, {})", t2, f32ty(&out_shape), x, t1));
                        let c2 = e.scalar((2.0f32 / std::f32::consts::PI).sqrt(), &out_shape);
                        let t3 = e.fresh("g");
                        e.line(&format!("{} = {} multiply({}, {})", t3, f32ty(&out_shape), c2, t2));
                        let th = e.fresh("g");
                        e.line(&format!("{} = {} tanh({})", th, f32ty(&out_shape), t3));
                        let one = e.scalar(1.0, &out_shape);
                        let t4 = e.fresh("g");
                        e.line(&format!("{} = {} add({}, {})", t4, f32ty(&out_shape), one, th));
                        let half = e.scalar(0.5, &out_shape);
                        let t5 = e.fresh("g");
                        e.line(&format!("{} = {} multiply({}, {})", t5, f32ty(&out_shape), half, x));
                        e.line(&format!("{} = {} multiply({}, {})", n, f32ty(&out_shape), t5, t4));
                    }
                    OpKind::MatMul => {
                        let (sa, sb) = (arg_shape(0), arg_shape(1));
                        if sa.len() == 2 && sb.len() == 2 {
                            e.line(&format!(
                                "{} = {} dot({}, {}), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
                                n,
                                f32ty(&out_shape),
                                arg_name(0),
                                arg_name(1)
                            ));
                        } else if sa.len() == sb.len() && sa.len() >= 3 {
                            let batch: Vec<usize> = (0..sa.len() - 2).collect();
                            e.line(&format!(
                                "{} = {} dot({}, {}), lhs_batch_dims={}, rhs_batch_dims={}, lhs_contracting_dims={{{}}}, rhs_contracting_dims={{{}}}",
                                n,
                                f32ty(&out_shape),
                                arg_name(0),
                                arg_name(1),
                                dims_attr(&batch),
                                dims_attr(&batch),
                                sa.len() - 1,
                                sb.len() - 2
                            ));
                        } else if sa.len() > 2 && sb.len() == 2 {
                            // [B.., M, K] @ [K, N]: flatten batch, dot, unflatten.
                            let m: usize = sa[..sa.len() - 1].iter().product();
                            let k = sa[sa.len() - 1];
                            let flat = e.fresh("mm");
                            e.line(&format!("{} = {} reshape({})", flat, f32ty(&[m, k]), arg_name(0)));
                            let d = e.fresh("mm");
                            e.line(&format!(
                                "{} = {} dot({}, {}), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
                                d,
                                f32ty(&[m, sb[1]]),
                                flat,
                                arg_name(1)
                            ));
                            e.line(&format!("{} = {} reshape({})", n, f32ty(&out_shape), d));
                        } else {
                            return Err(DepyfError::Backend(format!("xla: unsupported matmul {:?} @ {:?}", sa, sb)));
                        }
                    }
                    OpKind::Transpose => {
                        let r = arg_shape(0).len();
                        let mut perm: Vec<usize> = (0..r).collect();
                        perm.swap(r - 2, r - 1);
                        e.line(&format!("{} = {} transpose({}), dimensions={}", n, f32ty(&out_shape), arg_name(0), dims_attr(&perm)));
                    }
                    OpKind::Permute(perm) => {
                        e.line(&format!("{} = {} transpose({}), dimensions={}", n, f32ty(&out_shape), arg_name(0), dims_attr(perm)));
                    }
                    OpKind::Reshape(_) => {
                        e.line(&format!("{} = {} reshape({})", n, f32ty(&out_shape), arg_name(0)));
                    }
                    OpKind::Sum(ax) | OpKind::Max(ax) | OpKind::Min(ax) | OpKind::Mean(ax) => {
                        let kind = match op {
                            OpKind::Sum(_) | OpKind::Mean(_) => "add",
                            OpKind::Max(_) => "max",
                            _ => "min",
                        };
                        let in_shape = arg_shape(0);
                        let dims: Vec<usize> = match ax {
                            Some(a) => vec![*a],
                            None => (0..in_shape.len()).collect(),
                        };
                        let r = e.reduce(&arg_name(0), &in_shape, &dims, kind, &out_shape);
                        if matches!(op, OpKind::Mean(_)) {
                            let count: usize = dims.iter().map(|&d| in_shape[d]).product();
                            let c = e.scalar(count as f32, &out_shape);
                            e.line(&format!("{} = {} divide({}, {})", n, f32ty(&out_shape), r, c));
                        } else {
                            e.line(&format!("{} = {} copy({})", n, f32ty(&out_shape), r));
                        }
                    }
                    OpKind::Softmax => {
                        let shape = arg_shape(0);
                        let last = shape.len() - 1;
                        let mut red_shape = shape.clone();
                        red_shape.pop();
                        let kept: Vec<usize> = (0..last).collect();
                        let m = e.reduce(&arg_name(0), &shape, &[last], "max", &red_shape);
                        let mb = e.broadcast_dims(&m, &shape, &kept);
                        let sh = e.fresh("sm");
                        e.line(&format!("{} = {} subtract({}, {})", sh, f32ty(&shape), arg_name(0), mb));
                        let ex = e.fresh("sm");
                        e.line(&format!("{} = {} exponential({})", ex, f32ty(&shape), sh));
                        let s = e.reduce(&ex, &shape, &[last], "add", &red_shape);
                        let sb = e.broadcast_dims(&s, &shape, &kept);
                        e.line(&format!("{} = {} divide({}, {})", n, f32ty(&shape), ex, sb));
                    }
                    OpKind::LayerNorm => {
                        let shape = arg_shape(0);
                        let last = shape.len() - 1;
                        let d = shape[last];
                        let mut red_shape = shape.clone();
                        red_shape.pop();
                        let kept: Vec<usize> = (0..last).collect();
                        let s = e.reduce(&arg_name(0), &shape, &[last], "add", &red_shape);
                        let cnt = e.scalar(d as f32, &red_shape);
                        let mean = e.fresh("ln");
                        e.line(&format!("{} = {} divide({}, {})", mean, f32ty(&red_shape), s, cnt));
                        let mb = e.broadcast_dims(&mean, &shape, &kept);
                        let cen = e.fresh("ln");
                        e.line(&format!("{} = {} subtract({}, {})", cen, f32ty(&shape), arg_name(0), mb));
                        let sq = e.fresh("ln");
                        e.line(&format!("{} = {} multiply({}, {})", sq, f32ty(&shape), cen, cen));
                        let vs = e.reduce(&sq, &shape, &[last], "add", &red_shape);
                        let cnt2 = e.scalar(d as f32, &red_shape);
                        let var = e.fresh("ln");
                        e.line(&format!("{} = {} divide({}, {})", var, f32ty(&red_shape), vs, cnt2));
                        let eps = e.scalar(1e-5, &red_shape);
                        let ve = e.fresh("ln");
                        e.line(&format!("{} = {} add({}, {})", ve, f32ty(&red_shape), var, eps));
                        let sd = e.fresh("ln");
                        e.line(&format!("{} = {} sqrt({})", sd, f32ty(&red_shape), ve));
                        let sdb = e.broadcast_dims(&sd, &shape, &kept);
                        let norm = e.fresh("ln");
                        e.line(&format!("{} = {} divide({}, {})", norm, f32ty(&shape), cen, sdb));
                        let gb = e.broadcast_to(&arg_name(1), &arg_shape(1), &shape);
                        let scaled = e.fresh("ln");
                        e.line(&format!("{} = {} multiply({}, {})", scaled, f32ty(&shape), norm, gb));
                        let bb = e.broadcast_to(&arg_name(2), &arg_shape(2), &shape);
                        e.line(&format!("{} = {} add({}, {})", n, f32ty(&shape), scaled, bb));
                    }
                    OpKind::Embedding => {
                        // table [V, D], ids [..I] (f32 -> s32), gather.
                        let tshape = arg_shape(0);
                        let ishape = arg_shape(1);
                        let d = tshape[1];
                        let ids32 = e.fresh("emb");
                        let ity = if ishape.is_empty() {
                            "s32[]".to_string()
                        } else {
                            format!("s32[{}]", ishape.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(","))
                        };
                        e.line(&format!("{} = {} convert({})", ids32, ity, arg_name(1)));
                        let offset_dim = ishape.len(); // D lands after all index dims
                        e.line(&format!(
                            "{} = {} gather({}, {}), offset_dims={{{}}}, collapsed_slice_dims={{0}}, start_index_map={{0}}, index_vector_dim={}, slice_sizes={{1,{}}}",
                            n,
                            f32ty(&out_shape),
                            arg_name(0),
                            ids32,
                            offset_dim,
                            ishape.len(),
                            d
                        ));
                    }
                    OpKind::CrossEntropy => {
                        // logits [..,V], targets [..]: mean over rows of
                        // (logsumexp(l) - l[target]) via one-hot.
                        let lshape = arg_shape(0);
                        let v = *lshape.last().unwrap();
                        let rows: usize = lshape[..lshape.len() - 1].iter().product::<usize>().max(1);
                        let l2 = e.fresh("ce");
                        e.line(&format!("{} = {} reshape({})", l2, f32ty(&[rows, v]), arg_name(0)));
                        let t2 = e.fresh("ce");
                        e.line(&format!("{} = {} reshape({})", t2, f32ty(&[rows]), arg_name(1)));
                        // logsumexp
                        let m = e.reduce(&l2, &[rows, v], &[1], "max", &[rows]);
                        let mb = e.broadcast_dims(&m, &[rows, v], &[0]);
                        let sh = e.fresh("ce");
                        e.line(&format!("{} = {} subtract({}, {})", sh, f32ty(&[rows, v]), l2, mb));
                        let ex = e.fresh("ce");
                        e.line(&format!("{} = {} exponential({})", ex, f32ty(&[rows, v]), sh));
                        let se = e.reduce(&ex, &[rows, v], &[1], "add", &[rows]);
                        // (remaining reductions below reuse row-major one-hot picks)
                        let lg = e.fresh("ce");
                        e.line(&format!("{} = {} log({})", lg, f32ty(&[rows]), se));
                        let lse = e.fresh("ce");
                        e.line(&format!("{} = {} add({}, {})", lse, f32ty(&[rows]), m, lg));
                        // one-hot pick of target logit
                        let t32 = e.fresh("ce");
                        e.line(&format!("{} = s32[{}] convert({})", t32, rows, t2));
                        let tb = e.fresh("ce");
                        e.line(&format!("{} = s32[{},{}] broadcast({}), dimensions={{0}}", tb, rows, v, t32));
                        let io = e.fresh("ce");
                        e.line(&format!("{} = s32[{},{}] iota(), iota_dimension=1", io, rows, v));
                        let eq = e.fresh("ce");
                        e.line(&format!("{} = pred[{},{}] compare({}, {}), direction=EQ", eq, rows, v, io, tb));
                        let oh = e.fresh("ce");
                        e.line(&format!("{} = {} convert({})", oh, f32ty(&[rows, v]), eq));
                        let pick = e.fresh("ce");
                        e.line(&format!("{} = {} multiply({}, {})", pick, f32ty(&[rows, v]), l2, oh));
                        let tl = e.reduce(&pick, &[rows, v], &[1], "add", &[rows]);
                        let diff = e.fresh("ce");
                        e.line(&format!("{} = {} subtract({}, {})", diff, f32ty(&[rows]), lse, tl));
                        let tot = e.reduce(&diff, &[rows], &[0], "add", &[]);
                        let cnt = e.scalar(rows as f32, &[]);
                        e.line(&format!("{} = f32[] divide({}, {})", n, tot, cnt));
                    }
                }
                names[id] = n;
            }
        }
    }

    // ROOT tuple.
    let out_types: Vec<String> = g.outputs.iter().map(|&o| f32ty(&g.nodes[o].shape)).collect();
    let out_names: Vec<String> = g.outputs.iter().map(|&o| names[o].clone()).collect();
    e.line(&format!("ROOT out = ({}) tuple({})", out_types.join(", "), out_names.join(", ")));

    let mut module = String::new();
    let _ = writeln!(module, "HloModule {}\n", sanitize(&g.name));
    if e.used_add {
        module.push_str("add_f32 {\n  lhs = f32[] parameter(0)\n  rhs = f32[] parameter(1)\n  ROOT r = f32[] add(lhs, rhs)\n}\n\n");
    }
    if e.used_max {
        module.push_str("max_f32 {\n  lhs = f32[] parameter(0)\n  rhs = f32[] parameter(1)\n  ROOT r = f32[] maximum(lhs, rhs)\n}\n\n");
    }
    if e.used_min {
        module.push_str("min_f32 {\n  lhs = f32[] parameter(0)\n  rhs = f32[] parameter(1)\n  ROOT r = f32[] minimum(lhs, rhs)\n}\n\n");
    }
    module.push_str("ENTRY main {\n");
    module.push_str(&e.body);
    module.push_str("}\n");
    Ok(module)
}

fn sanitize(name: &str) -> String {
    let s = super::sanitize(name);
    if s.is_empty() {
        "graph".into()
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::eager;
    use crate::graph::Graph;
    use crate::tensor::Rng;

    fn cross_check(g: &Graph, inputs: Vec<Tensor>, tol: f32) {
        let rt = Runtime::cpu().expect("pjrt");
        let g = Arc::new(g.clone());
        let f = compile("test", &g, &rt).unwrap_or_else(|e| panic!("xla compile failed: {}\n{}", e, emit_hlo(&g).unwrap()));
        let rcs: Vec<Rc<Tensor>> = inputs.into_iter().map(Rc::new).collect();
        let got = f.call(&rcs).expect("xla exec");
        let want = eager::execute(&g, &rcs).expect("eager exec");
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.iter()) {
            assert!(a.allclose(b, tol), "xla {:?} vs eager {:?}", a, b);
        }
    }

    #[test]
    fn elementwise_with_broadcast() {
        let mut g = Graph::new("ew");
        let x = g.placeholder("x", &[2, 3]);
        let b = g.placeholder("b", &[3]);
        let c = g.const_scalar(2.0);
        let s = g.add_op(OpKind::Add, vec![x, b]).unwrap();
        let m = g.add_op(OpKind::Mul, vec![s, c]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        g.set_outputs(vec![r]);
        let mut rng = Rng::new(1);
        cross_check(&g, vec![Tensor::randn(&[2, 3], &mut rng), Tensor::randn(&[3], &mut rng)], 1e-5);
    }

    #[test]
    fn matmul_variants() {
        let mut rng = Rng::new(2);
        // 2D
        let mut g = Graph::new("mm2");
        let a = g.placeholder("a", &[4, 5]);
        let b = g.placeholder("b", &[5, 3]);
        let m = g.add_op(OpKind::MatMul, vec![a, b]).unwrap();
        g.set_outputs(vec![m]);
        cross_check(&g, vec![Tensor::randn(&[4, 5], &mut rng), Tensor::randn(&[5, 3], &mut rng)], 1e-4);
        // batched
        let mut g = Graph::new("mm3");
        let a = g.placeholder("a", &[2, 4, 5]);
        let b = g.placeholder("b", &[2, 5, 3]);
        let m = g.add_op(OpKind::MatMul, vec![a, b]).unwrap();
        g.set_outputs(vec![m]);
        cross_check(&g, vec![Tensor::randn(&[2, 4, 5], &mut rng), Tensor::randn(&[2, 5, 3], &mut rng)], 1e-4);
        // batched @ unbatched
        let mut g = Graph::new("mmb");
        let a = g.placeholder("a", &[2, 4, 5]);
        let b = g.placeholder("b", &[5, 3]);
        let m = g.add_op(OpKind::MatMul, vec![a, b]).unwrap();
        g.set_outputs(vec![m]);
        cross_check(&g, vec![Tensor::randn(&[2, 4, 5], &mut rng), Tensor::randn(&[5, 3], &mut rng)], 1e-4);
    }

    #[test]
    fn reductions_and_softmax() {
        let mut rng = Rng::new(3);
        let mut g = Graph::new("red");
        let x = g.placeholder("x", &[3, 4]);
        let s0 = g.add_op(OpKind::Sum(Some(0)), vec![x]).unwrap();
        let s1 = g.add_op(OpKind::Mean(Some(1)), vec![x]).unwrap();
        let sa = g.add_op(OpKind::Sum(None), vec![x]).unwrap();
        let mx = g.add_op(OpKind::Max(None), vec![x]).unwrap();
        let sm = g.add_op(OpKind::Softmax, vec![x]).unwrap();
        g.set_outputs(vec![s0, s1, sa, mx, sm]);
        cross_check(&g, vec![Tensor::randn(&[3, 4], &mut rng)], 1e-5);
    }

    #[test]
    fn unary_chain_and_gelu() {
        let mut rng = Rng::new(4);
        let mut g = Graph::new("un");
        let x = g.placeholder("x", &[8]);
        let a = g.add_op(OpKind::Tanh, vec![x]).unwrap();
        let b = g.add_op(OpKind::Gelu, vec![a]).unwrap();
        let c = g.add_op(OpKind::Sigmoid, vec![b]).unwrap();
        let d = g.add_op(OpKind::Neg, vec![c]).unwrap();
        let f = g.add_op(OpKind::Abs, vec![d]).unwrap();
        g.set_outputs(vec![f]);
        cross_check(&g, vec![Tensor::randn(&[8], &mut rng)], 1e-5);
    }

    #[test]
    fn layernorm_matches_eager() {
        let mut rng = Rng::new(5);
        let mut g = Graph::new("ln");
        let x = g.placeholder("x", &[4, 16]);
        let gm = g.placeholder("g", &[16]);
        let bt = g.placeholder("b", &[16]);
        let y = g.add_op(OpKind::LayerNorm, vec![x, gm, bt]).unwrap();
        g.set_outputs(vec![y]);
        cross_check(
            &g,
            vec![Tensor::randn(&[4, 16], &mut rng), Tensor::randn(&[16], &mut rng), Tensor::randn(&[16], &mut rng)],
            1e-4,
        );
    }

    #[test]
    fn embedding_and_cross_entropy() {
        let mut rng = Rng::new(6);
        let mut g = Graph::new("emb");
        let table = g.placeholder("table", &[10, 4]);
        let ids = g.placeholder("ids", &[2, 3]);
        let emb = g.add_op(OpKind::Embedding, vec![table, ids]).unwrap();
        g.set_outputs(vec![emb]);
        let ids_t = Tensor::new(vec![2, 3], vec![0.0, 3.0, 9.0, 1.0, 1.0, 2.0]);
        cross_check(&g, vec![Tensor::randn(&[10, 4], &mut rng), ids_t], 1e-5);

        let mut g = Graph::new("ce");
        let logits = g.placeholder("logits", &[6, 10]);
        let tgt = g.placeholder("tgt", &[6]);
        let ce = g.add_op(OpKind::CrossEntropy, vec![logits, tgt]).unwrap();
        g.set_outputs(vec![ce]);
        let tgt_t = Tensor::new(vec![6], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        cross_check(&g, vec![Tensor::randn(&[6, 10], &mut rng), tgt_t], 1e-4);
    }

    #[test]
    fn transpose_permute_reshape() {
        let mut rng = Rng::new(7);
        let mut g = Graph::new("tp");
        let x = g.placeholder("x", &[2, 3, 4]);
        let t = g.add_op(OpKind::Transpose, vec![x]).unwrap();
        let p = g.add_op(OpKind::Permute(vec![2, 0, 1]), vec![x]).unwrap();
        let r = g.add_op(OpKind::Reshape(vec![6, -1]), vec![x]).unwrap();
        g.set_outputs(vec![t, p, r]);
        cross_check(&g, vec![Tensor::randn(&[2, 3, 4], &mut rng)], 1e-6);
    }

    #[test]
    fn const_tensor_embedded() {
        let mut g = Graph::new("ct");
        let x = g.placeholder("x", &[2, 2]);
        let c = g.const_tensor(Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        let s = g.add_op(OpKind::Add, vec![x, c]).unwrap();
        g.set_outputs(vec![s]);
        cross_check(&g, vec![Tensor::ones(&[2, 2])], 1e-6);
    }

    fn small_graph(name: &str) -> Arc<Graph> {
        let mut g = Graph::new(name);
        let x = g.placeholder("x", &[2, 2]);
        let c = g.const_scalar(2.0);
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![m]).unwrap();
        g.set_outputs(vec![s]);
        Arc::new(g)
    }

    /// Structurally identical graphs — however they are named, whichever
    /// session captured them — must hit one PJRT compile per process.
    #[test]
    fn identical_graphs_compile_once_per_runtime() {
        let rt = Runtime::cpu().expect("pjrt");
        // Same graph content from "two sessions": both name their first
        // capture __compiled_fn_1-style, but names don't matter either way.
        let f1 = compile("__compiled_fn_1", &small_graph("__compiled_fn_1"), &rt).unwrap();
        assert_eq!(rt.compiles.get(), 1);
        let f2 = compile("__compiled_fn_7", &small_graph("__compiled_fn_7"), &rt).unwrap();
        assert_eq!(rt.compiles.get(), 1, "content-hash key must dedupe the second compile");
        let x = vec![Rc::new(Tensor::ones(&[2, 2]))];
        assert_eq!(f1.call(&x).unwrap()[0].item(), 8.0);
        assert_eq!(f2.call(&x).unwrap()[0].item(), 8.0);
        // A structurally different graph still compiles.
        let mut g = Graph::new("other");
        let x0 = g.placeholder("x", &[2, 2]);
        let r = g.add_op(OpKind::Relu, vec![x0]).unwrap();
        g.set_outputs(vec![r]);
        compile("other", &Arc::new(g), &rt).unwrap();
        assert_eq!(rt.compiles.get(), 2);
    }

    /// Two sequential runtimes over the same disk-cache dir: the second
    /// skips lowering and reuses the persisted HLO text.
    #[test]
    fn disk_cache_is_shared_across_runtimes() {
        let dir = std::env::temp_dir().join(format!("depyf_xla_diskcache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let g = small_graph("g");
        {
            let rt1 = Runtime::cpu_with_disk_cache(&dir).expect("pjrt");
            compile("a", &g, &rt1).unwrap();
            assert_eq!(rt1.disk_hits.get(), 0);
            assert_eq!(rt1.disk_cache().unwrap().len(), 1, "first run persists the HLO");
        }
        let rt2 = Runtime::cpu_with_disk_cache(&dir).expect("pjrt");
        let f = compile("b", &g, &rt2).unwrap();
        assert_eq!(rt2.disk_hits.get(), 1, "second run must reuse the persisted HLO");
        assert_eq!(rt2.compiles.get(), 1);
        assert_eq!(f.call(&[Rc::new(Tensor::ones(&[2, 2]))]).unwrap()[0].item(), 8.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
