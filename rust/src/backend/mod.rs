//! Graph backends: the eager reference executor, the XLA/PJRT backend,
//! and the composite `sharded` / `batched` backends built on the staged
//! [`Backend`] pipeline (`plan` → `lower`). The loop-program compiler
//! lives in its own top-level module ([`crate::codegen`], registered as
//! `codegen`) but speaks the exact same contract.
//!
//! The public contract lives in [`crate::api`]: [`CompileRequest`] in,
//! [`CompilePlan`](crate::api::CompilePlan) out of `plan`, an executable
//! [`CompiledModule`](crate::api::CompiledModule) out of `lower`, with a
//! [`Capabilities`](crate::api::Capabilities) bitset validated up front by
//! the registry and `SessionBuilder`. Everything here is re-exported for
//! convenience. (The legacy `BackendKind` / `compile_graph` shims are
//! gone — use a registered backend name or `Arc<dyn Backend>`.)

pub mod batched;
pub mod eager;
pub mod partition;
pub mod recording;
pub mod resilient;
pub mod sharded;
pub mod xla;

pub use crate::api::{
    backend_names, compile_with_policy, eager_graph_fn, lookup_backend, module_from_fn,
    register_backend, Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule,
    EagerBackend, FallbackPolicy, ModuleArtifact, ModuleStats, PolicyCompiled, XlaBackend,
};
pub use batched::BatchedBackend;
pub use recording::{
    localize_divergence, replay_bundle, single_call_bundle, tensor_diff, CulpritOp, Mismatch,
    RecordingBackend, RecordingModule, ReplayOptions, ReplayReport,
};
pub use resilient::{ResilienceStats, ResilientBackend};
pub use sharded::ShardedBackend;

/// Shared file-stem sanitizer for backend artifact names (`__hlo_*.txt`,
/// `__plan_*.json`): one rule for every backend, so artifact file names
/// never diverge between them.
pub(crate) fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Tensor;
    use std::rc::Rc;
    use std::sync::Arc;

    #[test]
    fn eager_compile_and_call() {
        let mut g = Graph::new("__compiled_fn_0");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        let req = CompileRequest::new("__compiled_fn_0", Arc::new(g));
        let pc = compile_with_policy(&EagerBackend, &req).unwrap();
        let out = pc.f.call(&[Rc::new(Tensor::new(vec![2], vec![-1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 2.0]);
        assert_eq!(pc.f.calls.get(), 1);
    }

    #[test]
    fn xla_without_runtime_degrades_to_eager() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        g.set_outputs(vec![x]);
        let req = CompileRequest::new("g", Arc::new(g));
        let pc = compile_with_policy(&XlaBackend, &req).unwrap();
        assert!(pc.f.backend_name.starts_with("eager"));
        assert!(pc.fallback_reason.is_some());
    }

    #[test]
    fn composite_backends_declare_capabilities() {
        assert!(ShardedBackend::new().capabilities().contains(Capabilities::PARTITION));
        assert!(BatchedBackend::new().capabilities().contains(Capabilities::DYNAMIC_BATCH));
        assert!(!ShardedBackend::new().requires_runtime());
        assert!(!BatchedBackend::new().requires_runtime());
        assert!(XlaBackend.requires_runtime());
    }
}
