//! Graph backends: the eager reference executor and the XLA/PJRT backend.
//!
//! The public surface now lives in [`crate::api`]: the pluggable
//! [`Backend`] trait, the name registry ([`register_backend`] /
//! [`lookup_backend`]) and the explicit [`FallbackPolicy`] — all
//! re-exported here for convenience. [`BackendKind`] and [`compile_graph`]
//! remain as thin legacy shims over that machinery.

pub mod eager;
pub mod xla;

pub use crate::api::{
    backend_names, compile_with_policy, eager_graph_fn, lookup_backend, register_backend, Backend,
    CompileCtx, EagerBackend, FallbackPolicy, PolicyCompiled, XlaBackend,
};

use std::rc::Rc;

use crate::graph::{CompiledGraphFn, Graph};
use crate::runtime::Runtime;

/// The closed two-variant backend selector of the original API. New code
/// should pass `Rc<dyn Backend>` (any registered backend) instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Node-by-node CPU reference execution.
    Eager,
    /// Lower to HLO text, compile + run via PJRT (fused kernels dispatched
    /// to AOT Pallas artifacts when shapes match).
    Xla,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Eager => "eager",
            BackendKind::Xla => "xla",
        }
    }

    /// The trait-object equivalent of this kind.
    pub fn to_backend(self) -> Rc<dyn Backend> {
        match self {
            BackendKind::Eager => Rc::new(EagerBackend),
            BackendKind::Xla => Rc::new(XlaBackend),
        }
    }
}

/// Compile a captured graph with the chosen backend, degrading to eager on
/// failure (the pre-[`FallbackPolicy`] behaviour).
#[deprecated(note = "use a `Backend` implementation with `api::compile_with_policy` (explicit FallbackPolicy)")]
pub fn compile_graph(
    name: &str,
    graph: Rc<Graph>,
    kind: BackendKind,
    runtime: Option<Rc<Runtime>>,
) -> CompiledGraphFn {
    let ctx = CompileCtx { runtime, fallback: FallbackPolicy::Eager };
    compile_with_policy(kind.to_backend().as_ref(), name, graph, &ctx)
        .expect("FallbackPolicy::Eager never fails")
        .f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::tensor::Tensor;

    #[test]
    #[allow(deprecated)]
    fn eager_compile_and_call() {
        let mut g = Graph::new("__compiled_fn_0");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        let f = compile_graph("__compiled_fn_0", Rc::new(g), BackendKind::Eager, None);
        let out = f.call(&[Rc::new(Tensor::new(vec![2], vec![-1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 2.0]);
        assert_eq!(f.calls.get(), 1);
    }

    #[test]
    #[allow(deprecated)]
    fn xla_without_runtime_degrades_to_eager() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        g.set_outputs(vec![x]);
        let f = compile_graph("g", Rc::new(g), BackendKind::Xla, None);
        assert!(f.backend_name.starts_with("eager"));
    }

    #[test]
    fn kind_to_backend_round_trip() {
        assert_eq!(BackendKind::Eager.to_backend().name(), "eager");
        assert_eq!(BackendKind::Xla.to_backend().name(), "xla");
        assert!(BackendKind::Xla.to_backend().requires_runtime());
    }
}
