//! Graph backends: the eager reference executor and the XLA/PJRT backend.
//!
//! `compile_graph` is dynamo's exit point: it turns a captured [`Graph`]
//! into a [`CompiledGraphFn`] callable installed into the VM globals.

pub mod eager;
pub mod xla;

use std::rc::Rc;

use crate::graph::{CompiledGraphFn, Graph};
use crate::runtime::Runtime;

/// Which backend compiles captured graphs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Node-by-node CPU reference execution.
    Eager,
    /// Lower to HLO text, compile + run via PJRT (fused kernels dispatched
    /// to AOT Pallas artifacts when shapes match).
    Xla,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Eager => "eager",
            BackendKind::Xla => "xla",
        }
    }
}

/// Compile a captured graph with the chosen backend.
///
/// The XLA backend needs a [`Runtime`]; if compilation fails (unsupported
/// op, no runtime) it degrades to eager — mirroring how torch.compile
/// backends fall back — and records the reason in the returned name.
pub fn compile_graph(
    name: &str,
    graph: Rc<Graph>,
    kind: BackendKind,
    runtime: Option<Rc<Runtime>>,
) -> CompiledGraphFn {
    if kind == BackendKind::Xla {
        if let Some(rt) = runtime {
            match xla::compile(name, &graph, &rt) {
                Ok(f) => return f,
                Err(e) => {
                    // Degrade to eager; callers can see backend_name.
                    let g = Rc::clone(&graph);
                    return CompiledGraphFn {
                        name: name.to_string(),
                        graph: g,
                        backend_name: format!("eager (xla fallback: {})", e),
                        executor: Box::new(move |inputs| eager::execute(&graph, inputs)),
                        calls: std::cell::Cell::new(0),
                    };
                }
            }
        }
    }
    let g = Rc::clone(&graph);
    CompiledGraphFn {
        name: name.to_string(),
        graph,
        backend_name: "eager".into(),
        executor: Box::new(move |inputs| eager::execute(&g, inputs)),
        calls: std::cell::Cell::new(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;
    use crate::tensor::Tensor;

    #[test]
    fn eager_compile_and_call() {
        let mut g = Graph::new("__compiled_fn_0");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        let f = compile_graph("__compiled_fn_0", Rc::new(g), BackendKind::Eager, None);
        let out = f.call(&[Rc::new(Tensor::new(vec![2], vec![-1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 2.0]);
        assert_eq!(f.calls.get(), 1);
    }

    #[test]
    fn xla_without_runtime_degrades_to_eager() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        g.set_outputs(vec![x]);
        let f = compile_graph("g", Rc::new(g), BackendKind::Xla, None);
        assert!(f.backend_name.starts_with("eager"));
    }
}
