//! Graph partitioning + stitching helpers for composite backends.
//!
//! Captured graphs are built in topological order, so a partition is a
//! contiguous range of op nodes. The interesting question is *where* to
//! cut: each boundary has a **frontier** — the set of op values produced
//! before the cut and consumed after it — and boundaries with a frontier
//! of one are the graph's articulation points (a single tensor flows
//! through, e.g. between transformer blocks). [`partition_by_ops`] packs
//! ops up to a size budget and then slides each cut back to the smallest
//! frontier in the tail window, so shard boundaries land on articulation
//! points whenever the budget allows.
//!
//! [`extract`] materializes a partition as a standalone [`Graph`] (cut
//! inputs become placeholders, constants are replicated) whose
//! `content_hash` is the per-partition compile-cache key, and
//! [`Stitcher`] runs a list of partition executables over a shared value
//! environment, reassembling the original graph's outputs.

use std::rc::Rc;
use std::sync::Arc;

use crate::api::{CompiledModule, DepyfError};
use crate::graph::{Graph, NodeId, NodeKind};
use crate::tensor::Tensor;

/// One contiguous partition of a graph's op nodes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Op node ids (original graph) executed by this partition, ascending.
    pub nodes: Vec<NodeId>,
    /// Original-graph values read from outside: placeholders and earlier
    /// partitions' op outputs (constants are replicated, not imported).
    pub inputs: Vec<NodeId>,
    /// Values this partition must export: consumed by later partitions or
    /// listed in the graph's outputs.
    pub outputs: Vec<NodeId>,
}

/// For every boundary between consecutive op nodes (index `k` = cut after
/// the k-th op, `1..ops.len()`), the number of op values crossing it.
pub fn frontier_sizes(g: &Graph) -> Vec<usize> {
    let ops: Vec<NodeId> = op_nodes(g);
    let pos_of: std::collections::HashMap<NodeId, usize> =
        ops.iter().enumerate().map(|(i, &id)| (id, i)).collect();
    // last op position consuming each op value (graph outputs pin to end).
    let mut last_use: Vec<usize> = vec![0; ops.len()];
    for (pi, &id) in ops.iter().enumerate() {
        if let NodeKind::Op(_, args) = &g.nodes[id].kind {
            for a in args {
                if let Some(&src) = pos_of.get(a) {
                    last_use[src] = last_use[src].max(pi);
                }
            }
        }
    }
    for (pi, &id) in ops.iter().enumerate() {
        if g.outputs.contains(&id) {
            last_use[pi] = ops.len().max(1) - 1;
        }
    }
    (1..ops.len())
        .map(|k| (0..k).filter(|&src| last_use[src] >= k && last_use[src] != src).count())
        .collect()
}

/// Boundaries whose frontier is exactly one value — the articulation
/// points a sharded backend prefers to cut at.
pub fn articulation_points(g: &Graph) -> Vec<usize> {
    frontier_sizes(g)
        .into_iter()
        .enumerate()
        .filter(|&(_, f)| f == 1)
        .map(|(i, _)| i + 1)
        .collect()
}

/// Split the graph into contiguous partitions of at most `max_ops` op
/// nodes each. Cuts prefer the smallest frontier (articulation points) in
/// the trailing half of each full window.
pub fn partition_by_ops(g: &Graph, max_ops: usize) -> Vec<Partition> {
    let ops = op_nodes(g);
    let max_ops = max_ops.max(1);
    if ops.is_empty() {
        return Vec::new();
    }
    let frontiers = frontier_sizes(g);
    let mut cut_after: Vec<usize> = Vec::new(); // boundary indices (op count)
    let mut start = 0usize;
    while ops.len() - start > max_ops {
        // Candidate boundaries in (start + max_ops/2, start + max_ops];
        // pick the last one with the minimal frontier.
        let lo = start + max_ops.div_ceil(2);
        let hi = start + max_ops;
        let mut best = hi;
        let mut best_frontier = usize::MAX;
        for k in lo..=hi {
            let f = frontiers[k - 1];
            if f <= best_frontier {
                best_frontier = f;
                best = k;
            }
        }
        cut_after.push(best);
        start = best;
    }
    // Materialize partitions from the chosen boundaries.
    let mut bounds = vec![0usize];
    bounds.extend(cut_after);
    bounds.push(ops.len());
    let mut parts = Vec::new();
    for w in bounds.windows(2) {
        parts.push(build_partition(g, &ops[w[0]..w[1]]));
    }
    parts
}

fn op_nodes(g: &Graph) -> Vec<NodeId> {
    g.nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(n.kind, NodeKind::Op(..)))
        .map(|(id, _)| id)
        .collect()
}

fn build_partition(g: &Graph, ops: &[NodeId]) -> Partition {
    let inside: std::collections::HashSet<NodeId> = ops.iter().copied().collect();
    let mut inputs = Vec::new();
    for &id in ops {
        if let NodeKind::Op(_, args) = &g.nodes[id].kind {
            for &a in args {
                let is_const =
                    matches!(g.nodes[a].kind, NodeKind::ConstScalar(_) | NodeKind::ConstTensor(_));
                if !inside.contains(&a) && !is_const && !inputs.contains(&a) {
                    inputs.push(a);
                }
            }
        }
    }
    // Exported: consumed outside this partition, or a graph output.
    let mut outputs = Vec::new();
    for &id in ops {
        let used_outside = g.nodes.iter().enumerate().any(|(other, n)| {
            !inside.contains(&other)
                && matches!(&n.kind, NodeKind::Op(_, args) if args.contains(&id))
        });
        if (used_outside || g.outputs.contains(&id)) && !outputs.contains(&id) {
            outputs.push(id);
        }
    }
    Partition { nodes: ops.to_vec(), inputs, outputs }
}

/// Materialize a partition as a standalone graph: partition inputs become
/// placeholders (original placeholder names are kept; cut values are named
/// `cut_<id>`), constants used inside are replicated, and the partition's
/// exports become the subgraph outputs. The subgraph's `content_hash` is
/// the per-partition compile-cache key.
pub fn extract(g: &Graph, part: &Partition, name: &str) -> Result<Graph, DepyfError> {
    let mut sub = Graph::new(name);
    let mut map: std::collections::HashMap<NodeId, NodeId> = std::collections::HashMap::new();
    for &id in &part.inputs {
        let pname = match &g.nodes[id].kind {
            NodeKind::Placeholder { name } => name.clone(),
            _ => format!("cut_{}", id),
        };
        map.insert(id, sub.placeholder(&pname, &g.nodes[id].shape));
    }
    for &id in &part.nodes {
        let NodeKind::Op(op, args) = &g.nodes[id].kind else {
            return Err(DepyfError::Backend(format!("partition node {} is not an op", id)));
        };
        let mut sub_args = Vec::with_capacity(args.len());
        for &a in args {
            let mapped = match map.get(&a) {
                Some(&m) => m,
                None => match &g.nodes[a].kind {
                    NodeKind::ConstScalar(v) => {
                        let m = sub.const_scalar(*v);
                        map.insert(a, m);
                        m
                    }
                    NodeKind::ConstTensor(t) => {
                        let m = sub.const_tensor(t.clone());
                        map.insert(a, m);
                        m
                    }
                    other => {
                        return Err(DepyfError::Backend(format!(
                            "partition arg {} ({:?}) neither imported nor const",
                            a, other
                        )))
                    }
                },
            };
            sub_args.push(mapped);
        }
        let sid = sub.add_op(op.clone(), sub_args)?;
        map.insert(id, sid);
    }
    let outs: Result<Vec<NodeId>, DepyfError> = part
        .outputs
        .iter()
        .map(|o| {
            map.get(o).copied().ok_or_else(|| {
                DepyfError::Backend(format!("partition output {} not produced", o))
            })
        })
        .collect();
    sub.set_outputs(outs?);
    Ok(sub)
}

/// One compiled partition inside a [`Stitcher`].
pub struct StitchPart {
    pub part: Partition,
    pub module: Arc<dyn CompiledModule>,
}

/// Executes a list of partition modules over a shared environment indexed
/// by original-graph node ids, reassembling the original outputs.
pub struct Stitcher {
    graph: Arc<Graph>,
    parts: Vec<StitchPart>,
}

impl Stitcher {
    pub fn new(graph: Arc<Graph>, parts: Vec<StitchPart>) -> Stitcher {
        Stitcher { graph, parts }
    }

    pub fn parts(&self) -> &[StitchPart] {
        &self.parts
    }

    /// The original (pre-partition) graph the stitcher reassembles.
    pub fn graph(&self) -> &Arc<Graph> {
        &self.graph
    }

    pub fn run(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let g = &*self.graph;
        g.check_inputs(inputs)?;
        let mut env: Vec<Option<Rc<Tensor>>> = vec![None; g.nodes.len()];
        for (&slot, input) in g.inputs.iter().zip(inputs.iter()) {
            env[slot] = Some(Rc::clone(input));
        }
        // Constants that are read across partition boundaries never occur
        // (they are replicated), but a constant can BE a graph output.
        for &o in &g.outputs {
            match &g.nodes[o].kind {
                NodeKind::ConstScalar(v) => env[o] = Some(Rc::new(Tensor::scalar(*v as f32))),
                NodeKind::ConstTensor(t) => env[o] = Some(Rc::new(t.clone())),
                _ => {}
            }
        }
        for sp in &self.parts {
            let part_inputs: Result<Vec<Rc<Tensor>>, DepyfError> = sp
                .part
                .inputs
                .iter()
                .map(|&id| {
                    env[id].clone().ok_or_else(|| {
                        DepyfError::Backend(format!("stitch: partition input {} unevaluated", id))
                    })
                })
                .collect();
            let outs = sp.module.call(&part_inputs?)?;
            if outs.len() != sp.part.outputs.len() {
                return Err(DepyfError::Backend(format!(
                    "stitch: partition returned {} outputs, expected {}",
                    outs.len(),
                    sp.part.outputs.len()
                )));
            }
            for (&id, t) in sp.part.outputs.iter().zip(outs.into_iter()) {
                env[id] = Some(Rc::new(t));
            }
        }
        g.outputs
            .iter()
            .map(|&o| {
                env[o]
                    .as_ref()
                    .map(|t| (**t).clone())
                    .ok_or_else(|| DepyfError::Backend(format!("stitch: output {} unevaluated", o)))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::eager::{self, EagerModule};
    use crate::graph::OpKind;
    use crate::tensor::Rng;

    /// x @ w1 -> relu -> @ w2 -> softmax -> sum : a chain with clear
    /// articulation points between every consecutive op.
    fn mlp() -> Graph {
        let mut g = Graph::new("mlp");
        let x = g.placeholder("x", &[4, 8]);
        let w1 = g.placeholder("w1", &[8, 8]);
        let w2 = g.placeholder("w2", &[8, 8]);
        let h = g.add_op(OpKind::MatMul, vec![x, w1]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![h]).unwrap();
        let o = g.add_op(OpKind::MatMul, vec![r, w2]).unwrap();
        let sm = g.add_op(OpKind::Softmax, vec![o]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![sm]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    #[test]
    fn chain_boundaries_are_articulation_points() {
        let g = mlp();
        // Every boundary in a pure chain carries exactly one value.
        assert_eq!(frontier_sizes(&g), vec![1, 1, 1, 1]);
        assert_eq!(articulation_points(&g), vec![1, 2, 3, 4]);
    }

    #[test]
    fn diamond_has_a_wider_frontier() {
        // x -> (a, b) -> a+b : the middle boundary carries two values.
        let mut g = Graph::new("diamond");
        let x = g.placeholder("x", &[4]);
        let a = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let b = g.add_op(OpKind::Neg, vec![x]).unwrap();
        let s = g.add_op(OpKind::Add, vec![a, b]).unwrap();
        g.set_outputs(vec![s]);
        assert_eq!(frontier_sizes(&g), vec![1, 2]);
        assert_eq!(articulation_points(&g), vec![1]);
    }

    #[test]
    fn partitions_cover_all_ops_without_overlap() {
        let g = mlp();
        for max_ops in 1..=6 {
            let parts = partition_by_ops(&g, max_ops);
            let mut seen: Vec<NodeId> = parts.iter().flat_map(|p| p.nodes.clone()).collect();
            let expected: Vec<NodeId> = (0..g.nodes.len())
                .filter(|&i| matches!(g.nodes[i].kind, NodeKind::Op(..)))
                .collect();
            seen.sort_unstable();
            assert_eq!(seen, expected, "max_ops={}", max_ops);
            for p in &parts {
                assert!(p.nodes.len() <= max_ops, "max_ops={} violated: {:?}", max_ops, p.nodes);
            }
        }
        assert_eq!(partition_by_ops(&g, 2).len(), 3);
        assert_eq!(partition_by_ops(&g, 100).len(), 1);
    }

    #[test]
    fn extracted_subgraphs_stitch_back_to_reference() {
        let g = Arc::new(mlp());
        let mut rng = Rng::new(42);
        let inputs: Vec<Rc<Tensor>> = vec![
            Rc::new(Tensor::randn(&[4, 8], &mut rng)),
            Rc::new(Tensor::randn(&[8, 8], &mut rng)),
            Rc::new(Tensor::randn(&[8, 8], &mut rng)),
        ];
        let want = eager::execute(&g, &inputs).unwrap();
        for max_ops in 1..=5 {
            let parts = partition_by_ops(&g, max_ops);
            let stitch_parts: Vec<StitchPart> = parts
                .into_iter()
                .enumerate()
                .map(|(i, part)| {
                    let sub = extract(&g, &part, &format!("mlp.p{}", i)).unwrap();
                    let module: Arc<dyn CompiledModule> = Arc::new(EagerModule::new(Arc::new(sub)));
                    StitchPart { part, module }
                })
                .collect();
            let stitcher = Stitcher::new(Arc::clone(&g), stitch_parts);
            let got = stitcher.run(&inputs).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(want.iter()) {
                assert_eq!(a.shape(), b.shape());
                assert_eq!(a.data(), b.data(), "bitwise divergence at max_ops={}", max_ops);
            }
        }
    }

    #[test]
    fn constants_are_replicated_and_const_outputs_survive() {
        let mut g = Graph::new("constout");
        let x = g.placeholder("x", &[2]);
        let c = g.const_scalar(2.0);
        let ct = g.const_tensor(Tensor::new(vec![2], vec![5.0, 6.0]));
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let a = g.add_op(OpKind::Add, vec![m, ct]).unwrap();
        g.set_outputs(vec![a, ct]);
        let g = Arc::new(g);
        let parts = partition_by_ops(&g, 1);
        assert_eq!(parts.len(), 2);
        // Constants never appear as cross-partition inputs.
        for p in &parts {
            assert!(p.inputs.iter().all(|&i| !matches!(
                g.nodes[i].kind,
                NodeKind::ConstScalar(_) | NodeKind::ConstTensor(_)
            )));
        }
        let stitch_parts: Vec<StitchPart> = parts
            .into_iter()
            .enumerate()
            .map(|(i, part)| {
                let sub = extract(&g, &part, &format!("c.p{}", i)).unwrap();
                let module: Arc<dyn CompiledModule> = Arc::new(EagerModule::new(Arc::new(sub)));
                StitchPart { part, module }
            })
            .collect();
        let got = Stitcher::new(Arc::clone(&g), stitch_parts)
            .run(&[Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]))])
            .unwrap();
        assert_eq!(got[0].data(), &[7.0, 10.0]);
        assert_eq!(got[1].data(), &[5.0, 6.0]);
    }

    #[test]
    fn extracted_hash_is_per_partition_stable() {
        let g = mlp();
        let parts = partition_by_ops(&g, 2);
        let h1: Vec<u64> =
            parts.iter().enumerate().map(|(i, p)| extract(&g, p, &format!("a{}", i)).unwrap().content_hash()).collect();
        // Same structure under different names hashes identically.
        let h2: Vec<u64> =
            parts.iter().enumerate().map(|(i, p)| extract(&g, p, &format!("b{}", i)).unwrap().content_hash()).collect();
        assert_eq!(h1, h2);
        // Distinct partitions hash differently.
        assert!(h1.windows(2).all(|w| w[0] != w[1]), "{:?}", h1);
    }
}
