//! The `recording` backend wrapper: decorates any inner backend's
//! [`CompiledModule`] so every call is captured into a versioned,
//! self-contained [`TraceBundle`] (`__trace_*.json`, indexed in
//! `manifest.json` as [`ArtifactKind::Trace`]).
//!
//! This is the paper's "artifacts on disk faithfully reproduce what
//! happened in memory" promise extended to *execution*: a trace bundle
//! carries the lossless graph serialization, the guard context, the inner
//! module's stats and the bit-exact input/output tensors of every call —
//! enough to re-run the exact computation offline on any registered
//! backend ([`replay_bundle`], `depyf replay`) and to cross-check backends
//! against the eager oracle. Mismatches are localized per op by cutting
//! the graph into single-op partitions with the sharded partitioner and
//! replaying each against oracle intermediates ([`localize_divergence`]);
//! every divergence yields a minimized single-op repro bundle.

use std::rc::Rc;
use std::sync::{Arc, Mutex, PoisonError};

use crate::api::trace::{TraceBundle, TraceCall};
use crate::api::{
    ArtifactKind, Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError,
    FallbackPolicy, ModuleArtifact, ModuleStats,
};
use crate::graph::{Graph, NodeKind};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

use super::eager;
use super::partition::{extract, partition_by_ops};

/// Wraps an inner backend; every lowered module records its calls.
pub struct RecordingBackend {
    inner: Arc<dyn Backend>,
}

impl RecordingBackend {
    pub fn new(inner: Arc<dyn Backend>) -> RecordingBackend {
        RecordingBackend { inner }
    }

    /// Wrap a registered backend, looked up by name.
    pub fn wrapping(inner_name: &str) -> Result<RecordingBackend, DepyfError> {
        let inner = crate::api::lookup_backend(inner_name).ok_or_else(|| {
            DepyfError::Backend(format!(
                "recording: unknown inner backend '{}' (registered: {})",
                inner_name,
                crate::api::backend_names().join(", ")
            ))
        })?;
        Ok(RecordingBackend { inner })
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<dyn Backend> {
        &self.inner
    }
}

impl Backend for RecordingBackend {
    fn name(&self) -> &str {
        "recording"
    }

    /// Inherits everything the wrapped backend declares, plus `WRAPPER`.
    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities() | Capabilities::WRAPPER
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        // The plan is the inner backend's decision — recording adds no
        // compile-time structure, only runtime observation.
        self.inner.plan(req)
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let module = self.inner.lower(req, plan)?;
        Ok(Arc::new(RecordingModule {
            name: req.name.clone(),
            backend_name: format!("recording({})", module.backend_name()),
            inner_backend: module.backend_name().to_string(),
            graph: Arc::clone(&req.graph),
            guards: req.guards.clone(),
            cache_key: req.cache_key,
            inner: module,
            calls: Mutex::new(Vec::new()),
        }))
    }
}

/// A [`CompiledModule`] decorator that forwards `call` to the wrapped
/// module and appends a [`TraceCall`] per invocation. `artifacts()` emits
/// the trace bundle alongside whatever the inner module dumps.
pub struct RecordingModule {
    name: String,
    backend_name: String,
    inner_backend: String,
    graph: Arc<Graph>,
    guards: Vec<String>,
    cache_key: u64,
    inner: Arc<dyn CompiledModule>,
    /// Appended under a `Mutex`: concurrent callers record their calls in
    /// arrival order (any interleaving is a valid trace — each entry is
    /// self-contained).
    calls: Mutex<Vec<TraceCall>>,
}

/// The guard-entry id baked into a compiled fn's name (`__compiled_fn_N`
/// → `N`); falls back to the sanitized name for custom names. Trace file
/// names embed it *in addition to* the content hash: two guard entries
/// can wrap structurally identical graphs (same hash), and their traces
/// must not collide into one `(kind, name)` refresh slot.
fn entry_suffix(name: &str) -> String {
    let stem = name.trim_end_matches(|c: char| c.is_ascii_digit());
    if stem.len() == name.len() {
        super::sanitize(name)
    } else {
        name[stem.len()..].to_string()
    }
}

impl RecordingModule {
    /// Snapshot the recorded state as a self-contained bundle.
    pub fn bundle(&self) -> TraceBundle {
        TraceBundle {
            name: self.name.clone(),
            backend: self.inner_backend.clone(),
            cache_key: self.cache_key,
            guards: self.guards.clone(),
            stats: self.inner.stats(),
            graph: (*self.graph).clone(),
            calls: self.calls.lock().unwrap_or_else(PoisonError::into_inner).clone(),
        }
    }

    /// Calls recorded so far.
    pub fn recorded_calls(&self) -> usize {
        self.calls.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// The dump-dir file name for this module's trace: content hash for
    /// grouping, guard-entry id for uniqueness (see [`entry_suffix`]).
    pub fn trace_file_name(&self) -> String {
        format!("__trace_{:016x}_e{}.json", self.cache_key, entry_suffix(&self.name))
    }
}

impl CompiledModule for RecordingModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let outputs = self.inner.call(inputs)?;
        self.calls.lock().unwrap_or_else(PoisonError::into_inner).push(TraceCall {
            inputs: inputs.iter().map(|t| (**t).clone()).collect(),
            outputs: outputs.clone(),
            served_by: None,
        });
        Ok(outputs)
    }

    /// A call that failed on the wrapped module and was served by a
    /// fallback still lands in the trace — tagged with the backend that
    /// actually produced the outputs, so `depyf replay` can re-run it
    /// against the originally-requested backend later.
    fn record_degraded(&self, inputs: &[Rc<Tensor>], outputs: &[Tensor], served_by: &str) {
        self.calls.lock().unwrap_or_else(PoisonError::into_inner).push(TraceCall {
            inputs: inputs.iter().map(|t| (**t).clone()).collect(),
            outputs: outputs.to_vec(),
            served_by: Some(served_by.to_string()),
        });
    }

    fn backend_name(&self) -> &str {
        &self.backend_name
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        let mut arts = self.inner.artifacts();
        arts.push(ModuleArtifact {
            kind: ArtifactKind::Trace,
            name: self.name.clone(),
            file: self.trace_file_name(),
            content: self.bundle().to_json(),
        });
        arts
    }

    fn stats(&self) -> ModuleStats {
        self.inner.stats()
    }
}

// ---- replay ----

/// Options for [`replay_bundle`].
pub struct ReplayOptions {
    /// Comparison tolerance. `0.0` (the default) demands **bit equality**
    /// — identical f32 bit patterns, NaN payloads and -0.0 included. A
    /// positive eps compares `|a - b| <= eps` with NaN matching NaN (for
    /// backends like XLA whose fusion reorders float accumulation).
    pub eps: f32,
    /// Runtime handed to backends that lower to PJRT.
    pub runtime: Option<Arc<Runtime>>,
    /// Localize each mismatch to the first diverging op (slower: compiles
    /// one single-op subgraph per graph node).
    pub localize: bool,
    /// Optimizer level for the replay compile (`--opt-level`). Bundles
    /// always carry the *pre-optimizer* captured graph, so replaying the
    /// same trace at `O0` vs `O2` bisects optimizer/fusion miscompiles.
    pub opt_level: crate::graph::OptLevel,
}

impl Default for ReplayOptions {
    fn default() -> ReplayOptions {
        ReplayOptions {
            eps: 0.0,
            runtime: None,
            localize: true,
            opt_level: crate::graph::OptLevel::default(),
        }
    }
}

/// The first op at which a backend diverges from the eager oracle, plus a
/// minimized single-op repro bundle (the extracted subgraph with the
/// oracle's inputs/outputs for that op).
#[derive(Clone, Debug)]
pub struct CulpritOp {
    /// Node id in the original graph.
    pub node: usize,
    /// The op's method name (`relu`, `matmul`, ...).
    pub op: String,
    /// Max divergence observed at that op's output.
    pub diff: f32,
    /// Self-contained repro: single-op graph + the one call that diverges.
    pub repro: TraceBundle,
}

/// One replay mismatch.
#[derive(Clone, Debug)]
pub struct Mismatch {
    /// Index into the bundle's `calls`.
    pub call: usize,
    /// Output position within that call.
    pub output: usize,
    /// Max divergence (`f32::INFINITY` for shape/arity mismatches).
    pub diff: f32,
    /// Human-readable description of what diverged.
    pub detail: String,
    pub culprit: Option<CulpritOp>,
}

/// The outcome of replaying one bundle on one backend.
pub struct ReplayReport {
    pub name: String,
    /// Backend the bundle was re-executed on.
    pub backend: String,
    /// `Some(name)` in differential mode (reference recomputed by that
    /// backend) — `None` when the recorded outputs were the reference.
    pub against: Option<String>,
    pub calls: usize,
    pub mismatches: Vec<Mismatch>,
}

impl ReplayReport {
    pub fn ok(&self) -> bool {
        self.mismatches.is_empty()
    }

    /// One-paragraph human summary (the CLI's per-bundle output).
    pub fn render(&self) -> String {
        let reference = match &self.against {
            Some(o) => format!("against {}", o),
            None => "against recorded outputs".to_string(),
        };
        if self.ok() {
            return format!(
                "{}: OK — {} call(s) replayed on {} {} with no mismatch",
                self.name, self.calls, self.backend, reference
            );
        }
        let mut out = format!(
            "{}: {} mismatch(es) over {} call(s) on {} {}\n",
            self.name,
            self.mismatches.len(),
            self.calls,
            self.backend,
            reference
        );
        for m in &self.mismatches {
            out.push_str(&format!("  call {} output {}: {}\n", m.call, m.output, m.detail));
            if let Some(c) = &m.culprit {
                out.push_str(&format!(
                    "    first divergence at node v{} ({}), max |Δ| {:e}\n",
                    c.node, c.op, c.diff
                ));
            }
        }
        out.pop();
        out
    }
}

/// Compare two tensors under the replay tolerance. `None` = match;
/// `Some(diff)` = mismatch with the max observed divergence.
pub fn tensor_diff(a: &Tensor, b: &Tensor, eps: f32) -> Option<f32> {
    if a.shape() != b.shape() {
        return Some(f32::INFINITY);
    }
    let mut worst: Option<f32> = None;
    for (x, y) in a.data().iter().zip(b.data().iter()) {
        // Identical bits always match — also the eps path, so equal
        // infinities don't fall into the NaN-producing subtraction below.
        let matches = x.to_bits() == y.to_bits()
            || (eps > 0.0 && ((x.is_nan() && y.is_nan()) || (x - y).abs() <= eps));
        if !matches {
            let d = (x - y).abs();
            let d = if d.is_nan() { f32::INFINITY } else { d };
            worst = Some(worst.map_or(d, |w: f32| w.max(d)));
        }
    }
    worst
}

/// Run the eager oracle over the graph, returning the value of **every**
/// node (placeholders, consts and op results) — the per-op ground truth
/// [`localize_divergence`] checks backends against.
fn oracle_env(graph: &Graph, inputs: &[Rc<Tensor>]) -> Result<Vec<Option<Tensor>>, DepyfError> {
    let mut env: Vec<Option<Tensor>> = vec![None; graph.nodes.len()];
    for (&slot, input) in graph.inputs.iter().zip(inputs.iter()) {
        env[slot] = Some((**input).clone());
    }
    for (id, node) in graph.nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::ConstScalar(v) => env[id] = Some(Tensor::scalar(*v as f32)),
            NodeKind::ConstTensor(t) => env[id] = Some(t.clone()),
            _ => {}
        }
    }
    let mut op_values: Vec<(usize, Tensor)> = Vec::new();
    eager::execute_traced(graph, inputs, |id, v| op_values.push((id, v.clone())))?;
    for (id, v) in op_values {
        env[id] = Some(v);
    }
    Ok(env)
}

/// Localize a divergence to the first op where `backend` disagrees with
/// the eager oracle: the graph is cut into **single-op partitions** with
/// the sharded partitioner, each partition is extracted as a standalone
/// subgraph, compiled by `backend`, and fed the *oracle's* values for its
/// inputs — so a divergence at op k cannot be masked or amplified by an
/// earlier one. Returns `None` when every op matches in isolation (the
/// divergence only manifests composed, e.g. fused accumulation order).
pub fn localize_divergence(
    graph: &Arc<Graph>,
    inputs: &[Rc<Tensor>],
    backend: &dyn Backend,
    opts: &ReplayOptions,
) -> Result<Option<CulpritOp>, DepyfError> {
    let env = oracle_env(graph, inputs)?;
    for part in partition_by_ops(graph, 1) {
        let node = *part.nodes.first().expect("single-op partition");
        let sub = Arc::new(extract(graph, &part, &format!("{}.v{}", graph.name, node))?);
        let sub_name = sub.name.clone();
        let req = CompileRequest::new(&sub_name, Arc::clone(&sub))
            .with_runtime(opts.runtime.clone())
            .with_fallback(FallbackPolicy::Error)
            .with_opt_level(opts.opt_level);
        let module = backend.compile(&req)?;
        let part_inputs: Result<Vec<Rc<Tensor>>, DepyfError> = part
            .inputs
            .iter()
            .map(|&id| {
                env[id]
                    .clone()
                    .map(Rc::new)
                    .ok_or_else(|| DepyfError::Backend(format!("localize: node {} unevaluated", id)))
            })
            .collect();
        let part_inputs = part_inputs?;
        let got = module.call(&part_inputs)?;
        for (&out_id, out_t) in part.outputs.iter().zip(got.iter()) {
            let want = env[out_id]
                .as_ref()
                .ok_or_else(|| DepyfError::Backend(format!("localize: node {} unevaluated", out_id)))?;
            if let Some(diff) = tensor_diff(out_t, want, opts.eps) {
                let op = match &graph.nodes[node].kind {
                    NodeKind::Op(op, _) => op.method_name().to_string(),
                    other => format!("{:?}", other),
                };
                let repro = TraceBundle {
                    name: sub.name.clone(),
                    backend: backend.name().to_string(),
                    cache_key: sub.content_hash(),
                    guards: Vec::new(),
                    stats: module.stats(),
                    graph: (*sub).clone(),
                    calls: vec![TraceCall {
                        inputs: part_inputs.iter().map(|t| (**t).clone()).collect(),
                        outputs: part
                            .outputs
                            .iter()
                            .map(|&id| env[id].clone().expect("checked above"))
                            .collect(),
                        served_by: None,
                    }],
                };
                return Ok(Some(CulpritOp { node, op, diff, repro }));
            }
        }
    }
    Ok(None)
}

/// A bundle holding only one recorded call — the minimal whole-graph
/// repro `replay` and the conformance harness dump on mismatch.
pub fn single_call_bundle(bundle: &TraceBundle, call: usize) -> TraceBundle {
    TraceBundle { calls: vec![bundle.calls[call].clone()], ..bundle.clone() }
}

/// Re-execute a recorded bundle on `backend`.
///
/// * `oracle == None`: the **recorded outputs** are the reference — "does
///   this backend still produce what was observed at record time?"
/// * `oracle == Some(b)`: differential mode (`--against eager`) — the
///   reference is recomputed by `b` on the recorded inputs, so two
///   backends are compared on exactly the captured workload.
///
/// Backend failures propagate as errors (no silent eager degrade: a
/// replay that cannot run the requested backend is a failed replay).
pub fn replay_bundle(
    bundle: &TraceBundle,
    backend: &dyn Backend,
    oracle: Option<&dyn Backend>,
    opts: &ReplayOptions,
) -> Result<ReplayReport, DepyfError> {
    let graph = Arc::new(bundle.graph.clone());
    let req = CompileRequest::new(&bundle.name, Arc::clone(&graph))
        .with_runtime(opts.runtime.clone())
        .with_guards(bundle.guards.clone())
        .with_fallback(FallbackPolicy::Error)
        .with_opt_level(opts.opt_level);
    let module = backend.compile(&req)?;
    let oracle_module = match oracle {
        Some(o) => Some(o.compile(&req)?),
        None => None,
    };
    let mut mismatches = Vec::new();
    for (ci, call) in bundle.calls.iter().enumerate() {
        let inputs: Vec<Rc<Tensor>> = call.inputs.iter().cloned().map(Rc::new).collect();
        let got = module.call(&inputs)?;
        let reference: Vec<Tensor> = match &oracle_module {
            Some(om) => om.call(&inputs)?,
            None => call.outputs.clone(),
        };
        if got.len() != reference.len() {
            mismatches.push(Mismatch {
                call: ci,
                output: 0,
                diff: f32::INFINITY,
                detail: format!("arity mismatch: {} outputs vs {} expected", got.len(), reference.len()),
                culprit: None,
            });
            continue;
        }
        let mut diverged = false;
        for (oi, (g, r)) in got.iter().zip(reference.iter()).enumerate() {
            if let Some(diff) = tensor_diff(g, r, opts.eps) {
                let mut detail = if g.shape() != r.shape() {
                    format!("shape mismatch: {:?} vs {:?}", g.shape(), r.shape())
                } else {
                    format!("max |Δ| {:e} (eps {:e})", diff, opts.eps)
                };
                // Localize once per diverging call (the per-op sweep covers
                // every output of the graph at once). A failed localization
                // is reported, not silently conflated with "every op
                // matches in isolation".
                let culprit = if opts.localize && !diverged {
                    match localize_divergence(&graph, &inputs, backend, opts) {
                        Ok(c) => c,
                        Err(e) => {
                            detail.push_str(&format!(" (localization failed: {})", e));
                            None
                        }
                    }
                } else {
                    None
                };
                diverged = true;
                mismatches.push(Mismatch { call: ci, output: oi, diff, detail, culprit });
            }
        }
    }
    Ok(ReplayReport {
        name: bundle.name.clone(),
        backend: backend.name().to_string(),
        against: oracle.map(|o| o.name().to_string()),
        calls: bundle.calls.len(),
        mismatches,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EagerBackend;
    use crate::backend::eager::EagerModule;
    use crate::graph::OpKind;
    use crate::hijack::DumpDir;
    use crate::tensor::Rng;

    fn chain_graph(name: &str) -> Arc<Graph> {
        let mut g = Graph::new(name);
        let x = g.placeholder("x", &[2, 3]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let e = g.add_op(OpKind::Exp, vec![r]).unwrap();
        let n = g.add_op(OpKind::Neg, vec![e]).unwrap();
        g.set_outputs(vec![n]);
        Arc::new(g)
    }

    fn rand_inputs(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
        let mut rng = Rng::new(seed);
        g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng))).collect()
    }

    #[test]
    fn wrapper_inherits_capabilities_and_registers() {
        let rec = RecordingBackend::new(Arc::new(crate::backend::ShardedBackend::new()));
        assert!(rec.capabilities().contains(Capabilities::WRAPPER));
        assert!(rec.capabilities().contains(Capabilities::PARTITION));
        assert!(!rec.requires_runtime());
        // The default registered instance wraps eager.
        let reg = crate::api::lookup_backend("recording").expect("registered");
        assert!(reg.capabilities().contains(Capabilities::WRAPPER));
        assert!(RecordingBackend::wrapping("batched").is_ok());
        assert!(RecordingBackend::wrapping("no-such").is_err());
    }

    #[test]
    fn record_then_replay_round_trips_through_text() {
        let g = chain_graph("__compiled_fn_1");
        let req = CompileRequest::new("__compiled_fn_1", Arc::clone(&g))
            .with_guards(vec!["check_tensor(args[0], shape=[2, 3])".into()]);
        let rec = RecordingBackend::new(Arc::new(EagerBackend));
        let module = rec.compile(&req).unwrap();
        assert_eq!(module.backend_name(), "recording(eager)");
        for seed in [1u64, 2, 3] {
            module.call(&rand_inputs(&g, seed)).unwrap();
        }
        let arts = module.artifacts();
        let trace = arts.iter().find(|a| a.kind == ArtifactKind::Trace).expect("trace artifact");
        assert_eq!(trace.name, "__compiled_fn_1");
        assert!(trace.file.starts_with("__trace_") && trace.file.ends_with("_e1.json"), "{}", trace.file);
        // The bundle survives the text round-trip and replays clean.
        let bundle = TraceBundle::parse(&trace.content).unwrap();
        assert_eq!(bundle.calls.len(), 3);
        assert_eq!(bundle.backend, "eager");
        assert_eq!(bundle.guards.len(), 1);
        let report = replay_bundle(&bundle, &EagerBackend, None, &ReplayOptions::default()).unwrap();
        assert!(report.ok(), "{}", report.render());
        assert!(report.render().contains("OK"));
        // Differential mode against the same backend is also clean.
        let diff = replay_bundle(&bundle, &EagerBackend, Some(&EagerBackend), &ReplayOptions::default())
            .unwrap();
        assert!(diff.ok());
        assert_eq!(diff.against.as_deref(), Some("eager"));
    }

    /// Satellite: fusion/optimization live *below* the trace format.
    /// Bundles serialize the pre-optimizer captured graph (hash intact),
    /// and replaying them at any opt level reproduces the recorded bits.
    #[test]
    fn bundles_carry_the_preoptimizer_graph_and_replay_at_any_level() {
        use crate::graph::OptLevel;
        // A graph the optimizer definitely rewrites: const subexpression,
        // double-neg, and a fusible elementwise chain.
        let mut g = Graph::new("__compiled_fn_3");
        let x = g.placeholder("x", &[2, 3]);
        let c1 = g.const_scalar(2.0);
        let c2 = g.const_scalar(3.0);
        let cc = g.add_op(OpKind::Add, vec![c1, c2]).unwrap();
        let t = g.add_op(OpKind::Mul, vec![x, cc]).unwrap();
        let n1 = g.add_op(OpKind::Neg, vec![t]).unwrap();
        let n2 = g.add_op(OpKind::Neg, vec![n1]).unwrap();
        let r = g.add_op(OpKind::Gelu, vec![n2]).unwrap();
        g.set_outputs(vec![r]);
        let g = Arc::new(g);
        let opt = crate::graph::optimize(&g, OptLevel::O2);
        assert!(opt.changed(), "test graph must actually optimize");

        let req = CompileRequest::new("__compiled_fn_3", Arc::clone(&g));
        let module = RecordingBackend::new(Arc::new(EagerBackend)).compile(&req).unwrap();
        module.call(&rand_inputs(&g, 21)).unwrap();
        let trace = module.artifacts().into_iter().find(|a| a.kind == ArtifactKind::Trace).unwrap();
        let bundle = TraceBundle::parse(&trace.content).unwrap();
        // The bundle's graph is the ORIGINAL capture, not the optimized one.
        assert_eq!(bundle.graph.content_hash(), g.content_hash());
        assert_ne!(bundle.graph.content_hash(), opt.graph.content_hash());
        // Replays are clean (bitwise) at every opt level.
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
            let opts = ReplayOptions { opt_level: level, ..Default::default() };
            let report = replay_bundle(&bundle, &EagerBackend, None, &opts).unwrap();
            assert!(report.ok(), "level {}: {}", level, report.render());
        }
    }

    #[test]
    fn replay_detects_tampered_outputs() {
        let g = chain_graph("__compiled_fn_1");
        let req = CompileRequest::new("__compiled_fn_1", Arc::clone(&g));
        let module = RecordingBackend::new(Arc::new(EagerBackend)).compile(&req).unwrap();
        module.call(&rand_inputs(&g, 9)).unwrap();
        let trace = module.artifacts().into_iter().find(|a| a.kind == ArtifactKind::Trace).unwrap();
        let mut bundle = TraceBundle::parse(&trace.content).unwrap();
        // Corrupt one recorded output value.
        let t = &bundle.calls[0].outputs[0];
        let mut data = t.data().to_vec();
        data[0] += 0.5;
        bundle.calls[0].outputs[0] = Tensor::new(t.shape().to_vec(), data);
        let report = replay_bundle(&bundle, &EagerBackend, None, &ReplayOptions::default()).unwrap();
        assert_eq!(report.mismatches.len(), 1);
        assert!((report.mismatches[0].diff - 0.5).abs() < 1e-4, "{}", report.mismatches[0].diff);
        // Under a generous eps the same replay passes.
        let lax = ReplayOptions { eps: 1.0, ..Default::default() };
        assert!(replay_bundle(&bundle, &EagerBackend, None, &lax).unwrap().ok());
        // Differential mode ignores recorded outputs: still clean.
        let diff = replay_bundle(&bundle, &EagerBackend, Some(&EagerBackend), &ReplayOptions::default())
            .unwrap();
        assert!(diff.ok());
    }

    /// A deliberately wrong backend: every `exp` result is off by one (the
    /// error propagates downstream, like a real miscompiled kernel would).
    struct BuggyExp;

    fn sabotage_exp(g: &Graph) -> Graph {
        let mut out = Graph::new(&g.name);
        let mut map = vec![0usize; g.nodes.len()];
        for (id, node) in g.nodes.iter().enumerate() {
            map[id] = match &node.kind {
                NodeKind::Placeholder { name } => out.placeholder(name, &node.shape),
                NodeKind::ConstScalar(v) => out.const_scalar(*v),
                NodeKind::ConstTensor(t) => out.const_tensor(t.clone()),
                NodeKind::Op(op, args) => {
                    let margs = args.iter().map(|a| map[*a]).collect();
                    let n = out.add_op(op.clone(), margs).unwrap();
                    if matches!(op, OpKind::Exp) {
                        let one = out.const_scalar(1.0);
                        out.add_op(OpKind::Add, vec![n, one]).unwrap()
                    } else {
                        n
                    }
                }
            };
        }
        out.set_outputs(g.outputs.iter().map(|o| map[*o]).collect());
        out
    }

    impl Backend for BuggyExp {
        fn name(&self) -> &str {
            "buggy-exp"
        }
        fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
            Ok(CompilePlan::monolithic("buggy-exp", req, "eager"))
        }
        fn lower(
            &self,
            req: &CompileRequest,
            _plan: &CompilePlan,
        ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
            let wrong = Arc::new(sabotage_exp(&req.graph));
            Ok(Arc::new(EagerModule::with_name(wrong, "buggy-exp".into())))
        }
    }

    #[test]
    fn localization_names_the_diverging_op() {
        let g = chain_graph("__compiled_fn_2");
        // Record ground truth with the honest eager backend.
        let req = CompileRequest::new("__compiled_fn_2", Arc::clone(&g));
        let module = RecordingBackend::new(Arc::new(EagerBackend)).compile(&req).unwrap();
        module.call(&rand_inputs(&g, 4)).unwrap();
        let bundle = TraceBundle::parse(
            &module.artifacts().into_iter().find(|a| a.kind == ArtifactKind::Trace).unwrap().content,
        )
        .unwrap();
        // Replay on the buggy backend: the graph ends in neg(exp(relu(x))),
        // so the end-to-end output diverges and the per-op sweep must pin
        // the exp node (id 2), not relu or neg.
        let report = replay_bundle(&bundle, &BuggyExp, None, &ReplayOptions::default()).unwrap();
        assert_eq!(report.mismatches.len(), 1, "{}", report.render());
        let culprit = report.mismatches[0].culprit.as_ref().expect("localized");
        assert_eq!(culprit.op, "exp");
        assert_eq!(culprit.node, 2);
        assert!((culprit.diff - 1.0).abs() < 1e-4, "{}", culprit.diff);
        // The minimized repro is itself a valid, replayable bundle that
        // reproduces the divergence in one op.
        let repro = TraceBundle::parse(&culprit.repro.to_json()).unwrap();
        assert_eq!(repro.graph.num_ops(), 1);
        assert_eq!(repro.calls.len(), 1);
        assert!(replay_bundle(&repro, &EagerBackend, None, &ReplayOptions::default()).unwrap().ok());
        let rerun = replay_bundle(&repro, &BuggyExp, None, &ReplayOptions::default()).unwrap();
        assert_eq!(rerun.mismatches.len(), 1);
        assert!(report.render().contains("exp"), "{}", report.render());
    }

    /// Tentpole satellite: a degraded call is still traced, tagged with
    /// the backend that actually served it, and the tag survives the text
    /// round-trip for `depyf replay --backend recorded`.
    #[test]
    fn degraded_calls_are_traced_with_their_serving_backend() {
        let g = chain_graph("__compiled_fn_4");
        let req = CompileRequest::new("__compiled_fn_4", Arc::clone(&g));
        let module = RecordingBackend::new(Arc::new(EagerBackend)).compile(&req).unwrap();
        let inputs = rand_inputs(&g, 13);
        let outputs = module.call(&inputs).unwrap();
        module.record_degraded(&inputs, &outputs, "eager (xla call fallback)");
        let trace = module.artifacts().into_iter().find(|a| a.kind == ArtifactKind::Trace).unwrap();
        let bundle = TraceBundle::parse(&trace.content).unwrap();
        assert_eq!(bundle.calls.len(), 2);
        assert_eq!(bundle.calls[0].served_by, None);
        assert_eq!(bundle.calls[1].served_by.as_deref(), Some("eager (xla call fallback)"));
        // The degraded call replays like any other (outputs are real).
        let report = replay_bundle(&bundle, &EagerBackend, None, &ReplayOptions::default()).unwrap();
        assert!(report.ok(), "{}", report.render());
    }

    #[test]
    fn tensor_diff_is_bitwise_at_eps_zero() {
        let a = Tensor::new(vec![2], vec![0.0, f32::NAN]);
        let b = Tensor::new(vec![2], vec![-0.0, f32::NAN]);
        // -0.0 differs bitwise from 0.0; identical NaN payloads match.
        assert!(tensor_diff(&a, &b, 0.0).is_some());
        assert!(tensor_diff(&a, &a, 0.0).is_none());
        // eps mode: -0.0 ≈ 0.0 and NaN pairs with NaN.
        assert!(tensor_diff(&a, &b, 1e-9).is_none());
        // Shape mismatches are infinite.
        let c = Tensor::new(vec![1, 2], vec![0.0, f32::NAN]);
        assert_eq!(tensor_diff(&a, &c, 0.0), Some(f32::INFINITY));
    }

    /// Satellite: two guard entries wrapping structurally identical graphs
    /// share one content hash — their trace artifacts must land in two
    /// files, not refresh each other's.
    #[test]
    fn trace_files_do_not_collide_on_shared_content_hash() {
        let g1 = chain_graph("__compiled_fn_1");
        let g2 = chain_graph("__compiled_fn_2");
        assert_eq!(g1.content_hash(), g2.content_hash(), "same structure must share a hash");
        let rec = RecordingBackend::new(Arc::new(EagerBackend));
        let m1 = rec.compile(&CompileRequest::new("__compiled_fn_1", Arc::clone(&g1))).unwrap();
        let m2 = rec.compile(&CompileRequest::new("__compiled_fn_2", Arc::clone(&g2))).unwrap();
        m1.call(&rand_inputs(&g1, 1)).unwrap();
        m2.call(&rand_inputs(&g2, 2)).unwrap();
        m2.call(&rand_inputs(&g2, 3)).unwrap();
        // Mirror Session::finish(): module artifacts flow through the
        // (kind, name)-keyed refresh writer.
        let dir = std::env::temp_dir().join(format!("depyf_trace_collide_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dump = DumpDir::create(&dir).unwrap();
        for m in [&m1, &m2] {
            for art in m.artifacts() {
                dump.write_refresh(art.kind, &art.name, &art.file, &art.content).unwrap();
            }
        }
        let traces: Vec<_> =
            dump.artifacts().into_iter().filter(|a| a.kind == ArtifactKind::Trace).collect();
        assert_eq!(traces.len(), 2, "each entry keeps its own trace file: {:?}", traces);
        assert_ne!(traces[0].path, traces[1].path);
        let b1 = TraceBundle::load(&traces[0].path).unwrap();
        let b2 = TraceBundle::load(&traces[1].path).unwrap();
        assert_eq!(b1.calls.len(), 1);
        assert_eq!(b2.calls.len(), 2, "second entry's calls must not be clobbered by the first");
        std::fs::remove_dir_all(&dir).ok();
    }
}
