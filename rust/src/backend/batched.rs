//! The `batched` backend: pads/buckets the dynamic leading dim so **one
//! executable serves multiple guard entries**.
//!
//! Guard entries specialize on exact shapes, so a model called with batch
//! sizes 5, 6, 7 and 8 normally compiles four executables. This backend
//! runs a conservative *batch-safety analysis* over the captured graph: a
//! node is `batched` when its leading dim equals the batch size **and**
//! every op touching it is row-wise along that dim (elementwise chains,
//! `[B,K] @ [K,N]` matmuls, per-row softmax/layernorm, axis≥1 reductions,
//! embedding lookups). If the whole graph passes, inputs are padded with
//! zero rows up to the next power-of-two bucket, the **padded** graph is
//! compiled (its `content_hash` is the compile-cache key, so every guard
//! entry in the same bucket reuses one executable — the PR 2 cache, per
//! bucket), and batched outputs are sliced back to the true batch. Rows
//! below the pad are bitwise identical to the unpadded execution. Graphs
//! that fail the analysis compile exactly (no padding) — correctness is
//! never traded for reuse.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Mutex, PoisonError};

use crate::api::{
    ArtifactKind, Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError,
    ModuleArtifact, ModuleStats,
};
use crate::api::plan::BatchPlan;
use crate::graph::{Graph, NodeKind, OpKind};
use crate::tensor::Tensor;

use super::eager::ExecPlan;
use super::xla;

/// Result of the batch-safety analysis: the batch size and, per node,
/// whether its leading dim carries the batch.
struct BatchInfo {
    batch: usize,
    flags: Vec<bool>,
}

/// Decide which nodes are batched along dim 0, or `None` when any op uses
/// a batched value in a non-row-wise way (reductions over dim 0,
/// transposes that move it, contractions against it...).
fn analyze(g: &Graph) -> Option<BatchInfo> {
    // The batch size: dim 0 of the first rank>=1 placeholder.
    let batch = g.inputs.iter().find_map(|&id| match &g.nodes[id].kind {
        NodeKind::Placeholder { .. } if !g.nodes[id].shape.is_empty() => Some(g.nodes[id].shape[0]),
        _ => None,
    })?;
    if batch == 0 {
        return None;
    }
    let mut flags = vec![false; g.nodes.len()];
    for (id, node) in g.nodes.iter().enumerate() {
        flags[id] = match &node.kind {
            NodeKind::Placeholder { .. } => !node.shape.is_empty() && node.shape[0] == batch,
            NodeKind::ConstScalar(_) | NodeKind::ConstTensor(_) => false,
            NodeKind::Op(op, args) => {
                let f = |i: usize| flags[args[i]];
                let shape = |i: usize| g.nodes[args[i]].shape.as_slice();
                let out = &node.shape;
                match op {
                    OpKind::Add
                    | OpKind::Sub
                    | OpKind::Mul
                    | OpKind::Div
                    | OpKind::Pow
                    | OpKind::Maximum
                    | OpKind::Minimum => {
                        let out_b = f(0) || f(1);
                        if out_b {
                            for i in 0..2 {
                                if f(i) {
                                    // A batched operand must align rank-for-rank
                                    // so its dim 0 is the output's dim 0.
                                    if shape(i).len() != out.len() {
                                        return None;
                                    }
                                } else if shape(i).len() == out.len() && shape(i)[0] != 1 {
                                    // Full-rank unbatched operand spanning the
                                    // batch dim: padding would misalign it.
                                    return None;
                                }
                            }
                        }
                        out_b
                    }
                    OpKind::Neg
                    | OpKind::Relu
                    | OpKind::Gelu
                    | OpKind::Tanh
                    | OpKind::Sigmoid
                    | OpKind::Exp
                    | OpKind::Log
                    | OpKind::Sqrt
                    | OpKind::Abs => f(0),
                    OpKind::Softmax => {
                        if f(0) && shape(0).len() < 2 {
                            return None; // softmax over the batch dim itself
                        }
                        f(0)
                    }
                    OpKind::MatMul => match (f(0), f(1)) {
                        (false, false) => false,
                        // [B,..,K] @ [K,N]: rows of the result come from rows
                        // of the batched lhs.
                        (true, false) => {
                            if shape(1).len() == 2 {
                                true
                            } else {
                                return None;
                            }
                        }
                        // Batched rhs: its dim 0 is contracted (rank 2) or a
                        // batch dim that must match an unbatched lhs — unsafe.
                        (false, true) => return None,
                        // Both batched: dim 0 must be a shared batch dim.
                        (true, true) => {
                            if shape(0).len() == shape(1).len() && shape(0).len() >= 3 {
                                true
                            } else {
                                return None;
                            }
                        }
                    },
                    OpKind::Transpose => {
                        if f(0) {
                            if shape(0).len() >= 3 {
                                true
                            } else {
                                return None; // rank-2 transpose moves dim 0
                            }
                        } else {
                            false
                        }
                    }
                    OpKind::Permute(perm) => {
                        if f(0) {
                            if perm.first() == Some(&0) {
                                true
                            } else {
                                return None;
                            }
                        } else {
                            false
                        }
                    }
                    OpKind::Reshape(spec) => {
                        if f(0) {
                            // Row-preserving reshape only: [-1, rest] where
                            // rest covers exactly one input row.
                            let row: usize = shape(0)[1..].iter().product();
                            let rest: i64 = spec[1..].iter().product();
                            if spec.first() == Some(&-1)
                                && spec[1..].iter().all(|&d| d > 0)
                                && rest == row as i64
                            {
                                true
                            } else {
                                return None;
                            }
                        } else {
                            false
                        }
                    }
                    OpKind::Sum(ax) | OpKind::Mean(ax) | OpKind::Max(ax) | OpKind::Min(ax) => {
                        if f(0) {
                            match ax {
                                Some(a) if *a >= 1 => true,
                                _ => return None, // reduces over/through dim 0
                            }
                        } else {
                            false
                        }
                    }
                    OpKind::LayerNorm => {
                        if f(1) || f(2) {
                            return None; // padded params would be wrong
                        }
                        if f(0) {
                            if shape(0).len() >= 2 {
                                true
                            } else {
                                return None;
                            }
                        } else {
                            false
                        }
                    }
                    OpKind::Embedding => {
                        if f(0) {
                            return None; // padded table rows change lookups
                        }
                        f(1) // padded ids are 0 → valid rows, sliced away
                    }
                    OpKind::CrossEntropy => {
                        if f(0) || f(1) {
                            return None; // mean over rows mixes padding in
                        }
                        false
                    }
                }
            }
        };
    }
    if g.inputs.iter().any(|&id| flags[id]) {
        Some(BatchInfo { batch, flags })
    } else {
        None
    }
}

/// Rebuild the graph with every batched node's leading dim padded to
/// `bucket`. Node ids are preserved 1:1. Fails (→ exact compile) if shape
/// inference disagrees with the analysis.
fn pad_graph(g: &Graph, info: &BatchInfo, bucket: usize) -> Option<Graph> {
    let mut padded = Graph::new(&g.name);
    for (id, node) in g.nodes.iter().enumerate() {
        let expect: Vec<usize> = if info.flags[id] {
            let mut s = node.shape.clone();
            s[0] = bucket;
            s
        } else {
            node.shape.clone()
        };
        let new_id = match &node.kind {
            NodeKind::Placeholder { name } => padded.placeholder(name, &expect),
            NodeKind::ConstScalar(v) => padded.const_scalar(*v),
            NodeKind::ConstTensor(t) => padded.const_tensor(t.clone()),
            NodeKind::Op(op, args) => padded.add_op(op.clone(), args.clone()).ok()?,
        };
        debug_assert_eq!(new_id, id);
        if padded.nodes[new_id].shape != expect {
            return None;
        }
    }
    padded.set_outputs(g.outputs.clone());
    Some(padded)
}

fn bucket_of(batch: usize) -> usize {
    batch.next_power_of_two()
}

/// Rebuild the padded graph from a plan's [`BatchPlan`] alone (no
/// re-analysis): the flagged input placeholders get the bucket dim and
/// every op shape re-infers from there. `lower` uses this so the plan —
/// not a second analysis pass — is the source of truth.
fn pad_graph_from_plan(g: &Graph, b: &BatchPlan) -> Result<Graph, DepyfError> {
    let padded_ids: Vec<usize> = b.padded_inputs.iter().map(|&pos| g.inputs[pos]).collect();
    let mut padded = Graph::new(&g.name);
    for (id, node) in g.nodes.iter().enumerate() {
        let new_id = match &node.kind {
            NodeKind::Placeholder { name } => {
                let mut shape = node.shape.clone();
                if padded_ids.contains(&id) {
                    shape[b.dim] = b.bucket;
                }
                padded.placeholder(name, &shape)
            }
            NodeKind::ConstScalar(v) => padded.const_scalar(*v),
            NodeKind::ConstTensor(t) => padded.const_tensor(t.clone()),
            NodeKind::Op(op, args) => padded.add_op(op.clone(), args.clone()).map_err(|e| {
                DepyfError::Backend(format!("batched: padded graph no longer infers: {}", e))
            })?,
        };
        debug_assert_eq!(new_id, id);
    }
    padded.set_outputs(g.outputs.clone());
    Ok(padded)
}

fn pad_rows(t: &Tensor, bucket: usize) -> Tensor {
    let mut shape = t.shape().to_vec();
    let row: usize = shape[1..].iter().product::<usize>().max(1);
    let mut data = t.data().to_vec();
    data.resize(bucket * row, 0.0);
    shape[0] = bucket;
    Tensor::new(shape, data)
}

fn slice_rows(t: &Tensor, orig: usize) -> Tensor {
    let mut shape = t.shape().to_vec();
    let row: usize = shape[1..].iter().product::<usize>().max(1);
    let data = t.data()[..orig * row].to_vec();
    shape[0] = orig;
    Tensor::new(shape, data)
}

/// The `batched` backend. Holds a per-bucket cache of eager execution
/// plans keyed on (padded-graph content hash, fusion flag) — the PJRT
/// path reuses the runtime's own content-hash cache.
pub struct BatchedBackend {
    /// `Mutex` (not `RefCell`): the backend sits in the process-wide
    /// registry, so guard entries on different threads may lower into the
    /// same bucket concurrently.
    eager_plans: Mutex<HashMap<(u64, bool), Arc<ExecPlan>>>,
}

impl Default for BatchedBackend {
    fn default() -> Self {
        BatchedBackend::new()
    }
}

impl BatchedBackend {
    pub fn new() -> BatchedBackend {
        BatchedBackend { eager_plans: Mutex::new(HashMap::new()) }
    }
}

impl Backend for BatchedBackend {
    fn name(&self) -> &str {
        "batched"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::DYNAMIC_BATCH | Capabilities::USES_RUNTIME
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendPlan)?;
        // Batch-safety analysis and padding run on the *optimized* graph;
        // the monolithic fallback already plans it.
        let opt = req.optimized();
        let g = &opt.graph;
        let target = if req.runtime.is_some() { "xla" } else { "eager" };
        let padded = analyze(g).and_then(|info| {
            let bucket = bucket_of(info.batch);
            pad_graph(g, &info, bucket).map(|p| (info, bucket, p))
        });
        let Some((info, bucket, padded)) = padded else {
            // Not batch-safe: compile the exact shapes, no padding.
            return Ok(CompilePlan::monolithic("batched", req, target));
        };
        let mut plan = CompilePlan::monolithic("batched", req, target);
        plan.partitions[0].cache_key = padded.content_hash();
        plan.batch = Some(BatchPlan {
            dim: 0,
            orig: info.batch,
            bucket,
            padded_inputs: (0..g.inputs.len()).filter(|&i| info.flags[g.inputs[i]]).collect(),
            sliced_outputs: (0..g.outputs.len()).filter(|&i| info.flags[g.outputs[i]]).collect(),
        });
        Ok(plan)
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendLower)?;
        let opt = req.optimized();
        let target = plan.partitions.first().map(|p| p.target.as_str()).unwrap_or("eager");
        let (exec_graph, batch) = match &plan.batch {
            Some(b) => (Arc::new(pad_graph_from_plan(&opt.graph, b)?), Some(b.clone())),
            None => (Arc::clone(&opt.graph), None),
        };
        let mut cache_hits = 0u64;
        let inner: Arc<dyn CompiledModule> = match target {
            "xla" => {
                let rt = req.runtime.as_ref().ok_or_else(|| {
                    DepyfError::Backend("batched: plan targets xla but no runtime was provided".into())
                })?;
                let inner_name = match &batch {
                    Some(b) => format!("{}@b{}", req.name, b.bucket),
                    None => req.name.clone(),
                };
                let module = xla::compile_module(&inner_name, &exec_graph, rt)?;
                cache_hits += module.cache_hit as u64;
                Arc::new(module)
            }
            _ => {
                let key = (exec_graph.content_hash(), req.opt_level.fuses());
                // Plan-building happens outside the lock; a racing thread
                // may build the same plan, but the map stays consistent and
                // both plans execute identically (last insert wins).
                let cached =
                    self.eager_plans.lock().unwrap_or_else(PoisonError::into_inner).get(&key).cloned();
                let plan_arc = match cached {
                    Some(p) => {
                        cache_hits += 1;
                        p
                    }
                    None => {
                        let p = Arc::new(ExecPlan::with_fusion(
                            Arc::clone(&exec_graph),
                            req.opt_level.fuses(),
                        ));
                        self.eager_plans
                            .lock()
                            .unwrap_or_else(PoisonError::into_inner)
                            .insert(key, Arc::clone(&p));
                        p
                    }
                };
                Arc::new(SharedPlanModule { plan: plan_arc })
            }
        };
        Ok(Arc::new(BatchedModule {
            graph: Arc::clone(&opt.graph),
            inner,
            batch,
            plan_json: plan.to_json(),
            name: req.name.clone(),
            cache_hits,
        }))
    }
}

/// An eager [`ExecPlan`] shared (via `Arc`) across every guard entry whose
/// padded graph lands in the same bucket.
struct SharedPlanModule {
    plan: Arc<ExecPlan>,
}

impl CompiledModule for SharedPlanModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.plan.run(inputs)
    }

    fn backend_name(&self) -> &str {
        "eager"
    }
}

/// The lowered batched module: pad flagged inputs to the bucket, run the
/// shared inner executable, slice flagged outputs back.
pub struct BatchedModule {
    graph: Arc<Graph>,
    inner: Arc<dyn CompiledModule>,
    batch: Option<BatchPlan>,
    plan_json: String,
    name: String,
    cache_hits: u64,
}

impl CompiledModule for BatchedModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.graph.check_inputs(inputs)?;
        let Some(b) = &self.batch else {
            return self.inner.call(inputs);
        };
        // Already at the bucket size (power-of-two batch): padding and
        // slicing would copy every flagged tensor to produce identical
        // data — the inner executable takes the inputs as-is.
        if b.orig == b.bucket {
            return self.inner.call(inputs);
        }
        let padded: Vec<Rc<Tensor>> = inputs
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if b.padded_inputs.contains(&i) {
                    Rc::new(pad_rows(t, b.bucket))
                } else {
                    Rc::clone(t)
                }
            })
            .collect();
        let outs = self.inner.call(&padded)?;
        Ok(outs
            .into_iter()
            .enumerate()
            .map(|(i, t)| if b.sliced_outputs.contains(&i) { slice_rows(&t, b.orig) } else { t })
            .collect())
    }

    fn backend_name(&self) -> &str {
        "batched"
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        let mut arts = vec![ModuleArtifact {
            kind: ArtifactKind::Plan,
            name: self.name.clone(),
            file: format!("__plan_{}.json", super::sanitize(&self.name)),
            content: self.plan_json.clone(),
        }];
        arts.extend(self.inner.artifacts());
        arts
    }

    fn stats(&self) -> ModuleStats {
        ModuleStats {
            partitions: 1,
            bucket: self.batch.as_ref().map(|b| b.bucket as u64),
            cache_hits: self.cache_hits,
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::eager;
    use crate::graph::OpKind;
    use crate::tensor::Rng;

    /// (x @ W + b).relu().softmax(): batch-safe along dim 0.
    fn mlp(batch: usize, d: usize) -> Graph {
        let mut g = Graph::new("bm");
        let x = g.placeholder("x", &[batch, d]);
        let w = g.placeholder("w", &[d, d]);
        let bias = g.placeholder("b", &[d]);
        let h = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let hb = g.add_op(OpKind::Add, vec![h, bias]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![hb]).unwrap();
        let sm = g.add_op(OpKind::Softmax, vec![r]).unwrap();
        g.set_outputs(vec![sm]);
        g
    }

    fn rand_inputs(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
        let mut rng = Rng::new(seed);
        g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng))).collect()
    }

    #[test]
    fn analysis_flags_batch_rows_only() {
        let g = mlp(5, 6);
        let info = analyze(&g).expect("mlp is batch-safe");
        assert_eq!(info.batch, 5);
        // x flagged; w, bias not.
        assert!(info.flags[g.inputs[0]]);
        assert!(!info.flags[g.inputs[1]] && !info.flags[g.inputs[2]]);
        // Every op output is batched.
        assert!(info.flags[*g.outputs.first().unwrap()]);
    }

    #[test]
    fn analysis_rejects_row_mixing_ops() {
        // Sum over the batch dim.
        let mut g = Graph::new("r0");
        let x = g.placeholder("x", &[5, 3]);
        let s = g.add_op(OpKind::Sum(Some(0)), vec![x]).unwrap();
        g.set_outputs(vec![s]);
        assert!(analyze(&g).is_none());
        // Full reduce.
        let mut g = Graph::new("r1");
        let x = g.placeholder("x", &[5, 3]);
        let s = g.add_op(OpKind::Sum(None), vec![x]).unwrap();
        g.set_outputs(vec![s]);
        assert!(analyze(&g).is_none());
        // Rank-2 transpose moves the batch dim.
        let mut g = Graph::new("t");
        let x = g.placeholder("x", &[5, 3]);
        let t = g.add_op(OpKind::Transpose, vec![x]).unwrap();
        g.set_outputs(vec![t]);
        assert!(analyze(&g).is_none());
        // Contraction against the batch dim: x [5,3] @ y [3,2] where the
        // *rhs* is the batched side.
        let mut g = Graph::new("mm");
        let w = g.placeholder("w", &[4, 5]);
        let x = g.placeholder("x", &[5, 3]);
        let m = g.add_op(OpKind::MatMul, vec![w, x]).unwrap();
        g.set_outputs(vec![m]);
        assert!(analyze(&g).is_none());
    }

    #[test]
    fn padded_execution_is_bitwise_equal() {
        for batch in [1usize, 3, 5, 6, 7, 8] {
            let g = Arc::new(mlp(batch, 4));
            let req = CompileRequest::new("bm", Arc::clone(&g));
            let b = BatchedBackend::new();
            let plan = b.plan(&req).unwrap();
            assert_eq!(plan.batch.as_ref().unwrap().bucket, batch.next_power_of_two());
            let module = b.lower(&req, &plan).unwrap();
            let inputs = rand_inputs(&g, 7 + batch as u64);
            let got = module.call(&inputs).unwrap();
            let want = eager::execute(&g, &inputs).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, bb) in got.iter().zip(want.iter()) {
                assert_eq!(a.shape(), bb.shape(), "batch={}", batch);
                assert_eq!(a.data(), bb.data(), "bitwise divergence at batch={}", batch);
            }
        }
    }

    #[test]
    fn bucket_shares_one_executable_across_guard_entries() {
        // Batches 5 and 6 land in bucket 8: the padded graphs are
        // identical, so the second lower reuses the first's ExecPlan.
        let backend = BatchedBackend::new();
        for (i, batch) in [5usize, 6].into_iter().enumerate() {
            let g = Arc::new(mlp(batch, 4));
            let req = CompileRequest::new("bm", Arc::clone(&g));
            let plan = backend.plan(&req).unwrap();
            let module = backend.lower(&req, &plan).unwrap();
            assert_eq!(module.stats().cache_hits, i as u64, "batch={}", batch);
            assert_eq!(module.stats().bucket, Some(8));
        }
        assert_eq!(
            backend.eager_plans.lock().unwrap().len(),
            1,
            "one plan serves the bucket"
        );
        // A different bucket (16) compiles separately.
        let g = Arc::new(mlp(9, 4));
        let req = CompileRequest::new("bm", Arc::clone(&g));
        let plan = backend.plan(&req).unwrap();
        backend.lower(&req, &plan).unwrap();
        assert_eq!(backend.eager_plans.lock().unwrap().len(), 2);
    }

    /// Satellite: rows exactly at a power of two take the no-pad fast
    /// path (orig == bucket) and still produce bitwise-eager results,
    /// including batch 1 (pow2) and the first bucket above (9 → 16).
    #[test]
    fn bucket_boundary_rows_exactly_at_power_of_two() {
        for batch in [1usize, 2, 4, 8, 16] {
            let g = Arc::new(mlp(batch, 4));
            let req = CompileRequest::new("bm", Arc::clone(&g));
            let backend = BatchedBackend::new();
            let plan = backend.plan(&req).unwrap();
            let b = plan.batch.as_ref().expect("mlp is batch-safe");
            assert_eq!(b.orig, batch);
            assert_eq!(b.bucket, batch, "a power-of-two batch is its own bucket");
            let module = backend.lower(&req, &plan).unwrap();
            assert_eq!(module.stats().bucket, Some(batch as u64));
            let inputs = rand_inputs(&g, 100 + batch as u64);
            let got = module.call(&inputs).unwrap();
            let want = eager::execute(&g, &inputs).unwrap();
            for (a, w) in got.iter().zip(want.iter()) {
                assert_eq!(a.shape(), w.shape(), "batch={}", batch);
                assert_eq!(a.data(), w.data(), "bitwise divergence at pow2 batch={}", batch);
            }
        }
        // One past the boundary pads up to the next bucket.
        let g = Arc::new(mlp(9, 4));
        let req = CompileRequest::new("bm", Arc::clone(&g));
        let backend = BatchedBackend::new();
        let plan = backend.plan(&req).unwrap();
        assert_eq!(plan.batch.as_ref().unwrap().bucket, 16);
    }

    /// Satellite: 0-row inputs are never padded (bucket_of(0) would be
    /// degenerate); the graph compiles exactly and the empty result is
    /// bitwise-identical to eager.
    #[test]
    fn zero_row_inputs_fall_back_exactly() {
        let g = Arc::new(mlp(0, 4));
        let req = CompileRequest::new("bm0", Arc::clone(&g));
        let backend = BatchedBackend::new();
        let plan = backend.plan(&req).unwrap();
        assert!(plan.batch.is_none(), "batch 0 must not be bucketed");
        let module = backend.lower(&req, &plan).unwrap();
        assert_eq!(module.stats().bucket, None);
        let inputs = rand_inputs(&g, 3);
        assert_eq!(inputs[0].numel(), 0);
        let got = module.call(&inputs).unwrap();
        let want = eager::execute(&g, &inputs).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, w) in got.iter().zip(want.iter()) {
            assert_eq!(a.shape(), w.shape());
            assert_eq!(a.shape()[0], 0, "zero rows in, zero rows out");
            assert_eq!(a.data(), w.data());
        }
    }

    /// Satellite: every batch-unsafe shape falls back to an *exact*
    /// compile — no batch plan, no bucket stat, per-plan cache key equal
    /// to the unpadded graph's hash — and stays bitwise-equal to eager.
    #[test]
    fn batch_unsafe_graphs_compile_exactly_and_bitwise() {
        let cases: Vec<(&str, Graph)> = vec![
            ("sum over batch dim", {
                let mut g = Graph::new("u0");
                let x = g.placeholder("x", &[5, 3]);
                let s = g.add_op(OpKind::Sum(Some(0)), vec![x]).unwrap();
                g.set_outputs(vec![s]);
                g
            }),
            ("rank-2 transpose moves batch", {
                let mut g = Graph::new("u1");
                let x = g.placeholder("x", &[5, 3]);
                let t = g.add_op(OpKind::Transpose, vec![x]).unwrap();
                let r = g.add_op(OpKind::Relu, vec![t]).unwrap();
                g.set_outputs(vec![r]);
                g
            }),
            ("batched rhs contraction", {
                let mut g = Graph::new("u2");
                let w = g.placeholder("w", &[5, 5]);
                let x = g.placeholder("x", &[5, 3]);
                let m = g.add_op(OpKind::MatMul, vec![w, x]).unwrap();
                g.set_outputs(vec![m]);
                g
            }),
            ("cross_entropy means over rows", {
                let mut g = Graph::new("u3");
                let logits = g.placeholder("logits", &[5, 4]);
                let tgt = g.placeholder("tgt", &[5]);
                let ce = g.add_op(OpKind::CrossEntropy, vec![logits, tgt]).unwrap();
                g.set_outputs(vec![ce]);
                g
            }),
        ];
        for (why, g) in cases {
            let g = Arc::new(g);
            let req = CompileRequest::new(&g.name.clone(), Arc::clone(&g));
            let backend = BatchedBackend::new();
            let plan = backend.plan(&req).unwrap();
            assert!(plan.batch.is_none(), "{} must not be padded", why);
            assert_eq!(
                plan.partitions[0].cache_key,
                g.content_hash(),
                "{}: exact compile keys on the unpadded graph",
                why
            );
            let module = backend.lower(&req, &plan).unwrap();
            assert_eq!(module.stats().bucket, None, "{}", why);
            let inputs: Vec<Rc<Tensor>> = match why {
                "cross_entropy means over rows" => {
                    let mut rng = Rng::new(17);
                    vec![
                        Rc::new(Tensor::randn(&[5, 4], &mut rng)),
                        Rc::new(Tensor::new(vec![5], vec![0.0, 3.0, 1.0, 2.0, 0.0])),
                    ]
                }
                _ => rand_inputs(&g, 23),
            };
            let got = module.call(&inputs).unwrap();
            let want = eager::execute(&g, &inputs).unwrap();
            for (a, w) in got.iter().zip(want.iter()) {
                assert_eq!(a.shape(), w.shape(), "{}", why);
                assert_eq!(a.data(), w.data(), "{}: bitwise divergence on exact fallback", why);
            }
        }
    }

    #[test]
    fn unsafe_graphs_fall_back_to_exact_compiles() {
        let mut g = Graph::new("exact");
        let x = g.placeholder("x", &[5, 3]);
        let s = g.add_op(OpKind::Mean(None), vec![x]).unwrap();
        g.set_outputs(vec![s]);
        let g = Arc::new(g);
        let req = CompileRequest::new("exact", Arc::clone(&g));
        let backend = BatchedBackend::new();
        let plan = backend.plan(&req).unwrap();
        assert!(plan.batch.is_none(), "row-mixing graph must not be padded");
        let module = backend.lower(&req, &plan).unwrap();
        assert_eq!(module.stats().bucket, None);
        let inputs = rand_inputs(&g, 3);
        let got = module.call(&inputs).unwrap();
        let want = eager::execute(&g, &inputs).unwrap();
        assert_eq!(got[0].data(), want[0].data());
    }

    #[test]
    fn plan_artifact_records_the_bucket_decision() {
        let g = Arc::new(mlp(5, 4));
        let req = CompileRequest::new("bm", Arc::clone(&g));
        let backend = BatchedBackend::new();
        let plan = backend.plan(&req).unwrap();
        let module = backend.lower(&req, &plan).unwrap();
        let arts = module.artifacts();
        let plan_art = arts.iter().find(|a| a.kind == ArtifactKind::Plan).expect("plan artifact");
        let parsed = CompilePlan::parse(&plan_art.content).unwrap();
        assert_eq!(parsed, plan);
        assert_eq!(parsed.batch.unwrap().bucket, 8);
    }

    #[test]
    fn embedding_ids_are_batchable() {
        let mut g = Graph::new("emb");
        let table = g.placeholder("table", &[10, 4]);
        let ids = g.placeholder("ids", &[3]);
        let e = g.add_op(OpKind::Embedding, vec![table, ids]).unwrap();
        g.set_outputs(vec![e]);
        let g = Arc::new(g);
        // ids is the *second* input, but it is the first rank>=1 input to
        // define the batch? No: table comes first, so batch = 10 and only
        // coincidental dims flag. The analysis must still be *correct*:
        // compare against eager either way.
        let req = CompileRequest::new("emb", Arc::clone(&g));
        let backend = BatchedBackend::new();
        let plan = backend.plan(&req).unwrap();
        let module = backend.lower(&req, &plan).unwrap();
        let mut rng = Rng::new(9);
        let table_t = Rc::new(Tensor::randn(&[10, 4], &mut rng));
        let ids_t = Rc::new(Tensor::new(vec![3], vec![0.0, 7.0, 2.0]));
        let got = module.call(&[Rc::clone(&table_t), Rc::clone(&ids_t)]).unwrap();
        let want = eager::execute(&g, &[table_t, ids_t]).unwrap();
        assert_eq!(got[0].data(), want[0].data());
    }
}
