//! The `sharded` backend: splits a captured graph at articulation points
//! into several PJRT/eager executables and stitches their outputs.
//!
//! `plan()` partitions the graph's topologically-ordered op nodes into
//! contiguous shards of at most `max_ops` ops, sliding each cut onto the
//! smallest crossing frontier (see [`super::partition`]) — for chain-like
//! models that means cuts land on single-tensor articulation points, so
//! shards exchange exactly one value. Each shard is extracted as a
//! standalone subgraph whose `content_hash` is its own compile-cache key
//! (identical shards across graphs/sessions compile once). `lower()`
//! compiles every shard to PJRT (when a runtime is present) or to an
//! eager [`ExecPlan`](super::eager::ExecPlan) and wires them through a
//! [`Stitcher`](super::partition::Stitcher). Partition boundaries are
//! recorded as a typed plan artifact plus per-partition HLO dumps.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Mutex, PoisonError};

use crate::api::{
    ArtifactKind, Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError,
    ModuleArtifact, ModuleStats,
};
use crate::api::plan::PartitionPlan;
use crate::tensor::Tensor;

use super::eager::EagerModule;
use super::partition::{extract, partition_by_ops, Partition, StitchPart, Stitcher};
use super::xla;

/// Default shard budget. Deliberately small so the corpus-scale graphs in
/// this reproduction actually shard; production graphs would raise it via
/// [`ShardedBackend::with_max_ops`].
pub const DEFAULT_MAX_OPS: usize = 4;

/// The `sharded` backend.
pub struct ShardedBackend {
    max_ops: usize,
    /// Subgraphs extracted at `plan()` time, keyed by content hash, so
    /// `lower()` reuses them instead of re-running extraction (names are
    /// excluded from the hash; structurally identical shards share one
    /// entry, like the runtime's executable cache). A `Mutex` because the
    /// backend lives in the process-wide registry and compiles can be
    /// issued from any thread.
    subgraphs: Mutex<HashMap<u64, Arc<crate::graph::Graph>>>,
}

impl Default for ShardedBackend {
    fn default() -> Self {
        ShardedBackend::new()
    }
}

impl ShardedBackend {
    pub fn new() -> ShardedBackend {
        ShardedBackend::with_max_ops(DEFAULT_MAX_OPS)
    }

    /// Override the per-shard op budget (≥ 1).
    pub fn with_max_ops(max_ops: usize) -> ShardedBackend {
        ShardedBackend { max_ops: max_ops.max(1), subgraphs: Mutex::new(HashMap::new()) }
    }
}

impl Backend for ShardedBackend {
    fn name(&self) -> &str {
        "sharded"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities::PARTITION | Capabilities::USES_RUNTIME
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendPlan)?;
        // Partition the *optimized* graph: plan node ids, shard cache keys
        // and the stitcher all live in post-optimizer coordinates.
        let opt = req.optimized();
        let target = if req.runtime.is_some() { "xla" } else { "eager" };
        let parts = partition_by_ops(&opt.graph, self.max_ops);
        let mut partitions = Vec::with_capacity(parts.len());
        for (i, part) in parts.iter().enumerate() {
            let sub = Arc::new(extract(&opt.graph, part, &shard_name(&req.name, i))?);
            let cache_key = sub.content_hash();
            self.subgraphs.lock().unwrap_or_else(PoisonError::into_inner).insert(cache_key, sub);
            partitions.push(PartitionPlan {
                index: i,
                target: target.to_string(),
                nodes: part.nodes.clone(),
                inputs: part.inputs.clone(),
                outputs: part.outputs.clone(),
                cache_key,
            });
        }
        Ok(CompilePlan {
            backend: "sharded".into(),
            graph: opt.graph.name.clone(),
            cache_key: req.cache_key,
            partitions,
            batch: None,
            opt: Some(crate::api::plan::OptSummary::from_optimized(&opt)),
        })
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        let (stitcher, cache_hits) = self.lower_stitcher(req, plan)?;
        Ok(Arc::new(ShardedModule {
            stitcher,
            plan_json: plan.to_json(),
            name: req.name.clone(),
            cache_hits,
        }))
    }
}

impl ShardedBackend {
    /// Lower every partition of `plan` to its module and wire the results
    /// through a [`Stitcher`]. Shared by `lower()` (sequential stitching)
    /// and the serving pipeline ([`crate::serve::PipelinedShardedModule`]),
    /// which runs each partition on its own stage thread instead. Returns
    /// the stitcher plus the number of per-shard compile-cache hits.
    pub fn lower_stitcher(
        &self,
        req: &CompileRequest,
        plan: &CompilePlan,
    ) -> Result<(Stitcher, u64), DepyfError> {
        crate::faults::gate(crate::faults::Site::BackendLower)?;
        let opt = req.optimized();
        let mut stitch_parts = Vec::with_capacity(plan.partitions.len());
        let mut cache_hits = 0u64;
        for p in &plan.partitions {
            let part = Partition {
                nodes: p.nodes.clone(),
                inputs: p.inputs.clone(),
                outputs: p.outputs.clone(),
            };
            // Reuse the subgraph plan() extracted; fall back to a fresh
            // extraction for externally-supplied (e.g. parsed) plans.
            let cached = self
                .subgraphs
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .get(&p.cache_key)
                .cloned();
            let sub = match cached {
                Some(s) => s,
                None => Arc::new(extract(&opt.graph, &part, &shard_name(&req.name, p.index))?),
            };
            let module: Arc<dyn CompiledModule> = match p.target.as_str() {
                "xla" => {
                    let rt = req.runtime.as_ref().ok_or_else(|| {
                        DepyfError::Backend(format!(
                            "sharded: partition {} targets xla but no runtime was provided",
                            p.index
                        ))
                    })?;
                    let m = xla::compile_module(&shard_name(&req.name, p.index), &sub, rt)?;
                    cache_hits += m.cache_hit as u64;
                    Arc::new(m)
                }
                _ => Arc::new(EagerModule::with_fusion(
                    Arc::clone(&sub),
                    "eager".into(),
                    req.opt_level.fuses(),
                )),
            };
            stitch_parts.push(StitchPart { part, module });
        }
        Ok((Stitcher::new(Arc::clone(&opt.graph), stitch_parts), cache_hits))
    }
}

fn shard_name(graph_name: &str, index: usize) -> String {
    format!("{}.p{}", graph_name, index)
}

/// The lowered sharded module: a [`Stitcher`] over per-partition modules.
pub struct ShardedModule {
    stitcher: Stitcher,
    plan_json: String,
    name: String,
    cache_hits: u64,
}

impl CompiledModule for ShardedModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.stitcher.run(inputs)
    }

    fn backend_name(&self) -> &str {
        "sharded"
    }

    fn artifacts(&self) -> Vec<ModuleArtifact> {
        let mut arts = vec![ModuleArtifact {
            kind: ArtifactKind::Plan,
            name: self.name.clone(),
            file: format!("__plan_{}.json", super::sanitize(&self.name)),
            content: self.plan_json.clone(),
        }];
        for sp in self.stitcher.parts() {
            arts.extend(sp.module.artifacts());
        }
        arts
    }

    fn stats(&self) -> ModuleStats {
        ModuleStats {
            partitions: self.stitcher.parts().len() as u64,
            bucket: None,
            cache_hits: self.cache_hits,
        }
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::eager;
    use crate::graph::{Graph, OpKind};
    use crate::tensor::Rng;

    fn deep_chain(depth: usize) -> Graph {
        let mut g = Graph::new("chain");
        let x = g.placeholder("x", &[3, 5]);
        let mut cur = x;
        for i in 0..depth {
            cur = match i % 3 {
                0 => g.add_op(OpKind::Relu, vec![cur]).unwrap(),
                1 => g.add_op(OpKind::Tanh, vec![cur]).unwrap(),
                _ => g.add_op(OpKind::Gelu, vec![cur]).unwrap(),
            };
        }
        let s = g.add_op(OpKind::Sum(None), vec![cur]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    fn rand_inputs(g: &Graph, seed: u64) -> Vec<Rc<Tensor>> {
        let mut rng = Rng::new(seed);
        g.input_shapes().into_iter().map(|(_, s)| Rc::new(Tensor::randn(&s, &mut rng))).collect()
    }

    #[test]
    fn plan_shards_and_records_per_partition_keys() {
        let g = Arc::new(deep_chain(9)); // 10 ops
        let req = CompileRequest::new("chain", Arc::clone(&g));
        let backend = ShardedBackend::with_max_ops(4);
        let plan = backend.plan(&req).unwrap();
        assert!(plan.partitions.len() >= 3, "{:?}", plan.partitions.len());
        assert!(plan.batch.is_none());
        let keys: Vec<u64> = plan.partitions.iter().map(|p| p.cache_key).collect();
        // Per-partition cache keys are real content hashes, not copies of
        // the whole-graph key.
        assert!(keys.iter().all(|&k| k != plan.cache_key));
        // The plan round-trips through its JSON dump.
        let parsed = CompilePlan::parse(&plan.to_json()).unwrap();
        assert_eq!(parsed, plan);
    }

    #[test]
    fn sharded_is_bitwise_equal_to_eager() {
        for max_ops in [1usize, 2, 4, 100] {
            let g = Arc::new(deep_chain(7));
            let req = CompileRequest::new("chain", Arc::clone(&g));
            let backend = ShardedBackend::with_max_ops(max_ops);
            let module = backend.compile(&req).unwrap();
            let inputs = rand_inputs(&g, 11);
            let got = module.call(&inputs).unwrap();
            let want = eager::execute(&g, &inputs).unwrap();
            for (a, b) in got.iter().zip(want.iter()) {
                assert_eq!(a.data(), b.data(), "bitwise divergence at max_ops={}", max_ops);
            }
            assert_eq!(module.stats().partitions as usize, if max_ops >= 8 { 1 } else { 8usize.div_ceil(max_ops) });
        }
    }

    #[test]
    fn module_artifacts_expose_the_plan() {
        let g = Arc::new(deep_chain(5));
        let req = CompileRequest::new("chain", Arc::clone(&g));
        let backend = ShardedBackend::with_max_ops(2);
        let module = backend.compile(&req).unwrap();
        let arts = module.artifacts();
        let plan_art = arts.iter().find(|a| a.kind == ArtifactKind::Plan).expect("plan artifact");
        assert_eq!(plan_art.file, "__plan_chain.json");
        let parsed = CompilePlan::parse(&plan_art.content).unwrap();
        assert_eq!(parsed.backend, "sharded");
        assert!(parsed.partitions.len() >= 2);
    }

    #[test]
    fn branch_outputs_survive_sharding() {
        // Two outputs, one consumed mid-graph: exports must cover both.
        let mut g = Graph::new("multi");
        let x = g.placeholder("x", &[4]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let e = g.add_op(OpKind::Exp, vec![r]).unwrap();
        let n = g.add_op(OpKind::Neg, vec![e]).unwrap();
        g.set_outputs(vec![r, n]);
        let g = Arc::new(g);
        let req = CompileRequest::new("multi", Arc::clone(&g));
        let module = ShardedBackend::with_max_ops(1).compile(&req).unwrap();
        let inputs = rand_inputs(&g, 5);
        let got = module.call(&inputs).unwrap();
        let want = eager::execute(&g, &inputs).unwrap();
        assert_eq!(got.len(), 2);
        for (a, b) in got.iter().zip(want.iter()) {
            assert_eq!(a.data(), b.data());
        }
    }
}
