//! [`ResilientBackend`] — retry-with-backoff plus a circuit breaker
//! around any inner backend's `plan`/`lower`.
//!
//! A transparent wrapper (like the serve layer's `CachingBackend`): it
//! keeps the inner backend's `name()` and capabilities, so nothing
//! downstream can tell it is there — except that transient compile
//! failures ([`DepyfError::is_transient`], including panics caught by
//! its own `catch_unwind`) are retried with exponential backoff, and a
//! run of consecutive *final* failures trips a circuit breaker:
//!
//! * **closed** — normal operation; each final failure increments a
//!   consecutive-failure count, any success resets it.
//! * **open** — after `trip_threshold` consecutive failures. Compiles
//!   fail fast with a `Backend` error (no inner attempt), which under
//!   [`FallbackPolicy::Eager`](crate::api::FallbackPolicy) degrades
//!   dispatch to the eager executor instead of hammering a compiler
//!   that is down. The cooldown is *count-based* (deterministic — no
//!   wall clock): after `cooldown_skips` fail-fast skips the breaker
//!   moves to half-open.
//! * **half-open** — the next compile is a probe: success closes the
//!   breaker, failure re-opens it (and counts as another trip). Under
//!   concurrency more than one in-flight probe may be admitted; that
//!   only costs extra attempts, never correctness.
//!
//! Retries, trips, fail-fast skips and caught panics are counted in
//! [`ResilienceStats`]; `depyf serve` wraps every backend in this and
//! folds the counts into `metrics.json` / `BENCH_serve.json`. On the
//! CLI, `resilient:<name>` wraps any registered backend explicitly.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use crate::api::{
    Backend, Capabilities, CompilePlan, CompileRequest, CompiledModule, DepyfError,
};

/// Retry/trip/skip/panic counters, shared out via [`ResilientBackend::stats`]
/// so the serve layer can merge them into its metrics snapshot.
#[derive(Debug, Default)]
pub struct ResilienceStats {
    retries: AtomicU64,
    trips: AtomicU64,
    skips: AtomicU64,
    panics: AtomicU64,
}

impl ResilienceStats {
    /// Transient failures that were retried (per retry, not per request).
    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::Relaxed)
    }

    /// Times the breaker entered the open state (including re-opens from
    /// a failed half-open probe).
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Compiles failed fast by an open breaker without touching the
    /// inner backend.
    pub fn skips(&self) -> u64 {
        self.skips.load(Ordering::Relaxed)
    }

    /// Inner-backend panics converted to [`DepyfError::Panic`].
    pub fn panics(&self) -> u64 {
        self.panics.load(Ordering::Relaxed)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BreakerState {
    Closed { consecutive_failures: u32 },
    Open { skips_remaining: u32 },
    HalfOpen,
}

/// Retry + circuit-breaker wrapper around any [`Backend`]. See the
/// module docs for the state machine.
pub struct ResilientBackend {
    inner: Arc<dyn Backend>,
    max_retries: u32,
    backoff: Duration,
    trip_threshold: u32,
    cooldown_skips: u32,
    state: Mutex<BreakerState>,
    stats: Arc<ResilienceStats>,
}

impl ResilientBackend {
    /// Wrap `inner` with the defaults: 2 retries at 1ms doubling
    /// backoff, breaker trips after 3 consecutive failures, half-open
    /// probe after 2 fail-fast skips.
    pub fn new(inner: Arc<dyn Backend>) -> ResilientBackend {
        ResilientBackend {
            inner,
            max_retries: 2,
            backoff: Duration::from_millis(1),
            trip_threshold: 3,
            cooldown_skips: 2,
            state: Mutex::new(BreakerState::Closed { consecutive_failures: 0 }),
            stats: Arc::new(ResilienceStats::default()),
        }
    }

    /// Wrap a registered backend, looked up by name.
    pub fn wrapping(inner_name: &str) -> Result<ResilientBackend, DepyfError> {
        let inner = crate::api::lookup_backend(inner_name).ok_or_else(|| {
            DepyfError::Backend(format!(
                "resilient: unknown inner backend '{}' (registered: {})",
                inner_name,
                crate::api::backend_names().join(", ")
            ))
        })?;
        Ok(ResilientBackend::new(inner))
    }

    /// Override the retry policy (`backoff` doubles per retry; zero
    /// disables sleeping, handy in tests).
    pub fn with_retry(mut self, max_retries: u32, backoff: Duration) -> ResilientBackend {
        self.max_retries = max_retries;
        self.backoff = backoff;
        self
    }

    /// Override the breaker: trip after `trip_threshold` consecutive
    /// failures (min 1), half-open after `cooldown_skips` fail-fast skips.
    pub fn with_breaker(mut self, trip_threshold: u32, cooldown_skips: u32) -> ResilientBackend {
        self.trip_threshold = trip_threshold.max(1);
        self.cooldown_skips = cooldown_skips;
        self
    }

    pub fn stats(&self) -> Arc<ResilienceStats> {
        Arc::clone(&self.stats)
    }

    /// The breaker state as a report string: `closed`, `open` or
    /// `half-open`.
    pub fn breaker_state(&self) -> &'static str {
        match *self.state.lock().unwrap_or_else(PoisonError::into_inner) {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }

    /// Breaker admission. `Err` = open, fail fast (counted as a skip).
    fn admit(&self) -> Result<(), DepyfError> {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        match *st {
            BreakerState::Closed { .. } | BreakerState::HalfOpen => Ok(()),
            BreakerState::Open { skips_remaining: 0 } => {
                *st = BreakerState::HalfOpen;
                Ok(())
            }
            BreakerState::Open { ref mut skips_remaining } => {
                *skips_remaining -= 1;
                self.stats.skips.fetch_add(1, Ordering::Relaxed);
                Err(DepyfError::Backend(format!(
                    "{}: circuit breaker open after {} consecutive compile failures; failing fast",
                    self.inner.name(),
                    self.trip_threshold
                )))
            }
        }
    }

    fn on_success(&self) {
        *self.state.lock().unwrap_or_else(PoisonError::into_inner) =
            BreakerState::Closed { consecutive_failures: 0 };
    }

    fn on_failure(&self) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        *st = match *st {
            BreakerState::Closed { consecutive_failures } => {
                let n = consecutive_failures + 1;
                if n >= self.trip_threshold {
                    self.stats.trips.fetch_add(1, Ordering::Relaxed);
                    BreakerState::Open { skips_remaining: self.cooldown_skips }
                } else {
                    BreakerState::Closed { consecutive_failures: n }
                }
            }
            BreakerState::HalfOpen => {
                self.stats.trips.fetch_add(1, Ordering::Relaxed);
                BreakerState::Open { skips_remaining: self.cooldown_skips }
            }
            open @ BreakerState::Open { .. } => open,
        };
    }

    /// One breaker-admitted, panic-isolated, retrying attempt sequence.
    /// `AssertUnwindSafe` is sound for the same reason as in
    /// `compile_with_policy`: every lock below recovers from poison.
    fn protected<T>(
        &self,
        what: &str,
        attempt: &dyn Fn() -> Result<T, DepyfError>,
    ) -> Result<T, DepyfError> {
        self.admit()?;
        let mut tries = 0u32;
        loop {
            let result = catch_unwind(AssertUnwindSafe(attempt)).unwrap_or_else(|payload| {
                self.stats.panics.fetch_add(1, Ordering::Relaxed);
                Err(DepyfError::from_panic(
                    &format!("backend {} {}", self.inner.name(), what),
                    payload,
                ))
            });
            match result {
                Ok(v) => {
                    self.on_success();
                    return Ok(v);
                }
                Err(e) if e.is_transient() && tries < self.max_retries => {
                    tries += 1;
                    self.stats.retries.fetch_add(1, Ordering::Relaxed);
                    if !self.backoff.is_zero() {
                        std::thread::sleep(self.backoff * (1 << (tries - 1).min(8)));
                    }
                }
                Err(e) => {
                    self.on_failure();
                    return Err(e);
                }
            }
        }
    }
}

impl Backend for ResilientBackend {
    /// Transparent: keeps the inner name so `backend_name` stamps,
    /// artifact files and logs are unchanged by the wrapper.
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities() | Capabilities::WRAPPER
    }

    fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
        self.protected("plan", &|| self.inner.plan(req))
    }

    fn lower(&self, req: &CompileRequest, plan: &CompilePlan) -> Result<Arc<dyn CompiledModule>, DepyfError> {
        self.protected("lower", &|| self.inner.lower(req, plan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{CompilePlan, EagerBackend};
    use crate::graph::{Graph, OpKind};
    use std::rc::Rc;
    use std::sync::Arc;

    fn relu_graph() -> Arc<Graph> {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        g.set_outputs(vec![r]);
        Arc::new(g)
    }

    /// Fails (transiently or by panic) for the first `fail_first` plan
    /// calls, then behaves like eager.
    struct Flaky {
        fail_first: u64,
        panics: bool,
        calls: AtomicU64,
    }

    impl Flaky {
        fn new(fail_first: u64, panics: bool) -> Flaky {
            Flaky { fail_first, panics, calls: AtomicU64::new(0) }
        }
    }

    impl Backend for Flaky {
        fn name(&self) -> &str {
            "flaky"
        }
        fn plan(&self, req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
            let n = self.calls.fetch_add(1, Ordering::Relaxed);
            if n < self.fail_first {
                if self.panics {
                    panic!("flaky plan #{}", n);
                }
                return Err(DepyfError::Runtime(format!("flaky plan #{}", n)));
            }
            Ok(CompilePlan::monolithic("flaky", req, "eager"))
        }
        fn lower(
            &self,
            req: &CompileRequest,
            _plan: &CompilePlan,
        ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
            Ok(Arc::new(crate::backend::eager::EagerModule::with_name(
                Arc::clone(&req.graph),
                "flaky".into(),
            )))
        }
    }

    fn req() -> CompileRequest {
        CompileRequest::new("g", relu_graph())
    }

    #[test]
    fn transparent_name_and_capabilities() {
        let r = ResilientBackend::new(Arc::new(EagerBackend));
        assert_eq!(r.name(), "eager");
        assert!(r.capabilities().contains(Capabilities::WRAPPER));
        assert!(!r.requires_runtime());
        let module = r.compile(&req()).unwrap();
        assert_eq!(module.backend_name(), "eager");
        let out = module
            .call(&[Rc::new(crate::tensor::Tensor::new(vec![2], vec![-1.0, 2.0]))])
            .unwrap();
        assert_eq!(out[0].data(), &[0.0, 2.0]);
    }

    #[test]
    fn transient_failures_are_retried_to_success() {
        let r = ResilientBackend::new(Arc::new(Flaky::new(2, false)))
            .with_retry(2, Duration::ZERO);
        let module = r.compile(&req()).expect("third attempt succeeds");
        assert_eq!(module.backend_name(), "flaky");
        assert_eq!(r.stats().retries(), 2);
        assert_eq!(r.stats().trips(), 0);
        assert_eq!(r.breaker_state(), "closed");
    }

    #[test]
    fn panics_are_caught_counted_and_retried() {
        let r = ResilientBackend::new(Arc::new(Flaky::new(1, true)))
            .with_retry(2, Duration::ZERO);
        let module = r.compile(&req()).expect("retry after caught panic");
        assert_eq!(module.backend_name(), "flaky");
        assert_eq!(r.stats().panics(), 1);
        assert_eq!(r.stats().retries(), 1);
    }

    #[test]
    fn structural_failures_are_not_retried() {
        struct Structural;
        impl Backend for Structural {
            fn name(&self) -> &str {
                "structural"
            }
            fn plan(&self, _req: &CompileRequest) -> Result<CompilePlan, DepyfError> {
                Err(DepyfError::Backend("unsupported op".into()))
            }
            fn lower(
                &self,
                _req: &CompileRequest,
                _plan: &CompilePlan,
            ) -> Result<Arc<dyn CompiledModule>, DepyfError> {
                unreachable!()
            }
        }
        let r = ResilientBackend::new(Arc::new(Structural)).with_retry(5, Duration::ZERO);
        let err = r.plan(&req()).unwrap_err();
        assert_eq!(err.layer(), "backend");
        assert_eq!(r.stats().retries(), 0, "structural errors fail immediately");
    }

    #[test]
    fn breaker_trips_fails_fast_probes_and_recovers() {
        // 12 transient failures, then healthy. No retries, trip after 3
        // failures, half-open after 2 skips → the exact sequence below.
        let r = ResilientBackend::new(Arc::new(Flaky::new(12, false)))
            .with_retry(0, Duration::ZERO)
            .with_breaker(3, 2);
        // Three real failures close→open (inner sees 3 calls).
        for _ in 0..3 {
            assert_eq!(r.plan(&req()).unwrap_err().layer(), "runtime");
        }
        assert_eq!(r.breaker_state(), "open");
        assert_eq!(r.stats().trips(), 1);
        // Two fail-fast skips: inner is never touched.
        for _ in 0..2 {
            let err = r.plan(&req()).unwrap_err();
            assert!(err.to_string().contains("circuit breaker open"), "{}", err);
        }
        assert_eq!(r.stats().skips(), 2);
        // Probe (inner call #4) fails → re-open; trips now 2.
        assert_eq!(r.plan(&req()).unwrap_err().layer(), "runtime");
        assert_eq!(r.breaker_state(), "open");
        assert_eq!(r.stats().trips(), 2);
        // Burn the cooldown (2 more skips), then keep probing until the
        // inner backend heals: probes 5..=12 fail, each re-opening with a
        // 2-skip cooldown; probe 13 succeeds and closes the breaker.
        let mut closed = false;
        for _ in 0..40 {
            if r.plan(&req()).is_ok() {
                closed = true;
                break;
            }
        }
        assert!(closed, "breaker never recovered");
        assert_eq!(r.breaker_state(), "closed");
        assert!(r.stats().skips() > 2);
        // Healthy again: no fail-fast.
        r.plan(&req()).unwrap();
    }
}
