//! Eager reference backend: executes a captured graph node-by-node with the
//! CPU tensor library. This is the correctness oracle for the XLA backend
//! and the executor the debugger steps through (`on_node` callback maps to
//! dump lines).

use std::rc::Rc;

use crate::api::DepyfError;
use crate::graph::{Graph, NodeKind, OpKind};
use crate::tensor::{self, Tensor};

/// Execute with a per-node callback (node id, result) — used by the
/// debugger to step through `__compiled_fn` dumps line by line.
pub fn execute_traced(
    g: &Graph,
    inputs: &[Rc<Tensor>],
    on_node: impl FnMut(usize, &Tensor),
) -> Result<Vec<Tensor>, DepyfError> {
    execute_traced_inner(g, inputs, on_node).map_err(DepyfError::Backend)
}

fn execute_traced_inner(
    g: &Graph,
    inputs: &[Rc<Tensor>],
    mut on_node: impl FnMut(usize, &Tensor),
) -> Result<Vec<Tensor>, String> {
    if inputs.len() != g.inputs.len() {
        return Err(format!("graph {} expects {} inputs, got {}", g.name, g.inputs.len(), inputs.len()));
    }
    let mut env: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for (slot, input) in g.inputs.iter().zip(inputs.iter()) {
        let node = &g.nodes[*slot];
        if node.shape != input.shape() {
            return Err(format!(
                "graph {} input {} shape mismatch: expected {:?}, got {:?}",
                g.name,
                slot,
                node.shape,
                input.shape()
            ));
        }
        env[*slot] = Some((**input).clone());
    }
    for (id, node) in g.nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::Placeholder { .. } => {}
            NodeKind::ConstScalar(v) => env[id] = Some(Tensor::scalar(*v as f32)),
            NodeKind::ConstTensor(t) => env[id] = Some(t.clone()),
            NodeKind::Op(op, args) => {
                let get = |i: usize| -> Result<&Tensor, String> {
                    env[args[i]].as_ref().ok_or_else(|| format!("node {} uses unevaluated node {}", id, args[i]))
                };
                let r = match op {
                    OpKind::Add => tensor::add(get(0)?, get(1)?)?,
                    OpKind::Sub => tensor::sub(get(0)?, get(1)?)?,
                    OpKind::Mul => tensor::mul(get(0)?, get(1)?)?,
                    OpKind::Div => tensor::div(get(0)?, get(1)?)?,
                    OpKind::Pow => tensor::pow(get(0)?, get(1)?)?,
                    OpKind::Maximum => tensor::maximum(get(0)?, get(1)?)?,
                    OpKind::Minimum => tensor::minimum(get(0)?, get(1)?)?,
                    OpKind::Neg => tensor::neg(get(0)?),
                    OpKind::Relu => tensor::relu(get(0)?),
                    OpKind::Gelu => tensor::gelu(get(0)?),
                    OpKind::Tanh => tensor::tanh(get(0)?),
                    OpKind::Sigmoid => tensor::sigmoid(get(0)?),
                    OpKind::Exp => tensor::exp(get(0)?),
                    OpKind::Log => tensor::log(get(0)?),
                    OpKind::Sqrt => tensor::sqrt(get(0)?),
                    OpKind::Abs => tensor::abs(get(0)?),
                    OpKind::MatMul => tensor::matmul(get(0)?, get(1)?)?,
                    OpKind::Transpose => tensor::transpose(get(0)?)?,
                    OpKind::Reshape(spec) => {
                        let t = get(0)?;
                        let shape = tensor::reshape_infer(t.numel(), spec)?;
                        t.reshape(shape)
                    }
                    OpKind::Permute(perm) => tensor::permute(get(0)?, perm)?,
                    OpKind::Softmax => tensor::softmax(get(0)?)?,
                    OpKind::Sum(ax) => tensor::sum(get(0)?, *ax)?,
                    OpKind::Mean(ax) => tensor::mean(get(0)?, *ax)?,
                    OpKind::Max(ax) => tensor::max_reduce(get(0)?, *ax)?,
                    OpKind::Min(ax) => tensor::min_reduce(get(0)?, *ax)?,
                    OpKind::LayerNorm => tensor::layernorm(get(0)?, get(1)?, get(2)?, 1e-5)?,
                    OpKind::Embedding => tensor::embedding(get(0)?, get(1)?)?,
                    OpKind::CrossEntropy => tensor::cross_entropy(get(0)?, get(1)?)?,
                };
                on_node(id, &r);
                env[id] = Some(r);
            }
        }
    }
    g.outputs
        .iter()
        .map(|&o| env[o].clone().ok_or_else(|| format!("output node {} unevaluated", o)))
        .collect()
}

/// Plain execution without tracing.
pub fn execute(g: &Graph, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
    execute_traced(g, inputs, |_, _| {})
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn executes_mlp_block() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let w = g.placeholder("w", &[3, 4]);
        let m = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![r]).unwrap();
        g.set_outputs(vec![s]);
        let x_t = Rc::new(Tensor::ones(&[2, 3]));
        let w_t = Rc::new(Tensor::ones(&[3, 4]));
        let out = execute(&g, &[x_t, w_t]).unwrap();
        assert_eq!(out[0].item(), 24.0);
    }

    #[test]
    fn const_nodes() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let c = g.const_scalar(2.0);
        let ct = g.const_tensor(Tensor::new(vec![2], vec![10.0, 20.0]));
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let a = g.add_op(OpKind::Add, vec![m, ct]).unwrap();
        g.set_outputs(vec![a]);
        let out = execute(&g, &[Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[12.0, 24.0]);
    }

    #[test]
    fn input_shape_checked() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        g.set_outputs(vec![x]);
        assert!(execute(&g, &[Rc::new(Tensor::ones(&[3, 2]))]).is_err());
        assert!(execute(&g, &[]).is_err());
    }

    #[test]
    fn traced_callback_order() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let a = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let b = g.add_op(OpKind::Exp, vec![a]).unwrap();
        g.set_outputs(vec![b]);
        let mut seen = Vec::new();
        execute_traced(&g, &[Rc::new(Tensor::zeros(&[2]))], |id, _| seen.push(id)).unwrap();
        assert_eq!(seen, vec![a, b]);
    }
}
