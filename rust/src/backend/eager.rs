//! Eager reference backend: executes a captured graph with the CPU tensor
//! library. This is the correctness oracle for the XLA backend and the
//! executor the debugger steps through (`on_node` callback maps to dump
//! lines).
//!
//! The hot path is [`ExecPlan`]: a per-graph execution plan computed once
//! at compile time — constants pre-materialized into an env template, op
//! steps laid out in order, last-use (liveness) lists so intermediate
//! buffers are released as soon as possible, and a reusable slot arena so
//! steady-state calls do no per-call planning work and no env reallocation.
//!
//! At `--opt-level 2` the plan additionally **fuses elementwise chains**:
//! maximal runs of broadcasting-compatible unary/binary elementwise ops
//! whose interior values are consumed only inside the run collapse into a
//! [`FusedRegion`] executed as one stride-walked pass over the output —
//! broadcast inputs gathered by a chunk odometer, every op a tight loop
//! over cache-resident chunk buffers, one output allocation and **zero
//! intermediate tensors** between fused ops, with per-element math that
//! is bit-for-bit the same as the unfused per-op kernels. Fusion lives
//! entirely here, *below* the graph IR: there is no `FusedElementwise`
//! `OpKind`, so `graph::serde` / `content_hash` / trace bundles are
//! untouched (see `graph::opt` module docs).

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Mutex, TryLockError};

use crate::api::{CompiledModule, DepyfError};
use crate::graph::{Graph, NodeId, NodeKind, OpKind};
use crate::tensor::{self, Tensor};

/// Evaluate one op node against the environment. Shared by the planned and
/// traced executors, and by the optimizer's constant folder
/// (`graph::opt`), so folded constants carry exactly the bits execution
/// would produce. Tensor-library failures surface as typed
/// [`DepyfError::Tensor`] (shape vs axis vs index), not strings.
pub fn eval_op(g: &Graph, id: usize, env: &[Option<Tensor>]) -> Result<Tensor, DepyfError> {
    let (op, args) = match &g.nodes[id].kind {
        NodeKind::Op(op, args) => (op, args),
        _ => return Err(DepyfError::Backend(format!("node {} is not an op", id))),
    };
    let get = |i: usize| -> Result<&Tensor, DepyfError> {
        env[args[i]]
            .as_ref()
            .ok_or_else(|| DepyfError::Backend(format!("node {} uses unevaluated node {}", id, args[i])))
    };
    Ok(match op {
        OpKind::Add => tensor::add(get(0)?, get(1)?)?,
        OpKind::Sub => tensor::sub(get(0)?, get(1)?)?,
        OpKind::Mul => tensor::mul(get(0)?, get(1)?)?,
        OpKind::Div => tensor::div(get(0)?, get(1)?)?,
        OpKind::Pow => tensor::pow(get(0)?, get(1)?)?,
        OpKind::Maximum => tensor::maximum(get(0)?, get(1)?)?,
        OpKind::Minimum => tensor::minimum(get(0)?, get(1)?)?,
        OpKind::Neg => tensor::neg(get(0)?),
        OpKind::Relu => tensor::relu(get(0)?),
        OpKind::Gelu => tensor::gelu(get(0)?),
        OpKind::Tanh => tensor::tanh(get(0)?),
        OpKind::Sigmoid => tensor::sigmoid(get(0)?),
        OpKind::Exp => tensor::exp(get(0)?),
        OpKind::Log => tensor::log(get(0)?),
        OpKind::Sqrt => tensor::sqrt(get(0)?),
        OpKind::Abs => tensor::abs(get(0)?),
        OpKind::MatMul => tensor::matmul(get(0)?, get(1)?)?,
        OpKind::Transpose => tensor::transpose(get(0)?)?,
        OpKind::Reshape(spec) => {
            let t = get(0)?;
            let shape = tensor::reshape_infer(t.numel(), spec)?;
            t.reshape(shape)
        }
        OpKind::Permute(perm) => tensor::permute(get(0)?, perm)?,
        OpKind::Softmax => tensor::softmax(get(0)?)?,
        OpKind::Sum(ax) => tensor::sum(get(0)?, *ax)?,
        OpKind::Mean(ax) => tensor::mean(get(0)?, *ax)?,
        OpKind::Max(ax) => tensor::max_reduce(get(0)?, *ax)?,
        OpKind::Min(ax) => tensor::min_reduce(get(0)?, *ax)?,
        OpKind::LayerNorm => tensor::layernorm(get(0)?, get(1)?, get(2)?, 1e-5)?,
        OpKind::Embedding => tensor::embedding(get(0)?, get(1)?)?,
        OpKind::CrossEntropy => tensor::cross_entropy(get(0)?, get(1)?)?,
    })
}

/// Op kinds a fused region may contain: pure per-element unary/binary
/// math (broadcasting). Everything else (matmul, reductions, shape ops,
/// softmax/layernorm rows) materializes as usual.
fn fusible(op: &OpKind) -> bool {
    matches!(
        op,
        OpKind::Add
            | OpKind::Sub
            | OpKind::Mul
            | OpKind::Div
            | OpKind::Pow
            | OpKind::Maximum
            | OpKind::Minimum
            | OpKind::Neg
            | OpKind::Relu
            | OpKind::Gelu
            | OpKind::Tanh
            | OpKind::Sigmoid
            | OpKind::Exp
            | OpKind::Log
            | OpKind::Sqrt
            | OpKind::Abs
    )
}

/// Apply one fusible op over chunk slices, dispatching on the op kind
/// **once per chunk** so each arm is a tight, vectorizable loop. Every
/// arm's per-element body is the same scalar computation the unfused
/// kernels in [`tensor::ops`] use (gelu/sigmoid literally share one
/// function), so fused and unfused execution are bitwise identical.
fn apply_chunk(op: &OpKind, a: &[f32], b: &[f32], dst: &mut [f32]) {
    macro_rules! bin {
        ($f:expr) => {
            for ((d, &x), &y) in dst.iter_mut().zip(a.iter()).zip(b.iter()) {
                *d = $f(x, y);
            }
        };
    }
    macro_rules! un {
        ($f:expr) => {
            for (d, &x) in dst.iter_mut().zip(a.iter()) {
                *d = $f(x);
            }
        };
    }
    match op {
        OpKind::Add => bin!(|x, y| x + y),
        OpKind::Sub => bin!(|x, y| x - y),
        OpKind::Mul => bin!(|x, y| x * y),
        OpKind::Div => bin!(|x, y| x / y),
        OpKind::Pow => bin!(|x: f32, y: f32| x.powf(y)),
        OpKind::Maximum => bin!(f32::max),
        OpKind::Minimum => bin!(f32::min),
        OpKind::Neg => un!(|x: f32| -x),
        OpKind::Relu => un!(|x: f32| x.max(0.0)),
        OpKind::Gelu => un!(tensor::gelu_scalar),
        OpKind::Tanh => un!(f32::tanh),
        OpKind::Sigmoid => un!(tensor::sigmoid_scalar),
        OpKind::Exp => un!(f32::exp),
        OpKind::Log => un!(f32::ln),
        OpKind::Sqrt => un!(f32::sqrt),
        OpKind::Abs => un!(f32::abs),
        other => unreachable!("non-elementwise op {:?} in a fused region", other),
    }
}

/// Chunk size of the fused executor: small enough that the whole register
/// file of a region (one buffer per op) stays cache-resident, large
/// enough to amortize per-chunk dispatch.
const FUSE_CHUNK: usize = 4096;

/// Where a fused op reads each operand from.
#[derive(Clone, Copy, Debug)]
enum FusedArg {
    /// External value: index into [`FusedRegion::inputs`].
    Input(usize),
    /// Result of an earlier op in the same region (register index).
    Reg(usize),
}

#[derive(Debug)]
struct FusedOp {
    op: OpKind,
    a: FusedArg,
    /// Ignored for unary ops.
    b: FusedArg,
}

/// Reusable chunk buffers of one fused region — like the [`ExecPlan`]
/// env arena, steady-state calls allocate nothing but the output tensor.
#[derive(Debug, Default)]
struct FuseScratch {
    /// One chunk buffer per *interior* op (the root writes into the
    /// output directly, so `ops.len() - 1` buffers).
    op_buf: Vec<Vec<f32>>,
    /// One chunk buffer per broadcast (non-dense) input; dense inputs
    /// keep an empty placeholder.
    in_buf: Vec<Vec<f32>>,
}

/// A maximal run of elementwise ops executed as one chunked, stride-walked
/// pass: external inputs are read through broadcast strides onto the
/// region output's shape, interior values live in chunk-sized op buffers
/// (never materialized as tensors), and only the root node's tensor is
/// allocated.
#[derive(Debug)]
pub struct FusedRegion {
    /// The node whose env slot this region writes.
    root: NodeId,
    out_shape: Vec<usize>,
    /// Env slots read (placeholders, constants, unfused op results).
    inputs: Vec<NodeId>,
    /// Region ops in topological order; the last one produces the output.
    ops: Vec<FusedOp>,
    /// Per input: shape equals `out_shape` (read directly, no gather).
    /// Precomputed at plan time from the graph's static shapes.
    dense: Vec<bool>,
    /// Broadcast strides onto `out_shape` per non-dense input (empty for
    /// dense ones).
    strides: Vec<Vec<usize>>,
    /// Reused chunk buffers — steady-state calls reallocate nothing.
    /// A `Mutex` (uncontended in the common case) so one plan can be
    /// dispatched from many threads; a contended call falls back to a
    /// local scratch rather than blocking.
    scratch: Mutex<FuseScratch>,
}

impl FusedRegion {
    /// Number of graph ops collapsed into this region.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Execute the region: the flat output index space is walked in
    /// [`FUSE_CHUNK`]-sized chunks. Broadcast inputs are gathered into
    /// chunk buffers with a stride odometer (no div/mod per element;
    /// dense inputs are sliced directly), then every region op runs as a
    /// tight per-chunk loop over cache-resident buffers. Chunk buffers
    /// live in the region's scratch arena, so the only tensor-sized
    /// (and steady-state only) allocation is the region output.
    fn run(&self, env: &[Option<Tensor>]) -> Result<Tensor, DepyfError> {
        let mut srcs: Vec<&Tensor> = Vec::with_capacity(self.inputs.len());
        for &id in &self.inputs {
            srcs.push(env[id].as_ref().ok_or_else(|| {
                DepyfError::Backend(format!("fused region at node {} uses unevaluated node {}", self.root, id))
            })?);
        }
        let rank = self.out_shape.len();
        let n: usize = self.out_shape.iter().product();
        let chunk = n.min(FUSE_CHUNK).max(1);
        let any_gather = self.dense.iter().any(|d| !d);
        let last = self.ops.len() - 1;
        // Reused chunk buffers (the try_lock fallback covers concurrent
        // dispatch of one plan from several threads, like the env arena).
        let mut borrowed;
        let mut local;
        let scratch: &mut FuseScratch = match self.scratch.try_lock() {
            Ok(b) => {
                borrowed = b;
                &mut *borrowed
            }
            // A panicking holder leaves the buffers intact (they're
            // overwritten before use) — recover rather than degrading
            // every later call to the local-alloc path.
            Err(TryLockError::Poisoned(b)) => {
                borrowed = b.into_inner();
                &mut *borrowed
            }
            Err(TryLockError::WouldBlock) => {
                local = FuseScratch::default();
                &mut local
            }
        };
        let FuseScratch { op_buf, in_buf } = scratch;
        op_buf.resize_with(last, Vec::new);
        for buf in op_buf.iter_mut() {
            buf.resize(chunk, 0.0);
        }
        in_buf.resize_with(self.inputs.len(), Vec::new);
        for (p, buf) in in_buf.iter_mut().enumerate() {
            buf.resize(if self.dense[p] { 0 } else { chunk }, 0.0);
        }
        let mut out = vec![0f32; n];
        let mut coords = vec![0usize; rank];
        let mut gidx = vec![0usize; srcs.len()];
        let mut start = 0usize;
        while start < n {
            let len = (n - start).min(chunk);
            if any_gather {
                // Odometer walk shared by every broadcast input.
                for i in 0..len {
                    for (p, buf) in in_buf.iter_mut().enumerate() {
                        if !self.dense[p] {
                            buf[i] = srcs[p].data()[gidx[p]];
                        }
                    }
                    for ax in (0..rank).rev() {
                        coords[ax] += 1;
                        for (p, s) in self.strides.iter().enumerate() {
                            if !self.dense[p] {
                                gidx[p] += s[ax];
                            }
                        }
                        if coords[ax] < self.out_shape[ax] {
                            break;
                        }
                        coords[ax] = 0;
                        for (p, s) in self.strides.iter().enumerate() {
                            if !self.dense[p] {
                                gidx[p] -= s[ax] * self.out_shape[ax];
                            }
                        }
                    }
                }
            }
            for (k, fo) in self.ops.iter().enumerate() {
                let (done, rest) = op_buf.split_at_mut(k);
                let done: &[Vec<f32>] = done;
                let a = pick_src(fo.a, &self.dense, &srcs, in_buf, done, start, len);
                let b = pick_src(fo.b, &self.dense, &srcs, in_buf, done, start, len);
                if k == last {
                    // The root writes straight into the output tensor.
                    apply_chunk(&fo.op, a, b, &mut out[start..start + len]);
                } else {
                    apply_chunk(&fo.op, a, b, &mut rest[0][..len]);
                }
            }
            start += len;
        }
        Ok(Tensor::new(self.out_shape.clone(), out))
    }
}

/// Resolve one fused-op operand to its chunk slice: a dense input reads
/// the tensor directly at the chunk offset, a broadcast input reads its
/// gathered chunk buffer, and a register reads an earlier op's buffer.
fn pick_src<'a>(
    arg: FusedArg,
    dense: &[bool],
    srcs: &'a [&'a Tensor],
    in_buf: &'a [Vec<f32>],
    done: &'a [Vec<f32>],
    start: usize,
    len: usize,
) -> &'a [f32] {
    match arg {
        FusedArg::Input(p) if dense[p] => &srcs[p].data()[start..start + len],
        FusedArg::Input(p) => &in_buf[p][..len],
        FusedArg::Reg(r) => &done[r][..len],
    }
}

/// One execution step: an ordinary op evaluation or a fused region.
enum Step {
    Op(NodeId),
    Fused(FusedRegion),
}

impl Step {
    /// The env slot this step writes.
    fn writes(&self) -> NodeId {
        match self {
            Step::Op(id) => *id,
            Step::Fused(r) => r.root,
        }
    }
}

/// `(op, args)` of an op node, `None` for leaves.
fn node_op(g: &Graph, id: NodeId) -> Option<(&OpKind, &[NodeId])> {
    match &g.nodes[id].kind {
        NodeKind::Op(op, args) => Some((op, args.as_slice())),
        _ => None,
    }
}

/// Group fusible elementwise ops into regions. Regions are rooted at the
/// *last* node of a run (largest id) and grown backwards through args: a
/// producer joins only when it is itself fusible, not a graph output,
/// consumed exclusively inside the region, and its shape broadcasts onto
/// the root's shape. Deterministic: roots are visited in descending node
/// order, membership grows to a fixpoint.
fn fuse_steps(g: &Graph) -> Vec<Step> {
    let n = g.nodes.len();
    let mut consumers: Vec<Vec<NodeId>> = vec![Vec::new(); n];
    for (id, node) in g.nodes.iter().enumerate() {
        if let NodeKind::Op(_, args) = &node.kind {
            for &a in args {
                consumers[a].push(id);
            }
        }
    }
    let is_output: Vec<bool> = {
        let mut v = vec![false; n];
        for &o in &g.outputs {
            v[o] = true;
        }
        v
    };
    let broadcasts_onto = |inner: NodeId, root: NodeId| -> bool {
        tensor::broadcast_shapes(&g.nodes[inner].shape, &g.nodes[root].shape)
            .map(|s| s == g.nodes[root].shape)
            .unwrap_or(false)
    };
    let mut region_of: Vec<Option<usize>> = vec![None; n];
    let mut regions: Vec<Vec<NodeId>> = Vec::new();
    for root in (0..n).rev() {
        if region_of[root].is_some() {
            continue;
        }
        let Some((op, _)) = node_op(g, root) else { continue };
        if !fusible(op) {
            continue;
        }
        let mut members = vec![root];
        // Fixpoint growth: a producer may only join once every one of its
        // consumers has (e.g. a value feeding two members).
        loop {
            let mut grew = false;
            let mut mi = 0;
            while mi < members.len() {
                let m = members[mi];
                mi += 1;
                let (_, args) = node_op(g, m).expect("members are ops");
                for &a in args.iter() {
                    if members.contains(&a) || region_of[a].is_some() || is_output[a] {
                        continue;
                    }
                    let Some((aop, _)) = node_op(g, a) else { continue };
                    if !fusible(aop)
                        || !consumers[a].iter().all(|c| members.contains(c))
                        || !broadcasts_onto(a, root)
                    {
                        continue;
                    }
                    members.push(a);
                    grew = true;
                }
            }
            if !grew {
                break;
            }
        }
        if members.len() >= 2 {
            let rid = regions.len();
            for &m in &members {
                region_of[m] = Some(rid);
            }
            members.sort_unstable();
            regions.push(members);
        }
    }
    // Emit steps in node order; a region materializes at its root.
    let mut steps = Vec::new();
    for (id, node) in g.nodes.iter().enumerate() {
        if !matches!(node.kind, NodeKind::Op(..)) {
            continue;
        }
        match region_of[id] {
            None => steps.push(Step::Op(id)),
            Some(rid) => {
                let members = &regions[rid];
                if *members.last().unwrap() != id {
                    continue; // interior member: evaluated inside the region
                }
                let mut reg_index: HashMap<NodeId, usize> = HashMap::new();
                let mut inputs: Vec<NodeId> = Vec::new();
                let mut ops = Vec::with_capacity(members.len());
                for (k, &m) in members.iter().enumerate() {
                    reg_index.insert(m, k);
                    let (op, args) = node_op(g, m).expect("members are ops");
                    let mut resolve = |a: NodeId| -> FusedArg {
                        if let Some(&r) = reg_index.get(&a) {
                            return FusedArg::Reg(r);
                        }
                        match inputs.iter().position(|&x| x == a) {
                            Some(p) => FusedArg::Input(p),
                            None => {
                                inputs.push(a);
                                FusedArg::Input(inputs.len() - 1)
                            }
                        }
                    };
                    let a = resolve(args[0]);
                    let b = if args.len() > 1 { resolve(args[1]) } else { a };
                    ops.push(FusedOp { op: op.clone(), a, b });
                }
                let out_shape = g.nodes[id].shape.clone();
                let dense: Vec<bool> =
                    inputs.iter().map(|&a| g.nodes[a].shape == out_shape).collect();
                let strides: Vec<Vec<usize>> = inputs
                    .iter()
                    .zip(dense.iter())
                    .map(|(&a, &d)| {
                        if d {
                            Vec::new()
                        } else {
                            tensor::broadcast_strides_for(&g.nodes[a].shape, out_shape.len())
                        }
                    })
                    .collect();
                steps.push(Step::Fused(FusedRegion {
                    root: id,
                    out_shape,
                    inputs,
                    ops,
                    dense,
                    strides,
                    scratch: Mutex::new(FuseScratch::default()),
                }));
            }
        }
    }
    steps
}

/// A per-graph execution plan: everything derivable from the graph alone,
/// computed once when the backend compiles it instead of on every call.
pub struct ExecPlan {
    graph: Arc<Graph>,
    /// Env template with constants pre-materialized (`ConstScalar` /
    /// `ConstTensor` nodes); tensors share storage via `Arc`, so cloning
    /// the template per call is pointer-cheap.
    template: Vec<Option<Tensor>>,
    /// Execution steps in order: plain op evaluations and fused
    /// elementwise regions (graph nodes are topologically ordered by
    /// construction; placeholders and constants are skipped).
    steps: Vec<Step>,
    /// Parallel to `steps`: env slots whose value dies after that step
    /// (not used by any later step and not a graph output). Freed eagerly
    /// so peak memory is bounded by live values, not graph size.
    dead_after: Vec<Vec<NodeId>>,
    /// Reused env buffer — steady-state calls reallocate nothing. A
    /// `Mutex` so the plan is `Sync`; concurrent callers that lose the
    /// `try_lock` race use a local env instead of serializing.
    arena: Mutex<Vec<Option<Tensor>>>,
}

impl ExecPlan {
    /// Plan with elementwise fusion on (the `--opt-level 2` executor).
    pub fn new(graph: Arc<Graph>) -> ExecPlan {
        ExecPlan::with_fusion(graph, true)
    }

    /// Plan without fusion: one step per op node, exactly the pre-fusion
    /// executor (`--opt-level 0|1`).
    pub fn unfused(graph: Arc<Graph>) -> ExecPlan {
        ExecPlan::with_fusion(graph, false)
    }

    pub fn with_fusion(graph: Arc<Graph>, fuse: bool) -> ExecPlan {
        let mut template: Vec<Option<Tensor>> = vec![None; graph.nodes.len()];
        for (id, node) in graph.nodes.iter().enumerate() {
            match &node.kind {
                NodeKind::ConstScalar(v) => template[id] = Some(Tensor::scalar(*v as f32)),
                NodeKind::ConstTensor(t) => template[id] = Some(t.clone()),
                _ => {}
            }
        }
        let steps = if fuse {
            fuse_steps(&graph)
        } else {
            graph
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| matches!(n.kind, NodeKind::Op(..)))
                .map(|(id, _)| Step::Op(id))
                .collect()
        };
        // Liveness: a slot dies after the last step that reads it, unless
        // it is a graph output (outputs stay live through collection).
        // Fused regions read only their external inputs; interior member
        // slots are never written, so they never appear here.
        let mut last_use: Vec<Option<usize>> = vec![None; graph.nodes.len()];
        for (si, step) in steps.iter().enumerate() {
            match step {
                Step::Op(id) => {
                    if let NodeKind::Op(_, args) = &graph.nodes[*id].kind {
                        for &a in args {
                            last_use[a] = Some(si);
                        }
                    }
                }
                Step::Fused(r) => {
                    for &a in &r.inputs {
                        last_use[a] = Some(si);
                    }
                }
            }
        }
        let mut dead_after: Vec<Vec<NodeId>> = vec![Vec::new(); steps.len()];
        for (node, lu) in last_use.iter().enumerate() {
            if let Some(si) = lu {
                if !graph.outputs.contains(&node) {
                    dead_after[*si].push(node);
                }
            }
        }
        ExecPlan { graph, template, steps, dead_after, arena: Mutex::new(Vec::new()) }
    }

    pub fn graph(&self) -> &Arc<Graph> {
        &self.graph
    }

    /// How many fused regions the plan contains (0 when unfused).
    pub fn fused_regions(&self) -> usize {
        self.steps.iter().filter(|s| matches!(s, Step::Fused(_))).count()
    }

    /// Graph ops collapsed into fused regions (members, roots included).
    pub fn fused_ops(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                Step::Fused(r) => r.len(),
                Step::Op(_) => 0,
            })
            .sum()
    }

    /// Execute the plan. Reuses the internal arena when free (the planned
    /// executor never re-enters itself; the fallback covers exotic
    /// aliasing of one plan from two callables).
    pub fn run(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        let g = &*self.graph;
        g.check_inputs(inputs)?;
        let mut borrowed;
        let mut local;
        let env: &mut Vec<Option<Tensor>> = match self.arena.try_lock() {
            Ok(b) => {
                borrowed = b;
                &mut *borrowed
            }
            // Poison recovery: the arena is fully reset below before any
            // slot is read, so a panicked holder's state is harmless.
            Err(TryLockError::Poisoned(b)) => {
                borrowed = b.into_inner();
                &mut *borrowed
            }
            Err(TryLockError::WouldBlock) => {
                local = Vec::new();
                &mut local
            }
        };
        env.clear();
        env.extend(self.template.iter().cloned());
        for (slot, input) in g.inputs.iter().zip(inputs.iter()) {
            env[*slot] = Some((**input).clone());
        }
        for (si, step) in self.steps.iter().enumerate() {
            let r = match step {
                Step::Op(id) => eval_op(g, *id, env)?,
                Step::Fused(region) => region.run(env)?,
            };
            env[step.writes()] = Some(r);
            for &dead in &self.dead_after[si] {
                env[dead] = None;
            }
        }
        let out = g
            .outputs
            .iter()
            .map(|&o| {
                env[o].clone().ok_or_else(|| DepyfError::Backend(format!("output node {} unevaluated", o)))
            })
            .collect();
        // Drop live tensors now rather than holding them until the next
        // call (the arena itself keeps only empty slots).
        env.clear();
        out
    }
}

/// The eager backend's [`CompiledModule`]: an [`ExecPlan`] built once at
/// lower time, with an optional custom `backend_name` stamp (used by the
/// fallback path and by custom backends that delegate execution here).
pub struct EagerModule {
    plan: ExecPlan,
    backend_name: String,
}

impl EagerModule {
    pub fn new(graph: Arc<Graph>) -> EagerModule {
        EagerModule::with_name(graph, "eager".into())
    }

    pub fn with_name(graph: Arc<Graph>, backend_name: String) -> EagerModule {
        EagerModule { plan: ExecPlan::new(graph), backend_name }
    }

    /// Explicit fusion control — backends thread `OptLevel::fuses()` here
    /// so `--opt-level 0|1` really runs the pre-fusion executor.
    pub fn with_fusion(graph: Arc<Graph>, backend_name: String, fuse: bool) -> EagerModule {
        EagerModule { plan: ExecPlan::with_fusion(graph, fuse), backend_name }
    }

    pub fn from_plan(plan: ExecPlan, backend_name: String) -> EagerModule {
        EagerModule { plan, backend_name }
    }

    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }
}

impl CompiledModule for EagerModule {
    fn call(&self, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
        self.plan.run(inputs)
    }

    fn backend_name(&self) -> &str {
        &self.backend_name
    }
}

/// Execute with a per-node callback (node id, result) — used by the
/// debugger to step through `__compiled_fn` dumps line by line. Walks
/// nodes directly (no plan): the debugger path trades speed for the
/// callback ordering guarantee.
pub fn execute_traced(
    g: &Graph,
    inputs: &[Rc<Tensor>],
    mut on_node: impl FnMut(usize, &Tensor),
) -> Result<Vec<Tensor>, DepyfError> {
    g.check_inputs(inputs)?;
    let mut env: Vec<Option<Tensor>> = vec![None; g.nodes.len()];
    for (slot, input) in g.inputs.iter().zip(inputs.iter()) {
        env[*slot] = Some((**input).clone());
    }
    for (id, node) in g.nodes.iter().enumerate() {
        match &node.kind {
            NodeKind::Placeholder { .. } => {}
            NodeKind::ConstScalar(v) => env[id] = Some(Tensor::scalar(*v as f32)),
            NodeKind::ConstTensor(t) => env[id] = Some(t.clone()),
            NodeKind::Op(..) => {
                let r = eval_op(g, id, &env)?;
                on_node(id, &r);
                env[id] = Some(r);
            }
        }
    }
    g.outputs
        .iter()
        .map(|&o| env[o].clone().ok_or_else(|| DepyfError::Backend(format!("output node {} unevaluated", o))))
        .collect()
}

/// Plain one-shot execution (tests, oracles). Hot callers should build an
/// [`ExecPlan`] once instead.
pub fn execute(g: &Graph, inputs: &[Rc<Tensor>]) -> Result<Vec<Tensor>, DepyfError> {
    execute_traced(g, inputs, |_, _| {})
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::tensor::Rng;

    #[test]
    fn executes_mlp_block() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let w = g.placeholder("w", &[3, 4]);
        let m = g.add_op(OpKind::MatMul, vec![x, w]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![m]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![r]).unwrap();
        g.set_outputs(vec![s]);
        let x_t = Rc::new(Tensor::ones(&[2, 3]));
        let w_t = Rc::new(Tensor::ones(&[3, 4]));
        let out = execute(&g, &[x_t, w_t]).unwrap();
        assert_eq!(out[0].item(), 24.0);
    }

    #[test]
    fn const_nodes() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let c = g.const_scalar(2.0);
        let ct = g.const_tensor(Tensor::new(vec![2], vec![10.0, 20.0]));
        let m = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let a = g.add_op(OpKind::Add, vec![m, ct]).unwrap();
        g.set_outputs(vec![a]);
        let out = execute(&g, &[Rc::new(Tensor::new(vec![2], vec![1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[12.0, 24.0]);
    }

    #[test]
    fn input_shape_checked() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        g.set_outputs(vec![x]);
        assert!(execute(&g, &[Rc::new(Tensor::ones(&[3, 2]))]).is_err());
        assert!(execute(&g, &[]).is_err());
    }

    #[test]
    fn traced_callback_order() {
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2]);
        let a = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let b = g.add_op(OpKind::Exp, vec![a]).unwrap();
        g.set_outputs(vec![b]);
        let mut seen = Vec::new();
        execute_traced(&g, &[Rc::new(Tensor::zeros(&[2]))], |id, _| seen.push(id)).unwrap();
        assert_eq!(seen, vec![a, b]);
    }

    fn mlp(n: usize, d: usize) -> Graph {
        let mut g = Graph::new("plan_mlp");
        let x = g.placeholder("x", &[n, d]);
        let w1 = g.placeholder("w1", &[d, d]);
        let w2 = g.placeholder("w2", &[d, d]);
        let c = g.const_scalar(0.5);
        let h = g.add_op(OpKind::MatMul, vec![x, w1]).unwrap();
        let r = g.add_op(OpKind::Relu, vec![h]).unwrap();
        let sc = g.add_op(OpKind::Mul, vec![r, c]).unwrap();
        let o = g.add_op(OpKind::MatMul, vec![sc, w2]).unwrap();
        let sm = g.add_op(OpKind::Softmax, vec![o]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![sm]).unwrap();
        g.set_outputs(vec![s]);
        g
    }

    #[test]
    fn plan_matches_unplanned_execution() {
        let g = Arc::new(mlp(4, 8));
        let plan = ExecPlan::new(Arc::clone(&g));
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            let inputs: Vec<Rc<Tensor>> = vec![
                Rc::new(Tensor::randn(&[4, 8], &mut rng)),
                Rc::new(Tensor::randn(&[8, 8], &mut rng)),
                Rc::new(Tensor::randn(&[8, 8], &mut rng)),
            ];
            let via_plan = plan.run(&inputs).unwrap();
            let via_walk = execute(&g, &inputs).unwrap();
            assert_eq!(via_plan.len(), via_walk.len());
            for (a, b) in via_plan.iter().zip(via_walk.iter()) {
                assert!(a.allclose(b, 0.0), "plan diverged from reference");
            }
        }
    }

    #[test]
    fn plan_keeps_intermediate_outputs_alive() {
        // An intermediate that is ALSO an output must survive dead-slot
        // freeing even though later steps consume it.
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[3]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let e = g.add_op(OpKind::Exp, vec![r]).unwrap();
        g.set_outputs(vec![r, e]);
        let plan = ExecPlan::new(Arc::new(g));
        let out = plan.run(&[Rc::new(Tensor::new(vec![3], vec![-1.0, 0.0, 1.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 0.0, 1.0]);
        assert!((out[1].data()[2] - 1.0f32.exp()).abs() < 1e-6);
    }

    fn assert_bitwise_eq(a: &[Tensor], b: &[Tensor], why: &str) {
        assert_eq!(a.len(), b.len(), "{}", why);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.shape(), y.shape(), "{}", why);
            let eq = x.data().iter().zip(y.data().iter()).all(|(p, q)| p.to_bits() == q.to_bits());
            assert!(eq, "{}: {:?} vs {:?}", why, x, y);
        }
    }

    /// Broadcast-heavy elementwise chain: bias add ([d] onto [n,d]), const
    /// scale, gelu, residual — the fusion candidate shape.
    fn elementwise_chain() -> Graph {
        let mut g = Graph::new("fuse");
        let x = g.placeholder("x", &[3, 4]);
        let b = g.placeholder("b", &[4]);
        let c = g.const_scalar(0.7);
        let t = g.add_op(OpKind::Mul, vec![x, c]).unwrap();
        let t2 = g.add_op(OpKind::Add, vec![t, b]).unwrap();
        let a = g.add_op(OpKind::Gelu, vec![t2]).unwrap();
        let s = g.add_op(OpKind::Sigmoid, vec![a]).unwrap();
        let r = g.add_op(OpKind::Add, vec![s, x]).unwrap();
        g.set_outputs(vec![r]);
        g
    }

    #[test]
    fn fused_plan_is_bitwise_equal_to_unfused_and_traced() {
        let g = Arc::new(elementwise_chain());
        let fused = ExecPlan::new(Arc::clone(&g));
        let unfused = ExecPlan::unfused(Arc::clone(&g));
        assert!(fused.fused_regions() >= 1, "chain must fuse");
        assert!(fused.fused_ops() >= 4, "{}", fused.fused_ops());
        assert_eq!(unfused.fused_regions(), 0);
        let mut rng = Rng::new(0xF5ED);
        for _ in 0..4 {
            let inputs: Vec<Rc<Tensor>> = vec![
                Rc::new(Tensor::randn(&[3, 4], &mut rng)),
                Rc::new(Tensor::randn(&[4], &mut rng)),
            ];
            let f = fused.run(&inputs).unwrap();
            let u = unfused.run(&inputs).unwrap();
            let t = execute(&g, &inputs).unwrap();
            assert_bitwise_eq(&f, &u, "fused vs unfused");
            assert_bitwise_eq(&f, &t, "fused vs traced");
        }
    }

    #[test]
    fn fusion_respects_outputs_and_external_consumers() {
        // An interior value that is also a graph output (or consumed by a
        // non-fusible op) must stay materialized.
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[4]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let e = g.add_op(OpKind::Exp, vec![r]).unwrap();
        g.set_outputs(vec![r, e]);
        let plan = ExecPlan::new(Arc::new(g));
        // r is an output: the two ops cannot collapse into one region.
        assert_eq!(plan.fused_regions(), 0);
        let out = plan.run(&[Rc::new(Tensor::new(vec![4], vec![-1.0, 0.0, 1.0, 2.0]))]).unwrap();
        assert_eq!(out[0].data(), &[0.0, 0.0, 1.0, 2.0]);

        // A value consumed by a reduction (non-fusible) stays out too.
        let mut g = Graph::new("g2");
        let x = g.placeholder("x", &[4]);
        let r = g.add_op(OpKind::Relu, vec![x]).unwrap();
        let t = g.add_op(OpKind::Tanh, vec![r]).unwrap();
        let s = g.add_op(OpKind::Sum(None), vec![r]).unwrap();
        let m = g.add_op(OpKind::Add, vec![t, s]).unwrap();
        g.set_outputs(vec![m]);
        let g = Arc::new(g);
        let plan = ExecPlan::new(Arc::clone(&g));
        let mut rng = Rng::new(3);
        let inputs = vec![Rc::new(Tensor::randn(&[4], &mut rng))];
        assert_bitwise_eq(&plan.run(&inputs).unwrap(), &execute(&g, &inputs).unwrap(), "mixed");
    }

    #[test]
    fn fusion_recomputes_smaller_intermediates_exactly() {
        // An interior value of smaller shape than the region output
        // (bias-side chain) is recomputed per output element — bitwise
        // identical to materializing it.
        let mut g = Graph::new("g");
        let x = g.placeholder("x", &[2, 3]);
        let b = g.placeholder("b", &[3]);
        let nb = g.add_op(OpKind::Neg, vec![b]).unwrap(); // shape [3]
        let a = g.add_op(OpKind::Add, vec![x, nb]).unwrap(); // shape [2,3]
        let r = g.add_op(OpKind::Relu, vec![a]).unwrap();
        g.set_outputs(vec![r]);
        let g = Arc::new(g);
        let plan = ExecPlan::new(Arc::clone(&g));
        assert_eq!(plan.fused_regions(), 1);
        assert_eq!(plan.fused_ops(), 3);
        let mut rng = Rng::new(9);
        let inputs: Vec<Rc<Tensor>> =
            vec![Rc::new(Tensor::randn(&[2, 3], &mut rng)), Rc::new(Tensor::randn(&[3], &mut rng))];
        assert_bitwise_eq(&plan.run(&inputs).unwrap(), &execute(&g, &inputs).unwrap(), "recompute");
    }

    #[test]
    fn matmul_heavy_graphs_gain_no_regions() {
        let g = Arc::new(mlp(4, 8));
        let plan = ExecPlan::new(Arc::clone(&g));
        // mlp: matmul/softmax/sum break the chain; relu+mul(c) still fuse.
        assert_eq!(plan.fused_regions(), 1);
        assert_eq!(plan.fused_ops(), 2);
    }

    #[test]
    fn plan_checks_inputs_like_reference() {
        let g = Arc::new(mlp(2, 4));
        let plan = ExecPlan::new(Arc::clone(&g));
        assert!(plan.run(&[]).is_err());
        assert!(plan
            .run(&[
                Rc::new(Tensor::ones(&[4, 2])), // transposed: wrong shape
                Rc::new(Tensor::ones(&[4, 4])),
                Rc::new(Tensor::ones(&[4, 4])),
            ])
            .is_err());
    }
}
